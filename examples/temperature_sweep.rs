//! Temperature sensitivity (paper Sec. 8.3): ChargeCache is calibrated at
//! the worst-case 85 C, so its grants are safe at any temperature — and
//! colder devices leak slower, so the circuit layer *allows* bigger
//! reductions at low temperature (the AL-DRAM comparison point).
//!
//! ```sh
//! cargo run --release --example temperature_sweep
//! ```

use chargecache::runtime::charge_model::timing_table_or_analytic;

fn main() {
    println!("Legal tRCD/tRAS reduction vs temperature (from the circuit");
    println!("layer: JAX/Pallas AOT artifacts via PJRT when built)\n");
    println!("temp    age=0.125ms      age=1ms        age=8ms        age=64ms");
    for temp in [25.0, 45.0, 55.0, 65.0, 75.0, 85.0] {
        let (table, from_hlo) = timing_table_or_analytic(temp, 1.25);
        print!("{temp:>4}C");
        for age in [0.125e-3, 1e-3, 8e-3, 64e-3] {
            let (rcd, ras) = table.reduction_cycles(age);
            print!("   [-{rcd:>2}/-{ras:>2}] cyc");
        }
        println!("{}", if from_hlo { "" } else { "  (analytic)" });
    }
    println!("\nreading: at the paper's 1 ms duration the grant is -4/-8 at");
    println!("85 C — and remains valid (or grows) at every lower temperature,");
    println!("unlike AL-DRAM which loses its margin as devices heat up.");
}
