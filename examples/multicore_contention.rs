//! Multiprogrammed contention study: how bank conflicts grow with core
//! count and why that amplifies ChargeCache (paper Sec. 6.3's analysis of
//! the eight-core results).
//!
//! ```sh
//! cargo run --release --example multicore_contention
//! ```

use chargecache::config::SystemConfig;
use chargecache::coordinator::parallel_map;
use chargecache::latency::MechanismKind;
use chargecache::sim::System;

fn main() {
    println!("cores  RLTL@1ms  CC-hit%   speedup(CC)   RMPKC");
    let counts = [1usize, 2, 4, 8];
    let rows = parallel_map(counts.len(), |i| {
        let n = counts[i];
        let mut cfg = SystemConfig::multi_core(n);
        cfg.insts_per_core = 120_000;
        cfg.warmup_cpu_cycles = 60_000;
        let base = System::new_mix(&cfg, MechanismKind::Baseline, 7).run();
        let cc = System::new_mix(&cfg, MechanismKind::ChargeCache, 7).run();
        let tput_base: f64 = base.core_ipc.iter().sum();
        let tput_cc: f64 = cc.core_ipc.iter().sum();
        (
            n,
            cc.rltl_at_ms(1.0),
            cc.reduced_act_fraction(),
            tput_cc / tput_base,
            base.rmpkc(),
        )
    });
    for (n, rltl, hits, speedup, rmpkc) in rows {
        println!(
            "{n:>5}  {:>7.1}%  {:>6.1}%  {:>11.2}%  {rmpkc:>6.2}",
            rltl * 100.0,
            hits * 100.0,
            (speedup - 1.0) * 100.0
        );
    }
    println!("\npaper: more cores -> more bank conflicts -> higher RLTL ->");
    println!("more HCRAC hits -> larger ChargeCache speedup (8.6% avg at 8 cores)");
}
