//! Quickstart: simulate one workload under Baseline vs ChargeCache and
//! print the headline comparison.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use chargecache::config::SystemConfig;
use chargecache::latency::MechanismKind;
use chargecache::sim::System;
use chargecache::trace::Profile;

fn main() {
    // The paper's single-core configuration (Table 1), scaled-down horizon.
    let mut cfg = SystemConfig::single_core();
    cfg.insts_per_core = 300_000;
    cfg.warmup_cpu_cycles = 150_000;

    let workload = Profile::by_name("tpcc64").expect("known workload");
    println!("workload: {} (working set {} MiB)", workload.name, workload.ws_bytes() >> 20);

    let base = System::new(&cfg, MechanismKind::Baseline, &[workload]).run();
    let cc = System::new(&cfg, MechanismKind::ChargeCache, &[workload]).run();

    println!("\n              {:>12} {:>12}", "Baseline", "ChargeCache");
    println!("IPC           {:>12.4} {:>12.4}", base.ipc(), cc.ipc());
    println!("cycles        {:>12} {:>12}", base.cpu_cycles, cc.cpu_cycles);
    println!("activations   {:>12} {:>12}", base.acts(), cc.acts());
    println!(
        "reduced ACTs  {:>11.1}% {:>11.1}%",
        base.reduced_act_fraction() * 100.0,
        cc.reduced_act_fraction() * 100.0
    );
    println!(
        "read latency  {:>12.1} {:>12.1}  (bus cycles)",
        base.avg_read_latency(),
        cc.avg_read_latency()
    );
    println!(
        "DRAM energy   {:>11.1}uJ {:>11.1}uJ",
        base.energy.total_nj() / 1000.0,
        cc.energy.total_nj() / 1000.0
    );
    println!("\nspeedup: {:.2}%", (cc.ipc() / base.ipc() - 1.0) * 100.0);
    println!(
        "1ms-RLTL: {:.0}% of activations re-open a recently-precharged row",
        cc.rltl_at_ms(1.0) * 100.0
    );
}
