//! Row-Level Temporal Locality analysis (the paper's Sec. 3 observation):
//! measure t-RLTL per workload and show how bank conflicts create it.
//!
//! ```sh
//! cargo run --release --example rltl_analysis
//! ```

use chargecache::analysis::rltl::RLTL_INTERVALS_MS;
use chargecache::config::SystemConfig;
use chargecache::coordinator::parallel_map;
use chargecache::latency::MechanismKind;
use chargecache::sim::System;
use chargecache::trace::PROFILES;

fn main() {
    let mut cfg = SystemConfig::single_core();
    cfg.insts_per_core = 200_000;
    cfg.warmup_cpu_cycles = 100_000;

    println!("t-RLTL per workload (fraction of activations that re-open a");
    println!("row precharged less than t ago) — paper Fig. 1 companion\n");

    let results = parallel_map(PROFILES.len(), |i| {
        let r = System::new(&cfg, MechanismKind::Baseline, &[&PROFILES[i]]).run();
        (PROFILES[i].name, r)
    });

    print!("{:>12} {:>8}", "workload", "RMPKC");
    for ms in [0.125, 1.0, 8.0, 32.0] {
        print!(" {:>8}", format!("{ms}ms"));
    }
    println!("  reuse-dist");
    for (name, r) in &results {
        print!("{:>12} {:>8.2}", name, r.rmpkc());
        for ms in [0.125, 1.0, 8.0, 32.0] {
            print!(" {:>7.1}%", r.rltl_at_ms(ms) * 100.0);
        }
        println!();
    }

    // Aggregate like the paper: activation-weighted average.
    let acts: u64 = results.iter().map(|(_, r)| r.acts()).sum();
    println!("\nactivation-weighted average RLTL:");
    for (i, &ms) in RLTL_INTERVALS_MS.iter().enumerate() {
        let avg: f64 = results
            .iter()
            .map(|(_, r)| r.rltl[i] * r.acts() as f64)
            .sum::<f64>()
            / acts.max(1) as f64;
        println!("  t = {ms:>7} ms : {:>5.1}%", avg * 100.0);
    }
    println!("\npaper: 83% at 1 ms (single-core average)");
}
