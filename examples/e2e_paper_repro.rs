//! End-to-end driver: exercises the FULL stack on a real (small) workload
//! suite and reports every headline metric of the paper in one run —
//! circuit layer (PJRT-loaded Pallas/JAX artifacts) -> timing tables ->
//! cycle-accurate simulation -> energy/area models.
//!
//! This is the repo's "proof all layers compose" run (recorded in
//! EXPERIMENTS.md):
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_paper_repro
//! ```

use chargecache::coordinator::experiments::{fig1, run_suite, ExperimentScale};
use chargecache::energy::HcracCost;
use chargecache::runtime::charge_model::timing_table_or_analytic;
use chargecache::config::SystemConfig;

fn main() {
    let scale = ExperimentScale {
        insts_per_core: 150_000,
        warmup_cycles: 75_000,
        mixes: 6,
        ..ExperimentScale::default()
    };

    // --- Circuit layer (L1/L2 via PJRT) ------------------------------
    let (table, from_hlo) = timing_table_or_analytic(85.0, 1.25);
    let (rcd, ras) = table.reduction_cycles(1e-3);
    println!("== Circuit layer ({}) ==", if from_hlo { "AOT HLO via PJRT" } else { "analytic fallback" });
    let (rcd_ns, ras_ns) = table.reduction_ns(1e-3);
    println!("1 ms-old row: tRCD -{rcd_ns:.2} ns / tRAS -{ras_ns:.2} ns -> -{rcd}/-{ras} cycles");
    println!("paper Sec. 6.2: -4.5 ns / -9.6 ns -> -4/-8 cycles\n");

    // --- Fig. 1 -------------------------------------------------------
    println!("== Fig. 1: RLTL ==");
    for (ms, single, eight) in fig1(scale) {
        if [0.125, 1.0, 8.0, 32.0].contains(&ms) {
            println!("t={ms:>6} ms: single {:>5.1}%  eight {:>5.1}%", single * 100.0, eight * 100.0);
        }
    }
    println!("paper: 83% / 89% at 1 ms\n");

    // --- Fig. 4 + Fig. 5 ----------------------------------------------
    println!("== Fig. 4/5: performance and energy ==");
    let suite = run_suite(scale, true);
    let rows_a = suite.fig4a();
    let avg_a = |i: usize| {
        rows_a.iter().map(|r| r.speedups[i].1 - 1.0).sum::<f64>() / rows_a.len() as f64
    };
    let max_a =
        |i: usize| rows_a.iter().map(|r| r.speedups[i].1 - 1.0).fold(f64::MIN, f64::max);
    println!(
        "single-core: CC avg {:.1}% (paper 2.1%) max {:.1}% (paper 9.3%); NUAT avg {:.1}%; LL-DRAM avg {:.1}%",
        avg_a(0) * 100.0, max_a(0) * 100.0, avg_a(1) * 100.0, avg_a(3) * 100.0
    );
    let rows_b = suite.fig4b();
    let avg_b = |i: usize| {
        rows_b.iter().map(|r| r.speedups[i].1 - 1.0).sum::<f64>() / rows_b.len() as f64
    };
    println!(
        "eight-core : CC avg {:.1}% (paper 8.6%); NUAT {:.1}% (paper 2.5%); CC+NUAT {:.1}% (paper 9.6%); LL-DRAM {:.1}% (paper ~13.4%)",
        avg_b(0) * 100.0, avg_b(1) * 100.0, avg_b(2) * 100.0, avg_b(3) * 100.0
    );
    let hit = rows_b.iter().map(|r| r.speedups[0].2).sum::<f64>() / rows_b.len() as f64;
    println!("reduced-latency activations (8-core CC): {:.0}% (paper 67%)", hit * 100.0);

    let fig5 = suite.fig5(true);
    let cc_e: Vec<f64> = fig5.iter().map(|(_, pm)| pm[0].1).collect();
    let avg_e = cc_e.iter().sum::<f64>() / cc_e.len() as f64;
    let max_e = cc_e.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "DRAM energy (8-core CC): avg -{:.1}% max -{:.1}% (paper 7.9% / 14.1%)\n",
        avg_e * 100.0,
        max_e * 100.0
    );

    // --- Sec. 6.5 ------------------------------------------------------
    println!("== Sec. 6.5: overhead ==");
    let cost = HcracCost::of(&SystemConfig::eight_core(), 170e6);
    println!(
        "storage {} B (paper 5376 B), area {:.3} mm^2 (paper 0.022), power {:.3} mW (paper 0.149)",
        cost.storage_bytes, cost.area_mm2, cost.total_mw()
    );
}
