//! Minimal benchmark harness (offline build — no criterion): warmup +
//! timed iterations, reporting mean/min/throughput. Each `[[bench]]`
//! target is a plain `main()` that both *times* its figure's pipeline and
//! *prints* the regenerated figure rows, so `cargo bench` doubles as the
//! reproduction driver.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<3} mean={:>12.3?} min={:>12.3?}",
            self.name, self.iters, self.mean, self.min
        );
    }

    /// Report with a derived throughput figure.
    pub fn report_throughput(&self, units: f64, unit_name: &str) {
        let per_sec = units / self.mean.as_secs_f64();
        println!(
            "bench {:<40} iters={:<3} mean={:>12.3?} min={:>12.3?}  {:>12.0} {unit_name}/s",
            self.name, self.iters, self.mean, self.min, per_sec
        );
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut total = Duration::ZERO;
    let mut min = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed();
        total += dt;
        min = min.min(dt);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters.max(1),
        min,
    }
}

/// `--quick` support for CI-speed runs.
pub fn is_quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok()
}
