//! Fig. 1 bench: regenerate the average t-RLTL series (single- and
//! eight-core) and time the analysis pipeline.

#[path = "harness.rs"]
mod harness;

use chargecache::coordinator::experiments::{fig1, ExperimentScale};

fn main() {
    let scale = if harness::is_quick() {
        ExperimentScale {
            insts_per_core: 20_000,
            warmup_cycles: 8_000,
            mixes: 2,
            ..ExperimentScale::default()
        }
    } else {
        ExperimentScale {
            insts_per_core: 120_000,
            warmup_cycles: 60_000,
            mixes: 8,
            ..ExperimentScale::default()
        }
    };

    let mut rows = Vec::new();
    let r = harness::bench("fig1/rltl_suite", 0, 1, || {
        rows = fig1(scale);
    });
    r.report();

    println!("\nFig. 1 — average t-RLTL (paper: 83%/89% at 1 ms)");
    println!("{:>8} {:>9} {:>9}", "t(ms)", "1-core", "8-core");
    for (ms, s, e) in &rows {
        println!("{:>8} {:>8.1}% {:>8.1}%", ms, s * 100.0, e * 100.0);
    }
}
