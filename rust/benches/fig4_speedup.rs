//! Fig. 4 bench: regenerate the speedup comparison (ChargeCache / NUAT /
//! CC+NUAT / LL-DRAM) for single-core and eight-core workloads.

#[path = "harness.rs"]
mod harness;

use chargecache::coordinator::experiments::{run_suite, ExperimentScale, SuiteResults};

fn main() {
    let scale = if harness::is_quick() {
        ExperimentScale {
            insts_per_core: 15_000,
            warmup_cycles: 6_000,
            mixes: 2,
            ..ExperimentScale::default()
        }
    } else {
        ExperimentScale {
            insts_per_core: 100_000,
            warmup_cycles: 50_000,
            mixes: 8,
            ..ExperimentScale::default()
        }
    };

    let mut suite: Option<SuiteResults> = None;
    let r = harness::bench("fig4/full_suite", 0, 1, || {
        suite = Some(run_suite(scale, true));
    });
    r.report();
    let suite = suite.unwrap();

    println!("\nFig. 4a — single-core speedup (sorted by RMPKC):");
    println!("{:>12} {:>8} {:>7} {:>7} {:>8} {:>8}", "workload", "RMPKC", "CC", "NUAT", "CC+NUAT", "LL-DRAM");
    for row in suite.fig4a() {
        print!("{:>12} {:>8.2}", row.workload, row.rmpkc);
        for (_, s, _) in &row.speedups {
            print!(" {:>6.2}%", (s - 1.0) * 100.0);
        }
        println!();
    }

    println!("\nFig. 4b — eight-core weighted speedup:");
    for row in suite.fig4b() {
        print!("{:>12} {:>8.2}", row.workload, row.rmpkc);
        for (_, s, _) in &row.speedups {
            print!(" {:>6.2}%", (s - 1.0) * 100.0);
        }
        println!();
    }

    let rows = suite.fig4b();
    let avg = |i: usize| {
        rows.iter().map(|r| r.speedups[i].1 - 1.0).sum::<f64>() / rows.len() as f64 * 100.0
    };
    println!(
        "\n8-core averages: CC {:.1}% (paper 8.6) NUAT {:.1}% (2.5) CC+NUAT {:.1}% (9.6) LL {:.1}% (13.4)",
        avg(0), avg(1), avg(2), avg(3)
    );
}
