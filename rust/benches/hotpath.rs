//! Hot-path micro-benchmarks — the §Perf instrumentation (DESIGN.md §9).
//!
//! Measures the simulator's inner loops in isolation (bank FSM, HCRAC,
//! LLC, scheduler tick, trace generation) plus the end-to-end simulated
//! cycles/second figure that bounds every experiment's wall time.
//!
//! Two modes:
//!
//! * default — the full suite; rewrites `BENCH_engine.json` at the repo
//!   root with the strict-vs-event figures, the event-mode 4-core-mix
//!   rate, the per-policy controller-tick rates, the warmup-forking
//!   sweep ratio, the shard-scaling rows (64-core/8-channel mix at
//!   1/2/4/8 channel shards), and the wake-wheel rows (the same mix
//!   under wheel vs heap, plus the direct index microbench at 1/8/64
//!   components).
//! * `--check` (CI regression gate) — runs the event-mode 4-core-mix
//!   figure and the wake-index microbench and compares them against the
//!   committed `BENCH_engine.json`; exits nonzero on a >20% regression.
//!   Every verdict line names the baseline's class (provisional /
//!   workstation / CI-recorded); a missing or provisional baseline
//!   (`cycles_per_sec` absent or 0) passes but warns loudly on stderr
//!   that the gate is unarmed.

#[path = "harness.rs"]
mod harness;

use chargecache::config::SystemConfig;
use chargecache::controller::{MemController, Request, SchedulerKind};
use chargecache::coordinator::experiments::{
    fig1_with, run_suite_with, sweep_capacity_with, ExperimentScale,
};
use chargecache::coordinator::jobs::{JobEngine, JobGraph, JobSpec};
use chargecache::cpu::Llc;
use chargecache::dram::command::Loc;
use chargecache::latency::chargecache::ChargeCache;
use chargecache::latency::{Mechanism, MechanismKind, RowKey};
use chargecache::sim::engine::LoopMode;
use chargecache::sim::wake::{WakeImpl, WakeIndex};
use chargecache::sim::{SimResult, System};
use chargecache::trace::{Profile, SynthTrace, TraceSource, XorShift64};

const BENCH_JSON_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");

fn main() {
    if std::env::args().skip(1).any(|a| a == "--check") {
        check_against_committed();
        return;
    }
    let cfg = SystemConfig::default();

    // HCRAC ops.
    {
        let mut cc = ChargeCache::new(&cfg);
        let mut rng = XorShift64::new(1);
        let n = 1_000_000u64;
        harness::bench("hotpath/hcrac_insert_lookup_1M", 1, 3, || {
            for i in 0..n {
                let key = RowKey::new(0, (i % 8) as u32, rng.below(4096) as u32);
                if i % 2 == 0 {
                    cc.on_precharge(i, 0, key);
                } else {
                    std::hint::black_box(cc.on_activate(i, 0, key));
                }
            }
        })
        .report_throughput(n as f64, "ops");
    }

    // LLC accesses.
    {
        let mut llc = Llc::new(cfg.cpu.llc_bytes, cfg.cpu.llc_ways, 64);
        let mut rng = XorShift64::new(2);
        let n = 1_000_000u64;
        harness::bench("hotpath/llc_access_1M", 1, 3, || {
            for _ in 0..n {
                std::hint::black_box(llc.access(rng.below(1 << 20), false));
            }
        })
        .report_throughput(n as f64, "ops");
    }

    // Trace generation.
    {
        let p = Profile::by_name("mcf").unwrap();
        let mut t = SynthTrace::new(p, 3, 0);
        let n = 1_000_000u64;
        harness::bench("hotpath/synth_trace_1M", 1, 3, || {
            for _ in 0..n {
                std::hint::black_box(t.next_entry());
            }
        })
        .report_throughput(n as f64, "entries");
    }

    // Controller tick under load (the simulator's dominant loop), per
    // scheduler policy — the per-bank-indexing payoff and the relative
    // cost of FCFS/BLISS land here (recorded in BENCH_engine.json).
    let mut policy_tick_cps: Vec<(&'static str, f64)> = Vec::new();
    for sched in SchedulerKind::all() {
        let mut pcfg = cfg.clone();
        pcfg.mc.scheduler = sched;
        let n_cycles = 200_000u64;
        let r = harness::bench(
            &format!("hotpath/controller_tick_200k_{}", sched.label()),
            1,
            3,
            || {
                let mut mc = MemController::new(&pcfg, MechanismKind::ChargeCache, 0);
                let mut rng = XorShift64::new(4);
                let mut done = Vec::new();
                let mut id = 0u64;
                for now in 0..n_cycles {
                    if now % 4 == 0 {
                        let _ = mc.enqueue(
                            Request {
                                id,
                                core: (id % 4) as u32,
                                loc: Loc {
                                    channel: 0,
                                    rank: 0,
                                    bank: rng.below(8) as u32,
                                    row: rng.below(256) as u32,
                                    col: rng.below(128) as u32,
                                },
                                is_write: rng.below(4) == 0,
                                arrived: now,
                            },
                            now,
                        );
                        id += 1;
                    }
                    done.clear();
                    mc.tick(now, &mut done);
                }
            },
        );
        r.report_throughput(n_cycles as f64, "bus-cycles");
        policy_tick_cps.push((sched.label(), n_cycles as f64 / r.mean.as_secs_f64()));
    }

    // Idle controller tick (common case in low-RMPKC phases).
    {
        let n_cycles = 2_000_000u64;
        harness::bench("hotpath/controller_tick_2M_idle", 1, 3, || {
            let mut mc = MemController::new(&cfg, MechanismKind::ChargeCache, 0);
            let mut done = Vec::new();
            for now in 0..n_cycles {
                done.clear();
                mc.tick(now, &mut done);
            }
        })
        .report_throughput(n_cycles as f64, "bus-cycles");
    }

    // End-to-end simulated CPU cycles per second — the headline perf
    // number that bounds the experiment suite's wall time.
    {
        let mut scfg = SystemConfig::default();
        scfg.insts_per_core = 100_000;
        scfg.warmup_cpu_cycles = 10_000;
        let p = Profile::by_name("tpcc64").unwrap();
        let mut cycles = 0u64;
        let r = harness::bench("hotpath/end_to_end_single_core", 1, 3, || {
            let res = System::new(&scfg, MechanismKind::ChargeCache, &[p]).run();
            cycles = res.cpu_cycles;
        });
        r.report_throughput(cycles as f64, "cpu-cycles");
    }

    // End-to-end multiprogrammed.
    {
        let mut scfg = SystemConfig::eight_core();
        scfg.cpu.cores = 8;
        scfg.insts_per_core = 25_000;
        scfg.warmup_cpu_cycles = 5_000;
        let mut cycles = 0u64;
        let r = harness::bench("hotpath/end_to_end_eight_core", 1, 2, || {
            let res = System::new_mix(&scfg, MechanismKind::ChargeCache, 0).run();
            cycles = res.cpu_cycles;
        });
        r.report_throughput(cycles as f64, "cpu-cycles");
    }

    let memo = bench_suite_memo();
    let fork = bench_warmup_fork();
    let shard_rows = bench_shard_scaling();
    let wake = bench_wake_wheel();
    engine_vs_strict_tick(&policy_tick_cps, &memo, &fork, &shard_rows, &wake);
}

/// Wake-wheel figures for `BENCH_engine.json`: the 64-core/8-channel mix
/// end-to-end under wheel vs heap, plus the direct index microbench.
struct WakeWheelFigures {
    mix_wheel_cps: f64,
    mix_heap_cps: f64,
    /// `(components, wheel_events_per_sec, heap_events_per_sec)`.
    rows: Vec<(usize, f64, f64)>,
}

/// Drive one [`WakeIndex`] through the event kernel's operation mix —
/// advance to the minimum, drain the due batch, re-arm every drained id,
/// clamp a random id down (the completion-delivery pattern) — and return
/// drained events per second. The op sequence is identical for both
/// implementations (seeded RNG), so the two rates are comparable.
fn wake_events_rate(imp: WakeImpl, n: usize, rounds: u64, reps: u32) -> f64 {
    let mut events = 0u64;
    let r = harness::bench(&format!("hotpath/wake_{}_{n}c", imp.name()), 1, reps, || {
        let mut idx = WakeIndex::with_impl(n, imp);
        let mut rng = XorShift64::new(9);
        let mut due: Vec<u32> = Vec::new();
        let mut drained = 0u64;
        loop {
            let now = idx.min_bound();
            due.clear();
            idx.drain_due(now, &mut due);
            due.sort_unstable();
            due.dedup();
            drained += due.len() as u64;
            for &id in &due {
                idx.set(id as usize, now + 1 + rng.below(200));
            }
            // External clamp-down on a random component, like a
            // completion landing mid-sleep.
            let id = rng.below(n as u64) as usize;
            let clamp = now + 1 + rng.below(16);
            idx.set(id, idx.bound(id).min(clamp));
            if drained >= rounds {
                break;
            }
        }
        events = drained;
    });
    r.report_throughput(events as f64, "events");
    events as f64 / r.mean.as_secs_f64()
}

/// The wheel-vs-heap rows: end-to-end 64-core/8-channel mix cycles/s on
/// each implementation (bit-identity re-asserted — the equivalence suite
/// pins it, but a drifted perf run would poison the figure), and the
/// direct microbench at 1/8/64 components.
fn bench_wake_wheel() -> WakeWheelFigures {
    let mut mix_cps = [0.0f64; 2];
    let mut baseline: Option<SimResult> = None;
    for (i, imp) in [WakeImpl::Wheel, WakeImpl::Heap].into_iter().enumerate() {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 64;
        cfg.dram.channels = 8;
        cfg.insts_per_core = 10_000;
        cfg.warmup_cpu_cycles = 5_000;
        cfg.wake_impl = imp;
        let mut res: Option<SimResult> = None;
        let r = harness::bench(&format!("hotpath/mix64_8ch_wake_{}", imp.name()), 1, 2, || {
            res = Some(System::new_mix(&cfg, MechanismKind::ChargeCache, 1).run());
        });
        let res = res.unwrap();
        r.report_throughput(res.cpu_cycles as f64, "cpu-cycles");
        mix_cps[i] = res.cpu_cycles as f64 / r.mean.as_secs_f64();
        match &baseline {
            None => baseline = Some(res),
            Some(b) => assert_eq!(b, &res, "wheel and heap runs drifted"),
        }
    }
    let rows = [1usize, 8, 64]
        .into_iter()
        .map(|n| {
            let wheel = wake_events_rate(WakeImpl::Wheel, n, 400_000, 3);
            let heap = wake_events_rate(WakeImpl::Heap, n, 400_000, 3);
            (n, wheel, heap)
        })
        .collect::<Vec<_>>();
    println!(
        "wake wheel vs heap on mix64_8ch: {:.2}x ({:.2}M vs {:.2}M sim-cycles/s)",
        mix_cps[0] / mix_cps[1].max(1e-9),
        mix_cps[0] / 1e6,
        mix_cps[1] / 1e6
    );
    WakeWheelFigures { mix_wheel_cps: mix_cps[0], mix_heap_cps: mix_cps[1], rows }
}

/// Warmup-forking figures for `BENCH_engine.json`.
struct WarmupForkFigures {
    legs: usize,
    warmup_cpu_cycles: u64,
    cold_wall_s: f64,
    fork_wall_s: f64,
    warmup_cycles_reused: u64,
    warmup_cycles_simulated: u64,
}

impl WarmupForkFigures {
    fn wall_ratio(&self) -> f64 {
        self.cold_wall_s / self.fork_wall_s.max(1e-9)
    }
}

/// A `measure_cycles` sweep whose legs share one warmed-up snapshot
/// (equal warmup fingerprints), run cold (`checkpoint.warmup_fork=off`)
/// vs forked — the checkpoint-forking wall-clock claim. Bit-identity
/// between the two passes is re-asserted here; the checkpoint test
/// suite pins it, but a perf run that drifted would poison the figure.
fn bench_warmup_fork() -> WarmupForkFigures {
    let legs = 6u64;
    let warmup = 200_000u64;
    let run = |fork: bool| {
        let mut eng = JobEngine::new();
        let mut g = JobGraph::new();
        let tickets: Vec<_> = (0..legs)
            .map(|k| {
                let mut cfg = SystemConfig::default();
                cfg.insts_per_core = 50_000;
                cfg.warmup_cpu_cycles = warmup;
                cfg.measure_cycles = Some(40_000 + 10_000 * k);
                cfg.checkpoint.warmup_fork = fork;
                g.submit(JobSpec::single(cfg, MechanismKind::ChargeCache, 0))
            })
            .collect();
        let t0 = std::time::Instant::now();
        let results = eng.run(g);
        let wall = t0.elapsed().as_secs_f64();
        let out: Vec<SimResult> = tickets.iter().map(|&t| results.get(t).clone()).collect();
        (wall, out, eng.stats())
    };
    let (cold_wall_s, cold, _) = run(false);
    let (fork_wall_s, forked, stats) = run(true);
    assert_eq!(cold, forked, "forked sweep drifted from the cold runs");
    let figures = WarmupForkFigures {
        legs: legs as usize,
        warmup_cpu_cycles: warmup,
        cold_wall_s,
        fork_wall_s,
        warmup_cycles_reused: stats.warmup_cycles_forked,
        warmup_cycles_simulated: stats.warmup_cycles_simulated,
    };
    println!(
        "hotpath/warmup_fork: {legs}-leg sweep {cold_wall_s:.2}s cold vs {fork_wall_s:.2}s forked ({:.2}x); warmup cycles: {} reused, {} simulated",
        figures.wall_ratio(),
        figures.warmup_cycles_reused,
        figures.warmup_cycles_simulated,
    );
    figures
}

/// Shard-scaling rows for the channel-sharded event loop (`sim::shard`):
/// the 64-core / 8-channel mix at 1/2/4/8 shards. Returns
/// `(shards, cycles_per_sec, sim_cycles, wall_s)` per row. Bit-identity
/// across shard counts is re-asserted here — the equivalence suite pins
/// it, but a perf run that silently drifted would poison the figures.
fn bench_shard_scaling() -> Vec<(usize, f64, u64, f64)> {
    let mut rows = Vec::new();
    let mut baseline: Option<SimResult> = None;
    for shards in [1usize, 2, 4, 8] {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 64;
        cfg.dram.channels = 8;
        cfg.insts_per_core = 10_000;
        cfg.warmup_cpu_cycles = 5_000;
        cfg.sim_threads = shards;
        let mut res: Option<SimResult> = None;
        let r = harness::bench(&format!("hotpath/mix64_8ch_shards_{shards}"), 1, 2, || {
            res = Some(System::new_mix(&cfg, MechanismKind::ChargeCache, 1).run());
        });
        let res = res.unwrap();
        r.report_throughput(res.cpu_cycles as f64, "cpu-cycles");
        let wall = r.mean.as_secs_f64();
        rows.push((shards, res.cpu_cycles as f64 / wall, res.cpu_cycles, wall));
        match &baseline {
            None => baseline = Some(res),
            Some(b) => assert_eq!(b, &res, "{shards}-shard run drifted from 1-shard"),
        }
    }
    if let (Some((_, one, _, _)), Some((_, four, _, _))) =
        (rows.first().copied(), rows.iter().find(|r| r.0 == 4).copied())
    {
        println!("shard scaling at 4 shards: {:.2}x ({:.2}M -> {:.2}M sim-cycles/s)",
            four / one, one / 1e6, four / 1e6);
    }
    rows
}

/// Quick-suite memoization figures for `BENCH_engine.json`.
struct SuiteMemoFigures {
    insts_per_core: u64,
    mixes: usize,
    memo_wall_s: f64,
    no_memo_wall_s: f64,
    submitted: u64,
    simulated: u64,
}

impl SuiteMemoFigures {
    fn dedup_factor(&self) -> f64 {
        self.submitted as f64 / self.simulated.max(1) as f64
    }
}

/// Wall-clock of a `figures`-shaped quick suite (fig1 + single suite +
/// full suite + capacity sweep) with the job-graph memoization on vs the
/// `--no-memo` path that simulates every submitted leg — the tentpole
/// perf claim, recorded alongside the per-loop figures.
fn bench_suite_memo() -> SuiteMemoFigures {
    let scale = ExperimentScale {
        insts_per_core: 8_000,
        warmup_cycles: 3_000,
        mixes: 2,
        ..ExperimentScale::default()
    };
    let run = |memo: bool| {
        let mut eng = if memo { JobEngine::new() } else { JobEngine::no_memo() };
        let t0 = std::time::Instant::now();
        std::hint::black_box(fig1_with(scale, &mut eng));
        std::hint::black_box(run_suite_with(scale, false, &mut eng));
        std::hint::black_box(run_suite_with(scale, true, &mut eng));
        std::hint::black_box(sweep_capacity_with(scale, &[64, 128, 256], &mut eng));
        (t0.elapsed().as_secs_f64(), eng.stats())
    };
    let (memo_wall_s, memo_stats) = run(true);
    let (no_memo_wall_s, raw_stats) = run(false);
    let figures = SuiteMemoFigures {
        insts_per_core: scale.insts_per_core,
        mixes: scale.mixes,
        memo_wall_s,
        no_memo_wall_s,
        submitted: memo_stats.submitted,
        simulated: memo_stats.simulated,
    };
    assert_eq!(
        raw_stats.simulated, raw_stats.submitted,
        "no-memo baseline must simulate every submission"
    );
    println!(
        "hotpath/suite_memoization: {:.2}s memoized vs {:.2}s raw ({:.2}x), {} legs submitted / {} simulated ({:.2}x dedup)",
        memo_wall_s,
        no_memo_wall_s,
        no_memo_wall_s / memo_wall_s.max(1e-9),
        figures.submitted,
        figures.simulated,
        figures.dedup_factor()
    );
    figures
}

/// The event-mode 4-core mix (the workload the wake index and the
/// per-bank request indexing target: two channels, closed-row policy,
/// deep queues). Returns `(cycles_per_sec, sim_cycles, wall_s)`.
fn bench_mix4_event(reps: u32) -> (f64, u64, f64) {
    let mix_insts = 25_000u64;
    let mut mix_cfg = SystemConfig::eight_core();
    mix_cfg.cpu.cores = 4;
    mix_cfg.insts_per_core = mix_insts;
    mix_cfg.warmup_cpu_cycles = 10_000;
    let mut mix_cycles = 0u64;
    let r = harness::bench("hotpath/mix4_event_driven", 1, reps, || {
        let res = System::new_mix(&mix_cfg, MechanismKind::ChargeCache, 0).run();
        mix_cycles = res.cpu_cycles;
    });
    r.report_throughput(mix_cycles as f64, "cpu-cycles");
    let wall = r.mean.as_secs_f64();
    (mix_cycles as f64 / wall, mix_cycles, wall)
}

/// Pull `section.field` out of the committed JSON without a JSON
/// dependency (the bench writes the file, so the shape is under our
/// control): the first occurrence of `"field":` after `"section"`.
fn extract_rate(json: &str, section: &str, field: &str) -> Option<f64> {
    let obj = json.split(&format!("\"{section}\"")).nth(1)?;
    let after = obj.split(&format!("\"{field}\":")).nth(1)?;
    let num: String = after
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E'))
        .collect();
    num.parse().ok()
}

fn extract_mix_rate(json: &str) -> Option<f64> {
    extract_rate(json, "four_core_mix_event", "cycles_per_sec")
}

/// `--check`: the CI regression gate on the event-mode 4-core-mix rate.
///
/// The committed figure is wall-clock and therefore machine-bound, so
/// the gate only *hard-fails* when the baseline itself was recorded on a
/// CI runner (`"recorded_on_ci": true`, stamped by the full bench from
/// the `CI` env var). A workstation-recorded or provisional baseline
/// still gets measured and reported, but a slower CI machine comparing
/// against fast-workstation numbers must not permanently redline the
/// job.
fn check_against_committed() {
    let committed = std::fs::read_to_string(BENCH_JSON_PATH).ok();
    let baseline = committed.as_deref().and_then(extract_mix_rate).filter(|b| *b > 0.0);
    let ci_recorded = committed
        .as_deref()
        .map(|s| s.contains("\"recorded_on_ci\": true"))
        .unwrap_or(false);
    // Baseline provenance, named in every verdict line so a CI log says
    // at a glance how much the comparison means: only a CI-recorded
    // baseline arms the hard gate.
    let class = match (baseline.is_some(), ci_recorded) {
        (true, true) => "CI-recorded",
        (true, false) => "workstation",
        (false, _) => "provisional",
    };
    let (cps, _, _) = bench_mix4_event(2);
    match baseline {
        Some(base) => {
            let ratio = cps / base;
            println!(
                "bench-check: mix4 event-mode {cps:.0} sim-cycles/s vs committed {base:.0} ({ratio:.2}x)"
            );
            if ratio < 0.8 {
                if ci_recorded {
                    eprintln!(
                        "bench-check: FAIL ({class} baseline) — event-mode 4-core-mix rate \
                         fell >20% below the CI-recorded baseline"
                    );
                    std::process::exit(1);
                }
                eprintln!(
                    "bench-check: PASS ({class} baseline) — >20% below the committed figure, \
                     but the baseline was not CI-recorded (cross-machine wall clock); \
                     re-record on CI to arm the gate"
                );
            } else {
                println!("bench-check: PASS ({class} baseline)");
            }
        }
        None => eprintln!(
            "bench-check: PASS ({class} baseline) — BENCH_engine.json is missing or zero-valued; \
             the regression gate is NOT armed and this pass is vacuous. Measured {cps:.0} \
             sim-cycles/s; run `cargo bench --bench hotpath` on CI to record a real baseline"
        ),
    }

    // The wake_wheel section: the direct index microbench (events/s at
    // 1/8/64 components, both implementations — cheap enough to always
    // measure and print), gated on the 64-component wheel rate against
    // the committed figure under the same CI-recorded-baseline rule.
    let mut wheel_64 = 0.0;
    for n in [1usize, 8, 64] {
        let wheel = wake_events_rate(WakeImpl::Wheel, n, 200_000, 2);
        let heap = wake_events_rate(WakeImpl::Heap, n, 200_000, 2);
        println!(
            "bench-check: wake {n}c — wheel {wheel:.0} events/s, heap {heap:.0} events/s ({:.2}x)",
            wheel / heap.max(1e-9)
        );
        if n == 64 {
            wheel_64 = wheel;
        }
    }
    let wake_base = committed
        .as_deref()
        .and_then(|s| extract_rate(s, "wake_wheel", "wheel_events_per_sec_64"))
        .filter(|b| *b > 0.0);
    match wake_base {
        Some(base) => {
            let ratio = wheel_64 / base;
            println!(
                "bench-check: wake_wheel 64c {wheel_64:.0} events/s vs committed {base:.0} ({ratio:.2}x)"
            );
            if ratio < 0.8 && ci_recorded {
                eprintln!(
                    "bench-check: FAIL ({class} baseline) — wheel 64-component event rate \
                     fell >20% below the CI-recorded baseline"
                );
                std::process::exit(1);
            }
            println!("bench-check: wake_wheel PASS ({class} baseline)");
        }
        None => eprintln!(
            "bench-check: wake_wheel PASS (provisional baseline) — no committed \
             wheel_events_per_sec_64; the wake gate is NOT armed. Measured {wheel_64:.0} events/s"
        ),
    }
}

/// The event kernel vs the per-cycle loop on the memory-bound `mcf`
/// profile, plus the event-mode 4-core mix (the wake-index/slab-path
/// acceptance workload), the per-policy controller-tick rates, and the
/// suite-memoization figures. Emits `BENCH_engine.json` (repo root) so
/// future PRs have a perf trajectory to track.
fn engine_vs_strict_tick(
    policy_tick_cps: &[(&'static str, f64)],
    memo: &SuiteMemoFigures,
    fork: &WarmupForkFigures,
    shard_rows: &[(usize, f64, u64, f64)],
    wake: &WakeWheelFigures,
) {
    let insts = 150_000u64;
    let run_mode = |mode: LoopMode, label: &str| -> (f64, SimResult) {
        let p = Profile::by_name("mcf").unwrap();
        let mut cfg = SystemConfig::default();
        cfg.insts_per_core = insts;
        cfg.warmup_cpu_cycles = 30_000;
        cfg.loop_mode = mode;
        let mut res: Option<SimResult> = None;
        let r = harness::bench(label, 1, 3, || {
            res = Some(System::new(&cfg, MechanismKind::ChargeCache, &[p]).run());
        });
        let res = res.unwrap();
        r.report_throughput(res.cpu_cycles as f64, "cpu-cycles");
        (r.mean.as_secs_f64(), res)
    };

    let (strict_s, strict) = run_mode(LoopMode::StrictTick, "hotpath/mcf_strict_tick");
    let (event_s, event) = run_mode(LoopMode::EventDriven, "hotpath/mcf_event_driven");

    let strict_cps = strict.cpu_cycles as f64 / strict_s;
    let event_cps = event.cpu_cycles as f64 / event_s;
    let speedup = event_cps / strict_cps;
    // Full-state identity via the derived SimResult equality.
    let identical = strict == event;
    println!(
        "engine speedup on mcf: {speedup:.2}x ({:.2}M -> {:.2}M sim-cycles/s), stats identical: {identical}",
        strict_cps / 1e6,
        event_cps / 1e6
    );

    let (mix_cps, mix_cycles, mix_wall) = bench_mix4_event(3);
    // Provenance marker for the --check gate: wall-clock figures only
    // gate hard against baselines recorded on CI-class hardware.
    let on_ci = std::env::var("CI").is_ok();

    let policies_json = policy_tick_cps
        .iter()
        .map(|(label, cps)| format!("    \"{label}\": {{ \"tick_cycles_per_sec\": {cps:.0} }}"))
        .collect::<Vec<_>>()
        .join(",\n");
    let shard_json = shard_rows
        .iter()
        .map(|(s, cps, cycles, wall)| {
            format!(
                "      {{ \"shards\": {s}, \"wall_s\": {wall:.6}, \
                 \"sim_cpu_cycles\": {cycles}, \"cycles_per_sec\": {cps:.0} }}"
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let shard_speedup_4 = match (shard_rows.first(), shard_rows.iter().find(|r| r.0 == 4)) {
        (Some((_, one, _, _)), Some((_, four, _, _))) if *one > 0.0 => four / one,
        _ => 0.0,
    };
    let wake_rows_json = wake
        .rows
        .iter()
        .map(|(n, wheel, heap)| {
            format!(
                "      {{ \"components\": {n}, \"wheel_events_per_sec\": {wheel:.0}, \
                 \"heap_events_per_sec\": {heap:.0} }}"
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let wheel_eps_64 = wake.rows.iter().find(|r| r.0 == 64).map(|r| r.1).unwrap_or(0.0);
    let json = format!(
        "{{\n  \"bench\": \"engine_vs_strict_tick\",\n  \"workload\": \"mcf\",\n  \
         \"mechanism\": \"ChargeCache\",\n  \"insts_per_core\": {insts},\n  \
         \"strict_tick\": {{ \"wall_s\": {strict_s:.6}, \"sim_cpu_cycles\": {}, \
         \"cycles_per_sec\": {strict_cps:.0} }},\n  \
         \"event_driven\": {{ \"wall_s\": {event_s:.6}, \"sim_cpu_cycles\": {}, \
         \"cycles_per_sec\": {event_cps:.0} }},\n  \
         \"speedup\": {speedup:.3},\n  \"stats_identical\": {identical},\n  \
         \"recorded_on_ci\": {on_ci},\n  \
         \"four_core_mix_event\": {{ \"insts_per_core\": 25000, \
         \"wall_s\": {mix_wall:.6}, \"sim_cpu_cycles\": {mix_cycles}, \
         \"cycles_per_sec\": {mix_cps:.0} }},\n  \
         \"suite_memo\": {{ \"insts_per_core\": {}, \"mixes\": {}, \
         \"memo_wall_s\": {:.6}, \"no_memo_wall_s\": {:.6}, \"speedup\": {:.3}, \
         \"legs_submitted\": {}, \"legs_simulated\": {}, \"dedup_factor\": {:.3} }},\n  \
         \"warmup_fork\": {{ \"legs\": {}, \"warmup_cpu_cycles\": {}, \
         \"cold_wall_s\": {:.6}, \"fork_wall_s\": {:.6}, \"wall_ratio\": {:.3}, \
         \"warmup_cycles_reused\": {}, \"warmup_cycles_simulated\": {} }},\n  \
         \"shard_scaling\": {{ \"workload\": \"mix64_8ch\", \"insts_per_core\": 10000, \
         \"speedup_at_4\": {shard_speedup_4:.3}, \"rows\": [\n{shard_json}\n    ] }},\n  \
         \"wake_wheel\": {{ \"workload\": \"mix64_8ch\", \"insts_per_core\": 10000, \
         \"mix_wheel_cycles_per_sec\": {:.0}, \"mix_heap_cycles_per_sec\": {:.0}, \
         \"mix_speedup\": {:.3}, \"wheel_events_per_sec_64\": {wheel_eps_64:.0}, \
         \"rows\": [\n{wake_rows_json}\n    ] }},\n  \
         \"policies\": {{\n{policies_json}\n  }}\n}}\n",
        strict.cpu_cycles,
        event.cpu_cycles,
        memo.insts_per_core,
        memo.mixes,
        memo.memo_wall_s,
        memo.no_memo_wall_s,
        memo.no_memo_wall_s / memo.memo_wall_s.max(1e-9),
        memo.submitted,
        memo.simulated,
        memo.dedup_factor(),
        fork.legs,
        fork.warmup_cpu_cycles,
        fork.cold_wall_s,
        fork.fork_wall_s,
        fork.wall_ratio(),
        fork.warmup_cycles_reused,
        fork.warmup_cycles_simulated,
        wake.mix_wheel_cps,
        wake.mix_heap_cps,
        wake.mix_wheel_cps / wake.mix_heap_cps.max(1e-9),
    );
    match std::fs::write(BENCH_JSON_PATH, &json) {
        Ok(()) => println!("wrote {BENCH_JSON_PATH}"),
        Err(e) => eprintln!("could not write {BENCH_JSON_PATH}: {e}"),
    }
}
