//! Fig. 3 bench: the circuit layer. Times both paths — the pure-Rust
//! analytic port and (when artifacts exist) the AOT HLO executed via
//! PJRT — and regenerates the Fig. 3 ready-time family + Sec. 6.2 deltas.

#[path = "harness.rs"]
mod harness;

use chargecache::latency::timing_table::{circuit, TimingTable};

fn main() {
    // Rust analytic path.
    let r = harness::bench("fig3/analytic_table_64pt", 1, 5, || {
        TimingTable::analytic(64, 85.0, 1.25)
    });
    r.report();

    let (a, tau) = circuit::calibrate();
    let beta = circuit::calibrate_restore(a, tau);
    let r = harness::bench("fig3/sense_latency_single_lane", 2, 10, || {
        circuit::sense_latency(1.45, a, beta)
    });
    r.report_throughput(circuit::N_STEPS as f64, "euler-steps");

    pjrt_benches();

    // Sec. 6.2 deltas from the analytic table.
    let table = TimingTable::analytic(64, 85.0, 1.25);
    let (rcd_ns, ras_ns) = table.reduction_ns(1e-3);
    println!("\nSec. 6.2 @1ms: tRCD -{rcd_ns:.2} ns, tRAS -{ras_ns:.2} ns (paper 4.5/9.6)");
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches() {
    println!("(pjrt feature off; HLO benches skipped — the analytic path above is the default)");
}

/// PJRT path (the production artifact).
#[cfg(feature = "pjrt")]
fn pjrt_benches() {
    use chargecache::runtime::{ChargeModelRuntime, Runtime};
    match Runtime::new(Runtime::default_dir()) {
        Ok(rt) if rt.artifacts_present() => {
            let cm = ChargeModelRuntime::load(&rt).expect("artifacts load");
            let r = harness::bench("fig3/hlo_latency_table_via_pjrt", 1, 5, || {
                cm.timing_table(85.0, 1.25).unwrap()
            });
            r.report();

            let b = cm.meta.get_usize("traj_batch").unwrap();
            let vdd = cm.meta.get("vdd").unwrap();
            let tau_ms = cm.meta.get("tau_leak_ms").unwrap();
            let ages = [0.0f64, 1.0, 8.0, 32.0, 64.0];
            let mut v0: Vec<f32> = ages
                .iter()
                .map(|&ms| (vdd / 2.0 + vdd / 2.0 * (-ms / tau_ms).exp()) as f32)
                .collect();
            v0.resize(b, v0[0]);
            let mut sweep = (0usize, Vec::new());
            let r = harness::bench("fig3/hlo_bitline_sweep", 1, 5, || {
                sweep = cm.bitline_sweep(&v0).unwrap();
            });
            r.report();

            let (samples, data) = sweep;
            let v_ready = cm.meta.get("v_ready").unwrap() as f32;
            let dt = cm.meta.get("dt_ns").unwrap() * cm.meta.get("traj_stride").unwrap();
            println!("\nFig. 3 — time to ready-to-access voltage (PJRT):");
            for (lane, &ms) in ages.iter().enumerate() {
                let cross = data[lane * samples..(lane + 1) * samples]
                    .iter()
                    .position(|&v| v >= v_ready)
                    .unwrap_or(samples);
                println!("  age {ms:>5} ms -> t_ready {:>6.2} ns", cross as f64 * dt);
            }
            println!("paper: 10 ns (fresh) .. 14.5 ns (64 ms old)");
        }
        _ => println!("(artifacts not built; PJRT benches skipped — run `make artifacts`)"),
    }
}
