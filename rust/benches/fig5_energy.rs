//! Fig. 5 bench: regenerate the DRAM energy-reduction comparison.

#[path = "harness.rs"]
mod harness;

use chargecache::coordinator::experiments::{run_suite, ExperimentScale, SuiteResults};

fn main() {
    let scale = if harness::is_quick() {
        ExperimentScale {
            insts_per_core: 15_000,
            warmup_cycles: 6_000,
            mixes: 2,
            ..ExperimentScale::default()
        }
    } else {
        ExperimentScale {
            insts_per_core: 80_000,
            warmup_cycles: 40_000,
            mixes: 8,
            ..ExperimentScale::default()
        }
    };

    let mut suite: Option<SuiteResults> = None;
    let r = harness::bench("fig5/energy_suite", 0, 1, || {
        suite = Some(run_suite(scale, true));
    });
    r.report();
    let suite = suite.unwrap();

    for (label, eight) in [("single-core", false), ("eight-core", true)] {
        let data = suite.fig5(eight);
        println!("\nFig. 5 — DRAM energy reduction, {label}:");
        let mechs = ["CC", "NUAT", "CC+NUAT", "LL-DRAM"];
        for (i, m) in mechs.iter().enumerate() {
            let vals: Vec<f64> = data.iter().map(|(_, pm)| pm[i].1).collect();
            let avg = vals.iter().sum::<f64>() / vals.len() as f64 * 100.0;
            let max = vals.iter().cloned().fold(f64::MIN, f64::max) * 100.0;
            println!("  {m:>8}: avg {avg:>5.1}%  max {max:>5.1}%");
        }
    }
    println!("\npaper (CC): 1-core avg 1.8% max 6.9%; 8-core avg 7.9% max 14.1%");
}
