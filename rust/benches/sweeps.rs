//! Sensitivity-study benches (HPCA'16 Sec. 6.4/7.1): HCRAC capacity,
//! caching duration (with circuit-layer-derived reductions), temperature.

#[path = "harness.rs"]
mod harness;

use chargecache::coordinator::experiments::{
    sweep_capacity, sweep_duration, sweep_temperature, ExperimentScale,
};

fn main() {
    let scale = if harness::is_quick() {
        ExperimentScale {
            insts_per_core: 12_000,
            warmup_cycles: 5_000,
            mixes: 1,
            ..ExperimentScale::default()
        }
    } else {
        ExperimentScale {
            insts_per_core: 60_000,
            warmup_cycles: 30_000,
            mixes: 4,
            ..ExperimentScale::default()
        }
    };

    let mut cap = Vec::new();
    harness::bench("sweeps/capacity", 0, 1, || {
        cap = sweep_capacity(scale, &[32, 64, 128, 256, 512]);
    })
    .report();
    println!("capacity (entries/core) -> CC speedup:");
    for (e, s) in &cap {
        println!("  {e:>5} entries: {:+.2}%", (s - 1.0) * 100.0);
    }

    let mut dur = Vec::new();
    harness::bench("sweeps/duration", 0, 1, || {
        dur = sweep_duration(scale, &[0.125, 0.5, 1.0, 4.0, 16.0]);
    })
    .report();
    println!("caching duration -> CC speedup (reductions from circuit layer):");
    for (d, s) in &dur {
        println!("  {d:>6} ms: {:+.2}%", (s - 1.0) * 100.0);
    }

    let mut temp = Vec::new();
    harness::bench("sweeps/temperature", 0, 1, || {
        temp = sweep_temperature(scale, &[45.0, 65.0, 85.0]);
    })
    .report();
    println!("temperature -> CC speedup (fixed 1 ms duration):");
    for (t, s) in &temp {
        println!("  {t:>4} C: {:+.2}%", (s - 1.0) * 100.0);
    }
    println!("\npaper: benefits hold at worst-case temperature (Sec. 8.3)");

    // Ablation: the paper's future-work designs (footnote 3 + Sec. 6.3).
    ablation_hcrac_designs(scale);
}

/// Per-core vs shared HCRAC and LRU vs BIP insertion — the design points
/// the paper explicitly leaves to future work.
fn ablation_hcrac_designs(scale: ExperimentScale) {
    use chargecache::config::{HcracPolicy, HcracSharing, SystemConfig};
    use chargecache::coordinator::parallel_map;
    use chargecache::latency::MechanismKind;
    use chargecache::sim::System;

    let variants: [(&str, HcracSharing, HcracPolicy); 3] = [
        ("per-core LRU (paper)", HcracSharing::PerCore, HcracPolicy::Lru),
        ("shared LRU (fn.3)", HcracSharing::Shared, HcracPolicy::Lru),
        ("per-core BIP", HcracSharing::PerCore, HcracPolicy::Bip),
    ];
    let mut rows = Vec::new();
    harness::bench("sweeps/ablation_hcrac_designs", 0, 1, || {
        rows = variants
            .iter()
            .map(|(name, sharing, policy)| {
                let gains = parallel_map(scale.mixes, |mix| {
                    let mut cfg: SystemConfig = scale.eight_cfg();
                    cfg.chargecache.sharing = *sharing;
                    cfg.chargecache.policy = *policy;
                    let b: f64 = System::new_mix(&cfg, MechanismKind::Baseline, mix)
                        .run()
                        .core_ipc
                        .iter()
                        .sum();
                    let c = System::new_mix(&cfg, MechanismKind::ChargeCache, mix).run();
                    let ct: f64 = c.core_ipc.iter().sum();
                    (ct / b, c.reduced_act_fraction())
                });
                let speedup =
                    gains.iter().map(|g| g.0).sum::<f64>() / gains.len() as f64;
                let hits = gains.iter().map(|g| g.1).sum::<f64>() / gains.len() as f64;
                (*name, speedup, hits)
            })
            .collect();
    })
    .report();
    println!("\nHCRAC design ablation (8-core, CC speedup / hit fraction):");
    for (name, s, h) in &rows {
        println!("  {name:<22} {:+.2}%  hits {:.0}%", (s - 1.0) * 100.0, h * 100.0);
    }
}
