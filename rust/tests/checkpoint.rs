//! Checkpointed warmup forking (DESIGN.md §12): a leg forked from a
//! warmed-up snapshot must be bit-identical to the same leg run cold —
//! across mechanisms, loop modes, and shard counts — and the job graph's
//! fork groups must reuse (not re-simulate) the shared warmup.

use chargecache::config::SystemConfig;
use chargecache::coordinator::jobs::{JobEngine, JobGraph, JobSpec};
use chargecache::latency::MechanismKind;
use chargecache::sim::engine::LoopMode;
use chargecache::sim::{SimResult, SimSnapshot, System};
use chargecache::trace::Profile;

fn small_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.insts_per_core = 4_000;
    cfg.warmup_cpu_cycles = 2_000;
    cfg
}

/// Run the system cold, then again as warmup + capture +
/// restore-into-fresh + measure, and return both results.
fn cold_and_forked(build: impl Fn() -> System) -> (SimResult, SimResult) {
    let cold = build().run();
    let mut warm = build();
    warm.run_warmup();
    let snap = SimSnapshot::capture(&warm);
    let mut fresh = build();
    snap.restore_into(&mut fresh).expect("identity triple matches a same-config system");
    (cold, fresh.run_measure())
}

#[test]
fn fork_matches_cold_across_mechanisms() {
    let p = Profile::by_name("mcf").unwrap();
    for mech in MechanismKind::all() {
        let cfg = small_cfg();
        let (cold, forked) = cold_and_forked(|| System::new(&cfg, mech, &[p]));
        assert_eq!(cold, forked, "{mech:?}: forked run drifted from the cold run");
    }
}

#[test]
fn fork_matches_cold_across_loop_modes_and_shards() {
    let cases =
        [(LoopMode::StrictTick, 1usize), (LoopMode::EventDriven, 1), (LoopMode::EventDriven, 2)];
    for (mode, shards) in cases {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.insts_per_core = 2_000;
        cfg.warmup_cpu_cycles = 2_000;
        cfg.measure_cycles = Some(6_000);
        cfg.loop_mode = mode;
        cfg.sim_threads = shards;
        let mech = MechanismKind::ChargeCache;
        let (cold, forked) = cold_and_forked(|| System::new_mix(&cfg, mech, 0));
        assert_eq!(cold, forked, "{mode:?} at {shards} shard(s): forked run drifted");
    }
}

#[test]
fn snapshot_json_round_trips_and_rejects_corruption() {
    let p = Profile::by_name("mcf").unwrap();
    let cfg = small_cfg();
    let mech = MechanismKind::ChargeCache;
    let cold = System::new(&cfg, mech, &[p]).run();

    let mut warm = System::new(&cfg, mech, &[p]);
    warm.run_warmup();
    let snap = SimSnapshot::capture(&warm);
    let text = snap.encode();

    let decoded = SimSnapshot::decode(&text).expect("encoded snapshot decodes");
    assert_eq!(decoded, snap, "JSON round-trip must be lossless (exact u64 word tokens)");
    let mut fresh = System::new(&cfg, mech, &[p]);
    decoded.restore_into(&mut fresh).expect("decoded snapshot restores");
    assert_eq!(cold, fresh.run_measure(), "decoded-snapshot fork drifted from the cold run");

    // Truncation is detected at decode; a well-formed snapshot for a
    // different identity is detected at restore.
    assert!(SimSnapshot::decode(&text[..text.len() / 2]).is_none());
    let mut other = System::new(&cfg, MechanismKind::Baseline, &[p]);
    assert!(snap.restore_into(&mut other).is_none(), "mechanism mismatch must refuse to restore");
}

/// The acceptance demo: a 6-leg `measure_cycles` sweep shares one warmup,
/// so the job graph simulates the warmup once and forks it six times —
/// >= 5x fewer simulated warmup cycles than the naive path — while
/// staying bit-identical to the unforked sweep.
#[test]
fn job_graph_fork_groups_reuse_5x_warmup_cycles() {
    let legs = 6u64;
    let warmup = 1_000u64;
    let run = |fork: bool| {
        let mut eng = JobEngine::new();
        let mut g = JobGraph::new();
        let tickets: Vec<_> = (0..legs)
            .map(|k| {
                let mut cfg = SystemConfig::default();
                cfg.insts_per_core = 2_000;
                cfg.warmup_cpu_cycles = warmup;
                cfg.measure_cycles = Some(1_500 + 250 * k);
                cfg.checkpoint.warmup_fork = fork;
                g.submit(JobSpec::single(cfg, MechanismKind::ChargeCache, 0))
            })
            .collect();
        let results = eng.run(g);
        let out: Vec<SimResult> = tickets.iter().map(|&t| results.get(t).clone()).collect();
        (out, eng.stats())
    };

    let (cold, cold_stats) = run(false);
    assert_eq!(cold_stats.warmup_forks, 0);
    assert_eq!(cold_stats.warmup_sims, 0);

    let (forked, stats) = run(true);
    assert_eq!(cold, forked, "forked sweep drifted from the cold sweep");
    assert_eq!(stats.warmup_sims, 1, "one shared warmup simulation for the whole group");
    assert_eq!(stats.warmup_forks, legs);
    assert_eq!(stats.warmup_cycles_simulated, warmup);
    assert_eq!(stats.warmup_cycles_forked, legs * warmup);
    assert!(
        stats.warmup_cycles_forked >= 5 * stats.warmup_cycles_simulated,
        "fork group must reuse >= 5x the warmup cycles it simulates"
    );
}

/// Sampling knobs are outside the warmup identity, so a full-detail
/// warmup snapshot also serves sampled legs; the sampled estimate must
/// land near the full-detail measurement.
#[test]
fn sampled_leg_forks_from_full_detail_snapshot() {
    let p = Profile::by_name("mcf").unwrap();
    let mech = MechanismKind::ChargeCache;
    let mut full = SystemConfig::default();
    full.warmup_cpu_cycles = 2_000;
    full.measure_cycles = Some(20_000);

    let mut warm = System::new(&full, mech, &[p]);
    warm.run_warmup();
    let snap = SimSnapshot::capture(&warm);

    let mut full_sys = System::new(&full, mech, &[p]);
    snap.restore_into(&mut full_sys).expect("restore into full-detail leg");
    let full_res = full_sys.run_measure();
    assert!(full_res.sampled.is_none(), "full-detail runs carry no sampling summary");

    let mut sampled_cfg = full.clone();
    sampled_cfg.sample.detail_cycles = 2_000;
    sampled_cfg.sample.period_cycles = 5_000;
    let mut sampled_sys = System::new(&sampled_cfg, mech, &[p]);
    snap.restore_into(&mut sampled_sys).expect("sampling knobs are outside warmup identity");
    let sampled_res = sampled_sys.run_measure();

    let s = sampled_res.sampled.expect("sampled run carries a summary");
    assert!(s.intervals >= 2, "expected several detailed intervals, got {}", s.intervals);
    assert!(s.detailed_insts > 0 && s.skipped_insts > 0);
    let frac = s.detail_fraction();
    assert!(frac > 0.0 && frac < 1.0, "detail fraction {frac} must be a strict tradeoff");
    let full_ipc = full_res.ipc();
    assert!(
        s.ipc_mean > 0.5 * full_ipc && s.ipc_mean < 2.0 * full_ipc,
        "sampled IPC {} strayed from full-detail IPC {full_ipc}",
        s.ipc_mean
    );
}
