//! Memoization correctness: the job graph (DESIGN.md §5) must be
//! invisible in the results — a memoized suite is bit-identical to an
//! uncached run — and its dedup/hit counters must match the leg counts
//! the experiment structure predicts.

use chargecache::coordinator::experiments::{
    fig1_with, run_suite_with, sweep_capacity_with, ExperimentScale,
};
use chargecache::coordinator::jobs::JobEngine;
use chargecache::trace::PROFILES;

/// Mechanisms per suite leg (Baseline, CC, NUAT, CC+NUAT, LL-DRAM).
const MECHS: u64 = 5;

fn tiny(mixes: usize) -> ExperimentScale {
    ExperimentScale {
        insts_per_core: 2_000,
        warmup_cycles: 1_000,
        mixes,
        ..ExperimentScale::default()
    }
}

#[test]
fn memoized_suite_is_bit_identical_to_uncached() {
    let scale = tiny(1);
    let singles = PROFILES.len() as u64;
    let legs = singles * MECHS + MECHS;

    let mut memo = JobEngine::new();
    let memo_suite = run_suite_with(scale, true, &mut memo);
    // All legs of one fresh suite are unique: memoization must neither
    // skip nor repeat any.
    assert_eq!(memo.stats().submitted, legs);
    assert_eq!(memo.stats().simulated, legs);
    assert_eq!(memo.stats().eliminated(), 0);

    let mut raw = JobEngine::no_memo();
    let raw_suite = run_suite_with(scale, true, &mut raw);
    assert_eq!(raw.stats().simulated, legs);

    // Bit-identical results (SimResult includes every counter, the f64
    // IPC/RLTL vectors, and the energy breakdown).
    assert_eq!(memo_suite.single, raw_suite.single);
    assert_eq!(memo_suite.eight, raw_suite.eight);
    assert_eq!(memo_suite.alone_ipc, raw_suite.alone_ipc);
}

#[test]
fn figures_pipeline_simulates_each_unique_leg_once() {
    // The `figures` execution shape: fig1, both suites, and a capacity
    // sweep over ONE engine. Counter arithmetic is exact.
    let mixes = 2u64;
    let scale = tiny(mixes as usize);
    let singles = PROFILES.len() as u64;

    let mut eng = JobEngine::new();
    let fig1_rows = fig1_with(scale, &mut eng);
    assert!(!fig1_rows.is_empty());
    let single_suite = run_suite_with(scale, false, &mut eng);
    let full_suite = run_suite_with(scale, true, &mut eng);
    let sweep = sweep_capacity_with(scale, &[64, 128], &mut eng);
    assert_eq!(sweep.len(), 2);

    // Submissions: fig1 runs every Baseline leg, the single suite all
    // single legs, the full suite everything, the sweep one Baseline and
    // two CC points per mix.
    let submitted = (singles + mixes)
        + singles * MECHS
        + (singles * MECHS + mixes * MECHS)
        + (mixes + 2 * mixes);
    // Unique simulations: the full suite's legs plus the sweep's
    // 64-entry CC point — fig1 is a subset of the suite's Baselines, and
    // the sweep's 128-entry point IS the default configuration the suite
    // already ran.
    let unique = singles * MECHS + mixes * MECHS + mixes;

    let s = eng.stats();
    assert_eq!(s.submitted, submitted);
    assert_eq!(s.simulated, unique);
    assert_eq!(s.eliminated(), submitted - unique);
    assert!(
        s.eliminated() >= 40,
        "a figures-shaped run must eliminate >= 40 redundant legs, got {}",
        s.eliminated()
    );

    // Shared legs really are shared: the single-only suite and the full
    // suite returned the same (cached) results.
    assert_eq!(single_suite.single, full_suite.single);
}

#[test]
fn result_cache_round_trips_suite_across_engines() {
    let dir = std::env::temp_dir().join(format!("cc_result_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let scale = tiny(1);
    let singles = PROFILES.len() as u64;
    let legs = singles * MECHS + MECHS;

    let mut first = JobEngine::with_disk(&dir).unwrap();
    let suite_a = run_suite_with(scale, true, &mut first);
    assert_eq!(first.stats().simulated, legs);

    // A new engine (fresh process, conceptually) over the same directory
    // must load every leg from disk, bit-identically, simulating nothing.
    let mut second = JobEngine::with_disk(&dir).unwrap();
    let suite_b = run_suite_with(scale, true, &mut second);
    assert_eq!(second.stats().simulated, 0);
    assert_eq!(second.stats().disk_hits, legs);
    assert_eq!(suite_a.single, suite_b.single);
    assert_eq!(suite_a.eight, suite_b.eight);

    let _ = std::fs::remove_dir_all(&dir);
}
