//! Scenario-API correctness (DESIGN.md §10): the checked-in declarative
//! specs must reproduce the legacy sweep implementations **bit for
//! bit** through the shared JobEngine, scenario legs must dedupe and
//! memoize with the exact counter arithmetic the structure predicts
//! (mirroring `tests/memo.rs`), and every example spec must stay
//! parseable and expandable.

use chargecache::coordinator::experiments::{
    run_suite_with, sweep_capacity_with, sweep_duration_with, sweep_temperature_with,
    ExperimentScale,
};
use chargecache::coordinator::jobs::JobEngine;
use chargecache::coordinator::scenario::ScenarioSpec;
use chargecache::latency::MechanismKind;
use chargecache::trace::PROFILES;

const CAPACITY: &str = include_str!("../../examples/scenarios/sweep_capacity.json");
const DURATION: &str = include_str!("../../examples/scenarios/sweep_duration.json");
const TEMPERATURE: &str = include_str!("../../examples/scenarios/sweep_temperature.json");

fn tiny(mixes: usize) -> ExperimentScale {
    ExperimentScale {
        insts_per_core: 2_000,
        warmup_cycles: 1_000,
        mixes,
        ..ExperimentScale::default()
    }
}

#[test]
fn capacity_scenario_matches_legacy_sweep_bit_for_bit() {
    let scale = tiny(2);
    let entries = [32usize, 64, 128, 256, 512, 1024];
    // Independent engines on both sides: each path simulates its own
    // legs, so equality below is bit-identity of two real runs, not one
    // cache read.
    let legacy = sweep_capacity_with(scale, &entries, &mut JobEngine::new());

    let plan = ScenarioSpec::parse(CAPACITY).unwrap().expand(&scale).unwrap();
    let run = plan.run_with(&mut JobEngine::new());

    assert_eq!(run.rows.len(), legacy.len());
    for (row, (e, s)) in run.rows.iter().zip(&legacy) {
        assert_eq!(row.mechanism, MechanismKind::ChargeCache);
        assert_eq!(row.coords[0].0, "chargecache.entries_per_core");
        assert_eq!(row.coords[0].1.parse::<usize>().unwrap(), *e);
        assert_eq!(
            row.speedup.to_bits(),
            s.to_bits(),
            "entries {e}: scenario {} vs legacy {s}",
            row.speedup
        );
    }
}

#[test]
fn duration_scenario_matches_legacy_sweep_bit_for_bit() {
    let scale = tiny(1);
    let durations = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    let legacy = sweep_duration_with(scale, &durations, &mut JobEngine::new());

    let plan = ScenarioSpec::parse(DURATION).unwrap().expand(&scale).unwrap();
    let run = plan.run_with(&mut JobEngine::new());

    assert_eq!(run.rows.len(), legacy.len());
    for (row, (d, s)) in run.rows.iter().zip(&legacy) {
        assert_eq!(row.coords[0].1.parse::<f64>().unwrap(), *d);
        assert_eq!(
            row.speedup.to_bits(),
            s.to_bits(),
            "duration {d} ms: scenario {} vs legacy {s}",
            row.speedup
        );
    }
}

#[test]
fn temperature_scenario_matches_legacy_sweep_bit_for_bit() {
    let scale = tiny(1);
    let temps = [45.0, 55.0, 65.0, 75.0, 85.0];
    let legacy = sweep_temperature_with(scale, &temps, &mut JobEngine::new());

    let plan = ScenarioSpec::parse(TEMPERATURE).unwrap().expand(&scale).unwrap();
    let run = plan.run_with(&mut JobEngine::new());

    assert_eq!(run.rows.len(), legacy.len());
    for (row, (t, s)) in run.rows.iter().zip(&legacy) {
        assert_eq!(row.coords[0].0, "temperature_c");
        assert_eq!(row.coords[0].1.parse::<f64>().unwrap(), *t);
        assert_eq!(
            row.speedup.to_bits(),
            s.to_bits(),
            "temperature {t} C: scenario {} vs legacy {s}",
            row.speedup
        );
    }
}

#[test]
fn scenario_legs_dedupe_and_memoize_with_exact_counters() {
    let mixes = 2usize;
    let scale = tiny(mixes);
    let plan = ScenarioSpec::parse(CAPACITY).unwrap().expand(&scale).unwrap();
    let points = 6u64;

    let mut eng = JobEngine::new();
    let first = plan.run_with(&mut eng);
    // Shared-baseline layout: one Baseline per mix + one CC leg per
    // (point x mix); a fresh engine simulates every unique leg.
    let legs = mixes as u64 + points * mixes as u64;
    assert_eq!(first.legs_submitted as u64, legs);
    assert_eq!(eng.stats().submitted, legs);
    assert_eq!(eng.stats().simulated, legs);
    assert_eq!(eng.stats().eliminated(), 0);

    // Re-running the same plan on the same engine simulates nothing and
    // reproduces the rows bit-identically from memory.
    let second = plan.run_with(&mut eng);
    assert_eq!(eng.stats().submitted, 2 * legs);
    assert_eq!(eng.stats().simulated, legs);
    assert_eq!(eng.stats().memory_hits, legs);
    assert_eq!(first, second);
}

#[test]
fn scenario_shares_legs_with_a_prior_suite_run() {
    // The engine-sharing payoff: after the full suite, the capacity
    // scenario's shared baselines and its 128-entry point (the default
    // config the suite already ran as its CC legs) all come from cache.
    let mixes = 1usize;
    let scale = tiny(mixes);
    let singles = PROFILES.len() as u64;
    let mechs = 5u64;

    let mut eng = JobEngine::new();
    run_suite_with(scale, true, &mut eng);
    let suite_legs = singles * mechs + mixes as u64 * mechs;
    assert_eq!(eng.stats().simulated, suite_legs);

    let plan = ScenarioSpec::parse(CAPACITY).unwrap().expand(&scale).unwrap();
    plan.run_with(&mut eng);
    // New simulations: only the five non-default capacity points.
    assert_eq!(eng.stats().simulated, suite_legs + 5 * mixes as u64);
    // Cache served the baseline(s) and the 128-entry point.
    assert_eq!(eng.stats().memory_hits, 2 * mixes as u64);
}

#[test]
fn example_specs_parse_and_expand() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).expect("examples/scenarios exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let spec = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("{path:?} does not parse: {e}"));
        let plan = spec
            .expand(&tiny(1))
            .unwrap_or_else(|e| panic!("{path:?} does not expand: {e}"));
        assert!(plan.leg_count() > 0, "{path:?} expands to zero legs");
        seen += 1;
    }
    assert!(seen >= 4, "expected the checked-in example specs, found {seen}");
}

#[test]
fn grid_scenario_crosses_axes_with_per_point_baseline() {
    // The two-axis example: scheduler x temperature with a per-point
    // baseline (the scheduler perturbs Baseline behavior).
    let text = include_str!("../../examples/scenarios/scheduler_temperature_grid.json");
    let scale = tiny(1);
    let plan = ScenarioSpec::parse(text).unwrap().expand(&scale).unwrap();
    assert_eq!(plan.points.len(), 6, "3 schedulers x 2 temperatures");
    // Per-point baseline: one Baseline per point plus two mechanisms.
    assert_eq!(plan.leg_count(), 6 + 6 * 2);

    let mut eng = JobEngine::new();
    let run = plan.run_with(&mut eng);
    assert_eq!(run.rows.len(), 12);
    // FR-FCFS at the paper's worst-case temperature must appear, and
    // every speedup must be a sane ratio.
    assert!(run
        .rows
        .iter()
        .any(|r| r.coords[0].1 == "fr-fcfs" && r.coords[1].1 == "85.0"));
    for row in &run.rows {
        assert!(
            row.speedup > 0.5 && row.speedup < 2.0,
            "implausible speedup {} at {:?}",
            row.speedup,
            row.coords
        );
    }
}
