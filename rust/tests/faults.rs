//! Fault-injection robustness (DESIGN.md §13): the seeded retention-fault
//! model must stay bit-deterministic across loop modes and shard counts,
//! disabled `fault.*` knobs must be invisible, and every harness recovery
//! path — retry/backoff, per-leg failure reports, cache quarantine,
//! structured parse errors — must actually run under injected faults,
//! never panicking and never serving a wrong result.

use std::sync::Mutex;

use chargecache::config::SystemConfig;
use chargecache::coordinator::jobs::{JobEngine, JobGraph, JobSpec};
use chargecache::coordinator::scenario::ScenarioSpec;
use chargecache::coordinator::ExperimentScale;
use chargecache::error::SimError;
use chargecache::faulthooks;
use chargecache::latency::MechanismKind;
use chargecache::sim::engine::LoopMode;
use chargecache::sim::{SimResult, System};
use chargecache::trace::file::{write_trace, FileTrace};
use chargecache::trace::{Profile, SynthTrace};

const GUARD_BAND: &str = include_str!("../../examples/scenarios/guard_band.json");

/// Fault-hook budgets are process-global; every test that arms them (or
/// reads files another armed test could corrupt) serializes here.
static HOOKS: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HOOKS.lock().unwrap_or_else(|e| e.into_inner())
}

/// A worst-case fault config: every row weak with a zero safe window, so
/// the first ChargeCache hit on any row is a guaranteed violation, and a
/// zero guard band, so blacklisted rows are guard-suppressed thereafter.
fn faulty_mix_cfg(mode: LoopMode, shards: usize) -> SystemConfig {
    let mut cfg = SystemConfig::eight_core();
    cfg.dram.channels = 4;
    cfg.insts_per_core = 6_000;
    cfg.warmup_cpu_cycles = 3_000;
    cfg.loop_mode = mode;
    cfg.sim_threads = shards;
    cfg.fault.enabled = true;
    cfg.fault.weak_ppm = 1_000_000;
    cfg.fault.retention_pct = 0;
    cfg.fault.guard_band_pct = 0;
    cfg.fault.blacklist_threshold = 1;
    cfg
}

fn tiny_single(workload: usize) -> JobSpec {
    let mut cfg = SystemConfig::single_core();
    cfg.insts_per_core = 1_500;
    cfg.warmup_cpu_cycles = 500;
    cfg.checkpoint.warmup_fork = false;
    JobSpec::single(cfg, MechanismKind::ChargeCache, workload)
}

#[test]
fn fault_on_runs_are_bit_identical_across_loop_modes_and_shards() {
    let run = |mode, shards| {
        System::new_mix(&faulty_mix_cfg(mode, shards), MechanismKind::ChargeCache, 1).run()
    };
    let strict = run(LoopMode::StrictTick, 1);
    assert!(strict.timing_violations() > 0, "injected weak rows must actually violate");
    assert!(strict.mitigation_evictions() > 0, "violations must evict their HCRAC entries");
    assert!(strict.rows_blacklisted() > 0, "threshold 1 must blacklist violating rows");
    let t1 = run(LoopMode::EventDriven, 1);
    assert_eq!(strict, t1, "strict vs event drift with faults enabled");
    for shards in [2usize, 4] {
        let tn = run(LoopMode::EventDriven, shards);
        assert_eq!(t1, tn, "{shards}-shard fault-on run drifted from 1-shard");
    }
}

#[test]
fn disabled_fault_knobs_are_invisible() {
    // With `fault.enabled` off, every other fault.* knob must be inert:
    // the run is bit-identical to one at the default fault config.
    let run = |mutate: &dyn Fn(&mut SystemConfig)| {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.insts_per_core = 4_000;
        cfg.warmup_cpu_cycles = 2_000;
        mutate(&mut cfg);
        System::new_mix(&cfg, MechanismKind::ChargeCache, 0).run()
    };
    let default = run(&|_| {});
    let weird = run(&|c| {
        c.fault.weak_ppm = 999_999;
        c.fault.retention_pct = 0;
        c.fault.drift_interval_ms = 0.5;
        c.fault.drift_retention_pct = 1;
        c.fault.guard_band_pct = 3;
        c.fault.blacklist_threshold = 9;
    });
    assert_eq!(default, weird, "fault.* with fault.enabled=off perturbed the simulation");
}

#[test]
fn injected_job_panic_retries_then_succeeds_bit_identically() {
    let _g = lock();
    let mut clean_eng = JobEngine::new();
    let mut g = JobGraph::new();
    let t = g.submit(tiny_single(0));
    let clean: SimResult = clean_eng.run(g).get(t).clone();

    faulthooks::set_job_panics(1);
    let mut eng = JobEngine::new();
    let mut g = JobGraph::new();
    let t = g.submit(tiny_single(0));
    let results = eng.run(g);
    faulthooks::set_job_panics(0);

    assert_eq!(results.try_get(t), Some(&clean), "retried leg drifted from a clean run");
    assert!(results.failures().is_empty());
    let s = eng.stats();
    assert_eq!(s.retries, 1);
    assert_eq!(s.failed, 0);
    assert!(
        s.summary().contains("faults: 1 retried, 0 failed"),
        "summary must surface retry counters: {}",
        s.summary()
    );
}

#[test]
fn exhausted_retries_report_failures_without_aborting() {
    let _g = lock();
    // Two legs, three attempts each: a budget of 6 panics fails both
    // deterministically regardless of worker interleaving.
    faulthooks::set_job_panics(6);
    let mut eng = JobEngine::new();
    let mut g = JobGraph::new();
    let t0 = g.submit(tiny_single(0));
    let t1 = g.submit(tiny_single(1));
    let results = eng.run(g);
    faulthooks::set_job_panics(0);

    assert!(results.try_get(t0).is_none() && results.try_get(t1).is_none());
    assert_eq!(results.failures().len(), 2);
    for f in results.failures() {
        assert!(f.error.contains("injected job fault"), "unexpected panic message: {}", f.error);
        assert!(!f.workload.is_empty() && !f.mechanism.is_empty());
    }
    let s = eng.stats();
    assert_eq!(s.failed, 2);
    assert_eq!(s.retries, 4, "each failed leg burned its two retries");
    assert!(s.summary().contains("faults: 4 retried, 2 failed"), "{}", s.summary());
}

#[test]
fn corrupted_disk_entries_quarantine_and_resimulate_bit_identically() {
    let _g = lock();
    let dir = std::env::temp_dir().join(format!("cc_faults_disk_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let submit = |g: &mut JobGraph| (0..3).map(|w| g.submit(tiny_single(w))).collect::<Vec<_>>();

    let mut first = JobEngine::with_disk(&dir).unwrap();
    let mut g = JobGraph::new();
    let tickets = submit(&mut g);
    let res = first.run(g);
    let clean: Vec<SimResult> = tickets.iter().map(|&t| res.get(t).clone()).collect();

    // Rot every persisted entry: clobber the middle byte (fuzz-style; a
    // flip landing in a string field degrades to an identity-mismatch
    // miss, one landing anywhere else breaks the decode outright).
    let mut corrupted = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] = b'!';
        std::fs::write(&path, &bytes).unwrap();
        corrupted += 1;
    }
    assert_eq!(corrupted, 3, "expected one persisted entry per leg");

    let mut second = JobEngine::with_disk(&dir).unwrap();
    let mut g = JobGraph::new();
    let tickets = submit(&mut g);
    let res = second.run(g);
    for (i, &t) in tickets.iter().enumerate() {
        assert_eq!(
            res.get(t),
            &clean[i],
            "corrupt entry must fall back to an identical cold run, never a wrong result"
        );
    }
    let s = second.stats();
    assert_eq!(s.disk_hits, 0, "no corrupt entry may be served");
    assert_eq!(s.simulated, 3, "every leg re-simulates");
    assert!(s.quarantined >= 1, "structural corruption must quarantine at least one file");
    let bads = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| e.as_ref().unwrap().path().to_string_lossy().ends_with(".bad"))
        .count();
    assert_eq!(bads as u64, s.quarantined, "each quarantined entry is preserved as .bad");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_spec_fuzz_never_panics_and_pins_the_offset() {
    // Every truncation parses to a structured error or a valid spec —
    // never a panic — and ParseAt offsets stay within the input.
    for cut in 0..GUARD_BAND.len() {
        match ScenarioSpec::parse_named(&GUARD_BAND[..cut], "guard_band.json") {
            Ok(_) => {}
            Err(SimError::ParseAt { ref file, offset, .. }) => {
                assert_eq!(file, "guard_band.json");
                assert!(offset <= cut as u64, "offset {offset} past the {cut}-byte input");
            }
            Err(_) => {} // vocabulary/shape errors are fine too
        }
    }
    // Byte flips: clobbering any single position must fail cleanly or
    // parse to some spec, never panic.
    let bytes = GUARD_BAND.as_bytes();
    for i in 0..bytes.len() {
        let mut m = bytes.to_vec();
        m[i] = b'!';
        let text = String::from_utf8(m).unwrap();
        let _ = ScenarioSpec::parse_named(&text, "f");
    }
}

#[test]
fn trace_text_fuzz_reports_offsets_and_never_panics() {
    let mut text = String::from("# chargecache trace\n");
    for i in 0..40u64 {
        if i % 3 == 0 {
            text.push_str(&format!("{} {:#x} W\n", i % 8, 0x40 * i + 7));
        } else {
            text.push_str(&format!("{} {:#x}\n", i % 8, 0x100 + i));
        }
    }
    assert_eq!(FileTrace::from_text(&text, "f.trace").unwrap().len(), 40);
    for cut in 0..text.len() {
        match FileTrace::from_text(&text[..cut], "f.trace") {
            Ok(t) => assert!(t.len() <= 40),
            Err(SimError::ParseAt { offset, .. }) => {
                assert!((offset as usize) < text.len(), "offset {offset} out of range");
            }
            Err(e) => assert!(e.to_string().contains("empty trace"), "{e}"),
        }
    }
}

#[test]
fn injected_trace_truncation_is_a_structured_error_not_a_panic() {
    let _g = lock();
    let dir = std::env::temp_dir().join(format!("cc_faults_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.trace");
    let p = Profile::by_name("mcf").unwrap();
    let mut src = SynthTrace::new(p, 7, 0);
    write_trace(&path, &mut src, 400).unwrap();
    assert_eq!(FileTrace::load(&path).unwrap().len(), 400);

    faulthooks::set_truncate_trace(1);
    let r = FileTrace::load(&path);
    faulthooks::set_truncate_trace(0);
    match r {
        // The half-way cut can land exactly on a line boundary...
        Ok(t) => assert!(t.len() < 400, "truncated read must drop entries"),
        // ...but normally lands mid-token and must name file + offset.
        Err(e) => {
            let s = e.to_string();
            assert!(
                s.contains("parse error in") && s.contains("t.trace"),
                "expected a structured parse error, got: {s}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_with_injected_panics_completes_with_a_failure_report() {
    let _g = lock();
    let spec = ScenarioSpec::parse(
        r#"{ "name": "t", "mechanisms": ["cc"],
             "axes": [ { "param": "chargecache.entries_per_core", "values": [64, 256] } ] }"#,
    )
    .unwrap();
    let scale = ExperimentScale {
        insts_per_core: 1_000,
        warmup_cycles: 500,
        mixes: 1,
        ..ExperimentScale::default()
    };
    let plan = spec.expand(&scale).unwrap();

    // A budget larger than every attempt of every leg: the whole sweep
    // fails, yet run_with must return a complete report, not abort.
    faulthooks::set_job_panics(1_000);
    let mut eng = JobEngine::new();
    let run = plan.run_with(&mut eng);
    faulthooks::set_job_panics(0);

    assert!(run.rows.is_empty(), "every unit failed, so no row survives");
    assert!(run.failed_legs >= 2);
    let s = eng.stats();
    assert_eq!(s.failed as usize, run.failed_legs);
    assert!(s.retries >= 2 * s.failed, "each failed leg burned its retries");
    assert!(s.summary().contains("faults:"), "summary must surface fault counters");
}
