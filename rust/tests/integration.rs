//! Integration tests across the whole stack: runtime <-> artifacts,
//! circuit-layer <-> architecture-layer consistency, and end-to-end
//! paper-shape checks on small horizons.

use chargecache::config::SystemConfig;
use chargecache::coordinator::experiments::{run_suite, ExperimentScale};
use chargecache::latency::MechanismKind;
use chargecache::sim::System;
use chargecache::trace::{Profile, PROFILES};

/// The PJRT/HLO cross-language consistency tests only exist when the
/// `pjrt` feature (and its manually-added `xla` dependency) is enabled;
/// the default offline build exercises the analytic circuit model, which
/// `latency::timing_table` pins against the same paper endpoints.
#[cfg(feature = "pjrt")]
mod hlo {
    use chargecache::latency::timing_table::TimingTable;
    use chargecache::runtime::{ChargeModelRuntime, Runtime};

    fn artifacts_available() -> Option<Runtime> {
        let rt = Runtime::new(Runtime::default_dir()).ok()?;
        rt.artifacts_present().then_some(rt)
    }

    /// The HLO artifacts (JAX/Pallas circuit layer) must agree with the
    /// pure-Rust analytic port: this is the cross-language consistency
    /// oracle for the whole codesign bridge.
    #[test]
    fn hlo_timing_table_matches_rust_analytic() {
        let Some(rt) = artifacts_available() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cm = ChargeModelRuntime::load(&rt).unwrap();
        let hlo = cm.timing_table(85.0, 1.25).unwrap();
        let analytic = TimingTable::analytic(64, 85.0, 1.25);
        for &age in analytic.ages() {
            let (h_rcd, h_ras) = hlo.reduction_ns(age);
            let (a_rcd, a_ras) = analytic.reduction_ns(age);
            // f32 HLO vs f64 Rust: tolerate the Euler grid quantum
            // (0.01 ns) plus small float drift.
            assert!(
                (h_rcd - a_rcd).abs() < 0.05,
                "tRCD mismatch at {age}s: HLO {h_rcd} vs analytic {a_rcd}"
            );
            assert!(
                (h_ras - a_ras).abs() < 0.05,
                "tRAS mismatch at {age}s: HLO {h_ras} vs analytic {a_ras}"
            );
        }
    }

    /// The production operating point must round to the paper's -4/-8
    /// cycles through the real PJRT path.
    #[test]
    fn hlo_grants_paper_reductions_at_1ms() {
        let Some(rt) = artifacts_available() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cm = ChargeModelRuntime::load(&rt).unwrap();
        let table = cm.timing_table(85.0, 1.25).unwrap();
        assert_eq!(table.reduction_cycles(1e-3), (4, 8));
    }

    /// Sec. 6.2 endpoints through the PJRT sense_latency entry point.
    #[test]
    fn hlo_sense_latency_reproduces_sec62() {
        let Some(rt) = artifacts_available() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cm = ChargeModelRuntime::load(&rt).unwrap();
        let n = cm.meta.get_usize("latency_batch").unwrap();
        let vdd = cm.meta.get("vdd").unwrap() as f32;
        let tau = cm.meta.get("tau_leak_ms").unwrap();
        let v_worst = (vdd / 2.0) as f64 + (vdd as f64 / 2.0) * (-64.0 / tau).exp();
        let mut v = vec![vdd; n];
        v[1] = v_worst as f32;
        let (t_ready, t_restore) = cm.sense_latency(&v).unwrap();
        assert!((t_ready[0] - 10.0).abs() < 0.05, "full-charge t_ready {}", t_ready[0]);
        assert!((t_ready[1] - 14.5).abs() < 0.05, "worst-case t_ready {}", t_ready[1]);
        assert!(
            ((t_restore[1] - t_restore[0]) - 9.6).abs() < 0.15,
            "tRAS delta {}",
            t_restore[1] - t_restore[0]
        );
    }

    /// Fig. 3 trajectories through PJRT: monotone family, correct shape.
    #[test]
    fn hlo_bitline_sweep_family_is_ordered() {
        let Some(rt) = artifacts_available() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cm = ChargeModelRuntime::load(&rt).unwrap();
        let b = cm.meta.get_usize("traj_batch").unwrap();
        let vdd = cm.meta.get("vdd").unwrap() as f32;
        let v0: Vec<f32> =
            (0..b).map(|i| vdd * (0.80 + 0.2 * i as f32 / (b - 1) as f32)).collect();
        let (samples, data) = cm.bitline_sweep(&v0).unwrap();
        let v_ready = cm.meta.get("v_ready").unwrap() as f32;
        let cross: Vec<usize> = (0..b)
            .map(|lane| {
                data[lane * samples..(lane + 1) * samples]
                    .iter()
                    .position(|&v| v >= v_ready)
                    .unwrap_or(samples)
            })
            .collect();
        // More initial charge -> earlier crossing.
        for w in cross.windows(2) {
            assert!(w[1] <= w[0], "crossings must be ordered: {cross:?}");
        }
    }
}

/// End-to-end paper shape on a reduced horizon: multiprogrammed 4-core,
/// ChargeCache improves throughput (the paper's per-core-IPC metric;
/// cycles-to-last-finish is chaotic under shared-LLC interleaving and is
/// NOT a stable comparison basis).
#[test]
fn multicore_mechanism_ordering_end_to_end() {
    let mut cfg = SystemConfig::eight_core();
    cfg.cpu.cores = 4;
    cfg.insts_per_core = 60_000;
    cfg.warmup_cpu_cycles = 30_000;
    let run = |kind| -> f64 {
        System::new_mix(&cfg, kind, 1).run().core_ipc.iter().sum()
    };
    let base = run(MechanismKind::Baseline);
    let cc = run(MechanismKind::ChargeCache);
    let ll = run(MechanismKind::LlDram);
    assert!(cc >= base * 0.99, "ChargeCache must not hurt throughput: {cc} vs {base}");
    assert!(ll >= base * 0.99, "LL-DRAM must not hurt throughput: {ll} vs {base}");
}

/// ChargeCache's hit rate rises with bank conflicts: an 8-core mix sees a
/// larger reduced-activation fraction than the same apps run alone
/// (paper Sec. 6.3's explanation of the 8-core win).
#[test]
fn multicore_increases_hcrac_hit_fraction() {
    let mut cfg8 = SystemConfig::eight_core();
    cfg8.cpu.cores = 4;
    cfg8.insts_per_core = 50_000;
    cfg8.warmup_cpu_cycles = 25_000;
    let multi = System::new_mix(&cfg8, MechanismKind::ChargeCache, 3).run();

    let mut cfg1 = SystemConfig::single_core();
    cfg1.insts_per_core = 50_000;
    cfg1.warmup_cpu_cycles = 25_000;
    // Alone runs of the same mix members, averaged.
    let profiles = chargecache::trace::profile::multicore_mix(3, 4);
    let mut singles = 0.0;
    for p in &profiles {
        let r = System::new(&cfg1, MechanismKind::ChargeCache, &[*p]).run();
        singles += r.reduced_act_fraction();
    }
    singles /= profiles.len() as f64;
    assert!(
        multi.reduced_act_fraction() >= singles * 0.9,
        "multiprogramming should not reduce HCRAC hits: multi {} vs single-avg {}",
        multi.reduced_act_fraction(),
        singles
    );
}

/// Mini evaluation suite keeps the paper's aggregate orderings.
#[test]
fn mini_suite_orderings() {
    let scale = ExperimentScale {
        insts_per_core: 25_000,
        warmup_cycles: 10_000,
        mixes: 2,
        ..ExperimentScale::default()
    };
    let suite = run_suite(scale, true);
    let rows4a = suite.fig4a();
    let avg = |idx: usize| -> f64 {
        rows4a.iter().map(|r| r.speedups[idx].1).sum::<f64>() / rows4a.len() as f64
    };
    let (cc, nuat, ccn, ll) = (avg(0), avg(1), avg(2), avg(3));
    // LL-DRAM is the upper bound; CC+NUAT >= CC ~ >= NUAT (small noise ok).
    assert!(ll + 1e-6 >= cc, "LL {ll} vs CC {cc}");
    assert!(ll + 1e-6 >= ccn, "LL {ll} vs CC+NUAT {ccn}");
    assert!(cc >= nuat - 0.005, "CC {cc} vs NUAT {nuat}");
    // Fig. 5 view exists for all mixes.
    assert_eq!(suite.fig5(true).len(), 2);
}

/// Every named workload runs and produces nonzero IPC.
#[test]
fn all_profiles_simulate() {
    let mut cfg = SystemConfig::default();
    cfg.insts_per_core = 8_000;
    cfg.warmup_cpu_cycles = 3_000;
    for p in PROFILES.iter() {
        let r = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        assert!(r.ipc() > 0.0, "{} produced zero IPC", p.name);
        assert!(r.ipc() <= 3.0 + 1e-9, "{} exceeded issue width", p.name);
    }
}

/// Trace files round-trip through the system: a file-driven run matches
/// the generator-driven run exactly.
#[test]
fn file_trace_reproduces_synth_run() {
    use chargecache::trace::file::{write_trace, FileTrace};
    use chargecache::trace::{SynthTrace, TraceSource};

    let p = Profile::by_name("gcc").unwrap();
    let dir = std::env::temp_dir().join("cc_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("gcc.trace");
    // Enough records that the horizon never wraps.
    let mut src = SynthTrace::new(p, 99, 0);
    write_trace(&path, &mut src, 200_000).unwrap();

    let mut cfg = SystemConfig::default();
    cfg.insts_per_core = 20_000;
    cfg.warmup_cpu_cycles = 5_000;
    cfg.seed = 99;

    let synth: Box<dyn TraceSource> = Box::new(SynthTrace::new(p, 99, 0));
    let a = System::with_traces(&cfg, MechanismKind::ChargeCache, vec![synth], "synth".into())
        .run();
    let file: Box<dyn TraceSource> = Box::new(FileTrace::load(&path).unwrap());
    let b = System::with_traces(&cfg, MechanismKind::ChargeCache, vec![file], "file".into())
        .run();
    assert_eq!(a.cpu_cycles, b.cpu_cycles);
    assert_eq!(a.acts(), b.acts());
}
