//! Steady-state allocation audit of the hot simulation loop.
//!
//! The zero-allocation request path (slab request queues, the
//! generational inflight slab, the slab MSHR file, recycled scratch
//! buffers, and the lazily-pruned wake index) promises **zero heap
//! allocations per tick in steady state**. This binary installs a
//! counting global allocator and drives a 4-core, two-channel mix on the
//! event kernel: after a warm region long enough for every slab,
//! freelist, heap, and row-keyed tracker to hit its high-water capacity,
//! a measured region of the hot loop must perform no allocations at all.
//!
//! The workload is `gobmk` (5 MiB working set): it overflows the 4 MiB
//! LLC — so the DRAM read/write/writeback path is exercised hard — while
//! keeping the DRAM row footprint bounded, so the RLTL/reuse trackers'
//! per-row maps stop growing once warm.

use std::alloc::{GlobalAlloc, Layout, System as SystemAlloc};
use std::sync::atomic::{AtomicU64, Ordering};

use chargecache::config::SystemConfig;
use chargecache::latency::MechanismKind;
use chargecache::sim::engine::{advance, LoopMode};
use chargecache::sim::System;
use chargecache::trace::Profile;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        SystemAlloc.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        SystemAlloc.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        SystemAlloc.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn hot_loop_is_allocation_free_in_steady_state() {
    let mut cfg = SystemConfig::eight_core();
    cfg.cpu.cores = 4;
    cfg.loop_mode = LoopMode::EventDriven;
    let p = Profile::by_name("gobmk").unwrap();
    let profiles = [p, p, p, p];
    let mut sys = System::new(&cfg, MechanismKind::ChargeCache, &profiles);

    // Warm region: fills the LLC, touches the whole row working set, and
    // lets every reusable structure reach its high-water capacity.
    let mut now = advance(&mut sys, LoopMode::EventDriven, 0, 2_000_000, |_| false);

    // Measured steady state. Watermark growth is rare but legal *during
    // warmup* (e.g. a hash map crossing its next capacity threshold on a
    // late-seen row); if a window still observes it, extend the warm
    // region and re-measure — what must never happen is allocation in a
    // genuinely steady window.
    let mut allocs = u64::MAX;
    for _ in 0..4 {
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        let end = advance(&mut sys, LoopMode::EventDriven, now, now + 400_000, |_| false);
        allocs = ALLOC_CALLS.load(Ordering::Relaxed) - before;
        assert_eq!(end, now + 400_000, "region must run to its bound");
        now = end;
        if allocs == 0 {
            break;
        }
    }
    assert_eq!(allocs, 0, "hot loop allocated {allocs} times in a steady-state window");

    // The audited workload must actually stress DRAM for the audit to
    // mean anything (guards against it silently going LLC-resident);
    // checked on a fresh short run rather than the manually-advanced
    // system, whose clock bookkeeping `run()` does not expect.
    let mut check_cfg = cfg.clone();
    check_cfg.insts_per_core = 20_000;
    check_cfg.warmup_cpu_cycles = 10_000;
    let r = System::new(&check_cfg, MechanismKind::ChargeCache, &profiles).run();
    assert!(r.acts() > 100, "audit workload produced no real DRAM activity");
}
