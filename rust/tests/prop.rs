//! Property-based tests on coordinator/DRAM invariants.
//!
//! The build is offline (no proptest crate), so properties are driven by a
//! seeded-random case generator: each property runs across many random
//! seeds and shrink-free failures print the offending seed for replay.

use chargecache::config::{RowPolicy, SystemConfig};
use chargecache::controller::{MemController, Request, RequestQueue, SchedulerKind};
use chargecache::dram::command::Loc;
use chargecache::latency::chargecache::ChargeCache;
use chargecache::latency::{Mechanism, MechanismKind, RowKey};
use chargecache::sim::engine::{advance, LoopMode};
use chargecache::sim::wake::{WakeImpl, WakeIndex};
use chargecache::sim::{SimSnapshot, System};
use chargecache::trace::XorShift64;

/// Run `body` for `cases` random seeds; panic messages carry the seed.
fn property(cases: u64, body: impl Fn(&mut XorShift64, u64)) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case * 7919;
        let mut rng = XorShift64::new(seed);
        body(&mut rng, seed);
    }
}

/// Drive a controller with a random request stream; the DRAM device's
/// debug assertions (every command >= its earliest legal cycle) act as the
/// invariant oracle — any timing violation panics.
#[test]
fn prop_no_timing_violation_under_random_traffic() {
    property(25, |rng, seed| {
        let mut cfg = SystemConfig::default();
        cfg.mc.row_policy = if rng.below(2) == 0 { RowPolicy::Open } else { RowPolicy::Closed };
        cfg.mc.scheduler = SchedulerKind::all()[rng.below(3) as usize];
        let kinds = [
            MechanismKind::Baseline,
            MechanismKind::ChargeCache,
            MechanismKind::Nuat,
            MechanismKind::ChargeCacheNuat,
            MechanismKind::LlDram,
        ];
        let kind = kinds[rng.below(5) as usize];
        let mut mc = MemController::new(&cfg, kind, 0);
        let mut done = Vec::new();
        let mut id = 0u64;
        let mut issued = 0u64;
        let mut completed = 0u64;
        for now in 0..40_000u64 {
            // Random arrivals, bursty.
            if rng.below(3) == 0 {
                let req = Request {
                    id,
                    core: 0,
                    loc: Loc {
                        channel: 0,
                        rank: 0,
                        bank: rng.below(8) as u32,
                        row: rng.below(64) as u32,
                        col: rng.below(128) as u32,
                    },
                    is_write: rng.below(4) == 0,
                    arrived: now,
                };
                let is_write = req.is_write;
                if mc.enqueue(req, now) {
                    id += 1;
                    if !is_write {
                        issued += 1;
                    }
                }
            }
            done.clear();
            mc.tick(now, &mut done);
            completed += done.len() as u64;
        }
        // Conservation: every completed read was issued (seed {seed}).
        assert!(completed <= issued, "completions exceed reads (seed {seed})");
        // Liveness: the controller must have made progress.
        assert!(completed > 0, "no read ever completed (seed {seed})");
    });
}

/// HCRAC must never serve an entry older than the caching duration, under
/// arbitrary interleavings of inserts/lookups with arbitrary time gaps.
#[test]
fn prop_hcrac_never_serves_stale_entries() {
    property(40, |rng, seed| {
        let cfg = SystemConfig::default();
        let duration = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut cc = ChargeCache::new(&cfg);
        let mut now = 0u64;
        // Shadow model: exact insertion times.
        let mut inserted: std::collections::HashMap<u64, u64> = Default::default();
        for _ in 0..3000 {
            now += rng.below(duration / 4) + 1;
            let key = RowKey::new(0, rng.below(8) as u32, rng.below(32) as u32);
            if rng.below(2) == 0 {
                cc.on_precharge(now, 0, key);
                inserted.insert(key.0, now);
            } else {
                let grant = cc.on_activate(now, 0, key);
                if grant.reduced {
                    let age = now - inserted[&key.0];
                    assert!(
                        age <= duration,
                        "stale grant: age {age} > {duration} (seed {seed})"
                    );
                }
            }
        }
    });
}

/// ChargeCache grants imply a real prior precharge (no phantom hits), and
/// the hit count matches the number of reduced grants.
#[test]
fn prop_hcrac_hits_require_prior_precharge() {
    property(30, |rng, seed| {
        let cfg = SystemConfig::default();
        let mut cc = ChargeCache::new(&cfg);
        let mut seen = std::collections::HashSet::new();
        let mut reduced = 0u64;
        let mut now = 0;
        for _ in 0..2000 {
            now += rng.below(100) + 1;
            let key = RowKey::new(0, rng.below(4) as u32, rng.below(1024) as u32);
            if rng.below(2) == 0 {
                cc.on_precharge(now, 0, key);
                seen.insert(key.0);
            } else {
                let g = cc.on_activate(now, 0, key);
                if g.reduced {
                    reduced += 1;
                    assert!(seen.contains(&key.0), "phantom hit (seed {seed})");
                }
            }
        }
        assert_eq!(cc.hits, reduced, "hit accounting mismatch (seed {seed})");
    });
}

/// No scheduler may starve row-conflict requests: every enqueued read
/// eventually completes even under a hammering row-hit stream (FR-FCFS
/// via the starvation cap, FCFS by construction, BLISS via blacklisting).
#[test]
fn prop_no_starvation_of_conflicting_request() {
    property(12, |rng, _seed| {
        let mut cfg = SystemConfig::default();
        cfg.mc.scheduler = SchedulerKind::all()[rng.below(3) as usize];
        let mut mc = MemController::new(&cfg, MechanismKind::Baseline, 0);
        let mut done = Vec::new();
        // Victim read to row 99 in bank 0.
        mc.enqueue(
            Request {
                id: 0,
                core: 0,
                loc: Loc { channel: 0, rank: 0, bank: 0, row: 99, col: 0 },
                is_write: false,
                arrived: 0,
            },
            0,
        );
        let mut id = 1;
        let mut victim_done = false;
        for now in 0..200_000u64 {
            // Hammer row 1 in the same bank with fresh hits.
            if now % 3 == 0 && rng.below(2) == 0 {
                mc.enqueue(
                    Request {
                        id,
                        core: 0,
                        loc: Loc {
                            channel: 0,
                            rank: 0,
                            bank: 0,
                            row: 1,
                            col: (id % 128) as u32,
                        },
                        is_write: false,
                        arrived: now,
                    },
                    now,
                );
                id += 1;
            }
            done.clear();
            mc.tick(now, &mut done);
            if done.iter().any(|c| c.req_id == 0) {
                victim_done = true;
                break;
            }
        }
        assert!(victim_done, "conflicting request starved");
    });
}

/// Request-queue conservation through the full system: reads in == reads
/// completed + still queued, across random multi-bank traffic.
#[test]
fn prop_read_conservation() {
    property(15, |rng, seed| {
        let cfg = SystemConfig::default();
        let mut mc = MemController::new(&cfg, MechanismKind::ChargeCache, 0);
        let mut done = Vec::new();
        let mut sent = std::collections::HashSet::new();
        let mut got = std::collections::HashSet::new();
        let mut id = 0u64;
        for now in 0..60_000u64 {
            if rng.below(4) == 0 {
                let req = Request {
                    id,
                    core: 0,
                    loc: Loc {
                        channel: 0,
                        rank: 0,
                        bank: rng.below(8) as u32,
                        row: rng.below(16) as u32,
                        col: rng.below(128) as u32,
                    },
                    is_write: false,
                    arrived: now,
                };
                if mc.enqueue(req, now) {
                    sent.insert(id);
                    id += 1;
                }
            }
            done.clear();
            mc.tick(now, &mut done);
            for c in &done {
                assert!(got.insert(c.req_id), "duplicate completion (seed {seed})");
                assert!(sent.contains(&c.req_id), "unknown completion (seed {seed})");
            }
        }
        // Drain.
        for now in 60_000..400_000u64 {
            done.clear();
            mc.tick(now, &mut done);
            for c in &done {
                assert!(got.insert(c.req_id), "duplicate completion (seed {seed})");
            }
            if got.len() == sent.len() {
                break;
            }
        }
        assert_eq!(got.len(), sent.len(), "lost reads (seed {seed})");
    });
}

/// The event kernel's wake contract, tested directly on the controller
/// for **every scheduler policy**: whenever `next_event_at(now)` says the
/// next event is strictly in the future, ticking at `now` must be a no-op
/// (no command issued, no completion delivered, no stat moved). A
/// violation here is exactly a "late wake" bug — a policy reporting a
/// wake bound later than its true next issue cycle, the failure mode that
/// would silently break the event-driven/strict-tick equivalence.
#[test]
fn prop_wake_bound_is_never_late_for_any_policy() {
    for sched in SchedulerKind::all() {
        property(8, |rng, seed| {
            let mut cfg = SystemConfig::default();
            cfg.mc.row_policy =
                if rng.below(2) == 0 { RowPolicy::Open } else { RowPolicy::Closed };
            cfg.mc.scheduler = sched;
            let mut mc = MemController::new(&cfg, MechanismKind::ChargeCache, 0);
            let mut done = Vec::new();
            let mut id = 0u64;
            for now in 0..30_000u64 {
                if rng.below(3) == 0 {
                    let req = Request {
                        id,
                        core: rng.below(4) as u32,
                        loc: Loc {
                            channel: 0,
                            rank: 0,
                            bank: rng.below(8) as u32,
                            row: rng.below(32) as u32,
                            col: rng.below(128) as u32,
                        },
                        is_write: rng.below(4) == 0,
                        arrived: now,
                    };
                    if mc.enqueue(req, now) {
                        id += 1;
                    }
                }
                let wake = mc.next_event_at(now);
                let quiet = wake > now;
                let before = format!("{:?}", mc.stats());
                done.clear();
                mc.tick(now, &mut done);
                if quiet {
                    assert!(
                        done.is_empty(),
                        "[{sched:?}] completion in quiet cycle {now} (seed {seed})"
                    );
                    assert_eq!(
                        before,
                        format!("{:?}", mc.stats()),
                        "[{sched:?}] stats moved at {now}, wake {wake} (seed {seed})"
                    );
                }
            }
        });
    }
}

/// The slab-backed request queue against a plain `Vec<Request>` oracle:
/// under randomized push/remove interleavings (including full drains and
/// slot recycling), acceptance, removal results, and — critically for
/// FR-FCFS/FCFS/BLISS semantics — exact arrival-order iteration must
/// match the Vec's behavior at every step.
#[test]
fn prop_slab_queue_matches_vec_oracle() {
    property(25, |rng, seed| {
        let cap = 1 + rng.below(64) as usize;
        let mut q = RequestQueue::new(cap);
        let mut oracle: Vec<Request> = Vec::new();
        let mut id = 0u64;
        for step in 0..1500u64 {
            if rng.below(5) < 3 {
                let req = Request {
                    id,
                    core: rng.below(8) as u32,
                    loc: Loc {
                        channel: 0,
                        rank: 0,
                        bank: rng.below(8) as u32,
                        row: rng.below(64) as u32,
                        col: rng.below(128) as u32,
                    },
                    is_write: rng.below(4) == 0,
                    arrived: step,
                };
                let pushed = q.push(req);
                assert_eq!(pushed, oracle.len() < cap, "push acceptance (seed {seed})");
                if pushed {
                    oracle.push(req);
                    id += 1;
                }
            } else if !oracle.is_empty() {
                // Remove the pos-th request in arrival order, exactly as
                // a scheduler pick would: key from iteration, not index.
                let pos = rng.below(oracle.len() as u64) as usize;
                let key = q.iter_keyed().nth(pos).expect("pos in range").0;
                let removed = q.remove(key);
                let expected = oracle.remove(pos);
                assert_eq!(removed, expected, "removed request (seed {seed})");
            }
            assert_eq!(q.len(), oracle.len(), "length drift (seed {seed})");
            assert_eq!(q.is_empty(), oracle.is_empty());
            assert_eq!(q.is_full(), oracle.len() >= cap);
            let got: Vec<u64> = q.iter().map(|r| r.id).collect();
            let want: Vec<u64> = oracle.iter().map(|r| r.id).collect();
            assert_eq!(got, want, "iteration order drift (seed {seed})");
        }
    });
}

/// The wake index against a full component rescan, over random tick
/// schedules: after event-driven advances of arbitrary (often tiny)
/// chunks, every cached bound must still be conservative — no later than
/// the freshly recomputed `next_event_at` of its component. A violation
/// is a missed invalidation (the index failure mode that would silently
/// break strict/event bit-identity).
#[test]
fn prop_wake_index_is_never_later_than_full_rescan() {
    property(5, |rng, _seed| {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 2;
        cfg.loop_mode = LoopMode::EventDriven;
        let kinds = [MechanismKind::Baseline, MechanismKind::ChargeCache, MechanismKind::Nuat];
        let kind = kinds[rng.below(3) as usize];
        cfg.mc.scheduler = SchedulerKind::all()[rng.below(3) as usize];
        let mut sys = System::new_mix(&cfg, kind, rng.below(8) as usize);
        let mut now = 0u64;
        for _ in 0..60 {
            let chunk = 1 + rng.below(4_000);
            now = advance(&mut sys, LoopMode::EventDriven, now, now + chunk, |_| false);
            sys.assert_wake_bounds_conservative(now);
        }
    });
}

/// The timing wheel against the heap oracle, as plain data structures:
/// identical random operation sequences (raises, clamps, `u64::MAX`
/// parking, far-future overflow bounds, batched drains at a random
/// monotone `now`) must produce identical `min_bound` values at every
/// step and identical sorted-deduped drain batches. This is the direct
/// differential form of the equivalence the engine tests observe
/// end-to-end; component counts cover the degenerate single-entry
/// index, one wheel slot's worth, and a multi-level population.
#[test]
fn prop_wheel_and_heap_agree_on_random_op_sequences() {
    for n in [1usize, 3, 64, 257] {
        property(8, |rng, seed| {
            let mut wheel = WakeIndex::with_impl(n, WakeImpl::Wheel);
            let mut heap = WakeIndex::with_impl(n, WakeImpl::Heap);
            assert_eq!(wheel.kind(), WakeImpl::Wheel, "auto must not leak in");
            assert_eq!(heap.kind(), WakeImpl::Heap);
            let mut now = 0u64;
            for step in 0..4_000u64 {
                let id = rng.below(n as u64) as usize;
                match rng.below(10) {
                    // Mostly ordinary re-arms near the present...
                    0..=5 => {
                        let b = now + rng.below(500);
                        wheel.set(id, b);
                        heap.set(id, b);
                    }
                    // ...some parked forever...
                    6 => {
                        wheel.set(id, u64::MAX);
                        heap.set(id, u64::MAX);
                    }
                    // ...some far beyond the wheel's bucketed horizon
                    // (forces the overflow list)...
                    7 => {
                        let b = now + (1u64 << 50) + rng.below(1 << 20);
                        wheel.set(id, b);
                        heap.set(id, b);
                    }
                    // ...and some clamped below the current cursor (the
                    // re-heat path sampling and shard reassembly take).
                    _ => {
                        let b = rng.below(now + 1);
                        wheel.set(id, b);
                        heap.set(id, b);
                    }
                }
                assert_eq!(
                    wheel.min_bound(),
                    heap.min_bound(),
                    "min diverged at step {step} (n {n}, seed {seed})"
                );
                if rng.below(4) == 0 {
                    now += rng.below(300);
                    let (mut a, mut b) = (Vec::new(), Vec::new());
                    wheel.drain_due(now, &mut a);
                    heap.drain_due(now, &mut b);
                    a.sort_unstable();
                    a.dedup();
                    b.sort_unstable();
                    b.dedup();
                    assert_eq!(a, b, "drain diverged at step {step} (n {n}, seed {seed})");
                    // Honor the drain contract: re-arm every drained id.
                    for &id in &a {
                        let nb = now + 1 + rng.below(200);
                        wheel.set(id as usize, nb);
                        heap.set(id as usize, nb);
                    }
                }
            }
        });
    }
}

/// The epoch-barrier exchange contract of the channel-sharded loop
/// (`sim::shard`), over random small configs: a cross-shard message may
/// never be delivered *earlier* than its single-thread event-mode time
/// (nor later — staged enqueues land at exactly the next bus boundary,
/// the same cycle the sequential trailing wake clamp guarantees). Early
/// or late delivery would shift queue occupancy, scheduler picks, and
/// completion times, so the observable form of the property is full
/// `SimResult` bit-identity between N-shard and 1-shard event runs.
#[test]
fn prop_sharded_delivery_times_match_event_mode() {
    property(6, |rng, seed| {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 2 + 2 * rng.below(2) as usize; // 2 or 4
        cfg.dram.channels = [2, 4, 8][rng.below(3) as usize];
        cfg.mc.scheduler = SchedulerKind::all()[rng.below(3) as usize];
        cfg.mc.row_policy = if rng.below(2) == 0 { RowPolicy::Open } else { RowPolicy::Closed };
        cfg.insts_per_core = 2_000 + rng.below(2_000);
        cfg.warmup_cpu_cycles = 1_000 + rng.below(1_000);
        cfg.loop_mode = LoopMode::EventDriven;
        let kinds = [MechanismKind::Baseline, MechanismKind::ChargeCache, MechanismKind::Nuat];
        let kind = kinds[rng.below(3) as usize];
        let mix = rng.below(8) as usize;
        cfg.sim_threads = 1;
        let seq = System::new_mix(&cfg, kind, mix).run();
        cfg.sim_threads = 2 + rng.below(3) as usize; // 2..=4 shards
        let sharded = System::new_mix(&cfg, kind, mix).run();
        assert_eq!(
            seq, sharded,
            "sharded run drifted from event mode ({} shards, seed {seed})",
            cfg.sim_threads
        );
    });
}

/// The checkpoint identity contract (DESIGN.md §12) under randomized
/// configs: warmup + capture + restore-into-fresh + measure must be
/// bit-identical to the uninterrupted run, across random mechanisms,
/// schedulers, row policies, core/channel counts, loop modes, and
/// trace seeds — including snapshots that detour through the JSON
/// codec, as disk-cached ones do.
#[test]
fn prop_forked_runs_match_cold_runs() {
    property(6, |rng, seed| {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 2 + 2 * rng.below(2) as usize; // 2 or 4
        cfg.dram.channels = [2, 4, 8][rng.below(3) as usize];
        cfg.mc.scheduler = SchedulerKind::all()[rng.below(3) as usize];
        cfg.mc.row_policy = if rng.below(2) == 0 { RowPolicy::Open } else { RowPolicy::Closed };
        cfg.insts_per_core = 2_000 + rng.below(2_000);
        cfg.warmup_cpu_cycles = 1_000 + rng.below(2_000);
        cfg.loop_mode =
            if rng.below(2) == 0 { LoopMode::EventDriven } else { LoopMode::StrictTick };
        cfg.seed = seed;
        let kind = MechanismKind::all()[rng.below(5) as usize];
        let mix = rng.below(8) as usize;

        let cold = System::new_mix(&cfg, kind, mix).run();

        let mut warm = System::new_mix(&cfg, kind, mix);
        warm.run_warmup();
        let mut snap = SimSnapshot::capture(&warm);
        if rng.below(2) == 0 {
            snap = SimSnapshot::decode(&snap.encode()).expect("codec round-trip");
        }
        let mut fresh = System::new_mix(&cfg, kind, mix);
        snap.restore_into(&mut fresh).expect("same-config restore");
        let forked = fresh.run_measure();
        assert_eq!(cold, forked, "forked run drifted from cold ({kind:?}, seed {seed})");
    });
}

/// The mechanism ordering invariant at system level, across random small
/// workloads: LL-DRAM cycles <= ChargeCache cycles <= ~Baseline cycles.
#[test]
fn prop_mechanism_ordering_on_random_workloads() {
    use chargecache::trace::PROFILES;
    property(4, |rng, seed| {
        let mut cfg = SystemConfig::default();
        cfg.insts_per_core = 40_000;
        cfg.warmup_cpu_cycles = 15_000;
        cfg.seed = seed;
        let p = &PROFILES[rng.below(PROFILES.len() as u64) as usize];
        let base = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let cc = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let ll = System::new(&cfg, MechanismKind::LlDram, &[p]).run();
        // Tolerate a few % scheduling chaos (FR-FCFS decisions shift when
        // commands become ready earlier; LLC interleavings diverge).
        assert!(
            cc.ipc() >= base.ipc() * 0.97,
            "CC slower than baseline on {}: {} vs {} (seed {seed})",
            p.name,
            cc.ipc(),
            base.ipc()
        );
        assert!(
            ll.ipc() >= cc.ipc() * 0.97,
            "LL-DRAM slower than CC on {}: {} vs {} (seed {seed})",
            p.name,
            ll.ipc(),
            cc.ipc()
        );
    });
}
