//! Differential tests for the event-driven kernel (`sim::engine`): the
//! event loop and the strict per-cycle loop must produce **bit-identical**
//! [`SimResult`]s across mechanisms, core counts, row policies, and
//! measurement modes — plus determinism of the parallel experiment runner
//! across worker counts.
//!
//! Note on CC+NUAT: `CombinedMech::on_activate` now grants the
//! element-wise *minimum* effective timing when both components reduce
//! (it used to always prefer the ChargeCache grant). Strict-vs-event
//! equivalence is unaffected — both loops run the same mechanism — but
//! CC+NUAT rows recorded by pre-fix builds may legitimately differ under
//! asymmetric reduction configs, which is why `diskjson::VERSION` was
//! bumped with the change.

use chargecache::config::{RowPolicy, SystemConfig, TrafficMode};
use chargecache::controller::SchedulerKind;
use chargecache::coordinator::runner::parallel_map_threads;
use chargecache::latency::MechanismKind;
use chargecache::sim::engine::LoopMode;
use chargecache::sim::wake::WakeImpl;
use chargecache::sim::{SimResult, System};
use chargecache::trace::Profile;

const MECHS: [MechanismKind; 4] = [
    MechanismKind::Baseline,
    MechanismKind::ChargeCache,
    MechanismKind::Nuat,
    MechanismKind::LlDram,
];

fn run_single(kind: MechanismKind, mode: LoopMode, workload: &str) -> SimResult {
    let mut cfg = SystemConfig::single_core();
    cfg.insts_per_core = 20_000;
    cfg.warmup_cpu_cycles = 8_000;
    cfg.loop_mode = mode;
    let p = Profile::by_name(workload).unwrap();
    System::new(&cfg, kind, &[p]).run()
}

fn run_mix(kind: MechanismKind, mode: LoopMode) -> SimResult {
    // The paper's multi-core shape scaled to 4 cores: 2 channels,
    // closed-row policy, fixed-work measurement.
    let mut cfg = SystemConfig::eight_core();
    cfg.cpu.cores = 4;
    cfg.insts_per_core = 8_000;
    cfg.warmup_cpu_cycles = 4_000;
    cfg.loop_mode = mode;
    System::new_mix(&cfg, kind, 1).run()
}

/// Assert full-state identity. The headline fields get their own
/// assertions (readable failures); the derived `SimResult: PartialEq`
/// then covers every remaining field, so a divergence points at the
/// differing field instead of dumping two Debug strings.
fn assert_identical(strict: &SimResult, event: &SimResult, what: &str) {
    assert_eq!(strict.cpu_cycles, event.cpu_cycles, "{what}: cpu_cycles drift");
    assert_eq!(strict.acts(), event.acts(), "{what}: acts drift");
    assert_eq!(strict.total_insts, event.total_insts, "{what}: total_insts drift");
    assert_eq!(strict.core_ipc, event.core_ipc, "{what}: IPC drift");
    assert_eq!(strict, event, "{what}: full-result drift");
}

#[test]
fn single_core_matrix_is_bit_identical() {
    for kind in MECHS {
        for wl in ["mcf", "tpcc64"] {
            let strict = run_single(kind, LoopMode::StrictTick, wl);
            let event = run_single(kind, LoopMode::EventDriven, wl);
            assert_identical(&strict, &event, &format!("{wl}/{}", kind.label()));
        }
    }
}

#[test]
fn four_core_mix_matrix_is_bit_identical() {
    for kind in MECHS {
        let strict = run_mix(kind, LoopMode::StrictTick);
        let event = run_mix(kind, LoopMode::EventDriven);
        assert_identical(&strict, &event, kind.label());
    }
}

#[test]
fn closed_row_policy_single_core_is_bit_identical() {
    // The eager-precharge pass has its own wake bound; pin it in
    // isolation from the multi-core mix.
    let run = |mode: LoopMode| -> SimResult {
        let mut cfg = SystemConfig::single_core();
        cfg.mc.row_policy = RowPolicy::Closed;
        cfg.insts_per_core = 15_000;
        cfg.warmup_cpu_cycles = 6_000;
        cfg.loop_mode = mode;
        let p = Profile::by_name("libquantum").unwrap();
        System::new(&cfg, MechanismKind::ChargeCache, &[p]).run()
    };
    assert_identical(&run(LoopMode::StrictTick), &run(LoopMode::EventDriven), "closed-row");
}

#[test]
fn fixed_time_window_is_bit_identical() {
    // The measure_cycles = Some(n) path (multiprogrammed methodology).
    let run = |mode: LoopMode| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 2;
        cfg.insts_per_core = 10_000;
        cfg.warmup_cpu_cycles = 5_000;
        cfg.measure_cycles = Some(60_000);
        cfg.loop_mode = mode;
        System::new_mix(&cfg, MechanismKind::ChargeCacheNuat, 0).run()
    };
    assert_identical(&run(LoopMode::StrictTick), &run(LoopMode::EventDriven), "fixed-time");
}

#[test]
fn fcfs_and_bliss_single_core_are_bit_identical() {
    // The new scheduler policies must satisfy the same wake contract as
    // FR-FCFS: strict-tick and event-driven runs may not drift by a bit.
    for sched in [SchedulerKind::Fcfs, SchedulerKind::Bliss] {
        for kind in [MechanismKind::Baseline, MechanismKind::ChargeCache] {
            let run = |mode: LoopMode| -> SimResult {
                let mut cfg = SystemConfig::single_core();
                cfg.mc.scheduler = sched;
                cfg.insts_per_core = 20_000;
                cfg.warmup_cpu_cycles = 8_000;
                cfg.loop_mode = mode;
                let p = Profile::by_name("mcf").unwrap();
                System::new(&cfg, kind, &[p]).run()
            };
            assert_identical(
                &run(LoopMode::StrictTick),
                &run(LoopMode::EventDriven),
                &format!("mcf/{}/{}", sched.label(), kind.label()),
            );
        }
    }
}

#[test]
fn fcfs_and_bliss_four_core_mix_are_bit_identical() {
    for sched in [SchedulerKind::Fcfs, SchedulerKind::Bliss] {
        let run = |mode: LoopMode| -> SimResult {
            let mut cfg = SystemConfig::eight_core();
            cfg.mc.scheduler = sched;
            cfg.cpu.cores = 4;
            cfg.insts_per_core = 8_000;
            cfg.warmup_cpu_cycles = 4_000;
            cfg.loop_mode = mode;
            System::new_mix(&cfg, MechanismKind::ChargeCache, 1).run()
        };
        assert_identical(
            &run(LoopMode::StrictTick),
            &run(LoopMode::EventDriven),
            sched.label(),
        );
    }
}

#[test]
fn sharded_64_core_mix_is_bit_identical_across_shard_counts() {
    // The channel-sharded event loop (`sim::shard`) must be a pure
    // parallelization: the paper's large shape (64 cores, 8 channels)
    // run at 1/2/4/8 shards and under the strict per-cycle oracle may
    // not drift by a bit. 1 shard takes the exact sequential event
    // path, so t1 vs strict also re-pins the event-loop contract on
    // this shape.
    let run = |kind: MechanismKind, mode: LoopMode, shards: usize| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 64;
        cfg.dram.channels = 8;
        cfg.insts_per_core = 800;
        cfg.warmup_cpu_cycles = 1_500;
        cfg.loop_mode = mode;
        cfg.sim_threads = shards;
        System::new_mix(&cfg, kind, 1).run()
    };
    for kind in [MechanismKind::Baseline, MechanismKind::ChargeCache] {
        let strict = run(kind, LoopMode::StrictTick, 1);
        let t1 = run(kind, LoopMode::EventDriven, 1);
        assert_identical(&strict, &t1, &format!("64-core/{}/event", kind.label()));
        for shards in [2usize, 4, 8] {
            let tn = run(kind, LoopMode::EventDriven, shards);
            assert_identical(&t1, &tn, &format!("64-core/{}/{shards}-shard", kind.label()));
        }
    }
}

#[test]
fn wake_wheel_matches_heap_oracle_across_mechanisms_and_shards() {
    // The wake-impl axis of the equivalence matrix: the timing wheel as
    // the production index, the lazily-pruned heap as the differential
    // oracle, and strict-tick (which never consults the index) as
    // ground truth. On the paper's large shape (64 cores, 8 channels)
    // every mechanism must be bit-identical across heap vs wheel and
    // across 1/2/4/8 wheel-backed shards — the wake index may only ever
    // change *when* the kernel looks at a component, never what it sees.
    let run = |kind: MechanismKind, imp: WakeImpl, mode: LoopMode, shards: usize| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 64;
        cfg.dram.channels = 8;
        cfg.insts_per_core = 800;
        cfg.warmup_cpu_cycles = 1_500;
        cfg.loop_mode = mode;
        cfg.sim_threads = shards;
        cfg.wake_impl = imp;
        System::new_mix(&cfg, kind, 1).run()
    };
    for kind in MECHS {
        let strict = run(kind, WakeImpl::Heap, LoopMode::StrictTick, 1);
        let heap = run(kind, WakeImpl::Heap, LoopMode::EventDriven, 1);
        let wheel = run(kind, WakeImpl::Wheel, LoopMode::EventDriven, 1);
        assert_identical(&strict, &heap, &format!("64-core/{}/heap-vs-strict", kind.label()));
        assert_identical(&heap, &wheel, &format!("64-core/{}/wheel-vs-heap", kind.label()));
        for shards in [2usize, 4, 8] {
            let tn = run(kind, WakeImpl::Wheel, LoopMode::EventDriven, shards);
            assert_identical(
                &wheel,
                &tn,
                &format!("64-core/{}/wheel-{shards}-shard", kind.label()),
            );
        }
    }
}

#[test]
fn sharding_ignores_strict_tick_and_uneven_channel_splits() {
    // `sim.threads` > 1 under StrictTick must silently take the oracle
    // path (the knob only applies to the event loop), and a shard count
    // that doesn't divide the channel count (3 shards, 2 channels ->
    // capped; 3 shards over 8 channels -> uneven chunks) must still be
    // bit-identical.
    let run = |mode: LoopMode, channels: usize, shards: usize| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.dram.channels = channels;
        cfg.insts_per_core = 4_000;
        cfg.warmup_cpu_cycles = 2_000;
        cfg.loop_mode = mode;
        cfg.sim_threads = shards;
        System::new_mix(&cfg, MechanismKind::ChargeCache, 1).run()
    };
    let strict = run(LoopMode::StrictTick, 2, 3);
    let capped = run(LoopMode::EventDriven, 2, 3);
    assert_identical(&strict, &capped, "3-shards-over-2-channels");
    let strict8 = run(LoopMode::StrictTick, 8, 1);
    let uneven = run(LoopMode::EventDriven, 8, 3);
    assert_identical(&strict8, &uneven, "3-shards-over-8-channels");
}

#[test]
fn closed_loop_rows_ignore_every_traffic_knob() {
    // `traffic.mode = closed` (the default) must leave the closed-loop
    // pipeline bit-identical no matter what the other traffic.* knobs
    // say: the injector only exists in open mode, and its RNG draws from
    // its own SplitMix64 domain, so the synth trace streams never see a
    // perturbed sequence. This is the upgrade-safety row — configs that
    // predate the traffic subsystem must reproduce exactly.
    let run = |touch: bool, mode: LoopMode| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.insts_per_core = 8_000;
        cfg.warmup_cpu_cycles = 4_000;
        cfg.loop_mode = mode;
        if touch {
            cfg.traffic.rate_rps = 123_456_789.0;
            cfg.traffic.seed = 999;
            cfg.traffic.burst_on_us = 2.5;
            cfg.traffic.mmpp_ratio = 9.0;
        }
        System::new_mix(&cfg, MechanismKind::ChargeCache, 1).run()
    };
    for mode in [LoopMode::StrictTick, LoopMode::EventDriven] {
        let pristine = run(false, mode);
        let touched = run(true, mode);
        assert_identical(&pristine, &touched, &format!("{mode:?}/traffic-knobs"));
    }
}

#[test]
fn open_loop_percentiles_are_bit_identical_across_modes_wakes_and_shards() {
    // The open-loop injector joins the determinism matrix: Poisson
    // arrivals over 8 channels must produce the same latency histogram —
    // hence the same percentiles — under the strict per-cycle oracle,
    // the event loop with either wake index, and 1/2/4/8 channel shards.
    let run = |imp: WakeImpl, mode: LoopMode, shards: usize| -> SimResult {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 8;
        cfg.dram.channels = 8;
        cfg.insts_per_core = 800;
        cfg.warmup_cpu_cycles = 2_000;
        cfg.measure_cycles = Some(60_000);
        cfg.loop_mode = mode;
        cfg.sim_threads = shards;
        cfg.wake_impl = imp;
        cfg.traffic.mode = TrafficMode::Poisson;
        cfg.traffic.rate_rps = 60_000_000.0;
        System::new_mix(&cfg, MechanismKind::ChargeCache, 0).run()
    };
    let strict = run(WakeImpl::Heap, LoopMode::StrictTick, 1);
    let lat = strict.latency.expect("open-loop run records read latencies");
    assert!(lat.samples > 0, "no reads completed in the open-loop window");
    assert_eq!(strict.total_insts, 0, "open-loop measure must quiesce the cores");
    let heap = run(WakeImpl::Heap, LoopMode::EventDriven, 1);
    let wheel = run(WakeImpl::Wheel, LoopMode::EventDriven, 1);
    assert_identical(&strict, &heap, "open-loop/heap-vs-strict");
    assert_identical(&heap, &wheel, "open-loop/wheel-vs-heap");
    for shards in [2usize, 4, 8] {
        let tn = run(WakeImpl::Wheel, LoopMode::EventDriven, shards);
        assert_identical(&wheel, &tn, &format!("open-loop/{shards}-shard"));
    }
}

#[test]
fn parallel_map_threads_is_deterministic_across_thread_counts() {
    // Real simulation payload (the same jobs the experiment suites run),
    // mapped across 1, 2, and 8 workers: index-pure + in-order results.
    let sim = |i: usize| -> (u64, u64, String) {
        let wl = ["mcf", "gcc", "tpcc64"][i % 3];
        let kind = MECHS[i % MECHS.len()];
        let mut cfg = SystemConfig::single_core();
        cfg.insts_per_core = 4_000;
        cfg.warmup_cpu_cycles = 2_000;
        let p = Profile::by_name(wl).unwrap();
        let r = System::new(&cfg, kind, &[p]).run();
        (r.cpu_cycles, r.acts(), format!("{:?}", r.core_ipc))
    };
    let t1 = parallel_map_threads(6, 1, sim);
    let t2 = parallel_map_threads(6, 2, sim);
    let t8 = parallel_map_threads(6, 8, sim);
    assert_eq!(t1, t2, "1-thread vs 2-thread results diverged");
    assert_eq!(t1, t8, "1-thread vs 8-thread results diverged");
}
