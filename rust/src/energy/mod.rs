//! DRAM energy (DRAMPower-style IDD current model) and ChargeCache
//! area/power (McPAT-style analytic SRAM model) — the paper's Sec. 6.4 and
//! Sec. 6.5 substrates.

pub mod area;
pub mod dram_energy;

pub use area::HcracCost;
pub use dram_energy::{DddIdd, EnergyBreakdown, EnergyModel};
