//! DRAM energy model — DRAMPower-equivalent JEDEC IDD accounting.
//!
//! Per-command energies and state-dependent background power are derived
//! from Micron DDR3-1600 4 Gb x8 datasheet currents, scaled to the rank's
//! chip count (64-bit bus / x8 = 8 devices). ChargeCache affects energy two
//! ways (paper Sec. 6.4): reduced-tRAS activations cost slightly less, and
//! shorter execution time cuts background + refresh energy.

use crate::config::{SystemConfig, Timing};
use crate::controller::McStats;

/// DDR3 IDD currents in mA (Micron MT41J512M8, DDR3-1600).
#[derive(Debug, Clone)]
pub struct DddIdd {
    pub vdd: f64,
    pub idd0: f64,
    pub idd2n: f64,
    pub idd3n: f64,
    pub idd4r: f64,
    pub idd4w: f64,
    pub idd5b: f64,
    /// DRAM devices per rank (64-bit channel of x8 chips).
    pub chips: f64,
}

impl Default for DddIdd {
    fn default() -> Self {
        Self {
            vdd: 1.5,
            idd0: 95.0,
            idd2n: 42.0,
            idd3n: 45.0,
            idd4r: 180.0,
            idd4w: 185.0,
            idd5b: 215.0,
            chips: 8.0,
        }
    }
}

/// Energy totals in nanojoules.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub act_pre_nj: f64,
    pub read_nj: f64,
    pub write_nj: f64,
    pub refresh_nj: f64,
    pub background_nj: f64,
}

impl EnergyBreakdown {
    pub fn total_nj(&self) -> f64 {
        self.act_pre_nj + self.read_nj + self.write_nj + self.refresh_nj + self.background_nj
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.act_pre_nj += other.act_pre_nj;
        self.read_nj += other.read_nj;
        self.write_nj += other.write_nj;
        self.refresh_nj += other.refresh_nj;
        self.background_nj += other.background_nj;
    }
}

/// The energy model bound to a timing/IDD configuration.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    idd: DddIdd,
    timing: Timing,
    tras_reduced: u64,
}

impl EnergyModel {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            idd: DddIdd::default(),
            timing: cfg.timing.clone(),
            tras_reduced: cfg.timing.tras - cfg.chargecache.tras_reduction,
        }
    }

    /// mA * cycles -> nJ at VDD across the rank's chips.
    #[inline]
    fn ma_cycles_to_nj(&self, ma: f64, cycles: f64) -> f64 {
        // mA * V * ns = pJ; / 1000 -> nJ.
        ma * self.idd.vdd * (cycles * self.timing.tck_ns) * self.idd.chips / 1000.0
    }

    /// Energy of one ACT+PRE pair with effective tRAS (DRAMPower eq.):
    /// the IDD0 window minus the background current already accounted
    /// for. A reduced tRAS shortens the effective row cycle
    /// (tRC_eff = tRAS_eff + tRP), which is where ChargeCache's per-ACT
    /// saving comes from.
    pub fn act_pre_nj(&self, tras_eff: u64) -> f64 {
        let tras = tras_eff as f64;
        let trc = tras + self.timing.trp as f64;
        let bg = self.idd.idd3n * tras + self.idd.idd2n * (trc - tras);
        self.ma_cycles_to_nj(self.idd.idd0 * trc - bg, 1.0) // currents already x cycles
    }

    pub fn read_nj(&self) -> f64 {
        self.ma_cycles_to_nj(self.idd.idd4r - self.idd.idd3n, self.timing.tbl as f64)
    }

    pub fn write_nj(&self) -> f64 {
        self.ma_cycles_to_nj(self.idd.idd4w - self.idd.idd3n, self.timing.tbl as f64)
    }

    pub fn refresh_nj(&self) -> f64 {
        self.ma_cycles_to_nj(self.idd.idd5b - self.idd.idd3n, self.timing.trfc as f64)
    }

    /// Full-run energy for one channel.
    ///
    /// * `stats` — command counts from the controller,
    /// * `rank_active_cycles` — per-rank cycles with >= 1 open bank,
    /// * `bus_cycles` — measured wall time in bus cycles.
    pub fn channel_energy(
        &self,
        stats: &McStats,
        rank_active_cycles: &[u64],
        bus_cycles: u64,
    ) -> EnergyBreakdown {
        let acts_std = stats.acts - stats.acts_reduced;
        let act_pre_nj = acts_std as f64 * self.act_pre_nj(self.timing.tras)
            + stats.acts_reduced as f64 * self.act_pre_nj(self.tras_reduced);
        let read_nj = stats.reads as f64 * self.read_nj();
        let write_nj = stats.writes as f64 * self.write_nj();
        let refresh_nj = stats.refreshes as f64 * self.refresh_nj();
        let mut background_nj = 0.0;
        for &active in rank_active_cycles {
            let active = active.min(bus_cycles) as f64;
            let idle = bus_cycles as f64 - active;
            background_nj += self.ma_cycles_to_nj(self.idd.idd3n, active)
                + self.ma_cycles_to_nj(self.idd.idd2n, idle);
        }
        EnergyBreakdown { act_pre_nj, read_nj, write_nj, refresh_nj, background_nj }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> EnergyModel {
        EnergyModel::new(&SystemConfig::default())
    }

    #[test]
    fn act_pre_energy_positive_and_reduced_tras_cheaper() {
        let m = model();
        let std = m.act_pre_nj(28);
        let red = m.act_pre_nj(20);
        assert!(std > 0.0);
        assert!(red < std, "reduced tRAS must cost less: {red} vs {std}");
    }

    #[test]
    fn burst_energies_positive() {
        let m = model();
        assert!(m.read_nj() > 0.0);
        assert!(m.write_nj() > m.read_nj() * 0.9); // IDD4W slightly higher
        assert!(m.refresh_nj() > m.read_nj());
    }

    #[test]
    fn background_scales_with_time() {
        let m = model();
        let stats = McStats::default();
        let e1 = m.channel_energy(&stats, &[0], 1000);
        let e2 = m.channel_energy(&stats, &[0], 2000);
        assert!((e2.background_nj / e1.background_nj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn active_standby_costs_more_than_idle() {
        let m = model();
        let stats = McStats::default();
        let idle = m.channel_energy(&stats, &[0], 1000);
        let active = m.channel_energy(&stats, &[1000], 1000);
        assert!(active.background_nj > idle.background_nj);
    }

    #[test]
    fn shorter_run_saves_energy() {
        // The headline effect: same work, fewer cycles -> less energy.
        let m = model();
        let mut stats = McStats::default();
        stats.acts = 1000;
        stats.reads = 3000;
        stats.refreshes = 10;
        let slow = m.channel_energy(&stats, &[500_000], 1_000_000);
        let fast = m.channel_energy(&stats, &[480_000], 930_000);
        assert!(fast.total_nj() < slow.total_nj());
    }

    #[test]
    fn ballpark_activation_energy() {
        // An ACT/PRE pair on a DDR3 rank is ~10-40 nJ across 8 chips.
        let m = model();
        let e = m.act_pre_nj(28);
        assert!(e > 5.0 && e < 60.0, "ACT+PRE energy {e} nJ out of range");
    }
}
