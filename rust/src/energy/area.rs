//! HCRAC area / power — the paper's Sec. 6.5 overhead analysis (McPAT at
//! 22 nm in the paper; here a calibrated analytic SRAM model).
//!
//! Storage follows Eq. (1)/(2) exactly (implemented by
//! [`SystemConfig::hcrac_storage_bits`]). Area and power use per-bit
//! constants calibrated so the paper's 8-core / 2-channel configuration
//! lands on the published 0.022 mm^2 and 0.149 mW:
//!   area_per_bit  = 0.022 mm^2 / 43008 bits
//!   power         = static (per bit) + dynamic (per access)

use crate::config::SystemConfig;

/// 22 nm SRAM area per bit, calibrated to the paper's report [mm^2/bit].
pub const AREA_MM2_PER_BIT: f64 = 0.022 / 43008.0;
/// Static leakage per bit [mW/bit] (~60% of the paper's power figure).
pub const STATIC_MW_PER_BIT: f64 = 0.149 * 0.6 / 43008.0;
/// Dynamic energy per HCRAC access [pJ] (lookup or insert of ~21 bits).
pub const DYNAMIC_PJ_PER_ACCESS: f64 = 0.35;

/// Area/power report for a ChargeCache configuration.
#[derive(Debug, Clone)]
pub struct HcracCost {
    pub storage_bits: u64,
    pub storage_bytes: u64,
    pub area_mm2: f64,
    pub static_mw: f64,
    /// Dynamic power at the given access rate.
    pub dynamic_mw: f64,
}

impl HcracCost {
    /// `accesses_per_sec`: HCRAC lookups+inserts per second (activate +
    /// precharge rate of the memory controller).
    pub fn of(cfg: &SystemConfig, accesses_per_sec: f64) -> Self {
        let bits = cfg.hcrac_storage_bits();
        let dynamic_mw = accesses_per_sec * DYNAMIC_PJ_PER_ACCESS * 1e-12 * 1e3;
        Self {
            storage_bits: bits,
            storage_bytes: bits / 8,
            area_mm2: bits as f64 * AREA_MM2_PER_BIT,
            static_mw: bits as f64 * STATIC_MW_PER_BIT,
            dynamic_mw,
        }
    }

    pub fn total_mw(&self) -> f64 {
        self.static_mw + self.dynamic_mw
    }

    /// Overhead relative to a 4 MB LLC (paper compares against it).
    pub fn area_fraction_of_llc(&self) -> f64 {
        // Paper: 0.022 mm^2 is 0.24% of the 4 MB LLC => LLC ~ 9.17 mm^2.
        self.area_mm2 / 9.17
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configuration_reproduces_sec65() {
        // 8 cores, 2 channels, 128-entry HCRAC: 5376 bytes, 0.022 mm^2.
        let cfg = SystemConfig::eight_core();
        // Paper's average access rate: every ACT + PRE; ~10M/s per channel
        // is representative of the evaluated workloads.
        let cost = HcracCost::of(&cfg, 170e6);
        assert_eq!(cost.storage_bytes, 5376);
        assert!((cost.area_mm2 - 0.022).abs() < 1e-9);
        // Power within ~15% of the published 0.149 mW.
        assert!(
            (cost.total_mw() - 0.149).abs() < 0.02,
            "power {} mW",
            cost.total_mw()
        );
        // "only 0.24% of the 4MB LLC" area.
        assert!((cost.area_fraction_of_llc() - 0.0024).abs() < 2e-4);
    }

    #[test]
    fn storage_scales_linearly_with_entries() {
        let mut cfg = SystemConfig::eight_core();
        let base = HcracCost::of(&cfg, 0.0).storage_bits;
        cfg.chargecache.entries_per_core = 256;
        assert_eq!(HcracCost::of(&cfg, 0.0).storage_bits, base * 2);
    }

    #[test]
    fn dynamic_power_scales_with_access_rate() {
        let cfg = SystemConfig::eight_core();
        let a = HcracCost::of(&cfg, 1e6).dynamic_mw;
        let b = HcracCost::of(&cfg, 2e6).dynamic_mw;
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
