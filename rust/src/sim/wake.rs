//! WakeIndex — indexed wake scheduling for the event kernel.
//!
//! [`crate::sim::System::next_wake`] used to recompute every component's
//! `next_event_at` bound on *every* event jump: O(cores + controllers)
//! work per jump, with each controller bound itself costing a queue scan
//! (`SchedPolicy::next_ready_at`). The index caches one bound per
//! component and maintains it **incrementally**: a bound is recomputed
//! only when its component is ticked, and pulled down (never pushed up)
//! when an external mutation could wake the component earlier — a
//! completion delivered to a core, or an enqueue landing in a
//! controller. The global minimum then costs O(log n) amortized via a
//! lazily-pruned min-heap instead of a rescan.
//!
//! ## Soundness
//!
//! The event kernel's wake contract ([`crate::sim::engine`]) tolerates
//! *early* bounds (a too-early wake is a no-op tick) but never *late*
//! ones. The index preserves that one-sidedness: cached values start at
//! 0 (hot), are only ever replaced by a freshly computed `next_event_at`
//! immediately after the component ticked, or clamped *down* by an
//! invalidation. Stale heap entries are harmless — an entry is trusted
//! only while it matches the component's current cached bound; anything
//! else is discarded when it surfaces.
//!
//! The channel-sharded loop ([`crate::sim::shard`], DESIGN.md §11)
//! reuses the same structure per shard: each `ShardState` holds a
//! private `WakeIndex` over its local controllers, indexed by local
//! channel id and kept in the **bus-cycle** domain (the coordinator
//! converts to CPU cycles). The soundness argument is unchanged — and
//! because early bounds are free, the sharded path may start every lend
//! hot at 0 rather than translating the sequential index's entries.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cached per-component wake bounds with an O(log n) global minimum.
///
/// Component ids are dense `0..n` (the [`crate::sim::System`] maps cores
/// first, then controllers). A bound of `u64::MAX` means "only an
/// external invalidation can wake this component" and gets no heap
/// entry at all.
#[derive(Debug)]
pub struct WakeIndex {
    /// Current bound per component — the single source of truth.
    bounds: Vec<u64>,
    /// Min-heap of `(bound, component)` snapshots; entries whose bound
    /// no longer matches `bounds` are stale and lazily discarded.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl WakeIndex {
    /// All `n` components start hot at cycle 0.
    pub fn new(n: usize) -> Self {
        let mut heap = BinaryHeap::with_capacity(2 * n + 8);
        for id in 0..n {
            heap.push(Reverse((0, id as u32)));
        }
        Self { bounds: vec![0; n], heap }
    }

    /// Number of indexed components.
    pub fn len(&self) -> usize {
        self.bounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bounds.is_empty()
    }

    /// The cached bound of component `id`.
    #[inline]
    pub fn bound(&self, id: usize) -> u64 {
        self.bounds[id]
    }

    /// Replace component `id`'s bound.
    pub fn set(&mut self, id: usize, bound: u64) {
        if self.bounds[id] == bound {
            return;
        }
        self.bounds[id] = bound;
        if bound != u64::MAX {
            self.heap.push(Reverse((bound, id as u32)));
        }
    }

    /// The minimum cached bound over every component, or `u64::MAX` when
    /// every component sleeps indefinitely. Amortized O(log n): each
    /// discarded stale entry was paid for by the `set` that pushed it.
    pub fn min_bound(&mut self) -> u64 {
        while let Some(&Reverse((bound, id))) = self.heap.peek() {
            if self.bounds[id as usize] == bound {
                return bound;
            }
            self.heap.pop();
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_index_is_hot_everywhere() {
        let mut w = WakeIndex::new(3);
        assert_eq!(w.len(), 3);
        assert_eq!(w.min_bound(), 0);
        assert_eq!(w.bound(2), 0);
    }

    #[test]
    fn min_tracks_updates_and_prunes_stale_entries() {
        let mut w = WakeIndex::new(3);
        w.set(0, 10);
        w.set(1, 7);
        w.set(2, 30);
        assert_eq!(w.min_bound(), 7);
        w.set(1, 40); // the (7, 1) entry becomes stale
        assert_eq!(w.min_bound(), 10);
        w.set(0, 50);
        assert_eq!(w.min_bound(), 30);
    }

    #[test]
    fn lowering_a_bound_takes_effect_immediately() {
        let mut w = WakeIndex::new(2);
        w.set(0, 100);
        w.set(1, 200);
        assert_eq!(w.min_bound(), 100);
        w.set(1, 5);
        assert_eq!(w.min_bound(), 5);
    }

    #[test]
    fn max_bound_means_never_self_wakes() {
        let mut w = WakeIndex::new(2);
        w.set(0, u64::MAX);
        w.set(1, u64::MAX);
        assert_eq!(w.min_bound(), u64::MAX);
        w.set(0, 9);
        assert_eq!(w.min_bound(), 9);
    }

    #[test]
    fn redundant_sets_are_noops() {
        let mut w = WakeIndex::new(1);
        w.set(0, 4);
        w.set(0, 4);
        w.set(0, 4);
        assert_eq!(w.min_bound(), 4);
        w.set(0, 6);
        assert_eq!(w.min_bound(), 6);
    }

    #[test]
    fn interleaved_raise_lower_sequences_stay_consistent() {
        // Exercise the lazy heap with a deterministic pseudo-random walk
        // against a naive rescan oracle.
        let n = 8usize;
        let mut w = WakeIndex::new(n);
        let mut oracle = vec![0u64; n];
        let mut state = 0x9E37_79B9u64;
        for _ in 0..2000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let id = (state >> 33) as usize % n;
            let bound = if state % 17 == 0 { u64::MAX } else { state % 10_000 };
            w.set(id, bound);
            oracle[id] = bound;
            assert_eq!(w.min_bound(), *oracle.iter().min().unwrap());
        }
    }
}
