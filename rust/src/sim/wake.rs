//! Wake scheduling for the event kernel — a hierarchical timing wheel
//! with the original lazily-pruned min-heap kept as a differential
//! oracle.
//!
//! [`crate::sim::System::next_wake`] used to recompute every component's
//! `next_event_at` bound on *every* event jump: O(cores + controllers)
//! work per jump, with each controller bound itself costing a queue scan
//! (`SchedPolicy::next_ready_at`). The index caches one bound per
//! component and maintains it **incrementally**: a bound is recomputed
//! only when its component is ticked, and pulled down (never pushed up)
//! when an external mutation could wake the component earlier — a
//! completion delivered to a core, or an enqueue landing in a
//! controller.
//!
//! Two interchangeable structures answer the global-minimum query behind
//! the [`WakeIndex`] facade:
//!
//! * [`WakeWheel`] (default) — a hierarchical timing wheel: [`LEVELS`]
//!   levels of [`SLOTS`] slots at power-of-two granularities (level `l`
//!   buckets `2^(6l)` bus/CPU cycles per slot), covering a `2^48`-cycle
//!   horizon with an overflow list beyond it. Insert, clamp-down, and
//!   cursor advance are O(1) amortized; the minimum is found by
//!   scanning per-level occupancy bitmasks, not by heap rebalancing.
//! * [`WakeHeap`] (oracle) — the original lazily-pruned
//!   `BinaryHeap<Reverse<(bound, id)>>`, O(log n) per operation with
//!   occupancy-triggered compaction, kept selectable for differential
//!   property tests, wheel-vs-heap equivalence rows, and benchmark
//!   comparisons.
//!
//! [`WakeImpl`] selects between them: `sim.wake_impl` in the parameter
//! registry, with the `auto` default deferring to `PALLAS_WAKE_IMPL`
//! (`"heap"` selects the oracle; anything else means wheel).
//!
//! ## Soundness
//!
//! The event kernel's wake contract ([`crate::sim::engine`]) tolerates
//! *early* bounds (a too-early wake is a no-op tick) but never *late*
//! ones. Both implementations preserve that one-sidedness the same way:
//! cached values start at 0 (hot), are only ever replaced by a freshly
//! computed `next_event_at` immediately after the component ticked, or
//! clamped *down* by an invalidation. `bounds` is the single source of
//! truth; a heap entry or wheel slot entry is trusted only while it
//! matches the component's current cached bound, and anything else is
//! discarded when it surfaces. The wheel adds one invariant: every
//! entry bucketed in a slot is `>= cursor`, and the cursor only ever
//! advances to a value no greater than the smallest live slot entry, so
//! a minimum scan can never skip a live bound. Bounds set *below* the
//! cursor (re-heating after a sampled fast-forward, shard reassembly)
//! are parked in a small `due` side list that the minimum query scans
//! first — an early bound is free, so parking is always sound.
//!
//! ## Batched draining
//!
//! [`WakeIndex::drain_due`] pops every component whose bound is
//! `<= now` in one call, so the event loop dispatches a whole bus
//! boundary's wakes with one index traversal instead of one minimum
//! query per component. The one-sided contract survives because a drain
//! is a bulk pop of already-due entries: the caller must re-`set` every
//! drained id to its next bound before the next query (every call site
//! re-sets to `>= now + 1` or to a trailing clamp), exactly as it would
//! after ticking that component under per-component popping. A drained
//! id may appear twice (an id can own two live-looking entries after a
//! set-away-and-back sequence), so callers sort + dedup the batch.
//!
//! The channel-sharded loop ([`crate::sim::shard`], DESIGN.md §11)
//! reuses the same structure per shard: each `ShardState` holds a
//! private `WakeIndex` over its local controllers, indexed by local
//! channel id and kept in the **bus-cycle** domain (the coordinator
//! converts to CPU cycles). The soundness argument is unchanged — and
//! because early bounds are free, the sharded path may start every lend
//! hot at 0 rather than translating the sequential index's entries.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::OnceLock;

/// log2 of the slot count per wheel level.
pub const SLOT_BITS: usize = 6;
/// Slots per wheel level (one occupancy `u64` per level).
pub const SLOTS: usize = 1 << SLOT_BITS;
/// Wheel levels; level `l` buckets `2^(SLOT_BITS * l)` cycles per slot.
pub const LEVELS: usize = 8;
/// Bits of horizon the bucketed levels cover; bounds at or beyond
/// `cursor`'s `2^48`-cycle block boundary go to the overflow list.
pub const HORIZON_BITS: usize = SLOT_BITS * LEVELS;

const SLOT_MASK: u64 = (SLOTS - 1) as u64;

/// Which wake-index structure the event kernel runs on.
///
/// Both implementations are bit-identical in simulation results (the
/// engine-equivalence suite pins this); the choice only affects kernel
/// speed. Hashed into the config fingerprint anyway — like `loop_mode`
/// and `sim_threads` — so the equivalence tests can never compare a
/// cached result against itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WakeImpl {
    /// Defer to `PALLAS_WAKE_IMPL` (`"heap"` → heap, else wheel).
    Auto,
    /// Hierarchical timing wheel (the default resolution).
    Wheel,
    /// Lazily-pruned min-heap (the differential oracle).
    Heap,
}

impl WakeImpl {
    pub const NAMES: [&'static str; 3] = ["auto", "wheel", "heap"];

    pub fn name(self) -> &'static str {
        match self {
            WakeImpl::Auto => "auto",
            WakeImpl::Wheel => "wheel",
            WakeImpl::Heap => "heap",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(WakeImpl::Auto),
            "wheel" => Some(WakeImpl::Wheel),
            "heap" => Some(WakeImpl::Heap),
            _ => None,
        }
    }

    /// Collapse [`WakeImpl::Auto`] against the environment: the first
    /// resolution reads `PALLAS_WAKE_IMPL` once (process-wide), the same
    /// pattern `sim_threads: 0` uses for `PALLAS_SIM_THREADS`.
    pub fn resolved(self) -> WakeImpl {
        match self {
            WakeImpl::Auto => {
                static IMP: OnceLock<WakeImpl> = OnceLock::new();
                *IMP.get_or_init(|| {
                    match std::env::var("PALLAS_WAKE_IMPL").ok().as_deref() {
                        Some("heap") => WakeImpl::Heap,
                        _ => WakeImpl::Wheel,
                    }
                })
            }
            other => other,
        }
    }
}

/// Cached per-component wake bounds, dispatching the minimum/drain
/// machinery to the configured implementation.
///
/// Component ids are dense `0..n` (the [`crate::sim::System`] maps cores
/// first, then controllers). A bound of `u64::MAX` means "only an
/// external invalidation can wake this component" and gets no entry at
/// all.
#[derive(Debug)]
pub enum WakeIndex {
    Wheel(WakeWheel),
    Heap(WakeHeap),
}

impl WakeIndex {
    /// All `n` components start hot at cycle 0, on the wheel.
    pub fn new(n: usize) -> Self {
        WakeIndex::Wheel(WakeWheel::new(n))
    }

    /// All `n` components hot at 0, on the requested implementation
    /// (`Auto` resolves through the environment).
    pub fn with_impl(n: usize, imp: WakeImpl) -> Self {
        match imp.resolved() {
            WakeImpl::Heap => WakeIndex::Heap(WakeHeap::new(n)),
            _ => WakeIndex::Wheel(WakeWheel::new(n)),
        }
    }

    /// Which implementation this index runs on.
    pub fn kind(&self) -> WakeImpl {
        match self {
            WakeIndex::Wheel(_) => WakeImpl::Wheel,
            WakeIndex::Heap(_) => WakeImpl::Heap,
        }
    }

    /// Number of indexed components.
    pub fn len(&self) -> usize {
        match self {
            WakeIndex::Wheel(w) => w.len(),
            WakeIndex::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The cached bound of component `id`.
    #[inline]
    pub fn bound(&self, id: usize) -> u64 {
        match self {
            WakeIndex::Wheel(w) => w.bound(id),
            WakeIndex::Heap(h) => h.bound(id),
        }
    }

    /// Replace component `id`'s bound.
    #[inline]
    pub fn set(&mut self, id: usize, bound: u64) {
        match self {
            WakeIndex::Wheel(w) => w.set(id, bound),
            WakeIndex::Heap(h) => h.set(id, bound),
        }
    }

    /// The minimum cached bound over every component, or `u64::MAX` when
    /// every component sleeps indefinitely.
    #[inline]
    pub fn min_bound(&mut self) -> u64 {
        match self {
            WakeIndex::Wheel(w) => w.min_bound(),
            WakeIndex::Heap(h) => h.min_bound(),
        }
    }

    /// Pop every id whose bound is `<= now` into `out` (appended; may
    /// contain duplicates — callers sort + dedup). Contract: the caller
    /// must re-`set` every drained id before the next query; every call
    /// site re-sets to `>= now + 1` (a recomputed `next_event_at` or a
    /// trailing clamp), so no live bound is ever lost.
    #[inline]
    pub fn drain_due(&mut self, now: u64, out: &mut Vec<u32>) {
        match self {
            WakeIndex::Wheel(w) => w.drain_due(now, out),
            WakeIndex::Heap(h) => h.drain_due(now, out),
        }
    }
}

/// The original lazily-pruned min-heap index (differential oracle).
///
/// Every `set` pushes a `(bound, id)` snapshot; entries whose bound no
/// longer matches `bounds` are stale and discarded when they surface.
/// Occupancy-triggered compaction rebuilds the heap from `bounds` when
/// stale churn grows it past `4n + 64` entries, pinning memory at
/// O(components) even under adversarial clamp patterns.
#[derive(Debug)]
pub struct WakeHeap {
    /// Current bound per component — the single source of truth.
    bounds: Vec<u64>,
    /// Min-heap of `(bound, component)` snapshots.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
}

impl WakeHeap {
    /// All `n` components start hot at cycle 0.
    pub fn new(n: usize) -> Self {
        let mut heap = BinaryHeap::with_capacity(2 * n + 8);
        for id in 0..n {
            heap.push(Reverse((0, id as u32)));
        }
        Self { bounds: vec![0; n], heap }
    }

    pub fn len(&self) -> usize {
        self.bounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bounds.is_empty()
    }

    #[inline]
    pub fn bound(&self, id: usize) -> u64 {
        self.bounds[id]
    }

    /// Heap entries currently held, live and stale (test hook for the
    /// compaction bound).
    pub fn heap_len(&self) -> usize {
        self.heap.len()
    }

    /// Replace component `id`'s bound.
    pub fn set(&mut self, id: usize, bound: u64) {
        if self.bounds[id] == bound {
            return;
        }
        self.bounds[id] = bound;
        if bound == u64::MAX {
            return;
        }
        if self.heap.len() >= 4 * self.bounds.len() + 64 {
            self.compact();
        }
        self.heap.push(Reverse((bound, id as u32)));
    }

    /// Drop every stale entry by rebuilding the heap from `bounds`.
    /// Amortized free: triggered only after >= 3n + 64 stale pushes,
    /// each of which already paid O(log n).
    fn compact(&mut self) {
        self.heap.clear();
        for (id, &b) in self.bounds.iter().enumerate() {
            if b != u64::MAX {
                self.heap.push(Reverse((b, id as u32)));
            }
        }
    }

    /// The minimum cached bound, amortized O(log n): each discarded
    /// stale entry was paid for by the `set` that pushed it.
    pub fn min_bound(&mut self) -> u64 {
        while let Some(&Reverse((bound, id))) = self.heap.peek() {
            if self.bounds[id as usize] == bound {
                return bound;
            }
            self.heap.pop();
        }
        u64::MAX
    }

    /// Pop every id with a live bound `<= now` into `out` (duplicates
    /// possible; see [`WakeIndex::drain_due`] for the re-set contract).
    pub fn drain_due(&mut self, now: u64, out: &mut Vec<u32>) {
        while let Some(&Reverse((bound, id))) = self.heap.peek() {
            if bound > now {
                break;
            }
            self.heap.pop();
            if self.bounds[id as usize] == bound {
                out.push(id);
            }
        }
    }
}

/// Hierarchical timing wheel over bus/CPU-cycle bounds.
///
/// Level `l` (`0..LEVELS`) holds 64 slots of `2^(6l)` cycles each; a
/// bound `b >= cursor` is bucketed at the smallest level whose slot
/// field still distinguishes it from the cursor — i.e. the smallest `l`
/// with `b >> 6(l+1) == cursor >> 6(l+1)` — giving exact (1-cycle)
/// resolution inside the cursor's current 64-cycle window and coarser
/// resolution further out. Bounds not within the cursor's `2^48` block
/// go to `overflow`; bounds *below* the cursor go to the `due` side
/// list (early wakes are free, so parking them unsorted is sound).
///
/// Minimum queries scan the level-0 occupancy mask from the cursor's
/// slot, cascading coarser slots down as the cursor crosses their
/// ranges; the cursor never advances past a live entry. Stale entries
/// (bound no longer matching `bounds`) are dropped wherever they
/// surface, and a `live`-entry counter triggers a full rebuild at
/// `> 4n + 64` entries so set-heavy adversarial patterns cannot grow
/// the wheel past O(components).
#[derive(Debug)]
pub struct WakeWheel {
    /// Current bound per component — the single source of truth.
    bounds: Vec<u64>,
    /// `LEVELS * SLOTS` buckets of `(bound, id)` snapshots.
    slots: Vec<Vec<(u64, u32)>>,
    /// One occupancy bit per slot, per level.
    occ: [u64; LEVELS],
    /// Scan position: every slot entry is `>= cursor` when live.
    cursor: u64,
    /// Live-looking entries parked below the cursor.
    due: Vec<(u64, u32)>,
    /// Entries beyond the cursor's `2^HORIZON_BITS` block.
    overflow: Vec<(u64, u32)>,
    /// Total entries across `slots`, `due`, and `overflow`.
    live: usize,
}

impl WakeWheel {
    /// All `n` components start hot at cycle 0.
    pub fn new(n: usize) -> Self {
        let mut w = Self {
            bounds: vec![0; n],
            slots: vec![Vec::new(); LEVELS * SLOTS],
            occ: [0; LEVELS],
            cursor: 0,
            due: Vec::new(),
            overflow: Vec::new(),
            live: 0,
        };
        for id in 0..n {
            w.insert(0, id as u32);
        }
        w
    }

    pub fn len(&self) -> usize {
        self.bounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bounds.is_empty()
    }

    #[inline]
    pub fn bound(&self, id: usize) -> u64 {
        self.bounds[id]
    }

    /// Entries currently held, live and stale (test hook for the
    /// compaction bound).
    pub fn entry_count(&self) -> usize {
        self.live
    }

    /// Replace component `id`'s bound.
    pub fn set(&mut self, id: usize, bound: u64) {
        if self.bounds[id] == bound {
            return;
        }
        self.bounds[id] = bound;
        if bound == u64::MAX {
            return;
        }
        if self.live >= 4 * self.bounds.len() + 64 {
            self.compact();
        }
        self.insert(bound, id as u32);
    }

    /// Bucket `(b, id)`: below the cursor → `due`; within the cursor's
    /// `2^48` block → the smallest level whose slot field distinguishes
    /// `b` from the cursor; otherwise → `overflow`. O(LEVELS) worst
    /// case, O(1) for near-future bounds (the common case).
    fn insert(&mut self, b: u64, id: u32) {
        self.live += 1;
        if b < self.cursor {
            self.due.push((b, id));
            return;
        }
        for l in 0..LEVELS {
            let shift = SLOT_BITS * (l + 1);
            if (b >> shift) == (self.cursor >> shift) {
                let s = ((b >> (SLOT_BITS * l)) & SLOT_MASK) as usize;
                self.slots[l * SLOTS + s].push((b, id));
                self.occ[l] |= 1u64 << s;
                return;
            }
        }
        self.overflow.push((b, id));
    }

    /// Drop every stale entry by rebuilding the wheel from `bounds`
    /// (cursor unchanged). Amortized free, same argument as the heap's
    /// compaction.
    fn compact(&mut self) {
        for l in 0..LEVELS {
            let mut m = self.occ[l];
            while m != 0 {
                let s = m.trailing_zeros() as usize;
                self.slots[l * SLOTS + s].clear();
                m &= m - 1;
            }
            self.occ[l] = 0;
        }
        self.due.clear();
        self.overflow.clear();
        self.live = 0;
        for id in 0..self.bounds.len() {
            let b = self.bounds[id];
            if b != u64::MAX {
                self.insert(b, id as u32);
            }
        }
    }

    /// Prune the `due` side list and return its minimum live bound.
    fn due_min(&mut self) -> u64 {
        let Self { bounds, due, live, .. } = self;
        let mut min = u64::MAX;
        let mut i = 0;
        while i < due.len() {
            let (b, id) = due[i];
            if bounds[id as usize] != b {
                due.swap_remove(i);
                *live -= 1;
            } else {
                min = min.min(b);
                i += 1;
            }
        }
        min
    }

    /// The minimum live bound bucketed in the wheel levels / overflow,
    /// advancing the cursor to it (entries are left in place — this is
    /// a peek). Returns `u64::MAX` when the wheel is empty.
    fn wheel_min(&mut self) -> u64 {
        'outer: loop {
            // Level 0: exact-cycle slots of the cursor's 64-cycle
            // window, scanned ascending via the occupancy mask.
            let w0 = (self.cursor >> SLOT_BITS) << SLOT_BITS;
            let cs0 = (self.cursor & SLOT_MASK) as u32;
            loop {
                let masked = self.occ[0] & (!0u64 << cs0);
                if masked == 0 {
                    break;
                }
                let s = masked.trailing_zeros() as usize;
                let expected = w0 + s as u64;
                let Self { bounds, slots, due, live, .. } = self;
                let slot = &mut slots[s];
                let mut i = 0;
                while i < slot.len() {
                    let (b, id) = slot[i];
                    if bounds[id as usize] != b {
                        slot.swap_remove(i);
                        *live -= 1;
                    } else if b != expected {
                        // Live but left over from an older window (its
                        // newer copy sits in `due`): park it there too —
                        // a live bound is never dropped.
                        let e = slot.swap_remove(i);
                        due.push(e);
                    } else {
                        i += 1;
                    }
                }
                if slot.is_empty() {
                    self.occ[0] &= !(1u64 << s);
                    continue;
                }
                self.cursor = expected;
                return expected;
            }
            // Cascade: rebucket the lowest cursor-path slot (the coarser
            // slot whose range contains the cursor) down a level, then
            // rescan — its entries may fall anywhere from the current
            // window up, so the cursor must not move yet.
            for l in 1..LEVELS {
                let csl = ((self.cursor >> (SLOT_BITS * l)) & SLOT_MASK) as usize;
                if self.occ[l] & (1u64 << csl) == 0 {
                    continue;
                }
                self.occ[l] &= !(1u64 << csl);
                let entries = std::mem::take(&mut self.slots[l * SLOTS + csl]);
                self.live -= entries.len();
                let mut moved = false;
                for (b, id) in entries {
                    if self.bounds[id as usize] != b {
                        continue;
                    }
                    moved |= b >= self.cursor;
                    // Re-bucketing lands strictly below level `l` (the
                    // slot fields at `l` now match the cursor's), or in
                    // `due` for sub-cursor strays.
                    self.insert(b, id);
                }
                if moved {
                    continue 'outer;
                }
            }
            // Later slots, finest level first: the first live entry's
            // slot start lower-bounds every remaining wheel entry, so
            // the cursor may jump there before cascading the slot down.
            for l in 1..LEVELS {
                let csl = ((self.cursor >> (SLOT_BITS * l)) & SLOT_MASK) as u32;
                loop {
                    let masked = if csl >= 63 { 0 } else { self.occ[l] & (!0u64 << (csl + 1)) };
                    if masked == 0 {
                        break;
                    }
                    let s = masked.trailing_zeros() as usize;
                    self.occ[l] &= !(1u64 << s);
                    let entries = std::mem::take(&mut self.slots[l * SLOTS + s]);
                    self.live -= entries.len();
                    let wl = (self.cursor >> (SLOT_BITS * (l + 1))) << (SLOT_BITS * (l + 1));
                    let slot_start = wl + ((s as u64) << (SLOT_BITS * l));
                    let in_range = |b: u64| (b >> (SLOT_BITS * l)) == (slot_start >> (SLOT_BITS * l));
                    let any = entries
                        .iter()
                        .any(|&(b, id)| self.bounds[id as usize] == b && in_range(b));
                    if any {
                        // Everything live outside the slot's range is an
                        // older-window stray (provably `< slot_start`),
                        // which `insert` routes to `due`.
                        self.cursor = slot_start;
                    }
                    for (b, id) in entries {
                        if self.bounds[id as usize] == b {
                            self.insert(b, id);
                        }
                    }
                    if any {
                        continue 'outer;
                    }
                }
            }
            // Overflow: every bucketed level is clean, so the smallest
            // live overflow bound (if any) is the wheel minimum. Jump
            // the cursor to it and pull its 2^48 block into the levels.
            if !self.overflow.is_empty() {
                {
                    let Self { bounds, overflow, live, .. } = self;
                    let before = overflow.len();
                    overflow.retain(|&(b, id)| bounds[id as usize] == b);
                    *live -= before - overflow.len();
                }
                // Sub-cursor strays keep the cursor monotone by moving
                // to `due` instead of becoming minimum candidates.
                let mut i = 0;
                while i < self.overflow.len() {
                    if self.overflow[i].0 < self.cursor {
                        let e = self.overflow.swap_remove(i);
                        self.due.push(e);
                    } else {
                        i += 1;
                    }
                }
                if let Some(min_b) = self.overflow.iter().map(|&(b, _)| b).min() {
                    self.cursor = min_b;
                    let mut i = 0;
                    while i < self.overflow.len() {
                        if (self.overflow[i].0 >> HORIZON_BITS) == (self.cursor >> HORIZON_BITS) {
                            let (b, id) = self.overflow.swap_remove(i);
                            self.live -= 1;
                            self.insert(b, id);
                        } else {
                            i += 1;
                        }
                    }
                    continue 'outer;
                }
            }
            return u64::MAX;
        }
    }

    /// The minimum cached bound over every component.
    pub fn min_bound(&mut self) -> u64 {
        let due = self.due_min();
        let wheel = self.wheel_min();
        due.min(wheel)
    }

    /// Pop every id with a live bound `<= now` into `out` (duplicates
    /// possible; see [`WakeIndex::drain_due`] for the re-set contract).
    pub fn drain_due(&mut self, now: u64, out: &mut Vec<u32>) {
        {
            let Self { bounds, due, live, .. } = self;
            let mut i = 0;
            while i < due.len() {
                let (b, id) = due[i];
                if bounds[id as usize] != b {
                    due.swap_remove(i);
                    *live -= 1;
                } else if b <= now {
                    out.push(id);
                    due.swap_remove(i);
                    *live -= 1;
                } else {
                    i += 1;
                }
            }
        }
        loop {
            let m = self.wheel_min();
            if m > now {
                break;
            }
            // `wheel_min` left the cursor's level-0 slot holding exactly
            // the live entries at bound `m`; take the whole bucket.
            let s = (m & SLOT_MASK) as usize;
            let slot = &mut self.slots[s];
            let n = slot.len();
            for (_, id) in slot.drain(..) {
                out.push(id);
            }
            self.live -= n;
            self.occ[0] &= !(1u64 << s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both(n: usize) -> [WakeIndex; 2] {
        [WakeIndex::with_impl(n, WakeImpl::Wheel), WakeIndex::with_impl(n, WakeImpl::Heap)]
    }

    #[test]
    fn fresh_index_is_hot_everywhere() {
        for mut w in both(3) {
            assert_eq!(w.len(), 3);
            assert_eq!(w.min_bound(), 0, "{:?}", w.kind());
            assert_eq!(w.bound(2), 0);
        }
    }

    #[test]
    fn min_tracks_updates_and_prunes_stale_entries() {
        for mut w in both(3) {
            w.set(0, 10);
            w.set(1, 7);
            w.set(2, 30);
            assert_eq!(w.min_bound(), 7, "{:?}", w.kind());
            w.set(1, 40); // the (7, 1) entry becomes stale
            assert_eq!(w.min_bound(), 10);
            w.set(0, 50);
            assert_eq!(w.min_bound(), 30);
        }
    }

    #[test]
    fn lowering_a_bound_takes_effect_immediately() {
        for mut w in both(2) {
            w.set(0, 100);
            w.set(1, 200);
            assert_eq!(w.min_bound(), 100, "{:?}", w.kind());
            w.set(1, 5);
            assert_eq!(w.min_bound(), 5);
        }
    }

    #[test]
    fn max_bound_means_never_self_wakes() {
        for mut w in both(2) {
            w.set(0, u64::MAX);
            w.set(1, u64::MAX);
            assert_eq!(w.min_bound(), u64::MAX, "{:?}", w.kind());
            w.set(0, 9);
            assert_eq!(w.min_bound(), 9);
        }
    }

    #[test]
    fn redundant_sets_are_noops() {
        for mut w in both(1) {
            w.set(0, 4);
            w.set(0, 4);
            w.set(0, 4);
            assert_eq!(w.min_bound(), 4, "{:?}", w.kind());
            w.set(0, 6);
            assert_eq!(w.min_bound(), 6);
        }
    }

    #[test]
    fn interleaved_raise_lower_sequences_stay_consistent() {
        // Exercise both structures with a deterministic pseudo-random
        // walk against a naive rescan oracle.
        let n = 8usize;
        for mut w in both(n) {
            let mut oracle = vec![0u64; n];
            let mut state = 0x9E37_79B9u64;
            for _ in 0..2000 {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                let id = (state >> 33) as usize % n;
                let bound = if state % 17 == 0 { u64::MAX } else { state % 10_000 };
                w.set(id, bound);
                oracle[id] = bound;
                assert_eq!(w.min_bound(), *oracle.iter().min().unwrap(), "{:?}", w.kind());
            }
        }
    }

    #[test]
    fn heap_stays_o_components_under_adversarial_clamps() {
        // Alternate every component between two bounds forever: each
        // flip pushes a fresh entry and strands a stale one. Compaction
        // must pin the heap at O(components) regardless.
        let n = 8usize;
        let mut h = WakeHeap::new(n);
        for round in 0..100_000u64 {
            let id = (round % n as u64) as usize;
            h.set(id, 1_000 + round % 2);
            assert!(
                h.heap_len() <= 4 * n + 64,
                "heap grew past O(components): {} entries after round {round}",
                h.heap_len()
            );
        }
        assert_eq!(h.min_bound(), 1_000);
    }

    #[test]
    fn wheel_stays_o_components_under_adversarial_clamps() {
        let n = 8usize;
        let mut w = WakeWheel::new(n);
        for round in 0..100_000u64 {
            let id = (round % n as u64) as usize;
            w.set(id, 1_000 + round % 2);
            assert!(
                w.entry_count() <= 4 * n + 64,
                "wheel grew past O(components): {} entries after round {round}",
                w.entry_count()
            );
        }
        assert_eq!(w.min_bound(), 1_000);
    }

    #[test]
    fn drain_due_pops_exactly_the_due_set() {
        for mut w in both(5) {
            w.set(0, 10);
            w.set(1, 25);
            w.set(2, 25);
            w.set(3, 40);
            w.set(4, u64::MAX);
            let mut out = Vec::new();
            w.drain_due(25, &mut out);
            out.sort_unstable();
            out.dedup();
            assert_eq!(out, vec![0, 1, 2], "{:?}", w.kind());
            // Contract: every drained id is re-set past `now`.
            for &id in &out {
                w.set(id as usize, 100 + id as u64);
            }
            assert_eq!(w.min_bound(), 40);
            out.clear();
            w.drain_due(39, &mut out);
            assert!(out.is_empty(), "{:?}", w.kind());
            out.clear();
            w.drain_due(200, &mut out);
            out.sort_unstable();
            out.dedup();
            assert_eq!(out, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn wheel_handles_far_future_and_below_cursor_bounds() {
        // Overflow horizon: a bound beyond the cursor's 2^48 block must
        // surface once everything nearer is gone; re-heating a component
        // below the advanced cursor (the sampled fast-forward pattern)
        // must surface immediately.
        let mut w = WakeWheel::new(3);
        let far = 1u64 << 50;
        w.set(0, 1_000);
        w.set(1, far);
        w.set(2, u64::MAX);
        assert_eq!(w.min_bound(), 1_000);
        w.set(0, u64::MAX);
        assert_eq!(w.min_bound(), far, "overflow bound must surface");
        // The cursor sits at `far`; park a bound far below it.
        w.set(2, 500);
        assert_eq!(w.min_bound(), 500, "below-cursor bound must win");
        let mut out = Vec::new();
        w.drain_due(600, &mut out);
        assert_eq!(out, vec![2]);
        w.set(2, far + 7);
        assert_eq!(w.min_bound(), far);
    }

    #[test]
    fn wheel_and_heap_agree_on_random_drain_streams() {
        // Drive identical op sequences through both and require the
        // same min at every step and the same (sorted, deduped) drain
        // batches — the in-module twin of the tests/prop.rs suite.
        let n = 16usize;
        let mut wheel = WakeIndex::with_impl(n, WakeImpl::Wheel);
        let mut heap = WakeIndex::with_impl(n, WakeImpl::Heap);
        let mut now = 0u64;
        let mut state = 0xDEAD_BEEFu64;
        let mut a = Vec::new();
        let mut b = Vec::new();
        for step in 0..4000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let id = (state >> 33) as usize % n;
            let bound = match state % 11 {
                0 => u64::MAX,
                1 => now + ((state >> 7) % (1 << 52)), // overflow territory
                2 => now.saturating_sub((state >> 9) % 100), // at/below now
                _ => now + 1 + (state >> 9) % 500,
            };
            wheel.set(id, bound);
            heap.set(id, bound);
            assert_eq!(wheel.min_bound(), heap.min_bound(), "step {step}");
            if state % 5 == 0 {
                now = now.max(wheel.min_bound().min(now + (state >> 40) % 64));
                a.clear();
                b.clear();
                wheel.drain_due(now, &mut a);
                heap.drain_due(now, &mut b);
                a.sort_unstable();
                a.dedup();
                b.sort_unstable();
                b.dedup();
                assert_eq!(a, b, "drain batches diverged at step {step}, now {now}");
                for &id in &a {
                    let nb = now + 1 + (u64::from(id) * 37) % 200;
                    wheel.set(id as usize, nb);
                    heap.set(id as usize, nb);
                }
                assert_eq!(wheel.min_bound(), heap.min_bound(), "post-drain step {step}");
            }
        }
    }

    #[test]
    fn wake_impl_parses_and_names_round_trip() {
        for name in WakeImpl::NAMES {
            let imp = WakeImpl::parse(name).unwrap();
            assert_eq!(imp.name(), name);
        }
        assert_eq!(WakeImpl::parse("quadtree"), None);
        // Resolution never yields Auto.
        assert_ne!(WakeImpl::Auto.resolved(), WakeImpl::Auto);
        assert_eq!(WakeImpl::Wheel.resolved(), WakeImpl::Wheel);
        assert_eq!(WakeImpl::Heap.resolved(), WakeImpl::Heap);
    }
}
