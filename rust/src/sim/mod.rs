//! Full-system simulation: cores + LLC + controllers wired together, plus
//! the result/statistics types every experiment consumes.

pub mod stats;
pub mod system;

pub use stats::SimResult;
pub use system::System;
