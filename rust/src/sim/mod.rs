//! Full-system simulation: cores + LLC + controllers wired together, the
//! event-driven loop kernel, plus the result/statistics types every
//! experiment consumes.

pub mod checkpoint;
pub mod engine;
pub mod latency_hist;
pub mod sample;
pub mod shard;
pub mod stats;
pub mod system;
pub mod traffic;
pub mod wake;

pub use checkpoint::SimSnapshot;
pub use engine::LoopMode;
pub use latency_hist::{LatencyHist, LatencySummary};
pub use sample::SampleSummary;
pub use stats::SimResult;
pub use system::System;
