//! Simulation results and derived metrics (IPC, weighted speedup, RMPKC).

use crate::analysis::rltl::RLTL_INTERVALS_MS;
use crate::controller::McStats;
use crate::energy::EnergyBreakdown;
use crate::sim::latency_hist::LatencySummary;
use crate::sim::sample::SampleSummary;

/// Everything one simulation run produces.
///
/// `PartialEq` is derived (through [`McStats`] and [`EnergyBreakdown`])
/// so the strict-vs-event differential tests compare values directly and
/// a divergence names the differing field — the pre-derive checks
/// compared `format!("{a:?}")` strings and dumped both on failure.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Workload label (profile name or mix id).
    pub workload: String,
    pub mechanism: &'static str,
    /// Per-core IPC over the measured region.
    pub core_ipc: Vec<f64>,
    /// Measured CPU cycles (warmup excluded) until the last core finished.
    pub cpu_cycles: u64,
    /// Per-channel controller statistics.
    pub mc: Vec<McStats>,
    /// Merged t-RLTL fractions, aligned with [`RLTL_INTERVALS_MS`].
    pub rltl: Vec<f64>,
    /// DRAM energy breakdown over the measured region.
    pub energy: EnergyBreakdown,
    /// Total instructions retired in the measured region (all cores).
    pub total_insts: u64,
    /// LLC behaviour.
    pub llc_hits: u64,
    pub llc_misses: u64,
    /// Interval-sampling summary when the run used `sample.*`
    /// ([`crate::sim::sample`]); `None` for full-detail runs. The other
    /// fields then cover only the detailed intervals.
    pub sampled: Option<SampleSummary>,
    /// Per-request read-latency distribution over the measured region
    /// (bus cycles), merged across channels in canonical order. `None`
    /// when no read completed in the window.
    pub latency: Option<LatencySummary>,
}

impl SimResult {
    /// Total activations across channels.
    pub fn acts(&self) -> u64 {
        self.mc.iter().map(|m| m.acts).sum()
    }

    /// Fraction of activations served with reduced timing (paper Sec. 5
    /// reports 67% for multiprogrammed workloads under ChargeCache).
    pub fn reduced_act_fraction(&self) -> f64 {
        let acts = self.acts();
        if acts == 0 {
            return 0.0;
        }
        self.mc.iter().map(|m| m.acts_reduced).sum::<u64>() as f64 / acts as f64
    }

    /// Row misses (activations) per kilo-CPU-cycle — the paper's RMPKC.
    pub fn rmpkc(&self) -> f64 {
        if self.cpu_cycles == 0 {
            return 0.0;
        }
        self.acts() as f64 / (self.cpu_cycles as f64 / 1000.0)
    }

    /// Single-core IPC (panics if multi-core).
    pub fn ipc(&self) -> f64 {
        assert_eq!(self.core_ipc.len(), 1);
        self.core_ipc[0]
    }

    /// t-RLTL at a tracked interval.
    pub fn rltl_at_ms(&self, ms: f64) -> f64 {
        let idx = RLTL_INTERVALS_MS
            .iter()
            .position(|&m| (m - ms).abs() < 1e-12)
            .expect("interval not tracked");
        self.rltl[idx]
    }

    /// DRAM energy per retired instruction [nJ/inst] — the basis for the
    /// Fig. 5 comparison (energy for a fixed amount of work; required
    /// because fixed-time windows do differing amounts of work).
    pub fn energy_per_inst(&self) -> f64 {
        self.energy.total_nj() / self.total_insts.max(1) as f64
    }

    /// Timing violations across channels (`fault.*` injection): reduced
    /// ACTs past a weak row's true safe window, each replayed at full
    /// timing.
    pub fn timing_violations(&self) -> u64 {
        self.mc.iter().map(|m| m.timing_violations).sum()
    }

    /// Violations whose row was evicted from the mechanism table.
    pub fn mitigation_evictions(&self) -> u64 {
        self.mc.iter().map(|m| m.mitigation_evictions).sum()
    }

    /// Reduced grants clamped to full timing by the blacklist guard band.
    pub fn guard_suppressed(&self) -> u64 {
        self.mc.iter().map(|m| m.guard_suppressed).sum()
    }

    /// Rows blacklisted by the adaptive guard across channels.
    pub fn rows_blacklisted(&self) -> u64 {
        self.mc.iter().map(|m| m.rows_blacklisted).sum()
    }

    /// Mean read latency in bus cycles.
    pub fn avg_read_latency(&self) -> f64 {
        let (sum, cnt) = self
            .mc
            .iter()
            .fold((0u64, 0u64), |(s, c), m| (s + m.read_latency_sum, c + m.read_latency_cnt));
        if cnt == 0 {
            0.0
        } else {
            sum as f64 / cnt as f64
        }
    }
}

/// Weighted speedup of a multiprogrammed run against per-core alone IPCs
/// (Snavely & Tullsen; the paper's multi-core metric, Sec. 6.1).
pub fn weighted_speedup(shared_ipc: &[f64], alone_ipc: &[f64]) -> f64 {
    assert_eq!(shared_ipc.len(), alone_ipc.len());
    shared_ipc
        .iter()
        .zip(alone_ipc)
        .map(|(s, a)| if *a > 0.0 { s / a } else { 0.0 })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(acts: u64, reduced: u64, cycles: u64) -> SimResult {
        let mut mc = McStats::default();
        mc.acts = acts;
        mc.acts_reduced = reduced;
        SimResult {
            workload: "test".into(),
            mechanism: "Baseline",
            core_ipc: vec![1.5],
            cpu_cycles: cycles,
            mc: vec![mc],
            rltl: vec![0.0; RLTL_INTERVALS_MS.len()],
            energy: EnergyBreakdown::default(),
            total_insts: 1000,
            llc_hits: 0,
            llc_misses: 0,
            sampled: None,
            latency: None,
        }
    }

    #[test]
    fn rmpkc_definition() {
        let r = result_with(500, 0, 1_000_000);
        assert!((r.rmpkc() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reduced_fraction() {
        let r = result_with(100, 67, 1000);
        assert!((r.reduced_act_fraction() - 0.67).abs() < 1e-12);
    }

    #[test]
    fn weighted_speedup_identity() {
        let ipc = vec![1.0, 2.0, 0.5];
        assert!((weighted_speedup(&ipc, &ipc) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_speedup_degradation() {
        let shared = vec![0.5, 1.0];
        let alone = vec![1.0, 2.0];
        assert!((weighted_speedup(&shared, &alone) - 1.0).abs() < 1e-12);
    }
}
