//! Channel-sharded parallel execution of one simulation.
//!
//! Memory channels are architecturally independent below the enqueue
//! boundary: a [`MemController`] and its DRAM devices never read another
//! channel's state. This module partitions the controllers into
//! contiguous per-shard domains, each advanced by a worker thread with
//! its **own bus-domain [`WakeIndex`]**, and synchronizes them with the
//! coordinator (which owns the cores, LLC, mapper, and in-flight slab)
//! at deterministic *epoch barriers*.
//!
//! ## Epochs and the quantum
//!
//! An epoch is one visited bus-cycle boundary. The minimum cross-shard
//! latency in the system is exactly one bus cycle: a request enqueued at
//! bus cycle `t` is first visible to its controller at `t + 1` (the
//! sequential event loop's trailing enqueue clamp encodes the same
//! fact), and a completion drained at bus cycle `t` reaches its core at
//! CPU cycle `t * cpu_per_bus` — the very boundary at which it is
//! exchanged. The epoch quantum is therefore one bus cycle: no message
//! can ever arrive in a shard's past, because every message is handed
//! over at the first boundary at which the receiver may act on it.
//!
//! ## Canonical exchange order
//!
//! Determinism (bit-identity with the single-threaded event loop) holds
//! because every exchange is ordered canonically, independent of thread
//! timing:
//!
//! * the coordinator flushes staged enqueues to shard inboxes in the
//!   order the cores issued them (core index order within a cycle);
//! * each shard ticks its due channels in ascending channel order,
//!   appending completions in that order;
//! * the coordinator applies shard outputs in ascending shard order, so
//!   the concatenation is ascending **global channel order** — exactly
//!   the order `System::tick_indexed` drains completions in, which in
//!   turn fixes the in-flight slab's freelist recycling order.
//!
//! Within a shard, per-channel wake bounds follow the exact sequential
//! update rules (recompute after a tick, clamp to `enqueue_bus + 1` on
//! an enqueue), so each channel ticks at precisely the same bus cycles
//! as under the single-threaded loop.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::controller::{Completion, MemController, Request};
use crate::dram::command::Loc;
use crate::sim::wake::WakeIndex;

/// A core→channel request crossing a shard boundary: enqueued on the
/// coordinator at bus cycle `bus`, delivered to the owning shard at the
/// next epoch (bus cycle `bus + 1` — the enqueue clamp guarantees that
/// boundary is visited).
#[derive(Debug, Clone, Copy)]
pub struct EnqMsg {
    /// Global channel index.
    pub ch: u32,
    /// Bus cycle at which the coordinator staged the enqueue.
    pub bus: u64,
    pub req: Request,
}

/// Everything a shard publishes at an epoch barrier, in canonical order.
#[derive(Debug, Default)]
pub struct EpochOut {
    /// Completions drained this epoch, ascending local-channel order.
    pub completions: Vec<Completion>,
    /// Write locations drained from write queues this epoch (feeds the
    /// coordinator's write-queue mirror for forwarding decisions).
    pub drained: Vec<(u32, Loc)>,
    /// `(channel, rq_len, wq_len)` for every channel ticked this epoch
    /// (authoritative refresh of the coordinator's occupancy mirror).
    pub occ: Vec<(u32, u32, u32)>,
    /// The shard's minimum wake bound after the epoch, bus domain.
    pub min_bound_bus: u64,
}

impl EpochOut {
    fn clear(&mut self) {
        self.completions.clear();
        self.drained.clear();
        self.occ.clear();
        self.min_bound_bus = u64::MAX;
    }
}

/// One shard's owned state: a contiguous run of controllers starting at
/// global channel `base`, plus their bus-domain wake index.
pub struct ShardState {
    /// Global channel index of local channel 0.
    pub base: usize,
    pub mcs: Vec<MemController>,
    /// Per-local-channel wake bounds, **bus-cycle** domain — maintained
    /// by the same rules as the sequential loop's controller entries.
    pub wake: WakeIndex,
}

impl ShardState {
    /// Build a shard over `mcs`, every channel hot at bus cycle 0 — an
    /// early bound is a no-op tick, so starting hot is always sound.
    pub fn new(base: usize, mcs: Vec<MemController>) -> Self {
        let wake = WakeIndex::new(mcs.len());
        Self { base, mcs, wake }
    }

    /// Run one epoch at bus cycle `bus`: deliver inbound enqueues, tick
    /// every due channel in ascending order, publish outputs into `out`.
    pub fn run_epoch(&mut self, inbox: &mut Vec<EnqMsg>, bus: u64, out: &mut EpochOut) {
        out.clear();
        for m in inbox.drain(..) {
            let li = m.ch as usize - self.base;
            let accepted = self.mcs[li].enqueue(m.req, m.bus);
            debug_assert!(accepted, "admission was pre-checked on the coordinator");
            // The sequential trailing clamp: the controller may first act
            // on the enqueue at the next bus boundary after it landed.
            let clamped = self.wake.bound(li).min(m.bus + 1);
            self.wake.set(li, clamped);
        }
        for li in 0..self.mcs.len() {
            if self.wake.bound(li) > bus {
                continue;
            }
            let ch = (self.base + li) as u32;
            let mc = &mut self.mcs[li];
            mc.tick(bus, &mut out.completions);
            for &loc in mc.drained_writes() {
                out.drained.push((ch, loc));
            }
            let (rq, wq) = mc.occupancy();
            out.occ.push((ch, rq as u32, wq as u32));
            let b = mc.next_event_at(bus + 1).max(bus + 1);
            self.wake.set(li, b);
        }
        out.min_bound_bus = self.wake.min_bound();
    }
}

/// Coordinator↔worker mailbox for one shard. The coordinator publishes
/// an epoch by writing `bus` then bumping `epoch`; the worker runs the
/// epoch and acknowledges by storing the same value to `done`. Payloads
/// travel through the mutex-guarded buffers, exchanged by `mem::swap` so
/// capacities recycle and the steady state allocates nothing.
pub struct ShardSlot {
    /// Epoch sequence number, bumped by the coordinator (Release).
    pub epoch: AtomicU64,
    /// Last epoch the worker finished (worker stores with Release).
    pub done: AtomicU64,
    /// Bus cycle of the pending epoch (written before `epoch` bumps).
    pub bus: AtomicU64,
    /// Coordinator sets this after the last epoch; the worker returns.
    pub stop: AtomicBool,
    /// Inbound enqueues for the pending epoch.
    pub inbox: Mutex<Vec<EnqMsg>>,
    /// The finished epoch's outputs.
    pub out: Mutex<EpochOut>,
}

impl Default for ShardSlot {
    fn default() -> Self {
        Self {
            epoch: AtomicU64::new(0),
            done: AtomicU64::new(0),
            bus: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            inbox: Mutex::new(Vec::new()),
            out: Mutex::new(EpochOut::default()),
        }
    }
}

/// Worker thread body: spin (with yield fallback) for epoch requests,
/// run them, and hand the shard state back when stopped so the
/// coordinator can reassemble `MemHierarchy::mcs`.
pub fn worker_loop(mut st: ShardState, slot: &ShardSlot) -> ShardState {
    let mut seen = 0u64;
    let mut inbox: Vec<EnqMsg> = Vec::new();
    let mut out = EpochOut::default();
    let mut spins = 0u32;
    loop {
        let e = slot.epoch.load(Ordering::Acquire);
        if e == seen {
            if slot.stop.load(Ordering::Acquire) {
                return st;
            }
            spins += 1;
            if spins > 1_000 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
            continue;
        }
        spins = 0;
        seen = e;
        let bus = slot.bus.load(Ordering::Acquire);
        {
            let mut shared = slot.inbox.lock().unwrap();
            std::mem::swap(&mut *shared, &mut inbox);
        }
        st.run_epoch(&mut inbox, bus, &mut out);
        {
            let mut shared = slot.out.lock().unwrap();
            std::mem::swap(&mut *shared, &mut out);
        }
        slot.done.store(e, Ordering::Release);
    }
}
