//! Channel-sharded parallel execution of one simulation.
//!
//! Memory channels are architecturally independent below the enqueue
//! boundary: a [`MemController`] and its DRAM devices never read another
//! channel's state. This module partitions the controllers into
//! contiguous per-shard domains, each advanced by a worker thread with
//! its **own bus-domain [`WakeIndex`]**, and synchronizes them with the
//! coordinator (which owns the cores, LLC, mapper, and in-flight slab)
//! at deterministic *epoch barriers*.
//!
//! ## Epochs and the quantum
//!
//! An epoch is one visited bus-cycle boundary. The minimum cross-shard
//! latency in the system is exactly one bus cycle: a request enqueued at
//! bus cycle `t` is first visible to its controller at `t + 1` (the
//! sequential event loop's trailing enqueue clamp encodes the same
//! fact), and a completion drained at bus cycle `t` reaches its core at
//! CPU cycle `t * cpu_per_bus` — the very boundary at which it is
//! exchanged. The epoch quantum is therefore one bus cycle: no message
//! can ever arrive in a shard's past, because every message is handed
//! over at the first boundary at which the receiver may act on it.
//!
//! ## Canonical exchange order
//!
//! Determinism (bit-identity with the single-threaded event loop) holds
//! because every exchange is ordered canonically, independent of thread
//! timing:
//!
//! * the coordinator flushes staged enqueues to shard inboxes in the
//!   order the cores issued them (core index order within a cycle);
//! * each shard ticks its due channels in ascending channel order,
//!   appending completions in that order;
//! * the coordinator applies shard outputs in ascending shard order, so
//!   the concatenation is ascending **global channel order** — exactly
//!   the order `System::tick_indexed` drains completions in, which in
//!   turn fixes the in-flight slab's freelist recycling order.
//!
//! Within a shard, per-channel wake bounds follow the exact sequential
//! update rules (recompute after a tick, clamp to `enqueue_bus + 1` on
//! an enqueue), so each channel ticks at precisely the same bus cycles
//! as under the single-threaded loop.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::controller::{Completion, MemController, Request};
use crate::dram::command::Loc;
use crate::sim::wake::{WakeImpl, WakeIndex};

/// Process-wide count of hung-shard flags raised by [`Watchdog`]
/// (telemetry; a flag never alters simulation state or results).
static HUNG_SHARDS: AtomicU64 = AtomicU64::new(0);

/// Hung-shard flags raised so far in this process.
pub fn hung_shards() -> u64 {
    HUNG_SHARDS.load(Ordering::Relaxed)
}

/// Default watchdog threshold: `PALLAS_WATCHDOG_MS` (0 disables),
/// falling back to 10 s — far beyond any epoch's real compute, so a
/// flag means a worker is genuinely stuck, not slow.
fn watchdog_threshold_ms() -> u64 {
    static MS: OnceLock<u64> = OnceLock::new();
    *MS.get_or_init(|| {
        std::env::var("PALLAS_WATCHDOG_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(10_000)
    })
}

/// Stall detector for one epoch-barrier wait: the coordinator polls it
/// from the yield path while waiting on a worker's acknowledgement, and
/// if the wait outlives the threshold the shard is flagged (once per
/// wait) on stderr and in [`hung_shards`]. Detection only — the wait
/// itself continues, so results are unaffected.
pub struct Watchdog {
    shard: usize,
    threshold_ms: u64,
    start: Option<Instant>,
    fired: bool,
}

impl Watchdog {
    /// Watchdog for a wait on `shard`, thresholded from the environment.
    pub fn new(shard: usize) -> Self {
        Self::with_threshold(shard, watchdog_threshold_ms())
    }

    /// Explicit threshold (tests); `ms == 0` disables.
    pub fn with_threshold(shard: usize, ms: u64) -> Self {
        Self { shard, threshold_ms: ms, start: None, fired: false }
    }

    /// Poll from a wait loop's slow path (every few thousand spins — the
    /// clock is only read here). The first poll stamps the start time.
    pub fn poll(&mut self) {
        if self.fired || self.threshold_ms == 0 {
            return;
        }
        let now = Instant::now();
        match self.start {
            None => self.start = Some(now),
            Some(t0) => {
                if now.duration_since(t0).as_millis() as u64 >= self.threshold_ms {
                    self.fired = true;
                    HUNG_SHARDS.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "warning: watchdog — shard {} has not acknowledged its epoch in {} ms (hung worker?)",
                        self.shard, self.threshold_ms
                    );
                }
            }
        }
    }

    /// Whether this wait was flagged.
    pub fn fired(&self) -> bool {
        self.fired
    }
}

/// A core→channel request crossing a shard boundary: enqueued on the
/// coordinator at bus cycle `bus`, delivered to the owning shard at the
/// next epoch (bus cycle `bus + 1` — the enqueue clamp guarantees that
/// boundary is visited).
#[derive(Debug, Clone, Copy)]
pub struct EnqMsg {
    /// Global channel index.
    pub ch: u32,
    /// Bus cycle at which the coordinator staged the enqueue.
    pub bus: u64,
    pub req: Request,
}

/// Everything a shard publishes at an epoch barrier, in canonical order.
#[derive(Debug, Default)]
pub struct EpochOut {
    /// Completions drained this epoch, ascending local-channel order.
    pub completions: Vec<Completion>,
    /// Write locations drained from write queues this epoch (feeds the
    /// coordinator's write-queue mirror for forwarding decisions).
    pub drained: Vec<(u32, Loc)>,
    /// `(channel, rq_len, wq_len)` for every channel ticked this epoch
    /// (authoritative refresh of the coordinator's occupancy mirror).
    pub occ: Vec<(u32, u32, u32)>,
    /// The shard's minimum wake bound after the epoch, bus domain.
    pub min_bound_bus: u64,
}

impl EpochOut {
    fn clear(&mut self) {
        self.completions.clear();
        self.drained.clear();
        self.occ.clear();
        self.min_bound_bus = u64::MAX;
    }
}

/// One shard's owned state: a contiguous run of controllers starting at
/// global channel `base`, plus their bus-domain wake index.
pub struct ShardState {
    /// Global channel index of local channel 0.
    pub base: usize,
    pub mcs: Vec<MemController>,
    /// Per-local-channel wake bounds, **bus-cycle** domain — maintained
    /// by the same rules as the sequential loop's controller entries,
    /// on the same implementation (wheel or heap oracle) the
    /// coordinator's index runs on.
    pub wake: WakeIndex,
    /// Scratch for each epoch's batch of due local channels.
    due: Vec<u32>,
}

impl ShardState {
    /// Build a shard over `mcs`, every channel hot at bus cycle 0 — an
    /// early bound is a no-op tick, so starting hot is always sound.
    pub fn new(base: usize, mcs: Vec<MemController>, imp: WakeImpl) -> Self {
        let wake = WakeIndex::with_impl(mcs.len(), imp);
        Self { base, mcs, wake, due: Vec::new() }
    }

    /// Run one epoch at bus cycle `bus`: deliver inbound enqueues, tick
    /// every due channel in ascending order (the batch comes from one
    /// `drain_due` traversal), publish outputs into `out`.
    pub fn run_epoch(&mut self, inbox: &mut Vec<EnqMsg>, bus: u64, out: &mut EpochOut) {
        out.clear();
        for m in inbox.drain(..) {
            let li = m.ch as usize - self.base;
            let accepted = self.mcs[li].enqueue(m.req, m.bus);
            debug_assert!(accepted, "admission was pre-checked on the coordinator");
            // The sequential trailing clamp: the controller may first act
            // on the enqueue at the next bus boundary after it landed.
            let clamped = self.wake.bound(li).min(m.bus + 1);
            self.wake.set(li, clamped);
        }
        let mut due = std::mem::take(&mut self.due);
        due.clear();
        self.wake.drain_due(bus, &mut due);
        due.sort_unstable();
        due.dedup();
        for &li in &due {
            let li = li as usize;
            let ch = (self.base + li) as u32;
            let mc = &mut self.mcs[li];
            mc.tick(bus, &mut out.completions);
            for &loc in mc.drained_writes() {
                out.drained.push((ch, loc));
            }
            let (rq, wq) = mc.occupancy();
            out.occ.push((ch, rq as u32, wq as u32));
            // Re-set every drained channel (the drain consumed its index
            // entry): a fresh bound, always `>= bus + 1`.
            let b = mc.next_event_at(bus + 1).max(bus + 1);
            self.wake.set(li, b);
        }
        self.due = due;
        out.min_bound_bus = self.wake.min_bound();
    }
}

/// Coordinator↔worker mailbox for one shard. The coordinator publishes
/// an epoch by writing `bus` then bumping `epoch`; the worker runs the
/// epoch and acknowledges by storing the same value to `done`. Payloads
/// travel through the mutex-guarded buffers, exchanged by `mem::swap` so
/// capacities recycle and the steady state allocates nothing.
pub struct ShardSlot {
    /// Epoch sequence number, bumped by the coordinator (Release).
    pub epoch: AtomicU64,
    /// Last epoch the worker finished (worker stores with Release).
    pub done: AtomicU64,
    /// Bus cycle of the pending epoch (written before `epoch` bumps).
    pub bus: AtomicU64,
    /// Coordinator sets this after the last epoch; the worker returns.
    pub stop: AtomicBool,
    /// Inbound enqueues for the pending epoch.
    pub inbox: Mutex<Vec<EnqMsg>>,
    /// The finished epoch's outputs.
    pub out: Mutex<EpochOut>,
}

impl Default for ShardSlot {
    fn default() -> Self {
        Self {
            epoch: AtomicU64::new(0),
            done: AtomicU64::new(0),
            bus: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            inbox: Mutex::new(Vec::new()),
            out: Mutex::new(EpochOut::default()),
        }
    }
}

/// Worker thread body: spin (with yield fallback) for epoch requests,
/// run them, and hand the shard state back when stopped so the
/// coordinator can reassemble `MemHierarchy::mcs`.
pub fn worker_loop(mut st: ShardState, slot: &ShardSlot) -> ShardState {
    let mut seen = 0u64;
    let mut inbox: Vec<EnqMsg> = Vec::new();
    let mut out = EpochOut::default();
    let mut spins = 0u32;
    loop {
        let e = slot.epoch.load(Ordering::Acquire);
        if e == seen {
            if slot.stop.load(Ordering::Acquire) {
                return st;
            }
            spins += 1;
            if spins > 1_000 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
            continue;
        }
        spins = 0;
        seen = e;
        let bus = slot.bus.load(Ordering::Acquire);
        {
            let mut shared = slot.inbox.lock().unwrap();
            std::mem::swap(&mut *shared, &mut inbox);
        }
        st.run_epoch(&mut inbox, bus, &mut out);
        {
            let mut shared = slot.out.lock().unwrap();
            std::mem::swap(&mut *shared, &mut out);
        }
        slot.done.store(e, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watchdog_flags_a_stalled_wait_once() {
        let before = hung_shards();
        let mut wd = Watchdog::with_threshold(3, 1);
        assert!(!wd.fired());
        wd.poll(); // stamps the start time
        std::thread::sleep(std::time::Duration::from_millis(5));
        wd.poll();
        assert!(wd.fired(), "threshold elapsed: the wait must be flagged");
        let after = hung_shards();
        assert!(after > before, "the global flag counter must move");
        wd.poll();
        wd.poll();
        assert!(wd.fired(), "one flag per wait; further polls are no-ops");
    }

    #[test]
    fn zero_threshold_disables_the_watchdog() {
        let mut wd = Watchdog::with_threshold(0, 0);
        wd.poll();
        std::thread::sleep(std::time::Duration::from_millis(2));
        wd.poll();
        assert!(!wd.fired());
    }
}
