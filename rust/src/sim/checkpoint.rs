//! Simulation-state snapshot/restore (DESIGN.md §12).
//!
//! A checkpoint captures the **complete mutable state** of a [`System`]
//! at a phase boundary — cores (including trace cursors and RNG state),
//! LLC, MSHRs, slab request queues, controllers, mechanism tables with
//! their expiry clocks, and analysis trackers — as a flat `u64` word
//! stream. The encoding is serde-free and versioned; `f64`s travel as
//! IEEE-754 bit patterns (the same discipline as the result cache), so a
//! run restored from a snapshot is **bit-identical** to an uninterrupted
//! one.
//!
//! ## Identity contract
//!
//! For any config/mechanism/workload triple:
//!
//! ```text
//! run()  ≡  { run_warmup(); capture → fresh System → restore; run_measure() }
//! ```
//!
//! What a snapshot does *not* contain, and why that is sound:
//!
//! * **Immutable shape** — queue capacities, table geometries, core and
//!   channel counts all derive from the config; restore targets a fresh
//!   `System` built from the same warmup-relevant config slice, which
//!   [`SimSnapshot::restore_into`] enforces via the warmup fingerprint.
//! * **`WakeIndex`** — the event kernel tolerates *early* wake bounds
//!   (a too-early wake is a no-op tick), so the restored system keeps
//!   a fresh all-hot-at-0 index (wheel or heap, per `sim.wake_impl`);
//!   every bound is recomputed on first tick. See [`crate::sim::wake`].
//! * **`BankEngine`** — a pure index over queue contents and open rows;
//!   the controller rebuilds it exactly from the restored queues via a
//!   generation-stamped table reset (O(banks), no reallocation — a
//!   sweep leg's restore reuses the tables in place), mirroring its
//!   `debug_assert_consistent` invariant.
//! * **Scratch buffers** — per-tick vectors (`fill_scratch`, drained-write
//!   lists, completion out-params) are empty at phase boundaries.
//!
//! Word streams are strictly sequential: every component writes a section
//! tag first, and import fails (`None`) on any tag, version, or shape
//! mismatch — callers fall back to a cold run, never a corrupt one.

use crate::latency::MechanismKind;
use crate::sim::system::System;

/// Bump when the word-stream layout changes; decode refuses other
/// versions (the caller re-simulates instead).
///
/// v2: CommandSink gained the fault-injection state section and four
/// violation/mitigation stat counters.
///
/// v3: CommandSink gained the per-request latency histogram section
/// (tag `TRAFFIC`, sparse bucket encoding).
pub const SNAPSHOT_VERSION: u64 = 3;

/// Section tags (ASCII-packed) — cheap structural checks so a truncated
/// or shifted stream fails fast instead of misassigning words.
pub mod tags {
    pub const SYSTEM: u64 = 0x5359_5354; // "SYST"
    pub const CORE: u64 = 0x434F_5245; // "CORE"
    pub const TRACE: u64 = 0x5452_4143; // "TRAC"
    pub const MSHR: u64 = 0x4D53_4852; // "MSHR"
    pub const LLC: u64 = 0x4C4C_4343; // "LLCC"
    pub const HIER: u64 = 0x4849_4552; // "HIER"
    pub const MC: u64 = 0x4D43_5452; // "MCTR"
    pub const QUEUE: u64 = 0x5155_4555; // "QUEU"
    pub const SINK: u64 = 0x53494E_4B; // "SINK"
    pub const POLICY: u64 = 0x504F_4C49; // "POLI"
    pub const MECH: u64 = 0x4D45_4348; // "MECH"
    pub const RLTL: u64 = 0x524C_544C; // "RLTL"
    pub const REUSE: u64 = 0x5255_5345; // "RUSE"
    pub const CHANNEL: u64 = 0x4348_414E; // "CHAN"
    pub const RANK: u64 = 0x52_414E4B; // "RANK"
    pub const BANK: u64 = 0x42_414E4B; // "BANK"
    pub const FAULT: u64 = 0x4641_554C; // "FAUL"
    pub const TRAFFIC: u64 = 0x5452_4646; // "TRFF"
}

/// Append-only word-stream encoder.
#[derive(Debug, Default)]
pub struct Enc {
    words: Vec<u64>,
}

impl Enc {
    pub fn new() -> Self {
        Self { words: Vec::new() }
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.words.push(v);
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.words.push(v as u64);
    }

    #[inline]
    pub fn usize(&mut self, v: usize) {
        self.words.push(v as u64);
    }

    #[inline]
    pub fn bool(&mut self, v: bool) {
        self.words.push(v as u64);
    }

    /// IEEE-754 bit pattern — never a decimal round-trip.
    #[inline]
    pub fn f64(&mut self, v: f64) {
        self.words.push(v.to_bits());
    }

    #[inline]
    pub fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.words.push(1);
                self.words.push(x);
            }
            None => self.words.push(0),
        }
    }

    #[inline]
    pub fn opt_u32(&mut self, v: Option<u32>) {
        self.opt_u64(v.map(|x| x as u64));
    }

    /// Section marker (see [`tags`]).
    #[inline]
    pub fn tag(&mut self, t: u64) {
        self.words.push(t);
    }

    /// Append a pre-encoded word block verbatim (length-prefixed
    /// sub-streams: the caller writes the length separately).
    pub fn extend(&mut self, words: &[u64]) {
        self.words.extend_from_slice(words);
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn into_words(self) -> Vec<u64> {
        self.words
    }
}

/// Strictly-sequential word-stream decoder. Every getter returns `None`
/// past the end; [`Dec::tag`] additionally fails on a value mismatch.
#[derive(Debug)]
pub struct Dec<'a> {
    words: &'a [u64],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(words: &'a [u64]) -> Self {
        Self { words, pos: 0 }
    }

    #[inline]
    pub fn u64(&mut self) -> Option<u64> {
        let v = self.words.get(self.pos).copied()?;
        self.pos += 1;
        Some(v)
    }

    #[inline]
    pub fn u32(&mut self) -> Option<u32> {
        u32::try_from(self.u64()?).ok()
    }

    #[inline]
    pub fn usize(&mut self) -> Option<usize> {
        usize::try_from(self.u64()?).ok()
    }

    #[inline]
    pub fn bool(&mut self) -> Option<bool> {
        match self.u64()? {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }

    #[inline]
    pub fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    #[inline]
    pub fn opt_u64(&mut self) -> Option<Option<u64>> {
        match self.u64()? {
            0 => Some(None),
            1 => Some(Some(self.u64()?)),
            _ => None,
        }
    }

    #[inline]
    pub fn opt_u32(&mut self) -> Option<Option<u32>> {
        match self.opt_u64()? {
            None => Some(None),
            Some(x) => u32::try_from(x).ok().map(Some),
        }
    }

    /// Expect section tag `t` next; any other value is a format error.
    #[inline]
    pub fn tag(&mut self, t: u64) -> Option<()> {
        if self.u64()? == t {
            Some(())
        } else {
            None
        }
    }

    /// Take the next `n` words as a sub-stream (length-prefixed blocks).
    pub fn take(&mut self, n: usize) -> Option<&'a [u64]> {
        let sub = self.words.get(self.pos..self.pos.checked_add(n)?)?;
        self.pos += n;
        Some(sub)
    }

    /// True once every word has been consumed — imports require this so
    /// a component that reads too little fails instead of shifting the
    /// stream for its successors.
    pub fn finished(&self) -> bool {
        self.pos == self.words.len()
    }

    pub fn remaining(&self) -> usize {
        self.words.len() - self.pos
    }
}

/// One captured warmed-up simulation state, plus the identity needed to
/// decide which runs may legally fork from it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimSnapshot {
    /// [`crate::config::SystemConfig::warmup_fingerprint`] of the run
    /// that produced this snapshot — restore refuses any other.
    pub warmup_fingerprint: u64,
    pub mechanism: MechanismKind,
    pub workload: String,
    /// CPU cycle at capture (the warmup boundary).
    pub cpu_cycle: u64,
    /// The [`System::export_state`] word stream.
    pub words: Vec<u64>,
}

impl SimSnapshot {
    /// Capture `sys`'s complete mutable state (call at a phase boundary,
    /// i.e. right after warmup).
    pub fn capture(sys: &System) -> Self {
        Self {
            warmup_fingerprint: sys.warmup_fingerprint(),
            mechanism: sys.kind(),
            workload: sys.workload().to_string(),
            cpu_cycle: sys.cpu_cycle(),
            words: sys.export_state(),
        }
    }

    /// Overwrite `sys`'s mutable state from this snapshot. `None` (and
    /// `sys` possibly half-written — discard it) when the snapshot does
    /// not belong to `sys`'s warmup identity or the stream is corrupt;
    /// callers fall back to a cold run.
    pub fn restore_into(&self, sys: &mut System) -> Option<()> {
        if self.warmup_fingerprint != sys.warmup_fingerprint()
            || self.mechanism != sys.kind()
            || self.workload != sys.workload()
        {
            return None;
        }
        sys.import_state(&self.words)
    }

    /// On-disk JSON form. Every word is an exact decimal `u64` token —
    /// [`crate::coordinator::json`] parses the full 64-bit range without
    /// rounding through `f64`.
    pub fn encode(&self) -> String {
        let mut out = String::with_capacity(128 + self.words.len() * 12);
        out.push_str("{\n");
        out.push_str(&format!("  \"version\": {SNAPSHOT_VERSION},\n"));
        out.push_str(&format!(
            "  \"warmup_fingerprint\": {},\n",
            self.warmup_fingerprint
        ));
        out.push_str(&format!("  \"mechanism\": \"{}\",\n", self.mechanism.name()));
        out.push_str(&format!("  \"workload\": \"{}\",\n", escape(&self.workload)));
        out.push_str(&format!("  \"cpu_cycle\": {},\n", self.cpu_cycle));
        out.push_str("  \"words\": [");
        for (i, w) in self.words.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&w.to_string());
        }
        out.push_str("]\n}\n");
        out
    }

    /// Parse [`SimSnapshot::encode`] output. `None` on any malformed,
    /// truncated, or wrong-version document.
    pub fn decode(text: &str) -> Option<Self> {
        let v = crate::coordinator::json::parse_root(text)?;
        if v.field("version")?.u64()? != SNAPSHOT_VERSION {
            return None;
        }
        let words = v
            .field("words")?
            .arr()?
            .iter()
            .map(|w| w.u64())
            .collect::<Option<Vec<u64>>>()?;
        Some(Self {
            warmup_fingerprint: v.field("warmup_fingerprint")?.u64()?,
            mechanism: MechanismKind::parse(v.field("mechanism")?.str()?)?,
            workload: v.field("workload")?.str()?.to_string(),
            cpu_cycle: v.field("cpu_cycle")?.u64()?,
            words,
        })
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            c => vec![c],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_round_trip_every_primitive() {
        let mut e = Enc::new();
        e.tag(tags::SYSTEM);
        e.u64(u64::MAX);
        e.u32(7);
        e.usize(42);
        e.bool(true);
        e.bool(false);
        e.f64(-0.0);
        e.f64(1.5);
        e.opt_u64(None);
        e.opt_u64(Some(3));
        e.opt_u32(Some(9));
        let words = e.into_words();
        let mut d = Dec::new(&words);
        assert_eq!(d.tag(tags::SYSTEM), Some(()));
        assert_eq!(d.u64(), Some(u64::MAX));
        assert_eq!(d.u32(), Some(7));
        assert_eq!(d.usize(), Some(42));
        assert_eq!(d.bool(), Some(true));
        assert_eq!(d.bool(), Some(false));
        // -0.0 must survive as its bit pattern, not collapse to +0.0.
        assert_eq!(d.f64().map(f64::to_bits), Some((-0.0f64).to_bits()));
        assert_eq!(d.f64(), Some(1.5));
        assert_eq!(d.opt_u64(), Some(None));
        assert_eq!(d.opt_u64(), Some(Some(3)));
        assert_eq!(d.opt_u32(), Some(Some(9)));
        assert!(d.finished());
        assert_eq!(d.u64(), None, "past-the-end read fails");
    }

    #[test]
    fn tag_mismatch_and_bad_bool_fail() {
        let words = [tags::CORE, 5];
        let mut d = Dec::new(&words);
        assert_eq!(d.tag(tags::MSHR), None);
        let mut d2 = Dec::new(&words[1..]);
        assert_eq!(d2.bool(), None, "5 is not a bool");
    }

    #[test]
    fn take_slices_subblocks() {
        let words = [3u64, 10, 20, 30, 99];
        let mut d = Dec::new(&words);
        let n = d.usize().unwrap();
        let sub = d.take(n).unwrap();
        assert_eq!(sub, &[10, 20, 30]);
        assert_eq!(d.u64(), Some(99));
        assert!(d.finished());
        let mut short = Dec::new(&[5u64]);
        assert!(short.take(2).is_none(), "over-long take fails");
    }

    #[test]
    fn snapshot_json_round_trips_extreme_words() {
        let snap = SimSnapshot {
            warmup_fingerprint: 0xDEAD_BEEF_1234_5678,
            mechanism: MechanismKind::ChargeCacheNuat,
            workload: "m4".to_string(),
            cpu_cycle: 123_456,
            // 0x8000... is (-0.0f64).to_bits(): the sign-bit-set pattern
            // that a float round-trip would mangle.
            words: vec![0, u64::MAX, (-0.0f64).to_bits(), 1],
        };
        let text = snap.encode();
        let back = SimSnapshot::decode(&text).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn snapshot_decode_rejects_corruption() {
        let snap = SimSnapshot {
            warmup_fingerprint: 1,
            mechanism: MechanismKind::Baseline,
            workload: "s0".to_string(),
            cpu_cycle: 10,
            words: vec![1, 2, 3],
        };
        let good = snap.encode();
        assert!(SimSnapshot::decode(&good).is_some());
        // Wrong version.
        let v2 = good.replace("\"version\": 1", "\"version\": 999");
        assert!(SimSnapshot::decode(&v2).is_none());
        // Truncated document.
        assert!(SimSnapshot::decode(&good[..good.len() / 2]).is_none());
        // Unknown mechanism.
        let bad_mech = good.replace("\"baseline\"", "\"bogus\"");
        assert!(SimSnapshot::decode(&bad_mech).is_none());
        // Non-integer word.
        let bad_word = good.replace("[1,2,3]", "[1,2.5,3]");
        assert!(SimSnapshot::decode(&bad_word).is_none());
        assert!(SimSnapshot::decode("").is_none());
    }
}
