//! SimPoint-style interval sampling (Sherwood et al., ASPLOS'02 lineage):
//! statistics for runs that simulate fixed-length detailed intervals
//! separated by functional fast-forward.
//!
//! The sampling loop itself lives in [`System::run_sampled`]; this module
//! owns the summary arithmetic. Each detailed interval contributes one
//! system-IPC sample (instructions retired by all cores / interval
//! cycles) and — when the interval served at least one DRAM read — one
//! mean-read-latency sample (bus cycles). The summary reports the sample
//! means with 95% confidence half-widths under the usual normal
//! approximation, `1.96 * s / sqrt(n)` with `s` the (n-1)-denominator
//! standard deviation. Intervals are taken at a fixed period rather than
//! randomly, so the CI is exact only under the stationarity assumption
//! SimPoint-style sampling always makes; the pinning test in
//! tests/checkpoint.rs checks the estimates against full runs.
//!
//! All arithmetic here is plain `f64` on already-collected samples — the
//! simulation's own control flow never consults these values, so they
//! cannot perturb bit-identity of the detailed intervals.
//!
//! [`System::run_sampled`]: crate::sim::system::System

/// Summary of one sampled measured region, attached to
/// [`SimResult::sampled`](crate::sim::stats::SimResult::sampled).
#[derive(Debug, Clone, PartialEq)]
pub struct SampleSummary {
    /// Number of detailed intervals simulated.
    pub intervals: u64,
    /// Instructions retired inside detailed intervals.
    pub detailed_insts: u64,
    /// Instructions fast-forwarded between intervals.
    pub skipped_insts: u64,
    /// Mean per-interval system IPC.
    pub ipc_mean: f64,
    /// 95% confidence half-width of `ipc_mean`.
    pub ipc_ci95: f64,
    /// Mean per-interval read latency (bus cycles; 0 if no interval
    /// served a read).
    pub latency_mean: f64,
    /// 95% confidence half-width of `latency_mean`.
    pub latency_ci95: f64,
}

impl SampleSummary {
    /// Build the summary from per-interval samples.
    pub fn from_samples(
        ipc: &[f64],
        latency: &[f64],
        detailed_insts: u64,
        skipped_insts: u64,
    ) -> Self {
        let (ipc_mean, ipc_ci95) = mean_ci95(ipc);
        let (latency_mean, latency_ci95) = mean_ci95(latency);
        Self {
            intervals: ipc.len() as u64,
            detailed_insts,
            skipped_insts,
            ipc_mean,
            ipc_ci95,
            latency_mean,
            latency_ci95,
        }
    }

    /// Fraction of retired instructions that were simulated in detail.
    pub fn detail_fraction(&self) -> f64 {
        let total = self.detailed_insts + self.skipped_insts;
        if total == 0 {
            return 0.0;
        }
        self.detailed_insts as f64 / total as f64
    }
}

/// Sample mean and 95% confidence half-width (`1.96 * s / sqrt(n)`,
/// sample standard deviation). Empty input: `(0, 0)`; a single sample
/// has no spread estimate, so its half-width is 0.
pub fn mean_ci95(samples: &[f64]) -> (f64, f64) {
    let n = samples.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return (mean, 0.0);
    }
    let var =
        samples.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
    (mean, 1.96 * var.sqrt() / (n as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_ci_of_constant_samples_is_tight() {
        let (m, ci) = mean_ci95(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(m, 2.0);
        assert_eq!(ci, 0.0);
    }

    #[test]
    fn mean_ci_matches_hand_computation() {
        // Samples 1..=4: mean 2.5, sample variance 5/3.
        let (m, ci) = mean_ci95(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        let expect = 1.96 * (5.0f64 / 3.0).sqrt() / 2.0;
        assert!((ci - expect).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_are_safe() {
        assert_eq!(mean_ci95(&[]), (0.0, 0.0));
        assert_eq!(mean_ci95(&[7.25]), (7.25, 0.0));
    }

    #[test]
    fn summary_accounts_for_detail_fraction() {
        let s = SampleSummary::from_samples(&[1.0, 3.0], &[], 250, 750);
        assert_eq!(s.intervals, 2);
        assert_eq!(s.ipc_mean, 2.0);
        assert_eq!(s.latency_mean, 0.0);
        assert_eq!(s.latency_ci95, 0.0);
        assert!((s.detail_fraction() - 0.25).abs() < 1e-12);
        let empty = SampleSummary::from_samples(&[], &[], 0, 0);
        assert_eq!(empty.detail_fraction(), 0.0);
    }
}
