//! The full simulated system: trace-driven cores → shared LLC → per-channel
//! memory controllers → DDR3 devices, simulated cycle-accurately with a
//! 5:1 CPU:bus clock ratio (4 GHz / 800 MHz, Table 1).
//!
//! Time is advanced by the event kernel ([`crate::sim::engine`]): each
//! component surfaces its next wake cycle and the clock fast-forwards to
//! the global minimum. [`crate::sim::LoopMode::StrictTick`] keeps the
//! original per-cycle loop; both produce bit-identical [`SimResult`]s.

use std::collections::HashMap;

use crate::config::SystemConfig;
use crate::controller::{AddressMapper, Completion, MapScheme, MemController, Request};
use crate::cpu::core_model::{Core, MemPort};
use crate::cpu::Llc;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::latency::MechanismKind;
use crate::sim::engine::{self, EventDriven};
use crate::sim::stats::SimResult;
use crate::trace::{profile::multicore_mix, Profile, SynthTrace, TraceSource};

/// LLC + controllers + mapper: the memory side of the system, split from
/// the cores so each core can tick with a mutable borrow of this.
struct MemHierarchy {
    llc: Llc,
    mcs: Vec<MemController>,
    mapper: AddressMapper,
    /// Current bus cycle (updated by the system loop).
    bus_now: u64,
    next_req_id: u64,
    /// In-flight read id -> (core, line).
    inflight: HashMap<u64, (u32, u64)>,
}

impl MemPort for MemHierarchy {
    fn load(&mut self, core: u32, line: u64, _seq: u64) -> Result<bool, ()> {
        if self.llc.probe(line) {
            self.llc.access(line, false);
            return Ok(true);
        }
        let loc = self.mapper.map_line(line);
        // Admission control before mutating the LLC: the read channel must
        // accept, and (conservatively) every channel must have writeback
        // room since the victim's channel is unknown until eviction.
        if !self.mcs[loc.channel as usize].can_accept_read()
            || !self.mcs.iter().all(|m| m.can_accept_write())
        {
            return Err(());
        }
        let res = self.llc.access(line, false);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        let id = self.next_req_id;
        self.next_req_id += 1;
        self.inflight.insert(id, (core, line));
        let accepted = self.mcs[loc.channel as usize].enqueue(
            Request { id, core, loc, is_write: false, arrived: self.bus_now },
            self.bus_now,
        );
        debug_assert!(accepted, "admission was pre-checked");
        Ok(false)
    }

    fn store(&mut self, core: u32, line: u64) -> Result<(), ()> {
        if !self.mcs.iter().all(|m| m.can_accept_write()) {
            return Err(());
        }
        let _ = core;
        let res = self.llc.access(line, true);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        Ok(())
    }
}

impl MemHierarchy {
    fn send_write(&mut self, line: u64) {
        let loc = self.mapper.map_line(line);
        let id = self.next_req_id;
        self.next_req_id += 1;
        let accepted = self.mcs[loc.channel as usize].enqueue(
            Request { id, core: u32::MAX, loc, is_write: true, arrived: self.bus_now },
            self.bus_now,
        );
        debug_assert!(accepted, "writeback admission pre-checked");
    }
}

/// The simulated system.
pub struct System {
    cfg: SystemConfig,
    kind: MechanismKind,
    cores: Vec<Core>,
    hier: MemHierarchy,
    cpu_cycle: u64,
    workload: String,
    /// Scratch buffer for completion delivery (avoids per-tick allocs).
    completions: Vec<Completion>,
}

impl System {
    /// Build a system running `profiles[i]` on core `i`.
    pub fn new(cfg: &SystemConfig, kind: MechanismKind, profiles: &[&Profile]) -> Self {
        assert_eq!(profiles.len(), cfg.cpu.cores, "one profile per core");
        let workload = profiles.iter().map(|p| p.name).collect::<Vec<_>>().join("+");
        let traces: Vec<Box<dyn TraceSource>> = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Box::new(SynthTrace::new(p, cfg.seed ^ (i as u64) << 8, i as u64))
                    as Box<dyn TraceSource>
            })
            .collect();
        Self::with_traces(cfg, kind, traces, workload)
    }

    /// Build the paper's eight-core mix `mix_idx`.
    pub fn new_mix(cfg: &SystemConfig, kind: MechanismKind, mix_idx: usize) -> Self {
        let profiles = multicore_mix(mix_idx, cfg.cpu.cores);
        let mut s = Self::new(cfg, kind, &profiles);
        s.workload = format!("mix{mix_idx:02}");
        s
    }

    /// Build from explicit trace sources (file replay, tests).
    pub fn with_traces(
        cfg: &SystemConfig,
        kind: MechanismKind,
        traces: Vec<Box<dyn TraceSource>>,
        workload: String,
    ) -> Self {
        assert_eq!(traces.len(), cfg.cpu.cores);
        let cores = traces
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                Core::new(
                    i as u32,
                    t,
                    cfg.cpu.window,
                    cfg.cpu.issue_width,
                    cfg.cpu.mshrs,
                    cfg.cpu.llc_hit_cycles,
                )
            })
            .collect();
        let mcs = (0..cfg.dram.channels)
            .map(|ch| MemController::new(cfg, kind, ch as u32))
            .collect();
        Self {
            cfg: cfg.clone(),
            kind,
            cores,
            hier: MemHierarchy {
                llc: Llc::new(cfg.cpu.llc_bytes, cfg.cpu.llc_ways, cfg.dram.line_bytes),
                mcs,
                mapper: AddressMapper::new(&cfg.dram, MapScheme::RoRaBaColCh),
                bus_now: 0,
                next_req_id: 0,
                inflight: HashMap::new(),
            },
            cpu_cycle: 0,
            workload,
            completions: Vec::new(),
        }
    }

    /// Names of the workloads on each core.
    pub fn workload(&self) -> &str {
        &self.workload
    }

    /// Run warmup + measured region; returns the result.
    pub fn run(&mut self) -> SimResult {
        let mode = self.cfg.loop_mode;

        // Warmup: caches, HCRAC, and DRAM state get warm; stats reset after.
        let start = self.cpu_cycle;
        let warmup_end = self.cfg.warmup_cpu_cycles;
        self.cpu_cycle = engine::advance(self, mode, start, warmup_end, |_| false);
        for core in &mut self.cores {
            core.reset_stats();
            core.target = self.cfg.insts_per_core;
        }
        for mc in &mut self.hier.mcs {
            mc.reset_stats();
        }
        self.hier.llc.reset_stats();
        let measure_start = self.cpu_cycle;
        let bus_start = self.cpu_cycle / self.cfg.cpu.cpu_per_bus;

        // Measured region. Fixed-time: run exactly `measure_cycles` (the
        // stable basis for multiprogrammed comparisons). Fixed-work: run
        // until every core reaches its instruction target (hard cap
        // guards against pathological stalls).
        match self.cfg.measure_cycles {
            Some(n) => {
                for core in &mut self.cores {
                    core.target = 0; // no finish target in fixed-time mode
                }
                let end = measure_start + n;
                self.cpu_cycle = engine::advance(self, mode, measure_start, end, |_| false);
            }
            None => {
                let cap = measure_start
                    + self.cfg.insts_per_core * 400
                    + 10 * self.cfg.warmup_cpu_cycles;
                self.cpu_cycle = engine::advance(self, mode, measure_start, cap, |s| {
                    s.cores.iter().all(|c| c.stats.finished_at.is_some())
                });
            }
        }
        let end = self.cpu_cycle;
        let bus_end = end / self.cfg.cpu.cpu_per_bus;
        for mc in &mut self.hier.mcs {
            mc.finalize(bus_end);
        }
        // Energy window: the mean core-finish time. Using last-finish
        // would let one chaotic laggard dominate the background-energy
        // comparison between mechanisms (multiprogrammed runs diverge).
        let mean_finish = self
            .cores
            .iter()
            .map(|c| c.stats.finished_at.unwrap_or(end))
            .sum::<u64>()
            / self.cores.len() as u64;
        let bus_energy_end = mean_finish / self.cfg.cpu.cpu_per_bus;

        // Per-core IPC: fixed-time mode uses the shared window; fixed-work
        // uses each core's own window up to its instruction target.
        let core_ipc = self
            .cores
            .iter()
            .map(|c| match self.cfg.measure_cycles {
                Some(n) => c.stats.retired as f64 / n as f64,
                None => {
                    let fin = c.stats.finished_at.unwrap_or(end);
                    let cycles = (fin - measure_start).max(1);
                    c.stats.retired.min(self.cfg.insts_per_core) as f64 / cycles as f64
                }
            })
            .collect();

        // Merge RLTL across channels (keys are channel-qualified, so the
        // merged histograms never conflate same-coordinate rows).
        let mut rltl = self.hier.mcs[0].rltl().clone();
        for mc in &self.hier.mcs[1..] {
            rltl.merge(mc.rltl());
        }

        // DRAM energy over the measured region.
        let emodel = EnergyModel::new(&self.cfg);
        let mut energy = EnergyBreakdown::default();
        let bus_cycles = bus_energy_end.saturating_sub(bus_start).max(1);
        for mc in &self.hier.mcs {
            energy.add(&emodel.channel_energy(mc.stats(), &mc.rank_active_cycles, bus_cycles));
        }

        let total_insts = self
            .cores
            .iter()
            .map(|c| match self.cfg.measure_cycles {
                Some(_) => c.stats.retired,
                None => c.stats.retired.min(self.cfg.insts_per_core),
            })
            .sum();
        SimResult {
            workload: self.workload.clone(),
            mechanism: self.kind.label(),
            core_ipc,
            cpu_cycles: end - measure_start,
            mc: self.hier.mcs.iter().map(|m| m.stats().clone()).collect(),
            rltl: rltl.fractions(),
            energy,
            total_insts,
            llc_hits: self.hier.llc.hits,
            llc_misses: self.hier.llc.misses,
        }
    }
}

impl EventDriven for System {
    /// One simulation step at CPU cycle `now`: memory side first on bus
    /// boundaries (completions delivered before cores tick, as in the
    /// original loop), then every core in index order. The clock is
    /// owned by the loop driver.
    fn tick_at(&mut self, now: u64) {
        let cpb = self.cfg.cpu.cpu_per_bus;
        // Floor semantics: between boundaries the strict loop kept the
        // stale (floored) bus cycle, so recomputing it every visited
        // cycle is equivalent.
        self.hier.bus_now = now / cpb;
        if now % cpb == 0 {
            let bus = now / cpb;
            let mut completions = std::mem::take(&mut self.completions);
            completions.clear();
            for mc in &mut self.hier.mcs {
                mc.tick(bus, &mut completions);
            }
            for c in completions.drain(..) {
                if let Some((core, line)) = self.hier.inflight.remove(&c.req_id) {
                    self.cores[core as usize].complete_line(line);
                }
            }
            self.completions = completions;
        }
        for core in &mut self.cores {
            core.tick(now, &mut self.hier);
        }
    }

    /// Global next-wake: the minimum over every core's wake cycle and
    /// every controller's wake bus-cycle (mapped onto the CPU clock at
    /// the next bus boundary `>= now`). Exits early once any component
    /// is hot — the kernel then degrades to per-cycle ticking, which is
    /// exactly the strict loop.
    fn next_wake(&self, now: u64) -> u64 {
        let mut wake = u64::MAX;
        for core in &self.cores {
            wake = wake.min(core.next_event_at(now));
            if wake <= now {
                return now;
            }
        }
        let cpb = self.cfg.cpu.cpu_per_bus;
        let bus_next = (now + cpb - 1) / cpb;
        for mc in &self.hier.mcs {
            let b = mc.next_event_at(bus_next).max(bus_next);
            wake = wake.min(b.saturating_mul(cpb));
            if wake <= now {
                return now;
            }
        }
        wake.max(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::LoopMode;
    use crate::trace::Profile;

    fn quick_cfg(insts: u64) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.insts_per_core = insts;
        cfg.warmup_cpu_cycles = 20_000;
        cfg
    }

    #[test]
    fn event_kernel_matches_strict_tick_exactly() {
        // The engine's headline invariant: bit-identical results. The
        // full matrix lives in tests/engine_equiv.rs; this is the fast
        // in-crate smoke check.
        let mut cfg = quick_cfg(30_000);
        cfg.warmup_cpu_cycles = 12_000;
        for name in ["mcf", "gcc"] {
            let p = Profile::by_name(name).unwrap();
            cfg.loop_mode = LoopMode::StrictTick;
            let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            cfg.loop_mode = LoopMode::EventDriven;
            let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "{name} diverged");
        }
    }

    #[test]
    fn llc_resident_workload_runs_near_full_ipc() {
        let mut cfg = quick_cfg(150_000);
        cfg.warmup_cpu_cycles = 100_000; // enough to pull the WS into LLC
        let p = Profile::by_name("povray").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.ipc() > 1.2, "IPC {} too low for an LLC-resident app", r.ipc());
        assert!(r.rmpkc() < 5.0, "RMPKC {} too high", r.rmpkc());
    }

    #[test]
    fn memory_bound_workload_stresses_dram() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("mcf").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.ipc() < 1.0, "IPC {} too high for mcf-class", r.ipc());
        assert!(r.acts() > 100, "expected DRAM activity");
        assert!(r.rmpkc() > 1.0, "RMPKC {}", r.rmpkc());
    }

    #[test]
    fn lldram_never_slower_than_baseline() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("libquantum").unwrap();
        let base = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let ll = System::new(&cfg, MechanismKind::LlDram, &[p]).run();
        assert!(ll.ipc() >= base.ipc() * 0.999, "{} vs {}", ll.ipc(), base.ipc());
    }

    #[test]
    fn chargecache_between_baseline_and_lldram() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("tpcc64").unwrap();
        let base = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let cc = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let ll = System::new(&cfg, MechanismKind::LlDram, &[p]).run();
        assert!(cc.ipc() >= base.ipc() * 0.995);
        assert!(ll.ipc() >= cc.ipc() * 0.995);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = quick_cfg(30_000);
        let p = Profile::by_name("gcc").unwrap();
        let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        assert_eq!(a.ipc(), b.ipc());
        assert_eq!(a.acts(), b.acts());
        assert_eq!(a.cpu_cycles, b.cpu_cycles);
    }

    #[test]
    fn multicore_mix_runs_all_cores() {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.insts_per_core = 20_000;
        cfg.warmup_cpu_cycles = 10_000;
        let r = System::new_mix(&cfg, MechanismKind::ChargeCache, 0).run();
        assert_eq!(r.core_ipc.len(), 4);
        assert!(r.core_ipc.iter().all(|&i| i > 0.0));
        assert_eq!(r.mc.len(), 2); // two channels
    }

    #[test]
    fn energy_is_positive_and_dominated_by_known_terms() {
        let cfg = quick_cfg(40_000);
        let p = Profile::by_name("lbm").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.energy.total_nj() > 0.0);
        assert!(r.energy.background_nj > 0.0);
        assert!(r.energy.act_pre_nj > 0.0);
    }
}
