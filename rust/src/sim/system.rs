//! The full simulated system: trace-driven cores → shared LLC → per-channel
//! memory controllers → DDR3 devices, simulated cycle-accurately with a
//! 5:1 CPU:bus clock ratio (4 GHz / 800 MHz, Table 1).
//!
//! Time is advanced by the event kernel ([`crate::sim::engine`]): each
//! component surfaces its next wake cycle through the incrementally
//! maintained [`WakeIndex`] (a hierarchical timing wheel by default,
//! the lazily-pruned heap as the differential oracle — `sim.wake_impl`)
//! and the clock fast-forwards to the global minimum; components whose
//! cached bound lies in the future are not even ticked (their ticks are
//! no-ops by the wake contract). Each visited cycle drains its whole
//! batch of due components in one index traversal, so dispatch is
//! amortized per bus boundary instead of per event.
//! [`crate::sim::LoopMode::StrictTick`] keeps the original per-cycle
//! loop — every controller and every core, every cycle, with no index
//! bookkeeping — as the differential oracle; both modes produce
//! bit-identical [`SimResult`]s.

use std::sync::atomic::Ordering;

use crate::config::{SystemConfig, TrafficMode};
use crate::controller::{AddressMapper, Completion, MapScheme, MemController, Request};
use crate::cpu::core_model::{Core, MemPort};
use crate::cpu::Llc;
use crate::dram::command::Loc;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::latency::MechanismKind;
use crate::sim::engine::{self, EventDriven, LoopMode};
use crate::sim::latency_hist::LatencyHist;
use crate::sim::sample::SampleSummary;
use crate::sim::shard::{worker_loop, EnqMsg, EpochOut, ShardSlot, ShardState, Watchdog};
use crate::sim::stats::SimResult;
use crate::sim::traffic::{InjectPort, TrafficInjector, TRAFFIC_ID_BASE};
use crate::sim::wake::WakeIndex;
#[cfg(test)]
use crate::sim::wake::WakeImpl;
use crate::trace::{profile::multicore_mix, Profile, SynthTrace, TraceSource};

/// Completion predicate for a measured region. A plain function pointer
/// (not a generic) so [`System::advance_region`] can dispatch between
/// loop drivers without monomorphizing each phase.
type DoneFn = fn(&System) -> bool;

/// Writeback ids live in the upper id half-space so they can never
/// collide with the slab-generated read ids (whose generation word is
/// masked to 31 bits).
const WRITEBACK_ID_BASE: u64 = 1 << 63;

/// One in-flight read.
#[derive(Debug, Clone, Copy)]
struct InflightSlot {
    generation: u32,
    live: bool,
    core: u32,
    line: u64,
}

/// Generational-id slab for in-flight reads: the request id packs
/// `generation << 32 | slot`, so matching a completion is an array index
/// plus a generation check instead of the HashMap lookup the pre-slab
/// code paid per completion, and retired slots are recycled through a
/// freelist (zero steady-state allocation). The generation bumps at each
/// release, so a stale id can never match a recycled slot; it is masked
/// to 31 bits to keep the top id bit free for [`WRITEBACK_ID_BASE`].
#[derive(Debug, Default)]
struct InflightSlab {
    slots: Vec<InflightSlot>,
    free: Vec<u32>,
}

impl InflightSlab {
    /// Register an in-flight read; returns its generational id.
    fn insert(&mut self, core: u32, line: u64) -> u64 {
        let slot = match self.free.pop() {
            Some(s) => {
                let e = &mut self.slots[s as usize];
                debug_assert!(!e.live, "freelist returned a live slot");
                e.live = true;
                e.core = core;
                e.line = line;
                s
            }
            None => {
                self.slots.push(InflightSlot { generation: 0, live: true, core, line });
                (self.slots.len() - 1) as u32
            }
        };
        ((self.slots[slot as usize].generation as u64) << 32) | slot as u64
    }

    /// Resolve a completion id to `(core, line)` and release the slot.
    fn remove(&mut self, id: u64) -> Option<(u32, u64)> {
        let slot = (id & 0xFFFF_FFFF) as usize;
        let generation = (id >> 32) as u32;
        let e = self.slots.get_mut(slot)?;
        if !e.live || e.generation != generation {
            return None;
        }
        e.live = false;
        e.generation = (e.generation + 1) & 0x7FFF_FFFF;
        self.free.push(slot as u32);
        Some((e.core, e.line))
    }
}

/// LLC + controllers + mapper: the memory side of the system, split from
/// the cores so each core can tick with a mutable borrow of this.
struct MemHierarchy {
    llc: Llc,
    mcs: Vec<MemController>,
    mapper: AddressMapper,
    /// Current bus cycle (updated by the system loop).
    bus_now: u64,
    /// In-flight reads (id allocation + completion matching).
    inflight: InflightSlab,
    /// Id source for writebacks (offset by [`WRITEBACK_ID_BASE`]).
    next_writeback_id: u64,
    /// Per-channel: an enqueue landed since the wake index last saw this
    /// controller — the event-kernel invalidation hook.
    enqueued: Vec<bool>,
}

impl MemPort for MemHierarchy {
    fn load(&mut self, core: u32, line: u64, _seq: u64) -> Result<bool, ()> {
        if self.llc.probe(line) {
            self.llc.access(line, false);
            return Ok(true);
        }
        let loc = self.mapper.map_line(line);
        // Admission control before mutating the LLC: the read channel must
        // accept, and (conservatively) every channel must have writeback
        // room since the victim's channel is unknown until eviction.
        if !self.mcs[loc.channel as usize].can_accept_read()
            || !self.mcs.iter().all(|m| m.can_accept_write())
        {
            return Err(());
        }
        let res = self.llc.access(line, false);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        let id = self.inflight.insert(core, line);
        self.enqueued[loc.channel as usize] = true;
        let accepted = self.mcs[loc.channel as usize].enqueue(
            Request { id, core, loc, is_write: false, arrived: self.bus_now },
            self.bus_now,
        );
        debug_assert!(accepted, "admission was pre-checked");
        Ok(false)
    }

    fn store(&mut self, core: u32, line: u64) -> Result<(), ()> {
        if !self.mcs.iter().all(|m| m.can_accept_write()) {
            return Err(());
        }
        let _ = core;
        let res = self.llc.access(line, true);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        Ok(())
    }
}

/// Open-loop injection into the live hierarchy: traffic bypasses the LLC
/// entirely (it models uncached demand arriving at the memory system),
/// so admission is per-target-channel only — no cross-channel writeback
/// headroom check, unlike [`MemPort::load`]. The mirror port
/// ([`ShardedPort`]) evaluates the identical predicate.
impl InjectPort for MemHierarchy {
    fn try_inject(
        &mut self,
        line_addr: u64,
        is_write: bool,
        arrived_bus: u64,
        id: u64,
        _stream: u32,
    ) -> bool {
        let loc = self.mapper.map_line(line_addr);
        let ch = loc.channel as usize;
        if is_write {
            if !self.mcs[ch].can_accept_write() {
                return false;
            }
        } else if !self.mcs[ch].can_accept_read() {
            return false;
        }
        self.enqueued[ch] = true;
        let accepted = self.mcs[ch].enqueue(
            Request { id, core: u32::MAX, loc, is_write, arrived: arrived_bus },
            self.bus_now,
        );
        debug_assert!(accepted, "admission was pre-checked");
        true
    }
}

impl MemHierarchy {
    fn send_write(&mut self, line: u64) {
        let loc = self.mapper.map_line(line);
        let id = WRITEBACK_ID_BASE + self.next_writeback_id;
        self.next_writeback_id += 1;
        self.enqueued[loc.channel as usize] = true;
        let accepted = self.mcs[loc.channel as usize].enqueue(
            Request { id, core: u32::MAX, loc, is_write: true, arrived: self.bus_now },
            self.bus_now,
        );
        debug_assert!(accepted, "writeback admission pre-checked");
    }
}

/// The simulated system.
pub struct System {
    cfg: SystemConfig,
    kind: MechanismKind,
    cores: Vec<Core>,
    hier: MemHierarchy,
    cpu_cycle: u64,
    workload: String,
    /// Scratch buffer for completion delivery (avoids per-tick allocs).
    completions: Vec<Completion>,
    /// Cached wake bounds, CPU-cycle domain: cores at ids `0..cores`,
    /// controllers at ids `cores..cores + channels`.
    wake: WakeIndex,
    /// Scratch for the per-cycle batch of due component ids.
    due_scratch: Vec<u32>,
    /// Scratch for the per-cycle due-core list (drained cores plus
    /// completion-woken ones).
    core_scratch: Vec<u32>,
    /// Per-core synthetic profiles, kept for the open-loop injector's
    /// arrival streams; empty for explicit-trace systems, which cannot
    /// run open-loop.
    open_profiles: Vec<Profile>,
    /// Open-loop request injector (`traffic.mode != closed`), armed at
    /// the measurement boundary by [`System::enable_open_loop`]. `None`
    /// in closed-loop runs and throughout warmup; its presence is the
    /// open-mode flag every loop path checks (cores quiesce, the wake
    /// index gains the injector slot at `cores + channels`).
    injector: Option<TrafficInjector>,
}

impl System {
    /// Build a system running `profiles[i]` on core `i`.
    pub fn new(cfg: &SystemConfig, kind: MechanismKind, profiles: &[&Profile]) -> Self {
        assert_eq!(profiles.len(), cfg.cpu.cores, "one profile per core");
        let workload = profiles.iter().map(|p| p.name).collect::<Vec<_>>().join("+");
        let traces: Vec<Box<dyn TraceSource>> = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Box::new(SynthTrace::new(p, cfg.seed ^ (i as u64) << 8, i as u64))
                    as Box<dyn TraceSource>
            })
            .collect();
        let mut s = Self::with_traces(cfg, kind, traces, workload);
        s.open_profiles = profiles.iter().map(|&p| *p).collect();
        s
    }

    /// Build the paper's eight-core mix `mix_idx`.
    pub fn new_mix(cfg: &SystemConfig, kind: MechanismKind, mix_idx: usize) -> Self {
        let profiles = multicore_mix(mix_idx, cfg.cpu.cores);
        let mut s = Self::new(cfg, kind, &profiles);
        s.workload = format!("mix{mix_idx:02}");
        s
    }

    /// Build from explicit trace sources (file replay, tests).
    pub fn with_traces(
        cfg: &SystemConfig,
        kind: MechanismKind,
        traces: Vec<Box<dyn TraceSource>>,
        workload: String,
    ) -> Self {
        assert_eq!(traces.len(), cfg.cpu.cores);
        let cores: Vec<Core> = traces
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                Core::new(
                    i as u32,
                    t,
                    cfg.cpu.window,
                    cfg.cpu.issue_width,
                    cfg.cpu.mshrs,
                    cfg.cpu.llc_hit_cycles,
                )
            })
            .collect();
        let mcs: Vec<MemController> = (0..cfg.dram.channels)
            .map(|ch| MemController::new(cfg, kind, ch as u32))
            .collect();
        let wake = WakeIndex::with_impl(cores.len() + mcs.len(), cfg.wake_impl);
        Self {
            cfg: cfg.clone(),
            kind,
            cores,
            hier: MemHierarchy {
                llc: Llc::new(cfg.cpu.llc_bytes, cfg.cpu.llc_ways, cfg.dram.line_bytes),
                enqueued: vec![false; mcs.len()],
                mcs,
                mapper: AddressMapper::new(&cfg.dram, MapScheme::RoRaBaColCh),
                bus_now: 0,
                inflight: InflightSlab::default(),
                next_writeback_id: 0,
            },
            cpu_cycle: 0,
            workload,
            completions: Vec::new(),
            wake,
            due_scratch: Vec::new(),
            core_scratch: Vec::new(),
            open_profiles: Vec::new(),
            injector: None,
        }
    }

    /// Names of the workloads on each core.
    pub fn workload(&self) -> &str {
        &self.workload
    }

    /// Test oracle for the wake index: every cached bound must be no
    /// later than the component's freshly recomputed `next_event_at` —
    /// the "never late" half of the wake contract, the only direction
    /// that can break strict/event bit-identity (an early bound merely
    /// costs a no-op tick). Meaningful for event-driven systems; the
    /// strict loop does not maintain the index.
    pub fn assert_wake_bounds_conservative(&self, now: u64) {
        let cpb = self.cfg.cpu.cpu_per_bus;
        for (i, core) in self.cores.iter().enumerate() {
            let cached = self.wake.bound(i);
            let fresh = core.next_event_at(now);
            assert!(
                cached <= fresh,
                "core {i}: cached wake {cached} is later than fresh bound {fresh} at {now}"
            );
        }
        let bus_next = (now + cpb - 1) / cpb;
        for (ci, mc) in self.hier.mcs.iter().enumerate() {
            let cached = self.wake.bound(self.cores.len() + ci);
            let fresh = mc.next_event_at(bus_next).max(bus_next).saturating_mul(cpb);
            assert!(
                cached <= fresh,
                "mc {ci}: cached wake {cached} is later than fresh bound {fresh} at {now}"
            );
        }
    }

    /// Strict-tick step: every controller on bus boundaries, then every
    /// core, every visited cycle — the original loop, deliberately free
    /// of wake-index bookkeeping so it stays an *independent* oracle for
    /// the indexed path (a late cached bound cannot corrupt both sides
    /// of the differential tests at once).
    fn tick_all(&mut self, now: u64) {
        let cpb = self.cfg.cpu.cpu_per_bus;
        // Floor semantics: between boundaries the strict loop kept the
        // stale (floored) bus cycle, so recomputing it every visited
        // cycle is equivalent.
        self.hier.bus_now = now / cpb;
        if now % cpb == 0 {
            let bus = now / cpb;
            let mut completions = std::mem::take(&mut self.completions);
            completions.clear();
            for mc in &mut self.hier.mcs {
                mc.tick(bus, &mut completions);
            }
            for c in completions.drain(..) {
                if c.req_id & TRAFFIC_ID_BASE != 0 {
                    continue; // open-loop traffic: latency recorded at the column
                }
                if let Some((core, line)) = self.hier.inflight.remove(c.req_id) {
                    self.cores[core as usize].complete_line(line);
                }
            }
            self.completions = completions;
            if let Some(inj) = self.injector.as_mut() {
                inj.pump(bus, &mut self.hier);
            }
        }
        if self.injector.is_none() {
            for core in &mut self.cores {
                core.tick(now, &mut self.hier);
            }
        }
    }

    /// Indexed step: identical component visit order (controllers on a
    /// bus boundary first — completions land before cores tick — then
    /// cores in index order), but a component whose cached wake bound is
    /// still in the future is skipped outright: by the wake contract its
    /// tick would be a no-op. The cycle's entire due batch comes from
    /// one [`WakeIndex::drain_due`] traversal (sorted + deduped, then
    /// split into the core and controller segments), so dispatch is
    /// amortized per visited cycle, not per component. Every mutation
    /// re-indexes its component:
    ///
    /// * a **ticked** component gets a freshly computed bound;
    /// * a **completion** marks its core hot at `now` and joins it to
    ///   the due batch (the core ticks later this same cycle, as in the
    ///   strict order);
    /// * an **enqueue** (observed via `MemHierarchy::enqueued`) pulls the
    ///   target controller's bound down to the next bus boundary, where
    ///   its tick recomputes the true bound;
    /// * a controller drained at a **non-boundary** cycle (possible
    ///   after a sampled fast-forward re-heats the index) is re-clamped
    ///   to the next boundary — controllers only ever act on bus
    ///   boundaries, so the clamp is exact, and it must be re-inserted
    ///   because the drain consumed its index entry.
    fn tick_indexed(&mut self, now: u64) {
        let cpb = self.cfg.cpu.cpu_per_bus;
        let n_cores = self.cores.len();
        let n_ch = self.hier.mcs.len();
        let open = self.injector.is_some();
        self.hier.bus_now = now / cpb;
        let mut due = std::mem::take(&mut self.due_scratch);
        let mut due_cores = std::mem::take(&mut self.core_scratch);
        due.clear();
        due_cores.clear();
        self.wake.drain_due(now, &mut due);
        due.sort_unstable();
        due.dedup();
        let split = due.partition_point(|&id| (id as usize) < n_cores);
        due_cores.extend_from_slice(&due[..split]);
        if now % cpb == 0 {
            let bus = now / cpb;
            let mut completions = std::mem::take(&mut self.completions);
            completions.clear();
            for &id in &due[split..] {
                let ci = id as usize - n_cores;
                if ci >= n_ch {
                    // The injector's slot: its entry was consumed by the
                    // drain; the unconditional pump below re-arms it.
                    continue;
                }
                self.hier.mcs[ci].tick(bus, &mut completions);
                self.hier.enqueued[ci] = false;
                let b = self.hier.mcs[ci].next_event_at(bus + 1).max(bus + 1);
                self.wake.set(n_cores + ci, b.saturating_mul(cpb));
            }
            for c in completions.drain(..) {
                if c.req_id & TRAFFIC_ID_BASE != 0 {
                    continue; // open-loop traffic: latency recorded at the column
                }
                if let Some((core, line)) = self.hier.inflight.remove(c.req_id) {
                    let woke = self.cores[core as usize].complete_line(line);
                    debug_assert!(woke, "completion filled no MSHR waiter");
                    if woke && !open {
                        // A bound still in the future means this core was
                        // not part of the drained batch (nor woken by an
                        // earlier completion this cycle): join it exactly
                        // once.
                        if self.wake.bound(core as usize) > now {
                            due_cores.push(core);
                        }
                        self.wake.set(core as usize, now);
                    }
                }
            }
            self.completions = completions;
            // Pump at every visited boundary, matching the strict loop
            // (a boundary with nothing due is a no-op; the wake bound
            // guarantees every acting boundary is visited).
            if let Some(inj) = self.injector.as_mut() {
                inj.pump(bus, &mut self.hier);
                let b = inj.next_event_bus(bus);
                self.wake.set(n_cores + n_ch, b.saturating_mul(cpb));
            }
        } else {
            // Non-boundary cycle: controllers cannot act here. Their
            // drained entries must be re-inserted at the next boundary
            // or those wakes would be lost.
            let next_bus_cpu = (now / cpb + 1).saturating_mul(cpb);
            for &id in &due[split..] {
                self.wake.set(id as usize, next_bus_cpu);
            }
        }
        // Completion-woken cores joined at the tail: restore ascending
        // core order (the strict loop's visit order). Open-loop runs
        // quiesce the cores: their drained entries are simply dropped
        // (never re-inserted), parking them for the rest of the region.
        if !open {
            due_cores.sort_unstable();
            for &id in &due_cores {
                let i = id as usize;
                self.cores[i].tick(now, &mut self.hier);
                let bound = self.cores[i].next_event_at(now + 1);
                self.wake.set(i, bound);
            }
        }
        self.due_scratch = due;
        self.core_scratch = due_cores;
        // Enqueues that landed during the core ticks: the controller can
        // first act on them at the next bus boundary (a conservative
        // early bound; its tick there recomputes the real one).
        let next_bus_cpu = (now / cpb + 1).saturating_mul(cpb);
        for ci in 0..self.hier.mcs.len() {
            if self.hier.enqueued[ci] {
                self.hier.enqueued[ci] = false;
                let id = n_cores + ci;
                let clamped = self.wake.bound(id).min(next_bus_cpu);
                self.wake.set(id, clamped);
            }
        }
    }

    /// Run warmup + measured region; returns the result.
    ///
    /// Exactly `{ run_warmup(); run_measure() }` — the checkpoint layer
    /// ([`crate::sim::checkpoint`]) relies on that equivalence to fork
    /// sweep legs from a shared warmed-up snapshot.
    pub fn run(&mut self) -> SimResult {
        self.run_warmup();
        self.run_measure()
    }

    /// Advance `[start, end)` with the configured loop: the
    /// single-threaded event kernel, the strict per-cycle oracle, or —
    /// when the shard plan selects two or more shards — the
    /// channel-sharded parallel loop ([`advance_sharded`]). All three
    /// produce bit-identical results; `--sim-threads 1` (the default) is
    /// the exact pre-existing event path.
    ///
    /// [`advance_sharded`]: System::advance_sharded
    fn advance_region(&mut self, start: u64, end: u64, done: DoneFn) -> u64 {
        let mode = self.cfg.loop_mode;
        let shards = self.shard_plan();
        if shards >= 2 {
            self.advance_sharded(shards, start, end, done)
        } else {
            engine::advance(self, mode, start, end, done)
        }
    }

    /// Warmup phase: caches, HCRAC, and DRAM state get warm. Advances
    /// from the current clock to `warmup_cpu_cycles`; stats are reset by
    /// [`System::run_measure`]. The boundary between the two phases is
    /// the capture/restore point for
    /// [`crate::sim::checkpoint::SimSnapshot`].
    pub fn run_warmup(&mut self) {
        let start = self.cpu_cycle;
        let warmup_end = self.cfg.warmup_cpu_cycles;
        self.cpu_cycle = self.advance_region(start, warmup_end, |_| false);
    }

    /// Measured region: reset stats, run to the configured horizon (or
    /// instruction targets), and assemble the result. With
    /// `sample.detail_cycles` set (fixed-time mode only), the region is
    /// sampled: fixed-length detailed intervals separated by functional
    /// fast-forward (see [`crate::sim::sample`]).
    pub fn run_measure(&mut self) -> SimResult {
        for core in &mut self.cores {
            core.reset_stats();
            core.target = self.cfg.insts_per_core;
        }
        for mc in &mut self.hier.mcs {
            mc.reset_stats();
        }
        self.hier.llc.reset_stats();
        let measure_start = self.cpu_cycle;
        if self.cfg.traffic.mode != TrafficMode::Closed {
            self.enable_open_loop(measure_start);
        }

        // Fixed-time: run exactly `measure_cycles` (the stable basis for
        // multiprogrammed comparisons). Fixed-work: run until every core
        // reaches its instruction target (hard cap guards against
        // pathological stalls).
        let mut sampled = None;
        match self.cfg.measure_cycles {
            Some(n) => {
                for core in &mut self.cores {
                    core.target = 0; // no finish target in fixed-time mode
                }
                let end = measure_start + n;
                if self.cfg.sample.detail_cycles > 0 {
                    sampled = Some(self.run_sampled(measure_start, end));
                } else {
                    self.cpu_cycle = self.advance_region(measure_start, end, |_| false);
                }
            }
            None => {
                assert_eq!(
                    self.cfg.sample.detail_cycles, 0,
                    "interval sampling requires fixed-time mode (measure.cycles)"
                );
                let cap = measure_start
                    + self.cfg.insts_per_core * 400
                    + 10 * self.cfg.warmup_cpu_cycles;
                self.cpu_cycle = self.advance_region(measure_start, cap, |s| {
                    s.cores.iter().all(|c| c.stats.finished_at.is_some())
                });
            }
        }
        let mut result = self.collect(measure_start);
        result.sampled = sampled;
        result
    }

    /// Switch the measured region to open-loop traffic: build the
    /// injector over the per-core profiles, arm it at the measurement
    /// boundary (warmup always runs closed-loop), and rebuild the wake
    /// index with one extra slot for the injector — all-hot is a legal
    /// (conservative) start per the wake contract. The cores are
    /// quiesced from here on: the loop paths drop their wake entries and
    /// never tick them, so the injector's arrival processes are the only
    /// request source in the region.
    fn enable_open_loop(&mut self, measure_start: u64) {
        assert!(
            self.cfg.measure_cycles.is_some(),
            "open-loop traffic requires fixed-time mode (measure.cycles)"
        );
        assert_eq!(
            self.cfg.sample.detail_cycles, 0,
            "open-loop traffic is incompatible with interval sampling"
        );
        assert!(
            !self.open_profiles.is_empty(),
            "open-loop traffic requires synthetic profiles (not explicit traces)"
        );
        let mut inj = TrafficInjector::new(&self.cfg, &self.open_profiles);
        inj.start(measure_start / self.cfg.cpu.cpu_per_bus);
        self.injector = Some(inj);
        self.wake = WakeIndex::with_impl(
            self.cores.len() + self.hier.mcs.len() + 1,
            self.cfg.wake_impl,
        );
    }

    /// SimPoint-style interval sampling over a fixed-time region:
    /// simulate `sample.detail_cycles` in detail, then functionally
    /// fast-forward each core at its interval IPC (touching the LLC so
    /// its contents stay warm, no DRAM timing) to the next period
    /// boundary. Per-interval IPC/latency samples feed the confidence
    /// intervals in [`SampleSummary`]; DESIGN.md §12 documents the error
    /// model.
    fn run_sampled(&mut self, measure_start: u64, end: u64) -> SampleSummary {
        let detail = self.cfg.sample.detail_cycles;
        let period = self.cfg.sample.period_cycles;
        assert!(
            period > detail,
            "sample.period_cycles ({period}) must exceed sample.detail_cycles ({detail})"
        );
        let n_cores = self.cores.len();
        let mut ipc_samples = Vec::new();
        let mut lat_samples = Vec::new();
        let mut detailed_insts = 0u64;
        let mut skipped_insts = 0u64;
        let mut retired0 = vec![0u64; n_cores];
        let mut now = measure_start;
        while now < end {
            let d_end = (now + detail).min(end);
            let d_cycles = d_end - now;
            for (r, c) in retired0.iter_mut().zip(&self.cores) {
                *r = c.stats.retired;
            }
            let (lat_sum0, lat_cnt0) = self.read_latency_totals();
            self.cpu_cycle = self.advance_region(now, d_end, |_| false);
            now = d_end;
            let per_core: Vec<u64> = self
                .cores
                .iter()
                .zip(&retired0)
                .map(|(c, &r0)| c.stats.retired - r0)
                .collect();
            let d_insts: u64 = per_core.iter().sum();
            detailed_insts += d_insts;
            ipc_samples.push(d_insts as f64 / d_cycles as f64);
            let (lat_sum, lat_cnt) = self.read_latency_totals();
            if lat_cnt > lat_cnt0 {
                lat_samples.push((lat_sum - lat_sum0) as f64 / (lat_cnt - lat_cnt0) as f64);
            }
            let skip_cycles = (period - detail).min(end - now);
            if skip_cycles == 0 {
                continue;
            }
            // Integer IPC extrapolation keeps the skip deterministic
            // (u128 intermediate: insts x cycles can exceed 64 bits).
            let hier = &mut self.hier;
            for (ci, core) in self.cores.iter_mut().enumerate() {
                let skip =
                    ((per_core[ci] as u128 * skip_cycles as u128) / d_cycles as u128) as u64;
                skipped_insts += core.functional_advance(skip, &mut |line, is_write| {
                    let _ = hier.llc.access(line, is_write);
                });
                // The functional jump changed core state behind the wake
                // index: start the next interval hot (early is harmless).
                self.wake.set(ci, 0);
            }
            now += skip_cycles;
            self.cpu_cycle = now;
        }
        SampleSummary::from_samples(&ipc_samples, &lat_samples, detailed_insts, skipped_insts)
    }

    /// Aggregate read-latency counters across channels (bus cycles).
    fn read_latency_totals(&self) -> (u64, u64) {
        self.hier.mcs.iter().fold((0, 0), |(s, c), mc| {
            (s + mc.stats().read_latency_sum, c + mc.stats().read_latency_cnt)
        })
    }

    /// Shard count for this run: `sim.threads` from the config when set,
    /// else the process-wide `--sim-threads` / `PALLAS_SIM_THREADS` knob,
    /// capped at the channel count (a shard with no channels is dead
    /// weight). Only the event kernel shards; `--strict-tick` stays the
    /// untouched single-threaded oracle.
    fn shard_plan(&self) -> usize {
        if self.cfg.loop_mode != LoopMode::EventDriven {
            return 1;
        }
        let req = if self.cfg.sim_threads > 0 {
            self.cfg.sim_threads
        } else {
            crate::coordinator::runner::sim_threads()
        };
        req.max(1).min(self.hier.mcs.len())
    }

    /// Assemble the [`SimResult`] after the measured region.
    fn collect(&mut self, measure_start: u64) -> SimResult {
        let bus_start = measure_start / self.cfg.cpu.cpu_per_bus;
        let end = self.cpu_cycle;
        let bus_end = end / self.cfg.cpu.cpu_per_bus;
        for mc in &mut self.hier.mcs {
            mc.finalize(bus_end);
        }
        // Energy window: the mean core-finish time. Using last-finish
        // would let one chaotic laggard dominate the background-energy
        // comparison between mechanisms (multiprogrammed runs diverge).
        let mean_finish = self
            .cores
            .iter()
            .map(|c| c.stats.finished_at.unwrap_or(end))
            .sum::<u64>()
            / self.cores.len() as u64;
        let bus_energy_end = mean_finish / self.cfg.cpu.cpu_per_bus;

        // Per-core IPC: fixed-time mode uses the shared window; fixed-work
        // uses each core's own window up to its instruction target.
        let core_ipc = self
            .cores
            .iter()
            .map(|c| match self.cfg.measure_cycles {
                Some(n) => c.stats.retired as f64 / n as f64,
                None => {
                    let fin = c.stats.finished_at.unwrap_or(end);
                    let cycles = (fin - measure_start).max(1);
                    c.stats.retired.min(self.cfg.insts_per_core) as f64 / cycles as f64
                }
            })
            .collect();

        // Merge RLTL across channels (keys are channel-qualified, so the
        // merged histograms never conflate same-coordinate rows).
        let mut rltl = self.hier.mcs[0].rltl().clone();
        for mc in &self.hier.mcs[1..] {
            rltl.merge(mc.rltl());
        }

        // DRAM energy over the measured region.
        let emodel = EnergyModel::new(&self.cfg);
        let mut energy = EnergyBreakdown::default();
        let bus_cycles = bus_energy_end.saturating_sub(bus_start).max(1);
        for mc in &self.hier.mcs {
            energy.add(&emodel.channel_energy(mc.stats(), &mc.rank_active_cycles, bus_cycles));
        }

        let total_insts = self
            .cores
            .iter()
            .map(|c| match self.cfg.measure_cycles {
                Some(_) => c.stats.retired,
                None => c.stats.retired.min(self.cfg.insts_per_core),
            })
            .sum();

        // Per-request latency: merge the per-channel histograms in
        // canonical (ascending channel) order. `None` when no read
        // issued a column command in the window.
        let mut lat = LatencyHist::new();
        for mc in &self.hier.mcs {
            lat.merge(mc.latency_hist());
        }
        SimResult {
            workload: self.workload.clone(),
            mechanism: self.kind.label(),
            core_ipc,
            cpu_cycles: end - measure_start,
            mc: self.hier.mcs.iter().map(|m| m.stats().clone()).collect(),
            rltl: rltl.fractions(),
            energy,
            total_insts,
            llc_hits: self.hier.llc.hits,
            llc_misses: self.hier.llc.misses,
            sampled: None,
            latency: lat.summary(),
        }
    }

    /// The mechanism this system simulates.
    pub fn kind(&self) -> MechanismKind {
        self.kind
    }

    /// Current CPU cycle (the warmup boundary right after
    /// [`System::run_warmup`]).
    pub fn cpu_cycle(&self) -> u64 {
        self.cpu_cycle
    }

    /// The warmup identity of this run — see
    /// [`crate::config::SystemConfig::warmup_fingerprint`].
    pub fn warmup_fingerprint(&self) -> u64 {
        self.cfg.warmup_fingerprint(self.kind)
    }

    /// Checkpoint: the complete mutable state, in a fixed component
    /// order. The [`WakeIndex`] is deliberately excluded — a fresh
    /// all-hot-at-0 index is a legal (conservative) starting point, per
    /// the wake contract — and `completions` is an empty scratch buffer
    /// between ticks.
    pub fn export_state(&self) -> Vec<u64> {
        use crate::sim::checkpoint::{tags, Enc};
        let mut enc = Enc::new();
        enc.tag(tags::SYSTEM);
        enc.u64(self.cpu_cycle);
        enc.usize(self.cores.len());
        for core in &self.cores {
            core.export_state(&mut enc);
        }
        enc.tag(tags::HIER);
        self.hier.llc.export_state(&mut enc);
        enc.usize(self.hier.mcs.len());
        for mc in &self.hier.mcs {
            mc.export_state(&mut enc);
        }
        enc.u64(self.hier.bus_now);
        // In-flight slab verbatim (slot order pins future generational
        // ids; stale slot contents are part of the identity).
        enc.usize(self.hier.inflight.slots.len());
        for s in &self.hier.inflight.slots {
            enc.u32(s.generation);
            enc.bool(s.live);
            enc.u32(s.core);
            enc.u64(s.line);
        }
        enc.usize(self.hier.inflight.free.len());
        for &f in &self.hier.inflight.free {
            enc.u32(f);
        }
        enc.u64(self.hier.next_writeback_id);
        enc.usize(self.hier.enqueued.len());
        for &e in &self.hier.enqueued {
            enc.bool(e);
        }
        enc.into_words()
    }

    /// Restore from [`System::export_state`] words. `None` (with `self`
    /// possibly half-written — discard it) on any shape mismatch or
    /// corrupt stream. On success the system is at the captured clock
    /// with a fresh, all-hot wake index.
    pub fn import_state(&mut self, words: &[u64]) -> Option<()> {
        use crate::sim::checkpoint::{tags, Dec};
        let mut dec = Dec::new(words);
        let dec = &mut dec;
        dec.tag(tags::SYSTEM)?;
        self.cpu_cycle = dec.u64()?;
        if dec.usize()? != self.cores.len() {
            return None; // core count is config-derived shape
        }
        for core in self.cores.iter_mut() {
            core.import_state(dec)?;
        }
        dec.tag(tags::HIER)?;
        self.hier.llc.import_state(dec)?;
        if dec.usize()? != self.hier.mcs.len() {
            return None;
        }
        for mc in self.hier.mcs.iter_mut() {
            mc.import_state(dec)?;
        }
        self.hier.bus_now = dec.u64()?;
        let n_slots = dec.usize()?;
        self.hier.inflight.slots.clear();
        for _ in 0..n_slots {
            let generation = dec.u32()?;
            let live = dec.bool()?;
            let core = dec.u32()?;
            let line = dec.u64()?;
            self.hier.inflight.slots.push(InflightSlot { generation, live, core, line });
        }
        let n_free = dec.usize()?;
        self.hier.inflight.free.clear();
        for _ in 0..n_free {
            let f = dec.u32()?;
            if f as usize >= n_slots {
                return None;
            }
            self.hier.inflight.free.push(f);
        }
        self.hier.next_writeback_id = dec.u64()?;
        if dec.usize()? != self.hier.enqueued.len() {
            return None;
        }
        for e in self.hier.enqueued.iter_mut() {
            *e = dec.bool()?;
        }
        if !dec.finished() {
            return None; // trailing garbage is corruption
        }
        self.completions.clear();
        self.due_scratch.clear();
        self.core_scratch.clear();
        // Snapshots are always captured at the (closed-loop) warmup
        // boundary; a stale injector from a previous measured region
        // must not leak into the restored run.
        self.injector = None;
        // Fresh all-hot index (wheel or heap per config): every first
        // tick is at worst a no-op.
        self.wake =
            WakeIndex::with_impl(self.cores.len() + self.hier.mcs.len(), self.cfg.wake_impl);
        Some(())
    }

    /// Channel-sharded event loop (see [`crate::sim::shard`]): the
    /// controllers are partitioned into contiguous per-shard domains,
    /// each advanced by its own thread with a bus-domain wake index,
    /// synchronized at every visited bus boundary. Shard 0 runs inline
    /// on this thread; shards `1..` run on scoped workers that borrow
    /// the controllers for the duration of this call and hand them back
    /// at the end, so everything outside (stat resets, finalize, result
    /// assembly) is oblivious to the sharding.
    ///
    /// Control flow mirrors [`engine::advance`] exactly — same done
    /// checks, same end clamping — so the return value and every visited
    /// cycle match the single-threaded event loop bit for bit.
    fn advance_sharded(&mut self, shards: usize, mut now: u64, end: u64, done: DoneFn) -> u64 {
        let cpb = self.cfg.cpu.cpu_per_bus;
        let n_cores = self.cores.len();
        let n_ch = self.hier.mcs.len();
        let open = self.injector.is_some();
        let inj_slot = n_cores + n_ch;
        let chunk = (n_ch + shards - 1) / shards;
        let shards = (n_ch + chunk - 1) / chunk; // drop empty tail shards
        let rq_cap = self.cfg.mc.read_queue;
        let wq_cap = self.cfg.mc.write_queue;

        // Coordinator-side queue mirrors (exact — see [`ShardedPort`]).
        let mut rq_len: Vec<usize> = Vec::with_capacity(n_ch);
        let mut wq_len: Vec<usize> = Vec::with_capacity(n_ch);
        let mut wq_lines: Vec<Vec<Loc>> = Vec::with_capacity(n_ch);
        for mc in &self.hier.mcs {
            let (rq, wq) = mc.occupancy();
            rq_len.push(rq);
            wq_len.push(wq);
            wq_lines.push(mc.write_queue_locs().collect());
        }
        let mut staged: Vec<Vec<EnqMsg>> = (0..shards).map(|_| Vec::new()).collect();
        // Per-shard wake bounds, CPU-cycle domain. Hot at start: an early
        // bound costs a no-op epoch, never correctness.
        let mut shard_bound: Vec<u64> = vec![0; shards];

        // The controllers' entries in the CPU-domain wake index are owned
        // by `shard_bound` for the duration of this call.
        for ci in 0..n_ch {
            self.wake.set(n_cores + ci, u64::MAX);
        }

        // Lend the controllers out: shard 0 stays on this thread, the
        // rest move into scoped workers until this call returns.
        let mut remaining = std::mem::take(&mut self.hier.mcs);
        let mut worker_states: Vec<ShardState> = Vec::with_capacity(shards - 1);
        let mut shard0 = None;
        for s in 0..shards {
            let take = chunk.min(remaining.len());
            let rest = remaining.split_off(take);
            let st = ShardState::new(s * chunk, remaining, self.cfg.wake_impl);
            remaining = rest;
            if s == 0 {
                shard0 = Some(st);
            } else {
                worker_states.push(st);
            }
        }
        let mut shard0 = shard0.expect("at least one shard");
        let slots: Vec<ShardSlot> = (1..shards).map(|_| ShardSlot::default()).collect();

        let states: Vec<ShardState> = std::thread::scope(|scope| {
            let handles: Vec<_> = worker_states
                .into_iter()
                .zip(slots.iter())
                .map(|(st, slot)| scope.spawn(move || worker_loop(st, slot)))
                .collect();

            let mut epoch = 0u64;
            let mut inbox0: Vec<EnqMsg> = Vec::new();
            let mut out0 = EpochOut::default();
            let mut out_scratch = EpochOut::default();

            // The engine::advance control flow with the tick body inlined
            // (epoch barrier on bus boundaries, then core ticks).
            loop {
                if now >= end || done(self) {
                    break;
                }
                self.hier.bus_now = now / cpb;
                if now % cpb == 0 {
                    let bus = now / cpb;
                    epoch += 1;
                    // Signal due worker shards first: their epochs run
                    // concurrently with shard 0's inline one.
                    for s in 1..shards {
                        if shard_bound[s] <= now {
                            let slot = &slots[s - 1];
                            {
                                let mut shared = slot.inbox.lock().unwrap();
                                std::mem::swap(&mut *shared, &mut staged[s]);
                            }
                            slot.bus.store(bus, Ordering::Release);
                            slot.epoch.store(epoch, Ordering::Release);
                        }
                    }
                    if shard_bound[0] <= now {
                        std::mem::swap(&mut inbox0, &mut staged[0]);
                        shard0.run_epoch(&mut inbox0, bus, &mut out0);
                        self.apply_epoch_out(&out0, now, &mut rq_len, &mut wq_len, &mut wq_lines);
                        shard_bound[0] = out0.min_bound_bus.saturating_mul(cpb);
                    }
                    // Collect worker outputs in ascending shard order —
                    // concatenation is ascending global channel order, the
                    // canonical completion-delivery order.
                    for s in 1..shards {
                        if shard_bound[s] <= now {
                            let slot = &slots[s - 1];
                            let mut spins = 0u32;
                            let mut watchdog = Watchdog::new(s);
                            while slot.done.load(Ordering::Acquire) != epoch {
                                spins += 1;
                                if spins > 1_000 {
                                    std::thread::yield_now();
                                    // Clock reads only on the (rare) deep
                                    // stall path: a healthy worker acks
                                    // within the first few spins.
                                    if spins & 0xFFF == 0 {
                                        watchdog.poll();
                                    }
                                } else {
                                    std::hint::spin_loop();
                                }
                            }
                            {
                                let mut shared = slot.out.lock().unwrap();
                                std::mem::swap(&mut *shared, &mut out_scratch);
                            }
                            self.apply_epoch_out(
                                &out_scratch,
                                now,
                                &mut rq_len,
                                &mut wq_len,
                                &mut wq_lines,
                            );
                            shard_bound[s] = out_scratch.min_bound_bus.saturating_mul(cpb);
                        }
                    }
                }
                {
                    let mut port = ShardedPort {
                        llc: &mut self.hier.llc,
                        mapper: &self.hier.mapper,
                        inflight: &mut self.hier.inflight,
                        next_writeback_id: &mut self.hier.next_writeback_id,
                        bus_now: self.hier.bus_now,
                        chunk,
                        rq_cap,
                        wq_cap,
                        rq_len: &mut rq_len,
                        wq_len: &mut wq_len,
                        wq_lines: &mut wq_lines,
                        staged: &mut staged,
                    };
                    // Controllers are lent out (their coordinator-side
                    // entries sit at `u64::MAX`), so one drain yields
                    // exactly this cycle's due cores. Completion-woken
                    // cores were re-set to `now` by `apply_epoch_out`
                    // above, so they surface in the same batch.
                    let mut due = std::mem::take(&mut self.due_scratch);
                    due.clear();
                    self.wake.drain_due(now, &mut due);
                    due.sort_unstable();
                    due.dedup();
                    for &id in &due {
                        let i = id as usize;
                        if i >= n_cores {
                            // The injector's slot (controllers sit at
                            // u64::MAX): drained at a non-boundary, it
                            // must be re-armed or its wake is lost; the
                            // boundary pump below recomputes it.
                            debug_assert!(
                                open && i == inj_slot,
                                "only cores and the injector live in the lent index"
                            );
                            if now % cpb != 0 {
                                self.wake.set(i, (now / cpb + 1).saturating_mul(cpb));
                            }
                            continue;
                        }
                        if open {
                            continue; // cores quiesced under open-loop traffic
                        }
                        self.cores[i].tick(now, &mut port);
                        let bound = self.cores[i].next_event_at(now + 1);
                        self.wake.set(i, bound);
                    }
                    self.due_scratch = due;
                    // Pump at every visited boundary, after the epoch
                    // barrier delivered this cycle's completions and
                    // refreshed the queue mirrors — the same post-
                    // completion position as the sequential loops.
                    if now % cpb == 0 {
                        if let Some(inj) = self.injector.as_mut() {
                            let bus = now / cpb;
                            inj.pump(bus, &mut port);
                            let b = inj.next_event_bus(bus);
                            self.wake.set(inj_slot, b.saturating_mul(cpb));
                        }
                    }
                }
                // Trailing enqueue clamp at shard granularity: a staged
                // message forces its shard's epoch at the next boundary,
                // where delivery pulls the target channel's local bound
                // down — the sharded form of the sequential clamp.
                let next_bus_cpu = (now / cpb + 1).saturating_mul(cpb);
                for s in 0..shards {
                    if !staged[s].is_empty() {
                        shard_bound[s] = shard_bound[s].min(next_bus_cpu);
                    }
                }
                now += 1;
                if done(self) || now >= end {
                    break;
                }
                // Event jump: cores from the CPU-domain index, channels
                // from the per-shard bounds — the same global minimum the
                // sequential index would report.
                let mut wk = self.wake.min_bound();
                for &b in &shard_bound {
                    wk = wk.min(b);
                }
                now = wk.max(now).min(end - 1);
            }

            for slot in &slots {
                slot.stop.store(true, Ordering::Release);
            }
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });

        // Reassemble the hierarchy in channel order and restore the
        // controllers' CPU-domain wake entries from the shard-local ones.
        let mut mcs: Vec<MemController> = Vec::with_capacity(n_ch);
        for st in std::iter::once(shard0).chain(states) {
            for li in 0..st.mcs.len() {
                let b = st.wake.bound(li);
                self.wake.set(n_cores + st.base + li, b.saturating_mul(cpb));
            }
            mcs.extend(st.mcs);
        }
        self.hier.mcs = mcs;
        // Enqueues staged after the last visited boundary: the sequential
        // loop would already have them queued, so deliver them before
        // returning — the forwarding check still sees the same write
        // queues (no controller ticked in between).
        for msgs in &mut staged {
            for m in msgs.drain(..) {
                let ci = m.ch as usize;
                let accepted = self.hier.mcs[ci].enqueue(m.req, m.bus);
                debug_assert!(accepted, "admission was pre-checked");
                let id = n_cores + ci;
                let clamped = self.wake.bound(id).min((m.bus + 1).saturating_mul(cpb));
                self.wake.set(id, clamped);
            }
        }
        now
    }

    /// Apply one shard's epoch outputs on the coordinator: deliver
    /// completions through the in-flight slab (waking filled cores),
    /// retire drained writes from the write-queue mirror, and refresh
    /// the occupancy mirror for every channel the shard ticked.
    fn apply_epoch_out(
        &mut self,
        out: &EpochOut,
        now: u64,
        rq_len: &mut [usize],
        wq_len: &mut [usize],
        wq_lines: &mut [Vec<Loc>],
    ) {
        for c in &out.completions {
            if c.req_id & TRAFFIC_ID_BASE != 0 {
                continue; // open-loop traffic: latency recorded at the column
            }
            if let Some((core, line)) = self.hier.inflight.remove(c.req_id) {
                let woke = self.cores[core as usize].complete_line(line);
                debug_assert!(woke, "completion filled no MSHR waiter");
                if woke && self.injector.is_none() {
                    self.wake.set(core as usize, now);
                }
            }
        }
        for &(ch, loc) in &out.drained {
            let lines = &mut wq_lines[ch as usize];
            let idx = lines
                .iter()
                .position(|w| *w == loc)
                .expect("drained write missing from the coordinator mirror");
            lines.swap_remove(idx);
        }
        for &(ch, rq, wq) in &out.occ {
            rq_len[ch as usize] = rq as usize;
            wq_len[ch as usize] = wq as usize;
        }
    }
}

/// The cores' memory port during a sharded advance. The coordinator owns
/// the LLC, mapper, and in-flight slab outright; controller queue state
/// is **mirrored** (occupancy counts plus write-queue locations) so
/// admission control and write-to-read forwarding decide exactly what
/// the live controller will decide at delivery. Accepted requests are
/// staged per shard and flushed to the owning shard's inbox at the next
/// epoch barrier.
///
/// The mirrors are exact, not approximate: controllers mutate their
/// queues only inside epochs (enqueues from the delivered inbox,
/// dequeues from `schedule`), every epoch reports post-tick occupancy
/// and drained write locations for each ticked channel, and every
/// channel holding a staged enqueue is guaranteed to tick at the next
/// boundary (the enqueue clamp) — so between barriers the mirror equals
/// the queue state the sequential loop would hold at the same cycle.
struct ShardedPort<'a> {
    llc: &'a mut Llc,
    mapper: &'a AddressMapper,
    inflight: &'a mut InflightSlab,
    next_writeback_id: &'a mut u64,
    bus_now: u64,
    /// Channels per shard (`shard_of(ch) = ch / chunk`).
    chunk: usize,
    rq_cap: usize,
    wq_cap: usize,
    rq_len: &'a mut [usize],
    wq_len: &'a mut [usize],
    wq_lines: &'a mut [Vec<Loc>],
    staged: &'a mut [Vec<EnqMsg>],
}

impl ShardedPort<'_> {
    fn send_write(&mut self, line: u64) {
        let loc = self.mapper.map_line(line);
        let id = WRITEBACK_ID_BASE + *self.next_writeback_id;
        *self.next_writeback_id += 1;
        let ch = loc.channel as usize;
        self.wq_len[ch] += 1;
        self.wq_lines[ch].push(loc);
        self.staged[ch / self.chunk].push(EnqMsg {
            ch: loc.channel,
            bus: self.bus_now,
            req: Request { id, core: u32::MAX, loc, is_write: true, arrived: self.bus_now },
        });
    }
}

impl MemPort for ShardedPort<'_> {
    fn load(&mut self, core: u32, line: u64, _seq: u64) -> Result<bool, ()> {
        if self.llc.probe(line) {
            self.llc.access(line, false);
            return Ok(true);
        }
        let loc = self.mapper.map_line(line);
        let ch = loc.channel as usize;
        // Admission control against the mirrors — the same predicate
        // MemHierarchy::load evaluates against the live queues.
        if self.rq_len[ch] >= self.rq_cap || self.wq_len.iter().any(|&w| w >= self.wq_cap) {
            return Err(());
        }
        let res = self.llc.access(line, false);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        let id = self.inflight.insert(core, line);
        // The controller forwards a read matching a queued write without
        // consuming a read-queue slot; mirror that decision so the
        // occupancy mirror stays exact between epochs.
        let fwd = self.wq_lines[ch].iter().any(|w| {
            w.rank == loc.rank && w.bank == loc.bank && w.row == loc.row && w.col == loc.col
        });
        if !fwd {
            self.rq_len[ch] += 1;
        }
        self.staged[ch / self.chunk].push(EnqMsg {
            ch: loc.channel,
            bus: self.bus_now,
            req: Request { id, core, loc, is_write: false, arrived: self.bus_now },
        });
        Ok(false)
    }

    fn store(&mut self, core: u32, line: u64) -> Result<(), ()> {
        if self.wq_len.iter().any(|&w| w >= self.wq_cap) {
            return Err(());
        }
        let _ = core;
        let res = self.llc.access(line, true);
        if let crate::cpu::cache::LlcResult::Miss { writeback: Some(victim) } = res {
            self.send_write(victim);
        }
        Ok(())
    }
}

/// The mirror of [`InjectPort for MemHierarchy`]: identical per-channel
/// admission against the occupancy mirrors, identical forwarding
/// decision against the write-queue location mirror (a forwarded read
/// consumes no read-queue slot at delivery), and the accepted request is
/// staged for the owning shard's next epoch — exactly when a live
/// enqueue at this boundary would first be schedulable.
impl InjectPort for ShardedPort<'_> {
    fn try_inject(
        &mut self,
        line_addr: u64,
        is_write: bool,
        arrived_bus: u64,
        id: u64,
        _stream: u32,
    ) -> bool {
        let loc = self.mapper.map_line(line_addr);
        let ch = loc.channel as usize;
        if is_write {
            if self.wq_len[ch] >= self.wq_cap {
                return false;
            }
            self.wq_len[ch] += 1;
            self.wq_lines[ch].push(loc);
        } else {
            if self.rq_len[ch] >= self.rq_cap {
                return false;
            }
            let fwd = self.wq_lines[ch].iter().any(|w| {
                w.rank == loc.rank && w.bank == loc.bank && w.row == loc.row && w.col == loc.col
            });
            if !fwd {
                self.rq_len[ch] += 1;
            }
        }
        self.staged[ch / self.chunk].push(EnqMsg {
            ch: loc.channel,
            bus: self.bus_now,
            req: Request { id, core: u32::MAX, loc, is_write, arrived: arrived_bus },
        });
        true
    }
}

impl EventDriven for System {
    /// One simulation step at CPU cycle `now`: memory side first on bus
    /// boundaries (completions delivered before cores tick, as in the
    /// original loop), then cores in index order. The clock is owned by
    /// the loop driver; the strict oracle ticks every component, the
    /// event kernel only those whose cached wake bound is due.
    fn tick_at(&mut self, now: u64) {
        match self.cfg.loop_mode {
            LoopMode::StrictTick => self.tick_all(now),
            LoopMode::EventDriven => self.tick_indexed(now),
        }
    }

    /// Global next-wake straight from the wake index — O(1) amortized
    /// on the wheel (occupancy-bit scan from the cursor), O(log n) on
    /// the heap oracle — instead of recomputing every core and
    /// controller bound per jump (the controller bounds each cost a
    /// queue scan).
    fn next_wake(&mut self, now: u64) -> u64 {
        self.wake.min_bound().max(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::LoopMode;
    use crate::trace::Profile;

    fn quick_cfg(insts: u64) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.insts_per_core = insts;
        cfg.warmup_cpu_cycles = 20_000;
        cfg
    }

    #[test]
    fn event_kernel_matches_strict_tick_exactly() {
        // The engine's headline invariant: bit-identical results. The
        // full matrix lives in tests/engine_equiv.rs; this is the fast
        // in-crate smoke check. `SimResult: PartialEq` makes a failure
        // name the differing field instead of dumping two debug strings.
        let mut cfg = quick_cfg(30_000);
        cfg.warmup_cpu_cycles = 12_000;
        for name in ["mcf", "gcc"] {
            let p = Profile::by_name(name).unwrap();
            cfg.loop_mode = LoopMode::StrictTick;
            let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            cfg.loop_mode = LoopMode::EventDriven;
            let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            assert_eq!(a, b, "{name} diverged");
        }
    }

    #[test]
    fn wheel_and_heap_wake_indices_are_bit_identical() {
        // Same invariant as the loop-mode check, along the other axis:
        // the wake-index implementation must never be observable in
        // results. The full mechanism × shard matrix lives in
        // tests/engine_equiv.rs; this is the fast in-crate smoke check.
        let mut cfg = quick_cfg(30_000);
        cfg.warmup_cpu_cycles = 12_000;
        for name in ["mcf", "gcc"] {
            let p = Profile::by_name(name).unwrap();
            cfg.wake_impl = WakeImpl::Wheel;
            let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            cfg.wake_impl = WakeImpl::Heap;
            let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
            assert_eq!(a, b, "{name} diverged between wheel and heap");
        }
    }

    #[test]
    fn llc_resident_workload_runs_near_full_ipc() {
        let mut cfg = quick_cfg(150_000);
        cfg.warmup_cpu_cycles = 100_000; // enough to pull the WS into LLC
        let p = Profile::by_name("povray").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.ipc() > 1.2, "IPC {} too low for an LLC-resident app", r.ipc());
        assert!(r.rmpkc() < 5.0, "RMPKC {} too high", r.rmpkc());
    }

    #[test]
    fn memory_bound_workload_stresses_dram() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("mcf").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.ipc() < 1.0, "IPC {} too high for mcf-class", r.ipc());
        assert!(r.acts() > 100, "expected DRAM activity");
        assert!(r.rmpkc() > 1.0, "RMPKC {}", r.rmpkc());
    }

    #[test]
    fn lldram_never_slower_than_baseline() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("libquantum").unwrap();
        let base = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let ll = System::new(&cfg, MechanismKind::LlDram, &[p]).run();
        assert!(ll.ipc() >= base.ipc() * 0.999, "{} vs {}", ll.ipc(), base.ipc());
    }

    #[test]
    fn chargecache_between_baseline_and_lldram() {
        let cfg = quick_cfg(60_000);
        let p = Profile::by_name("tpcc64").unwrap();
        let base = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let cc = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let ll = System::new(&cfg, MechanismKind::LlDram, &[p]).run();
        assert!(cc.ipc() >= base.ipc() * 0.995);
        assert!(ll.ipc() >= cc.ipc() * 0.995);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = quick_cfg(30_000);
        let p = Profile::by_name("gcc").unwrap();
        let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        assert_eq!(a.ipc(), b.ipc());
        assert_eq!(a.acts(), b.acts());
        assert_eq!(a.cpu_cycles, b.cpu_cycles);
    }

    #[test]
    fn multicore_mix_runs_all_cores() {
        let mut cfg = SystemConfig::eight_core();
        cfg.cpu.cores = 4;
        cfg.insts_per_core = 20_000;
        cfg.warmup_cpu_cycles = 10_000;
        let r = System::new_mix(&cfg, MechanismKind::ChargeCache, 0).run();
        assert_eq!(r.core_ipc.len(), 4);
        assert!(r.core_ipc.iter().all(|&i| i > 0.0));
        assert_eq!(r.mc.len(), 2); // two channels
    }

    #[test]
    fn energy_is_positive_and_dominated_by_known_terms() {
        let cfg = quick_cfg(40_000);
        let p = Profile::by_name("lbm").unwrap();
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(r.energy.total_nj() > 0.0);
        assert!(r.energy.background_nj > 0.0);
        assert!(r.energy.act_pre_nj > 0.0);
    }

    #[test]
    fn inflight_slab_recycles_slots_with_fresh_generations() {
        let mut slab = InflightSlab::default();
        let a = slab.insert(1, 0x100);
        let b = slab.insert(2, 0x200);
        assert_ne!(a, b);
        assert_eq!(slab.remove(a), Some((1, 0x100)));
        // Stale id: the slot was released, so the old generation misses.
        assert_eq!(slab.remove(a), None);
        let c = slab.insert(3, 0x300);
        assert_ne!(c, a, "recycled slot must carry a fresh generation");
        assert_eq!(c & 0xFFFF_FFFF, a & 0xFFFF_FFFF, "slot index is reused");
        assert_eq!(slab.remove(c), Some((3, 0x300)));
        assert_eq!(slab.remove(b), Some((2, 0x200)));
        // Slab read ids never reach the writeback half-space.
        assert_eq!(c & WRITEBACK_ID_BASE, 0);
    }

    /// The checkpoint identity contract, at system granularity:
    /// `run()` must equal `{ run_warmup(); capture -> fresh -> restore;
    /// run_measure() }` bit for bit, in both loop modes. The full matrix
    /// (mechanisms, shards, randomized configs) lives in
    /// tests/checkpoint.rs; this is the in-crate smoke check.
    #[test]
    fn checkpoint_fork_matches_uninterrupted_run() {
        use crate::sim::checkpoint::SimSnapshot;
        let mut cfg = quick_cfg(0);
        cfg.warmup_cpu_cycles = 20_000;
        cfg.measure_cycles = Some(40_000);
        let p = Profile::by_name("mcf").unwrap();
        for mode in [LoopMode::StrictTick, LoopMode::EventDriven] {
            cfg.loop_mode = mode;
            let full = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();

            let mut warm = System::new(&cfg, MechanismKind::ChargeCache, &[p]);
            warm.run_warmup();
            let snap = SimSnapshot::capture(&warm);
            let mut forked = System::new(&cfg, MechanismKind::ChargeCache, &[p]);
            snap.restore_into(&mut forked).expect("snapshot belongs to this identity");
            assert_eq!(forked.cpu_cycle(), snap.cpu_cycle);
            let r = forked.run_measure();
            assert_eq!(full, r, "{mode:?}: forked leg diverged from the cold run");

            // A corrupt word stream must be rejected, not half-applied.
            let mut bad = snap.clone();
            bad.words.truncate(bad.words.len() / 2);
            assert!(bad.restore_into(&mut System::new(
                &cfg,
                MechanismKind::ChargeCache,
                &[p]
            ))
            .is_none());
        }
    }

    /// Interval sampling: the sampled IPC estimate must land near the
    /// full detailed run, detailed+skipped instruction accounting must
    /// add up, and the summary must carry usable confidence intervals.
    #[test]
    fn sampled_run_tracks_full_run_ipc() {
        let mut cfg = quick_cfg(0);
        cfg.warmup_cpu_cycles = 20_000;
        cfg.measure_cycles = Some(200_000);
        let p = Profile::by_name("gcc").unwrap();
        let full = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        assert!(full.sampled.is_none(), "sampling off by default");

        cfg.sample.detail_cycles = 10_000;
        cfg.sample.period_cycles = 20_000;
        let r = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let s = r.sampled.expect("sampling was enabled");
        assert_eq!(s.intervals, 10); // 200k cycles / 20k period
        assert!(s.detailed_insts > 0 && s.skipped_insts > 0);
        let rel = (s.ipc_mean - full.ipc()).abs() / full.ipc();
        assert!(
            rel < 0.25,
            "sampled IPC {} strayed from full-run IPC {} (rel err {rel:.3})",
            s.ipc_mean,
            full.ipc()
        );
        assert!(s.ipc_ci95 >= 0.0 && s.latency_mean > 0.0);
    }

    /// Open-loop traffic: the bit-identity invariant extends to the
    /// injected region (strict vs event here; the shard × wake-impl
    /// matrix lives in tests/engine_equiv.rs), and the merged histogram
    /// must surface ordered percentiles.
    #[test]
    fn open_loop_modes_are_bit_identical_and_record_latency() {
        let mut cfg = quick_cfg(0);
        cfg.warmup_cpu_cycles = 20_000;
        cfg.measure_cycles = Some(100_000);
        cfg.traffic.mode = TrafficMode::Poisson;
        cfg.traffic.rate_rps = 20_000_000.0;
        let p = Profile::by_name("mcf").unwrap();
        cfg.loop_mode = LoopMode::StrictTick;
        let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        cfg.loop_mode = LoopMode::EventDriven;
        let b = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        assert_eq!(a, b, "open-loop strict vs event diverged");
        let lat = a.latency.expect("open-loop run must record latency");
        assert!(lat.samples > 100, "expected arrivals at 20M rps, got {}", lat.samples);
        assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99 && lat.p99 <= lat.max);
        assert!(lat.mean > 0.0);
        // Cores are quiesced: the injector is the only request source.
        assert_eq!(a.total_insts, 0, "open-loop measure must not retire instructions");
    }

    /// The subsystem's reason to exist: past the service capacity the
    /// arrival FIFO backs up and the intended-arrival latency stamps
    /// make the tail explode, where a closed-loop run would simply
    /// self-throttle.
    #[test]
    fn overload_explodes_the_latency_tail() {
        let mut cfg = quick_cfg(0);
        cfg.warmup_cpu_cycles = 20_000;
        cfg.measure_cycles = Some(100_000);
        cfg.traffic.mode = TrafficMode::Det;
        let p = Profile::by_name("mcf").unwrap();
        cfg.traffic.rate_rps = 5_000_000.0;
        let light = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        cfg.traffic.rate_rps = 400_000_000.0;
        let heavy = System::new(&cfg, MechanismKind::Baseline, &[p]).run();
        let l = light.latency.expect("light run records latency");
        let h = heavy.latency.expect("heavy run records latency");
        assert!(
            h.p99 > l.p99.saturating_mul(4),
            "overload p99 {} vs light p99 {}",
            h.p99,
            l.p99
        );
        assert!(h.samples > l.samples, "heavier load must admit more requests");
    }

    /// Satellite guarantee, in-crate smoke form (the pinned cross-mode
    /// row lives in tests/engine_equiv.rs): with `traffic.mode = closed`
    /// the other traffic knobs are inert — same results bit for bit.
    #[test]
    fn traffic_knobs_do_not_perturb_closed_loop_runs() {
        let cfg = quick_cfg(30_000);
        let p = Profile::by_name("gcc").unwrap();
        let a = System::new(&cfg, MechanismKind::ChargeCache, &[p]).run();
        let mut loud = cfg.clone();
        loud.traffic.rate_rps = 123_456_789.0;
        loud.traffic.seed = 99;
        loud.traffic.mmpp_ratio = 16.0;
        let b = System::new(&loud, MechanismKind::ChargeCache, &[p]).run();
        assert_eq!(a, b, "closed-loop run perturbed by inert traffic knobs");
    }

    #[test]
    fn wake_bounds_stay_conservative_through_an_event_run() {
        let mut cfg = quick_cfg(0);
        cfg.loop_mode = LoopMode::EventDriven;
        let p = Profile::by_name("tpcc64").unwrap();
        let mut sys = System::new(&cfg, MechanismKind::ChargeCache, &[p]);
        let mut now = 0u64;
        for chunk in [1u64, 7, 100, 1_000, 10_000, 50_000] {
            now = engine::advance(&mut sys, LoopMode::EventDriven, now, now + chunk, |_| false);
            sys.assert_wake_bounds_conservative(now);
        }
    }
}
