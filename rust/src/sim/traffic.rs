//! Open-loop traffic injection (DESIGN.md §14).
//!
//! Closed-loop replay (the paper's methodology) couples the arrival rate
//! to the memory system's own service rate: a saturated controller stalls
//! the cores, which stops issuing requests. That can never observe the
//! question the ROADMAP's north star asks — *at what offered load does
//! the tail latency explode?* — because the offered load is not a free
//! variable. This module makes it one: a [`TrafficInjector`] draws
//! request arrival times from a pluggable stochastic process at a
//! configured rate ([`TrafficConfig::rate_rps`]), takes addresses from
//! the same synthetic profiles the cores replay
//! ([`crate::trace::synth::SynthTrace`]), and enqueues directly at the
//! memory controllers through an [`InjectPort`]. Requests that cannot be
//! admitted wait in an unbounded arrival FIFO — so under overload the
//! queueing delay (and hence the latency tail) grows without bound,
//! which is exactly the knee the latency-vs-load scenarios detect.
//!
//! ## Determinism
//!
//! All randomness comes from per-stream [`SplitMix64`] generators seeded
//! from `traffic.seed` — a domain disjoint from the XorShift64 streams
//! driving the synthetic traces, so enabling the subsystem cannot
//! perturb a closed-loop run. Arrival times are absolute `f64` bus
//! cycles computed by an identical operation sequence in every loop
//! mode; the injector acts only at visited bus-cycle boundaries, drains
//! streams in ascending stream order, and admits backlog strictly
//! head-first. Because its wake bound covers every boundary at which it
//! would act (next arrival, or the very next boundary while backlog is
//! pending), the strict-tick, event-driven, and channel-sharded loops
//! all observe the same injection sequence — bit-identical percentiles
//! at any `--sim-threads` count on either wake implementation.

use std::collections::VecDeque;

use crate::config::{SystemConfig, TrafficConfig, TrafficMode};
use crate::trace::synth::SynthTrace;
use crate::trace::TraceSource;

/// Request-id namespace for injected traffic. Disjoint from core ids
/// (generation<<32|slot, generation capped at 2^31) and writeback ids
/// (`1 << 63`): completions carrying this bit bypass the in-flight slab
/// entirely (fire-and-forget — latency is recorded controller-side).
pub const TRAFFIC_ID_BASE: u64 = 1 << 62;

/// Domain-separation salt for traffic RNG seeding ("TRAF" twice) — keeps
/// the streams independent of every other seeded domain in the system.
const TRAFFIC_SEED_SALT: u64 = 0x5452_4146_5452_4146;

/// SplitMix64 (Steele et al.): the arrival-process RNG. Tiny state, full
/// 64-bit period, and trivially seedable into independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 mantissa bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential draw with the given mean (inverse-CDF; `u = 0` maps
    /// to 0, never infinity, because `ln(1 - u)` sees `1.0`).
    #[inline]
    fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }
}

/// One arrival due for injection but not yet admitted by its channel.
#[derive(Debug, Clone, Copy)]
struct Pending {
    line_addr: u64,
    is_write: bool,
    /// Intended arrival bus cycle — becomes `Request::arrived`, so the
    /// measured latency includes time spent waiting in this FIFO.
    arrived_bus: u64,
    stream: u32,
}

/// Where the injector hands admitted requests to: implemented by the
/// live memory hierarchy and by the sharded coordinator's mirror port,
/// with identical admission predicates on both sides.
pub trait InjectPort {
    /// Admit one traffic request, or refuse (`false`) when the owning
    /// channel cannot accept it at this boundary; the injector holds it
    /// and retries at the next boundary (head-of-line order).
    fn try_inject(
        &mut self,
        line_addr: u64,
        is_write: bool,
        arrived_bus: u64,
        id: u64,
        stream: u32,
    ) -> bool;
}

/// One per-core arrival stream: a seeded arrival process over that
/// core's synthetic address profile (same region as the core, so the
/// injected traffic exercises the state warmup built).
struct ArrivalStream {
    trace: SynthTrace,
    rng: SplitMix64,
    mode: TrafficMode,
    /// Absolute bus cycle of the next arrival (fractional).
    t: f64,
    /// Mean interarrival while the stream is emitting, bus cycles:
    /// det/poisson use it directly; burst uses it for the ON state;
    /// MMPP's two rates are `ia_lo`/`ia_hi`.
    ia_on: f64,
    ia_lo: f64,
    ia_hi: f64,
    /// Mean modulating-state window lengths, bus cycles.
    on_len: f64,
    off_len: f64,
    sojourn: f64,
    /// Modulating state (burst: ON; MMPP: high-rate) and its end time.
    state_hi: bool,
    state_end: f64,
}

impl ArrivalStream {
    /// Advance `t` to the next arrival. Window truncation + redraw is
    /// exact for exponential interarrivals (memorylessness), so the
    /// burst/MMPP processes have their nominal rates.
    fn advance(&mut self) {
        match self.mode {
            TrafficMode::Closed => unreachable!("closed mode never builds streams"),
            TrafficMode::Det => self.t += self.ia_on,
            TrafficMode::Poisson => {
                let d = self.rng.exp(self.ia_on);
                self.t += d;
            }
            TrafficMode::Burst => loop {
                if self.state_hi {
                    let cand = self.t + self.rng.exp(self.ia_on);
                    if cand <= self.state_end {
                        self.t = cand;
                        return;
                    }
                    self.t = self.state_end;
                    self.state_hi = false;
                    self.state_end = self.t + self.rng.exp(self.off_len);
                } else {
                    self.t = self.state_end;
                    self.state_hi = true;
                    self.state_end = self.t + self.rng.exp(self.on_len);
                }
            },
            TrafficMode::Mmpp => loop {
                let ia = if self.state_hi { self.ia_hi } else { self.ia_lo };
                let cand = self.t + self.rng.exp(ia);
                if cand <= self.state_end {
                    self.t = cand;
                    return;
                }
                self.t = self.state_end;
                self.state_hi = !self.state_hi;
                self.state_end = self.t + self.rng.exp(self.sojourn);
            },
        }
    }
}

/// The open-loop request injector: one arrival stream per core, a global
/// head-first admission FIFO, and monotonically increasing traffic ids.
pub struct TrafficInjector {
    streams: Vec<ArrivalStream>,
    backlog: VecDeque<Pending>,
    next_seq: u64,
    started: bool,
    /// Arrivals generated / requests admitted (telemetry).
    pub generated: u64,
    pub injected: u64,
}

impl TrafficInjector {
    /// Build the per-core streams for `cfg.traffic` over the same
    /// per-core profiles (and address regions) the closed-loop cores
    /// replay. Panics on a degenerate process configuration — zero or
    /// negative rate, or zero-length modulating windows — which would
    /// otherwise spin forever drawing empty windows.
    pub fn new(cfg: &SystemConfig, profiles: &[crate::trace::profile::Profile]) -> Self {
        let t = &cfg.traffic;
        assert!(t.mode != TrafficMode::Closed, "no injector in closed-loop mode");
        assert!(t.rate_rps > 0.0, "traffic.rate_rps must be positive");
        if t.mode == TrafficMode::Burst {
            assert!(
                t.burst_on_us > 0.0 && t.burst_off_us > 0.0,
                "traffic.burst_on_us/burst_off_us must be positive"
            );
        }
        if t.mode == TrafficMode::Mmpp {
            assert!(t.mmpp_ratio > 0.0, "traffic.mmpp_ratio must be positive");
            assert!(t.mmpp_sojourn_us > 0.0, "traffic.mmpp_sojourn_us must be positive");
        }
        let bus_per_sec = 1e9 / cfg.timing.tck_ns;
        let n = profiles.len().max(1) as f64;
        let stream_rate = t.rate_rps / n; // requests/sec per stream
        let ia_mean = bus_per_sec / stream_rate; // bus cycles
        // Burst: Poisson at rate/duty inside exponential ON windows, so
        // the long-run average still hits the configured rate.
        let duty = t.burst_on_us / (t.burst_on_us + t.burst_off_us);
        // MMPP-2 with equal mean sojourns: (r_lo + r_hi)/2 = stream rate.
        let r_lo = 2.0 * stream_rate / (1.0 + t.mmpp_ratio);
        let r_hi = t.mmpp_ratio * r_lo;
        let streams = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| ArrivalStream {
                trace: SynthTrace::new(
                    p,
                    t.seed ^ TRAFFIC_SEED_SALT ^ ((i as u64) << 8),
                    i as u64,
                ),
                rng: SplitMix64::new(
                    t.seed
                        ^ TRAFFIC_SEED_SALT
                        ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                ),
                mode: t.mode,
                t: 0.0,
                ia_on: if t.mode == TrafficMode::Burst { ia_mean * duty } else { ia_mean },
                ia_lo: bus_per_sec / r_lo,
                ia_hi: bus_per_sec / r_hi,
                on_len: t.burst_on_us * 1e-6 * bus_per_sec,
                off_len: t.burst_off_us * 1e-6 * bus_per_sec,
                sojourn: t.mmpp_sojourn_us * 1e-6 * bus_per_sec,
                state_hi: t.mode == TrafficMode::Burst, // MMPP starts low
                state_end: 0.0,
            })
            .collect();
        Self {
            streams,
            backlog: VecDeque::new(),
            next_seq: 0,
            started: false,
            generated: 0,
            injected: 0,
        }
    }

    /// Arm the streams at the measurement boundary: warmup always runs
    /// closed-loop, so injection begins here and nowhere else. Each
    /// stream's clock starts at `start_bus` and its first arrival is
    /// drawn immediately.
    pub fn start(&mut self, start_bus: u64) {
        assert!(!self.started, "injector started twice");
        self.started = true;
        for s in &mut self.streams {
            s.t = start_bus as f64;
            s.state_end = match s.mode {
                TrafficMode::Burst => s.t + s.rng.exp(s.on_len),
                TrafficMode::Mmpp => s.t + s.rng.exp(s.sojourn),
                _ => f64::INFINITY,
            };
            s.advance();
        }
    }

    pub fn started(&self) -> bool {
        self.started
    }

    /// Requests waiting for admission.
    pub fn backlog_len(&self) -> usize {
        self.backlog.len()
    }

    /// Run the injector at a visited bus-cycle boundary: collect every
    /// arrival due by `bus` (ascending stream order — the canonical tie
    /// break within a boundary), then admit backlog head-first until a
    /// channel refuses. Identical in every loop mode because each mode
    /// visits every boundary this method would act at.
    pub fn pump<P: InjectPort>(&mut self, bus: u64, port: &mut P) {
        debug_assert!(self.started, "pump before start");
        let now = bus as f64;
        for (i, s) in self.streams.iter_mut().enumerate() {
            while s.t <= now {
                let arrived_bus = s.t as u64;
                let e = s.trace.next_entry();
                self.backlog.push_back(Pending {
                    line_addr: e.line_addr,
                    is_write: e.is_write,
                    arrived_bus,
                    stream: i as u32,
                });
                self.generated += 1;
                s.advance();
            }
        }
        while let Some(p) = self.backlog.front().copied() {
            let id = TRAFFIC_ID_BASE | self.next_seq;
            if !port.try_inject(p.line_addr, p.is_write, p.arrived_bus, id, p.stream) {
                break;
            }
            self.next_seq += 1;
            self.injected += 1;
            self.backlog.pop_front();
        }
    }

    /// Next bus cycle at which [`TrafficInjector::pump`] must run: the
    /// very next boundary while backlog is pending admission, else the
    /// first boundary at or after the earliest stream arrival.
    pub fn next_event_bus(&self, bus: u64) -> u64 {
        if !self.backlog.is_empty() {
            return bus + 1;
        }
        let mut next = f64::INFINITY;
        for s in &self.streams {
            next = next.min(s.t);
        }
        if next.is_finite() {
            (next.ceil() as u64).max(bus + 1)
        } else {
            u64::MAX
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::profile::Profile;

    fn open_cfg(mode: TrafficMode, rate: f64) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.traffic.mode = mode;
        cfg.traffic.rate_rps = rate;
        cfg
    }

    fn profiles(cfg: &SystemConfig, name: &str) -> Vec<Profile> {
        let p = *Profile::by_name(name).unwrap();
        vec![p; cfg.cpu.cores]
    }

    /// Port that admits everything and logs the injection order.
    #[derive(Default)]
    struct OpenPort {
        seen: Vec<(u64, u64, bool, u32)>, // (id, arrived, is_write, stream)
    }

    impl InjectPort for OpenPort {
        fn try_inject(
            &mut self,
            _line: u64,
            is_write: bool,
            arrived_bus: u64,
            id: u64,
            stream: u32,
        ) -> bool {
            self.seen.push((id, arrived_bus, is_write, stream));
            true
        }
    }

    /// Port that refuses everything — arrivals accumulate in the FIFO.
    struct ClosedPort;

    impl InjectPort for ClosedPort {
        fn try_inject(&mut self, _: u64, _: bool, _: u64, _: u64, _: u32) -> bool {
            false
        }
    }

    #[test]
    fn deterministic_across_instances() {
        for mode in [TrafficMode::Det, TrafficMode::Poisson, TrafficMode::Burst, TrafficMode::Mmpp]
        {
            let cfg = open_cfg(mode, 50_000_000.0);
            let ps = profiles(&cfg, "mcf");
            let mut a = TrafficInjector::new(&cfg, &ps);
            let mut b = TrafficInjector::new(&cfg, &ps);
            a.start(1000);
            b.start(1000);
            let (mut pa, mut pb) = (OpenPort::default(), OpenPort::default());
            for bus in 1000..6000 {
                a.pump(bus, &mut pa);
                b.pump(bus, &mut pb);
            }
            assert_eq!(pa.seen, pb.seen, "{mode:?}");
            assert!(!pa.seen.is_empty(), "{mode:?}: no arrivals at 50M rps");
        }
    }

    #[test]
    fn sparse_boundary_visits_see_the_same_sequence() {
        // Event-mode discipline: only visit the boundaries the injector
        // asks for. The injection sequence must match strict per-cycle
        // pumping exactly.
        let cfg = open_cfg(TrafficMode::Poisson, 20_000_000.0);
        let ps = profiles(&cfg, "mcf");
        let mut strict = TrafficInjector::new(&cfg, &ps);
        let mut event = TrafficInjector::new(&cfg, &ps);
        strict.start(0);
        event.start(0);
        let (mut pa, mut pb) = (OpenPort::default(), OpenPort::default());
        for bus in 0..20_000u64 {
            strict.pump(bus, &mut pa);
        }
        let mut bus = 0u64;
        while bus < 20_000 {
            event.pump(bus, &mut pb);
            let next = event.next_event_bus(bus);
            assert!(next > bus, "wake bound must advance");
            bus = next;
        }
        assert_eq!(pa.seen, pb.seen);
    }

    #[test]
    fn arrival_rate_approximates_the_configured_rate() {
        // 80M rps at 800M bus cycles/s = 0.1 arrivals/cycle; over 100k
        // cycles expect ~10k arrivals (±15% for the stochastic modes).
        for mode in [TrafficMode::Det, TrafficMode::Poisson, TrafficMode::Burst, TrafficMode::Mmpp]
        {
            let cfg = open_cfg(mode, 80_000_000.0);
            let ps = profiles(&cfg, "mcf");
            let mut inj = TrafficInjector::new(&cfg, &ps);
            inj.start(0);
            let mut port = OpenPort::default();
            for bus in 0..100_000u64 {
                inj.pump(bus, &mut port);
            }
            let n = port.seen.len() as f64;
            assert!(
                (n - 10_000.0).abs() < 1_500.0,
                "{mode:?}: {n} arrivals, expected ~10000"
            );
        }
    }

    #[test]
    fn backlog_holds_refused_requests_in_arrival_order() {
        let cfg = open_cfg(TrafficMode::Det, 80_000_000.0);
        let ps = profiles(&cfg, "mcf");
        let mut inj = TrafficInjector::new(&cfg, &ps);
        inj.start(0);
        for bus in 0..1000u64 {
            inj.pump(bus, &mut ClosedPort);
        }
        let held = inj.backlog_len();
        assert!(held > 50, "det @ 0.1/cycle over 1000 cycles: {held}");
        assert_eq!(inj.injected, 0);
        assert_eq!(inj.generated as usize, held);
        // Admission drains strictly head-first with intended (not
        // admission) arrival stamps, monotone within the stream.
        let mut port = OpenPort::default();
        inj.pump(1000, &mut port);
        assert_eq!(inj.backlog_len(), 0);
        let arrivals: Vec<u64> = port.seen.iter().map(|s| s.1).collect();
        let mut sorted = arrivals.clone();
        sorted.sort_unstable();
        assert_eq!(arrivals, sorted, "single-stream backlog preserves arrival order");
        assert!(*arrivals.last().unwrap() < 1000, "stamps are intended arrivals");
        // Ids are dense and namespaced.
        for (i, s) in port.seen.iter().enumerate() {
            assert_eq!(s.0, TRAFFIC_ID_BASE | i as u64);
        }
    }

    #[test]
    fn streams_split_the_rate_across_cores() {
        let mut cfg = open_cfg(TrafficMode::Det, 80_000_000.0);
        cfg.cpu.cores = 4;
        let ps = profiles(&cfg, "mcf");
        let mut inj = TrafficInjector::new(&cfg, &ps);
        inj.start(0);
        let mut port = OpenPort::default();
        for bus in 0..100_000u64 {
            inj.pump(bus, &mut port);
        }
        // Aggregate still ~10k; each stream carries ~2.5k.
        let n = port.seen.len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "total {n}");
        for s in 0..4u32 {
            let per = port.seen.iter().filter(|e| e.3 == s).count() as f64;
            assert!((per - 2_500.0).abs() < 200.0, "stream {s}: {per}");
        }
    }

    #[test]
    fn seed_moves_the_stochastic_arrivals() {
        let cfg_a = open_cfg(TrafficMode::Poisson, 40_000_000.0);
        let mut cfg_b = cfg_a.clone();
        cfg_b.traffic.seed ^= 1;
        let ps = profiles(&cfg_a, "mcf");
        let mut a = TrafficInjector::new(&cfg_a, &ps);
        let mut b = TrafficInjector::new(&cfg_b, &ps);
        a.start(0);
        b.start(0);
        let (mut pa, mut pb) = (OpenPort::default(), OpenPort::default());
        for bus in 0..10_000u64 {
            a.pump(bus, &mut pa);
            b.pump(bus, &mut pb);
        }
        assert_ne!(pa.seen, pb.seen);
    }

    #[test]
    fn next_event_bus_covers_every_acting_boundary() {
        let cfg = open_cfg(TrafficMode::Mmpp, 10_000_000.0);
        let ps = profiles(&cfg, "omnetpp");
        let mut inj = TrafficInjector::new(&cfg, &ps);
        inj.start(0);
        // With an empty backlog the bound is the next arrival's ceiling.
        let bound = inj.next_event_bus(0);
        assert!(bound >= 1);
        let mut port = OpenPort::default();
        inj.pump(bound, &mut port);
        assert!(!port.seen.is_empty(), "bound must land on the arrival");
        // With backlog pending, the bound is the very next boundary.
        for bus in bound + 1..bound + 500 {
            inj.pump(bus, &mut ClosedPort);
        }
        if inj.backlog_len() > 0 {
            assert_eq!(inj.next_event_bus(bound + 500), bound + 501);
        }
    }
}
