//! Event-driven simulation kernel: fast-forward the clock to the next
//! cycle at which any component can act, instead of ticking every cycle.
//!
//! The per-cycle loop spends most of its time ticking components that
//! provably cannot do anything: cores whose reorder windows are blocked
//! behind an outstanding DRAM miss, and controllers waiting out a timing
//! constraint (tRCD, tRP, tRFC, ...) with nothing legal to issue. On
//! memory-bound workloads (`mcf`, `tpcc64`) that is the overwhelming
//! majority of CPU cycles. The kernel skips them.
//!
//! ## The wake-time contract
//!
//! Every component exposes a `next_event_at(now)` method: a
//! **conservative lower bound** on the earliest cycle `>= now` at which
//! ticking it could change simulation state. Two properties make
//! cycle-skipping *exact* (bit-identical statistics vs per-cycle
//! ticking), and both are load-bearing:
//!
//! 1. **No-op ticks.** Ticking a component before its true next event
//!    must not change its state. The only exception is bookkeeping that
//!    feeds no statistic: [`crate::cpu::CoreStats::cycles`] counts
//!    *ticked* cycles and is excluded from [`crate::sim::SimResult`].
//! 2. **Lower bound.** `next_event_at` must never exceed the true next
//!    event time. An early wake merely costs a wasted (no-op) tick; a
//!    late wake would reorder command issue and silently break the
//!    equivalence against [`LoopMode::StrictTick`].
//!
//! Under these properties the driver may jump from `now` to the global
//! minimum wake time: every skipped cycle is a no-op for every
//! component, so the state trajectory — and therefore every statistic in
//! [`crate::sim::SimResult`] — is identical to per-cycle ticking.
//!
//! One subtlety is hysteresis state inside the controller (the
//! write-drain flag), which the strict loop re-evaluates every bus
//! cycle and which can oscillate with *unchanged* queue occupancy (the
//! opportunistic-drain trigger flips it on with an empty read queue and
//! a small write backlog; the yield-back flips it off the next cycle).
//! The controller therefore treats any tick that would flip the flag as
//! an event in its own right: while a flip is pending it reports "hot"
//! and the kernel ticks per-cycle through the window, reproducing the
//! strict loop's flag trajectory — and write-issue parity — exactly.
//!
//! The strict loop is kept as [`LoopMode::StrictTick`] (CLI:
//! `--strict-tick`) and the differential test suite asserts identical
//! `SimResult`s across mechanisms, core counts, and workload profiles.

/// How the system loop advances time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopMode {
    /// Fast-forward to the minimum wake time (the event kernel).
    EventDriven,
    /// Tick every CPU cycle (the original loop; the differential oracle).
    StrictTick,
}

/// A simulation the event kernel can drive.
pub trait EventDriven {
    /// Mutate state at CPU cycle `now`. The clock is owned by the driver:
    /// implementations must not advance it.
    fn tick_at(&mut self, now: u64);
    /// Earliest CPU cycle `>= now` at which ticking could change state
    /// (the wake-time contract above). `u64::MAX` means "only an already
    /// scheduled wake of another component can unblock this one".
    /// Takes `&mut self` so implementations may serve the answer from an
    /// incrementally maintained structure (the
    /// [`crate::sim::wake::WakeIndex`] — a hierarchical timing wheel by
    /// default, with the lazily-pruned heap as the selectable oracle)
    /// instead of rescanning every component per jump.
    fn next_wake(&mut self, now: u64) -> u64;
}

/// Drive `sim` from `now` until `done` reports completion or the clock
/// reaches `end` (exclusive tick bound). Returns the final clock value.
///
/// The return value is identical between modes: `end` when the region
/// runs to its bound, or `t + 1` when `done` first holds after the tick
/// at cycle `t` (ticks are the only mutators, so `done` can only change
/// across a tick, and every tick that changes state is executed in both
/// modes).
pub fn advance<S: EventDriven>(
    sim: &mut S,
    mode: LoopMode,
    mut now: u64,
    end: u64,
    done: impl Fn(&S) -> bool,
) -> u64 {
    loop {
        if now >= end || done(sim) {
            return now;
        }
        sim.tick_at(now);
        now += 1;
        if done(sim) || now >= end {
            return now;
        }
        if mode == LoopMode::EventDriven {
            // Jump to the global minimum wake, clamped to `end - 1` so a
            // capped region still ends with `now == end` in both modes.
            now = sim.next_wake(now).min(end - 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scripted component: state changes only at the listed cycles; any
    /// other tick is a no-op. Mirrors the wake contract exactly.
    struct Scripted {
        events: Vec<u64>,
        fired: Vec<u64>,
        ticked: Vec<u64>,
    }

    impl EventDriven for Scripted {
        fn tick_at(&mut self, now: u64) {
            self.ticked.push(now);
            if self.events.contains(&now) {
                self.fired.push(now);
            }
        }
        fn next_wake(&mut self, now: u64) -> u64 {
            self.events
                .iter()
                .copied()
                .filter(|&e| e >= now)
                .min()
                .unwrap_or(u64::MAX)
        }
    }

    fn scripted(events: &[u64]) -> Scripted {
        Scripted { events: events.to_vec(), fired: Vec::new(), ticked: Vec::new() }
    }

    #[test]
    fn event_mode_fires_same_events_as_strict() {
        let events = [3u64, 4, 17, 40, 99];
        let mut a = scripted(&events);
        let mut b = scripted(&events);
        let ea = advance(&mut a, LoopMode::StrictTick, 0, 100, |_| false);
        let eb = advance(&mut b, LoopMode::EventDriven, 0, 100, |_| false);
        assert_eq!(ea, eb);
        assert_eq!(a.fired, b.fired);
        assert_eq!(a.fired, events.to_vec());
    }

    #[test]
    fn event_mode_skips_idle_cycles() {
        let mut s = scripted(&[5, 50]);
        advance(&mut s, LoopMode::EventDriven, 0, 1000, |_| false);
        // Cycle 0 is always ticked; afterwards only events plus the final
        // clamped tick at end - 1.
        assert!(s.ticked.len() < 10, "ticked {} cycles", s.ticked.len());
        assert!(s.ticked.contains(&5) && s.ticked.contains(&50));
    }

    #[test]
    fn done_terminates_with_identical_clock() {
        let events = [2u64, 8, 30];
        let mut a = scripted(&events);
        let mut b = scripted(&events);
        let done = |s: &Scripted| s.fired.len() == 2;
        let ea = advance(&mut a, LoopMode::StrictTick, 0, 1000, done);
        let eb = advance(&mut b, LoopMode::EventDriven, 0, 1000, done);
        assert_eq!(ea, 9); // tick at 8 fired the second event
        assert_eq!(ea, eb);
        assert_eq!(a.fired, b.fired);
    }

    #[test]
    fn capped_region_ends_exactly_at_end() {
        let mut a = scripted(&[2]);
        let mut b = scripted(&[2]);
        let ea = advance(&mut a, LoopMode::StrictTick, 0, 64, |_| false);
        let eb = advance(&mut b, LoopMode::EventDriven, 0, 64, |_| false);
        assert_eq!(ea, 64);
        assert_eq!(eb, 64);
    }

    #[test]
    fn empty_region_is_a_noop() {
        let mut s = scripted(&[0]);
        assert_eq!(advance(&mut s, LoopMode::EventDriven, 5, 5, |_| false), 5);
        assert!(s.ticked.is_empty());
    }
}
