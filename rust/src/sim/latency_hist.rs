//! Log-bucketed per-request latency histogram (DESIGN.md §14).
//!
//! Fixed geometry: 64 octaves × 16 sub-buckets = 1024 counters, flat in
//! one pre-sized array, so recording is O(1) with **zero steady-state
//! allocation** and merging is element-wise counter addition. Values
//! below 16 land in exact unit buckets (octaves 0–3 degenerate to the
//! identity, so buckets 16–63 are never produced); from 16 upward each
//! octave `[2^k, 2^(k+1))` splits into 16 sub-buckets, bounding the
//! relative quantile error at 1/16 ≈ 6.25% while covering the full
//! `u64` range (`u64::MAX` maps to the last bucket, 1023).
//!
//! Quantiles use pure integer rank arithmetic (`rank = ceil(q·n)`,
//! computed in `u128`) and report the **lower bound** of the bucket the
//! cumulative count crosses the rank in — a deterministic, conservative
//! estimate that is bit-identical however the per-channel histograms
//! were merged, because counter addition is commutative. Merging is
//! nonetheless performed in canonical (ascending channel) order, the
//! same discipline every other cross-channel reduction in
//! [`crate::sim::system::System::collect`] follows.

use crate::sim::checkpoint::{Dec, Enc};

/// Octaves (power-of-two magnitude classes) covered by the geometry.
pub const OCTAVES: usize = 64;
/// Sub-buckets per octave.
pub const SUBS: usize = 16;
/// Total counters — fixed for the lifetime of the format.
pub const BUCKETS: usize = OCTAVES * SUBS;

/// Bucket index for a latency value. Exact below 16; log-bucketed with
/// 16 sub-buckets per octave above.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        v as usize
    } else {
        let oct = 63 - v.leading_zeros() as usize; // >= 4
        oct * SUBS + ((v >> (oct - 4)) & 0xF) as usize
    }
}

/// Smallest value mapping to bucket `b` (the quantile estimate the
/// histogram reports). Total over all 1024 indices; indices 16–63 are
/// never produced by [`bucket_index`] but still map somewhere sane.
#[inline]
pub fn bucket_lower_bound(b: usize) -> u64 {
    debug_assert!(b < BUCKETS);
    if b < SUBS * 4 {
        // Octaves 0–3: the exact region (only 0–15 are ever produced).
        b as u64
    } else {
        let oct = (b / SUBS) as u32;
        let sub = (b % SUBS) as u64;
        (1u64 << oct) | (sub << (oct - 4))
    }
}

/// Per-request latency histogram: fixed 1024-counter geometry plus the
/// exact sum/max/count needed for the mean and extremes.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHist {
    counts: Vec<u64>,
    samples: u64,
    sum: u64,
    max: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// The single allocation this type ever performs.
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], samples: 0, sum: 0, max: 0 }
    }

    /// Record one latency sample. O(1), allocation-free.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.samples += 1;
        self.sum = self.sum.wrapping_add(v);
        self.max = self.max.max(v);
    }

    /// Element-wise merge of `other` into `self`. Callers merge shards
    /// in canonical (ascending channel) order; the result is invariant
    /// to that order because addition commutes, but the discipline keeps
    /// every cross-channel reduction uniform.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.samples += other.samples;
        self.sum = self.sum.wrapping_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Zero every counter (stats reset at the warmup boundary).
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.samples = 0;
        self.sum = 0;
        self.max = 0;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of the recorded values (exact sum, not bucket-approximated).
    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.sum as f64 / self.samples as f64
        }
    }

    /// Quantile `num/den` (e.g. 999/1000 for p99.9) as the lower bound
    /// of the bucket containing the rank-`ceil(q·n)` sample. Integer
    /// arithmetic throughout — bit-stable across platforms and merge
    /// orders. Returns 0 on an empty histogram.
    pub fn quantile(&self, num: u64, den: u64) -> u64 {
        debug_assert!(num <= den && den > 0);
        if self.samples == 0 {
            return 0;
        }
        let rank =
            ((self.samples as u128 * num as u128 + den as u128 - 1) / den as u128).max(1) as u64;
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_lower_bound(b);
            }
        }
        // Unreachable when counters and `samples` agree; fall back to max.
        self.max
    }

    /// The percentile/mean digest exported into
    /// [`crate::sim::stats::SimResult::latency`]; `None` when nothing
    /// was recorded (e.g. a write-only window).
    pub fn summary(&self) -> Option<LatencySummary> {
        if self.samples == 0 {
            return None;
        }
        Some(LatencySummary {
            p50: self.quantile(50, 100),
            p95: self.quantile(95, 100),
            p99: self.quantile(99, 100),
            p999: self.quantile(999, 1000),
            mean: self.mean(),
            max: self.max,
            samples: self.samples,
        })
    }

    /// Checkpoint encoding: sparse `(bucket, count)` pairs — warmup-phase
    /// histograms touch a handful of octaves, so sparse beats 1024 dense
    /// words — then the exact aggregates.
    pub fn export_state(&self, enc: &mut Enc) {
        let nonzero = self.counts.iter().filter(|&&c| c != 0).count();
        enc.usize(nonzero);
        for (b, &c) in self.counts.iter().enumerate() {
            if c != 0 {
                enc.usize(b);
                enc.u64(c);
            }
        }
        enc.u64(self.samples);
        enc.u64(self.sum);
        enc.u64(self.max);
    }

    /// Overwrite from [`LatencyHist::export_state`] words. `None` on any
    /// out-of-range bucket or truncation (the stream is corrupt).
    pub fn import_state(&mut self, dec: &mut Dec) -> Option<()> {
        self.counts.fill(0);
        let nonzero = dec.usize()?;
        for _ in 0..nonzero {
            let b = dec.usize()?;
            if b >= BUCKETS {
                return None;
            }
            self.counts[b] = dec.u64()?;
        }
        self.samples = dec.u64()?;
        self.sum = dec.u64()?;
        self.max = dec.u64()?;
        Some(())
    }
}

/// Percentile digest of one run's read-latency distribution, in DRAM bus
/// cycles. Percentiles are bucket lower bounds (≤ 6.25% relative error);
/// `mean` and `max` are exact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub p999: u64,
    pub mean: f64,
    pub max: u64,
    pub samples: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        for v in 0..16u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_lower_bound(bucket_index(v)), v);
        }
    }

    #[test]
    fn bucket_bounds_bracket_every_magnitude() {
        // lower_bound(bucket(v)) <= v, and the next bucket's bound is
        // above v — across the whole u64 range including the extremes.
        let probes = [
            16u64,
            17,
            31,
            32,
            100,
            1000,
            65_535,
            1 << 40,
            (1 << 40) + 12345,
            u64::MAX / 3,
            u64::MAX - 1,
            u64::MAX,
        ];
        for v in probes {
            let b = bucket_index(v);
            assert!(b < BUCKETS, "{v}: bucket {b} out of range");
            let lo = bucket_lower_bound(b);
            assert!(lo <= v, "{v}: lower bound {lo} exceeds value");
            if b + 1 < BUCKETS && b >= 64 {
                assert!(bucket_lower_bound(b + 1) > v, "{v}: not bracketed");
            }
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1, "top value is the last bucket");
    }

    #[test]
    fn relative_error_is_bounded() {
        // The reported quantile (bucket lower bound) underestimates by
        // at most one sub-bucket width = 1/16 of the octave base.
        for v in [100u64, 999, 12_345, 1 << 33] {
            let lo = bucket_lower_bound(bucket_index(v));
            let err = (v - lo) as f64 / v as f64;
            assert!(err < 1.0 / 16.0 + 1e-12, "{v}: error {err}");
        }
    }

    #[test]
    fn quantiles_of_a_known_distribution() {
        let mut h = LatencyHist::new();
        // 100 samples: 1..=100 (all exact region is too narrow, use
        // values small enough that bucketing error < 1 sub-bucket).
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.samples(), 100);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-12);
        // p50 = 50th sample = 50; bucket lower bound of 50 is 48.
        assert_eq!(h.quantile(50, 100), bucket_lower_bound(bucket_index(50)));
        // p99 = 99th sample = 99 -> its bucket's lower bound (96).
        assert_eq!(h.quantile(99, 100), bucket_lower_bound(bucket_index(99)));
        // p100 = max's bucket.
        assert_eq!(h.quantile(1, 1), bucket_lower_bound(bucket_index(100)));
        // Minimum rank is clamped to 1, never 0.
        assert_eq!(h.quantile(0, 100), bucket_lower_bound(bucket_index(1)));
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        let mut whole = LatencyHist::new();
        for i in 0..1000u64 {
            let v = (i * 2_654_435_761) % 100_000;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, whole);
        assert_eq!(merged.summary(), whole.summary());
        // Merge order cannot matter (addition commutes).
        let mut rev = b.clone();
        rev.merge(&a);
        assert_eq!(rev, whole);
    }

    #[test]
    fn empty_histogram_has_no_summary() {
        let h = LatencyHist::new();
        assert_eq!(h.summary(), None);
        assert_eq!(h.quantile(99, 100), 0);
        let mut c = LatencyHist::new();
        c.record(5);
        c.clear();
        assert_eq!(c.summary(), None);
        assert_eq!(c, h, "clear restores the empty state");
    }

    #[test]
    fn checkpoint_round_trip_including_extremes() {
        let mut h = LatencyHist::new();
        for v in [0u64, 1, 15, 16, 17, 1000, 1 << 40, u64::MAX] {
            h.record(v);
            h.record(v);
        }
        let mut enc = Enc::new();
        h.export_state(&mut enc);
        let words = enc.into_words();
        let mut back = LatencyHist::new();
        back.record(77); // stale state must be overwritten
        let mut dec = Dec::new(&words);
        back.import_state(&mut dec).unwrap();
        assert!(dec.finished());
        assert_eq!(back, h);
        // Corrupt bucket index fails cleanly.
        let mut bad = words.clone();
        bad[1] = BUCKETS as u64; // first sparse pair's bucket
        assert!(LatencyHist::new().import_state(&mut Dec::new(&bad)).is_none());
    }

    #[test]
    fn summary_fields_are_consistent() {
        let mut h = LatencyHist::new();
        for v in [10u64, 20, 30, 40, 5000] {
            h.record(v);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.samples, 5);
        assert_eq!(s.max, 5000);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.p999);
        assert!(s.p999 <= s.max);
        assert!((s.mean - 1020.0).abs() < 1e-12);
    }
}
