//! `charge_meta.json` parser — a minimal flat-JSON reader (the build is
//! offline; no serde). The file is machine-written by `aot.py` with flat
//! `"key": value` pairs plus one string list, which is all we parse.

use std::collections::HashMap;
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

/// Metadata emitted by the AOT build describing the artifact shapes and
/// the calibrated circuit constants.
#[derive(Debug, Clone)]
pub struct ChargeMeta {
    pub numbers: HashMap<String, f64>,
    pub entry_points: Vec<String>,
}

impl ChargeMeta {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Parse a flat JSON object of numbers and one string array.
    pub fn parse(text: &str) -> Result<Self> {
        let mut numbers = HashMap::new();
        let mut entry_points = Vec::new();
        let body = text
            .trim()
            .strip_prefix('{')
            .and_then(|s| s.strip_suffix('}'))
            .context("not a JSON object")?;
        // Split top-level fields: the only nested structure is one [...]
        // array, so splitting on `",` / newline boundaries suffices when
        // we re-join array contents first.
        for raw in split_top_level(body) {
            let (key, value) = raw
                .split_once(':')
                .with_context(|| format!("bad field {raw:?}"))?;
            let key = key.trim().trim_matches('"').to_string();
            let value = value.trim();
            if let Some(list) = value.strip_prefix('[') {
                let list = list.strip_suffix(']').context("unterminated array")?;
                entry_points = list
                    .split(',')
                    .map(|s| s.trim().trim_matches('"').to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
            } else {
                let v: f64 = value
                    .trim_end_matches(',')
                    .parse()
                    .with_context(|| format!("bad number for {key}: {value:?}"))?;
                numbers.insert(key, v);
            }
        }
        if numbers.is_empty() {
            bail!("no numeric fields parsed");
        }
        Ok(Self { numbers, entry_points })
    }

    pub fn get(&self, key: &str) -> Result<f64> {
        self.numbers
            .get(key)
            .copied()
            .with_context(|| format!("missing meta key {key}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        Ok(self.get(key)? as usize)
    }
}

/// Split a JSON object body into `"key": value` chunks at top level
/// (commas inside `[...]` do not split).
fn split_top_level(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in body.chars() {
        match ch {
            '[' => {
                depth += 1;
                cur.push(ch);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(ch);
            }
            ',' if depth == 0 => {
                if !cur.trim().is_empty() {
                    out.push(cur.trim().to_string());
                }
                cur.clear();
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "vdd": 1.5,
  "table_n": 64,
  "tau_leak_ms": 124.95,
  "entry_points": [
    "bitline_sweep",
    "decay_curve",
    "latency_table"
  ],
  "dt_ns": 0.01
}"#;

    #[test]
    fn parses_numbers_and_list() {
        let m = ChargeMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.get("vdd").unwrap(), 1.5);
        assert_eq!(m.get_usize("table_n").unwrap(), 64);
        assert_eq!(m.get("dt_ns").unwrap(), 0.01);
        assert_eq!(m.entry_points.len(), 3);
        assert_eq!(m.entry_points[0], "bitline_sweep");
    }

    #[test]
    fn missing_key_errors() {
        let m = ChargeMeta::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(ChargeMeta::parse("not json").is_err());
        assert!(ChargeMeta::parse("{}").is_err());
    }

    #[test]
    fn parses_real_artifact_if_present() {
        let path = crate::runtime::default_artifacts_dir().join("charge_meta.json");
        if path.exists() {
            let m = ChargeMeta::load(&path).unwrap();
            assert_eq!(m.get("vdd").unwrap(), 1.5);
            assert!(m.get("a_per_ns").unwrap() > 0.0);
            assert!(m.entry_points.contains(&"latency_table".to_string()));
        }
    }
}
