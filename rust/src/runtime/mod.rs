//! Runtime bridge to the circuit layer.
//!
//! With the off-by-default `pjrt` feature, this module loads the
//! AOT-compiled circuit-layer artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the JAX/Pallas models) and executes them
//! from Rust via PJRT. Python never runs on the simulation path — this is
//! the only bridge to the circuit layer.
//!
//! The default build carries no `xla` dependency (the crate builds
//! offline with zero external deps); every caller goes through
//! [`charge_model::timing_table_or_analytic`], which falls back to the
//! pure-Rust analytic circuit model
//! ([`crate::latency::timing_table::circuit`]). Enabling `pjrt` requires
//! adding the `xla` dependency to `rust/Cargo.toml` (see the comment
//! there).

pub mod charge_model;
pub mod meta;

#[cfg(feature = "pjrt")]
pub use charge_model::ChargeModelRuntime;
pub use meta::ChargeMeta;

use std::path::PathBuf;

/// Default artifacts location (repo-root/artifacts — where
/// `python/compile/aot.py` emits), shared by the PJRT loader and the
/// artifact-presence probes in tests. `CARGO_MANIFEST_DIR` is the
/// `rust/` crate dir, hence the `..`.
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts")
}

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use std::path::{Path, PathBuf};

    use crate::error::{Context, Result};

    /// A compiled HLO artifact bound to a PJRT client.
    pub struct Artifact {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    /// PJRT CPU client + artifact loader.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client, dir: artifacts_dir.as_ref().to_path_buf() })
        }

        /// Default artifacts location (repo-root/rust/artifacts).
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        /// True if the artifact set exists (built by `make artifacts`).
        pub fn artifacts_present(&self) -> bool {
            self.dir.join("charge_meta.json").exists()
        }

        /// Load and compile `<name>.hlo.txt`.
        ///
        /// HLO *text* is the interchange format: jax >= 0.5 emits protos
        /// with 64-bit instruction ids that xla_extension 0.5.1 rejects;
        /// the text parser reassigns ids (see python/compile/aot.py).
        pub fn load(&self, name: &str) -> Result<Artifact> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            Ok(Artifact { exe, name: name.to_string() })
        }
    }

    impl Artifact {
        /// Execute with literal inputs; returns the tuple elements of the
        /// (return_tuple=True) result.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing {}", self.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            lit.to_tuple().context("decomposing result tuple")
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::{Artifact, Runtime};
