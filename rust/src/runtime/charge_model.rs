//! Typed wrappers over the circuit-layer artifacts: the latency table
//! (controller timing derivation), decay curves, and the Fig. 3 bitline
//! sweep — executed via PJRT at startup (never per-request) when the
//! `pjrt` feature is enabled. The default build resolves everything
//! through the pure-Rust analytic circuit model instead.

use crate::latency::timing_table::TimingTable;

#[cfg(feature = "pjrt")]
pub use pjrt_model::ChargeModelRuntime;

#[cfg(feature = "pjrt")]
mod pjrt_model {
    use crate::ensure;
    use crate::error::{Context, Result};
    use crate::latency::timing_table::TimingTable;

    use super::super::meta::ChargeMeta;
    use super::super::{Artifact, Runtime};

    /// All circuit-layer entry points, compiled and ready to execute.
    pub struct ChargeModelRuntime {
        pub meta: ChargeMeta,
        latency_table: Artifact,
        decay_curve: Artifact,
        bitline_sweep: Artifact,
        sense_latency: Artifact,
    }

    impl ChargeModelRuntime {
        /// Load every artifact from `rt`'s directory.
        pub fn load(rt: &Runtime) -> Result<Self> {
            let meta = ChargeMeta::load(rt.dir().join("charge_meta.json"))
                .context("loading charge_meta.json (run `make artifacts`)")?;
            Ok(Self {
                meta,
                latency_table: rt.load("latency_table")?,
                decay_curve: rt.load("decay_curve")?,
                bitline_sweep: rt.load("bitline_sweep")?,
                sense_latency: rt.load("sense_latency")?,
            })
        }

        /// Build the age -> (tRCD, tRAS) reduction [`TimingTable`] at the
        /// given temperature by executing the `latency_table` HLO.
        pub fn timing_table(&self, temp_c: f64, tck_ns: f64) -> Result<TimingTable> {
            let n = self.meta.get_usize("table_n")?;
            let ages = TimingTable::default_age_grid(n);
            let ages_f32: Vec<f32> = ages.iter().map(|&a| a as f32).collect();
            let t_in = xla::Literal::vec1(&ages_f32);
            let temp = xla::Literal::scalar(temp_c as f32);
            let out = self.latency_table.run(&[t_in, temp])?;
            ensure!(out.len() == 1, "latency_table returns one array");
            let flat: Vec<f32> = out[0].to_vec().context("latency_table output")?;
            ensure!(flat.len() == n * 2, "expected [{n},2] table");
            let reductions = (0..n)
                .map(|i| (flat[2 * i] as f64, flat[2 * i + 1] as f64))
                .collect();
            Ok(TimingTable::from_rows(ages, reductions, tck_ns))
        }

        /// Cell voltage after each retention time (seconds) at `temp_c`.
        pub fn decay_curve(&self, t_ret_s: &[f32], temp_c: f64) -> Result<Vec<f32>> {
            let n = self.meta.get_usize("table_n")?;
            ensure!(t_ret_s.len() == n, "decay_curve expects exactly {n} points");
            let out = self.decay_curve.run(&[
                xla::Literal::vec1(t_ret_s),
                xla::Literal::scalar(temp_c as f32),
            ])?;
            out[0].to_vec().context("decay_curve output")
        }

        /// Fig. 3: bitline-voltage trajectories for a family of initial
        /// cell voltages. Returns (samples_per_lane, flattened row-major
        /// data).
        pub fn bitline_sweep(&self, v_cell0: &[f32]) -> Result<(usize, Vec<f32>)> {
            let b = self.meta.get_usize("traj_batch")?;
            ensure!(v_cell0.len() == b, "bitline_sweep expects exactly {b} lanes");
            let out = self.bitline_sweep.run(&[xla::Literal::vec1(v_cell0)])?;
            let data: Vec<f32> = out[0].to_vec().context("bitline_sweep output")?;
            let samples = self.meta.get_usize("traj_samples")?;
            ensure!(data.len() == b * samples);
            Ok((samples, data))
        }

        /// Raw (t_ready, t_restore) in ns for a batch of initial voltages.
        pub fn sense_latency(&self, v_cell0: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
            let b = self.meta.get_usize("latency_batch")?;
            ensure!(v_cell0.len() == b, "sense_latency expects exactly {b} lanes");
            let out = self.sense_latency.run(&[xla::Literal::vec1(v_cell0)])?;
            ensure!(out.len() == 2);
            Ok((
                out[0].to_vec().context("sense_latency t_ready")?,
                out[1].to_vec().context("sense_latency t_restore")?,
            ))
        }
    }
}

/// Load the timing table from artifacts (pjrt builds only), falling back
/// to the pure-Rust analytic model when artifacts are absent or the
/// `pjrt` feature is off (e.g. plain `cargo test`).
/// Returns (table, true-if-from-artifacts).
pub fn timing_table_or_analytic(temp_c: f64, tck_ns: f64) -> (TimingTable, bool) {
    #[cfg(feature = "pjrt")]
    {
        use crate::ensure;
        use crate::error::Result;
        let try_rt = || -> Result<TimingTable> {
            let rt = super::Runtime::new(super::default_artifacts_dir())?;
            ensure!(rt.artifacts_present(), "artifacts not built");
            ChargeModelRuntime::load(&rt)?.timing_table(temp_c, tck_ns)
        };
        if let Ok(t) = try_rt() {
            return (t, true);
        }
    }
    (TimingTable::analytic(64, temp_c, tck_ns), false)
}
