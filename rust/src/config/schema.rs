//! Typed dotted-path registry over every [`SystemConfig`] field — the
//! single mechanism behind `--set path=value` overrides, scenario-spec
//! `set`/axis parameters, and `params` introspection (DESIGN.md §10).
//!
//! The registry is built by **exhaustively destructuring**
//! `SystemConfig::default()` in the same style as
//! [`SystemConfig::fingerprint`]: every field of the config and of every
//! nested struct is bound by name (no `..` rest patterns), and every
//! binding is consumed as its parameter's recorded default. Adding a
//! config field without deciding how it is exposed therefore breaks the
//! build — a removed/renamed field fails the destructure outright, and a
//! new field trips `unused_variables`, which CI compiles with
//! `-D warnings`. A field that must *not* be settable can be bound to
//! `_` with a comment saying why (none currently qualify).
//!
//! Each [`ParamDef`] carries typed getter/setter function pointers;
//! values parse according to the field's Rust type (enums through the
//! same name tables the CLI uses), so a `--set` that parses is a `--set`
//! that applies. Tests in this module assert that every registered path
//! round-trips set→get and moves `SystemConfig::fingerprint()`.

use std::sync::OnceLock;

use crate::bail;
use crate::config::{
    ChargeCacheConfig, CheckpointConfig, CpuConfig, DramGeneration, DramOrg, FaultConfig,
    HcracPolicy, HcracSharing, McConfig, NuatConfig, RowPolicy, SampleConfig, SystemConfig, Timing,
    TrafficConfig, TrafficMode,
};
use crate::controller::{SchedulerKind, SCHEDULER_NAMES};
use crate::error::Result;
use crate::latency::{MechanismKind, MECHANISM_NAMES};
use crate::sim::engine::LoopMode;
use crate::sim::wake::WakeImpl;

/// Value shape of one parameter (drives parsing and `params` output).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    U64,
    Usize,
    F64,
    /// `u64` or the literal `none` (e.g. `measure_cycles`).
    OptU64,
    /// Named choice; the canonical names (parsing also accepts the
    /// aliases of the underlying name table).
    Enum(&'static [&'static str]),
}

impl ParamKind {
    /// Short type tag for `params` output.
    pub fn describe(&self) -> String {
        match self {
            ParamKind::U64 => "u64".to_string(),
            ParamKind::Usize => "usize".to_string(),
            ParamKind::F64 => "f64".to_string(),
            ParamKind::OptU64 => "u64|none".to_string(),
            ParamKind::Enum(choices) => choices.join("|"),
        }
    }
}

/// Numeric config field: formatting, parsing, and its [`ParamKind`] tag.
trait Scalar: Sized {
    const KIND: ParamKind;
    fn fmt(&self) -> String;
    fn parse_scalar(s: &str) -> Option<Self>;
}

impl Scalar for u64 {
    const KIND: ParamKind = ParamKind::U64;
    fn fmt(&self) -> String {
        self.to_string()
    }
    fn parse_scalar(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl Scalar for usize {
    const KIND: ParamKind = ParamKind::Usize;
    fn fmt(&self) -> String {
        self.to_string()
    }
    fn parse_scalar(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl Scalar for f64 {
    const KIND: ParamKind = ParamKind::F64;
    fn fmt(&self) -> String {
        // `Display` prints the shortest string that round-trips the bit
        // pattern, so get→set→get is exact.
        format!("{self}")
    }
    fn parse_scalar(s: &str) -> Option<Self> {
        s.parse().ok().filter(|v: &f64| v.is_finite())
    }
}

/// Enum config field: canonical names plus tolerated aliases.
trait Choice: Sized + Copy {
    const CHOICES: &'static [&'static str];
    fn to_name(self) -> &'static str;
    fn from_name(s: &str) -> Option<Self>;
}

impl Choice for bool {
    const CHOICES: &'static [&'static str] = &["off", "on"];
    fn to_name(self) -> &'static str {
        if self {
            "on"
        } else {
            "off"
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => Some(true),
            "off" | "false" | "0" => Some(false),
            _ => None,
        }
    }
}

impl Choice for RowPolicy {
    const CHOICES: &'static [&'static str] = &["open", "closed"];
    fn to_name(self) -> &'static str {
        match self {
            RowPolicy::Open => "open",
            RowPolicy::Closed => "closed",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "open" => Some(RowPolicy::Open),
            "closed" => Some(RowPolicy::Closed),
            _ => None,
        }
    }
}

impl Choice for SchedulerKind {
    const CHOICES: &'static [&'static str] = &SCHEDULER_NAMES;
    fn to_name(self) -> &'static str {
        self.name()
    }
    fn from_name(s: &str) -> Option<Self> {
        SchedulerKind::parse(s)
    }
}

impl Choice for MechanismKind {
    const CHOICES: &'static [&'static str] = &MECHANISM_NAMES;
    fn to_name(self) -> &'static str {
        self.name()
    }
    fn from_name(s: &str) -> Option<Self> {
        MechanismKind::parse(s)
    }
}

impl Choice for HcracSharing {
    const CHOICES: &'static [&'static str] = &["per-core", "shared"];
    fn to_name(self) -> &'static str {
        match self {
            HcracSharing::PerCore => "per-core",
            HcracSharing::Shared => "shared",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "per-core" | "percore" => Some(HcracSharing::PerCore),
            "shared" => Some(HcracSharing::Shared),
            _ => None,
        }
    }
}

impl Choice for HcracPolicy {
    const CHOICES: &'static [&'static str] = &["lru", "bip"];
    fn to_name(self) -> &'static str {
        match self {
            HcracPolicy::Lru => "lru",
            HcracPolicy::Bip => "bip",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(HcracPolicy::Lru),
            "bip" => Some(HcracPolicy::Bip),
            _ => None,
        }
    }
}

impl Choice for DramGeneration {
    const CHOICES: &'static [&'static str] = &["ddr3-1600", "ddr3-1333", "ddr4-2400"];
    fn to_name(self) -> &'static str {
        match self {
            DramGeneration::Ddr3_1600 => "ddr3-1600",
            DramGeneration::Ddr3_1333 => "ddr3-1333",
            DramGeneration::Ddr4_2400 => "ddr4-2400",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ddr3-1600" | "ddr3_1600" => Some(DramGeneration::Ddr3_1600),
            "ddr3-1333" | "ddr3_1333" => Some(DramGeneration::Ddr3_1333),
            "ddr4-2400" | "ddr4_2400" | "ddr4" => Some(DramGeneration::Ddr4_2400),
            _ => None,
        }
    }
}

impl Choice for LoopMode {
    const CHOICES: &'static [&'static str] = &["event-driven", "strict-tick"];
    fn to_name(self) -> &'static str {
        match self {
            LoopMode::EventDriven => "event-driven",
            LoopMode::StrictTick => "strict-tick",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "event-driven" | "event" => Some(LoopMode::EventDriven),
            "strict-tick" | "strict" => Some(LoopMode::StrictTick),
            _ => None,
        }
    }
}

impl Choice for TrafficMode {
    const CHOICES: &'static [&'static str] = &["closed", "det", "poisson", "burst", "mmpp"];
    fn to_name(self) -> &'static str {
        match self {
            TrafficMode::Closed => "closed",
            TrafficMode::Det => "det",
            TrafficMode::Poisson => "poisson",
            TrafficMode::Burst => "burst",
            TrafficMode::Mmpp => "mmpp",
        }
    }
    fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "closed" => Some(TrafficMode::Closed),
            "det" | "deterministic" => Some(TrafficMode::Det),
            "poisson" => Some(TrafficMode::Poisson),
            "burst" | "onoff" => Some(TrafficMode::Burst),
            "mmpp" => Some(TrafficMode::Mmpp),
            _ => None,
        }
    }
}

impl Choice for WakeImpl {
    const CHOICES: &'static [&'static str] = &WakeImpl::NAMES;
    fn to_name(self) -> &'static str {
        self.name()
    }
    fn from_name(s: &str) -> Option<Self> {
        WakeImpl::parse(&s.to_ascii_lowercase())
    }
}

fn scalar_kind<T: Scalar>(_: &T) -> ParamKind {
    T::KIND
}

fn choice_kind<T: Choice>(_: &T) -> ParamKind {
    ParamKind::Enum(T::CHOICES)
}

fn set_scalar<T: Scalar>(slot: &mut T, path: &str, s: &str) -> Result<()> {
    match T::parse_scalar(s) {
        Some(v) => {
            *slot = v;
            Ok(())
        }
        None => bail!("invalid value {s:?} for {path}: expected {}", T::KIND.describe()),
    }
}

fn set_choice<T: Choice>(slot: &mut T, path: &str, s: &str) -> Result<()> {
    match T::from_name(s) {
        Some(v) => {
            *slot = v;
            Ok(())
        }
        None => bail!("invalid value {s:?} for {path} (one of: {})", T::CHOICES.join(" | ")),
    }
}

fn fmt_opt_u64(v: Option<u64>) -> String {
    match v {
        None => "none".to_string(),
        Some(c) => c.to_string(),
    }
}

fn parse_opt_u64(path: &str, s: &str) -> Result<Option<u64>> {
    if s.eq_ignore_ascii_case("none") {
        return Ok(None);
    }
    match s.parse() {
        Ok(v) => Ok(Some(v)),
        Err(_) => bail!("invalid value {s:?} for {path}: expected u64|none"),
    }
}

/// One registered parameter: dotted path, type, doc line, the default
/// config's value, and typed accessors.
pub struct ParamDef {
    pub path: &'static str,
    pub kind: ParamKind,
    pub doc: &'static str,
    /// `SystemConfig::default()`'s value, canonically formatted.
    pub default: String,
    getter: fn(&SystemConfig) -> String,
    setter: fn(&mut SystemConfig, &str) -> Result<()>,
}

impl ParamDef {
    /// Current value, canonically formatted.
    pub fn get(&self, cfg: &SystemConfig) -> String {
        (self.getter)(cfg)
    }

    /// Parse `value` and assign it.
    pub fn set(&self, cfg: &mut SystemConfig, value: &str) -> Result<()> {
        (self.setter)(cfg, value)
    }
}

/// Register one numeric field: `scalar_param!(defs, "mc.read_queue",
/// read_queue, "doc", mc.read_queue)` — the third argument is the
/// destructured default binding (consuming it is the build-breaking
/// coverage check), the last the field access path.
macro_rules! scalar_param {
    ($defs:expr, $path:literal, $default:ident, $doc:literal, $($f:ident).+ $(,)?) => {
        $defs.push(ParamDef {
            path: $path,
            kind: scalar_kind(&$default),
            doc: $doc,
            default: Scalar::fmt(&$default),
            getter: |c| Scalar::fmt(&c.$($f).+),
            setter: |c, s| set_scalar(&mut c.$($f).+, $path, s),
        });
    };
}

/// Register one enum field (see [`scalar_param!`]).
macro_rules! choice_param {
    ($defs:expr, $path:literal, $default:ident, $doc:literal, $($f:ident).+ $(,)?) => {
        $defs.push(ParamDef {
            path: $path,
            kind: choice_kind(&$default),
            doc: $doc,
            default: Choice::to_name($default).to_string(),
            getter: |c| Choice::to_name(c.$($f).+).to_string(),
            setter: |c, s| set_choice(&mut c.$($f).+, $path, s),
        });
    };
}

/// Build every [`ParamDef`] (see the module docs for the exhaustiveness
/// contract this function's destructuring enforces).
fn build() -> Vec<ParamDef> {
    let SystemConfig {
        dram,
        generation,
        timing,
        mc,
        cpu,
        chargecache,
        nuat,
        mechanism,
        temperature_c,
        insts_per_core,
        warmup_cpu_cycles,
        measure_cycles,
        seed,
        loop_mode,
        sim_threads,
        wake_impl,
        sample,
        checkpoint,
        fault,
        traffic,
    } = SystemConfig::default();
    let DramOrg { channels, ranks, banks, rows, row_bytes, line_bytes } = dram;
    let Timing {
        tck_ns,
        trcd,
        trp,
        tras,
        cl,
        cwl,
        tbl,
        tccd,
        trtp,
        twr,
        twtr,
        trrd,
        tfaw,
        trfc,
        trefi,
    } = timing;
    let McConfig {
        read_queue,
        write_queue,
        write_hi_watermark,
        write_lo_watermark,
        row_policy,
        scheduler,
    } = mc;
    let CpuConfig {
        cores,
        cpu_per_bus,
        issue_width,
        window,
        mshrs,
        llc_bytes,
        llc_ways,
        llc_hit_cycles,
    } = cpu;
    let ChargeCacheConfig {
        entries_per_core,
        ways,
        duration_ms,
        trcd_reduction,
        tras_reduction,
        sharing,
        policy,
    } = chargecache;
    let NuatConfig {
        window_ms,
        trcd_reduction: nuat_trcd_reduction,
        tras_reduction: nuat_tras_reduction,
    } = nuat;
    let SampleConfig { detail_cycles, period_cycles } = sample;
    let CheckpointConfig { warmup_fork, min_fork_group } = checkpoint;
    let FaultConfig {
        enabled: fault_enabled,
        weak_ppm,
        retention_pct,
        drift_interval_ms,
        drift_retention_pct,
        guard_band_pct,
        blacklist_threshold,
    } = fault;
    let TrafficConfig {
        mode: traffic_mode,
        rate_rps,
        burst_on_us,
        burst_off_us,
        mmpp_ratio,
        mmpp_sojourn_us,
        seed: traffic_seed,
    } = traffic;

    let mut defs: Vec<ParamDef> = Vec::new();
    // DramOrg.
    scalar_param!(defs, "dram.channels", channels, "Independent memory channels", dram.channels);
    scalar_param!(defs, "dram.ranks", ranks, "Ranks per channel", dram.ranks);
    scalar_param!(defs, "dram.banks", banks, "Banks per rank", dram.banks);
    scalar_param!(defs, "dram.rows", rows, "Rows per bank", dram.rows);
    scalar_param!(
        defs,
        "dram.row_bytes",
        row_bytes,
        "Row buffer (page) size in bytes",
        dram.row_bytes,
    );
    scalar_param!(defs, "dram.line_bytes", line_bytes, "Cache-line size in bytes", dram.line_bytes);
    // dram.generation: setting it applies the generation's full timing
    // table (later `timing.*` overrides still refine it), so it needs a
    // hand-rolled setter instead of `choice_param!`.
    defs.push(ParamDef {
        path: "dram.generation",
        kind: choice_kind(&generation),
        doc: "Named device generation; selecting one applies its timing table",
        default: Choice::to_name(generation).to_string(),
        getter: |c| Choice::to_name(c.generation).to_string(),
        setter: |c, s| {
            set_choice(&mut c.generation, "dram.generation", s)?;
            c.timing = c.generation.timing();
            Ok(())
        },
    });
    // Timing.
    scalar_param!(defs, "timing.tck_ns", tck_ns, "Bus clock period in nanoseconds", timing.tck_ns);
    scalar_param!(defs, "timing.trcd", trcd, "ACT-to-column delay (bus cycles)", timing.trcd);
    scalar_param!(defs, "timing.trp", trp, "Precharge time (bus cycles)", timing.trp);
    scalar_param!(defs, "timing.tras", tras, "ACT-to-PRE minimum (bus cycles)", timing.tras);
    scalar_param!(defs, "timing.cl", cl, "CAS (read) latency (bus cycles)", timing.cl);
    scalar_param!(defs, "timing.cwl", cwl, "CAS write latency (bus cycles)", timing.cwl);
    scalar_param!(defs, "timing.tbl", tbl, "Burst length (bus cycles)", timing.tbl);
    scalar_param!(defs, "timing.tccd", tccd, "Column-to-column delay (bus cycles)", timing.tccd);
    scalar_param!(defs, "timing.trtp", trtp, "Read-to-precharge (bus cycles)", timing.trtp);
    scalar_param!(defs, "timing.twr", twr, "Write recovery (bus cycles)", timing.twr);
    scalar_param!(defs, "timing.twtr", twtr, "Write-to-read turnaround (bus cycles)", timing.twtr);
    scalar_param!(
        defs,
        "timing.trrd",
        trrd,
        "ACT-to-ACT, different banks (bus cycles)",
        timing.trrd,
    );
    scalar_param!(defs, "timing.tfaw", tfaw, "Four-activate window (bus cycles)", timing.tfaw);
    scalar_param!(defs, "timing.trfc", trfc, "Refresh cycle time (bus cycles)", timing.trfc);
    scalar_param!(
        defs,
        "timing.trefi",
        trefi,
        "Average refresh interval (bus cycles)",
        timing.trefi,
    );
    // McConfig.
    scalar_param!(
        defs,
        "mc.read_queue",
        read_queue,
        "Read queue capacity per channel",
        mc.read_queue,
    );
    scalar_param!(
        defs,
        "mc.write_queue",
        write_queue,
        "Write queue capacity per channel",
        mc.write_queue,
    );
    scalar_param!(
        defs,
        "mc.write_hi_watermark",
        write_hi_watermark,
        "Start draining writes above this occupancy",
        mc.write_hi_watermark,
    );
    scalar_param!(
        defs,
        "mc.write_lo_watermark",
        write_lo_watermark,
        "Stop draining writes below this occupancy",
        mc.write_lo_watermark,
    );
    choice_param!(defs, "mc.row_policy", row_policy, "Row-buffer management policy", mc.row_policy);
    choice_param!(defs, "mc.scheduler", scheduler, "Memory-scheduler policy", mc.scheduler);
    // CpuConfig.
    scalar_param!(defs, "cpu.cores", cores, "Number of CPU cores", cpu.cores);
    scalar_param!(
        defs,
        "cpu.cpu_per_bus",
        cpu_per_bus,
        "CPU cycles per DRAM bus cycle",
        cpu.cpu_per_bus,
    );
    scalar_param!(
        defs,
        "cpu.issue_width",
        issue_width,
        "Instructions issued per CPU cycle",
        cpu.issue_width,
    );
    scalar_param!(defs, "cpu.window", window, "Reorder window entries", cpu.window);
    scalar_param!(defs, "cpu.mshrs", mshrs, "MSHRs per core", cpu.mshrs);
    scalar_param!(defs, "cpu.llc_bytes", llc_bytes, "Shared LLC size in bytes", cpu.llc_bytes);
    scalar_param!(defs, "cpu.llc_ways", llc_ways, "LLC associativity", cpu.llc_ways);
    scalar_param!(
        defs,
        "cpu.llc_hit_cycles",
        llc_hit_cycles,
        "LLC hit latency in CPU cycles",
        cpu.llc_hit_cycles,
    );
    // ChargeCacheConfig.
    scalar_param!(
        defs,
        "chargecache.entries_per_core",
        entries_per_core,
        "HCRAC entries per core",
        chargecache.entries_per_core,
    );
    scalar_param!(defs, "chargecache.ways", ways, "HCRAC associativity", chargecache.ways);
    scalar_param!(
        defs,
        "chargecache.duration_ms",
        duration_ms,
        "Caching duration in milliseconds",
        chargecache.duration_ms,
    );
    scalar_param!(
        defs,
        "chargecache.trcd_reduction",
        trcd_reduction,
        "tRCD reduction on an HCRAC hit (bus cycles)",
        chargecache.trcd_reduction,
    );
    scalar_param!(
        defs,
        "chargecache.tras_reduction",
        tras_reduction,
        "tRAS reduction on an HCRAC hit (bus cycles)",
        chargecache.tras_reduction,
    );
    choice_param!(
        defs,
        "chargecache.sharing",
        sharing,
        "Per-core replicas or one shared table",
        chargecache.sharing,
    );
    choice_param!(
        defs,
        "chargecache.policy",
        policy,
        "HCRAC insertion/replacement policy",
        chargecache.policy,
    );
    // NuatConfig.
    scalar_param!(
        defs,
        "nuat.window_ms",
        window_ms,
        "NUAT eligibility window after refresh (ms)",
        nuat.window_ms,
    );
    scalar_param!(
        defs,
        "nuat.trcd_reduction",
        nuat_trcd_reduction,
        "NUAT tRCD reduction (bus cycles)",
        nuat.trcd_reduction,
    );
    scalar_param!(
        defs,
        "nuat.tras_reduction",
        nuat_tras_reduction,
        "NUAT tRAS reduction (bus cycles)",
        nuat.tras_reduction,
    );
    // Top-level scalars.
    choice_param!(defs, "mechanism", mechanism, "Latency mechanism the simulation runs", mechanism);
    scalar_param!(
        defs,
        "temperature_c",
        temperature_c,
        "DRAM operating temperature (Celsius)",
        temperature_c,
    );
    scalar_param!(
        defs,
        "insts_per_core",
        insts_per_core,
        "Instructions to simulate per core",
        insts_per_core,
    );
    scalar_param!(
        defs,
        "warmup_cpu_cycles",
        warmup_cpu_cycles,
        "Warmup CPU cycles before measurement",
        warmup_cpu_cycles,
    );
    // measure_cycles: Option<u64> — the one field outside the two macro
    // shapes ("none" restores fixed-work measurement).
    defs.push(ParamDef {
        path: "measure_cycles",
        kind: ParamKind::OptU64,
        doc: "Fixed-time window in CPU cycles, or none for fixed-work",
        default: fmt_opt_u64(measure_cycles),
        getter: |c| fmt_opt_u64(c.measure_cycles),
        setter: |c, s| {
            c.measure_cycles = parse_opt_u64("measure_cycles", s)?;
            Ok(())
        },
    });
    scalar_param!(defs, "seed", seed, "RNG seed for trace generation", seed);
    choice_param!(
        defs,
        "loop_mode",
        loop_mode,
        "Event-driven kernel or per-cycle oracle",
        loop_mode,
    );
    scalar_param!(
        defs,
        "sim.threads",
        sim_threads,
        "Shard count for the channel-sharded event loop (0 = --sim-threads/PALLAS_SIM_THREADS)",
        sim_threads,
    );
    choice_param!(
        defs,
        "sim.wake_impl",
        wake_impl,
        "Wake-index implementation: timing wheel or heap oracle (auto = PALLAS_WAKE_IMPL)",
        wake_impl,
    );
    // SampleConfig.
    scalar_param!(
        defs,
        "sample.detail_cycles",
        detail_cycles,
        "Detailed cycles per sampling period (0 = full-detail run)",
        sample.detail_cycles,
    );
    scalar_param!(
        defs,
        "sample.period_cycles",
        period_cycles,
        "Sampling period in CPU cycles (detail + fast-forward)",
        sample.period_cycles,
    );
    // CheckpointConfig.
    choice_param!(
        defs,
        "checkpoint.warmup_fork",
        warmup_fork,
        "Fork sweep legs from a shared warmed-up snapshot",
        checkpoint.warmup_fork,
    );
    scalar_param!(
        defs,
        "checkpoint.min_fork_group",
        min_fork_group,
        "Legs sharing a warmup identity before a snapshot is built",
        checkpoint.min_fork_group,
    );
    // FaultConfig.
    choice_param!(
        defs,
        "fault.enabled",
        fault_enabled,
        "Deterministic retention-fault injection (seeded, off by default)",
        fault.enabled,
    );
    scalar_param!(
        defs,
        "fault.weak_ppm",
        weak_ppm,
        "Weak-row density in parts per million of row addresses",
        fault.weak_ppm,
    );
    scalar_param!(
        defs,
        "fault.retention_pct",
        retention_pct,
        "Weak row's true safe window as % of the caching duration",
        fault.retention_pct,
    );
    scalar_param!(
        defs,
        "fault.drift_interval_ms",
        drift_interval_ms,
        "Temperature-drift event period in milliseconds (0 = no drift)",
        fault.drift_interval_ms,
    );
    scalar_param!(
        defs,
        "fault.drift_retention_pct",
        drift_retention_pct,
        "Weak row's safe window during a hot drift interval (% of duration)",
        fault.drift_retention_pct,
    );
    scalar_param!(
        defs,
        "fault.guard_band_pct",
        guard_band_pct,
        "Blacklisted rows keep reduced timing only within this % of the duration",
        fault.guard_band_pct,
    );
    scalar_param!(
        defs,
        "fault.blacklist_threshold",
        blacklist_threshold,
        "Violations on one row before the mitigation blacklists it",
        fault.blacklist_threshold,
    );
    // TrafficConfig.
    choice_param!(
        defs,
        "traffic.mode",
        traffic_mode,
        "Open-loop arrival process, or closed for trace replay (default)",
        traffic.mode,
    );
    scalar_param!(
        defs,
        "traffic.rate_rps",
        rate_rps,
        "Aggregate offered load in requests/second (split over cores)",
        traffic.rate_rps,
    );
    scalar_param!(
        defs,
        "traffic.burst_on_us",
        burst_on_us,
        "Mean ON-window length in microseconds (burst mode)",
        traffic.burst_on_us,
    );
    scalar_param!(
        defs,
        "traffic.burst_off_us",
        burst_off_us,
        "Mean OFF-window length in microseconds (burst mode)",
        traffic.burst_off_us,
    );
    scalar_param!(
        defs,
        "traffic.mmpp_ratio",
        mmpp_ratio,
        "High-to-low rate ratio (MMPP mode)",
        traffic.mmpp_ratio,
    );
    scalar_param!(
        defs,
        "traffic.mmpp_sojourn_us",
        mmpp_sojourn_us,
        "Mean modulating-state sojourn in microseconds (MMPP mode)",
        traffic.mmpp_sojourn_us,
    );
    scalar_param!(
        defs,
        "traffic.seed",
        traffic_seed,
        "Seed for the SplitMix64 arrival streams (independent of `seed`)",
        traffic.seed,
    );
    defs
}

/// The parameter registry: every dotted path with its typed accessors.
pub struct Registry {
    defs: Vec<ParamDef>,
}

impl Registry {
    pub fn defs(&self) -> &[ParamDef] {
        &self.defs
    }

    /// Find `path`'s definition; unknown paths get an error that lists
    /// near matches (same prefix group or same leaf name) so a typo'd
    /// `--set` is a one-round-trip fix.
    pub fn lookup(&self, path: &str) -> Result<&ParamDef> {
        if let Some(d) = self.defs.iter().find(|d| d.path == path) {
            return Ok(d);
        }
        let head = path.split('.').next().unwrap_or(path);
        let leaf = path.rsplit('.').next().unwrap_or(path);
        let near: Vec<&str> = self
            .defs
            .iter()
            .map(|d| d.path)
            .filter(|&p| {
                p.starts_with(head) || p.rsplit('.').next().unwrap_or(p).contains(leaf)
            })
            .collect();
        if near.is_empty() {
            bail!("unknown parameter {path:?}; run `chargecache params` for the full list")
        }
        bail!(
            "unknown parameter {path:?}; close matches: {} (run `chargecache params` for all)",
            near.join(", ")
        )
    }

    pub fn set(&self, cfg: &mut SystemConfig, path: &str, value: &str) -> Result<()> {
        self.lookup(path)?.set(cfg, value)
    }

    pub fn get(&self, cfg: &SystemConfig, path: &str) -> Result<String> {
        Ok(self.lookup(path)?.get(cfg))
    }

    /// Apply `(path, value)` assignments in order (last wins on repeats).
    pub fn apply(&self, cfg: &mut SystemConfig, sets: &[(String, String)]) -> Result<()> {
        for (path, value) in sets {
            self.set(cfg, path, value)?;
        }
        Ok(())
    }
}

/// Parse one `PATH=VALUE` assignment (the `--set` argument form).
pub fn parse_assignment(s: &str) -> Result<(String, String)> {
    match s.split_once('=') {
        Some((p, v)) if !p.trim().is_empty() && !v.trim().is_empty() => {
            Ok((p.trim().to_string(), v.trim().to_string()))
        }
        _ => bail!("--set expects PATH=VALUE, got {s:?}"),
    }
}

/// The process-wide registry (built once; [`ParamDef`] accessors are
/// stateless function pointers, so sharing is free).
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry { defs: build() })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A valid value for `def` that differs from the default (drives the
    /// exhaustive round-trip test below).
    fn perturbed(def: &ParamDef) -> String {
        match def.kind {
            ParamKind::U64 | ParamKind::Usize => {
                (def.default.parse::<u64>().unwrap() + 1).to_string()
            }
            ParamKind::F64 => {
                let v: f64 = def.default.parse().unwrap();
                format!("{}", v * 2.0 + 1.0)
            }
            ParamKind::OptU64 => {
                if def.default == "none" {
                    "123456".to_string()
                } else {
                    "none".to_string()
                }
            }
            ParamKind::Enum(choices) => choices
                .iter()
                .find(|c| **c != def.default)
                .expect("every enum has >= 2 choices")
                .to_string(),
        }
    }

    #[test]
    fn every_param_round_trips_and_moves_the_fingerprint() {
        let reg = registry();
        // One def per config field (6 dram org + generation + 15 timing +
        // 6 mc + 8 cpu + 7 chargecache + 3 nuat + 2 sample +
        // 2 checkpoint + 7 fault + 7 traffic + 9 top-level incl.
        // sim.threads and sim.wake_impl). If this count moved, update it
        // together with the new field's ParamDef.
        assert_eq!(reg.defs().len(), 73, "registry must cover every SystemConfig field");
        let base = SystemConfig::default();
        for def in reg.defs() {
            // The recorded default is the default config's value.
            assert_eq!(def.get(&base), def.default, "{} default mismatch", def.path);
            let alt = perturbed(def);
            let mut cfg = base.clone();
            reg.set(&mut cfg, def.path, &alt).unwrap_or_else(|e| {
                panic!("setting {}={} failed: {}", def.path, alt, e)
            });
            // set→get round-trips canonically...
            assert_eq!(def.get(&cfg), alt, "{} did not round-trip", def.path);
            // ...and every parameter is simulation-relevant: it must move
            // the structural fingerprint that keys the result cache.
            assert_ne!(
                cfg.fingerprint(),
                base.fingerprint(),
                "{} did not change SystemConfig::fingerprint()",
                def.path
            );
        }
    }

    #[test]
    fn paths_are_unique_and_dotted() {
        let reg = registry();
        let mut seen = std::collections::HashSet::new();
        for def in reg.defs() {
            assert!(seen.insert(def.path), "duplicate path {}", def.path);
            assert!(!def.doc.is_empty(), "{} has no doc line", def.path);
        }
    }

    #[test]
    fn unknown_path_lists_near_matches() {
        let reg = registry();
        let err = reg.lookup("timing.trcdd").unwrap_err().to_string();
        assert!(err.contains("timing.trcd"), "no suggestion in {err:?}");
        let err = reg.lookup("chargecache.entries").unwrap_err().to_string();
        assert!(err.contains("chargecache.entries_per_core"), "{err:?}");
        assert!(reg.lookup("zzz.unknown").is_err());
    }

    #[test]
    fn enum_params_parse_aliases_and_reject_garbage() {
        let reg = registry();
        let mut cfg = SystemConfig::default();
        // Mechanism aliases come from the single name table.
        reg.set(&mut cfg, "mechanism", "chargecache").unwrap();
        assert_eq!(cfg.mechanism, MechanismKind::ChargeCache);
        assert_eq!(reg.get(&cfg, "mechanism").unwrap(), "cc");
        reg.set(&mut cfg, "mc.scheduler", "BLISS").unwrap();
        assert_eq!(cfg.mc.scheduler, SchedulerKind::Bliss);
        reg.set(&mut cfg, "loop_mode", "strict").unwrap();
        assert_eq!(cfg.loop_mode, LoopMode::StrictTick);
        let err = reg.set(&mut cfg, "mc.row_policy", "ajar").unwrap_err().to_string();
        assert!(err.contains("open | closed"), "choices missing from {err:?}");
        // Bool params take on/off with the usual aliases.
        reg.set(&mut cfg, "checkpoint.warmup_fork", "off").unwrap();
        assert!(!cfg.checkpoint.warmup_fork);
        reg.set(&mut cfg, "checkpoint.warmup_fork", "true").unwrap();
        assert!(cfg.checkpoint.warmup_fork);
        assert_eq!(reg.get(&cfg, "checkpoint.warmup_fork").unwrap(), "on");
        assert!(reg.set(&mut cfg, "checkpoint.warmup_fork", "maybe").is_err());
    }

    #[test]
    fn generation_applies_timing_preset() {
        let reg = registry();
        let mut cfg = SystemConfig::default();
        reg.set(&mut cfg, "dram.generation", "ddr3-1333").unwrap();
        assert_eq!(cfg.generation, DramGeneration::Ddr3_1333);
        assert_eq!(cfg.timing.trcd, 9);
        assert_eq!(cfg.timing.tck_ns, 1.5);
        // A later timing.* override refines the selected preset.
        reg.set(&mut cfg, "timing.trcd", "10").unwrap();
        assert_eq!(cfg.timing.trcd, 10);
        assert_eq!(cfg.timing.trp, 9, "other preset fields must survive");
        // The alias parses too.
        reg.set(&mut cfg, "dram.generation", "ddr4").unwrap();
        assert_eq!(reg.get(&cfg, "dram.generation").unwrap(), "ddr4-2400");
        assert_eq!(cfg.timing.trfc, 420);
    }

    #[test]
    fn option_and_float_values_parse() {
        let reg = registry();
        let mut cfg = SystemConfig::default();
        reg.set(&mut cfg, "measure_cycles", "5000000").unwrap();
        assert_eq!(cfg.measure_cycles, Some(5_000_000));
        reg.set(&mut cfg, "measure_cycles", "none").unwrap();
        assert_eq!(cfg.measure_cycles, None);
        reg.set(&mut cfg, "chargecache.duration_ms", "0.125").unwrap();
        assert_eq!(cfg.chargecache.duration_ms, 0.125);
        assert!(reg.set(&mut cfg, "temperature_c", "inf").is_err());
        assert!(reg.set(&mut cfg, "timing.trcd", "-3").is_err());
        assert!(reg.set(&mut cfg, "timing.trcd", "4.5").is_err());
    }

    #[test]
    fn assignment_syntax() {
        assert_eq!(
            parse_assignment("timing.trcd=12").unwrap(),
            ("timing.trcd".to_string(), "12".to_string())
        );
        assert_eq!(
            parse_assignment(" mc.scheduler = bliss ").unwrap().1,
            "bliss"
        );
        assert!(parse_assignment("noequals").is_err());
        assert!(parse_assignment("=v").is_err());
        assert!(parse_assignment("p=").is_err());
    }
}
