//! Typed simulation configuration (Table 1 of the paper as defaults).
//!
//! Every experiment is a [`SystemConfig`]; presets mirror the paper's
//! simulated system and the CLI layers overrides on top.

pub mod schema;

use crate::controller::SchedulerKind;
use crate::latency::MechanismKind;
use crate::sim::engine::LoopMode;
use crate::sim::wake::WakeImpl;

/// DRAM organization (DDR3-1600, Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct DramOrg {
    /// Independent memory channels (1 for single-core, 2 for 8-core runs).
    pub channels: usize,
    /// Ranks per channel.
    pub ranks: usize,
    /// Banks per rank.
    pub banks: usize,
    /// Rows per bank.
    pub rows: usize,
    /// Row buffer (page) size in bytes.
    pub row_bytes: usize,
    /// Cache-line size in bytes (column granularity of requests).
    pub line_bytes: usize,
}

impl DramOrg {
    /// Columns (cache lines) per row.
    pub fn cols(&self) -> usize {
        self.row_bytes / self.line_bytes
    }
    /// Total banks across the whole system.
    pub fn total_banks(&self) -> usize {
        self.channels * self.ranks * self.banks
    }
}

impl Default for DramOrg {
    fn default() -> Self {
        // Table 1: 1 rank/channel, 8 banks/rank, 64K rows/bank, 8KB rows.
        Self {
            channels: 1,
            ranks: 1,
            banks: 8,
            rows: 64 * 1024,
            row_bytes: 8 * 1024,
            line_bytes: 64,
        }
    }
}

/// DDR3-1600 timing parameters in DRAM bus cycles (800 MHz, tCK = 1.25 ns).
#[derive(Debug, Clone, PartialEq)]
pub struct Timing {
    /// Bus clock period in nanoseconds.
    pub tck_ns: f64,
    pub trcd: u64,
    pub trp: u64,
    pub tras: u64,
    /// CAS latency (read).
    pub cl: u64,
    /// CAS write latency.
    pub cwl: u64,
    /// Burst length in bus cycles (BL8 over DDR = 4).
    pub tbl: u64,
    /// Column-to-column delay.
    pub tccd: u64,
    /// Read-to-precharge.
    pub trtp: u64,
    /// Write recovery.
    pub twr: u64,
    /// Write-to-read turnaround (rank).
    pub twtr: u64,
    /// Activate-to-activate, different banks same rank.
    pub trrd: u64,
    /// Four-activate window.
    pub tfaw: u64,
    /// Refresh cycle time (all-bank REF duration).
    pub trfc: u64,
    /// Average refresh interval.
    pub trefi: u64,
}

impl Timing {
    /// tRC — activate-to-activate, same bank.
    pub fn trc(&self) -> u64 {
        self.tras + self.trp
    }
    /// Convert a duration in milliseconds to bus cycles.
    pub fn ms_to_cycles(&self, ms: f64) -> u64 {
        (ms * 1e6 / self.tck_ns) as u64
    }
    /// Convert bus cycles to nanoseconds.
    pub fn cycles_to_ns(&self, cycles: u64) -> f64 {
        cycles as f64 * self.tck_ns
    }
}

impl Default for Timing {
    fn default() -> Self {
        DramGeneration::Ddr3_1600.timing()
    }
}

/// Named DRAM device generations: registry-selectable timing presets
/// (`--set dram.generation=...`), so scaling claims can be made against
/// more than one device. Selecting a generation replaces the whole
/// [`Timing`] table; individual `timing.*` overrides still apply on top
/// when set *after* the generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramGeneration {
    /// DDR3-1600K (11-11-11-28) — Table 1 of the paper, the default.
    Ddr3_1600,
    /// DDR3-1333H (9-9-9-24) — the paper's companion speed grade.
    Ddr3_1333,
    /// DDR4-2400-class (17-17-17-39), 8Gb-class tRFC.
    Ddr4_2400,
}

impl DramGeneration {
    /// The full timing table for this generation, in bus cycles.
    pub fn timing(self) -> Timing {
        match self {
            // DDR3-1600K (11-11-11-28), 4Gb-class tRFC.
            DramGeneration::Ddr3_1600 => Timing {
                tck_ns: 1.25,
                trcd: 11,
                trp: 11,
                tras: 28,
                cl: 11,
                cwl: 8,
                tbl: 4,
                tccd: 4,
                trtp: 6,
                twr: 12,
                twtr: 6,
                trrd: 5,
                tfaw: 24,
                trfc: 208, // 260 ns
                trefi: 6240, // 7.8 us
            },
            // DDR3-1333H (9-9-9-24), 4Gb-class tRFC, tCK = 1.5 ns.
            DramGeneration::Ddr3_1333 => Timing {
                tck_ns: 1.5,
                trcd: 9,
                trp: 9,
                tras: 24,
                cl: 9,
                cwl: 7,
                tbl: 4,
                tccd: 4,
                trtp: 5,
                twr: 10,
                twtr: 5,
                trrd: 4,
                tfaw: 20,
                trfc: 174, // 260 ns
                trefi: 5200, // 7.8 us
            },
            // DDR4-2400 (17-17-17-39), 8Gb-class tRFC, tCK = 0.833 ns.
            DramGeneration::Ddr4_2400 => Timing {
                tck_ns: 0.833,
                trcd: 17,
                trp: 17,
                tras: 39,
                cl: 17,
                cwl: 12,
                tbl: 4,
                tccd: 6,
                trtp: 9,
                twr: 18,
                twtr: 9,
                trrd: 6,
                tfaw: 26,
                trfc: 420, // 350 ns
                trefi: 9363, // 7.8 us
            },
        }
    }
}

/// Row-buffer management policy (Table 1: open for 1-core, closed for MP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowPolicy {
    /// Leave the row open after column accesses (FR-FCFS exploits hits).
    Open,
    /// Auto-precharge after the last queued hit to the open row.
    Closed,
}

/// Memory-controller parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct McConfig {
    /// Read queue capacity per channel.
    pub read_queue: usize,
    /// Write queue capacity per channel.
    pub write_queue: usize,
    /// Start draining writes above this occupancy.
    pub write_hi_watermark: usize,
    /// Stop draining writes below this occupancy.
    pub write_lo_watermark: usize,
    pub row_policy: RowPolicy,
    /// Scheduling policy (CLI: `--scheduler fr-fcfs|fcfs|bliss`).
    pub scheduler: SchedulerKind,
}

impl Default for McConfig {
    fn default() -> Self {
        Self {
            read_queue: 64,
            write_queue: 64,
            write_hi_watermark: 48,
            write_lo_watermark: 16,
            row_policy: RowPolicy::Open,
            scheduler: SchedulerKind::FrFcfs,
        }
    }
}

/// CPU core / cache parameters (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuConfig {
    pub cores: usize,
    /// CPU cycles per DRAM bus cycle (4 GHz / 800 MHz = 5).
    pub cpu_per_bus: u64,
    /// Issue width (instructions per CPU cycle).
    pub issue_width: usize,
    /// Reorder window entries.
    pub window: usize,
    /// MSHRs per core.
    pub mshrs: usize,
    /// Shared LLC size in bytes.
    pub llc_bytes: usize,
    pub llc_ways: usize,
    /// LLC hit latency in CPU cycles.
    pub llc_hit_cycles: u64,
}

impl Default for CpuConfig {
    fn default() -> Self {
        Self {
            cores: 1,
            cpu_per_bus: 5,
            issue_width: 3,
            window: 128,
            mshrs: 8,
            llc_bytes: 4 * 1024 * 1024,
            llc_ways: 16,
            llc_hit_cycles: 33,
        }
    }
}

/// HCRAC organization: the paper's per-core replicas, or the shared
/// single-table design its footnote 3 leaves to future work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HcracSharing {
    /// One private table per core (paper default).
    PerCore,
    /// One table shared by all cores (same total capacity): any core's
    /// precharge benefits every core's later activation.
    Shared,
}

/// HCRAC insertion/replacement policy (the paper points at reuse-aware
/// policies [35,117,130,148] as future work for thrashing workloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HcracPolicy {
    /// Plain LRU (paper default).
    Lru,
    /// Bimodal insertion (BIP): most insertions land in the LRU way
    /// without promotion, protecting the table from thrashing row streams
    /// (mcf/omnetpp-class reuse distances).
    Bip,
}

/// ChargeCache (HCRAC) parameters (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct ChargeCacheConfig {
    /// Entries per core (per channel replica).
    pub entries_per_core: usize,
    pub ways: usize,
    /// Caching duration in milliseconds.
    pub duration_ms: f64,
    /// tRCD reduction in bus cycles on an HCRAC hit.
    pub trcd_reduction: u64,
    /// tRAS reduction in bus cycles on an HCRAC hit.
    pub tras_reduction: u64,
    pub sharing: HcracSharing,
    pub policy: HcracPolicy,
}

impl Default for ChargeCacheConfig {
    fn default() -> Self {
        Self {
            entries_per_core: 128,
            ways: 2,
            duration_ms: 1.0,
            trcd_reduction: 4,
            tras_reduction: 8,
            sharing: HcracSharing::PerCore,
            policy: HcracPolicy::Lru,
        }
    }
}

/// NUAT comparison mechanism parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct NuatConfig {
    /// Window after a refresh during which a row counts as highly charged.
    pub window_ms: f64,
    pub trcd_reduction: u64,
    pub tras_reduction: u64,
}

impl Default for NuatConfig {
    fn default() -> Self {
        Self {
            window_ms: 1.0,
            trcd_reduction: 4,
            tras_reduction: 8,
        }
    }
}

/// SimPoint-style interval sampling of the measured region
/// ([`crate::sim::sample`]). Off by default; requires fixed-time mode
/// (`measure_cycles`).
#[derive(Debug, Clone, PartialEq)]
pub struct SampleConfig {
    /// CPU cycles simulated in detail at the start of each period
    /// (registry: `sample.detail_cycles`; 0 disables sampling).
    pub detail_cycles: u64,
    /// Period length in CPU cycles: detail interval + functional
    /// fast-forward (registry: `sample.period_cycles`).
    pub period_cycles: u64,
}

impl Default for SampleConfig {
    fn default() -> Self {
        Self { detail_cycles: 0, period_cycles: 1_000_000 }
    }
}

/// Warmup-checkpoint forking in the job graph
/// ([`crate::coordinator::jobs`]): sweep legs whose warmup identities
/// agree simulate warmup once and fork.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointConfig {
    /// Fork sweep legs from a shared warmed-up snapshot (registry:
    /// `checkpoint.warmup_fork`).
    pub warmup_fork: bool,
    /// Minimum number of legs sharing a warmup identity before a
    /// snapshot is worth taking (registry: `checkpoint.min_fork_group`).
    pub min_fork_group: usize,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        Self { warmup_fork: true, min_fork_group: 2 }
    }
}

/// Deterministic retention-fault injection ([`crate::controller::fault`]).
/// Off by default; when enabled, a seeded per-row hash assigns
/// weak-retention profiles whose true safe window is shorter than the
/// ChargeCache caching duration, so a reduced-timing ACT past that window
/// raises a detectable timing violation. Everything derives from
/// `(seed, row, cycle)` hashing — no shared RNG stream — so sharded runs
/// stay bit-identical to single-threaded ones.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch (registry: `fault.enabled`). With this off the
    /// fault path is never consulted and results are bit-identical to a
    /// build without the subsystem.
    pub enabled: bool,
    /// Weak-row density in parts per million of row addresses
    /// (registry: `fault.weak_ppm`).
    pub weak_ppm: u64,
    /// A weak row's true safe window as a percentage of the ChargeCache
    /// caching duration (registry: `fault.retention_pct`).
    pub retention_pct: u64,
    /// Temperature-drift event period in milliseconds; 0 disables drift
    /// (registry: `fault.drift_interval_ms`). Hot intervals are picked by
    /// hashing the interval index, so they are shard-invariant.
    pub drift_interval_ms: f64,
    /// Weak-row safe window during a hot drift interval, as a percentage
    /// of the caching duration (registry: `fault.drift_retention_pct`).
    pub drift_retention_pct: u64,
    /// Mitigation guard band: once a row is blacklisted, reduced timing
    /// is only honored while its age is within this percentage of the
    /// caching duration (registry: `fault.guard_band_pct`).
    pub guard_band_pct: u64,
    /// Violations on one row before it is blacklisted
    /// (registry: `fault.blacklist_threshold`).
    pub blacklist_threshold: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            weak_ppm: 1000,
            retention_pct: 60,
            drift_interval_ms: 0.0,
            drift_retention_pct: 35,
            guard_band_pct: 50,
            blacklist_threshold: 2,
        }
    }
}

/// Arrival process driving the open-loop traffic injector
/// ([`crate::sim::traffic`]). `Closed` (the default) disables the
/// subsystem entirely: no injector is built, no traffic RNG is drawn,
/// and the run is bit-identical to one on a build without it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficMode {
    /// Closed-loop trace replay through the cores (the paper's
    /// methodology; the default).
    Closed,
    /// Deterministic arrivals at exactly `rate_rps`.
    Det,
    /// Poisson arrivals (exponential interarrivals) at `rate_rps`.
    Poisson,
    /// On/off bursts: Poisson arrivals inside exponential ON windows
    /// (means `burst_on_us`/`burst_off_us`), silent between them, with
    /// the ON rate scaled so the long-run average is `rate_rps`.
    Burst,
    /// 2-state Markov-modulated Poisson process: exponential sojourns
    /// (mean `mmpp_sojourn_us`) alternating between a low and a high
    /// rate with ratio `mmpp_ratio`, averaging `rate_rps`.
    Mmpp,
}

/// Open-loop traffic injection ([`crate::sim::traffic`], DESIGN.md §14).
/// Inactive unless `mode != closed`; injection runs only in the measured
/// region (warmup is always closed-loop), so every `traffic.*` knob is
/// canonicalized out of the warmup fingerprint and offered-load sweep
/// legs share one warmed-up checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficConfig {
    /// Arrival process (registry: `traffic.mode`).
    pub mode: TrafficMode,
    /// Aggregate offered load in requests/second, split evenly over the
    /// per-core streams (registry: `traffic.rate_rps`).
    pub rate_rps: f64,
    /// Mean ON-window length in microseconds, burst mode
    /// (registry: `traffic.burst_on_us`).
    pub burst_on_us: f64,
    /// Mean OFF-window length in microseconds, burst mode
    /// (registry: `traffic.burst_off_us`).
    pub burst_off_us: f64,
    /// High-to-low rate ratio, MMPP mode (registry: `traffic.mmpp_ratio`).
    pub mmpp_ratio: f64,
    /// Mean state sojourn in microseconds, MMPP mode
    /// (registry: `traffic.mmpp_sojourn_us`).
    pub mmpp_sojourn_us: f64,
    /// Seed for the SplitMix64 arrival streams — a domain independent of
    /// the trace-generation `seed` (registry: `traffic.seed`).
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            mode: TrafficMode::Closed,
            rate_rps: 50_000_000.0,
            burst_on_us: 1.0,
            burst_off_us: 4.0,
            mmpp_ratio: 4.0,
            mmpp_sojourn_us: 2.0,
            seed: 7,
        }
    }
}

/// Full system configuration for one simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub dram: DramOrg,
    /// Device generation the `timing` table was derived from. Selecting
    /// one via `--set dram.generation=...` replaces `timing` wholesale;
    /// later `timing.*` overrides refine it.
    pub generation: DramGeneration,
    pub timing: Timing,
    pub mc: McConfig,
    pub cpu: CpuConfig,
    pub chargecache: ChargeCacheConfig,
    pub nuat: NuatConfig,
    pub mechanism: MechanismKind,
    /// DRAM operating temperature in Celsius (sensitivity studies).
    pub temperature_c: f64,
    /// Instructions to simulate per core (after warmup).
    pub insts_per_core: u64,
    /// Warmup CPU cycles (caches + HCRAC warm; stats reset afterwards).
    pub warmup_cpu_cycles: u64,
    /// Fixed-time measurement: run exactly this many CPU cycles after
    /// warmup and report IPC = retired / cycles per core. `None` = run to
    /// the per-core instruction target (fixed-work). Fixed-time is the
    /// stable methodology for scaled-down multiprogrammed runs, where
    /// fixed-work windows diverge chaotically between mechanisms.
    pub measure_cycles: Option<u64>,
    /// RNG seed for trace generation.
    pub seed: u64,
    /// How the system loop advances time: the event-driven kernel
    /// (default) fast-forwards over provably idle cycles;
    /// [`LoopMode::StrictTick`] keeps the original per-cycle loop as the
    /// differential-testing oracle (CLI: `--strict-tick`).
    pub loop_mode: LoopMode,
    /// Shard count for the channel-sharded parallel event loop
    /// (registry: `sim.threads`). `0` (default) defers to the
    /// process-wide `--sim-threads` / `PALLAS_SIM_THREADS` knob; `1`
    /// forces the exact single-threaded event path. Sharded runs are
    /// bit-identical to single-threaded ones by construction
    /// ([`crate::sim::shard`]), so this knob trades wall-clock only.
    pub sim_threads: usize,
    /// Wake-index implementation for the event kernel (registry:
    /// `sim.wake_impl`). `Auto` (default) defers to the process-wide
    /// `PALLAS_WAKE_IMPL` knob and resolves to the hierarchical timing
    /// wheel; `Heap` forces the lazily-pruned binary heap kept as the
    /// differential-testing oracle. Both produce bit-identical results
    /// by the one-sided wake contract ([`crate::sim::wake`]), so this
    /// knob trades wall-clock only.
    pub wake_impl: WakeImpl,
    /// Interval sampling of the measured region (registry: `sample.*`).
    pub sample: SampleConfig,
    /// Warmup-checkpoint forking in the job graph (registry:
    /// `checkpoint.*`).
    pub checkpoint: CheckpointConfig,
    /// Deterministic retention-fault injection (registry: `fault.*`).
    pub fault: FaultConfig,
    /// Open-loop traffic injection (registry: `traffic.*`).
    pub traffic: TrafficConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            dram: DramOrg::default(),
            generation: DramGeneration::Ddr3_1600,
            timing: Timing::default(),
            mc: McConfig::default(),
            cpu: CpuConfig::default(),
            chargecache: ChargeCacheConfig::default(),
            nuat: NuatConfig::default(),
            mechanism: MechanismKind::Baseline,
            temperature_c: 85.0,
            insts_per_core: 2_000_000,
            warmup_cpu_cycles: 1_000_000,
            measure_cycles: None,
            seed: 42,
            loop_mode: LoopMode::EventDriven,
            sim_threads: 0,
            wake_impl: WakeImpl::Auto,
            sample: SampleConfig::default(),
            checkpoint: CheckpointConfig::default(),
            fault: FaultConfig::default(),
            traffic: TrafficConfig::default(),
        }
    }
}

/// Stable 64-bit FNV-1a accumulator behind [`SystemConfig::fingerprint`].
///
/// Deliberately not `std::hash::Hasher`: `DefaultHasher` is randomly
/// seeded per process and its algorithm is unspecified, while fingerprints
/// key the on-disk result cache (`--result-cache`) and must be identical
/// across invocations and builds. Floats are hashed by bit pattern.
#[derive(Debug, Clone)]
pub struct Fingerprint(u64);

impl Fingerprint {
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    pub fn push_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn push_usize(&mut self, v: usize) {
        self.push_u64(v as u64);
    }

    pub fn push_f64(&mut self, v: f64) {
        self.push_u64(v.to_bits());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fingerprint {
    fn default() -> Self {
        Self::new()
    }
}

impl SystemConfig {
    /// Stable structural hash of every simulation-relevant field — the
    /// `cfg` component of a job-graph key (`coordinator::jobs::JobKey`).
    ///
    /// **Contract:** the whole config is destructured exhaustively, with
    /// no `..` rest patterns, so adding a field to [`SystemConfig`] or any
    /// nested struct without deciding how it hashes is a compile error.
    /// New fields that influence simulation results must be pushed into
    /// the accumulator; a field that provably cannot affect results may
    /// instead be bound to `_` with a comment saying why. Two configs with
    /// equal fingerprints are treated as interchangeable by the result
    /// cache, including the on-disk one. The parameter registry
    /// ([`schema`]) enforces the same destructuring contract, so a new
    /// field must simultaneously decide how it hashes *and* how it is
    /// exposed to `--set`.
    pub fn fingerprint(&self) -> u64 {
        let SystemConfig {
            dram,
            generation,
            timing,
            mc,
            cpu,
            chargecache,
            nuat,
            mechanism,
            temperature_c,
            insts_per_core,
            warmup_cpu_cycles,
            measure_cycles,
            seed,
            loop_mode,
            sim_threads,
            wake_impl,
            sample,
            checkpoint,
            fault,
            traffic,
        } = self;
        let DramOrg { channels, ranks, banks, rows, row_bytes, line_bytes } = dram;
        let Timing {
            tck_ns,
            trcd,
            trp,
            tras,
            cl,
            cwl,
            tbl,
            tccd,
            trtp,
            twr,
            twtr,
            trrd,
            tfaw,
            trfc,
            trefi,
        } = timing;
        let McConfig {
            read_queue,
            write_queue,
            write_hi_watermark,
            write_lo_watermark,
            row_policy,
            scheduler,
        } = mc;
        let CpuConfig {
            cores,
            cpu_per_bus,
            issue_width,
            window,
            mshrs,
            llc_bytes,
            llc_ways,
            llc_hit_cycles,
        } = cpu;
        let ChargeCacheConfig {
            entries_per_core,
            ways,
            duration_ms,
            trcd_reduction,
            tras_reduction,
            sharing,
            policy,
        } = chargecache;
        let NuatConfig {
            window_ms,
            trcd_reduction: nuat_trcd_reduction,
            tras_reduction: nuat_tras_reduction,
        } = nuat;
        let SampleConfig { detail_cycles, period_cycles } = sample;
        let CheckpointConfig { warmup_fork, min_fork_group } = checkpoint;
        let FaultConfig {
            enabled,
            weak_ppm,
            retention_pct,
            drift_interval_ms,
            drift_retention_pct,
            guard_band_pct,
            blacklist_threshold,
        } = fault;
        let TrafficConfig {
            mode: traffic_mode,
            rate_rps,
            burst_on_us,
            burst_off_us,
            mmpp_ratio,
            mmpp_sojourn_us,
            seed: traffic_seed,
        } = traffic;

        let mut h = Fingerprint::new();
        // DramOrg.
        h.push_usize(*channels);
        h.push_usize(*ranks);
        h.push_usize(*banks);
        h.push_usize(*rows);
        h.push_usize(*row_bytes);
        h.push_usize(*line_bytes);
        // Generation label. The derived timing table is hashed field by
        // field below, so this only distinguishes a named preset from an
        // identical hand-rolled table — cheap, and it keeps the registry
        // round-trip invariant (every settable param moves the hash).
        h.push_u64(match generation {
            DramGeneration::Ddr3_1600 => 0,
            DramGeneration::Ddr3_1333 => 1,
            DramGeneration::Ddr4_2400 => 2,
        });
        // Timing.
        h.push_f64(*tck_ns);
        for t in [trcd, trp, tras, cl, cwl, tbl, tccd, trtp, twr, twtr, trrd, tfaw, trfc, trefi] {
            h.push_u64(*t);
        }
        // McConfig.
        h.push_usize(*read_queue);
        h.push_usize(*write_queue);
        h.push_usize(*write_hi_watermark);
        h.push_usize(*write_lo_watermark);
        h.push_u64(match row_policy {
            RowPolicy::Open => 0,
            RowPolicy::Closed => 1,
        });
        h.push_u64(match scheduler {
            SchedulerKind::FrFcfs => 0,
            SchedulerKind::Fcfs => 1,
            SchedulerKind::Bliss => 2,
        });
        // CpuConfig.
        h.push_usize(*cores);
        h.push_u64(*cpu_per_bus);
        h.push_usize(*issue_width);
        h.push_usize(*window);
        h.push_usize(*mshrs);
        h.push_usize(*llc_bytes);
        h.push_usize(*llc_ways);
        h.push_u64(*llc_hit_cycles);
        // ChargeCacheConfig.
        h.push_usize(*entries_per_core);
        h.push_usize(*ways);
        h.push_f64(*duration_ms);
        h.push_u64(*trcd_reduction);
        h.push_u64(*tras_reduction);
        h.push_u64(match sharing {
            HcracSharing::PerCore => 0,
            HcracSharing::Shared => 1,
        });
        h.push_u64(match policy {
            HcracPolicy::Lru => 0,
            HcracPolicy::Bip => 1,
        });
        // NuatConfig.
        h.push_f64(*window_ms);
        h.push_u64(*nuat_trcd_reduction);
        h.push_u64(*nuat_tras_reduction);
        // Top-level scalars. `mechanism` is hashed even though jobs carry
        // the mechanism separately (JobKey::mechanism): the field exists
        // on the config, so leaving it out would silently alias configs
        // that differ in it.
        h.push_u64(match mechanism {
            MechanismKind::Baseline => 0,
            MechanismKind::ChargeCache => 1,
            MechanismKind::Nuat => 2,
            MechanismKind::ChargeCacheNuat => 3,
            MechanismKind::LlDram => 4,
        });
        h.push_f64(*temperature_c);
        h.push_u64(*insts_per_core);
        h.push_u64(*warmup_cpu_cycles);
        match measure_cycles {
            None => h.push_u64(0),
            Some(c) => {
                h.push_u64(1);
                h.push_u64(*c);
            }
        }
        h.push_u64(*seed);
        // Strict-tick and event-driven runs are bit-identical by the
        // engine-equivalence contract, but the mode is hashed anyway:
        // sharing cached results across modes would make the differential
        // oracle silently compare a result against itself.
        h.push_u64(match loop_mode {
            LoopMode::EventDriven => 0,
            LoopMode::StrictTick => 1,
        });
        // Sharded and single-threaded runs are bit-identical by the shard
        // determinism contract, but hashed for the same reason as
        // loop_mode: the equivalence tests must never compare a cached
        // result against itself.
        h.push_usize(*sim_threads);
        // Wheel and heap wake indices are bit-identical by the one-sided
        // wake contract, but the choice is hashed for the same reason as
        // loop_mode: the wheel-vs-heap equivalence tests must never
        // compare a cached result against itself.
        h.push_u64(match wake_impl {
            WakeImpl::Auto => 0,
            WakeImpl::Wheel => 1,
            WakeImpl::Heap => 2,
        });
        // Sampling replaces stretches of the measured region with
        // functional fast-forward, so sampled and full results are NOT
        // interchangeable. Checkpoint forking is bit-identical to cold
        // runs by the fork-equivalence contract, but hashed for the same
        // reason as loop_mode: the equivalence tests (and the CI
        // checkpoint-equiv job) must never compare a cached result
        // against itself.
        h.push_u64(*detail_cycles);
        h.push_u64(*period_cycles);
        h.push_u64(*warmup_fork as u64);
        h.push_usize(*min_fork_group);
        // Fault injection rewrites timing grants when enabled, so every
        // knob is simulation-relevant; all are hashed unconditionally to
        // keep the registry round-trip invariant (every settable param
        // moves the hash) even while `fault.enabled` is off.
        h.push_u64(*enabled as u64);
        h.push_u64(*weak_ppm);
        h.push_u64(*retention_pct);
        h.push_f64(*drift_interval_ms);
        h.push_u64(*drift_retention_pct);
        h.push_u64(*guard_band_pct);
        h.push_u64(*blacklist_threshold);
        // Open-loop traffic replaces the request source in the measured
        // region, so every knob is simulation-relevant; all are hashed
        // unconditionally (registry round-trip invariant) even while
        // `traffic.mode` is closed.
        h.push_u64(match traffic_mode {
            TrafficMode::Closed => 0,
            TrafficMode::Det => 1,
            TrafficMode::Poisson => 2,
            TrafficMode::Burst => 3,
            TrafficMode::Mmpp => 4,
        });
        h.push_f64(*rate_rps);
        h.push_f64(*burst_on_us);
        h.push_f64(*burst_off_us);
        h.push_f64(*mmpp_ratio);
        h.push_f64(*mmpp_sojourn_us);
        h.push_u64(*traffic_seed);
        h.finish()
    }

    /// Stable hash of the **warmup-relevant** configuration slice for
    /// `mechanism` — the identity under which warmed-up snapshots are
    /// shared ([`crate::sim::checkpoint::SimSnapshot`], job-graph warmup
    /// forking). Two runs with equal warmup fingerprints, mechanism, and
    /// workload reach bit-identical system state at the end of warmup,
    /// so one leg's snapshot can seed the others.
    ///
    /// Implemented by canonicalizing the measure-phase-only fields and
    /// re-using [`SystemConfig::fingerprint`], so the exhaustive
    /// destructuring contract carries over: a new field is decided there
    /// and, if measure-only, neutralized here.
    ///
    /// Excluded (canonicalized): `insts_per_core`, `measure_cycles`,
    /// `sample.*` and `checkpoint.*` (all measure/orchestration only),
    /// `traffic.*` (warmup always runs closed-loop — injection starts at
    /// the measurement boundary, so every offered-load leg of a
    /// latency-vs-load sweep shares one warmed-up checkpoint),
    /// `temperature_c` (a label for externally derived timing
    /// reductions — the simulation never reads it; the reductions
    /// themselves are hashed via the mechanism blocks), and the
    /// `mechanism` field (jobs carry the simulated mechanism separately;
    /// the `mechanism` argument is hashed in its place). Mechanism
    /// parameter blocks the chosen mechanism never reads are also
    /// canonicalized: `chargecache.*` counts only for
    /// ChargeCache/combined (LL-DRAM reads just the two reduction
    /// fields), `nuat.*` only for NUAT/combined.
    pub fn warmup_fingerprint(&self, mechanism: MechanismKind) -> u64 {
        let mut c = self.clone();
        c.mechanism = mechanism;
        c.temperature_c = 0.0;
        c.insts_per_core = 0;
        c.measure_cycles = None;
        c.sample = SampleConfig::default();
        c.checkpoint = CheckpointConfig::default();
        // Warmup always replays the closed-loop trace; the injector only
        // exists from the measurement boundary on, so no traffic knob can
        // reach warmed-up state.
        c.traffic = TrafficConfig::default();
        // Fault injection rewrites warmup-phase timing grants when
        // enabled, so the whole block is warmup-relevant then; disabled,
        // none of its knobs are ever read and they canonicalize away.
        if !c.fault.enabled {
            c.fault = FaultConfig::default();
        }
        let reads_cc =
            matches!(mechanism, MechanismKind::ChargeCache | MechanismKind::ChargeCacheNuat);
        let reads_nuat = matches!(mechanism, MechanismKind::Nuat | MechanismKind::ChargeCacheNuat);
        if !reads_cc {
            let (rcd, ras) = (self.chargecache.trcd_reduction, self.chargecache.tras_reduction);
            c.chargecache = ChargeCacheConfig::default();
            if matches!(mechanism, MechanismKind::LlDram) {
                // LL-DRAM applies the two reduction fields to every ACT.
                c.chargecache.trcd_reduction = rcd;
                c.chargecache.tras_reduction = ras;
            }
        }
        if !reads_nuat {
            c.nuat = NuatConfig::default();
        }
        c.fingerprint()
    }

    /// The paper's single-core configuration (Table 1): 1 channel, open-row.
    pub fn single_core() -> Self {
        Self::default()
    }

    /// The paper's eight-core configuration: 2 channels, closed-row policy.
    pub fn eight_core() -> Self {
        let mut c = Self::default();
        c.cpu.cores = 8;
        c.dram.channels = 2;
        c.mc.row_policy = RowPolicy::Closed;
        c
    }

    /// Multi-core with `n` cores (paper scales 1-8).
    pub fn multi_core(n: usize) -> Self {
        if n == 1 {
            Self::single_core()
        } else {
            let mut c = Self::eight_core();
            c.cpu.cores = n;
            c
        }
    }

    /// Total HCRAC storage in bits — Eq. (1)/(2) of the paper.
    pub fn hcrac_storage_bits(&self) -> u64 {
        let entry_bits = (self.dram.ranks as f64).log2().ceil() as u64
            + (self.dram.banks as f64).log2().ceil() as u64
            + (self.dram.rows as f64).log2().ceil() as u64
            + 1;
        // LRU bits per entry for a `ways`-way set (1 bit suffices for 2-way).
        let lru_bits = ((self.chargecache.ways as f64).log2().ceil() as u64).max(1);
        (self.cpu.cores as u64)
            * (self.dram.channels as u64)
            * (self.chargecache.entries_per_core as u64)
            * (entry_bits + lru_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = SystemConfig::default();
        assert_eq!(c.timing.trcd, 11);
        assert_eq!(c.timing.tras, 28);
        assert_eq!(c.dram.cols(), 128);
        assert_eq!(c.cpu.cpu_per_bus, 5);
        assert_eq!(c.timing.trc(), 39);
    }

    #[test]
    fn eq1_storage_matches_paper() {
        // Paper Sec. 6.5: 128-entry HCRAC, 1 rank, 8 banks, 64K rows
        // -> EntrySize = 0 + 3 + 16 + 1 = 20 bits, +1 LRU bit = 21.
        // Per core, 2 channels: 2 * 128 * 21 = 5376 bits = 672 bytes.
        let mut c = SystemConfig::eight_core();
        c.cpu.cores = 1;
        assert_eq!(c.hcrac_storage_bits(), 5376);
        assert_eq!(c.hcrac_storage_bits() / 8, 672);
        // Full 8-core, 2-channel system: 5376 bytes (paper Sec. 6.5).
        let c8 = SystemConfig::eight_core();
        assert_eq!(c8.hcrac_storage_bits() / 8, 5376);
    }

    #[test]
    fn ms_to_cycles_round_trip() {
        let t = Timing::default();
        assert_eq!(t.ms_to_cycles(1.0), 800_000);
        assert_eq!(t.cycles_to_ns(800_000) as u64, 1_000_000);
    }

    #[test]
    fn fingerprint_is_stable_and_field_sensitive() {
        let a = SystemConfig::default();
        // Deterministic: same config, same hash, across calls and clones.
        assert_eq!(a.fingerprint(), a.fingerprint());
        assert_eq!(a.fingerprint(), a.clone().fingerprint());

        // Every class of field perturbation must move the hash.
        let mut seen = vec![a.fingerprint()];
        let perturbations: Vec<SystemConfig> = vec![
            {
                let mut c = a.clone();
                c.dram.banks = 16;
                c
            },
            {
                let mut c = a.clone();
                c.timing.trcd = 12;
                c
            },
            {
                let mut c = a.clone();
                c.mc.row_policy = RowPolicy::Closed;
                c
            },
            {
                let mut c = a.clone();
                c.mc.scheduler = SchedulerKind::Bliss;
                c
            },
            {
                let mut c = a.clone();
                c.cpu.cores = 2;
                c
            },
            {
                let mut c = a.clone();
                c.chargecache.entries_per_core = 256;
                c
            },
            {
                let mut c = a.clone();
                c.chargecache.duration_ms = 2.0;
                c
            },
            {
                let mut c = a.clone();
                c.nuat.window_ms = 2.0;
                c
            },
            {
                let mut c = a.clone();
                c.temperature_c = 45.0;
                c
            },
            {
                let mut c = a.clone();
                c.insts_per_core += 1;
                c
            },
            {
                let mut c = a.clone();
                c.measure_cycles = Some(0);
                c
            },
            {
                let mut c = a.clone();
                c.seed ^= 1;
                c
            },
            {
                let mut c = a.clone();
                c.loop_mode = LoopMode::StrictTick;
                c
            },
            {
                let mut c = a.clone();
                c.sim_threads = 4;
                c
            },
            {
                let mut c = a.clone();
                c.wake_impl = WakeImpl::Heap;
                c
            },
            {
                let mut c = a.clone();
                c.wake_impl = WakeImpl::Wheel;
                c
            },
            {
                // Same timing table, different generation label: the tag
                // itself must move the hash (registry round-trip).
                let mut c = a.clone();
                c.generation = DramGeneration::Ddr3_1333;
                c
            },
            {
                let mut c = a.clone();
                c.sample.detail_cycles = 10_000;
                c
            },
            {
                let mut c = a.clone();
                c.sample.period_cycles = 500_000;
                c
            },
            {
                let mut c = a.clone();
                c.checkpoint.warmup_fork = false;
                c
            },
            {
                let mut c = a.clone();
                c.checkpoint.min_fork_group = 3;
                c
            },
            {
                let mut c = a.clone();
                c.fault.enabled = true;
                c
            },
            {
                let mut c = a.clone();
                c.fault.weak_ppm = 50_000;
                c
            },
            {
                let mut c = a.clone();
                c.fault.retention_pct = 40;
                c
            },
            {
                let mut c = a.clone();
                c.fault.drift_interval_ms = 0.5;
                c
            },
            {
                let mut c = a.clone();
                c.fault.drift_retention_pct = 20;
                c
            },
            {
                let mut c = a.clone();
                c.fault.guard_band_pct = 25;
                c
            },
            {
                let mut c = a.clone();
                c.fault.blacklist_threshold = 1;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.mode = TrafficMode::Poisson;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.rate_rps = 100_000_000.0;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.burst_on_us = 2.0;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.burst_off_us = 8.0;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.mmpp_ratio = 9.0;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.mmpp_sojourn_us = 5.0;
                c
            },
            {
                let mut c = a.clone();
                c.traffic.seed ^= 1;
                c
            },
        ];
        for p in perturbations {
            let fp = p.fingerprint();
            assert!(!seen.contains(&fp), "fingerprint collision for {p:?}");
            seen.push(fp);
        }
    }

    #[test]
    fn fingerprint_none_vs_zero_measure_cycles() {
        // The Option tag must be hashed, not just the payload.
        let none = SystemConfig::default();
        let mut zero = none.clone();
        zero.measure_cycles = Some(0);
        assert_ne!(none.fingerprint(), zero.fingerprint());
    }

    #[test]
    fn warmup_fingerprint_ignores_measure_phase_knobs() {
        let a = SystemConfig::default();
        let base = a.warmup_fingerprint(MechanismKind::ChargeCache);
        for tweak in [
            (|c: &mut SystemConfig| c.temperature_c = 45.0) as fn(&mut SystemConfig),
            |c| c.insts_per_core += 1,
            |c| c.measure_cycles = Some(123_456),
            |c| c.sample.detail_cycles = 10_000,
            |c| c.sample.period_cycles = 500_000,
            |c| c.checkpoint.warmup_fork = false,
            |c| c.checkpoint.min_fork_group = 7,
            |c| c.mechanism = MechanismKind::Nuat,
            // Disabled fault knobs are never read during warmup.
            |c| c.fault.weak_ppm = 123_456,
            |c| c.fault.guard_band_pct = 99,
            // Traffic injection starts at the measurement boundary, so
            // no traffic knob — not even the mode — touches warmup.
            |c| c.traffic.mode = TrafficMode::Poisson,
            |c| {
                c.traffic.mode = TrafficMode::Mmpp;
                c.traffic.rate_rps = 123_000_000.0;
                c.traffic.seed ^= 99;
            },
            |c| c.traffic.burst_on_us = 3.5,
        ] {
            let mut c = a.clone();
            tweak(&mut c);
            assert_eq!(c.warmup_fingerprint(MechanismKind::ChargeCache), base);
        }
    }

    #[test]
    fn warmup_fingerprint_moves_with_warmup_relevant_knobs() {
        let a = SystemConfig::default();
        let base = a.warmup_fingerprint(MechanismKind::ChargeCache);
        for tweak in [
            (|c: &mut SystemConfig| c.seed ^= 1) as fn(&mut SystemConfig),
            |c| c.timing.trcd = 12,
            |c| c.warmup_cpu_cycles += 1,
            |c| c.cpu.cores = 2,
            |c| c.loop_mode = LoopMode::StrictTick,
            |c| c.sim_threads = 4,
            |c| c.wake_impl = WakeImpl::Heap,
            |c| c.chargecache.duration_ms = 2.0,
            // Enabled fault injection rewrites warmup-phase grants.
            |c| c.fault.enabled = true,
            |c| {
                c.fault.enabled = true;
                c.fault.weak_ppm = 123_456;
            },
        ] {
            let mut c = a.clone();
            tweak(&mut c);
            assert_ne!(c.warmup_fingerprint(MechanismKind::ChargeCache), base);
        }
        // The mechanism argument itself is part of the identity.
        assert_ne!(base, a.warmup_fingerprint(MechanismKind::Baseline));
        assert_ne!(base, a.warmup_fingerprint(MechanismKind::Nuat));
    }

    #[test]
    fn warmup_fingerprint_masks_unread_mechanism_blocks() {
        let a = SystemConfig::default();
        let mut b = a.clone();
        b.chargecache.duration_ms = 8.0;
        b.chargecache.entries_per_core = 512;
        // Baseline and NUAT never consult the HCRAC parameters...
        assert_eq!(
            a.warmup_fingerprint(MechanismKind::Baseline),
            b.warmup_fingerprint(MechanismKind::Baseline)
        );
        assert_eq!(
            a.warmup_fingerprint(MechanismKind::Nuat),
            b.warmup_fingerprint(MechanismKind::Nuat)
        );
        // ...but ChargeCache does.
        assert_ne!(
            a.warmup_fingerprint(MechanismKind::ChargeCache),
            b.warmup_fingerprint(MechanismKind::ChargeCache)
        );
        // LL-DRAM reads only the reduction fields.
        let mut r = a.clone();
        r.chargecache.trcd_reduction = 6;
        assert_eq!(
            b.warmup_fingerprint(MechanismKind::LlDram),
            a.warmup_fingerprint(MechanismKind::LlDram)
        );
        assert_ne!(
            r.warmup_fingerprint(MechanismKind::LlDram),
            a.warmup_fingerprint(MechanismKind::LlDram)
        );
        // NUAT parameters count only for NUAT/combined.
        let mut n = a.clone();
        n.nuat.window_ms = 4.0;
        assert_eq!(
            n.warmup_fingerprint(MechanismKind::ChargeCache),
            a.warmup_fingerprint(MechanismKind::ChargeCache)
        );
        assert_ne!(
            n.warmup_fingerprint(MechanismKind::Nuat),
            a.warmup_fingerprint(MechanismKind::Nuat)
        );
    }

    #[test]
    fn generation_presets() {
        // The default table IS the DDR3-1600 preset — pinned results
        // must not shift under the generation refactor.
        assert_eq!(DramGeneration::Ddr3_1600.timing(), Timing::default());
        let d1333 = DramGeneration::Ddr3_1333.timing();
        assert_eq!(d1333.trcd, 9);
        assert_eq!(d1333.trc(), 33);
        let d4 = DramGeneration::Ddr4_2400.timing();
        assert_eq!(d4.trcd, 17);
        assert!(d4.tck_ns < d1333.tck_ns, "DDR4-2400 clocks faster");
    }

    #[test]
    fn preset_policies() {
        assert_eq!(SystemConfig::single_core().mc.row_policy, RowPolicy::Open);
        assert_eq!(SystemConfig::eight_core().mc.row_policy, RowPolicy::Closed);
        assert_eq!(SystemConfig::eight_core().dram.channels, 2);
    }
}
