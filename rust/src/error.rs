//! Crate-local error type. The build is offline (no `anyhow`), so a small
//! enum plus anyhow-style context helpers cover the whole failure surface:
//! CLI parsing, trace/metadata IO, and artifact loading.

use std::fmt;

/// What went wrong, with a human-readable message chain.
#[derive(Debug)]
pub enum SimError {
    /// Filesystem / IO failure.
    Io(std::io::Error),
    /// Malformed input: a trace line, CLI option, or metadata field.
    Parse(String),
    /// Malformed input pinned to a source location: truncated or corrupt
    /// trace/scenario files report the file and byte offset instead of
    /// panicking or losing the position in a generic message.
    ParseAt {
        file: String,
        /// Byte offset of the offending input within `file`.
        offset: u64,
        msg: String,
    },
    /// Anything else worth a message (artifact loading, config errors).
    Msg(String),
}

pub type Result<T> = std::result::Result<T, SimError>;

impl SimError {
    pub fn msg(m: impl Into<String>) -> Self {
        SimError::Msg(m.into())
    }
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::Io(e) => write!(f, "io error: {e}"),
            SimError::Parse(m) => write!(f, "parse error: {m}"),
            SimError::ParseAt { file, offset, msg } => {
                write!(f, "parse error in {file} at byte {offset}: {msg}")
            }
            SimError::Msg(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for SimError {}

impl From<std::io::Error> for SimError {
    fn from(e: std::io::Error) -> Self {
        SimError::Io(e)
    }
}

impl From<std::num::ParseIntError> for SimError {
    fn from(e: std::num::ParseIntError) -> Self {
        SimError::Parse(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for SimError {
    fn from(e: std::num::ParseFloatError) -> Self {
        SimError::Parse(e.to_string())
    }
}

/// anyhow-style `.context(..)` / `.with_context(..)` on `Result` and
/// `Option`, so call sites read the same as they did under anyhow.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| SimError::Msg(format!("{msg}: {e}")))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| SimError::Msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| SimError::Msg(msg.to_string()))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| SimError::Msg(f()))
    }
}

/// Early-return with a formatted [`SimError::Msg`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::SimError::Msg(format!($($arg)*)))
    };
}

/// Bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("value was {}", 42)
    }

    #[test]
    fn bail_formats_message() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "value was 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        let r: std::result::Result<u32, std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "nope",
        ));
        let e = r.with_context(|| "loading thing".to_string()).unwrap_err();
        assert!(e.to_string().contains("loading thing"));
    }

    #[test]
    fn parse_at_reports_file_and_offset() {
        let e = SimError::ParseAt {
            file: "traces/x.trace".into(),
            offset: 137,
            msg: "bad hex address".into(),
        };
        assert_eq!(e.to_string(), "parse error in traces/x.trace at byte 137: bad hex address");
    }

    #[test]
    fn io_and_parse_conversions() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(matches!(parse("x").unwrap_err(), SimError::Parse(_)));
    }
}
