//! BankEngine — per-bank request indexing for the memory controller.
//!
//! The scheduler and the event-kernel wake bound both need one fact per
//! bank, many times per tick: *does any queued request hit the currently
//! open row?* The pre-refactor controller answered it by re-scanning both
//! request queues (an O(read-queue + write-queue) pass in `schedule()`,
//! `eager_precharge()`, and `next_event_at()` — the last one allocating a
//! scratch bitmap per call). This index maintains the answer
//! incrementally, O(1) per queue/row transition:
//!
//! * **enqueue/dequeue** — a per-bank `row -> queued-count` map is
//!   updated, and the open-row-hit counter bumps when the request's row
//!   matches the bank's open row;
//! * **ACT** — the hit counter is reseeded from the row map (one hash
//!   lookup);
//! * **PRE** (explicit, auto, or refresh-drain) — the hit counter drops
//!   to zero.
//!
//! The controller is the single writer: every path that moves a request
//! or a row must notify the engine, and `debug_assert_consistent`
//! re-derives the counters from queue + device state to catch a missed
//! notification in tests.

use std::collections::HashMap;

use crate::dram::command::Loc;

/// Incremental per-bank view over the request queues.
#[derive(Debug, Clone)]
pub struct BankEngine {
    banks_per_rank: usize,
    /// Per (rank, bank): queued-request count per row, both queues.
    rows: Vec<HashMap<u32, u32>>,
    /// Per (rank, bank): queued requests hitting the currently open row.
    open_hits: Vec<u32>,
}

impl BankEngine {
    pub fn new(ranks: usize, banks_per_rank: usize) -> Self {
        Self {
            banks_per_rank,
            rows: vec![HashMap::new(); ranks * banks_per_rank],
            open_hits: vec![0; ranks * banks_per_rank],
        }
    }

    #[inline]
    fn idx(&self, rank: u32, bank: u32) -> usize {
        rank as usize * self.banks_per_rank + bank as usize
    }

    /// A request entered a queue. `open_row` is its bank's open row at
    /// enqueue time.
    pub fn on_enqueue(&mut self, loc: &Loc, open_row: Option<u32>) {
        let i = self.idx(loc.rank, loc.bank);
        *self.rows[i].entry(loc.row).or_insert(0) += 1;
        if open_row == Some(loc.row) {
            self.open_hits[i] += 1;
        }
    }

    /// A request left a queue (its column command issued). `open_row` is
    /// its bank's open row after the issue (column commands do not close
    /// the row; auto-precharge resolution reports separately).
    pub fn on_dequeue(&mut self, loc: &Loc, open_row: Option<u32>) {
        let i = self.idx(loc.rank, loc.bank);
        match self.rows[i].get_mut(&loc.row) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.rows[i].remove(&loc.row);
            }
            None => debug_assert!(false, "dequeue of untracked request at {loc:?}"),
        }
        if open_row == Some(loc.row) {
            debug_assert!(self.open_hits[i] > 0, "open-hit underflow at {loc:?}");
            self.open_hits[i] -= 1;
        }
    }

    /// An ACT opened `row`: reseed the hit counter from the row index.
    pub fn on_row_opened(&mut self, rank: u32, bank: u32, row: u32) {
        let i = self.idx(rank, bank);
        self.open_hits[i] = self.rows[i].get(&row).copied().unwrap_or(0);
    }

    /// A PRE (explicit, auto, or refresh-drain) closed the bank's row.
    pub fn on_row_closed(&mut self, rank: u32, bank: u32) {
        let i = self.idx(rank, bank);
        self.open_hits[i] = 0;
    }

    /// Does any queued request hit the bank's currently open row? O(1) —
    /// this is the query the per-tick queue scans used to answer.
    #[inline]
    pub fn open_row_has_hit(&self, rank: u32, bank: u32) -> bool {
        self.open_hits[self.idx(rank, bank)] > 0
    }

    /// Re-derive both indexes from first principles and compare (test
    /// hook: catches any controller path that forgot a notification).
    pub fn debug_assert_consistent<'a>(
        &self,
        requests: impl Iterator<Item = &'a crate::controller::Request>,
        open_row_of: impl Fn(u32, u32) -> Option<u32>,
    ) {
        let mut rows = vec![HashMap::new(); self.rows.len()];
        let mut hits = vec![0u32; self.open_hits.len()];
        for req in requests {
            let i = self.idx(req.loc.rank, req.loc.bank);
            *rows[i].entry(req.loc.row).or_insert(0u32) += 1;
            if open_row_of(req.loc.rank, req.loc.bank) == Some(req.loc.row) {
                hits[i] += 1;
            }
        }
        debug_assert_eq!(rows, self.rows, "row index diverged from queues");
        debug_assert_eq!(hits, self.open_hits, "open-hit counters diverged");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(bank: u32, row: u32) -> Loc {
        Loc { channel: 0, rank: 0, bank, row, col: 0 }
    }

    #[test]
    fn enqueue_dequeue_tracks_open_hits() {
        let mut e = BankEngine::new(1, 8);
        e.on_enqueue(&loc(0, 5), None);
        assert!(!e.open_row_has_hit(0, 0));
        e.on_row_opened(0, 0, 5);
        assert!(e.open_row_has_hit(0, 0));
        e.on_enqueue(&loc(0, 5), Some(5));
        e.on_dequeue(&loc(0, 5), Some(5));
        assert!(e.open_row_has_hit(0, 0));
        e.on_dequeue(&loc(0, 5), Some(5));
        assert!(!e.open_row_has_hit(0, 0));
    }

    #[test]
    fn act_reseeds_from_queued_rows() {
        let mut e = BankEngine::new(1, 8);
        e.on_enqueue(&loc(3, 7), None);
        e.on_enqueue(&loc(3, 7), None);
        e.on_enqueue(&loc(3, 9), None);
        e.on_row_opened(0, 3, 9);
        assert!(e.open_row_has_hit(0, 3));
        e.on_row_closed(0, 3);
        assert!(!e.open_row_has_hit(0, 3));
        e.on_row_opened(0, 3, 7);
        assert!(e.open_row_has_hit(0, 3));
    }

    #[test]
    fn close_zeroes_hits_regardless_of_queue() {
        let mut e = BankEngine::new(2, 4);
        e.on_enqueue(&Loc { channel: 0, rank: 1, bank: 2, row: 4, col: 0 }, None);
        e.on_row_opened(1, 2, 4);
        assert!(e.open_row_has_hit(1, 2));
        e.on_row_closed(1, 2);
        assert!(!e.open_row_has_hit(1, 2));
    }
}
