//! BankEngine — per-bank request indexing for the memory controller.
//!
//! The scheduler and the event-kernel wake bound both need one fact per
//! bank, many times per tick: *does any queued request hit the currently
//! open row?* The pre-refactor controller answered it by re-scanning both
//! request queues (an O(read-queue + write-queue) pass in `schedule()`,
//! `eager_precharge()`, and `next_event_at()` — the last one allocating a
//! scratch bitmap per call). This index maintains the answer
//! incrementally, O(1) per queue/row transition:
//!
//! * **enqueue/dequeue** — a per-bank `row -> queued-count` table is
//!   updated, and the open-row-hit counter bumps when the request's row
//!   matches the bank's open row;
//! * **ACT** — the hit counter is reseeded from the row table (one
//!   probe);
//! * **PRE** (explicit, auto, or refresh-drain) — the hit counter drops
//!   to zero.
//!
//! The row tables used to be per-bank `HashMap<u32, u32>`s, which put a
//! SipHash invocation and a heap-allocated bucket walk on the hottest
//! controller path. They are now dense open-addressed tables ([`RowTable`])
//! keyed by the packed u64 [`RowKey`]: multiply-shift hashing, linear
//! probing with backward-shift deletion (no tombstones), and a per-bank
//! generation stamp so a full reset ([`BankEngine::clear`], used when a
//! sweep leg replays controller state) is O(banks) with **zero
//! reallocation** — stale slots die by stamp mismatch, not by rewriting
//! the slot array.
//!
//! The controller is the single writer: every path that moves a request
//! or a row must notify the engine, and `debug_assert_consistent`
//! re-derives the counters from queue + device state to catch a missed
//! notification in tests.

use std::collections::HashMap;

use crate::dram::command::Loc;
use crate::latency::RowKey;

/// One row-count slot. Live only while `gen` matches its table's
/// generation; `Default` (gen 0) is dead for every table generation.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    key: u64,
    count: u32,
    gen: u32,
}

/// One bank's open-addressed `RowKey -> queued-count` table.
///
/// Power-of-two capacity, grown at 1/2 load so a probe chain always
/// terminates at a dead slot. Deletion backward-shifts the chain
/// (Knuth 6.4 algorithm R), keeping lookups tombstone-free.
#[derive(Debug, Clone)]
struct RowTable {
    slots: Vec<Slot>,
    /// Capacity minus one (capacity is a power of two).
    mask: usize,
    /// Live (distinct-row) slots.
    len: usize,
    /// Current generation; bumped by `clear`.
    gen: u32,
}

impl RowTable {
    fn new(cap: usize) -> Self {
        let cap = cap.next_power_of_two().max(8);
        Self { slots: vec![Slot::default(); cap], mask: cap - 1, len: 0, gen: 1 }
    }

    /// Multiply-shift (Fibonacci) hashing: packed `RowKey`s differ in a
    /// handful of low row bits within one bank, and the high product
    /// bits spread exactly those.
    #[inline]
    fn home(&self, key: u64) -> usize {
        let shift = 64 - (self.mask + 1).trailing_zeros();
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> shift) as usize
    }

    #[inline]
    fn live(&self, i: usize) -> bool {
        self.slots[i].gen == self.gen
    }

    fn find(&self, key: u64) -> Option<usize> {
        let mut i = self.home(key);
        loop {
            if !self.live(i) {
                return None;
            }
            if self.slots[i].key == key {
                return Some(i);
            }
            i = (i + 1) & self.mask;
        }
    }

    fn get(&self, key: u64) -> u32 {
        self.find(key).map(|i| self.slots[i].count).unwrap_or(0)
    }

    fn inc(&mut self, key: u64) {
        if 2 * (self.len + 1) > self.slots.len() {
            self.grow();
        }
        let mut i = self.home(key);
        loop {
            if !self.live(i) {
                self.slots[i] = Slot { key, count: 1, gen: self.gen };
                self.len += 1;
                return;
            }
            if self.slots[i].key == key {
                self.slots[i].count += 1;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Decrement `key`, removing its slot at zero. Returns false if the
    /// key was untracked (the caller debug-asserts on that).
    fn dec(&mut self, key: u64) -> bool {
        let Some(i) = self.find(key) else {
            return false;
        };
        if self.slots[i].count > 1 {
            self.slots[i].count -= 1;
        } else {
            self.remove_at(i);
        }
        true
    }

    /// Backward-shift deletion: walk the probe chain after the hole and
    /// pull back every entry whose home lies at or before the hole, so
    /// no chain is ever split by a dead slot.
    fn remove_at(&mut self, mut i: usize) {
        self.len -= 1;
        let mut j = i;
        loop {
            j = (j + 1) & self.mask;
            if !self.live(j) {
                break;
            }
            let h = self.home(self.slots[j].key);
            // `j` may backfill the hole at `i` unless its home lies in
            // the cyclic interval (i, j] — moving such an entry would
            // break its own probe chain.
            let d_ij = j.wrapping_sub(i) & self.mask;
            let d_hj = j.wrapping_sub(h) & self.mask;
            if d_hj >= d_ij {
                self.slots[i] = self.slots[j];
                i = j;
            }
        }
        self.slots[i].gen = self.gen.wrapping_sub(1);
    }

    fn grow(&mut self) {
        let cap = self.slots.len() * 2;
        let old = std::mem::replace(&mut self.slots, vec![Slot::default(); cap]);
        let old_gen = self.gen;
        self.mask = cap - 1;
        self.gen = 1;
        self.len = 0;
        for s in old {
            if s.gen == old_gen {
                let mut i = self.home(s.key);
                while self.live(i) {
                    i = (i + 1) & self.mask;
                }
                self.slots[i] = Slot { key: s.key, count: s.count, gen: self.gen };
                self.len += 1;
            }
        }
    }

    /// O(1) reset: everything stamped with an older generation is dead.
    /// (On the astronomically distant stamp wraparound, fall back to a
    /// real wipe so an ancient slot can never resurrect.)
    fn clear(&mut self) {
        self.len = 0;
        if self.gen == u32::MAX {
            self.slots.fill(Slot::default());
            self.gen = 1;
        } else {
            self.gen += 1;
        }
    }

    fn iter_live(&self) -> impl Iterator<Item = (u64, u32)> + '_ {
        self.slots.iter().filter(|s| s.gen == self.gen).map(|s| (s.key, s.count))
    }
}

/// Incremental per-bank view over the request queues.
#[derive(Debug, Clone)]
pub struct BankEngine {
    banks_per_rank: usize,
    /// Stamped into every key (same qualification as the controller's
    /// own `row_key`), so table contents are debug-checkable RowKeys.
    channel: u32,
    /// Per (rank, bank): queued-request count per row, both queues.
    tables: Vec<RowTable>,
    /// Per (rank, bank): queued requests hitting the currently open row.
    open_hits: Vec<u32>,
}

impl BankEngine {
    /// `cap_hint` is the controller's total queue capacity (read +
    /// write): distinct queued rows per bank can never exceed it, and
    /// the per-bank tables start sized for an even spread (growing on
    /// the fly for skewed ones).
    pub fn new(ranks: usize, banks_per_rank: usize, channel: u32, cap_hint: usize) -> Self {
        let banks = (ranks * banks_per_rank).max(1);
        let per_bank = 2 * (cap_hint / banks).max(4);
        Self {
            banks_per_rank,
            channel,
            tables: vec![RowTable::new(per_bank); banks],
            open_hits: vec![0; banks],
        }
    }

    #[inline]
    fn idx(&self, rank: u32, bank: u32) -> usize {
        rank as usize * self.banks_per_rank + bank as usize
    }

    #[inline]
    fn key(&self, rank: u32, bank: u32, row: u32) -> u64 {
        RowKey::new_in_channel(self.channel, rank, bank, row).0
    }

    /// A request entered a queue. `open_row` is its bank's open row at
    /// enqueue time.
    pub fn on_enqueue(&mut self, loc: &Loc, open_row: Option<u32>) {
        let i = self.idx(loc.rank, loc.bank);
        let key = self.key(loc.rank, loc.bank, loc.row);
        self.tables[i].inc(key);
        if open_row == Some(loc.row) {
            self.open_hits[i] += 1;
        }
    }

    /// A request left a queue (its column command issued). `open_row` is
    /// its bank's open row after the issue (column commands do not close
    /// the row; auto-precharge resolution reports separately).
    pub fn on_dequeue(&mut self, loc: &Loc, open_row: Option<u32>) {
        let i = self.idx(loc.rank, loc.bank);
        let key = self.key(loc.rank, loc.bank, loc.row);
        let tracked = self.tables[i].dec(key);
        debug_assert!(tracked, "dequeue of untracked request at {loc:?}");
        if open_row == Some(loc.row) {
            debug_assert!(self.open_hits[i] > 0, "open-hit underflow at {loc:?}");
            self.open_hits[i] -= 1;
        }
    }

    /// An ACT opened `row`: reseed the hit counter from the row table.
    pub fn on_row_opened(&mut self, rank: u32, bank: u32, row: u32) {
        let i = self.idx(rank, bank);
        let key = self.key(rank, bank, row);
        self.open_hits[i] = self.tables[i].get(key);
    }

    /// A PRE (explicit, auto, or refresh-drain) closed the bank's row.
    pub fn on_row_closed(&mut self, rank: u32, bank: u32) {
        let i = self.idx(rank, bank);
        self.open_hits[i] = 0;
    }

    /// Does any queued request hit the bank's currently open row? O(1) —
    /// this is the query the per-tick queue scans used to answer.
    #[inline]
    pub fn open_row_has_hit(&self, rank: u32, bank: u32) -> bool {
        self.open_hits[self.idx(rank, bank)] > 0
    }

    /// Drop every row count and hit counter without reallocating: the
    /// generation stamps advance, the slot arrays stay. Used when a
    /// restored/replayed controller re-derives the index from its queues.
    pub fn clear(&mut self) {
        for t in &mut self.tables {
            t.clear();
        }
        self.open_hits.fill(0);
    }

    /// Per-(rank, bank) `row -> count` export (test/debug hook; the hot
    /// path never materializes maps).
    pub fn snapshot_rows(&self) -> Vec<HashMap<u32, u32>> {
        self.tables
            .iter()
            .map(|t| t.iter_live().map(|(k, c)| (RowKey(k).row(), c)).collect())
            .collect()
    }

    /// Re-derive both indexes from first principles and compare (test
    /// hook: catches any controller path that forgot a notification).
    pub fn debug_assert_consistent<'a>(
        &self,
        requests: impl Iterator<Item = &'a crate::controller::Request>,
        open_row_of: impl Fn(u32, u32) -> Option<u32>,
    ) {
        let mut rows: Vec<HashMap<u32, u32>> = vec![HashMap::new(); self.tables.len()];
        let mut hits = vec![0u32; self.open_hits.len()];
        for req in requests {
            let i = self.idx(req.loc.rank, req.loc.bank);
            *rows[i].entry(req.loc.row).or_insert(0u32) += 1;
            if open_row_of(req.loc.rank, req.loc.bank) == Some(req.loc.row) {
                hits[i] += 1;
            }
        }
        debug_assert_eq!(rows, self.snapshot_rows(), "row index diverged from queues");
        debug_assert_eq!(hits, self.open_hits, "open-hit counters diverged");
        #[cfg(debug_assertions)]
        for (i, t) in self.tables.iter().enumerate() {
            let (rank, bank) =
                ((i / self.banks_per_rank) as u32, (i % self.banks_per_rank) as u32);
            for (k, count) in t.iter_live() {
                debug_assert!(count > 0, "zero-count slot survived removal");
                debug_assert_eq!(
                    k,
                    self.key(rank, bank, RowKey(k).row()),
                    "key bucketed under the wrong bank table"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(bank: u32, row: u32) -> Loc {
        Loc { channel: 0, rank: 0, bank, row, col: 0 }
    }

    #[test]
    fn enqueue_dequeue_tracks_open_hits() {
        let mut e = BankEngine::new(1, 8, 0, 64);
        e.on_enqueue(&loc(0, 5), None);
        assert!(!e.open_row_has_hit(0, 0));
        e.on_row_opened(0, 0, 5);
        assert!(e.open_row_has_hit(0, 0));
        e.on_enqueue(&loc(0, 5), Some(5));
        e.on_dequeue(&loc(0, 5), Some(5));
        assert!(e.open_row_has_hit(0, 0));
        e.on_dequeue(&loc(0, 5), Some(5));
        assert!(!e.open_row_has_hit(0, 0));
    }

    #[test]
    fn act_reseeds_from_queued_rows() {
        let mut e = BankEngine::new(1, 8, 0, 64);
        e.on_enqueue(&loc(3, 7), None);
        e.on_enqueue(&loc(3, 7), None);
        e.on_enqueue(&loc(3, 9), None);
        e.on_row_opened(0, 3, 9);
        assert!(e.open_row_has_hit(0, 3));
        e.on_row_closed(0, 3);
        assert!(!e.open_row_has_hit(0, 3));
        e.on_row_opened(0, 3, 7);
        assert!(e.open_row_has_hit(0, 3));
    }

    #[test]
    fn close_zeroes_hits_regardless_of_queue() {
        let mut e = BankEngine::new(2, 4, 0, 64);
        e.on_enqueue(&Loc { channel: 0, rank: 1, bank: 2, row: 4, col: 0 }, None);
        e.on_row_opened(1, 2, 4);
        assert!(e.open_row_has_hit(1, 2));
        e.on_row_closed(1, 2);
        assert!(!e.open_row_has_hit(1, 2));
    }

    #[test]
    fn table_grows_past_its_hint_and_survives_generation_reset() {
        // Skew every request into one bank so the 8-slot initial table
        // must grow several times, then reset and re-populate: a stale
        // generation's rows must never resurrect.
        let mut e = BankEngine::new(1, 2, 3, 8);
        for row in 0..200u32 {
            e.on_enqueue(&loc(1, row), None);
        }
        e.on_enqueue(&loc(1, 7), None);
        let snap = e.snapshot_rows();
        assert_eq!(snap[1].len(), 200);
        assert_eq!(snap[1][&7], 2);
        e.on_row_opened(0, 1, 7);
        assert!(e.open_row_has_hit(0, 1));
        e.clear();
        assert!(!e.open_row_has_hit(0, 1));
        assert!(e.snapshot_rows().iter().all(|m| m.is_empty()));
        e.on_enqueue(&loc(1, 7), None);
        let snap = e.snapshot_rows();
        assert_eq!(snap[1][&7], 1, "post-clear count must restart from zero");
        e.on_row_opened(0, 1, 7);
        assert!(e.open_row_has_hit(0, 1));
    }

    #[test]
    fn backward_shift_deletion_keeps_probe_chains_intact() {
        // Fill one bank with enough rows to force collisions, then
        // remove in an order that exercises chain backfill, verifying
        // every surviving row stays findable with the right count.
        let mut e = BankEngine::new(1, 1, 0, 8);
        for row in 0..64u32 {
            e.on_enqueue(&loc(0, row), None);
            e.on_enqueue(&loc(0, row), None);
        }
        for row in (0..64u32).step_by(3) {
            e.on_dequeue(&loc(0, row), None);
            e.on_dequeue(&loc(0, row), None);
        }
        let snap = &e.snapshot_rows()[0];
        for row in 0..64u32 {
            if row % 3 == 0 {
                assert!(!snap.contains_key(&row), "removed row {row} resurrected");
            } else {
                assert_eq!(snap[&row], 2, "row {row} lost by backward shift");
            }
        }
        // Reseed-by-ACT still probes correctly after the deletions.
        e.on_row_opened(0, 0, 4);
        assert!(e.open_row_has_hit(0, 0));
        e.on_row_opened(0, 0, 3);
        assert!(!e.open_row_has_hit(0, 0));
    }
}
