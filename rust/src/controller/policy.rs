//! SchedPolicy — pluggable memory-scheduler policies.
//!
//! The controller's per-tick template is fixed (refresh drain, then a
//! ready **column** pass, then an **ACT/PRE** pass); what varies between
//! schedulers is *which* request each pass picks and *when* the next pick
//! could become legal. A policy supplies exactly those three decisions:
//!
//! * [`SchedPolicy::pick_column`] — pass 1: the queue slot key whose
//!   ready column command (row hit) should issue this cycle;
//! * [`SchedPolicy::pick_act_pre`] — pass 2: the queue slot key and
//!   command (ACT or conflict-PRE) to issue when no column was ready;
//! * [`SchedPolicy::next_ready_at`] — the policy's contribution to the
//!   controller's event-kernel wake bound: a conservative **lower** bound
//!   on the earliest bus cycle at which either pass could issue anything.
//!   Early bounds cost a no-op tick; a late bound would silently break
//!   the strict-tick equivalence, so every policy's bound is attacked by
//!   `tests/prop.rs::prop_wake_bound_is_never_late_for_any_policy`. The
//!   bound feeds the wake index (`sim::wake` — timing wheel or heap
//!   oracle) through `MemController::next_event_at`; the one-sided
//!   contract there is exactly this one, so a policy correct against the
//!   property test is correct under either index implementation.
//!
//! Policies consult the [`BankEngine`]'s flat per-bank row tables (open
//! row, queued-row hit counts) rather than scanning queues; see
//! `controller::bank_engine` for the open-addressed layout.
//!
//! Three policies ship:
//!
//! * **FR-FCFS+cap** (default) — row hits first, oldest first, with a
//!   conflict-PRE hysteresis window and a starvation cap that lets a
//!   sufficiently old conflicting request close a busy row.
//! * **FCFS** — strict arrival order: only the oldest schedulable request
//!   (oldest request outside a refresh-draining rank) may issue its next
//!   command. No row-hit reordering; the reference point scheduling
//!   studies compare against.
//! * **BLISS-style** — FR-FCFS order plus application blacklisting
//!   (Subramanian et al.): a core served too many consecutive column
//!   commands is blacklisted until the next clearing interval;
//!   non-blacklisted requests win ties in both passes, and a blacklisted
//!   core's open row loses its row-hit-first protection against
//!   non-blacklisted conflicts.

use std::collections::HashSet;

use crate::dram::command::CommandKind;
use crate::dram::device::Channel;

use super::bank_engine::BankEngine;
use super::queue::{Request, RequestQueue};

/// Row-hysteresis: a conflicting request must have waited this many bus
/// cycles before it may close an open row (FR-FCFS / BLISS pass 2).
pub const CONFLICT_AGE_CYCLES: u64 = 16;

/// FR-FCFS starvation cap: once a request has waited this long, it may
/// close an open row even while younger row hits keep arriving (the
/// classic FR-FCFS+cap fix — without it, a streaming core can starve a
/// conflicting one indefinitely).
pub const STARVE_CAP_CYCLES: u64 = 256;

/// BLISS: consecutive column commands served to one core before it is
/// blacklisted.
pub const BLISS_STREAK_CAP: u32 = 4;

/// BLISS: the blacklist is cleared on this fixed bus-cycle grid. A grid
/// (rather than `now + interval`) keeps clearing deterministic between
/// the strict-tick and event-driven loops, which visit different cycles.
pub const BLISS_CLEAR_INTERVAL: u64 = 10_000;

/// Which scheduler a controller runs (`SystemConfig::mc.scheduler`,
/// CLI `--scheduler`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    /// FR-FCFS with conflict hysteresis and a starvation cap (default).
    FrFcfs,
    /// Strict first-come-first-served (no row-hit reordering).
    Fcfs,
    /// FR-FCFS with BLISS-style application blacklisting.
    Bliss,
}

/// Canonical scheduler names in [`SchedulerKind::all`] order — the
/// single source for CLI parsing (`--scheduler`), registry choices
/// (`--set mc.scheduler=`), and scenario specs.
pub const SCHEDULER_NAMES: [&str; 3] = ["fr-fcfs", "fcfs", "bliss"];

impl SchedulerKind {
    pub fn all() -> [SchedulerKind; 3] {
        [SchedulerKind::FrFcfs, SchedulerKind::Fcfs, SchedulerKind::Bliss]
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::FrFcfs => "FR-FCFS",
            SchedulerKind::Fcfs => "FCFS",
            SchedulerKind::Bliss => "BLISS",
        }
    }

    /// Canonical lowercase name (the parse/print round-trip identity).
    pub fn name(&self) -> &'static str {
        SCHEDULER_NAMES[match self {
            SchedulerKind::FrFcfs => 0,
            SchedulerKind::Fcfs => 1,
            SchedulerKind::Bliss => 2,
        }]
    }

    /// Parse a scheduler name case-insensitively (`frfcfs` tolerated).
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "fr-fcfs" | "frfcfs" => Some(SchedulerKind::FrFcfs),
            "fcfs" => Some(SchedulerKind::Fcfs),
            "bliss" => Some(SchedulerKind::Bliss),
            _ => None,
        }
    }

    /// `name | name | ...` list for unknown-scheduler error messages.
    pub fn valid_names() -> String {
        SCHEDULER_NAMES.join(" | ")
    }
}

/// Read-only scheduling context for one bus cycle: the device timing
/// surface, refresh-drain flags, and the per-bank request index.
pub struct SchedCtx<'a> {
    pub dev: &'a Channel,
    pub ref_drain: &'a [bool],
    pub engine: &'a BankEngine,
    pub now: u64,
}

/// One scheduling policy. Implementations must be deterministic pure
/// functions of (their own state, the context, the queue) — the
/// strict-tick differential oracle depends on it.
pub trait SchedPolicy: Send {
    fn kind(&self) -> SchedulerKind;

    /// Pass 1: slot key ([`RequestQueue::iter_keyed`]) of the request
    /// whose ready column command should issue this cycle, or `None`.
    fn pick_column(&mut self, ctx: &SchedCtx, queue: &RequestQueue) -> Option<u32>;

    /// Pass 2: `(slot key, Activate | Precharge)` to issue, or `None`.
    fn pick_act_pre(
        &mut self,
        ctx: &SchedCtx,
        queue: &RequestQueue,
    ) -> Option<(u32, CommandKind)>;

    /// Wake-bound contribution (see module docs): a lower bound over both
    /// queues on the earliest cycle `>= ctx.now` at which this policy
    /// could issue any command. Must never be later than the true next
    /// issue cycle.
    fn next_ready_at(&self, ctx: &SchedCtx, rq: &RequestQueue, wq: &RequestQueue) -> u64;

    /// A column command issued for `core`'s request (BLISS bookkeeping).
    fn on_column_issued(&mut self, _now: u64, _core: u32) {}

    /// Checkpoint hook: stateless policies (FR-FCFS, FCFS) keep the
    /// defaults, which write/consume nothing.
    fn export_state(&self, _enc: &mut crate::sim::checkpoint::Enc) {}

    /// Restore what [`SchedPolicy::export_state`] wrote.
    fn import_state(&mut self, _dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        Some(())
    }
}

/// Build the policy instance for one controller.
pub fn build_policy(kind: SchedulerKind) -> Box<dyn SchedPolicy> {
    match kind {
        SchedulerKind::FrFcfs => Box::new(FrFcfs),
        SchedulerKind::Fcfs => Box::new(Fcfs),
        SchedulerKind::Bliss => Box::new(Bliss::new()),
    }
}

#[inline]
fn column_kind(req: &Request) -> CommandKind {
    if req.is_write {
        CommandKind::Write
    } else {
        CommandKind::Read
    }
}

/// Shared wake-bound term: the cycle `req`'s next command becomes
/// timing-legal, or `None` when the request is parked behind a refresh
/// drain or a pending auto-precharge (both are separate wake events owned
/// by the controller layer). `conflict_age` folds the policy's hysteresis
/// into the conflict-PRE term (a pure function of the request, so it
/// keeps the bound tight on row-conflict traffic).
fn request_ready_at(ctx: &SchedCtx, req: &Request, conflict_age: u64) -> Option<u64> {
    if ctx.ref_drain[req.loc.rank as usize] {
        return None;
    }
    let bank = ctx.dev.bank(&req.loc);
    if bank.next_autopre_at().is_some() {
        return None; // logically closing; its autopre is the event
    }
    Some(match bank.open_row() {
        Some(row) if row == req.loc.row => ctx.dev.earliest_issue(column_kind(req), &req.loc),
        Some(_) => ctx
            .dev
            .earliest_issue(CommandKind::Precharge, &req.loc)
            .max(req.arrived + conflict_age),
        None => ctx.dev.earliest_issue(CommandKind::Activate, &req.loc),
    })
}

/// Min of [`request_ready_at`] over every request in both queues — the
/// FR-FCFS-shaped bound (also sound for BLISS, whose blacklist reorders
/// preferences but never changes *when* a command first becomes legal).
fn all_requests_ready_at(
    ctx: &SchedCtx,
    rq: &RequestQueue,
    wq: &RequestQueue,
    conflict_age: u64,
) -> u64 {
    let mut t = u64::MAX;
    for req in rq.iter().chain(wq.iter()) {
        if let Some(c) = request_ready_at(ctx, req, conflict_age) {
            t = t.min(c);
        }
    }
    t
}

// ---------------------------------------------------------------------
// FR-FCFS + starvation cap (the default; extracted verbatim from the
// pre-refactor monolithic scheduler).
// ---------------------------------------------------------------------

/// First-ready FCFS with conflict hysteresis and a starvation cap.
pub struct FrFcfs;

impl SchedPolicy for FrFcfs {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::FrFcfs
    }

    fn pick_column(&mut self, ctx: &SchedCtx, queue: &RequestQueue) -> Option<u32> {
        for (key, req) in queue.iter_keyed() {
            if ctx.ref_drain[req.loc.rank as usize] {
                continue;
            }
            if ctx.dev.bank(&req.loc).open_row() != Some(req.loc.row) {
                continue;
            }
            if ctx.dev.can_issue(column_kind(req), &req.loc, ctx.now) {
                return Some(key);
            }
        }
        None
    }

    fn pick_act_pre(
        &mut self,
        ctx: &SchedCtx,
        queue: &RequestQueue,
    ) -> Option<(u32, CommandKind)> {
        for (key, req) in queue.iter_keyed() {
            if ctx.ref_drain[req.loc.rank as usize] {
                continue;
            }
            let bank = ctx.dev.bank(&req.loc);
            if bank.next_autopre_at().is_some() {
                continue; // logically closing; wait for the autopre
            }
            match bank.open_row() {
                None => {
                    if ctx.dev.can_issue(CommandKind::Activate, &req.loc, ctx.now) {
                        return Some((key, CommandKind::Activate));
                    }
                }
                Some(open) if open != req.loc.row => {
                    // Precharge only when no queued request still hits the
                    // open row (in either queue) — FR-FCFS row-hit-first —
                    // and the conflicting request has aged past the
                    // hysteresis window. The aging guard keeps a stream's
                    // in-flight same-row access (trickling in through the
                    // MSHRs) from losing its open row to a premature
                    // conflict precharge. Requests older than the
                    // starvation cap override the row-hit priority.
                    let age = ctx.now.saturating_sub(req.arrived);
                    let starving = age >= STARVE_CAP_CYCLES;
                    if age >= CONFLICT_AGE_CYCLES
                        && (starving || !ctx.engine.open_row_has_hit(req.loc.rank, req.loc.bank))
                        && ctx.dev.can_issue(CommandKind::Precharge, &req.loc, ctx.now)
                    {
                        return Some((key, CommandKind::Precharge));
                    }
                }
                Some(_) => {} // row hit, column not ready yet
            }
        }
        None
    }

    fn next_ready_at(&self, ctx: &SchedCtx, rq: &RequestQueue, wq: &RequestQueue) -> u64 {
        all_requests_ready_at(ctx, rq, wq, CONFLICT_AGE_CYCLES)
    }
}

// ---------------------------------------------------------------------
// Strict FCFS.
// ---------------------------------------------------------------------

/// Strict arrival-order scheduling: the oldest schedulable request (the
/// oldest one whose rank is not refresh-draining) is the *only*
/// candidate; nothing younger may overtake it, row hit or not.
pub struct Fcfs;

/// The head candidate of one queue under strict FCFS.
fn fcfs_candidate<'q>(ctx: &SchedCtx, queue: &'q RequestQueue) -> Option<(u32, &'q Request)> {
    queue
        .iter_keyed()
        .find(|(_, r)| !ctx.ref_drain[r.loc.rank as usize])
}

impl SchedPolicy for Fcfs {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fcfs
    }

    fn pick_column(&mut self, ctx: &SchedCtx, queue: &RequestQueue) -> Option<u32> {
        let (key, req) = fcfs_candidate(ctx, queue)?;
        if ctx.dev.bank(&req.loc).open_row() == Some(req.loc.row)
            && ctx.dev.can_issue(column_kind(req), &req.loc, ctx.now)
        {
            Some(key)
        } else {
            None
        }
    }

    fn pick_act_pre(
        &mut self,
        ctx: &SchedCtx,
        queue: &RequestQueue,
    ) -> Option<(u32, CommandKind)> {
        let (key, req) = fcfs_candidate(ctx, queue)?;
        let bank = ctx.dev.bank(&req.loc);
        if bank.next_autopre_at().is_some() {
            return None;
        }
        match bank.open_row() {
            None if ctx.dev.can_issue(CommandKind::Activate, &req.loc, ctx.now) => {
                Some((key, CommandKind::Activate))
            }
            // Head-of-queue conflicts close the row as soon as the PRE is
            // legal: strict FCFS has no row-hit-first protection and
            // therefore needs no hysteresis or starvation cap.
            Some(open)
                if open != req.loc.row
                    && ctx.dev.can_issue(CommandKind::Precharge, &req.loc, ctx.now) =>
            {
                Some((key, CommandKind::Precharge))
            }
            _ => None,
        }
    }

    fn next_ready_at(&self, ctx: &SchedCtx, rq: &RequestQueue, wq: &RequestQueue) -> u64 {
        // Only the head candidate of each queue can issue; which queue is
        // served depends on the controller's write-drain state, so min
        // over both (the non-serving head's bound is merely early, and an
        // early wake is a no-op tick).
        let mut t = u64::MAX;
        for queue in [rq, wq] {
            if let Some((_, req)) = fcfs_candidate(ctx, queue) {
                if let Some(c) = request_ready_at(ctx, req, 0) {
                    t = t.min(c);
                }
            }
        }
        t
    }
}

// ---------------------------------------------------------------------
// BLISS-style blacklisting.
// ---------------------------------------------------------------------

/// FR-FCFS order with application blacklisting: a core served
/// [`BLISS_STREAK_CAP`] consecutive column commands is blacklisted until
/// the next [`BLISS_CLEAR_INTERVAL`] grid point. Non-blacklisted requests
/// win both passes, and a blacklisted core's open row loses its
/// row-hit-first protection against non-blacklisted conflicts (the O(1)
/// stand-in for full BLISS priority inversion, using the bank's
/// activation owner).
pub struct Bliss {
    blacklist: HashSet<u32>,
    last_core: Option<u32>,
    streak: u32,
    next_clear: u64,
}

impl Bliss {
    pub fn new() -> Self {
        Self {
            blacklist: HashSet::new(),
            last_core: None,
            streak: 0,
            next_clear: BLISS_CLEAR_INTERVAL,
        }
    }

    /// Catch up to the clearing grid. Called at every pick so the state
    /// at any decision cycle is a function of (issue history, cycle)
    /// alone — identical between the strict and event loops even though
    /// they visit different cycles.
    fn maybe_clear(&mut self, now: u64) {
        while now >= self.next_clear {
            self.blacklist.clear();
            self.next_clear += BLISS_CLEAR_INTERVAL;
        }
    }

    #[inline]
    fn listed(&self, core: u32) -> bool {
        self.blacklist.contains(&core)
    }

    /// Is `req` an eligible pass-2 action, and which one?
    fn act_pre_of(&self, ctx: &SchedCtx, req: &Request) -> Option<CommandKind> {
        let bank = ctx.dev.bank(&req.loc);
        if bank.next_autopre_at().is_some() {
            return None;
        }
        match bank.open_row() {
            None if ctx.dev.can_issue(CommandKind::Activate, &req.loc, ctx.now) => {
                Some(CommandKind::Activate)
            }
            Some(open) if open != req.loc.row => {
                let age = ctx.now.saturating_sub(req.arrived);
                let starving = age >= STARVE_CAP_CYCLES;
                // A blacklisted owner forfeits row-hit-first protection
                // against a non-blacklisted conflicting request.
                let owner_forfeits =
                    self.listed(bank.open_owner) && !self.listed(req.core);
                if age >= CONFLICT_AGE_CYCLES
                    && (starving
                        || owner_forfeits
                        || !ctx.engine.open_row_has_hit(req.loc.rank, req.loc.bank))
                    && ctx.dev.can_issue(CommandKind::Precharge, &req.loc, ctx.now)
                {
                    Some(CommandKind::Precharge)
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

impl Default for Bliss {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedPolicy for Bliss {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Bliss
    }

    fn pick_column(&mut self, ctx: &SchedCtx, queue: &RequestQueue) -> Option<u32> {
        self.maybe_clear(ctx.now);
        let mut fallback = None;
        for (key, req) in queue.iter_keyed() {
            if ctx.ref_drain[req.loc.rank as usize] {
                continue;
            }
            if ctx.dev.bank(&req.loc).open_row() != Some(req.loc.row) {
                continue;
            }
            if ctx.dev.can_issue(column_kind(req), &req.loc, ctx.now) {
                if !self.listed(req.core) {
                    return Some(key);
                }
                if fallback.is_none() {
                    fallback = Some(key);
                }
            }
        }
        fallback
    }

    fn pick_act_pre(
        &mut self,
        ctx: &SchedCtx,
        queue: &RequestQueue,
    ) -> Option<(u32, CommandKind)> {
        self.maybe_clear(ctx.now);
        let mut fallback = None;
        for (key, req) in queue.iter_keyed() {
            if ctx.ref_drain[req.loc.rank as usize] {
                continue;
            }
            if let Some(kind) = self.act_pre_of(ctx, req) {
                if !self.listed(req.core) {
                    return Some((key, kind));
                }
                if fallback.is_none() {
                    fallback = Some((key, kind));
                }
            }
        }
        fallback
    }

    fn next_ready_at(&self, ctx: &SchedCtx, rq: &RequestQueue, wq: &RequestQueue) -> u64 {
        // The blacklist reorders preferences among *ready* requests; it
        // never delays the first legal issue past the FR-FCFS bound (the
        // owner-forfeits rule only widens eligibility), so the FR-FCFS
        // scan is a sound lower bound here too.
        all_requests_ready_at(ctx, rq, wq, CONFLICT_AGE_CYCLES)
    }

    fn on_column_issued(&mut self, now: u64, core: u32) {
        // LLC writebacks carry the pseudo-core u32::MAX; they are not an
        // application, so they neither accrue a streak, get blacklisted,
        // nor break a real core's streak.
        if core == u32::MAX {
            return;
        }
        self.maybe_clear(now);
        if self.last_core == Some(core) {
            self.streak += 1;
            if self.streak >= BLISS_STREAK_CAP {
                self.blacklist.insert(core);
            }
        } else {
            self.last_core = Some(core);
            self.streak = 1;
        }
    }

    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::POLICY);
        let mut listed: Vec<u32> = self.blacklist.iter().copied().collect();
        listed.sort_unstable();
        enc.usize(listed.len());
        for c in listed {
            enc.u32(c);
        }
        enc.opt_u32(self.last_core);
        enc.u32(self.streak);
        enc.u64(self.next_clear);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::POLICY)?;
        let n = dec.usize()?;
        self.blacklist.clear();
        for _ in 0..n {
            self.blacklist.insert(dec.u32()?);
        }
        self.last_core = dec.opt_u32()?;
        self.streak = dec.u32()?;
        self.next_clear = dec.u64()?;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_kind_labels_are_distinct() {
        let labels: HashSet<&str> = SchedulerKind::all().iter().map(|k| k.label()).collect();
        assert_eq!(labels.len(), 3);
    }

    #[test]
    fn build_policy_round_trips_kind() {
        for kind in SchedulerKind::all() {
            assert_eq!(build_policy(kind).kind(), kind);
        }
    }

    #[test]
    fn bliss_blacklists_after_streak_and_clears_on_grid() {
        let mut b = Bliss::new();
        for _ in 0..BLISS_STREAK_CAP {
            b.on_column_issued(10, 3);
        }
        assert!(b.listed(3));
        b.on_column_issued(11, 5);
        assert!(b.listed(3), "other cores do not clear the list");
        b.maybe_clear(BLISS_CLEAR_INTERVAL);
        assert!(!b.listed(3), "grid point clears the blacklist");
        assert_eq!(b.next_clear, 2 * BLISS_CLEAR_INTERVAL);
    }

    #[test]
    fn bliss_clear_grid_is_catch_up_not_restart() {
        let mut b = Bliss::new();
        // Jump far past several grid points in one step (the event loop
        // does this); next_clear must land on the grid, not at now + I.
        b.maybe_clear(3 * BLISS_CLEAR_INTERVAL + 17);
        assert_eq!(b.next_clear, 4 * BLISS_CLEAR_INTERVAL);
    }

    #[test]
    fn bliss_streak_resets_on_core_change() {
        let mut b = Bliss::new();
        b.on_column_issued(0, 1);
        b.on_column_issued(1, 1);
        b.on_column_issued(2, 2);
        b.on_column_issued(3, 1);
        assert!(!b.listed(1));
        assert!(!b.listed(2));
    }
}
