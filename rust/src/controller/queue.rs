//! Memory request queues (64-entry read + write queues per channel),
//! slab-backed with stable slot keys.

use crate::dram::command::Loc;

/// A memory request as seen by the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Globally unique id (completion matching).
    pub id: u64,
    /// Issuing core.
    pub core: u32,
    pub loc: Loc,
    pub is_write: bool,
    /// Bus cycle the request entered the controller.
    pub arrived: u64,
}

/// Null slot link.
const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct Slot {
    req: Request,
    prev: u32,
    next: u32,
    /// Currently threaded into the arrival list. Guards (in debug
    /// builds) against a policy handing back a stale key: the pre-slab
    /// `Vec::remove(idx)` panicked on out-of-range, but a recycled slot
    /// index would otherwise corrupt the freelist silently.
    linked: bool,
}

/// FIFO-ordered request queue with capacity; FR-FCFS scans it in arrival
/// order so "oldest first" falls out of iteration order.
///
/// Arrival order is an intrusive doubly-linked list threaded through a
/// slab of slots: `push` appends at the tail, `remove(key)` unlinks in
/// O(1) — the pre-slab `Vec<Request>` shifted every younger request left
/// on each issued column command — and iteration follows the links, so
/// FR-FCFS/FCFS/BLISS see exactly the arrival order the Vec gave them.
/// Slot keys are stable while a request is queued (scheduler picks
/// return them), and retired slots recycle through a freelist, so a warm
/// queue never allocates.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    slots: Vec<Slot>,
    free: Vec<u32>,
    head: u32,
    tail: u32,
    len: usize,
    cap: usize,
}

impl RequestQueue {
    pub fn new(cap: usize) -> Self {
        Self {
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            head: NIL,
            tail: NIL,
            len: 0,
            cap,
        }
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.cap
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Append at the tail (arrival order). Returns false if full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.is_full() {
            return false;
        }
        let slot = Slot { req, prev: self.tail, next: NIL, linked: true };
        let key = match self.free.pop() {
            Some(k) => {
                debug_assert!(!self.slots[k as usize].linked, "freelist slot still linked");
                self.slots[k as usize] = slot;
                k
            }
            None => {
                self.slots.push(slot);
                (self.slots.len() - 1) as u32
            }
        };
        if self.tail == NIL {
            self.head = key;
        } else {
            self.slots[self.tail as usize].next = key;
        }
        self.tail = key;
        self.len += 1;
        true
    }

    /// Remove by slot key (after the scheduler issued its column
    /// command): O(1) unlink; the key is recycled.
    pub fn remove(&mut self, key: u32) -> Request {
        debug_assert!(self.len > 0, "remove from an empty queue");
        debug_assert!(self.slots[key as usize].linked, "remove with a stale slot key");
        self.slots[key as usize].linked = false;
        let Slot { req, prev, next, .. } = self.slots[key as usize];
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev as usize].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next as usize].prev = prev;
        }
        self.free.push(key);
        self.len -= 1;
        req
    }

    /// The request behind a (currently queued) slot key.
    pub fn get(&self, key: u32) -> Request {
        debug_assert!(self.slots[key as usize].linked, "get with a stale slot key");
        self.slots[key as usize].req
    }

    /// Arrival-order iteration yielding `(slot key, request)` — the keys
    /// the scheduler's picks hand back to [`RequestQueue::get`] /
    /// [`RequestQueue::remove`].
    pub fn iter_keyed(&self) -> IterKeyed<'_> {
        IterKeyed { slots: &self.slots, cur: self.head }
    }

    /// Arrival-order iteration over the requests alone.
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.iter_keyed().map(|(_, r)| r)
    }

    /// Is a request with this id still queued? (Classification-map sweep
    /// at `finalize`.)
    pub fn contains_id(&self, id: u64) -> bool {
        self.iter().any(|r| r.id == id)
    }

    /// Checkpoint: the slab is serialized verbatim — slot order and the
    /// freelist pin which keys future pushes hand out, and stale (freed)
    /// slots keep their last contents so the restored slab is
    /// word-identical to the captured one.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::QUEUE);
        enc.usize(self.slots.len());
        for s in &self.slots {
            enc.u64(s.req.id);
            enc.u32(s.req.core);
            enc.u32(s.req.loc.channel);
            enc.u32(s.req.loc.rank);
            enc.u32(s.req.loc.bank);
            enc.u32(s.req.loc.row);
            enc.u32(s.req.loc.col);
            enc.bool(s.req.is_write);
            enc.u64(s.req.arrived);
            enc.u32(s.prev);
            enc.u32(s.next);
            enc.bool(s.linked);
        }
        enc.usize(self.free.len());
        for &k in &self.free {
            enc.u32(k);
        }
        enc.u32(self.head);
        enc.u32(self.tail);
        enc.usize(self.len);
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::dram::command::Loc;
        use crate::sim::checkpoint::tags;
        dec.tag(tags::QUEUE)?;
        let n = dec.usize()?;
        if n > self.cap {
            return None; // capacity is config-derived shape
        }
        self.slots.clear();
        for _ in 0..n {
            let req = Request {
                id: dec.u64()?,
                core: dec.u32()?,
                loc: Loc {
                    channel: dec.u32()?,
                    rank: dec.u32()?,
                    bank: dec.u32()?,
                    row: dec.u32()?,
                    col: dec.u32()?,
                },
                is_write: dec.bool()?,
                arrived: dec.u64()?,
            };
            let prev = dec.u32()?;
            let next = dec.u32()?;
            let linked = dec.bool()?;
            self.slots.push(Slot { req, prev, next, linked });
        }
        let free_n = dec.usize()?;
        self.free.clear();
        for _ in 0..free_n {
            self.free.push(dec.u32()?);
        }
        self.head = dec.u32()?;
        self.tail = dec.u32()?;
        self.len = dec.usize()?;
        if self.len > self.cap {
            return None;
        }
        Some(())
    }
}

/// Arrival-order iterator over `(slot key, request)` pairs.
pub struct IterKeyed<'a> {
    slots: &'a [Slot],
    cur: u32,
}

impl<'a> Iterator for IterKeyed<'a> {
    type Item = (u32, &'a Request);

    fn next(&mut self) -> Option<Self::Item> {
        if self.cur == NIL {
            return None;
        }
        let key = self.cur;
        let slot = &self.slots[key as usize];
        self.cur = slot.next;
        Some((key, &slot.req))
    }
}

// Row-hit scans over the queue (`has_row_hit` / `another_hit_exists`)
// used to live here; the BankEngine's incremental per-bank index
// (`controller::bank_engine`) replaced every caller.

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, bank: u32, row: u32) -> Request {
        Request {
            id,
            core: 0,
            loc: Loc { channel: 0, rank: 0, bank, row, col: 0 },
            is_write: false,
            arrived: id,
        }
    }

    fn key_at(q: &RequestQueue, pos: usize) -> u32 {
        q.iter_keyed().nth(pos).expect("position in range").0
    }

    #[test]
    fn capacity_enforced() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        assert!(!q.push(req(2, 0, 0)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn contains_id_tracks_membership() {
        let mut q = RequestQueue::new(8);
        q.push(req(7, 1, 10));
        assert!(q.contains_id(7));
        assert!(!q.contains_id(8));
        let k = key_at(&q, 0);
        q.remove(k);
        assert!(!q.contains_id(7));
    }

    #[test]
    fn fifo_order_preserved_on_remove() {
        let mut q = RequestQueue::new(8);
        for i in 0..4 {
            q.push(req(i, 0, i as u32));
        }
        let r = q.remove(key_at(&q, 1));
        assert_eq!(r.id, 1);
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
    }

    #[test]
    fn keys_are_stable_across_unrelated_removals() {
        let mut q = RequestQueue::new(8);
        for i in 0..4 {
            q.push(req(i, 0, i as u32));
        }
        let key3 = q.iter_keyed().find(|(_, r)| r.id == 3).unwrap().0;
        q.remove(key_at(&q, 0));
        q.remove(key_at(&q, 0));
        // Two older entries left; id 3's key still resolves to id 3.
        assert_eq!(q.get(key3).id, 3);
        assert_eq!(q.remove(key3).id, 3);
    }

    #[test]
    fn recycled_slots_keep_arrival_order() {
        let mut q = RequestQueue::new(4);
        for i in 0..4 {
            q.push(req(i, 0, 0));
        }
        // Remove from the middle and head, then refill: iteration must be
        // pure arrival order regardless of which slab slots got reused.
        q.remove(key_at(&q, 2));
        q.remove(key_at(&q, 0));
        assert!(q.push(req(10, 0, 0)));
        assert!(q.push(req(11, 0, 0)));
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 10, 11]);
        assert!(q.is_full());
    }

    #[test]
    fn checkpoint_restores_slab_keys_exactly() {
        use crate::sim::checkpoint::{Dec, Enc};
        let mut q = RequestQueue::new(4);
        for i in 0..4 {
            q.push(req(i, 0, i as u32));
        }
        q.remove(key_at(&q, 2));
        q.remove(key_at(&q, 0));
        q.push(req(10, 1, 5));
        let mut enc = Enc::new();
        q.export_state(&mut enc);
        let words = enc.into_words();
        let mut fresh = RequestQueue::new(4);
        let mut dec = Dec::new(&words);
        fresh.import_state(&mut dec).unwrap();
        assert!(dec.finished());
        let mut enc2 = Enc::new();
        fresh.export_state(&mut enc2);
        assert_eq!(enc2.into_words(), words, "re-export must be word-identical");
        // Future pushes must hand out the same recycled keys.
        assert!(fresh.push(req(20, 0, 1)));
        assert!(q.push(req(20, 0, 1)));
        let keys = |qq: &RequestQueue| qq.iter_keyed().map(|(k, r)| (k, r.id)).collect::<Vec<_>>();
        assert_eq!(keys(&fresh), keys(&q));
        // A slab bigger than the capacity is rejected.
        let mut tiny = RequestQueue::new(2);
        let mut dec2 = Dec::new(&words);
        assert!(tiny.import_state(&mut dec2).is_none());
    }

    #[test]
    fn drain_to_empty_and_reuse() {
        let mut q = RequestQueue::new(3);
        for round in 0..5u64 {
            for i in 0..3 {
                assert!(q.push(req(round * 10 + i, 0, 0)));
            }
            while !q.is_empty() {
                q.remove(key_at(&q, 0));
            }
            assert_eq!(q.len(), 0);
            assert!(q.iter().next().is_none());
        }
    }
}
