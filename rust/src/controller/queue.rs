//! Memory request queues (64-entry read + write queues per channel).

use crate::dram::command::Loc;

/// A memory request as seen by the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Globally unique id (completion matching).
    pub id: u64,
    /// Issuing core.
    pub core: u32,
    pub loc: Loc,
    pub is_write: bool,
    /// Bus cycle the request entered the controller.
    pub arrived: u64,
}

/// FIFO-ordered request queue with capacity; FR-FCFS scans it in arrival
/// order so "oldest first" falls out of iteration order.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    items: Vec<Request>,
    cap: usize,
}

impl RequestQueue {
    pub fn new(cap: usize) -> Self {
        Self { items: Vec::with_capacity(cap), cap }
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.cap
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn push(&mut self, req: Request) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push(req);
        true
    }

    /// Remove by position (after the scheduler issued its column command).
    pub fn remove(&mut self, idx: usize) -> Request {
        self.items.remove(idx)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.items.iter()
    }

    /// Index access in arrival order (scheduler scans by position).
    pub fn get(&self, idx: usize) -> Request {
        self.items[idx]
    }

    /// Any queued request that hits `row` open in the same bank?
    pub fn has_row_hit(&self, loc: &Loc, row: u32) -> bool {
        self.items
            .iter()
            .any(|r| r.loc.rank == loc.rank && r.loc.bank == loc.bank && r.loc.row == row)
    }

    /// Any queued request (other than index `skip`) targeting the same
    /// bank and row? Used by the closed-row policy to pick RDA vs RD.
    pub fn another_hit_exists(&self, skip: usize, loc: &Loc) -> bool {
        self.items.iter().enumerate().any(|(i, r)| {
            i != skip
                && r.loc.rank == loc.rank
                && r.loc.bank == loc.bank
                && r.loc.row == loc.row
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, bank: u32, row: u32) -> Request {
        Request {
            id,
            core: 0,
            loc: Loc { channel: 0, rank: 0, bank, row, col: 0 },
            is_write: false,
            arrived: id,
        }
    }

    #[test]
    fn capacity_enforced() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        assert!(!q.push(req(2, 0, 0)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn row_hit_detection() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 1, 10));
        q.push(req(1, 1, 11));
        let probe = Loc { channel: 0, rank: 0, bank: 1, row: 0, col: 0 };
        assert!(q.has_row_hit(&probe, 10));
        assert!(q.has_row_hit(&probe, 11));
        assert!(!q.has_row_hit(&probe, 12));
    }

    #[test]
    fn another_hit_skips_self() {
        let mut q = RequestQueue::new(8);
        q.push(req(0, 1, 10));
        q.push(req(1, 1, 10));
        let loc = Loc { channel: 0, rank: 0, bank: 1, row: 10, col: 0 };
        assert!(q.another_hit_exists(0, &loc));
        let mut q2 = RequestQueue::new(8);
        q2.push(req(0, 1, 10));
        assert!(!q2.another_hit_exists(0, &loc));
    }

    #[test]
    fn fifo_order_preserved_on_remove() {
        let mut q = RequestQueue::new(8);
        for i in 0..4 {
            q.push(req(i, 0, i as u32));
        }
        let r = q.remove(1);
        assert_eq!(r.id, 1);
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
    }
}
