//! Memory request queues (64-entry read + write queues per channel).

use crate::dram::command::Loc;

/// A memory request as seen by the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Globally unique id (completion matching).
    pub id: u64,
    /// Issuing core.
    pub core: u32,
    pub loc: Loc,
    pub is_write: bool,
    /// Bus cycle the request entered the controller.
    pub arrived: u64,
}

/// FIFO-ordered request queue with capacity; FR-FCFS scans it in arrival
/// order so "oldest first" falls out of iteration order.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    items: Vec<Request>,
    cap: usize,
}

impl RequestQueue {
    pub fn new(cap: usize) -> Self {
        Self { items: Vec::with_capacity(cap), cap }
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.cap
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn push(&mut self, req: Request) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push(req);
        true
    }

    /// Remove by position (after the scheduler issued its column command).
    pub fn remove(&mut self, idx: usize) -> Request {
        self.items.remove(idx)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.items.iter()
    }

    /// Index access in arrival order (scheduler scans by position).
    pub fn get(&self, idx: usize) -> Request {
        self.items[idx]
    }

    /// Is a request with this id still queued? (Classification-map sweep
    /// at `finalize`.)
    pub fn contains_id(&self, id: u64) -> bool {
        self.items.iter().any(|r| r.id == id)
    }
}

// Row-hit scans over the queue (`has_row_hit` / `another_hit_exists`)
// used to live here; the BankEngine's incremental per-bank index
// (`controller::bank_engine`) replaced every caller.

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, bank: u32, row: u32) -> Request {
        Request {
            id,
            core: 0,
            loc: Loc { channel: 0, rank: 0, bank, row, col: 0 },
            is_write: false,
            arrived: id,
        }
    }

    #[test]
    fn capacity_enforced() {
        let mut q = RequestQueue::new(2);
        assert!(q.push(req(0, 0, 0)));
        assert!(q.push(req(1, 0, 0)));
        assert!(!q.push(req(2, 0, 0)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn contains_id_tracks_membership() {
        let mut q = RequestQueue::new(8);
        q.push(req(7, 1, 10));
        assert!(q.contains_id(7));
        assert!(!q.contains_id(8));
        q.remove(0);
        assert!(!q.contains_id(7));
    }

    #[test]
    fn fifo_order_preserved_on_remove() {
        let mut q = RequestQueue::new(8);
        for i in 0..4 {
            q.push(req(i, 0, i as u32));
        }
        let r = q.remove(1);
        assert_eq!(r.id, 1);
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
    }
}
