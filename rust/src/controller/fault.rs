//! Deterministic retention-fault model and timing-violation guard.
//!
//! ChargeCache's safety argument assumes every row precharged within the
//! caching duration tolerates reduced tRCD/tRAS. Retention and timing
//! margins actually vary per cell, row, and temperature (Hassan's
//! leakage characterization; AL-DRAM), so this module injects the
//! counter-examples: a seeded per-row hash marks a configurable fraction
//! of rows *weak*, with a true safe window shorter than the caching
//! duration, optionally shrunk further during deterministic
//! temperature-drift intervals. A reduced-timing ACT past a weak row's
//! true window is a **timing violation** — detectable (ECC-class) but
//! costly: the access replays at full timing and the row is evicted from
//! the mechanism table ([`crate::latency::Mechanism::on_violation`]).
//!
//! The guard side is the adaptive mitigation: per-row violation counters
//! feed a blacklist, and blacklisted rows keep reduced timing only
//! within a configurable guard band of the caching duration
//! (`fault.guard_band_pct`) — the knob the guard-band scenario sweeps
//! against performance.
//!
//! **Determinism under sharding.** Every decision derives from
//! `(seed, RowKey, cycle)` via stateless hashing plus per-channel history
//! (`last_pre`); there is no shared sequential RNG stream whose draw
//! order could depend on thread interleaving. [`FaultState`] lives in
//! each channel's [`super::CommandSink`], and the channel-sharded loop
//! delivers each channel a bit-identical command stream at any shard
//! count, so N-shard runs match 1-shard runs bit for bit.

use std::collections::HashMap;

use crate::config::SystemConfig;
use crate::latency::RowKey;

/// Outcome of checking a reduced-timing grant against the fault model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultCheck {
    /// The row is strong, or within its true safe window: grant stands.
    Safe,
    /// Blacklisted row past the mitigation guard band: the grant is
    /// clamped to full timing *before* issue — no violation occurs.
    Suppress,
    /// Weak row past its true safe window: the reduced access fails
    /// detectably and must replay at full timing.
    Violation,
}

/// SplitMix64 finalizer — a stateless avalanche hash, so weak-row
/// assignment and drift scheduling are pure functions of their inputs.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

const WEAK_SALT: u64 = 0x57EA_4B0B;
const DRIFT_SALT: u64 = 0xD21F_7A0C;

/// Per-channel fault-injection state: the ground-truth retention model
/// (invisible to the controller proper) plus the guard's learned
/// per-row violation counters and blacklist.
pub struct FaultState {
    enabled: bool,
    seed: u64,
    weak_ppm: u64,
    /// Full timing the mitigation falls back to.
    trcd_std: u64,
    tras_std: u64,
    /// A weak row's true safe window, in bus cycles.
    safe_window: u64,
    /// Safe window during a hot drift interval.
    drift_window: u64,
    /// Drift interval length in bus cycles (0 = no drift).
    drift_interval: u64,
    /// Blacklisted rows keep reduced timing only within this age.
    guard_window: u64,
    blacklist_threshold: u64,
    /// Last precharge cycle per weak row (ground-truth charge age).
    last_pre: HashMap<u64, u64>,
    /// Guard state: violations observed per row; rows at or past the
    /// threshold carry `blacklisted = true`.
    violations: HashMap<u64, (u64, bool)>,
}

impl FaultState {
    pub fn new(cfg: &SystemConfig) -> Self {
        let duration = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let f = &cfg.fault;
        Self {
            enabled: f.enabled,
            seed: cfg.seed,
            weak_ppm: f.weak_ppm,
            trcd_std: cfg.timing.trcd,
            tras_std: cfg.timing.tras,
            safe_window: duration * f.retention_pct / 100,
            drift_window: duration * f.drift_retention_pct / 100,
            drift_interval: cfg.timing.ms_to_cycles(f.drift_interval_ms),
            guard_window: duration * f.guard_band_pct / 100,
            blacklist_threshold: f.blacklist_threshold.max(1),
            last_pre: HashMap::new(),
            violations: HashMap::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Full (non-reduced) timing for suppressed grants.
    pub fn full_timing(&self) -> (u64, u64) {
        (self.trcd_std, self.tras_std)
    }

    /// Ground truth: is this row weak? Pure hash of `(seed, key)`.
    #[inline]
    pub fn is_weak(&self, key: RowKey) -> bool {
        mix64(self.seed ^ WEAK_SALT ^ key.0) % 1_000_000 < self.weak_ppm
    }

    /// Ground truth: a weak row's safe window at `now` — shrunk during
    /// hot drift intervals, which are picked by hashing the interval
    /// index (shard-invariant: depends only on the cycle).
    #[inline]
    fn safe_window_at(&self, now: u64) -> u64 {
        if self.drift_interval > 0
            && mix64(self.seed ^ DRIFT_SALT ^ (now / self.drift_interval)) % 4 == 0
        {
            self.drift_window
        } else {
            self.safe_window
        }
    }

    /// Record a precharge: the row's cells are replenished now. Only
    /// weak rows are tracked, so the map stays proportional to the weak
    /// fraction of the touched footprint.
    #[inline]
    pub fn note_precharge(&mut self, now: u64, key: RowKey) {
        if self.enabled && self.is_weak(key) {
            self.last_pre.insert(key.0, now);
        }
    }

    /// Check a reduced-timing grant for `key` at `now`. Call only when
    /// the mechanism actually granted reduced timing.
    pub fn check(&self, now: u64, key: RowKey) -> FaultCheck {
        if !self.is_weak(key) {
            return FaultCheck::Safe;
        }
        let age = match self.last_pre.get(&key.0) {
            Some(&t) => now.saturating_sub(t),
            // No recorded precharge (e.g. entry predates fault tracking):
            // charge age is unknown but at most the mechanism's own
            // bound; treat as fresh rather than inventing a violation.
            None => return FaultCheck::Safe,
        };
        if self.is_blacklisted(key) && age > self.guard_window {
            return FaultCheck::Suppress;
        }
        if age > self.safe_window_at(now) {
            return FaultCheck::Violation;
        }
        FaultCheck::Safe
    }

    #[inline]
    fn is_blacklisted(&self, key: RowKey) -> bool {
        self.violations.get(&key.0).is_some_and(|&(_, b)| b)
    }

    /// Count a violation against `key`; returns true when this crossing
    /// of the threshold newly blacklists the row.
    pub fn record_violation(&mut self, key: RowKey) -> bool {
        let e = self.violations.entry(key.0).or_insert((0, false));
        e.0 += 1;
        if !e.1 && e.0 >= self.blacklist_threshold {
            e.1 = true;
            return true;
        }
        false
    }

    /// Checkpoint hook: the learned guard state and charge ages survive
    /// warmup forking. Maps are written in sorted key order so the word
    /// stream is deterministic.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::FAULT);
        let mut pre: Vec<(u64, u64)> = self.last_pre.iter().map(|(&k, &v)| (k, v)).collect();
        pre.sort_unstable();
        enc.usize(pre.len());
        for (k, v) in pre {
            enc.u64(k);
            enc.u64(v);
        }
        let mut vio: Vec<(u64, u64, bool)> =
            self.violations.iter().map(|(&k, &(n, b))| (k, n, b)).collect();
        vio.sort_unstable();
        enc.usize(vio.len());
        for (k, n, b) in vio {
            enc.u64(k);
            enc.u64(n);
            enc.bool(b);
        }
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::FAULT)?;
        let n = dec.usize()?;
        self.last_pre = HashMap::with_capacity(n);
        for _ in 0..n {
            let k = dec.u64()?;
            let v = dec.u64()?;
            self.last_pre.insert(k, v);
        }
        let n = dec.usize()?;
        self.violations = HashMap::with_capacity(n);
        for _ in 0..n {
            let k = dec.u64()?;
            let c = dec.u64()?;
            let b = dec.bool()?;
            self.violations.insert(k, (c, b));
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn faulty_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.fault.enabled = true;
        cfg.fault.weak_ppm = 1_000_000; // every row weak
        cfg.fault.retention_pct = 50;
        cfg.fault.blacklist_threshold = 2;
        cfg.fault.guard_band_pct = 25;
        cfg
    }

    fn key(row: u32) -> RowKey {
        RowKey::new(0, 0, row)
    }

    #[test]
    fn weak_assignment_is_deterministic_and_density_scaled() {
        let mut cfg = SystemConfig::default();
        cfg.fault.enabled = true;
        cfg.fault.weak_ppm = 100_000; // 10%
        let a = FaultState::new(&cfg);
        let b = FaultState::new(&cfg);
        let weak: usize = (0..10_000).filter(|&r| a.is_weak(key(r))).count();
        // ~10% with hash noise.
        assert!((500..2000).contains(&weak), "weak count {weak} far from 10%");
        for r in 0..1000 {
            assert_eq!(a.is_weak(key(r)), b.is_weak(key(r)), "assignment must be pure");
        }
        // A different seed draws a different weak set.
        cfg.seed ^= 0xDEAD;
        let c = FaultState::new(&cfg);
        assert!((0..10_000).any(|r| a.is_weak(key(r)) != c.is_weak(key(r))));
    }

    #[test]
    fn violation_past_safe_window_and_blacklist_guard() {
        let cfg = faulty_cfg();
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut f = FaultState::new(&cfg);
        let k = key(3);
        assert!(f.is_weak(k));
        f.note_precharge(0, k);
        // Inside the 50% true window: safe.
        assert_eq!(f.check(dur / 4, k), FaultCheck::Safe);
        // Past it (but within the caching duration): violation.
        assert_eq!(f.check(dur * 3 / 4, k), FaultCheck::Violation);
        // Two violations blacklist the row.
        assert!(!f.record_violation(k));
        assert!(f.record_violation(k), "second violation crosses the threshold");
        assert!(!f.record_violation(k), "already blacklisted");
        // Blacklisted: past the 25% guard band the grant is suppressed
        // instead of violating...
        assert_eq!(f.check(dur / 2, k), FaultCheck::Suppress);
        // ...and within it, still honored.
        assert_eq!(f.check(dur / 8, k), FaultCheck::Safe);
    }

    #[test]
    fn unknown_charge_age_is_not_a_violation() {
        let cfg = faulty_cfg();
        let f = FaultState::new(&cfg);
        assert_eq!(f.check(1 << 40, key(9)), FaultCheck::Safe);
    }

    #[test]
    fn drift_intervals_shrink_the_window_deterministically() {
        let mut cfg = faulty_cfg();
        cfg.fault.drift_interval_ms = 0.1;
        cfg.fault.drift_retention_pct = 10;
        let f = FaultState::new(&cfg);
        let interval = cfg.timing.ms_to_cycles(0.1);
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        // Roughly a quarter of intervals are hot; pure in the index.
        let hot: Vec<bool> =
            (0..64).map(|i| f.safe_window_at(i * interval) == dur / 10).collect();
        assert!(hot.iter().any(|&h| h), "some interval must run hot");
        assert!(hot.iter().any(|&h| !h), "some interval must run cool");
        let again: Vec<bool> =
            (0..64).map(|i| f.safe_window_at(i * interval) == dur / 10).collect();
        assert_eq!(hot, again);
    }

    #[test]
    fn state_round_trips_through_checkpoint() {
        let cfg = faulty_cfg();
        let mut f = FaultState::new(&cfg);
        f.note_precharge(10, key(1));
        f.note_precharge(20, key(2));
        f.record_violation(key(1));
        f.record_violation(key(1));
        let mut enc = crate::sim::checkpoint::Enc::default();
        f.export_state(&mut enc);
        let words = enc.into_words();
        let mut g = FaultState::new(&cfg);
        let mut dec = crate::sim::checkpoint::Dec::new(&words);
        g.import_state(&mut dec).expect("round trip");
        assert!(dec.finished());
        assert_eq!(g.last_pre, f.last_pre);
        assert_eq!(g.violations, f.violations);
        assert!(g.is_blacklisted(key(1)));
    }
}
