//! CommandSink — the mechanism hook layer of the controller.
//!
//! Every observable command event flows through here exactly once: ACT
//! (mechanism lookup → timing grant, RLTL/reuse tracking), PRE (mechanism
//! insert, RLTL close, open-time accounting), REF, and column issue
//! (row-buffer classification, latency accounting). Before the layering,
//! these callbacks were threaded separately through `issue_precharge`,
//! `resolve_autopre`, and `schedule` — three call sites that had to agree
//! on ordering; now the controller calls one sink method per event and
//! the ChargeCache/NUAT hook semantics (Fig. 2 of the paper) live in a
//! single file.

use crate::analysis::{ReuseTracker, RltlTracker};
use crate::config::SystemConfig;
use crate::latency::{build_mechanism, Mechanism, MechanismKind, RowKey, TimingGrant};
use crate::sim::latency_hist::LatencyHist;

use super::fault::{FaultCheck, FaultState};

/// How a request's first DRAM command classified it (row-buffer outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqClass {
    Hit,
    Miss,
    Conflict,
}

/// Controller statistics (reset after warmup).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct McStats {
    pub acts: u64,
    pub acts_reduced: u64,
    pub reads: u64,
    pub writes: u64,
    pub precharges: u64,
    pub refreshes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub read_latency_sum: u64,
    pub read_latency_cnt: u64,
    /// Aggregate bank-open time (for active-standby energy).
    pub bank_open_cycles: u64,
    /// Forwarded from the write queue (no DRAM access).
    pub wq_forwards: u64,
    /// Enqueue rejections (queue full) — backpressure signal.
    pub rejects: u64,
    /// Reduced-timing ACTs past a weak row's true safe window
    /// ([`super::fault`]); each replays at full timing.
    pub timing_violations: u64,
    /// Violations whose row was actually evicted from the mechanism
    /// table (the entry can already be gone, e.g. swept).
    pub mitigation_evictions: u64,
    /// Reduced grants clamped to full timing by the blacklist guard
    /// band before issue (no violation occurred).
    pub guard_suppressed: u64,
    /// Rows newly blacklisted after crossing the violation threshold.
    pub rows_blacklisted: u64,
}

/// Single funnel for ACT/PRE/REF/column events: owns the latency
/// mechanism, the RLTL/reuse trackers, and the stats they feed.
pub struct CommandSink {
    mech: Box<dyn Mechanism>,
    pub rltl: RltlTracker,
    pub reuse: ReuseTracker,
    pub stats: McStats,
    /// Per-read latency distribution over this channel ([`LatencyHist`]);
    /// recorded for every read that issues a column command (closed- and
    /// open-loop alike), merged across channels in
    /// [`crate::sim::system::System::collect`].
    pub latency: LatencyHist,
    /// Retention-fault model + timing-violation guard (`fault.*`; inert
    /// when disabled).
    pub fault: FaultState,
}

impl CommandSink {
    pub fn new(cfg: &SystemConfig, kind: MechanismKind) -> Self {
        Self {
            mech: build_mechanism(kind, cfg),
            rltl: RltlTracker::new(cfg.timing.tck_ns),
            reuse: ReuseTracker::new(),
            stats: McStats::default(),
            latency: LatencyHist::new(),
            fault: FaultState::new(cfg),
        }
    }

    /// Replace the mechanism (coordinator sweeps reuse a controller).
    pub fn set_mechanism(&mut self, mech: Box<dyn Mechanism>) {
        self.mech = mech;
    }

    /// An ACT is being issued for `core`'s request: mechanism lookup
    /// (ChargeCache/NUAT timing grant), fault/guard check on reduced
    /// grants, RLTL + reuse tracking, stats.
    pub fn on_activate(&mut self, now: u64, core: u32, key: RowKey) -> TimingGrant {
        let mut grant = self.mech.on_activate(now, core, key);
        if grant.reduced && self.fault.enabled() {
            match self.fault.check(now, key) {
                FaultCheck::Safe => {}
                FaultCheck::Suppress => {
                    // Blacklist guard band: issue at full timing instead
                    // of risking a repeat violation on a known-weak row.
                    let (trcd, tras) = self.fault.full_timing();
                    grant = TimingGrant {
                        trcd,
                        tras,
                        reduced: false,
                    };
                    self.stats.guard_suppressed += 1;
                }
                FaultCheck::Violation => {
                    // The reduced ACT failed on a decayed weak row: evict
                    // it from the mechanism table and replay at full
                    // timing (the wasted reduced attempt plus a full
                    // tRCD), counting toward the adaptive blacklist.
                    self.stats.timing_violations += 1;
                    if self.mech.on_violation(now, core, key) {
                        self.stats.mitigation_evictions += 1;
                    }
                    if self.fault.record_violation(key) {
                        self.stats.rows_blacklisted += 1;
                    }
                    let (trcd_std, tras_std) = self.fault.full_timing();
                    grant = TimingGrant {
                        trcd: trcd_std + grant.trcd,
                        tras: tras_std,
                        reduced: false,
                    };
                }
            }
        }
        self.rltl.on_activate(now, key);
        self.reuse.on_activate(key);
        self.stats.acts += 1;
        if grant.reduced {
            self.stats.acts_reduced += 1;
        }
        grant
    }

    /// A row closed (explicit PRE, auto-precharge, or refresh drain):
    /// mechanism insert, RLTL close, open-time accounting.
    pub fn on_precharge(&mut self, now: u64, owner: u32, key: RowKey, act_cycle: u64) {
        self.mech.on_precharge(now, owner, key);
        self.fault.note_precharge(now, key);
        self.rltl.on_precharge(now, key);
        self.stats.precharges += 1;
        self.stats.bank_open_cycles += now.saturating_sub(act_cycle);
    }

    /// An all-bank REF completed on `rank`.
    pub fn on_refresh(&mut self, now: u64, rank: u32, refresh_count: u64) {
        self.mech.on_refresh(now, rank, refresh_count);
        self.stats.refreshes += 1;
    }

    /// A column command issued: row-buffer classification plus read
    /// latency (`Some(ready - arrived)` for reads, `None` for writes).
    pub fn on_column(&mut self, class: ReqClass, is_write: bool, read_latency: Option<u64>) {
        match class {
            ReqClass::Hit => self.stats.row_hits += 1,
            ReqClass::Miss => self.stats.row_misses += 1,
            ReqClass::Conflict => self.stats.row_conflicts += 1,
        }
        if is_write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
            let lat = read_latency.expect("reads carry a latency sample");
            self.stats.read_latency_sum += lat;
            self.stats.read_latency_cnt += 1;
            self.latency.record(lat);
        }
    }

    /// Reset statistics (end of warmup). Mechanism state is retained —
    /// that is the point of warmup.
    pub fn reset_stats(&mut self) {
        self.stats = McStats::default();
        self.rltl.reset_counts();
        self.latency.clear();
    }

    /// Checkpoint: mechanism tables (with their expiry clocks), both
    /// trackers, and the stat counters, in a fixed field order.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::SINK);
        enc.tag(tags::MECH);
        self.mech.export_state(enc);
        self.rltl.export_state(enc);
        self.reuse.export_state(enc);
        let s = &self.stats;
        for v in [
            s.acts,
            s.acts_reduced,
            s.reads,
            s.writes,
            s.precharges,
            s.refreshes,
            s.row_hits,
            s.row_misses,
            s.row_conflicts,
            s.read_latency_sum,
            s.read_latency_cnt,
            s.bank_open_cycles,
            s.wq_forwards,
            s.rejects,
            s.timing_violations,
            s.mitigation_evictions,
            s.guard_suppressed,
            s.rows_blacklisted,
        ] {
            enc.u64(v);
        }
        self.fault.export_state(enc);
        enc.tag(tags::TRAFFIC);
        self.latency.export_state(enc);
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::SINK)?;
        dec.tag(tags::MECH)?;
        self.mech.import_state(dec)?;
        self.rltl.import_state(dec)?;
        self.reuse.import_state(dec)?;
        let s = &mut self.stats;
        for v in [
            &mut s.acts,
            &mut s.acts_reduced,
            &mut s.reads,
            &mut s.writes,
            &mut s.precharges,
            &mut s.refreshes,
            &mut s.row_hits,
            &mut s.row_misses,
            &mut s.row_conflicts,
            &mut s.read_latency_sum,
            &mut s.read_latency_cnt,
            &mut s.bank_open_cycles,
            &mut s.wq_forwards,
            &mut s.rejects,
            &mut s.timing_violations,
            &mut s.mitigation_evictions,
            &mut s.guard_suppressed,
            &mut s.rows_blacklisted,
        ] {
            *v = dec.u64()?;
        }
        self.fault.import_state(dec)?;
        dec.tag(tags::TRAFFIC)?;
        self.latency.import_state(dec)?;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activate_precharge_update_stats_and_trackers() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::Baseline);
        let key = RowKey::new(0, 0, 7);
        let g = sink.on_activate(10, 0, key);
        assert!(!g.reduced);
        assert_eq!(sink.stats.acts, 1);
        assert_eq!(sink.rltl.activations, 1);
        sink.on_precharge(50, 0, key, 10);
        assert_eq!(sink.stats.precharges, 1);
        assert_eq!(sink.stats.bank_open_cycles, 40);
    }

    #[test]
    fn chargecache_grant_counts_reduced_acts() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 1, 3);
        sink.on_activate(0, 0, key);
        sink.on_precharge(40, 0, key, 0);
        let g = sink.on_activate(80, 0, key);
        assert!(g.reduced);
        assert_eq!(sink.stats.acts, 2);
        assert_eq!(sink.stats.acts_reduced, 1);
    }

    #[test]
    fn column_events_classify_and_accumulate_latency() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::Baseline);
        sink.on_column(ReqClass::Hit, false, Some(26));
        sink.on_column(ReqClass::Conflict, true, None);
        assert_eq!(sink.stats.row_hits, 1);
        assert_eq!(sink.stats.row_conflicts, 1);
        assert_eq!(sink.stats.reads, 1);
        assert_eq!(sink.stats.writes, 1);
        assert_eq!(sink.stats.read_latency_sum, 26);
    }

    fn faulty_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.fault.enabled = true;
        cfg.fault.weak_ppm = 1_000_000; // every row weak
        cfg.fault.retention_pct = 50;
        cfg.fault.guard_band_pct = 50;
        cfg.fault.blacklist_threshold = 1;
        cfg
    }

    #[test]
    fn violation_replays_at_full_timing_and_evicts() {
        let cfg = faulty_cfg();
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 2, 5);
        sink.on_activate(0, 0, key);
        sink.on_precharge(10, 0, key, 0);
        // Past the 50% true safe window but still inside the caching
        // duration: the HCRAC grants reduced timing, the fault model
        // catches it.
        let g = sink.on_activate(10 + dur * 3 / 4, 0, key);
        assert!(!g.reduced, "violation must clamp the grant");
        assert!(g.trcd > cfg.timing.trcd, "replay pays the wasted reduced attempt");
        assert_eq!(g.tras, cfg.timing.tras);
        assert_eq!(sink.stats.timing_violations, 1);
        assert_eq!(sink.stats.mitigation_evictions, 1);
        assert_eq!(sink.stats.rows_blacklisted, 1);
        assert_eq!(sink.stats.acts_reduced, 0);
        // The row was evicted: the next ACT misses the HCRAC entirely.
        let g2 = sink.on_activate(11 + dur * 3 / 4, 0, key);
        assert!(!g2.reduced);
        assert_eq!(g2.trcd, cfg.timing.trcd);
        assert_eq!(sink.stats.timing_violations, 1, "no fault check on a full-timing grant");
    }

    #[test]
    fn blacklisted_row_is_guard_suppressed_not_violated() {
        let cfg = faulty_cfg();
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 2, 5);
        sink.on_activate(0, 0, key);
        sink.on_precharge(10, 0, key, 0);
        sink.on_activate(10 + dur * 3 / 4, 0, key); // violation → blacklist
        // Re-cache the row, then come back past the guard band again:
        // this time the guard clamps the grant before issue.
        let t1 = 10 + dur;
        sink.on_precharge(t1, 0, key, t1 - 5);
        let g = sink.on_activate(t1 + dur * 3 / 4, 0, key);
        assert!(!g.reduced);
        assert_eq!((g.trcd, g.tras), (cfg.timing.trcd, cfg.timing.tras));
        assert_eq!(sink.stats.guard_suppressed, 1);
        assert_eq!(sink.stats.timing_violations, 1, "suppression prevents the repeat violation");
        // Within the guard band the reduced grant is still honored.
        let t2 = t1 + dur;
        sink.on_precharge(t2, 0, key, t2 - 5);
        assert!(sink.on_activate(t2 + dur / 4, 0, key).reduced);
    }

    #[test]
    fn disabled_faults_leave_grants_untouched() {
        let mut cfg = faulty_cfg();
        cfg.fault.enabled = false;
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 2, 5);
        sink.on_activate(0, 0, key);
        sink.on_precharge(10, 0, key, 0);
        let g = sink.on_activate(10 + dur * 3 / 4, 0, key);
        assert!(g.reduced, "fault model must be inert when disabled");
        assert_eq!(sink.stats.timing_violations, 0);
        assert_eq!(sink.stats.guard_suppressed, 0);
    }

    #[test]
    fn fault_state_round_trips_through_sink_checkpoint() {
        let cfg = faulty_cfg();
        let dur = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 2, 5);
        sink.on_activate(0, 0, key);
        sink.on_precharge(10, 0, key, 0);
        sink.on_activate(10 + dur * 3 / 4, 0, key); // violation → blacklist
        let mut enc = crate::sim::checkpoint::Enc::default();
        sink.export_state(&mut enc);
        let words = enc.into_words();
        let mut sink2 = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let mut dec = crate::sim::checkpoint::Dec::new(&words);
        sink2.import_state(&mut dec).expect("sink round trip");
        assert!(dec.finished());
        assert_eq!(sink2.stats, sink.stats);
        // The blacklist survived: re-cache and return past the guard
        // band — suppressed, not violated.
        let t1 = 10 + dur;
        sink2.on_precharge(t1, 0, key, t1 - 5);
        sink2.on_activate(t1 + dur * 3 / 4, 0, key);
        assert_eq!(sink2.stats.guard_suppressed, 1);
        assert_eq!(sink2.stats.timing_violations, 1);
    }

    #[test]
    fn reset_clears_stats_but_keeps_mechanism_state() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 0, 9);
        sink.on_activate(0, 0, key);
        sink.on_precharge(40, 0, key, 0);
        sink.reset_stats();
        assert_eq!(sink.stats.acts, 0);
        // The HCRAC entry inserted before the reset still grants.
        assert!(sink.on_activate(80, 0, key).reduced);
    }
}
