//! CommandSink — the mechanism hook layer of the controller.
//!
//! Every observable command event flows through here exactly once: ACT
//! (mechanism lookup → timing grant, RLTL/reuse tracking), PRE (mechanism
//! insert, RLTL close, open-time accounting), REF, and column issue
//! (row-buffer classification, latency accounting). Before the layering,
//! these callbacks were threaded separately through `issue_precharge`,
//! `resolve_autopre`, and `schedule` — three call sites that had to agree
//! on ordering; now the controller calls one sink method per event and
//! the ChargeCache/NUAT hook semantics (Fig. 2 of the paper) live in a
//! single file.

use crate::analysis::{ReuseTracker, RltlTracker};
use crate::config::SystemConfig;
use crate::latency::{build_mechanism, Mechanism, MechanismKind, RowKey, TimingGrant};

/// How a request's first DRAM command classified it (row-buffer outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqClass {
    Hit,
    Miss,
    Conflict,
}

/// Controller statistics (reset after warmup).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct McStats {
    pub acts: u64,
    pub acts_reduced: u64,
    pub reads: u64,
    pub writes: u64,
    pub precharges: u64,
    pub refreshes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub read_latency_sum: u64,
    pub read_latency_cnt: u64,
    /// Aggregate bank-open time (for active-standby energy).
    pub bank_open_cycles: u64,
    /// Forwarded from the write queue (no DRAM access).
    pub wq_forwards: u64,
    /// Enqueue rejections (queue full) — backpressure signal.
    pub rejects: u64,
}

/// Single funnel for ACT/PRE/REF/column events: owns the latency
/// mechanism, the RLTL/reuse trackers, and the stats they feed.
pub struct CommandSink {
    mech: Box<dyn Mechanism>,
    pub rltl: RltlTracker,
    pub reuse: ReuseTracker,
    pub stats: McStats,
}

impl CommandSink {
    pub fn new(cfg: &SystemConfig, kind: MechanismKind) -> Self {
        Self {
            mech: build_mechanism(kind, cfg),
            rltl: RltlTracker::new(cfg.timing.tck_ns),
            reuse: ReuseTracker::new(),
            stats: McStats::default(),
        }
    }

    /// Replace the mechanism (coordinator sweeps reuse a controller).
    pub fn set_mechanism(&mut self, mech: Box<dyn Mechanism>) {
        self.mech = mech;
    }

    /// An ACT is being issued for `core`'s request: mechanism lookup
    /// (ChargeCache/NUAT timing grant), RLTL + reuse tracking, stats.
    pub fn on_activate(&mut self, now: u64, core: u32, key: RowKey) -> TimingGrant {
        let grant = self.mech.on_activate(now, core, key);
        self.rltl.on_activate(now, key);
        self.reuse.on_activate(key);
        self.stats.acts += 1;
        if grant.reduced {
            self.stats.acts_reduced += 1;
        }
        grant
    }

    /// A row closed (explicit PRE, auto-precharge, or refresh drain):
    /// mechanism insert, RLTL close, open-time accounting.
    pub fn on_precharge(&mut self, now: u64, owner: u32, key: RowKey, act_cycle: u64) {
        self.mech.on_precharge(now, owner, key);
        self.rltl.on_precharge(now, key);
        self.stats.precharges += 1;
        self.stats.bank_open_cycles += now.saturating_sub(act_cycle);
    }

    /// An all-bank REF completed on `rank`.
    pub fn on_refresh(&mut self, now: u64, rank: u32, refresh_count: u64) {
        self.mech.on_refresh(now, rank, refresh_count);
        self.stats.refreshes += 1;
    }

    /// A column command issued: row-buffer classification plus read
    /// latency (`Some(ready - arrived)` for reads, `None` for writes).
    pub fn on_column(&mut self, class: ReqClass, is_write: bool, read_latency: Option<u64>) {
        match class {
            ReqClass::Hit => self.stats.row_hits += 1,
            ReqClass::Miss => self.stats.row_misses += 1,
            ReqClass::Conflict => self.stats.row_conflicts += 1,
        }
        if is_write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
            let lat = read_latency.expect("reads carry a latency sample");
            self.stats.read_latency_sum += lat;
            self.stats.read_latency_cnt += 1;
        }
    }

    /// Reset statistics (end of warmup). Mechanism state is retained —
    /// that is the point of warmup.
    pub fn reset_stats(&mut self) {
        self.stats = McStats::default();
        self.rltl.reset_counts();
    }

    /// Checkpoint: mechanism tables (with their expiry clocks), both
    /// trackers, and the stat counters, in a fixed field order.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::SINK);
        enc.tag(tags::MECH);
        self.mech.export_state(enc);
        self.rltl.export_state(enc);
        self.reuse.export_state(enc);
        let s = &self.stats;
        for v in [
            s.acts,
            s.acts_reduced,
            s.reads,
            s.writes,
            s.precharges,
            s.refreshes,
            s.row_hits,
            s.row_misses,
            s.row_conflicts,
            s.read_latency_sum,
            s.read_latency_cnt,
            s.bank_open_cycles,
            s.wq_forwards,
            s.rejects,
        ] {
            enc.u64(v);
        }
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::SINK)?;
        dec.tag(tags::MECH)?;
        self.mech.import_state(dec)?;
        self.rltl.import_state(dec)?;
        self.reuse.import_state(dec)?;
        let s = &mut self.stats;
        for v in [
            &mut s.acts,
            &mut s.acts_reduced,
            &mut s.reads,
            &mut s.writes,
            &mut s.precharges,
            &mut s.refreshes,
            &mut s.row_hits,
            &mut s.row_misses,
            &mut s.row_conflicts,
            &mut s.read_latency_sum,
            &mut s.read_latency_cnt,
            &mut s.bank_open_cycles,
            &mut s.wq_forwards,
            &mut s.rejects,
        ] {
            *v = dec.u64()?;
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activate_precharge_update_stats_and_trackers() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::Baseline);
        let key = RowKey::new(0, 0, 7);
        let g = sink.on_activate(10, 0, key);
        assert!(!g.reduced);
        assert_eq!(sink.stats.acts, 1);
        assert_eq!(sink.rltl.activations, 1);
        sink.on_precharge(50, 0, key, 10);
        assert_eq!(sink.stats.precharges, 1);
        assert_eq!(sink.stats.bank_open_cycles, 40);
    }

    #[test]
    fn chargecache_grant_counts_reduced_acts() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 1, 3);
        sink.on_activate(0, 0, key);
        sink.on_precharge(40, 0, key, 0);
        let g = sink.on_activate(80, 0, key);
        assert!(g.reduced);
        assert_eq!(sink.stats.acts, 2);
        assert_eq!(sink.stats.acts_reduced, 1);
    }

    #[test]
    fn column_events_classify_and_accumulate_latency() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::Baseline);
        sink.on_column(ReqClass::Hit, false, Some(26));
        sink.on_column(ReqClass::Conflict, true, None);
        assert_eq!(sink.stats.row_hits, 1);
        assert_eq!(sink.stats.row_conflicts, 1);
        assert_eq!(sink.stats.reads, 1);
        assert_eq!(sink.stats.writes, 1);
        assert_eq!(sink.stats.read_latency_sum, 26);
    }

    #[test]
    fn reset_clears_stats_but_keeps_mechanism_state() {
        let cfg = SystemConfig::default();
        let mut sink = CommandSink::new(&cfg, MechanismKind::ChargeCache);
        let key = RowKey::new(0, 0, 9);
        sink.on_activate(0, 0, key);
        sink.on_precharge(40, 0, key, 0);
        sink.reset_stats();
        assert_eq!(sink.stats.acts, 0);
        // The HCRAC entry inserted before the reset still grants.
        assert!(sink.on_activate(80, 0, key).reduced);
    }
}
