//! Physical-address → DRAM-location mapping.
//!
//! Default scheme is Ramulator-style `Row:Rank:Bank:Col:Channel` (channel
//! interleave at cache-line granularity, banks striped above columns so
//! sequential rows of different arrays collide in banks — the bank-conflict
//! behaviour the paper's RLTL observation rests on).


use crate::config::DramOrg;
use crate::dram::command::Loc;

/// Address interleave scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapScheme {
    /// row : rank : bank : col : channel  (default; line-interleaved channels)
    RoRaBaColCh,
    /// row : col : rank : bank : channel  (bank-interleaved lines)
    RoColRaBaCh,
}

/// Decodes line-granularity physical addresses into DRAM locations.
#[derive(Debug, Clone)]
pub struct AddressMapper {
    org: DramOrg,
    scheme: MapScheme,
}

impl AddressMapper {
    pub fn new(org: &DramOrg, scheme: MapScheme) -> Self {
        assert!(org.channels.is_power_of_two());
        assert!(org.ranks.is_power_of_two());
        assert!(org.banks.is_power_of_two());
        assert!(org.rows.is_power_of_two());
        assert!(org.cols().is_power_of_two());
        Self { org: org.clone(), scheme }
    }

    /// Map a byte address. Only the line-index bits participate.
    pub fn map(&self, byte_addr: u64) -> Loc {
        let line = byte_addr / self.org.line_bytes as u64;
        self.map_line(line)
    }

    /// Map a cache-line index. The decoded `Loc.channel` selects the
    /// owning [`crate::controller::MemController`]; the controller stamps
    /// the same channel id into every Loc/RowKey it fabricates itself
    /// (refresh, eager precharge), so decoded and fabricated locations
    /// agree.
    #[inline]
    pub fn map_line(&self, line: u64) -> Loc {
        let ch_bits = self.org.channels.trailing_zeros();
        let ra_bits = self.org.ranks.trailing_zeros();
        let ba_bits = self.org.banks.trailing_zeros();
        let ro_bits = self.org.rows.trailing_zeros();
        let co_bits = self.org.cols().trailing_zeros();
        let mut a = line;
        let mut take = |bits: u32| -> u64 {
            let v = a & ((1u64 << bits) - 1);
            a >>= bits;
            v
        };
        match self.scheme {
            MapScheme::RoRaBaColCh => {
                let channel = take(ch_bits) as u32;
                let col = take(co_bits) as u32;
                let bank = take(ba_bits) as u32;
                let rank = take(ra_bits) as u32;
                let row = (take(ro_bits) as u32) % self.org.rows as u32;
                Loc { channel, rank, bank, row, col }
            }
            MapScheme::RoColRaBaCh => {
                let channel = take(ch_bits) as u32;
                let bank = take(ba_bits) as u32;
                let rank = take(ra_bits) as u32;
                let col = take(co_bits) as u32;
                let row = (take(ro_bits) as u32) % self.org.rows as u32;
                Loc { channel, rank, bank, row, col }
            }
        }
    }

    pub fn org(&self) -> &DramOrg {
        &self.org
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mapper() -> AddressMapper {
        AddressMapper::new(&DramOrg::default(), MapScheme::RoRaBaColCh)
    }

    #[test]
    fn sequential_lines_share_a_row() {
        let m = mapper();
        // 1 channel: consecutive lines walk the columns of one row.
        let a = m.map_line(0);
        let b = m.map_line(1);
        assert_eq!(a.row, b.row);
        assert_eq!(a.bank, b.bank);
        assert_eq!(b.col, a.col + 1);
    }

    #[test]
    fn crossing_the_row_boundary_switches_bank() {
        let m = mapper();
        let cols = 128u64;
        let a = m.map_line(cols - 1);
        let b = m.map_line(cols);
        assert_eq!(b.col, 0);
        assert_eq!(b.bank, a.bank + 1);
        assert_eq!(a.row, b.row);
    }

    #[test]
    fn full_bank_sweep_increments_row() {
        let m = mapper();
        let lines_per_row_group = 128u64 * 8; // cols * banks (1 rank)
        let a = m.map_line(0);
        let b = m.map_line(lines_per_row_group);
        assert_eq!(b.row, a.row + 1);
        assert_eq!(b.bank, 0);
    }

    #[test]
    fn two_channels_interleave_lines() {
        let mut org = DramOrg::default();
        org.channels = 2;
        let m = AddressMapper::new(&org, MapScheme::RoRaBaColCh);
        assert_eq!(m.map_line(0).channel, 0);
        assert_eq!(m.map_line(1).channel, 1);
        assert_eq!(m.map_line(2).channel, 0);
    }

    #[test]
    fn mapping_is_injective_over_a_window() {
        use std::collections::HashSet;
        let m = mapper();
        let mut seen = HashSet::new();
        for line in 0..100_000u64 {
            let l = m.map_line(line);
            assert!(seen.insert((l.channel, l.rank, l.bank, l.row, l.col)));
        }
    }

    #[test]
    fn byte_addresses_quantize_to_lines() {
        let m = mapper();
        assert_eq!(m.map(0), m.map(63));
        assert_ne!(m.map(63), m.map(64));
    }
}
