//! Memory controller: request queues, FR-FCFS scheduling, row-buffer
//! policy, refresh engine, and the latency-mechanism hook points.
//!
//! One controller instance drives one channel. Each bus cycle it issues at
//! most one DRAM command, chosen by priority:
//!
//! 1. refresh drain (PREs, then the all-bank REF at the tREFI deadline),
//! 2. FR-FCFS pass 1 — ready **column** commands (row hits), oldest first,
//! 3. FR-FCFS pass 2 — ready ACT/PRE commands, oldest first.
//!
//! ChargeCache/NUAT hooks (`Mechanism`) fire on every ACT (lookup → timing
//! grant) and every PRE (insert), exactly as in Fig. 2 of the paper.

pub mod mapping;
pub mod queue;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::analysis::{ReuseTracker, RltlTracker};
use crate::config::{RowPolicy, SystemConfig};
use crate::dram::command::{Command, CommandKind, Loc};
use crate::dram::device::Channel;
use crate::latency::{build_mechanism, Mechanism, MechanismKind, RowKey};

pub use mapping::{AddressMapper, MapScheme};
pub use queue::{Request, RequestQueue};

/// How a request's first DRAM command classified it (row-buffer outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqClass {
    Hit,
    Miss,
    Conflict,
}

/// Row-hysteresis: a conflicting request must have waited this many bus
/// cycles before it may close an open row (see the scheduler's pass 2).
const CONFLICT_AGE_CYCLES: u64 = 16;

/// FR-FCFS starvation cap: once a request has waited this long, it may
/// close an open row even while younger row hits keep arriving (the
/// classic FR-FCFS+cap fix — without it, a streaming core can starve a
/// conflicting one indefinitely).
const STARVE_CAP_CYCLES: u64 = 256;

/// A finished read (the core's MSHR is released at `ready` bus cycle).
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub req_id: u64,
    pub core: u32,
    pub ready: u64,
}

/// Controller statistics (reset after warmup).
#[derive(Debug, Clone, Default)]
pub struct McStats {
    pub acts: u64,
    pub acts_reduced: u64,
    pub reads: u64,
    pub writes: u64,
    pub precharges: u64,
    pub refreshes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub read_latency_sum: u64,
    pub read_latency_cnt: u64,
    /// Aggregate bank-open time (for active-standby energy).
    pub bank_open_cycles: u64,
    /// Forwarded from the write queue (no DRAM access).
    pub wq_forwards: u64,
    /// Enqueue rejections (queue full) — backpressure signal.
    pub rejects: u64,
}

/// One-channel memory controller.
pub struct MemController {
    pub dev: Channel,
    rq: RequestQueue,
    wq: RequestQueue,
    mech: Box<dyn Mechanism>,
    pub rltl: RltlTracker,
    pub reuse: ReuseTracker,
    pub stats: McStats,
    row_policy: RowPolicy,
    write_drain: bool,
    wq_hi: usize,
    wq_lo: usize,
    /// Per-rank refresh drain flag.
    ref_drain: Vec<bool>,
    completions: BinaryHeap<Reverse<(u64, u64, u32)>>,
    /// Request classification (parallel to queue entries by id).
    class_of: std::collections::HashMap<u64, ReqClass>,
    /// Per-rank open-bank count (active-standby energy accounting).
    rank_open: Vec<u32>,
    rank_active_since: Vec<u64>,
    /// Cycles each rank spent with >= 1 bank open.
    pub rank_active_cycles: Vec<u64>,
    /// Scratch: per (rank, bank), does any queued request hit the open
    /// row? Recomputed once per scheduling tick (collapses the O(n^2)
    /// per-candidate row-hit scans to a single O(n) pass).
    open_hit: Vec<bool>,
    banks_per_rank: usize,
}

impl MemController {
    pub fn new(cfg: &SystemConfig, kind: MechanismKind) -> Self {
        Self {
            dev: Channel::new(&cfg.dram, &cfg.timing),
            rq: RequestQueue::new(cfg.mc.read_queue),
            wq: RequestQueue::new(cfg.mc.write_queue),
            mech: build_mechanism(kind, cfg),
            rltl: RltlTracker::new(cfg.timing.tck_ns),
            reuse: ReuseTracker::new(),
            stats: McStats::default(),
            row_policy: cfg.mc.row_policy,
            write_drain: false,
            wq_hi: cfg.mc.write_hi_watermark,
            wq_lo: cfg.mc.write_lo_watermark,
            ref_drain: vec![false; cfg.dram.ranks],
            completions: BinaryHeap::new(),
            class_of: std::collections::HashMap::new(),
            rank_open: vec![0; cfg.dram.ranks],
            rank_active_since: vec![0; cfg.dram.ranks],
            rank_active_cycles: vec![0; cfg.dram.ranks],
            open_hit: vec![false; cfg.dram.ranks * cfg.dram.banks],
            banks_per_rank: cfg.dram.banks,
        }
    }

    /// Recompute the open-row-hit bitmap (one O(queues) pass). Called
    /// lazily: only the first time a scheduling tick actually needs a
    /// conflict/eager-PRE decision (most ticks resolve in pass 1).
    fn refresh_open_hit(&mut self) {
        self.open_hit.iter_mut().for_each(|b| *b = false);
        let bpr = self.banks_per_rank;
        for req in self.rq.iter().chain(self.wq.iter()) {
            let idx = req.loc.rank as usize * bpr + req.loc.bank as usize;
            if !self.open_hit[idx]
                && self.dev.bank(&req.loc).open_row() == Some(req.loc.row)
            {
                self.open_hit[idx] = true;
            }
        }
    }

    #[inline]
    fn open_row_has_hit(&mut self, rank: u32, bank: u32, fresh: &mut bool) -> bool {
        if !*fresh {
            self.refresh_open_hit();
            *fresh = true;
        }
        self.open_hit[rank as usize * self.banks_per_rank + bank as usize]
    }

    fn rank_opened(&mut self, rank: usize, now: u64) {
        if self.rank_open[rank] == 0 {
            self.rank_active_since[rank] = now;
        }
        self.rank_open[rank] += 1;
    }

    fn rank_closed(&mut self, rank: usize, now: u64) {
        debug_assert!(self.rank_open[rank] > 0);
        self.rank_open[rank] -= 1;
        if self.rank_open[rank] == 0 {
            self.rank_active_cycles[rank] +=
                now.saturating_sub(self.rank_active_since[rank]);
        }
    }

    /// Replace the mechanism (coordinator sweeps reuse a controller).
    pub fn set_mechanism(&mut self, mech: Box<dyn Mechanism>) {
        self.mech = mech;
    }

    /// Queue occupancy (reads, writes).
    pub fn occupancy(&self) -> (usize, usize) {
        (self.rq.len(), self.wq.len())
    }

    /// True if a read can be accepted right now.
    pub fn can_accept_read(&self) -> bool {
        !self.rq.is_full()
    }

    pub fn can_accept_write(&self) -> bool {
        !self.wq.is_full()
    }

    /// Enqueue a request. Returns false (and counts a reject) if full.
    /// Reads that match a queued write are forwarded without DRAM access.
    pub fn enqueue(&mut self, req: Request, now: u64) -> bool {
        if req.is_write {
            if self.wq.is_full() {
                self.stats.rejects += 1;
                return false;
            }
            self.wq.push(req);
            true
        } else {
            // Write-to-read forwarding at line granularity.
            let fwd = self.wq.iter().any(|w| {
                w.loc.rank == req.loc.rank
                    && w.loc.bank == req.loc.bank
                    && w.loc.row == req.loc.row
                    && w.loc.col == req.loc.col
            });
            if fwd {
                self.stats.wq_forwards += 1;
                self.completions.push(Reverse((now + 1, req.id, req.core)));
                return true;
            }
            if self.rq.is_full() {
                self.stats.rejects += 1;
                return false;
            }
            self.rq.push(req);
            true
        }
    }

    /// Advance one bus cycle: resolve auto-precharges, run the refresh
    /// engine, issue at most one command, then drain due completions into
    /// `out`.
    pub fn tick(&mut self, now: u64, out: &mut Vec<Completion>) {
        self.resolve_autopre(now);
        if !self.refresh_engine(now) {
            self.schedule(now);
        }
        while let Some(&Reverse((ready, id, core))) = self.completions.peek() {
            if ready > now {
                break;
            }
            self.completions.pop();
            out.push(Completion { req_id: id, core, ready });
        }
    }

    /// The cycle at which the earliest pending completion becomes ready
    /// (fast-forward hint for the system loop).
    pub fn next_completion_at(&self) -> Option<u64> {
        self.completions.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn has_work(&self) -> bool {
        !self.rq.is_empty() || !self.wq.is_empty() || !self.completions.is_empty()
    }

    /// Earliest bus cycle `>= now` at which ticking this controller could
    /// do anything: deliver a completion, resolve an auto-precharge,
    /// start or advance a refresh, or issue a command for a queued
    /// request — the event-kernel wake contract
    /// (see [`crate::sim::engine`]).
    ///
    /// The bound is a conservative *lower* bound: it ignores the
    /// scheduler's row-hit-first and write-drain gates (those can only
    /// delay an issue past this bound, and a too-early tick is a no-op),
    /// but it must never be later than the true next action. The
    /// conflict-precharge hysteresis IS folded in (`arrived +
    /// CONFLICT_AGE_CYCLES`) because it is a pure function of the
    /// request, keeping the bound tight on row-conflict traffic.
    pub fn next_event_at(&self, now: u64) -> u64 {
        // The write-drain hysteresis flag is itself mutable state the
        // strict loop re-evaluates every bus cycle, and the opportunistic
        // trigger can oscillate with period 2 (rq empty, 0 < wq <= lo
        // flips it on, the yield-back flips it off), making the write
        // issue cycle depend on tick parity. Any tick that would flip the
        // flag is therefore an event: report "hot" and let the kernel
        // tick per-cycle through the window, exactly like the strict
        // loop. (Ticking extra cycles is always safe — every event-mode
        // tick coincides with a strict-mode tick.)
        let drain_flips = if !self.write_drain {
            self.wq.len() >= self.wq_hi || (self.rq.is_empty() && !self.wq.is_empty())
        } else {
            self.wq.is_empty()
                || self.wq.len() <= self.wq_lo
                || (!self.rq.is_empty() && self.wq.len() < self.wq_hi)
        };
        if drain_flips {
            return now;
        }
        let mut t = u64::MAX;
        if let Some(r) = self.next_completion_at() {
            t = t.min(r);
        }
        for (ri, rank) in self.dev.ranks.iter().enumerate() {
            // The tREFI deadline flips this rank into drain mode.
            t = t.min(rank.next_refresh_at);
            for bank in &rank.banks {
                if let Some(ap) = bank.next_autopre_at() {
                    t = t.min(ap);
                }
            }
            if self.ref_drain[ri] {
                // Drain in progress: next action is the REF itself (all
                // banks closed) or the PRE of an open bank.
                if rank.all_closed() {
                    t = t.min(rank.ref_busy_until.max(now));
                } else {
                    for bank in &rank.banks {
                        if bank.open_row().is_some() {
                            t = t.min(bank.pre_at.max(rank.ref_busy_until));
                        }
                    }
                }
            }
        }
        // Closed-row policy: the eager-precharge pass closes an open bank
        // with no queued hits as soon as tRAS/tRTP allow. One O(queues)
        // pass builds the per-bank open-row-hit bitmap (same shape as
        // `refresh_open_hit`, which needs &mut and so cannot be reused
        // here).
        if self.row_policy == RowPolicy::Closed {
            let bpr = self.banks_per_rank;
            let mut open_hit = vec![false; self.dev.ranks.len() * bpr];
            for req in self.rq.iter().chain(self.wq.iter()) {
                let idx = req.loc.rank as usize * bpr + req.loc.bank as usize;
                if !open_hit[idx]
                    && self.dev.bank(&req.loc).open_row() == Some(req.loc.row)
                {
                    open_hit[idx] = true;
                }
            }
            for (ri, rank) in self.dev.ranks.iter().enumerate() {
                if self.ref_drain[ri] {
                    continue;
                }
                for (bi, bank) in rank.banks.iter().enumerate() {
                    if bank.open_row().is_some() && !open_hit[ri * bpr + bi] {
                        t = t.min(bank.pre_at);
                    }
                }
            }
        }
        // Queued requests: the cycle each one's next command becomes
        // timing-legal (queue arrivals re-trigger this computation, so a
        // fresh request surfaces at the next bus boundary).
        for req in self.rq.iter().chain(self.wq.iter()) {
            if self.ref_drain[req.loc.rank as usize] {
                continue; // drained ranks are covered above
            }
            let bank = self.dev.bank(&req.loc);
            if bank.next_autopre_at().is_some() {
                continue; // logically closing; its autopre is the event
            }
            let cand = match bank.open_row() {
                Some(row) if row == req.loc.row => {
                    let kind = if req.is_write { CommandKind::Write } else { CommandKind::Read };
                    self.dev.earliest_issue(kind, &req.loc)
                }
                Some(_) => self
                    .dev
                    .earliest_issue(CommandKind::Precharge, &req.loc)
                    .max(req.arrived + CONFLICT_AGE_CYCLES),
                None => self.dev.earliest_issue(CommandKind::Activate, &req.loc),
            };
            t = t.min(cand);
        }
        t.max(now)
    }

    fn resolve_autopre(&mut self, now: u64) {
        let rltl = &mut self.rltl;
        let mech = &mut self.mech;
        let stats = &mut self.stats;
        let mut closed: Vec<u32> = Vec::new();
        self.dev.tick_autopre(now, |rank, bank, row, owner, cycle, act_cycle| {
            let key = RowKey::new(rank, bank, row);
            mech.on_precharge(cycle, owner, key);
            rltl.on_precharge(cycle, key);
            stats.precharges += 1;
            stats.bank_open_cycles += cycle.saturating_sub(act_cycle);
            closed.push(rank);
        });
        for rank in closed {
            self.rank_closed(rank as usize, now);
        }
    }

    /// Refresh engine. Returns true if it consumed the command slot.
    fn refresh_engine(&mut self, now: u64) -> bool {
        for rank_idx in 0..self.dev.ranks.len() {
            if self.dev.ranks[rank_idx].refresh_due(now) {
                self.ref_drain[rank_idx] = true;
            }
            if !self.ref_drain[rank_idx] {
                continue;
            }
            let rank = &self.dev.ranks[rank_idx];
            if rank.all_closed() {
                let loc = Loc { channel: 0, rank: rank_idx as u32, bank: 0, row: 0, col: 0 };
                if self.dev.can_issue(CommandKind::Refresh, &loc, now) {
                    self.dev.issue(
                        Command { kind: CommandKind::Refresh, loc },
                        now,
                        0,
                        0,
                        0,
                    );
                    let count = self.dev.ranks[rank_idx].refresh_count;
                    self.mech.on_refresh(now, rank_idx as u32, count);
                    self.stats.refreshes += 1;
                    self.ref_drain[rank_idx] = false;
                    return true;
                }
                continue;
            }
            // Precharge one open bank (oldest activation first).
            let mut best: Option<(u64, usize)> = None;
            for (bi, b) in rank.banks.iter().enumerate() {
                if b.open_row().is_some() {
                    let cand = (b.act_cycle, bi);
                    if best.map_or(true, |x| cand < x) {
                        best = Some(cand);
                    }
                }
            }
            if let Some((_, bi)) = best {
                let bank = &self.dev.ranks[rank_idx].banks[bi];
                let row = bank.open_row().unwrap();
                let loc = Loc { channel: 0, rank: rank_idx as u32, bank: bi as u32, row, col: 0 };
                if self.dev.can_issue(CommandKind::Precharge, &loc, now) {
                    self.issue_precharge(now, loc);
                    return true;
                }
            }
            // Drain in progress but nothing legal: hold the slot so ACTs
            // cannot sneak in and extend the drain indefinitely.
            return true;
        }
        false
    }

    fn issue_precharge(&mut self, now: u64, loc: Loc) {
        let owner = self.dev.bank(&loc).open_owner;
        let act_cycle = self.dev.bank(&loc).act_cycle;
        self.dev.issue(Command { kind: CommandKind::Precharge, loc }, now, 0, 0, owner);
        let key = RowKey::new(loc.rank, loc.bank, loc.row);
        self.mech.on_precharge(now, owner, key);
        self.rltl.on_precharge(now, key);
        self.stats.precharges += 1;
        self.stats.bank_open_cycles += now - act_cycle;
        self.rank_closed(loc.rank as usize, now);
    }

    /// FR-FCFS scheduling; issues at most one command.
    fn schedule(&mut self, now: u64) {
        // Write drain mode hysteresis with read priority: drain when the
        // write queue is critically full (forced) or when there are no
        // reads to serve (opportunistic); yield back to reads as soon as
        // they arrive unless the forced condition still holds. This
        // prevents write bursts from starving the read path.
        if !self.write_drain {
            if self.wq.len() >= self.wq_hi || (self.rq.is_empty() && !self.wq.is_empty()) {
                self.write_drain = true;
            }
        } else if self.wq.is_empty()
            || self.wq.len() <= self.wq_lo
            || (!self.rq.is_empty() && self.wq.len() < self.wq_hi)
        {
            self.write_drain = false;
        }
        let serving_writes = self.write_drain && !self.wq.is_empty();
        // Lazily-computed open-row-hit bitmap (valid for this tick).
        let mut hit_map_fresh = false;
        if self.rq.is_empty() && self.wq.is_empty() {
            // Idle fast path; the closed policy still parks open banks.
            if self.row_policy == RowPolicy::Closed {
                self.eager_precharge(now, &mut hit_map_fresh);
            }
            return;
        }

        // Pass 1: ready column command, oldest first.
        let queue = if serving_writes { &self.wq } else { &self.rq };
        let mut issue_col: Option<(usize, Request, CommandKind)> = None;
        for (i, req) in queue.iter().enumerate() {
            if self.ref_drain[req.loc.rank as usize] {
                continue;
            }
            if self.dev.bank(&req.loc).open_row() != Some(req.loc.row) {
                continue;
            }
            // The closed-row policy precharges via the eager-idle pass
            // (pass 3) rather than auto-precharge: deciding at PRE time
            // with live queue knowledge avoids closing a row whose next
            // hit is still in flight (DDR3 RDA cannot be cancelled).
            let kind = if req.is_write { CommandKind::Write } else { CommandKind::Read };
            if self.dev.can_issue(kind, &req.loc, now) {
                issue_col = Some((i, *req, kind));
                break;
            }
        }
        if let Some((i, req, kind)) = issue_col {
            let ready = self.dev.issue(Command { kind, loc: req.loc }, now, 0, 0, req.core);
            let class = self
                .class_of
                .remove(&req.id)
                .unwrap_or(ReqClass::Hit);
            match class {
                ReqClass::Hit => self.stats.row_hits += 1,
                ReqClass::Miss => self.stats.row_misses += 1,
                ReqClass::Conflict => self.stats.row_conflicts += 1,
            }
            if req.is_write {
                self.stats.writes += 1;
                self.wq.remove(i);
            } else {
                self.stats.reads += 1;
                let ready = ready.expect("read returns data-ready cycle");
                self.completions.push(Reverse((ready, req.id, req.core)));
                self.stats.read_latency_sum += ready - req.arrived;
                self.stats.read_latency_cnt += 1;
                self.rq.remove(i);
            }
            return;
        }

        // Pass 2: ready ACT or PRE, oldest first (index scan: the lazy
        // hit-map computation needs &mut self mid-loop).
        let queue_len = if serving_writes { self.wq.len() } else { self.rq.len() };
        let mut action: Option<(u64, Request, CommandKind)> = None;
        for i in 0..queue_len {
            let req = if serving_writes { self.wq.get(i) } else { self.rq.get(i) };
            if self.ref_drain[req.loc.rank as usize] {
                continue;
            }
            match self.dev.bank(&req.loc).open_row() {
                None => {
                    if self.dev.can_issue(CommandKind::Activate, &req.loc, now) {
                        action = Some((req.id, req, CommandKind::Activate));
                        break;
                    }
                }
                Some(open) if open != req.loc.row => {
                    // Precharge only when no queued request still hits the
                    // open row (in either queue) — FR-FCFS row-hit-first —
                    // and the conflicting request has aged past the
                    // hysteresis window. The aging guard keeps a stream's
                    // in-flight same-row access (trickling in through the
                    // MSHRs) from losing its open row to a premature
                    // conflict precharge. Requests older than the
                    // starvation cap override the row-hit priority.
                    let age = now.saturating_sub(req.arrived);
                    let starving = age >= STARVE_CAP_CYCLES;
                    if age >= CONFLICT_AGE_CYCLES
                        && (starving
                            || !self.open_row_has_hit(
                                req.loc.rank,
                                req.loc.bank,
                                &mut hit_map_fresh,
                            ))
                        && self.dev.can_issue(CommandKind::Precharge, &req.loc, now)
                    {
                        action = Some((req.id, req, CommandKind::Precharge));
                        self.class_of.entry(req.id).or_insert(ReqClass::Conflict);
                        break;
                    }
                }
                Some(_) => {} // row hit, column not ready yet
            }
        }
        if action.is_none() && self.row_policy == RowPolicy::Closed {
            self.eager_precharge(now, &mut hit_map_fresh);
            return;
        }
        if let Some((id, req, kind)) = action {
            match kind {
                CommandKind::Activate => {
                    let key = RowKey::new(req.loc.rank, req.loc.bank, req.loc.row);
                    let grant = self.mech.on_activate(now, req.core, key);
                    self.rltl.on_activate(now, key);
                    self.reuse.on_activate(key);
                    self.dev.issue(
                        Command { kind, loc: req.loc },
                        now,
                        grant.trcd,
                        grant.tras,
                        req.core,
                    );
                    self.stats.acts += 1;
                    if grant.reduced {
                        self.stats.acts_reduced += 1;
                    }
                    self.rank_opened(req.loc.rank as usize, now);
                    self.class_of.entry(id).or_insert(ReqClass::Miss);
                }
                CommandKind::Precharge => {
                    let mut loc = req.loc;
                    loc.row = self.dev.bank(&req.loc).open_row().unwrap();
                    self.issue_precharge(now, loc);
                }
                _ => unreachable!(),
            }
        }
    }

    /// Pass 3 (closed-row policy): eager precharge of any open bank with
    /// no pending hits, using the spare command slot. tRAS reductions make
    /// this PRE legal earlier — ChargeCache's tRAS benefit under the
    /// closed policy.
    fn eager_precharge(&mut self, now: u64, hit_map_fresh: &mut bool) {
        let (nranks, nbanks) = (self.dev.ranks.len(), self.banks_per_rank);
        for ri in 0..nranks {
            if self.ref_drain[ri] {
                continue;
            }
            for bi in 0..nbanks {
                let open = self.dev.ranks[ri].banks[bi].open_row();
                if let Some(open) = open {
                    let loc = Loc {
                        channel: 0,
                        rank: ri as u32,
                        bank: bi as u32,
                        row: open,
                        col: 0,
                    };
                    if !self.open_row_has_hit(ri as u32, bi as u32, hit_map_fresh)
                        && self.dev.can_issue(CommandKind::Precharge, &loc, now)
                    {
                        self.issue_precharge(now, loc);
                        return;
                    }
                }
            }
        }
    }

    /// Finalize open-bank accounting at end of simulation.
    pub fn finalize(&mut self, now: u64) {
        for rank in &self.dev.ranks {
            for b in &rank.banks {
                if b.open_row().is_some() {
                    self.stats.bank_open_cycles += now.saturating_sub(b.act_cycle);
                }
            }
        }
        for r in 0..self.rank_open.len() {
            if self.rank_open[r] > 0 {
                self.rank_active_cycles[r] +=
                    now.saturating_sub(self.rank_active_since[r]);
                self.rank_active_since[r] = now;
            }
        }
    }

    /// Reset statistics (end of warmup). Mechanism state is retained —
    /// that is the point of warmup.
    pub fn reset_stats(&mut self) {
        self.stats = McStats::default();
        self.rltl.reset_counts();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn req(id: u64, bank: u32, row: u32, col: u32, write: bool) -> Request {
        Request {
            id,
            core: 0,
            loc: Loc { channel: 0, rank: 0, bank, row, col },
            is_write: write,
            arrived: 0,
        }
    }

    fn run_until_complete(mc: &mut MemController, mut now: u64, deadline: u64) -> Vec<Completion> {
        let mut done = Vec::new();
        while now < deadline {
            mc.tick(now, &mut done);
            now += 1;
        }
        done
    }

    #[test]
    fn single_read_completes_with_expected_latency() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        assert!(mc.enqueue(req(1, 0, 5, 3, false), 0));
        let done = run_until_complete(&mut mc, 0, 200);
        assert_eq!(done.len(), 1);
        // ACT@0 -> RD@tRCD(11) -> data at 11 + CL(11) + BL(4) = 26.
        assert_eq!(done[0].ready, 26);
        assert_eq!(mc.stats.acts, 1);
        assert_eq!(mc.stats.row_misses, 1);
    }

    #[test]
    fn row_hits_are_prioritized_and_counted() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        mc.enqueue(req(2, 0, 5, 1, false), 0);
        mc.enqueue(req(3, 0, 9, 0, false), 0); // conflicting row
        let done = run_until_complete(&mut mc, 0, 400);
        assert_eq!(done.len(), 3);
        assert_eq!(mc.stats.row_hits, 1);
        assert_eq!(mc.stats.row_misses, 1);
        assert_eq!(mc.stats.row_conflicts, 1);
        // Hit (id 2) must finish before the conflicting row 9 (id 3).
        let pos =
            |id: u64| done.iter().position(|c| c.req_id == id).unwrap();
        assert!(pos(2) < pos(3));
    }

    #[test]
    fn chargecache_speeds_up_reopened_row() {
        let c = cfg();
        // Baseline: open row 5, conflict to row 9, re-open row 5.
        let mut run = |kind: MechanismKind| -> u64 {
            let mut mc = MemController::new(&c, kind);
            mc.enqueue(req(1, 0, 5, 0, false), 0);
            let _ = run_until_complete(&mut mc, 0, 400);
            mc.enqueue(req(2, 0, 9, 0, false), 400);
            let _ = run_until_complete(&mut mc, 400, 800);
            mc.enqueue(req(3, 0, 5, 1, false), 800);
            let done = run_until_complete(&mut mc, 800, 1600);
            assert_eq!(done.len(), 1);
            done[0].ready
        };
        let base = run(MechanismKind::Baseline);
        let cc = run(MechanismKind::ChargeCache);
        // Request 3 re-activates row 5, which ChargeCache has cached
        // (inserted at its precharge) -> 4 cycles faster tRCD.
        assert_eq!(base - cc, 4);
    }

    #[test]
    fn write_drain_hysteresis() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        // Fill write queue past the high watermark.
        for i in 0..49 {
            assert!(mc.enqueue(req(i, (i % 8) as u32, (i / 8) as u32, 0, true), 0));
        }
        let _ = run_until_complete(&mut mc, 0, 4000);
        assert!(mc.stats.writes > 0, "drain must have issued writes");
        assert!(mc.occupancy().1 <= c.mc.write_lo_watermark);
    }

    #[test]
    fn read_forwarded_from_write_queue() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 3, true), 0);
        mc.enqueue(req(2, 0, 5, 3, false), 0);
        let mut done = Vec::new();
        mc.tick(0, &mut done);
        mc.tick(1, &mut done);
        assert!(done.iter().any(|c| c.req_id == 2));
        assert_eq!(mc.stats.wq_forwards, 1);
    }

    #[test]
    fn refresh_happens_on_schedule() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        let mut done = Vec::new();
        for now in 0..(c.timing.trefi * 3 + 100) {
            mc.tick(now, &mut done);
        }
        assert_eq!(mc.stats.refreshes, 3);
    }

    #[test]
    fn refresh_drains_open_banks_first() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let mut done = Vec::new();
        for now in 0..(c.timing.trefi + c.timing.trfc + 200) {
            mc.tick(now, &mut done);
        }
        assert_eq!(mc.stats.refreshes, 1);
        assert!(mc.stats.precharges >= 1);
    }

    #[test]
    fn closed_policy_precharges_idle_banks_eagerly() {
        let mut c = cfg();
        c.mc.row_policy = RowPolicy::Closed;
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let _ = run_until_complete(&mut mc, 0, 200);
        // The eager-idle pass closed the bank once no hits were pending.
        assert!(mc.dev.bank(&Loc { channel: 0, rank: 0, bank: 0, row: 5, col: 0 })
            .is_idle_closed());
        assert_eq!(mc.stats.precharges, 1);
    }

    #[test]
    fn closed_policy_keeps_row_open_while_hits_pending() {
        let mut c = cfg();
        c.mc.row_policy = RowPolicy::Closed;
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        mc.enqueue(req(2, 0, 5, 1, false), 0);
        let mut done = Vec::new();
        for now in 0..18 {
            mc.tick(now, &mut done);
        }
        // Second hit still queued or just served: row must not have been
        // precharged between the two column commands.
        assert_eq!(mc.stats.precharges, 0);
        assert_eq!(mc.stats.row_hits + mc.stats.row_misses, 2);
    }

    #[test]
    fn wake_bound_tracks_idle_act_read_and_completion() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        // Idle controller: nothing can happen before the tREFI deadline.
        assert_eq!(mc.next_event_at(0), c.timing.trefi);
        // A fresh request to a closed bank can ACT immediately.
        assert!(mc.enqueue(req(1, 0, 5, 3, false), 0));
        assert_eq!(mc.next_event_at(0), 0);
        let mut done = Vec::new();
        mc.tick(0, &mut done); // ACT issues
        // Next action: the RD once tRCD expires.
        assert_eq!(mc.next_event_at(1), c.timing.trcd);
        for now in 1..=c.timing.trcd {
            mc.tick(now, &mut done);
        }
        // RD issued at tRCD; the only remaining event is its completion
        // at tRCD + CL + BL (the queue is empty, the row stays open).
        assert_eq!(
            mc.next_event_at(c.timing.trcd + 1),
            c.timing.trcd + c.timing.cl + c.timing.tbl
        );
    }

    #[test]
    fn rltl_tracks_reopens_through_controller() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let _ = run_until_complete(&mut mc, 0, 300);
        mc.enqueue(req(2, 0, 9, 0, false), 300); // forces PRE of row 5
        let _ = run_until_complete(&mut mc, 300, 600);
        mc.enqueue(req(3, 0, 5, 0, false), 600); // re-open row 5
        let _ = run_until_complete(&mut mc, 600, 900);
        assert_eq!(mc.rltl.activations, 3);
        assert!(mc.rltl.fraction_at_ms(1.0) > 0.0);
    }
}
