//! Memory controller: request queues, pluggable scheduling, row-buffer
//! policy, refresh engine, and the latency-mechanism hook points.
//!
//! One controller instance drives one channel. It is layered (DESIGN.md
//! §4):
//!
//! * [`bank_engine::BankEngine`] — per-bank request indexes: requests
//!   bucketed by `(rank, bank)` with open-row-hit counts maintained
//!   incrementally on enqueue/issue/precharge, so the scheduler and the
//!   wake bound ask "does any queued request hit this open row?" in O(1)
//!   instead of re-scanning both queues.
//! * [`policy::SchedPolicy`] — the scheduling policy (FR-FCFS+cap by
//!   default; strict FCFS and BLISS-style blacklisting selectable via
//!   `SystemConfig::mc.scheduler` / `--scheduler`). Each policy supplies
//!   its two per-tick picks *and* its own wake-bound contribution, so
//!   [`MemController::next_event_at`] composes layer bounds instead of
//!   re-deriving scheduler logic.
//! * [`sink::CommandSink`] — the mechanism hook layer: ChargeCache/NUAT
//!   ACT/PRE/REF callbacks, RLTL/reuse tracking, and stats accounting in
//!   one funnel, exactly as in Fig. 2 of the paper.
//!
//! Each bus cycle the controller issues at most one DRAM command, chosen
//! by priority: refresh drain first, then the policy's ready-column pass,
//! then its ACT/PRE pass.

pub mod bank_engine;
pub mod fault;
pub mod mapping;
pub mod policy;
pub mod queue;
pub mod sink;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::analysis::{ReuseTracker, RltlTracker};
use crate::config::{RowPolicy, SystemConfig};
use crate::dram::command::{Command, CommandKind, Loc};
use crate::dram::device::Channel;
use crate::latency::{Mechanism, MechanismKind, RowKey};

pub use bank_engine::BankEngine;
pub use mapping::{AddressMapper, MapScheme};
pub use policy::{build_policy, SchedCtx, SchedPolicy, SchedulerKind, SCHEDULER_NAMES};
pub use policy::{CONFLICT_AGE_CYCLES, STARVE_CAP_CYCLES};
pub use queue::{Request, RequestQueue};
pub use sink::{CommandSink, McStats, ReqClass};

/// A finished read (the core's MSHR is released at `ready` bus cycle).
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub req_id: u64,
    pub core: u32,
    pub ready: u64,
}

/// One-channel memory controller.
pub struct MemController {
    pub dev: Channel,
    /// Which channel this controller drives (stamped into every `Loc` and
    /// `RowKey` it constructs, so multi-channel stats and keys never
    /// collide).
    channel: u32,
    rq: RequestQueue,
    wq: RequestQueue,
    /// Mechanism hooks + trackers + stats (the CommandSink layer).
    sink: CommandSink,
    /// Scheduling policy (the SchedPolicy layer).
    policy: Box<dyn SchedPolicy>,
    /// Per-bank request index (the BankEngine layer).
    engine: BankEngine,
    row_policy: RowPolicy,
    write_drain: bool,
    wq_hi: usize,
    wq_lo: usize,
    /// Per-rank refresh drain flag.
    ref_drain: Vec<bool>,
    completions: BinaryHeap<Reverse<(u64, u64, u32)>>,
    /// Request classification (parallel to queue entries by id).
    class_of: std::collections::HashMap<u64, ReqClass>,
    /// Scratch for ranks whose banks auto-precharged this tick (reused
    /// across ticks — the hot loop must not allocate).
    autopre_scratch: Vec<u32>,
    /// Per-rank open-bank count (active-standby energy accounting).
    rank_open: Vec<u32>,
    rank_active_since: Vec<u64>,
    /// Cycles each rank spent with >= 1 bank open.
    pub rank_active_cycles: Vec<u64>,
    /// Locations of writes issued (drained from the write queue) by the
    /// most recent [`MemController::tick`]. The sharded runner mirrors
    /// write-queue contents on the coordinating thread for exact
    /// write-to-read forwarding decisions; this log is how a drain
    /// propagates back to that mirror at the epoch barrier. Cleared at
    /// the start of every tick, so it holds at most one entry (one
    /// command per bus cycle) and never grows.
    wq_drained: Vec<Loc>,
}

impl MemController {
    pub fn new(cfg: &SystemConfig, kind: MechanismKind, channel: u32) -> Self {
        Self {
            dev: Channel::new(&cfg.dram, &cfg.timing),
            channel,
            rq: RequestQueue::new(cfg.mc.read_queue),
            wq: RequestQueue::new(cfg.mc.write_queue),
            sink: CommandSink::new(cfg, kind),
            policy: build_policy(cfg.mc.scheduler),
            engine: BankEngine::new(
                cfg.dram.ranks,
                cfg.dram.banks,
                channel,
                cfg.mc.read_queue + cfg.mc.write_queue,
            ),
            row_policy: cfg.mc.row_policy,
            write_drain: false,
            wq_hi: cfg.mc.write_hi_watermark,
            wq_lo: cfg.mc.write_lo_watermark,
            ref_drain: vec![false; cfg.dram.ranks],
            completions: BinaryHeap::new(),
            class_of: std::collections::HashMap::new(),
            autopre_scratch: Vec::with_capacity(cfg.dram.ranks * cfg.dram.banks),
            rank_open: vec![0; cfg.dram.ranks],
            rank_active_since: vec![0; cfg.dram.ranks],
            rank_active_cycles: vec![0; cfg.dram.ranks],
            wq_drained: Vec::new(),
        }
    }

    /// Controller statistics (owned by the CommandSink layer).
    pub fn stats(&self) -> &McStats {
        &self.sink.stats
    }

    /// Per-read latency histogram for this channel (recorded at column
    /// issue; merged across channels into [`crate::sim::SimResult`]).
    pub fn latency_hist(&self) -> &crate::sim::latency_hist::LatencyHist {
        &self.sink.latency
    }

    /// Row-level temporal locality tracker.
    pub fn rltl(&self) -> &RltlTracker {
        &self.sink.rltl
    }

    /// Row-reuse tracker.
    pub fn reuse(&self) -> &ReuseTracker {
        &self.sink.reuse
    }

    /// The scheduling policy this controller runs.
    pub fn scheduler(&self) -> SchedulerKind {
        self.policy.kind()
    }

    /// The channel this controller drives.
    pub fn channel_id(&self) -> u32 {
        self.channel
    }

    /// Channel-qualified row identity for mechanism/RLTL keys.
    #[inline]
    fn row_key(&self, rank: u32, bank: u32, row: u32) -> RowKey {
        RowKey::new_in_channel(self.channel, rank, bank, row)
    }

    fn rank_opened(&mut self, rank: usize, now: u64) {
        if self.rank_open[rank] == 0 {
            self.rank_active_since[rank] = now;
        }
        self.rank_open[rank] += 1;
    }

    fn rank_closed(&mut self, rank: usize, now: u64) {
        debug_assert!(self.rank_open[rank] > 0);
        self.rank_open[rank] -= 1;
        if self.rank_open[rank] == 0 {
            self.rank_active_cycles[rank] +=
                now.saturating_sub(self.rank_active_since[rank]);
        }
    }

    /// Replace the mechanism (coordinator sweeps reuse a controller).
    pub fn set_mechanism(&mut self, mech: Box<dyn Mechanism>) {
        self.sink.set_mechanism(mech);
    }

    /// Queue occupancy (reads, writes).
    pub fn occupancy(&self) -> (usize, usize) {
        (self.rq.len(), self.wq.len())
    }

    /// Write locations drained from the write queue by the most recent
    /// [`MemController::tick`] (at most one — one command per cycle).
    pub fn drained_writes(&self) -> &[Loc] {
        &self.wq_drained
    }

    /// Current write-queue locations, in queue-slot order. Used by the
    /// sharded runner to seed its coordinator-side write-queue mirror
    /// (exact write-to-read forwarding without touching the controller).
    pub fn write_queue_locs(&self) -> impl Iterator<Item = Loc> + '_ {
        self.wq.iter().map(|r| r.loc)
    }

    /// True if a read can be accepted right now.
    pub fn can_accept_read(&self) -> bool {
        !self.rq.is_full()
    }

    pub fn can_accept_write(&self) -> bool {
        !self.wq.is_full()
    }

    /// Enqueue a request. Returns false (and counts a reject) if full.
    /// Reads that match a queued write are forwarded without DRAM access.
    pub fn enqueue(&mut self, req: Request, now: u64) -> bool {
        if req.is_write {
            if self.wq.is_full() {
                self.sink.stats.rejects += 1;
                return false;
            }
            self.engine.on_enqueue(&req.loc, self.dev.bank(&req.loc).open_row());
            self.wq.push(req);
            true
        } else {
            // Write-to-read forwarding at line granularity.
            let fwd = self.wq.iter().any(|w| {
                w.loc.rank == req.loc.rank
                    && w.loc.bank == req.loc.bank
                    && w.loc.row == req.loc.row
                    && w.loc.col == req.loc.col
            });
            if fwd {
                self.sink.stats.wq_forwards += 1;
                self.completions.push(Reverse((now + 1, req.id, req.core)));
                return true;
            }
            if self.rq.is_full() {
                self.sink.stats.rejects += 1;
                return false;
            }
            self.engine.on_enqueue(&req.loc, self.dev.bank(&req.loc).open_row());
            self.rq.push(req);
            true
        }
    }

    /// Advance one bus cycle: resolve auto-precharges, run the refresh
    /// engine, issue at most one command, then drain due completions into
    /// `out`.
    pub fn tick(&mut self, now: u64, out: &mut Vec<Completion>) {
        self.wq_drained.clear();
        self.resolve_autopre(now);
        if !self.refresh_engine(now) {
            self.schedule(now);
        }
        while let Some(&Reverse((ready, id, core))) = self.completions.peek() {
            if ready > now {
                break;
            }
            self.completions.pop();
            out.push(Completion { req_id: id, core, ready });
        }
    }

    /// The cycle at which the earliest pending completion becomes ready
    /// (fast-forward hint for the system loop).
    pub fn next_completion_at(&self) -> Option<u64> {
        self.completions.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn has_work(&self) -> bool {
        !self.rq.is_empty() || !self.wq.is_empty() || !self.completions.is_empty()
    }

    /// Read-only scheduling context for the current device + index state.
    #[inline]
    fn ctx(&self, now: u64) -> SchedCtx<'_> {
        SchedCtx { dev: &self.dev, ref_drain: &self.ref_drain, engine: &self.engine, now }
    }

    /// Earliest bus cycle `>= now` at which ticking this controller could
    /// do anything: deliver a completion, resolve an auto-precharge,
    /// start or advance a refresh, eagerly close a row (closed policy),
    /// or issue a command for a queued request — the event-kernel wake
    /// contract (see [`crate::sim::engine`]).
    ///
    /// The bound is *composed from the layer bounds*: device/refresh
    /// terms from the controller itself, the eager-PRE term from the
    /// BankEngine's open-row-hit index, and the queued-request term from
    /// [`SchedPolicy::next_ready_at`] — so a policy change can never
    /// silently diverge from a hand-copied wake computation. Each term is
    /// a conservative *lower* bound (a too-early tick is a no-op), but
    /// must never be later than the layer's true next action.
    pub fn next_event_at(&self, now: u64) -> u64 {
        // The write-drain hysteresis flag is itself mutable state the
        // strict loop re-evaluates every bus cycle, and the opportunistic
        // trigger can oscillate with period 2 (rq empty, 0 < wq <= lo
        // flips it on, the yield-back flips it off), making the write
        // issue cycle depend on tick parity. Any tick that would flip the
        // flag is therefore an event: report "hot" and let the kernel
        // tick per-cycle through the window, exactly like the strict
        // loop. (Ticking extra cycles is always safe — every event-mode
        // tick coincides with a strict-mode tick.)
        let drain_flips = if !self.write_drain {
            self.wq.len() >= self.wq_hi || (self.rq.is_empty() && !self.wq.is_empty())
        } else {
            self.wq.is_empty()
                || self.wq.len() <= self.wq_lo
                || (!self.rq.is_empty() && self.wq.len() < self.wq_hi)
        };
        if drain_flips {
            return now;
        }
        let mut t = u64::MAX;
        if let Some(r) = self.next_completion_at() {
            t = t.min(r);
        }
        for (ri, rank) in self.dev.ranks.iter().enumerate() {
            // The tREFI deadline flips this rank into drain mode.
            t = t.min(rank.next_refresh_at);
            for bank in &rank.banks {
                if let Some(ap) = bank.next_autopre_at() {
                    t = t.min(ap);
                }
            }
            if self.ref_drain[ri] {
                // Drain in progress: next action is the REF itself (all
                // banks closed) or the PRE of an open bank.
                if rank.all_closed() {
                    t = t.min(rank.ref_busy_until.max(now));
                } else {
                    for bank in &rank.banks {
                        if bank.open_row().is_some() {
                            t = t.min(bank.pre_at.max(rank.ref_busy_until));
                        }
                    }
                }
            }
        }
        // Closed-row policy: the eager-precharge pass closes an open bank
        // with no queued hits as soon as tRAS/tRTP allow. The BankEngine's
        // incremental open-row-hit index answers "any hits?" in O(1) —
        // the pre-refactor code rebuilt a scratch bitmap from both queues
        // (an O(queues) scan plus a heap allocation) on every call.
        if self.row_policy == RowPolicy::Closed {
            for (ri, rank) in self.dev.ranks.iter().enumerate() {
                if self.ref_drain[ri] {
                    continue;
                }
                for (bi, bank) in rank.banks.iter().enumerate() {
                    if bank.open_row().is_some()
                        && !self.engine.open_row_has_hit(ri as u32, bi as u32)
                    {
                        t = t.min(bank.pre_at);
                    }
                }
            }
        }
        // Queued requests: the policy layer owns the bound for when its
        // next pick could become legal (queue arrivals re-trigger this
        // computation, so a fresh request surfaces at the next bus
        // boundary).
        t = t.min(self.policy.next_ready_at(&self.ctx(now), &self.rq, &self.wq));
        t.max(now)
    }

    fn resolve_autopre(&mut self, now: u64) {
        // Reused scratch (taken, not allocated): the hot loop stays
        // allocation-free even on ticks that close banks.
        let mut closed = std::mem::take(&mut self.autopre_scratch);
        debug_assert!(closed.is_empty());
        let sink = &mut self.sink;
        let engine = &mut self.engine;
        let channel = self.channel;
        self.dev.tick_autopre(now, |rank, bank, row, owner, cycle, act_cycle| {
            let key = RowKey::new_in_channel(channel, rank, bank, row);
            sink.on_precharge(cycle, owner, key, act_cycle);
            engine.on_row_closed(rank, bank);
            closed.push(rank);
        });
        for &rank in &closed {
            self.rank_closed(rank as usize, now);
        }
        closed.clear();
        self.autopre_scratch = closed;
    }

    /// Refresh engine. Returns true if it consumed the command slot.
    fn refresh_engine(&mut self, now: u64) -> bool {
        for rank_idx in 0..self.dev.ranks.len() {
            if self.dev.ranks[rank_idx].refresh_due(now) {
                self.ref_drain[rank_idx] = true;
            }
            if !self.ref_drain[rank_idx] {
                continue;
            }
            let rank = &self.dev.ranks[rank_idx];
            if rank.all_closed() {
                let loc = Loc {
                    channel: self.channel,
                    rank: rank_idx as u32,
                    bank: 0,
                    row: 0,
                    col: 0,
                };
                if self.dev.can_issue(CommandKind::Refresh, &loc, now) {
                    self.dev.issue(
                        Command { kind: CommandKind::Refresh, loc },
                        now,
                        0,
                        0,
                        0,
                    );
                    let count = self.dev.ranks[rank_idx].refresh_count;
                    self.sink.on_refresh(now, rank_idx as u32, count);
                    self.ref_drain[rank_idx] = false;
                    return true;
                }
                continue;
            }
            // Precharge one open bank (oldest activation first).
            if let Some(bi) = rank.oldest_open_bank() {
                let bank = &self.dev.ranks[rank_idx].banks[bi];
                let row = bank.open_row().expect("oldest_open_bank returns open banks");
                let loc = Loc {
                    channel: self.channel,
                    rank: rank_idx as u32,
                    bank: bi as u32,
                    row,
                    col: 0,
                };
                if self.dev.can_issue(CommandKind::Precharge, &loc, now) {
                    self.issue_precharge(now, loc);
                    return true;
                }
            }
            // Drain in progress but nothing legal: hold the slot so ACTs
            // cannot sneak in and extend the drain indefinitely.
            return true;
        }
        false
    }

    fn issue_precharge(&mut self, now: u64, loc: Loc) {
        let owner = self.dev.bank(&loc).open_owner;
        let act_cycle = self.dev.bank(&loc).act_cycle;
        self.dev.issue(Command { kind: CommandKind::Precharge, loc }, now, 0, 0, owner);
        let key = self.row_key(loc.rank, loc.bank, loc.row);
        self.sink.on_precharge(now, owner, key, act_cycle);
        self.engine.on_row_closed(loc.rank, loc.bank);
        self.rank_closed(loc.rank as usize, now);
    }

    /// One scheduling slot: write-drain hysteresis, then the policy's
    /// column pass, then its ACT/PRE pass, then (closed policy) the eager
    /// precharge pass. Issues at most one command.
    fn schedule(&mut self, now: u64) {
        // Write drain mode hysteresis with read priority: drain when the
        // write queue is critically full (forced) or when there are no
        // reads to serve (opportunistic); yield back to reads as soon as
        // they arrive unless the forced condition still holds. This
        // prevents write bursts from starving the read path.
        if !self.write_drain {
            if self.wq.len() >= self.wq_hi || (self.rq.is_empty() && !self.wq.is_empty()) {
                self.write_drain = true;
            }
        } else if self.wq.is_empty()
            || self.wq.len() <= self.wq_lo
            || (!self.rq.is_empty() && self.wq.len() < self.wq_hi)
        {
            self.write_drain = false;
        }
        let serving_writes = self.write_drain && !self.wq.is_empty();
        if self.rq.is_empty() && self.wq.is_empty() {
            // Idle fast path; the closed policy still parks open banks.
            if self.row_policy == RowPolicy::Closed {
                self.eager_precharge(now);
            }
            return;
        }

        // Pass 1: ready column command (policy's pick — FR-FCFS takes the
        // oldest row hit). The closed-row policy precharges via the
        // eager-idle pass (pass 3) rather than auto-precharge: deciding
        // at PRE time with live queue knowledge avoids closing a row
        // whose next hit is still in flight (DDR3 RDA cannot be
        // cancelled).
        let picked = {
            let ctx = SchedCtx {
                dev: &self.dev,
                ref_drain: &self.ref_drain,
                engine: &self.engine,
                now,
            };
            let queue = if serving_writes { &self.wq } else { &self.rq };
            self.policy.pick_column(&ctx, queue)
        };
        if let Some(key) = picked {
            let req = if serving_writes { self.wq.get(key) } else { self.rq.get(key) };
            let kind = if req.is_write { CommandKind::Write } else { CommandKind::Read };
            let ready = self.dev.issue(Command { kind, loc: req.loc }, now, 0, 0, req.core);
            let class = self.class_of.remove(&req.id).unwrap_or(ReqClass::Hit);
            let read_latency = if req.is_write {
                self.wq.remove(key);
                self.wq_drained.push(req.loc);
                None
            } else {
                let ready = ready.expect("read returns data-ready cycle");
                self.completions.push(Reverse((ready, req.id, req.core)));
                self.rq.remove(key);
                Some(ready - req.arrived)
            };
            self.engine.on_dequeue(&req.loc, self.dev.bank(&req.loc).open_row());
            self.sink.on_column(class, req.is_write, read_latency);
            self.policy.on_column_issued(now, req.core);
            return;
        }

        // Pass 2: ready ACT or conflict-PRE (policy's pick).
        let picked = {
            let ctx = SchedCtx {
                dev: &self.dev,
                ref_drain: &self.ref_drain,
                engine: &self.engine,
                now,
            };
            let queue = if serving_writes { &self.wq } else { &self.rq };
            self.policy.pick_act_pre(&ctx, queue)
        };
        if picked.is_none() && self.row_policy == RowPolicy::Closed {
            self.eager_precharge(now);
            return;
        }
        if let Some((key, kind)) = picked {
            let req = if serving_writes { self.wq.get(key) } else { self.rq.get(key) };
            match kind {
                CommandKind::Activate => {
                    let key = self.row_key(req.loc.rank, req.loc.bank, req.loc.row);
                    let grant = self.sink.on_activate(now, req.core, key);
                    self.dev.issue(
                        Command { kind, loc: req.loc },
                        now,
                        grant.trcd,
                        grant.tras,
                        req.core,
                    );
                    self.engine.on_row_opened(req.loc.rank, req.loc.bank, req.loc.row);
                    self.rank_opened(req.loc.rank as usize, now);
                    self.class_of.entry(req.id).or_insert(ReqClass::Miss);
                }
                CommandKind::Precharge => {
                    self.class_of.entry(req.id).or_insert(ReqClass::Conflict);
                    let mut loc = req.loc;
                    loc.row = self
                        .dev
                        .bank(&req.loc)
                        .open_row()
                        .expect("policy picked PRE on an open bank");
                    self.issue_precharge(now, loc);
                }
                _ => unreachable!("policies pick only ACT or PRE"),
            }
        }
    }

    /// Pass 3 (closed-row policy): eager precharge of any open bank with
    /// no pending hits, using the spare command slot. tRAS reductions make
    /// this PRE legal earlier — ChargeCache's tRAS benefit under the
    /// closed policy. The hit check is the BankEngine's O(1) index.
    fn eager_precharge(&mut self, now: u64) {
        for ri in 0..self.dev.ranks.len() {
            if self.ref_drain[ri] {
                continue;
            }
            for bi in 0..self.dev.ranks[ri].banks.len() {
                let open = self.dev.ranks[ri].banks[bi].open_row();
                if let Some(open) = open {
                    if !self.engine.open_row_has_hit(ri as u32, bi as u32) {
                        let loc = Loc {
                            channel: self.channel,
                            rank: ri as u32,
                            bank: bi as u32,
                            row: open,
                            col: 0,
                        };
                        if self.dev.can_issue(CommandKind::Precharge, &loc, now) {
                            self.issue_precharge(now, loc);
                            return;
                        }
                    }
                }
            }
        }
    }

    /// Finalize open-bank accounting at end of simulation, and sweep the
    /// classification map: every surviving `class_of` entry must belong
    /// to a still-queued request (requests retired through any other path
    /// — forwarding, simulation end — must not leak entries).
    pub fn finalize(&mut self, now: u64) {
        for rank in &self.dev.ranks {
            for b in &rank.banks {
                if b.open_row().is_some() {
                    self.sink.stats.bank_open_cycles += now.saturating_sub(b.act_cycle);
                }
            }
        }
        for r in 0..self.rank_open.len() {
            if self.rank_open[r] > 0 {
                self.rank_active_cycles[r] +=
                    now.saturating_sub(self.rank_active_since[r]);
                self.rank_active_since[r] = now;
            }
        }
        let (rq, wq) = (&self.rq, &self.wq);
        let before = self.class_of.len();
        self.class_of.retain(|id, _| rq.contains_id(*id) || wq.contains_id(*id));
        debug_assert_eq!(
            before,
            self.class_of.len(),
            "class_of leaked {} entries for retired requests",
            before - self.class_of.len()
        );
    }

    /// Reset statistics (end of warmup). Mechanism state is retained —
    /// that is the point of warmup.
    pub fn reset_stats(&mut self) {
        self.sink.reset_stats();
    }

    /// Checkpoint: device, both queues (slab-verbatim), sink (mechanism
    /// tables + trackers + stats), policy, and the controller's own
    /// bookkeeping. The BankEngine is *not* serialized — it is an index
    /// over queues + open rows and is re-derived on import by replaying
    /// `on_enqueue` for every queued request (the exact recipe
    /// `debug_assert_consistent` checks against). `autopre_scratch` and
    /// `wq_drained` are cleared at the top of every tick and carry no
    /// information across the snapshot boundary.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::MC);
        self.dev.export_state(enc);
        self.rq.export_state(enc);
        self.wq.export_state(enc);
        self.sink.export_state(enc);
        self.policy.export_state(enc);
        enc.bool(self.write_drain);
        enc.usize(self.ref_drain.len());
        for &d in &self.ref_drain {
            enc.bool(d);
        }
        let mut comps: Vec<(u64, u64, u32)> =
            self.completions.iter().map(|Reverse(t)| *t).collect();
        comps.sort_unstable();
        enc.usize(comps.len());
        for (ready, id, core) in comps {
            enc.u64(ready);
            enc.u64(id);
            enc.u32(core);
        }
        let mut classes: Vec<(u64, u64)> = self
            .class_of
            .iter()
            .map(|(&id, &c)| {
                (
                    id,
                    match c {
                        ReqClass::Hit => 0u64,
                        ReqClass::Miss => 1,
                        ReqClass::Conflict => 2,
                    },
                )
            })
            .collect();
        classes.sort_unstable();
        enc.usize(classes.len());
        for (id, c) in classes {
            enc.u64(id);
            enc.u64(c);
        }
        for &o in &self.rank_open {
            enc.u32(o);
        }
        for &s in &self.rank_active_since {
            enc.u64(s);
        }
        for &c in &self.rank_active_cycles {
            enc.u64(c);
        }
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::MC)?;
        self.dev.import_state(dec)?;
        self.rq.import_state(dec)?;
        self.wq.import_state(dec)?;
        self.sink.import_state(dec)?;
        self.policy.import_state(dec)?;
        self.write_drain = dec.bool()?;
        if dec.usize()? != self.ref_drain.len() {
            return None; // rank count is config-derived shape
        }
        for d in self.ref_drain.iter_mut() {
            *d = dec.bool()?;
        }
        self.completions.clear();
        for _ in 0..dec.usize()? {
            let ready = dec.u64()?;
            let id = dec.u64()?;
            let core = dec.u32()?;
            self.completions.push(Reverse((ready, id, core)));
        }
        self.class_of.clear();
        for _ in 0..dec.usize()? {
            let id = dec.u64()?;
            let class = match dec.u64()? {
                0 => ReqClass::Hit,
                1 => ReqClass::Miss,
                2 => ReqClass::Conflict,
                _ => return None,
            };
            self.class_of.insert(id, class);
        }
        for o in self.rank_open.iter_mut() {
            *o = dec.u32()?;
        }
        for s in self.rank_active_since.iter_mut() {
            *s = dec.u64()?;
        }
        for c in self.rank_active_cycles.iter_mut() {
            *c = dec.u64()?;
        }
        self.wq_drained.clear();
        // Re-derive the BankEngine index from restored queues + open rows
        // (mirror of the enqueue path). Generation-stamped reset: the
        // tables are wiped in O(banks) and refilled in place, so a sweep
        // leg's replay allocates nothing.
        let Self { engine, rq, wq, dev, .. } = self;
        engine.clear();
        for req in rq.iter().chain(wq.iter()) {
            engine.on_enqueue(&req.loc, dev.bank(&req.loc).open_row());
        }
        Some(())
    }

    /// Test hook: re-derive the BankEngine indexes from queue + device
    /// state and assert they match (debug builds only).
    #[cfg(test)]
    fn assert_engine_consistent(&self) {
        self.engine.debug_assert_consistent(
            self.rq.iter().chain(self.wq.iter()),
            |rank, bank| {
                self.dev.ranks[rank as usize].banks[bank as usize].open_row()
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn req(id: u64, bank: u32, row: u32, col: u32, write: bool) -> Request {
        Request {
            id,
            core: 0,
            loc: Loc { channel: 0, rank: 0, bank, row, col },
            is_write: write,
            arrived: 0,
        }
    }

    fn run_until_complete(mc: &mut MemController, mut now: u64, deadline: u64) -> Vec<Completion> {
        let mut done = Vec::new();
        while now < deadline {
            mc.tick(now, &mut done);
            now += 1;
        }
        done
    }

    #[test]
    fn single_read_completes_with_expected_latency() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        assert!(mc.enqueue(req(1, 0, 5, 3, false), 0));
        let done = run_until_complete(&mut mc, 0, 200);
        assert_eq!(done.len(), 1);
        // ACT@0 -> RD@tRCD(11) -> data at 11 + CL(11) + BL(4) = 26.
        assert_eq!(done[0].ready, 26);
        assert_eq!(mc.stats().acts, 1);
        assert_eq!(mc.stats().row_misses, 1);
    }

    #[test]
    fn row_hits_are_prioritized_and_counted() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        mc.enqueue(req(2, 0, 5, 1, false), 0);
        mc.enqueue(req(3, 0, 9, 0, false), 0); // conflicting row
        let done = run_until_complete(&mut mc, 0, 400);
        assert_eq!(done.len(), 3);
        assert_eq!(mc.stats().row_hits, 1);
        assert_eq!(mc.stats().row_misses, 1);
        assert_eq!(mc.stats().row_conflicts, 1);
        // Hit (id 2) must finish before the conflicting row 9 (id 3).
        let pos =
            |id: u64| done.iter().position(|c| c.req_id == id).unwrap();
        assert!(pos(2) < pos(3));
        mc.assert_engine_consistent();
    }

    #[test]
    fn chargecache_speeds_up_reopened_row() {
        let c = cfg();
        // Baseline: open row 5, conflict to row 9, re-open row 5.
        let mut run = |kind: MechanismKind| -> u64 {
            let mut mc = MemController::new(&c, kind, 0);
            mc.enqueue(req(1, 0, 5, 0, false), 0);
            let _ = run_until_complete(&mut mc, 0, 400);
            mc.enqueue(req(2, 0, 9, 0, false), 400);
            let _ = run_until_complete(&mut mc, 400, 800);
            mc.enqueue(req(3, 0, 5, 1, false), 800);
            let done = run_until_complete(&mut mc, 800, 1600);
            assert_eq!(done.len(), 1);
            done[0].ready
        };
        let base = run(MechanismKind::Baseline);
        let cc = run(MechanismKind::ChargeCache);
        // Request 3 re-activates row 5, which ChargeCache has cached
        // (inserted at its precharge) -> 4 cycles faster tRCD.
        assert_eq!(base - cc, 4);
    }

    #[test]
    fn write_drain_hysteresis() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        // Fill write queue past the high watermark.
        for i in 0..49 {
            assert!(mc.enqueue(req(i, (i % 8) as u32, (i / 8) as u32, 0, true), 0));
        }
        let _ = run_until_complete(&mut mc, 0, 4000);
        assert!(mc.stats().writes > 0, "drain must have issued writes");
        assert!(mc.occupancy().1 <= c.mc.write_lo_watermark);
        mc.assert_engine_consistent();
    }

    #[test]
    fn read_forwarded_from_write_queue() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 3, true), 0);
        mc.enqueue(req(2, 0, 5, 3, false), 0);
        let mut done = Vec::new();
        mc.tick(0, &mut done);
        mc.tick(1, &mut done);
        assert!(done.iter().any(|c| c.req_id == 2));
        assert_eq!(mc.stats().wq_forwards, 1);
    }

    #[test]
    fn refresh_happens_on_schedule() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        let mut done = Vec::new();
        for now in 0..(c.timing.trefi * 3 + 100) {
            mc.tick(now, &mut done);
        }
        assert_eq!(mc.stats().refreshes, 3);
    }

    #[test]
    fn refresh_drains_open_banks_first() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let mut done = Vec::new();
        for now in 0..(c.timing.trefi + c.timing.trfc + 200) {
            mc.tick(now, &mut done);
        }
        assert_eq!(mc.stats().refreshes, 1);
        assert!(mc.stats().precharges >= 1);
    }

    #[test]
    fn closed_policy_precharges_idle_banks_eagerly() {
        let mut c = cfg();
        c.mc.row_policy = RowPolicy::Closed;
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let _ = run_until_complete(&mut mc, 0, 200);
        // The eager-idle pass closed the bank once no hits were pending.
        assert!(mc.dev.bank(&Loc { channel: 0, rank: 0, bank: 0, row: 5, col: 0 })
            .is_idle_closed());
        assert_eq!(mc.stats().precharges, 1);
    }

    #[test]
    fn closed_policy_keeps_row_open_while_hits_pending() {
        let mut c = cfg();
        c.mc.row_policy = RowPolicy::Closed;
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        mc.enqueue(req(2, 0, 5, 1, false), 0);
        let mut done = Vec::new();
        for now in 0..18 {
            mc.tick(now, &mut done);
        }
        // Second hit still queued or just served: row must not have been
        // precharged between the two column commands.
        assert_eq!(mc.stats().precharges, 0);
        assert_eq!(mc.stats().row_hits + mc.stats().row_misses, 2);
    }

    #[test]
    fn wake_bound_tracks_idle_act_read_and_completion() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        // Idle controller: nothing can happen before the tREFI deadline.
        assert_eq!(mc.next_event_at(0), c.timing.trefi);
        // A fresh request to a closed bank can ACT immediately.
        assert!(mc.enqueue(req(1, 0, 5, 3, false), 0));
        assert_eq!(mc.next_event_at(0), 0);
        let mut done = Vec::new();
        mc.tick(0, &mut done); // ACT issues
        // Next action: the RD once tRCD expires.
        assert_eq!(mc.next_event_at(1), c.timing.trcd);
        for now in 1..=c.timing.trcd {
            mc.tick(now, &mut done);
        }
        // RD issued at tRCD; the only remaining event is its completion
        // at tRCD + CL + BL (the queue is empty, the row stays open).
        assert_eq!(
            mc.next_event_at(c.timing.trcd + 1),
            c.timing.trcd + c.timing.cl + c.timing.tbl
        );
    }

    #[test]
    fn rltl_tracks_reopens_through_controller() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        mc.enqueue(req(1, 0, 5, 0, false), 0);
        let _ = run_until_complete(&mut mc, 0, 300);
        mc.enqueue(req(2, 0, 9, 0, false), 300); // forces PRE of row 5
        let _ = run_until_complete(&mut mc, 300, 600);
        mc.enqueue(req(3, 0, 5, 0, false), 600); // re-open row 5
        let _ = run_until_complete(&mut mc, 600, 900);
        assert_eq!(mc.rltl().activations, 3);
        assert!(mc.rltl().fraction_at_ms(1.0) > 0.0);
    }

    /// Drive a two-bank row-hit stream from `core 0` (banks 0 and 1, row
    /// 1) plus one conflicting victim read (bank 0, row 99, core 1) at
    /// `victim_arrives`; returns the victim's completion cycle. The
    /// stream alternates banks so tCCD gaps leave the bank-0 PRE legal
    /// while younger hits are still queued — the exact situation the
    /// starvation cap (and BLISS's blacklist) must resolve. A
    /// single-bank stream would instead re-arm tRTP faster than the PRE
    /// window can open, and no scheduler could close the row.
    fn hammer_until_victim_completes(sched: SchedulerKind, victim_arrives: u64) -> u64 {
        let mut c = cfg();
        c.mc.scheduler = sched;
        let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
        let mut id = 100u64;
        let mut done = Vec::new();
        for now in 0..4_000u64 {
            if now % 3 == 0 && mc.can_accept_read() {
                mc.enqueue(
                    Request {
                        id,
                        core: 0,
                        loc: Loc {
                            channel: 0,
                            rank: 0,
                            bank: (id % 2) as u32,
                            row: 1,
                            col: (id % 128) as u32,
                        },
                        is_write: false,
                        arrived: now,
                    },
                    now,
                );
                id += 1;
            }
            if now == victim_arrives {
                mc.enqueue(
                    Request {
                        id: 1,
                        core: 1,
                        loc: Loc { channel: 0, rank: 0, bank: 0, row: 99, col: 0 },
                        is_write: false,
                        arrived: now,
                    },
                    now,
                );
            }
            done.clear();
            mc.tick(now, &mut done);
            if done.iter().any(|c| c.req_id == 1) {
                assert!(mc.stats().row_conflicts >= 1);
                return now;
            }
        }
        panic!("victim starved under {sched:?}");
    }

    /// Satellite: FR-FCFS starvation semantics. A conflicting request
    /// older than `STARVE_CAP_CYCLES` closes the row even while younger
    /// row hits keep arriving — and not before the cap, while hits are
    /// pending.
    #[test]
    fn starvation_cap_overrides_row_hit_priority() {
        let victim_arrives = 40u64;
        let at = hammer_until_victim_completes(SchedulerKind::FrFcfs, victim_arrives);
        // Not before the cap: hits were always pending, so the PRE could
        // only have issued once the victim's age reached the cap.
        assert!(
            at >= victim_arrives + STARVE_CAP_CYCLES,
            "victim finished at {at}, before the starvation cap"
        );
        // And promptly after it (PRE + ACT + RD + data, bounded loosely).
        assert!(
            at <= victim_arrives + STARVE_CAP_CYCLES + 120,
            "victim finished at {at}, long after the cap opened"
        );
    }

    /// Strict FCFS must serve a conflicting older request before a
    /// younger row hit (the inverse of FR-FCFS's reordering).
    #[test]
    fn fcfs_serves_in_strict_arrival_order() {
        let run = |sched: SchedulerKind| -> Vec<u64> {
            let mut c = cfg();
            c.mc.scheduler = sched;
            let mut mc = MemController::new(&c, MechanismKind::Baseline, 0);
            // Open row 5 with request 1, then a conflict (row 9) and a
            // row-5 hit behind it.
            mc.enqueue(req(1, 0, 5, 0, false), 0);
            mc.enqueue(req(2, 0, 9, 0, false), 0);
            mc.enqueue(req(3, 0, 5, 1, false), 0);
            run_until_complete(&mut mc, 0, 600)
                .iter()
                .map(|c| c.req_id)
                .collect()
        };
        assert_eq!(run(SchedulerKind::Fcfs), vec![1, 2, 3], "FCFS keeps arrival order");
        assert_eq!(run(SchedulerKind::FrFcfs), vec![1, 3, 2], "FR-FCFS reorders for the hit");
    }

    /// BLISS: once the streaming core is blacklisted, a conflicting
    /// request from another core closes its row long before the FR-FCFS
    /// starvation cap would have.
    #[test]
    fn bliss_breaks_streaks_faster_than_starvation_cap() {
        let victim_arrives = 40u64;
        let bliss = hammer_until_victim_completes(SchedulerKind::Bliss, victim_arrives);
        let frfcfs = hammer_until_victim_completes(SchedulerKind::FrFcfs, victim_arrives);
        assert!(
            bliss < frfcfs,
            "BLISS ({bliss}) should beat FR-FCFS's starvation cap ({frfcfs})"
        );
        assert!(
            bliss < victim_arrives + STARVE_CAP_CYCLES,
            "BLISS victim ({bliss}) should finish before the cap"
        );
    }

    /// The controller stamps its channel id into refresh/eager-PRE `Loc`s
    /// and into mechanism keys (satellite: no hard-coded channel 0). The
    /// ChargeCache hit pins key *consistency* across the PRE-insert and
    /// ACT-lookup paths on a nonzero channel: if any one site fell back
    /// to channel-0 keys, the re-activation would miss and the reduced
    /// grant would vanish.
    #[test]
    fn channel_id_reaches_mechanism_keys() {
        let c = cfg();
        let mut mc = MemController::new(&c, MechanismKind::ChargeCache, 3);
        assert_eq!(mc.channel_id(), 3);
        assert_eq!(mc.row_key(0, 0, 5).channel(), 3);
        let rd = |id: u64, row: u32| Request {
            id,
            core: 0,
            loc: Loc { channel: 3, rank: 0, bank: 0, row, col: 0 },
            is_write: false,
            arrived: 0,
        };
        mc.enqueue(rd(1, 5), 0); // open row 5
        let _ = run_until_complete(&mut mc, 0, 400);
        mc.enqueue(rd(2, 9), 400); // conflict: PRE row 5 -> HCRAC insert
        let _ = run_until_complete(&mut mc, 400, 800);
        mc.enqueue(rd(3, 5), 800); // re-open row 5 -> HCRAC hit
        let _ = run_until_complete(&mut mc, 800, 1600);
        assert_eq!(mc.stats().acts, 3);
        assert_eq!(
            mc.stats().acts_reduced,
            1,
            "channel-3 PRE-insert and ACT-lookup keys must agree"
        );
    }

    /// Checkpoint identity at the controller layer: snapshot mid-traffic
    /// (in-flight completions, queued requests, open rows, refresh drain
    /// possibly pending), restore into a fresh controller, then drive both
    /// with the same request stream — every completion and stat must
    /// match, and the rebuilt BankEngine must pass its oracle.
    #[test]
    fn checkpoint_restore_is_bit_identical_under_traffic() {
        use crate::sim::checkpoint::{Dec, Enc};
        for kind in [MechanismKind::Baseline, MechanismKind::ChargeCache, MechanismKind::Nuat] {
            let c = cfg();
            let mut rng = crate::trace::XorShift64::new(0xC0DE);
            let mut mc = MemController::new(&c, kind, 0);
            let mut done = Vec::new();
            let mut id = 0u64;
            fn traffic(
                mc: &mut MemController,
                now: u64,
                rng: &mut crate::trace::XorShift64,
                id: &mut u64,
            ) {
                if rng.below(3) == 0 {
                    let req = Request {
                        id: *id,
                        core: rng.below(4) as u32,
                        loc: Loc {
                            channel: 0,
                            rank: 0,
                            bank: rng.below(8) as u32,
                            row: rng.below(16) as u32,
                            col: rng.below(128) as u32,
                        },
                        is_write: rng.below(4) == 0,
                        arrived: now,
                    };
                    if mc.enqueue(req, now) {
                        *id += 1;
                    }
                }
            }
            for now in 0..8_000u64 {
                traffic(&mut mc, now, &mut rng, &mut id);
                done.clear();
                mc.tick(now, &mut done);
            }

            let mut enc = Enc::new();
            mc.export_state(&mut enc);
            let words = enc.into_words();
            let mut fresh = MemController::new(&c, kind, 0);
            let mut dec = Dec::new(&words);
            fresh.import_state(&mut dec).expect("import must succeed");
            assert!(dec.finished());
            fresh.assert_engine_consistent();

            // Same future on both sides, same RNG stream.
            let rng_words = rng.state();
            let mut rng2 = crate::trace::XorShift64::from_state(rng_words);
            let mut id2 = id;
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for now in 8_000..16_000u64 {
                traffic(&mut mc, now, &mut rng, &mut id);
                traffic(&mut fresh, now, &mut rng2, &mut id2);
                mc.tick(now, &mut a);
                fresh.tick(now, &mut b);
            }
            let pairs: Vec<(u64, u64)> = a.iter().map(|c| (c.req_id, c.ready)).collect();
            let pairs2: Vec<(u64, u64)> = b.iter().map(|c| (c.req_id, c.ready)).collect();
            assert_eq!(pairs, pairs2, "completions diverged after restore ({kind:?})");
            assert_eq!(mc.stats(), fresh.stats(), "stats diverged after restore ({kind:?})");
        }
    }

    /// Randomized cross-check of the BankEngine's incremental indexes
    /// against a from-scratch re-derivation, across every scheduler and
    /// both row policies. A missed notification on any enqueue/issue/
    /// precharge path would leave the counters stale *identically* in
    /// strict and event mode, so the differential tests cannot catch it —
    /// only this oracle can.
    #[test]
    fn bank_engine_index_survives_random_traffic() {
        let mut seed = 0xB1E5u64;
        for sched in SchedulerKind::all() {
            for row_policy in [RowPolicy::Open, RowPolicy::Closed] {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                let mut rng = crate::trace::XorShift64::new(seed);
                let mut c = cfg();
                c.mc.scheduler = sched;
                c.mc.row_policy = row_policy;
                let mut mc = MemController::new(&c, MechanismKind::ChargeCache, 0);
                let mut done = Vec::new();
                let mut id = 0u64;
                for now in 0..20_000u64 {
                    if rng.below(3) == 0 {
                        let req = Request {
                            id,
                            core: rng.below(4) as u32,
                            loc: Loc {
                                channel: 0,
                                rank: 0,
                                bank: rng.below(8) as u32,
                                row: rng.below(16) as u32,
                                col: rng.below(128) as u32,
                            },
                            is_write: rng.below(4) == 0,
                            arrived: now,
                        };
                        if mc.enqueue(req, now) {
                            id += 1;
                        }
                    }
                    done.clear();
                    mc.tick(now, &mut done);
                    if now % 64 == 0 {
                        mc.assert_engine_consistent();
                    }
                }
                mc.assert_engine_consistent();
            }
        }
    }
}
