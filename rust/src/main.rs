//! ChargeCache CLI — regenerates every figure/table of the paper, runs
//! ad-hoc simulations, and executes declarative scenario specs.
//!
//! ```text
//! chargecache run      [--workload W | --mix M] [--mechanism M] [--cores N]
//! chargecache suite    [--cores 1|8]                 fig4 + fig5 views
//! chargecache figures  [--quick] [--result-cache DIR]   every figure
//! chargecache fig1 | fig3 | fig4 | fig5 | area | timing-table | gen-traces
//! chargecache sweep    capacity|duration|temperature | --param PATH ...
//! chargecache scenario FILE... [--validate]
//! chargecache params                                 every --set parameter
//! chargecache help [COMMAND]
//! ```
//!
//! The command table ([`COMMANDS`]) is the single source for parsing
//! *and* help: `chargecache help` renders from it, `help COMMAND` shows
//! per-command flags. Every command accepts `--set path=value` overrides
//! for any [`SystemConfig`] field (see `chargecache params` for the
//! registry) plus the common horizon/memoization flags below.
//!
//! Every simulation runs on the event-driven kernel; pass `--strict-tick`
//! to use the original per-cycle loop (the differential-testing oracle —
//! results are bit-identical, only slower). Two threading knobs compose:
//! `--threads N` (env `PALLAS_THREADS`) pins how many *jobs* run
//! concurrently, and `--sim-threads N` (env `PALLAS_SIM_THREADS`,
//! registry `sim.threads`) shards each simulation's memory channels
//! across N worker threads — bit-identical to `--sim-threads 1` by the
//! epoch-barrier determinism contract (`sim::shard`). When only
//! `--sim-threads` is given, the job worker count is divided down so
//! jobs × shards stays within available parallelism.
//!
//! Every suite command executes through the fingerprint-keyed job graph
//! (`coordinator::jobs`, DESIGN.md §5): structurally identical legs are
//! deduplicated and memoized, so `figures` simulates each unique
//! (config, mechanism, workload) exactly once across all its figures and
//! scenarios sharing legs with earlier commands reuse them.
//! `--result-cache DIR` persists results across invocations; `--no-memo`
//! restores the naive one-simulation-per-leg behavior.
//!
//! The legacy `sweep-capacity` / `sweep-duration` / `sweep-temperature`
//! commands are thin deprecation aliases for `sweep <builtin>`, which
//! runs the checked-in scenario specs in `examples/scenarios/` —
//! bit-identical to the old bespoke sweep code (pinned by
//! `tests/scenario.rs`).

use chargecache::config::{schema, SystemConfig};
use chargecache::coordinator::cli::{self, Args, CommandSpec, FlagSpec};
use chargecache::coordinator::experiments::{fig1_with, run_suite_with, ExperimentScale};
use chargecache::coordinator::figures::{bar, f, log_bar, pct, print_table, slug, write_csv};
use chargecache::coordinator::jobs::{JobEngine, JobGraph, JobSpec};
use chargecache::coordinator::scenario::{ScenarioPlan, ScenarioRun, ScenarioSpec, WorkloadSel};
use chargecache::energy::HcracCost;
use chargecache::error::{Context, Result};
use chargecache::latency::MechanismKind;
use chargecache::runtime::charge_model::timing_table_or_analytic;
use chargecache::sim::engine::LoopMode;
use chargecache::sim::System;
use chargecache::trace::{file::write_trace, Profile, SynthTrace, PROFILES};
use chargecache::{bail, ensure};

/// Flags every command accepts.
const COMMON_FLAGS: &[FlagSpec] = &[
    FlagSpec::repeated("set", "PATH=VALUE", "Override any config field (see `params`)"),
    FlagSpec::value("insts", "N", "Instructions per core in the measured region"),
    FlagSpec::value("warmup", "N", "Warmup CPU cycles"),
    FlagSpec::value("mixes", "M", "Number of eight-core mixes"),
    FlagSpec::flag("quick", "Small horizon preset for smoke runs"),
    FlagSpec::value("scheduler", "NAME", "Memory scheduler (fr-fcfs | fcfs | bliss)"),
    FlagSpec::flag("strict-tick", "Per-cycle loop oracle instead of the event kernel"),
    FlagSpec::value("threads", "N", "Pin the parallel runner's job worker count"),
    FlagSpec::value("sim-threads", "N", "Channel shards per simulation (1 = single-threaded)"),
    FlagSpec::value("result-cache", "DIR", "Persist simulation results on disk"),
    FlagSpec::flag("no-memo", "Disable job dedup + caching (naive path)"),
    FlagSpec::flag("list-params", "Print the --set parameter registry and exit"),
    FlagSpec::flag("help", "Show this command's options and exit"),
];

const RUN_FLAGS: &[FlagSpec] = &[
    FlagSpec::value("workload", "NAME", "Single workload to run (default mcf)"),
    FlagSpec::value("mix", "M", "Run multiprogrammed mix M instead of a workload"),
    FlagSpec::value("mechanism", "NAME", "Mechanism (baseline | cc | nuat | cc+nuat | ll-dram)"),
    FlagSpec::value("cores", "N", "Core count (default 1)"),
    FlagSpec::value("entries", "N", "HCRAC entries per core (default 128)"),
    FlagSpec::value("duration", "MS", "Caching duration in ms (default 1.0)"),
];

const CORES_FLAG: &[FlagSpec] =
    &[FlagSpec::value("cores", "N", "1 = single-core, >1 = eight-core")];

const FIG3_FLAGS: &[FlagSpec] =
    &[FlagSpec::value("csv", "PATH", "Trajectory CSV path (default results/fig3_bitline.csv)")];

const AREA_FLAGS: &[FlagSpec] = &[
    FlagSpec::value("cores", "N", "Core count (default 8)"),
    FlagSpec::value("access-rate", "HZ", "ACT+PRE rate for dynamic power (default 170e6)"),
];

const SWEEP_FLAGS: &[FlagSpec] = &[
    FlagSpec::value("param", "PATH", "Registry path to sweep (alternative to a builtin name)"),
    FlagSpec::value("values", "V1,V2,...", "Explicit sweep values (comma-separated)"),
    FlagSpec::value("from", "X", "Range start (with --to/--steps)"),
    FlagSpec::value("to", "X", "Range end"),
    FlagSpec::value("steps", "N", "Range point count"),
    FlagSpec::flag("log", "Logarithmic range spacing"),
    FlagSpec::value(
        "derive",
        "RULE",
        "cc-timing-from-duration | cc-timing-from-temperature | latency-vs-load",
    ),
    FlagSpec::value("mechanism", "NAME", "Mechanism to measure (default cc)"),
    FlagSpec::value("base", "PRESET", "single | eight | core count (default eight)"),
    FlagSpec::flag("shared-baseline", "One Baseline at the base config (legacy sweep semantics)"),
    FlagSpec::flag("validate", "Expand and report the plan without simulating"),
];

const SCENARIO_FLAGS: &[FlagSpec] =
    &[FlagSpec::flag("validate", "Parse and expand the spec(s) without simulating")];

const GEN_TRACES_FLAGS: &[FlagSpec] =
    &[FlagSpec::value("out", "DIR", "Output directory (default traces)")];

const TIMING_TABLE_FLAGS: &[FlagSpec] =
    &[FlagSpec::value("temp", "C", "DRAM temperature in Celsius (default 85)")];

const NO_FLAGS: &[FlagSpec] = &[];

/// The subcommand table — parsing and `help` both render from it.
const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "run",
        aliases: &["simulate"],
        summary: "Run one simulation and print its stats",
        positional: None,
        flags: RUN_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "suite",
        aliases: &[],
        summary: "Full evaluation suite: Fig. 4 speedups + Fig. 5 energy",
        positional: None,
        flags: CORES_FLAG,
        deprecated: None,
    },
    CommandSpec {
        name: "figures",
        aliases: &[],
        summary: "Every figure + the capacity sweep over one memoized job graph",
        positional: None,
        flags: NO_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "fig1",
        aliases: &[],
        summary: "Fig. 1 — average t-RLTL (row-level temporal locality)",
        positional: None,
        flags: NO_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "fig3",
        aliases: &[],
        summary: "Fig. 3 — bitline voltage trajectories and ready times",
        positional: None,
        flags: FIG3_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "fig4",
        aliases: &[],
        summary: "Fig. 4 — per-mechanism speedup over baseline",
        positional: None,
        flags: CORES_FLAG,
        deprecated: None,
    },
    CommandSpec {
        name: "fig5",
        aliases: &[],
        summary: "Fig. 5 — DRAM energy reduction",
        positional: None,
        flags: CORES_FLAG,
        deprecated: None,
    },
    CommandSpec {
        name: "area",
        aliases: &[],
        summary: "Sec. 6.5 — HCRAC storage/area/power overhead",
        positional: None,
        flags: AREA_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "sweep",
        aliases: &[],
        summary:
            "Sweep parameters: a builtin (capacity | duration | temperature | tail-latency) \
             or --param",
        positional: Some("BUILTIN"),
        flags: SWEEP_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "scenario",
        aliases: &[],
        summary: "Run declarative scenario spec file(s) through the job graph",
        positional: Some("FILE"),
        flags: SCENARIO_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "params",
        aliases: &[],
        summary: "List every --set parameter (dotted path, type, default)",
        positional: None,
        flags: NO_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "gen-traces",
        aliases: &[],
        summary: "Write synthetic trace files for every workload",
        positional: None,
        flags: GEN_TRACES_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "timing-table",
        aliases: &[],
        summary: "Charge -> timing table (codesign bridge)",
        positional: None,
        flags: TIMING_TABLE_FLAGS,
        deprecated: None,
    },
    CommandSpec {
        name: "help",
        aliases: &[],
        summary: "Show help (optionally for one command)",
        positional: Some("COMMAND"),
        flags: NO_FLAGS,
        deprecated: None,
    },
    // Thin deprecation aliases for the pre-scenario sweep commands: same
    // flags, same results (bit-identity pinned by tests/scenario.rs),
    // forwarded to the scenario engine with a warning.
    CommandSpec {
        name: "sweep-capacity",
        aliases: &[],
        summary: "",
        positional: None,
        flags: NO_FLAGS,
        deprecated: Some("sweep capacity"),
    },
    CommandSpec {
        name: "sweep-duration",
        aliases: &[],
        summary: "",
        positional: None,
        flags: NO_FLAGS,
        deprecated: Some("sweep duration"),
    },
    CommandSpec {
        name: "sweep-temperature",
        aliases: &[],
        summary: "",
        positional: None,
        flags: NO_FLAGS,
        deprecated: Some("sweep temperature"),
    },
];

const TITLE: &str = "chargecache — ChargeCache (HPCA'16) reproduction\n\
\n\
  `figures` regenerates fig1 + fig4a/b + fig5 (1- and 8-core) + the\n\
  capacity sweep over ONE memoized job graph; `scenario FILE` runs any\n\
  declarative experiment grid (see examples/scenarios/) through the\n\
  same graph, so shared legs simulate exactly once.\n\
\n\
  `--set traffic.mode=<det|poisson|burst|mmpp>` switches the measured\n\
  region to open-loop arrivals at `traffic.rate_rps` with per-request\n\
  latency percentiles (see `params` for the traffic.* family and\n\
  DESIGN.md §14); `sweep tail-latency` plots p99 against offered load.";

/// Builtin sweeps: the checked-in scenario specs, embedded so they work
/// from any working directory. `examples/scenarios/` is the source of
/// truth; CI validates every file there parses and expands.
const BUILTIN_SCENARIOS: &[(&str, &str)] = &[
    ("capacity", include_str!("../../examples/scenarios/sweep_capacity.json")),
    ("duration", include_str!("../../examples/scenarios/sweep_duration.json")),
    ("temperature", include_str!("../../examples/scenarios/sweep_temperature.json")),
    ("tail-latency", include_str!("../../examples/scenarios/tail_latency.json")),
];

fn scale_from(args: &Args) -> Result<ExperimentScale> {
    let mut s = if args.flag("quick") {
        ExperimentScale::quick()
    } else {
        ExperimentScale::default()
    };
    s.insts_per_core = args.get_u64("insts", s.insts_per_core)?;
    s.warmup_cycles = args.get_u64("warmup", s.warmup_cycles)?;
    s.mixes = args.get_usize("mixes", s.mixes)?;
    s.scheduler = args.scheduler(s.scheduler)?;
    if args.flag("strict-tick") {
        s.loop_mode = LoopMode::StrictTick;
    }
    // `--set` overrides are validated once and interned into the scale;
    // every leg config the scale builds applies them last.
    s.with_overrides(args.set_overrides()?)
}

/// Build the shared job engine from the memoization flags: every suite
/// command executes through a fingerprint-keyed job graph that dedupes
/// identical (config, mechanism, workload) legs.
fn engine_from(args: &Args) -> Result<JobEngine> {
    let mut eng = match args.get("result-cache") {
        Some(dir) => JobEngine::with_disk(dir)?,
        None => JobEngine::new(),
    };
    if args.flag("no-memo") {
        eng.memo = false;
    }
    Ok(eng)
}

fn main() -> Result<()> {
    let args = Args::from_env(COMMANDS, COMMON_FLAGS)?;
    if args.flag("help") {
        // `chargecache CMD --help` — same output as `help CMD`.
        println!("{}", cli::render_command_help(args.spec, COMMON_FLAGS));
        return Ok(());
    }
    if args.flag("list-params") {
        return cmd_params();
    }
    if let Some(replacement) = args.spec.deprecated {
        // Once per process: embedders (and future multi-command drivers)
        // reuse this path, and one deprecation nudge per run is enough.
        static DEPRECATED: std::sync::Once = std::sync::Once::new();
        DEPRECATED.call_once(|| {
            eprintln!(
                "warning: `{}` is deprecated; use `chargecache {replacement}`. Simulation \
                 results are bit-identical via the scenario engine, but the CSV now lands \
                 at results/scenario_<name>.csv with axis-path headers.",
                args.command
            );
        });
    }
    // Worker-count pin for every parallel_map fan-out (reproducible
    // benchmarking); 0 keeps the PALLAS_THREADS / machine fallback.
    chargecache::coordinator::runner::set_threads(args.get_usize("threads", 0)?);
    // Shard-count pin for the channel-sharded simulation loop; a pin
    // (rather than a config field) so memoized results stay shared
    // across shard counts — sharded runs are bit-identical by contract.
    chargecache::coordinator::runner::set_sim_threads(args.get_usize("sim-threads", 0)?);
    // One engine per invocation: commands that run several experiments
    // (`figures`, multi-spec `scenario`) share its cache, so overlapping
    // legs simulate once.
    let mut eng = engine_from(&args)?;
    match args.command.as_str() {
        "run" => cmd_run(&args, &mut eng),
        "suite" => cmd_suite(&args, &mut eng),
        "figures" => cmd_figures(&args, &mut eng),
        "fig1" => cmd_fig1(&args, &mut eng),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args, &mut eng),
        "fig5" => cmd_fig5(&args, &mut eng),
        "area" => cmd_area(&args),
        "sweep" => cmd_sweep(&args, &mut eng),
        "scenario" => cmd_scenario(&args, &mut eng),
        "params" => cmd_params(),
        "gen-traces" => cmd_gen_traces(&args),
        "timing-table" => cmd_timing_table(&args),
        "help" => cmd_help(&args),
        "sweep-capacity" => run_builtin_scenario("capacity", &args, &mut eng),
        "sweep-duration" => run_builtin_scenario("duration", &args, &mut eng),
        "sweep-temperature" => run_builtin_scenario("temperature", &args, &mut eng),
        other => bail!("unhandled command {other:?} (table/dispatch mismatch)"),
    }?;
    // Dedup/hit telemetry for every command that ran the job graph.
    if eng.stats().submitted > 0 {
        println!("\n{}", eng.stats().summary());
    }
    Ok(())
}

fn cmd_help(args: &Args) -> Result<()> {
    match args.positionals.first() {
        None => println!("{}", cli::render_help(TITLE, COMMANDS, COMMON_FLAGS)),
        Some(name) => {
            let cmd = COMMANDS
                .iter()
                .find(|c| c.name == name.as_str() || c.aliases.contains(&name.as_str()))
                .with_context(|| format!("unknown command {name:?}"))?;
            println!("{}", cli::render_command_help(cmd, COMMON_FLAGS));
        }
    }
    Ok(())
}

fn cmd_params() -> Result<()> {
    let reg = schema::registry();
    println!("--set parameters ({} total, from the exhaustive registry):", reg.defs().len());
    // Grouped by dotted-path prefix in registry (first-appearance) order;
    // paths without a dot collect under "top-level".
    let mut groups: Vec<(&str, Vec<&schema::ParamDef>)> = Vec::new();
    for def in reg.defs() {
        let prefix = def.path.split_once('.').map_or("top-level", |(head, _)| head);
        match groups.iter_mut().find(|(p, _)| *p == prefix) {
            Some((_, defs)) => defs.push(def),
            None => groups.push((prefix, vec![def])),
        }
    }
    for (prefix, defs) in &groups {
        println!("\n[{prefix}]");
        let rows: Vec<Vec<String>> = defs
            .iter()
            .map(|d| {
                vec![d.path.to_string(), d.kind.describe(), d.default.clone(), d.doc.to_string()]
            })
            .collect();
        print_table(&["path", "type", "default", "description"], &rows);
    }
    Ok(())
}

fn cmd_fig1(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let scale = scale_from(args)?;
    println!("Fig. 1 — average t-RLTL ({} workloads, {} mixes)", PROFILES.len(), scale.mixes);
    let rows_data = fig1_with(scale, eng);
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|(ms, s, e)| {
            vec![
                format!("{ms} ms"),
                pct(*s),
                bar(*s, 1.0, 24),
                pct(*e),
                bar(*e, 1.0, 24),
            ]
        })
        .collect();
    print_table(&["t", "1-core", "", "8-core", ""], &rows);
    let csv_rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|(ms, s, e)| vec![ms.to_string(), s.to_string(), e.to_string()])
        .collect();
    write_csv("results/fig1_rltl.csv", &["t_ms", "single", "eight"], &csv_rows)?;
    println!("\nPaper: 1 ms-RLTL = 83% (1-core), 89% (8-core). CSV: results/fig1_rltl.csv");
    Ok(())
}

/// Fig. 3 — bitline trajectories and ready times.
///
/// With the `pjrt` feature the trajectories come from the AOT HLO
/// artifacts executed via PJRT; otherwise from the pure-Rust analytic
/// circuit model (the two are pinned against each other in tests).
fn cmd_fig3(args: &Args) -> Result<()> {
    let ages_ms = [0.0, 1.0, 4.0, 8.0, 16.0, 32.0, 48.0, 64.0];
    // Each branch produces: source label, samples per lane, sample period
    // (ns), initial voltages, row-major trajectories, per-lane ready times.
    let source: String;
    let samples: usize;
    let dt: f64;
    let v0: Vec<f64>;
    let trajectories: Vec<f64>;
    let readies: Vec<f64>;

    #[cfg(feature = "pjrt")]
    {
        use chargecache::runtime::{ChargeModelRuntime, Runtime};
        let rt = Runtime::new(Runtime::default_dir())?;
        if !rt.artifacts_present() {
            chargecache::bail!("artifacts not built — run `make artifacts` first");
        }
        let cm = ChargeModelRuntime::load(&rt)?;
        source = format!("PJRT: {}", rt.platform());
        let tau_ms = cm.meta.get("tau_leak_ms")?;
        let vdd = cm.meta.get("vdd")?;
        v0 = ages_ms
            .iter()
            .map(|&ms| vdd / 2.0 + (vdd / 2.0) * (-(ms) / tau_ms).exp())
            .collect();
        let v0_f32: Vec<f32> = v0.iter().map(|&v| v as f32).collect();
        let (s, data) = cm.bitline_sweep(&v0_f32)?;
        samples = s;
        dt = cm.meta.get("dt_ns")? * cm.meta.get("traj_stride")?;
        trajectories = data.iter().map(|&v| v as f64).collect();
        let v_ready = cm.meta.get("v_ready")?;
        readies = (0..ages_ms.len())
            .map(|lane| {
                let row = &trajectories[lane * samples..(lane + 1) * samples];
                row.iter().position(|&v| v >= v_ready).unwrap_or(samples) as f64 * dt
            })
            .collect();
    }

    #[cfg(not(feature = "pjrt"))]
    {
        use chargecache::latency::timing_table::circuit;
        source = "analytic circuit model (build with --features pjrt for HLO)".to_string();
        let (a, tau_ms) = circuit::calibrate();
        let beta = circuit::calibrate_restore(a, tau_ms);
        v0 = ages_ms
            .iter()
            .map(|&ms| circuit::v_cell_after(ms * 1e-3, circuit::T_CAL_CELSIUS, tau_ms))
            .collect();
        let stride = 10usize;
        dt = circuit::DT_NS * stride as f64;
        let lanes: Vec<Vec<f64>> =
            v0.iter().map(|&v| circuit::bitline_trajectory(v, a, stride)).collect();
        samples = lanes[0].len();
        trajectories = lanes.into_iter().flatten().collect();
        readies = v0.iter().map(|&v| circuit::sense_latency(v, a, beta).0).collect();
    }

    println!("Fig. 3 — bitline voltage vs time ({source})");
    println!("\n  age(ms)  V_init(V)  t_ready(ns)");
    let mut csv = Vec::new();
    for (lane, &ms) in ages_ms.iter().enumerate() {
        println!("  {:>6.1}  {:>9.4}  {:>10.2}", ms, v0[lane], readies[lane]);
        csv.push(vec![ms.to_string(), v0[lane].to_string(), readies[lane].to_string()]);
    }
    write_csv("results/fig3_ready_times.csv", &["age_ms", "v_init", "t_ready_ns"], &csv)?;

    // Sec. 6.2 headline numbers.
    let (tr_full, tr_worst) = (readies[0], readies[ages_ms.len() - 1]);
    println!("\nSec. 6.2: t_ready full = {tr_full:.2} ns, worst = {tr_worst:.2} ns");
    println!("          tRCD reduction = {:.2} ns (paper: 4.5 ns)", tr_worst - tr_full);

    // Trajectory CSV for plotting.
    let mut traj_rows = Vec::new();
    for s in 0..samples {
        let mut row = vec![format!("{}", s as f64 * dt)];
        for lane in 0..ages_ms.len() {
            row.push(format!("{}", trajectories[lane * samples + s]));
        }
        traj_rows.push(row);
    }
    let mut headers: Vec<String> = vec!["t_ns".into()];
    headers.extend(ages_ms.iter().map(|ms| format!("age_{ms}ms")));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    write_csv(
        args.get_str("csv", "results/fig3_bitline.csv"),
        &headers_ref,
        &traj_rows,
    )?;
    println!("Trajectories: results/fig3_bitline.csv");
    Ok(())
}

fn cmd_fig4(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let eight = args.get_usize("cores", 1)? > 1;
    render_fig4(args, eng, eight)
}

fn render_fig4(args: &Args, eng: &mut JobEngine, eight: bool) -> Result<()> {
    let scale = scale_from(args)?;
    println!(
        "Fig. 4{} — speedup ({} insts/core)",
        if eight { "b" } else { "a" },
        scale.insts_per_core
    );
    let suite = run_suite_with(scale, eight, eng);
    let rows = if eight { suite.fig4b() } else { suite.fig4a() };

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.workload.clone(), f(r.rmpkc, 2)];
            for (_, s, _) in &r.speedups {
                row.push(f(*s, 3));
            }
            row.push(pct(r.speedups[0].2)); // CC reduced-act fraction
            row
        })
        .collect();
    print_table(
        &["workload", "RMPKC", "CC", "NUAT", "CC+NUAT", "LL-DRAM", "CC hit%"],
        &table,
    );

    // Averages (paper: CC 2.1%/8.6%, NUAT ~0.5%/2.5%, CC+NUAT 9.6%, LL 13.4%).
    let mechs = ["ChargeCache", "NUAT", "CC+NUAT", "LL-DRAM"];
    let mut avg_row = vec!["AVERAGE".to_string(), String::new()];
    for (i, _) in mechs.iter().enumerate() {
        let avg = rows.iter().map(|r| r.speedups[i].1).sum::<f64>() / rows.len() as f64;
        avg_row.push(f(avg, 3));
    }
    let hit = rows.iter().map(|r| r.speedups[0].2).sum::<f64>() / rows.len() as f64;
    avg_row.push(pct(hit));
    print_table(&["", "", "CC", "NUAT", "CC+NUAT", "LL-DRAM", "CC hit%"], &[avg_row]);

    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.workload.clone(), r.rmpkc.to_string()];
            row.extend(r.speedups.iter().map(|(_, s, _)| s.to_string()));
            row
        })
        .collect();
    write_csv(
        &format!("results/fig4{}_speedup.csv", if eight { "b" } else { "a" }),
        &["workload", "rmpkc", "cc", "nuat", "cc_nuat", "lldram"],
        &csv,
    )?;
    Ok(())
}

fn cmd_fig5(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let eight = args.get_usize("cores", 8)? > 1;
    render_fig5(args, eng, eight)
}

fn render_fig5(args: &Args, eng: &mut JobEngine, eight: bool) -> Result<()> {
    let scale = scale_from(args)?;
    println!("Fig. 5 — DRAM energy reduction ({}-core)", if eight { 8 } else { 1 });
    let suite = run_suite_with(scale, eight, eng);
    let data = suite.fig5(eight);

    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|(w, per_mech)| {
            let mut row = vec![w.clone()];
            row.extend(per_mech.iter().map(|(_, frac)| pct(*frac)));
            row
        })
        .collect();
    print_table(&["workload", "CC", "NUAT", "CC+NUAT", "LL-DRAM"], &rows);

    for (i, m) in ["CC", "NUAT", "CC+NUAT", "LL-DRAM"].iter().enumerate() {
        let vals: Vec<f64> = data.iter().map(|(_, pm)| pm[i].1).collect();
        let avg = vals.iter().sum::<f64>() / vals.len() as f64;
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        println!("{m}: avg {} max {}", pct(avg), pct(max));
    }
    println!("Paper (CC): avg 7.9% / max 14.1% (8-core); avg 1.8% / max 6.9% (1-core)");

    let csv: Vec<Vec<String>> = data
        .iter()
        .map(|(w, pm)| {
            let mut row = vec![w.clone()];
            row.extend(pm.iter().map(|(_, v)| v.to_string()));
            row
        })
        .collect();
    write_csv(
        &format!("results/fig5_energy_{}core.csv", if eight { 8 } else { 1 }),
        &["workload", "cc", "nuat", "cc_nuat", "lldram"],
        &csv,
    )?;
    Ok(())
}

/// `suite` — the full evaluation matrix rendered as Fig. 4 + Fig. 5
/// views over one memoized engine (the second render reuses every leg).
fn cmd_suite(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let eight = args.get_usize("cores", 8)? > 1;
    render_fig4(args, eng, eight)?;
    println!();
    render_fig5(args, eng, eight)
}

fn cmd_area(args: &Args) -> Result<()> {
    let cores = args.get_usize("cores", 8)?;
    let cfg = SystemConfig::multi_core(cores);
    // Access rate: every ACT+PRE across channels; use the paper-scale
    // figure unless told otherwise.
    let rate = args.get_f64("access-rate", 170e6)?;
    let cost = HcracCost::of(&cfg, rate);
    println!(
        "Sec. 6.5 — HCRAC overhead ({} cores, {} channels)",
        cfg.cpu.cores, cfg.dram.channels
    );
    println!("  storage : {} bytes ({} bits)", cost.storage_bytes, cost.storage_bits);
    println!(
        "  area    : {:.4} mm^2 ({} of 4MB LLC)",
        cost.area_mm2,
        pct(cost.area_fraction_of_llc())
    );
    println!(
        "  power   : {:.4} mW (static {:.4} + dynamic {:.4})",
        cost.total_mw(),
        cost.static_mw,
        cost.dynamic_mw
    );
    println!("Paper: 5376 bytes, 0.022 mm^2 (0.24% of LLC), 0.149 mW");
    Ok(())
}

/// Regenerate every simulation-driven figure plus one sensitivity sweep
/// over the shared memoized engine. Overlap is the point: fig1's
/// baselines are a subset of the suite's Baseline legs, fig5 re-reads
/// fig4's suite wholesale, and the capacity scenario's shared baselines
/// and 128-entry point collapse onto legs the suite already ran.
fn cmd_figures(args: &Args, eng: &mut JobEngine) -> Result<()> {
    cmd_fig1(args, eng)?;
    println!();
    render_fig4(args, eng, false)?;
    println!();
    render_fig4(args, eng, true)?;
    println!();
    render_fig5(args, eng, false)?;
    println!();
    render_fig5(args, eng, true)?;
    println!();
    run_builtin_scenario("capacity", args, eng)?;
    println!();
    run_builtin_scenario("tail-latency", args, eng)
}

/// `sweep` — a builtin scenario by name, or a one-axis scenario built
/// from `--param` + `--values`/`--from --to --steps`.
fn cmd_sweep(args: &Args, eng: &mut JobEngine) -> Result<()> {
    if let Some(name) = args.positionals.first() {
        ensure!(
            args.positionals.len() == 1,
            "sweep takes one builtin name, got {:?}",
            args.positionals
        );
        // A builtin is a complete spec; axis-building flags would be
        // silently ignored, so reject the combination outright.
        for flag in ["param", "values", "from", "to", "steps", "derive", "base", "mechanism"] {
            ensure!(
                args.get(flag).is_none(),
                "--{flag} conflicts with the builtin sweep {name:?} (drop the builtin name \
                 to build an ad-hoc sweep, or edit examples/scenarios/)"
            );
        }
        ensure!(
            !args.flag("log") && !args.flag("shared-baseline"),
            "--log/--shared-baseline conflict with the builtin sweep {name:?}"
        );
        return run_builtin_scenario(name, args, eng);
    }
    let param = args.get("param").context(
        "sweep needs a builtin name (capacity | duration | temperature) or --param PATH",
    )?;
    let values: Vec<String> = match args.get("values") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => {
            let from = args.get_f64("from", f64::NAN)?;
            let to = args.get_f64("to", f64::NAN)?;
            let steps = args.get_usize("steps", 0)?;
            ensure!(
                from.is_finite() && to.is_finite() && steps >= 1,
                "sweep --param needs --values V1,V2,... or --from X --to Y --steps N"
            );
            chargecache::coordinator::scenario::range_values(from, to, steps, args.flag("log"))?
        }
    };
    ensure!(!values.is_empty(), "sweep has no values");
    let derive = match args.get("derive") {
        None => None,
        Some(s) => Some(
            chargecache::coordinator::scenario::DeriveRule::parse(s).with_context(|| {
                format!(
                    "unknown derive rule {s:?} \
                     (cc-timing-from-duration | cc-timing-from-temperature | latency-vs-load)"
                )
            })?,
        ),
    };
    let base = match args.get("base") {
        None | Some("eight") => chargecache::coordinator::scenario::BasePreset::Eight,
        Some("single") => chargecache::coordinator::scenario::BasePreset::Single,
        Some(n) => {
            let n: usize =
                n.parse().with_context(|| format!("--base expects single|eight|N, got {n:?}"))?;
            chargecache::coordinator::scenario::BasePreset::Cores(n)
        }
    };
    let mechanism = args.mechanism(MechanismKind::ChargeCache)?;
    ensure!(
        mechanism != MechanismKind::Baseline,
        "Baseline is the implicit speedup denominator; pick a mechanism to measure"
    );
    let spec = ScenarioSpec {
        name: format!("sweep-{}", slug(param)),
        description: format!("ad-hoc sweep of {param}"),
        base,
        set: Vec::new(),
        mechanisms: vec![mechanism],
        workloads: if base.cores() == 1 {
            WorkloadSel::Singles((0..PROFILES.len()).collect())
        } else {
            WorkloadSel::Mixes(None)
        },
        baseline: if args.flag("shared-baseline") {
            chargecache::coordinator::scenario::BaselineMode::Shared
        } else {
            chargecache::coordinator::scenario::BaselineMode::PerPoint
        },
        axes: vec![chargecache::coordinator::scenario::AxisSpec {
            param: param.to_string(),
            values,
            derive,
        }],
        insts_per_core: None,
        warmup_cycles: None,
    };
    run_scenario_spec(spec, args, eng)
}

/// `scenario FILE...` — run (or `--validate`) spec files in order over
/// one shared engine, so legs shared between specs simulate once.
fn cmd_scenario(args: &Args, eng: &mut JobEngine) -> Result<()> {
    ensure!(
        !args.positionals.is_empty(),
        "scenario needs at least one spec FILE (see examples/scenarios/)"
    );
    for (i, file) in args.positionals.iter().enumerate() {
        if i > 0 {
            println!();
        }
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading scenario spec {file:?}"))?;
        let spec = ScenarioSpec::parse_named(&text, file)?;
        run_scenario_spec(spec, args, eng)?;
    }
    Ok(())
}

fn run_builtin_scenario(name: &str, args: &Args, eng: &mut JobEngine) -> Result<()> {
    let text = BUILTIN_SCENARIOS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, t)| *t)
        .with_context(|| {
            format!(
                "unknown builtin sweep {name:?} (capacity | duration | temperature | tail-latency)"
            )
        })?;
    run_scenario_spec(ScenarioSpec::parse(text).expect("builtin specs parse"), args, eng)
}

/// Shared scenario execution: CLI horizon flags beat spec pins, then
/// expand, optionally stop at `--validate`, run, render, CSV.
fn run_scenario_spec(mut spec: ScenarioSpec, args: &Args, eng: &mut JobEngine) -> Result<()> {
    // Explicit CLI flags — including --quick — override the spec's
    // horizon pins (scale_from bakes the flags into the scale the pins
    // would otherwise beat).
    if args.get("insts").is_some() || args.flag("quick") {
        spec.insts_per_core = None;
    }
    if args.get("warmup").is_some() || args.flag("quick") {
        spec.warmup_cycles = None;
    }
    if args.get("mixes").is_some() {
        if let WorkloadSel::Mixes(m) = &mut spec.workloads {
            *m = None;
        }
    }
    let scale = scale_from(args)?;
    let plan = spec.expand(&scale)?;
    if args.flag("validate") {
        println!(
            "{}: OK — {} point(s) x {} mechanism(s) x {} workload(s) = {} legs ({} baseline)",
            plan.name,
            plan.points.len(),
            plan.mechanisms.len(),
            plan.units.len(),
            plan.leg_count(),
            match plan.baseline {
                chargecache::coordinator::scenario::BaselineMode::Shared => "shared",
                chargecache::coordinator::scenario::BaselineMode::PerPoint => "per-point",
            }
        );
        return Ok(());
    }
    let run = plan.run_with(eng);
    render_scenario(&plan, &run)
}

fn render_scenario(plan: &ScenarioPlan, run: &ScenarioRun) -> Result<()> {
    println!(
        "Scenario {} — {}",
        plan.name,
        if plan.description.is_empty() { "(no description)" } else { &plan.description }
    );
    println!(
        "{} point(s) x {} mechanism(s), {} workload unit(s), {} legs submitted",
        run.points,
        plan.mechanisms.len(),
        plan.units.len(),
        run.legs_submitted
    );
    if run.failed_legs > 0 {
        println!(
            "WARNING: {} leg(s) failed after retries — affected rows cover surviving units only",
            run.failed_legs
        );
    }
    let show_lat = run.rows.iter().any(|r| r.latency.is_some());
    let tail = plan.load_axis.is_some();
    // Log-scale p99 range for the tail-latency bar column.
    let (lo, hi) = run
        .rows
        .iter()
        .filter_map(|r| r.latency.map(|l| l.p99 as f64))
        .fold((f64::INFINITY, 0.0f64), |(lo, hi), v| (lo.min(v), hi.max(v)));
    let mut headers: Vec<&str> = plan.axes.iter().map(|a| a.as_str()).collect();
    headers.push("mechanism");
    headers.push("speedup");
    if show_lat {
        headers.push("p50");
        headers.push("p99");
        headers.push("p99.9");
    }
    headers.push("");
    let rows: Vec<Vec<String>> = run
        .rows
        .iter()
        .map(|r| {
            let mut row: Vec<String> = r.coords.iter().map(|(_, v)| v.clone()).collect();
            row.push(r.mechanism.label().to_string());
            row.push(f(r.speedup, 4));
            if show_lat {
                match r.latency {
                    Some(l) => {
                        row.push(l.p50.to_string());
                        row.push(l.p99.to_string());
                        row.push(l.p999.to_string());
                    }
                    None => row.extend((0..3).map(|_| "-".to_string())),
                }
            }
            // Tail studies chart p99 on a log scale (the saturation knee
            // shows as the bar running away); plain sweeps keep the
            // speedup bar.
            row.push(match (tail, r.latency) {
                (true, Some(l)) => log_bar(l.p99 as f64, lo / 2.0, hi, 30),
                (true, None) => String::new(),
                (false, _) => bar(r.speedup - 1.0, 0.15, 30),
            });
            row
        })
        .collect();
    print_table(&headers, &rows);
    if let Some(load_param) = &plan.load_axis {
        println!();
        for (label, knee) in run.knees(load_param) {
            match knee {
                Some(k) => println!(
                    "{label}: saturation knee at ~{k:.3e} req/s \
                     (p99 crosses 2x its low-load value)"
                ),
                None => println!("{label}: no knee in the swept range (p99 never doubled)"),
            }
        }
    }

    let path = format!("results/scenario_{}.csv", slug(&plan.name));
    let mut csv_headers: Vec<&str> = plan.axes.iter().map(|a| a.as_str()).collect();
    csv_headers.push("mechanism");
    csv_headers.push("speedup");
    if show_lat {
        csv_headers.extend(["p50", "p95", "p99", "p999", "mean", "samples", "base_p99"]);
    }
    let csv_rows: Vec<Vec<String>> = run
        .rows
        .iter()
        .map(|r| {
            let mut row: Vec<String> = r.coords.iter().map(|(_, v)| v.clone()).collect();
            row.push(r.mechanism.name().to_string());
            row.push(r.speedup.to_string());
            if show_lat {
                match r.latency {
                    Some(l) => row.extend([
                        l.p50.to_string(),
                        l.p95.to_string(),
                        l.p99.to_string(),
                        l.p999.to_string(),
                        l.mean.to_string(),
                        l.samples.to_string(),
                    ]),
                    None => row.extend((0..6).map(|_| String::new())),
                }
                row.push(r.base_latency.map_or(String::new(), |l| l.p99.to_string()));
            }
            row
        })
        .collect();
    write_csv(&path, &csv_headers, &csv_rows)?;
    println!("CSV: {path}");
    Ok(())
}

fn cmd_run(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let cores = args.get_usize("cores", 1)?;
    let quick = args.flag("quick");
    let mut cfg = SystemConfig::multi_core(cores);
    cfg.insts_per_core = args.get_u64("insts", if quick { 60_000 } else { 500_000 })?;
    cfg.warmup_cpu_cycles = args.get_u64("warmup", if quick { 30_000 } else { 250_000 })?;
    cfg.chargecache.duration_ms = args.get_f64("duration", 1.0)?;
    cfg.chargecache.entries_per_core = args.get_usize("entries", 128)?;
    cfg.mc.scheduler = args.scheduler(cfg.mc.scheduler)?;
    if args.flag("strict-tick") {
        cfg.loop_mode = LoopMode::StrictTick;
    }
    cfg.mechanism = args.mechanism(MechanismKind::ChargeCache)?;
    // `--set` wins over every convenience flag above (including
    // `--mechanism`, via the `mechanism` path).
    schema::registry().apply(&mut cfg, &args.set_overrides()?)?;
    let kind = cfg.mechanism;
    // Normalize before submission: JobKey carries the mechanism, and
    // suite/scenario legs leave cfg.mechanism at its Baseline default —
    // keeping `kind` in the config would fork the fingerprint and
    // defeat cache sharing with those legs.
    cfg.mechanism = MechanismKind::Baseline;

    // Route through the shared engine wherever the run is expressible as
    // a graph workload unit, so `--result-cache` serves repeated ad-hoc
    // runs from disk.
    let name = args.get_str("workload", "mcf");
    let result = if let Some(mix) = args.get("mix") {
        let mix: usize = mix.parse()?;
        let mut graph = JobGraph::new();
        let t = graph.submit(JobSpec::mix(cfg.clone(), kind, mix));
        eng.run(graph).get(t).clone()
    } else {
        let p = Profile::by_name(name)
            .with_context(|| format!("unknown workload {name:?}"))?;
        if cfg.cpu.cores == 1 {
            let w = PROFILES.iter().position(|q| q.name == p.name).expect("by_name found it");
            let mut graph = JobGraph::new();
            let t = graph.submit(JobSpec::single(cfg.clone(), kind, w));
            eng.run(graph).get(t).clone()
        } else {
            // One replica per core, from the post-override core count (so
            // `--set cpu.cores=4` works without also passing `--cores`).
            // Same-profile replicas aren't a graph workload unit, so this
            // shape runs directly (no memoization).
            let profiles: Vec<&Profile> = (0..cfg.cpu.cores).map(|_| p).collect();
            System::new(&cfg, kind, &profiles).run()
        }
    };

    println!("workload  : {}", result.workload);
    println!("mechanism : {}", result.mechanism);
    println!("scheduler : {}", cfg.mc.scheduler.label());
    println!("loop mode : {:?}", cfg.loop_mode);
    println!("cycles    : {}", result.cpu_cycles);
    for (i, ipc) in result.core_ipc.iter().enumerate() {
        println!("core {i} IPC: {ipc:.4}");
    }
    println!("RMPKC     : {:.3}", result.rmpkc());
    println!("acts      : {} (reduced: {})", result.acts(), pct(result.reduced_act_fraction()));
    println!(
        "row hit/miss/conf: {}/{}/{}",
        result.mc.iter().map(|m| m.row_hits).sum::<u64>(),
        result.mc.iter().map(|m| m.row_misses).sum::<u64>(),
        result.mc.iter().map(|m| m.row_conflicts).sum::<u64>()
    );
    println!("avg read latency : {:.1} bus cycles", result.avg_read_latency());
    if cfg.fault.enabled {
        println!(
            "faults    : {} violations ({} evicted), {} guard-suppressed, {} rows blacklisted",
            result.timing_violations(),
            result.mitigation_evictions(),
            result.guard_suppressed(),
            result.rows_blacklisted()
        );
    }
    println!("1ms-RLTL  : {}", pct(result.rltl_at_ms(1.0)));
    println!(
        "DRAM energy: {:.1} uJ (bg {:.1}, act {:.1}, rd {:.1}, wr {:.1}, ref {:.1})",
        result.energy.total_nj() / 1000.0,
        result.energy.background_nj / 1000.0,
        result.energy.act_pre_nj / 1000.0,
        result.energy.read_nj / 1000.0,
        result.energy.write_nj / 1000.0,
        result.energy.refresh_nj / 1000.0
    );
    Ok(())
}

fn cmd_gen_traces(args: &Args) -> Result<()> {
    let out = args.get_str("out", "traces");
    let n = args.get_u64("insts", 1_000_000)?;
    std::fs::create_dir_all(out)?;
    for p in PROFILES.iter() {
        let path = format!("{out}/{}.trace", p.name);
        let mut src = SynthTrace::new(p, 42, 0);
        write_trace(&path, &mut src, n / p.inst_per_mem as u64)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_timing_table(args: &Args) -> Result<()> {
    let temp = args.get_f64("temp", 85.0)?;
    let (table, from_artifacts) = timing_table_or_analytic(temp, 1.25);
    println!(
        "Charge -> timing table at {temp} C ({})",
        if from_artifacts { "AOT artifacts via PJRT" } else { "analytic fallback" }
    );
    let rows: Vec<Vec<String>> = table
        .ages()
        .iter()
        .step_by(8)
        .map(|&age| {
            let (rcd_ns, ras_ns) = table.reduction_ns(age);
            let (rcd, ras) = table.reduction_cycles(age);
            vec![
                format!("{:.3} ms", age * 1e3),
                format!("{rcd_ns:.2} ns"),
                format!("{ras_ns:.2} ns"),
                format!("-{rcd} cyc"),
                format!("-{ras} cyc"),
            ]
        })
        .collect();
    print_table(&["row age", "tRCD red", "tRAS red", "tRCD", "tRAS"], &rows);
    let (rcd, ras) = table.reduction_cycles(1e-3);
    println!("\nAt the paper's 1 ms duration: -{rcd} tRCD / -{ras} tRAS cycles (paper: -4/-8)");
    Ok(())
}
