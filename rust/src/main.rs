//! ChargeCache CLI — regenerates every figure/table of the paper and runs
//! ad-hoc simulations.
//!
//! ```text
//! chargecache fig1   [--insts N] [--mixes M] [--quick]      Fig. 1  (RLTL)
//! chargecache fig3   [--csv path]                           Fig. 3  (bitline)
//! chargecache fig4   --cores 1|8 [--insts N] [--quick]      Fig. 4  (speedup)
//! chargecache fig5   --cores 1|8 [--insts N] [--quick]      Fig. 5  (energy)
//! chargecache figures [--quick] [--result-cache DIR]        all of the above
//! chargecache area                                          Sec. 6.5 overhead
//! chargecache sweep-capacity | sweep-duration | sweep-temperature
//! chargecache simulate --workload mcf --mechanism cc [--cores N]
//! chargecache gen-traces --out dir [--insts N]              trace files
//! chargecache timing-table [--temp C]                       codesign bridge
//! ```
//!
//! Every simulation runs on the event-driven kernel; pass `--strict-tick`
//! to any simulating command to use the original per-cycle loop (the
//! differential-testing oracle — results are bit-identical, only slower).
//! `--threads N` (or the `PALLAS_THREADS` env var) pins the parallel
//! runner's worker count for reproducible suite benchmarking.
//!
//! Every suite command executes through the fingerprint-keyed job graph
//! (`coordinator::jobs`, DESIGN.md §5): structurally identical legs are
//! deduplicated and memoized, so `figures` simulates each unique
//! (config, mechanism, workload) exactly once across all its figures.
//! `--result-cache DIR` persists results across invocations; `--no-memo`
//! restores the naive one-simulation-per-leg behavior.

use chargecache::config::SystemConfig;
use chargecache::coordinator::cli::Args;
use chargecache::coordinator::experiments::{
    fig1_with, run_suite_with, sweep_capacity_with, sweep_duration_with, sweep_temperature_with,
    ExperimentScale,
};
use chargecache::coordinator::figures::{bar, f, pct, print_table, write_csv};
use chargecache::coordinator::jobs::JobEngine;
use chargecache::energy::HcracCost;
use chargecache::error::{Context, Result};
use chargecache::latency::MechanismKind;
use chargecache::runtime::charge_model::timing_table_or_analytic;
use chargecache::sim::engine::LoopMode;
use chargecache::sim::System;
use chargecache::trace::{file::write_trace, Profile, SynthTrace, PROFILES};

fn scale_from(args: &Args) -> Result<ExperimentScale> {
    let mut s = if args.flag("quick") {
        ExperimentScale::quick()
    } else {
        ExperimentScale::default()
    };
    s.insts_per_core = args.get_u64("insts", s.insts_per_core)?;
    s.warmup_cycles = args.get_u64("warmup", s.warmup_cycles)?;
    s.mixes = args.get_usize("mixes", s.mixes)?;
    s.scheduler = args.scheduler(s.scheduler)?;
    if args.flag("strict-tick") {
        s.loop_mode = LoopMode::StrictTick;
    }
    Ok(s)
}

/// Build the shared job engine from the memoization flags: every suite
/// command executes through a fingerprint-keyed job graph that dedupes
/// identical (config, mechanism, workload) legs.
fn engine_from(args: &Args) -> Result<JobEngine> {
    let mut eng = match args.get("result-cache") {
        Some(dir) => JobEngine::with_disk(dir)?,
        None => JobEngine::new(),
    };
    if args.flag("no-memo") {
        eng.memo = false;
    }
    Ok(eng)
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    // Worker-count pin for every parallel_map fan-out (reproducible
    // benchmarking); 0 keeps the PALLAS_THREADS / machine fallback.
    chargecache::coordinator::runner::set_threads(args.get_usize("threads", 0)?);
    // One engine per invocation: commands that run several experiments
    // (`figures`) share its cache, so overlapping legs simulate once.
    let mut eng = engine_from(&args)?;
    match args.command.as_str() {
        "fig1" => cmd_fig1(&args, &mut eng),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args, &mut eng),
        "fig5" => cmd_fig5(&args, &mut eng),
        "figures" => cmd_figures(&args, &mut eng),
        "area" => cmd_area(&args),
        "sweep-capacity" => cmd_sweep_capacity(&args, &mut eng),
        "sweep-duration" => cmd_sweep_duration(&args, &mut eng),
        "sweep-temperature" => cmd_sweep_temperature(&args, &mut eng),
        "simulate" => cmd_simulate(&args),
        "gen-traces" => cmd_gen_traces(&args),
        "timing-table" => cmd_timing_table(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }?;
    // Dedup/hit telemetry for every command that ran the job graph.
    if eng.stats().submitted > 0 {
        println!("\n{}", eng.stats().summary());
    }
    Ok(())
}

const HELP: &str = "chargecache — ChargeCache (HPCA'16) reproduction
commands: fig1 fig3 fig4 fig5 figures area sweep-capacity sweep-duration
          sweep-temperature simulate gen-traces timing-table

  figures regenerates fig1 + fig4a/b + fig5 (1- and 8-core) + the
  capacity sweep over ONE memoized job graph: legs shared between
  figures (fig1's baselines, fig5's suite, the sweep's default point)
  simulate exactly once; the run ends with dedup/hit counters.

common options: --insts N --warmup N --mixes M --quick --strict-tick
                --scheduler fr-fcfs|fcfs|bliss
                --threads N (or PALLAS_THREADS=N) pins the worker count
memoization:    --result-cache DIR persists simulation results on disk,
                keyed by config fingerprint — a re-run (same config)
                loads instead of simulating
                --no-memo disables dedup + caching (every submitted leg
                simulates; the pre-job-graph behavior)";

fn cmd_fig1(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let scale = scale_from(args)?;
    println!("Fig. 1 — average t-RLTL ({} workloads, {} mixes)", PROFILES.len(), scale.mixes);
    let rows_data = fig1_with(scale, eng);
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|(ms, s, e)| {
            vec![
                format!("{ms} ms"),
                pct(*s),
                bar(*s, 1.0, 24),
                pct(*e),
                bar(*e, 1.0, 24),
            ]
        })
        .collect();
    print_table(&["t", "1-core", "", "8-core", ""], &rows);
    let csv_rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|(ms, s, e)| vec![ms.to_string(), s.to_string(), e.to_string()])
        .collect();
    write_csv("results/fig1_rltl.csv", &["t_ms", "single", "eight"], &csv_rows)?;
    println!("\nPaper: 1 ms-RLTL = 83% (1-core), 89% (8-core). CSV: results/fig1_rltl.csv");
    Ok(())
}

/// Fig. 3 — bitline trajectories and ready times.
///
/// With the `pjrt` feature the trajectories come from the AOT HLO
/// artifacts executed via PJRT; otherwise from the pure-Rust analytic
/// circuit model (the two are pinned against each other in tests).
fn cmd_fig3(args: &Args) -> Result<()> {
    let ages_ms = [0.0, 1.0, 4.0, 8.0, 16.0, 32.0, 48.0, 64.0];
    // Each branch produces: source label, samples per lane, sample period
    // (ns), initial voltages, row-major trajectories, per-lane ready times.
    let source: String;
    let samples: usize;
    let dt: f64;
    let v0: Vec<f64>;
    let trajectories: Vec<f64>;
    let readies: Vec<f64>;

    #[cfg(feature = "pjrt")]
    {
        use chargecache::runtime::{ChargeModelRuntime, Runtime};
        let rt = Runtime::new(Runtime::default_dir())?;
        if !rt.artifacts_present() {
            chargecache::bail!("artifacts not built — run `make artifacts` first");
        }
        let cm = ChargeModelRuntime::load(&rt)?;
        source = format!("PJRT: {}", rt.platform());
        let tau_ms = cm.meta.get("tau_leak_ms")?;
        let vdd = cm.meta.get("vdd")?;
        v0 = ages_ms
            .iter()
            .map(|&ms| vdd / 2.0 + (vdd / 2.0) * (-(ms) / tau_ms).exp())
            .collect();
        let v0_f32: Vec<f32> = v0.iter().map(|&v| v as f32).collect();
        let (s, data) = cm.bitline_sweep(&v0_f32)?;
        samples = s;
        dt = cm.meta.get("dt_ns")? * cm.meta.get("traj_stride")?;
        trajectories = data.iter().map(|&v| v as f64).collect();
        let v_ready = cm.meta.get("v_ready")?;
        readies = (0..ages_ms.len())
            .map(|lane| {
                let row = &trajectories[lane * samples..(lane + 1) * samples];
                row.iter().position(|&v| v >= v_ready).unwrap_or(samples) as f64 * dt
            })
            .collect();
    }

    #[cfg(not(feature = "pjrt"))]
    {
        use chargecache::latency::timing_table::circuit;
        source = "analytic circuit model (build with --features pjrt for HLO)".to_string();
        let (a, tau_ms) = circuit::calibrate();
        let beta = circuit::calibrate_restore(a, tau_ms);
        v0 = ages_ms
            .iter()
            .map(|&ms| circuit::v_cell_after(ms * 1e-3, circuit::T_CAL_CELSIUS, tau_ms))
            .collect();
        let stride = 10usize;
        dt = circuit::DT_NS * stride as f64;
        let lanes: Vec<Vec<f64>> =
            v0.iter().map(|&v| circuit::bitline_trajectory(v, a, stride)).collect();
        samples = lanes[0].len();
        trajectories = lanes.into_iter().flatten().collect();
        readies = v0.iter().map(|&v| circuit::sense_latency(v, a, beta).0).collect();
    }

    println!("Fig. 3 — bitline voltage vs time ({source})");
    println!("\n  age(ms)  V_init(V)  t_ready(ns)");
    let mut csv = Vec::new();
    for (lane, &ms) in ages_ms.iter().enumerate() {
        println!("  {:>6.1}  {:>9.4}  {:>10.2}", ms, v0[lane], readies[lane]);
        csv.push(vec![ms.to_string(), v0[lane].to_string(), readies[lane].to_string()]);
    }
    write_csv("results/fig3_ready_times.csv", &["age_ms", "v_init", "t_ready_ns"], &csv)?;

    // Sec. 6.2 headline numbers.
    let (tr_full, tr_worst) = (readies[0], readies[ages_ms.len() - 1]);
    println!("\nSec. 6.2: t_ready full = {tr_full:.2} ns, worst = {tr_worst:.2} ns");
    println!("          tRCD reduction = {:.2} ns (paper: 4.5 ns)", tr_worst - tr_full);

    // Trajectory CSV for plotting.
    let mut traj_rows = Vec::new();
    for s in 0..samples {
        let mut row = vec![format!("{}", s as f64 * dt)];
        for lane in 0..ages_ms.len() {
            row.push(format!("{}", trajectories[lane * samples + s]));
        }
        traj_rows.push(row);
    }
    let mut headers: Vec<String> = vec!["t_ns".into()];
    headers.extend(ages_ms.iter().map(|ms| format!("age_{ms}ms")));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    write_csv(
        args.get_str("csv", "results/fig3_bitline.csv"),
        &headers_ref,
        &traj_rows,
    )?;
    println!("Trajectories: results/fig3_bitline.csv");
    Ok(())
}

fn cmd_fig4(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let eight = args.get_usize("cores", 1)? > 1;
    render_fig4(args, eng, eight)
}

fn render_fig4(args: &Args, eng: &mut JobEngine, eight: bool) -> Result<()> {
    let scale = scale_from(args)?;
    println!(
        "Fig. 4{} — speedup ({} insts/core)",
        if eight { "b" } else { "a" },
        scale.insts_per_core
    );
    let suite = run_suite_with(scale, eight, eng);
    let rows = if eight { suite.fig4b() } else { suite.fig4a() };

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.workload.clone(), f(r.rmpkc, 2)];
            for (_, s, _) in &r.speedups {
                row.push(f(*s, 3));
            }
            row.push(pct(r.speedups[0].2)); // CC reduced-act fraction
            row
        })
        .collect();
    print_table(
        &["workload", "RMPKC", "CC", "NUAT", "CC+NUAT", "LL-DRAM", "CC hit%"],
        &table,
    );

    // Averages (paper: CC 2.1%/8.6%, NUAT ~0.5%/2.5%, CC+NUAT 9.6%, LL 13.4%).
    let mechs = ["ChargeCache", "NUAT", "CC+NUAT", "LL-DRAM"];
    let mut avg_row = vec!["AVERAGE".to_string(), String::new()];
    for (i, _) in mechs.iter().enumerate() {
        let avg = rows.iter().map(|r| r.speedups[i].1).sum::<f64>() / rows.len() as f64;
        avg_row.push(f(avg, 3));
    }
    let hit = rows.iter().map(|r| r.speedups[0].2).sum::<f64>() / rows.len() as f64;
    avg_row.push(pct(hit));
    print_table(&["", "", "CC", "NUAT", "CC+NUAT", "LL-DRAM", "CC hit%"], &[avg_row]);

    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row = vec![r.workload.clone(), r.rmpkc.to_string()];
            row.extend(r.speedups.iter().map(|(_, s, _)| s.to_string()));
            row
        })
        .collect();
    write_csv(
        &format!("results/fig4{}_speedup.csv", if eight { "b" } else { "a" }),
        &["workload", "rmpkc", "cc", "nuat", "cc_nuat", "lldram"],
        &csv,
    )?;
    Ok(())
}

fn cmd_fig5(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let eight = args.get_usize("cores", 8)? > 1;
    render_fig5(args, eng, eight)
}

fn render_fig5(args: &Args, eng: &mut JobEngine, eight: bool) -> Result<()> {
    let scale = scale_from(args)?;
    println!("Fig. 5 — DRAM energy reduction ({}-core)", if eight { 8 } else { 1 });
    let suite = run_suite_with(scale, eight, eng);
    let data = suite.fig5(eight);

    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|(w, per_mech)| {
            let mut row = vec![w.clone()];
            row.extend(per_mech.iter().map(|(_, frac)| pct(*frac)));
            row
        })
        .collect();
    print_table(&["workload", "CC", "NUAT", "CC+NUAT", "LL-DRAM"], &rows);

    for (i, m) in ["CC", "NUAT", "CC+NUAT", "LL-DRAM"].iter().enumerate() {
        let vals: Vec<f64> = data.iter().map(|(_, pm)| pm[i].1).collect();
        let avg = vals.iter().sum::<f64>() / vals.len() as f64;
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        println!("{m}: avg {} max {}", pct(avg), pct(max));
    }
    println!("Paper (CC): avg 7.9% / max 14.1% (8-core); avg 1.8% / max 6.9% (1-core)");

    let csv: Vec<Vec<String>> = data
        .iter()
        .map(|(w, pm)| {
            let mut row = vec![w.clone()];
            row.extend(pm.iter().map(|(_, v)| v.to_string()));
            row
        })
        .collect();
    write_csv(
        &format!("results/fig5_energy_{}core.csv", if eight { 8 } else { 1 }),
        &["workload", "cc", "nuat", "cc_nuat", "lldram"],
        &csv,
    )?;
    Ok(())
}

fn cmd_area(args: &Args) -> Result<()> {
    let cores = args.get_usize("cores", 8)?;
    let cfg = SystemConfig::multi_core(cores);
    // Access rate: every ACT+PRE across channels; use the paper-scale
    // figure unless told otherwise.
    let rate = args.get_f64("access-rate", 170e6)?;
    let cost = HcracCost::of(&cfg, rate);
    println!(
        "Sec. 6.5 — HCRAC overhead ({} cores, {} channels)",
        cfg.cpu.cores, cfg.dram.channels
    );
    println!("  storage : {} bytes ({} bits)", cost.storage_bytes, cost.storage_bits);
    println!(
        "  area    : {:.4} mm^2 ({} of 4MB LLC)",
        cost.area_mm2,
        pct(cost.area_fraction_of_llc())
    );
    println!(
        "  power   : {:.4} mW (static {:.4} + dynamic {:.4})",
        cost.total_mw(),
        cost.static_mw,
        cost.dynamic_mw
    );
    println!("Paper: 5376 bytes, 0.022 mm^2 (0.24% of LLC), 0.149 mW");
    Ok(())
}

/// Regenerate every simulation-driven figure plus one sensitivity sweep
/// over the shared memoized engine. Overlap is the point: fig1's
/// baselines are a subset of the suite's Baseline legs, fig5 re-reads
/// fig4's suite wholesale, and the capacity sweep's 128-entry point *is*
/// the default configuration — each simulates exactly once.
fn cmd_figures(args: &Args, eng: &mut JobEngine) -> Result<()> {
    cmd_fig1(args, eng)?;
    println!();
    render_fig4(args, eng, false)?;
    println!();
    render_fig4(args, eng, true)?;
    println!();
    render_fig5(args, eng, false)?;
    println!();
    render_fig5(args, eng, true)?;
    println!();
    cmd_sweep_capacity(args, eng)
}

fn cmd_sweep_capacity(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let scale = scale_from(args)?;
    let entries = [32usize, 64, 128, 256, 512, 1024];
    println!("Sensitivity — HCRAC capacity (8-core, CC speedup vs baseline)");
    let rows = sweep_capacity_with(scale, &entries, eng);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(e, s)| vec![e.to_string(), f(*s, 4), bar(s - 1.0, 0.15, 30)])
        .collect();
    print_table(&["entries/core", "speedup", ""], &table);
    write_csv(
        "results/sweep_capacity.csv",
        &["entries", "speedup"],
        &rows.iter().map(|(e, s)| vec![e.to_string(), s.to_string()]).collect::<Vec<_>>(),
    )?;
    Ok(())
}

fn cmd_sweep_duration(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let scale = scale_from(args)?;
    let durations = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    println!("Sensitivity — caching duration (reductions from the circuit layer)");
    let rows = sweep_duration_with(scale, &durations, eng);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(d, s)| vec![format!("{d} ms"), f(*s, 4), bar(s - 1.0, 0.15, 30)])
        .collect();
    print_table(&["duration", "speedup", ""], &table);
    write_csv(
        "results/sweep_duration.csv",
        &["duration_ms", "speedup"],
        &rows.iter().map(|(d, s)| vec![d.to_string(), s.to_string()]).collect::<Vec<_>>(),
    )?;
    Ok(())
}

fn cmd_sweep_temperature(args: &Args, eng: &mut JobEngine) -> Result<()> {
    let scale = scale_from(args)?;
    let temps = [45.0, 55.0, 65.0, 75.0, 85.0];
    println!("Sensitivity — temperature (paper Sec. 8.3: CC works at worst case)");
    let rows = sweep_temperature_with(scale, &temps, eng);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(t, s)| vec![format!("{t} C"), f(*s, 4), bar(s - 1.0, 0.15, 30)])
        .collect();
    print_table(&["temp", "speedup", ""], &table);
    write_csv(
        "results/sweep_temperature.csv",
        &["temp_c", "speedup"],
        &rows.iter().map(|(t, s)| vec![t.to_string(), s.to_string()]).collect::<Vec<_>>(),
    )?;
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cores = args.get_usize("cores", 1)?;
    let mut cfg = SystemConfig::multi_core(cores);
    cfg.insts_per_core = args.get_u64("insts", 500_000)?;
    cfg.warmup_cpu_cycles = args.get_u64("warmup", 250_000)?;
    cfg.chargecache.duration_ms = args.get_f64("duration", 1.0)?;
    cfg.chargecache.entries_per_core = args.get_usize("entries", 128)?;
    cfg.mc.scheduler = args.scheduler(cfg.mc.scheduler)?;
    if args.flag("strict-tick") {
        cfg.loop_mode = LoopMode::StrictTick;
    }
    let kind = args.mechanism(MechanismKind::ChargeCache)?;

    let name = args.get_str("workload", "mcf");
    let result = if let Some(mix) = args.get("mix") {
        let mix: usize = mix.parse()?;
        System::new_mix(&cfg, kind, mix).run()
    } else {
        let p = Profile::by_name(name)
            .with_context(|| format!("unknown workload {name:?}"))?;
        let profiles: Vec<&Profile> = (0..cores).map(|_| p).collect();
        System::new(&cfg, kind, &profiles).run()
    };

    println!("workload  : {}", result.workload);
    println!("mechanism : {}", result.mechanism);
    println!("scheduler : {}", cfg.mc.scheduler.label());
    println!("loop mode : {:?}", cfg.loop_mode);
    println!("cycles    : {}", result.cpu_cycles);
    for (i, ipc) in result.core_ipc.iter().enumerate() {
        println!("core {i} IPC: {ipc:.4}");
    }
    println!("RMPKC     : {:.3}", result.rmpkc());
    println!("acts      : {} (reduced: {})", result.acts(), pct(result.reduced_act_fraction()));
    println!(
        "row hit/miss/conf: {}/{}/{}",
        result.mc.iter().map(|m| m.row_hits).sum::<u64>(),
        result.mc.iter().map(|m| m.row_misses).sum::<u64>(),
        result.mc.iter().map(|m| m.row_conflicts).sum::<u64>()
    );
    println!("avg read latency : {:.1} bus cycles", result.avg_read_latency());
    println!("1ms-RLTL  : {}", pct(result.rltl_at_ms(1.0)));
    println!(
        "DRAM energy: {:.1} uJ (bg {:.1}, act {:.1}, rd {:.1}, wr {:.1}, ref {:.1})",
        result.energy.total_nj() / 1000.0,
        result.energy.background_nj / 1000.0,
        result.energy.act_pre_nj / 1000.0,
        result.energy.read_nj / 1000.0,
        result.energy.write_nj / 1000.0,
        result.energy.refresh_nj / 1000.0
    );
    Ok(())
}

fn cmd_gen_traces(args: &Args) -> Result<()> {
    let out = args.get_str("out", "traces");
    let n = args.get_u64("insts", 1_000_000)?;
    std::fs::create_dir_all(out)?;
    for p in PROFILES.iter() {
        let path = format!("{out}/{}.trace", p.name);
        let mut src = SynthTrace::new(p, 42, 0);
        write_trace(&path, &mut src, n / p.inst_per_mem as u64)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_timing_table(args: &Args) -> Result<()> {
    let temp = args.get_f64("temp", 85.0)?;
    let (table, from_artifacts) = timing_table_or_analytic(temp, 1.25);
    println!(
        "Charge -> timing table at {temp} C ({})",
        if from_artifacts { "AOT artifacts via PJRT" } else { "analytic fallback" }
    );
    let rows: Vec<Vec<String>> = table
        .ages()
        .iter()
        .step_by(8)
        .map(|&age| {
            let (rcd_ns, ras_ns) = table.reduction_ns(age);
            let (rcd, ras) = table.reduction_cycles(age);
            vec![
                format!("{:.3} ms", age * 1e3),
                format!("{rcd_ns:.2} ns"),
                format!("{ras_ns:.2} ns"),
                format!("-{rcd} cyc"),
                format!("-{ras} cyc"),
            ]
        })
        .collect();
    print_table(&["row age", "tRCD red", "tRAS red", "tRCD", "tRAS"], &rows);
    let (rcd, ras) = table.reduction_cycles(1e-3);
    println!("\nAt the paper's 1 ms duration: -{rcd} tRCD / -{ras} tRAS cycles (paper: -4/-8)");
    Ok(())
}
