//! Rendering helpers: aligned ASCII tables, CSV emission, and simple bar
//! charts for terminal output.

use std::io::Write;
use std::path::Path;

use crate::error::{Context, Result};

/// Print an aligned table.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    for row in rows {
        println!("{}", line(row));
    }
}

/// Write rows as CSV (headers first).
pub fn write_csv<P: AsRef<Path>>(path: P, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// A one-line ASCII bar for terminal charts: `value` scaled into `width`
/// characters relative to `max`.
pub fn bar(value: f64, max: f64, width: usize) -> String {
    if max <= 0.0 {
        return String::new();
    }
    let n = ((value / max) * width as f64).round().max(0.0) as usize;
    "#".repeat(n.min(width))
}

/// A log-scale ASCII bar for quantities spanning orders of magnitude
/// (tail-latency curves): length proportional to `log(value/lo)` over
/// `log(hi/lo)`, so a saturation knee shows as the bar running away.
/// Empty when `value <= lo` or the range is degenerate.
pub fn log_bar(value: f64, lo: f64, hi: f64, width: usize) -> String {
    if lo <= 0.0 || hi <= lo || value <= lo {
        return String::new();
    }
    let t = ((value / lo).ln() / (hi / lo).ln()).min(1.0);
    "#".repeat(((t * width as f64).round() as usize).clamp(1, width))
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Filename-safe slug for result CSVs derived from user-provided names
/// (scenario names reach file paths through this).
pub fn slug(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect()
}

/// Percent formatting.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_scales() {
        assert_eq!(bar(5.0, 10.0, 10), "#####");
        assert_eq!(bar(10.0, 10.0, 10), "##########");
        assert_eq!(bar(20.0, 10.0, 10), "##########"); // clamped
        assert_eq!(bar(1.0, 0.0, 10), "");
    }

    #[test]
    fn log_bar_is_logarithmic() {
        // One decade out of two -> half the bar.
        assert_eq!(log_bar(100.0, 10.0, 1000.0, 10), "#####");
        assert_eq!(log_bar(1000.0, 10.0, 1000.0, 10), "##########");
        assert_eq!(log_bar(5000.0, 10.0, 1000.0, 10), "##########"); // clamped
        assert_eq!(log_bar(10.0, 10.0, 1000.0, 10), ""); // at the floor
        assert_eq!(log_bar(100.0, 0.0, 1000.0, 10), ""); // degenerate
    }

    #[test]
    fn slug_is_filename_safe() {
        assert_eq!(slug("sweep-capacity"), "sweep_capacity");
        assert_eq!(slug("Grid: sched/temp"), "grid__sched_temp");
        assert_eq!(slug("plain"), "plain");
    }

    #[test]
    fn formatting() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.086), "8.6%");
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("cc_fig_test");
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }
}
