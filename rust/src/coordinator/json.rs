//! Minimal zero-dependency JSON infrastructure, shared by the on-disk
//! result cache (`jobs::diskjson`) and the scenario-spec loader
//! ([`super::scenario`]). The offline build has no serde; this parser is
//! deliberately small and fully under our control.
//!
//! Numbers are kept as **raw source tokens** ([`Val::Num`]) rather than
//! eagerly converted: the result cache stores `f64` bit patterns as
//! full-precision `u64`s that must not round through `f64`, while
//! scenario specs read the very same token shape as `f64` (or hand it to
//! the config registry as text). Each consumer parses the token at the
//! precision it needs via [`Val::u64`] / [`Val::f64`] / [`Val::num_raw`].

/// One parsed JSON value.
#[derive(Debug, Clone)]
pub enum Val {
    /// Raw numeric token (optional sign, digits, fraction, exponent).
    Num(String),
    Bool(bool),
    Null,
    Str(String),
    Arr(Vec<Val>),
    Obj(Vec<(String, Val)>),
}

impl Val {
    /// Object field lookup (first match).
    pub fn field(&self, name: &str) -> Option<&Val> {
        match self {
            Val::Obj(items) => items.iter().find(|(k, _)| k == name).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object entries, if this is an object.
    pub fn entries(&self) -> Option<&[(String, Val)]> {
        match self {
            Val::Obj(items) => Some(items),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn arr(&self) -> Option<&[Val]> {
        match self {
            Val::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Numeric token parsed as `u64` — exact for the full 64-bit range
    /// (bit-pattern storage relies on this; a fractional or signed token
    /// is `None`, never a rounded value).
    pub fn u64(&self) -> Option<u64> {
        match self {
            Val::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    /// Numeric token parsed as `f64`.
    pub fn f64(&self) -> Option<f64> {
        match self {
            Val::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn str(&self) -> Option<&str> {
        match self {
            Val::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse one JSON document; trailing non-whitespace is a parse failure.
pub fn parse_root(text: &str) -> Option<Val> {
    parse_root_at(text).ok()
}

/// Like [`parse_root`], but a failure reports the byte offset the parser
/// stopped at — the position of (or just after) the offending input —
/// so loaders can surface a structured file + offset error instead of a
/// generic "malformed JSON".
pub fn parse_root_at(text: &str) -> std::result::Result<Val, u64> {
    let mut p = Parser::new(text);
    match p.value() {
        Some(v) => {
            p.ws();
            if p.i == p.s.len() {
                Ok(v)
            } else {
                Err(p.i as u64)
            }
        }
        None => Err(p.i as u64),
    }
}

/// Recursive-descent parser over the input bytes.
pub struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    pub fn new(s: &'a str) -> Self {
        Self { s: s.as_bytes(), i: 0 }
    }

    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Option<()> {
        self.ws();
        if self.i < self.s.len() && self.s[self.i] == b {
            self.i += 1;
            Some(())
        } else {
            None
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.s.get(self.i).copied()
    }

    /// Consume `word` if it starts at the cursor.
    fn literal(&mut self, word: &str) -> Option<()> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Some(())
        } else {
            None
        }
    }

    pub fn value(&mut self) -> Option<Val> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string().map(Val::Str),
            b'-' | b'0'..=b'9' => self.number(),
            b't' => self.literal("true").map(|()| Val::Bool(true)),
            b'f' => self.literal("false").map(|()| Val::Bool(false)),
            b'n' => self.literal("null").map(|()| Val::Null),
            _ => None,
        }
    }

    fn number(&mut self) -> Option<Val> {
        self.ws();
        let start = self.i;
        if self.s.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        let int_start = self.i;
        while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
            self.i += 1;
        }
        if self.i == int_start {
            return None;
        }
        // JSON forbids leading zeros ("01", "-007"): the integer part is
        // a lone 0 or starts with a nonzero digit. Accepting them would
        // let a corrupted cache entry reparse as a different number.
        if self.i - int_start > 1 && self.s[int_start] == b'0' {
            return None;
        }
        if self.s.get(self.i) == Some(&b'.') {
            self.i += 1;
            let frac_start = self.i;
            while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
                self.i += 1;
            }
            if self.i == frac_start {
                return None;
            }
        }
        if matches!(self.s.get(self.i), Some(&b'e') | Some(&b'E')) {
            self.i += 1;
            if matches!(self.s.get(self.i), Some(&b'+') | Some(&b'-')) {
                self.i += 1;
            }
            let exp_start = self.i;
            while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
                self.i += 1;
            }
            if self.i == exp_start {
                return None;
            }
        }
        let tok = std::str::from_utf8(&self.s[start..self.i]).ok()?;
        Some(Val::Num(tok.to_string()))
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = *self.s.get(self.i)?;
            self.i += 1;
            match b {
                b'"' => return Some(out),
                b'\\' => {
                    let e = *self.s.get(self.i)?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self.s.get(self.i..self.i + 4)?;
                            self.i += 4;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                            out.push(char::from_u32(code)?);
                        }
                        _ => return None,
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8: decode via str validation.
                    let start = self.i - 1;
                    let width = utf8_width(b)?;
                    let bytes = self.s.get(start..start + width)?;
                    self.i = start + width;
                    out.push_str(std::str::from_utf8(bytes).ok()?);
                }
            }
        }
    }

    fn array(&mut self) -> Option<Val> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Some(Val::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Some(Val::Arr(items));
                }
                _ => return None,
            }
        }
    }

    fn object(&mut self) -> Option<Val> {
        self.eat(b'{')?;
        let mut items = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Some(Val::Obj(items));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            let v = self.value()?;
            items.push((k, v));
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Some(Val::Obj(items));
                }
                _ => return None,
            }
        }
    }
}

fn utf8_width(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers_stay_exact_tokens() {
        let v = parse_root("[18446744073709551615, 0.125, -3, 1e3]").unwrap();
        let items = v.arr().unwrap();
        // Full-range u64 survives (a round-trip through f64 would not),
        // and the raw source token is preserved.
        assert_eq!(items[0].u64(), Some(u64::MAX));
        assert!(matches!(&items[0], Val::Num(s) if s == "18446744073709551615"));
        assert_eq!(items[1].f64(), Some(0.125));
        assert_eq!(items[1].u64(), None, "fractional token is not a u64");
        assert_eq!(items[2].f64(), Some(-3.0));
        assert_eq!(items[3].f64(), Some(1000.0));
    }

    #[test]
    fn u64_extremes_round_trip_exactly() {
        // The checkpoint codec stores f64 state as `to_bits()` words, so
        // the parser must round-trip every u64 — including 2^63 (the bit
        // pattern of -0.0) and u64::MAX, both of which a detour through
        // f64 would corrupt.
        let extremes = [0u64, u64::MAX, 9_223_372_036_854_775_808];
        assert_eq!(extremes[2], (-0.0f64).to_bits());
        for v in extremes {
            let text = format!("{{ \"w\": {v}, \"ws\": [{v}, {v}] }}");
            let root = parse_root(&text).unwrap();
            assert_eq!(root.field("w").unwrap().u64(), Some(v));
            for item in root.field("ws").unwrap().arr().unwrap() {
                assert_eq!(item.u64(), Some(v));
            }
        }
    }

    #[test]
    fn objects_arrays_strings_and_literals() {
        let v = parse_root(
            r#"{ "name": "capA", "on": true, "off": false, "nil": null, "xs": [] }"#,
        )
        .unwrap();
        assert_eq!(v.field("name").unwrap().str(), Some("capA"));
        assert!(matches!(v.field("on"), Some(Val::Bool(true))));
        assert!(matches!(v.field("off"), Some(Val::Bool(false))));
        assert!(matches!(v.field("nil"), Some(Val::Null)));
        assert_eq!(v.field("xs").unwrap().arr().unwrap().len(), 0);
        assert!(v.field("missing").is_none());
    }

    #[test]
    fn parse_failures_report_the_stop_offset() {
        // Truncated object: the cursor stops where the next key should
        // start (byte 9, just past the comma).
        assert_eq!(parse_root_at("{\"a\": 12,").unwrap_err(), 9);
        // Trailing garbage: the cursor stops at the garbage itself.
        assert_eq!(parse_root_at("{} trailing").unwrap_err(), 3);
        assert!(parse_root_at("{\"a\": 1}").is_ok());
    }

    #[test]
    fn malformed_inputs_fail() {
        for bad in ["", "{", "[1,]", "{\"a\" 1}", "1 2", "truex", "{\"a\":}", "--1", "1."] {
            assert!(parse_root(bad).is_none(), "{bad:?} should fail");
        }
    }

    #[test]
    fn negative_exponents_parse() {
        let v = parse_root("[1e-3, 2.5E-2, 1E+2, -4e-1]").unwrap();
        let items = v.arr().unwrap();
        assert_eq!(items[0].f64(), Some(1e-3));
        assert_eq!(items[1].f64(), Some(2.5e-2));
        assert_eq!(items[2].f64(), Some(100.0));
        assert_eq!(items[3].f64(), Some(-0.4));
        assert_eq!(items[0].u64(), None, "exponent token is not a u64");
    }

    #[test]
    fn leading_zeros_are_rejected() {
        for bad in ["01", "-01", "00", "[01]", "{\"a\": 007}", "01.5", "-00.5", "01e3"] {
            assert!(parse_root(bad).is_none(), "{bad:?} should fail");
        }
        // A lone zero, zero-led fractions, and zero-led *exponent digits*
        // (which JSON permits) all still parse.
        assert_eq!(parse_root("0").unwrap().u64(), Some(0));
        assert_eq!(parse_root("-0").unwrap().f64(), Some(-0.0));
        assert_eq!(parse_root("0.5").unwrap().f64(), Some(0.5));
        assert_eq!(parse_root("-0.5").unwrap().f64(), Some(-0.5));
        assert_eq!(parse_root("10").unwrap().u64(), Some(10));
        assert_eq!(parse_root("1e05").unwrap().f64(), Some(1e5));
    }

    #[test]
    fn deeply_nested_arrays_parse() {
        let depth = 64;
        let text = format!("{}7{}", "[".repeat(depth), "]".repeat(depth));
        let mut v = parse_root(&text).unwrap();
        for _ in 0..depth {
            v = match &v {
                Val::Arr(items) => {
                    assert_eq!(items.len(), 1);
                    items[0].clone()
                }
                other => panic!("expected array, got {other:?}"),
            };
        }
        assert_eq!(v.u64(), Some(7));
    }

    #[test]
    fn histogram_scale_u64_arrays_round_trip() {
        // The result cache stores latency-histogram state and the 7-slot
        // latency summary as plain u64 arrays; emulate a full 1024-bucket
        // dump mixing extremes and confirm every element survives exactly.
        let vals: Vec<u64> = (0..1024u64)
            .map(|i| match i % 4 {
                0 => 0,
                1 => u64::MAX,
                2 => u64::MAX - i,
                _ => 1u64 << (i % 63),
            })
            .collect();
        let text =
            format!("[{}]", vals.iter().map(u64::to_string).collect::<Vec<_>>().join(","));
        let root = parse_root(&text).unwrap();
        let items = root.arr().unwrap();
        assert_eq!(items.len(), 1024);
        for (item, v) in items.iter().zip(&vals) {
            assert_eq!(item.u64(), Some(*v));
        }
    }

    #[test]
    fn trailing_content_is_rejected() {
        assert!(parse_root("{} trailing").is_none());
        assert!(parse_root("  { \"a\": 1 }  ").is_some());
    }
}
