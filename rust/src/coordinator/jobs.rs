//! Suite-wide simulation job graph with fingerprint-keyed memoization
//! (DESIGN.md §5).
//!
//! Experiments no longer call [`parallel_map`] directly: they submit
//! [`JobSpec`]s into a [`JobGraph`], which
//!
//! 1. **dedupes** structurally identical legs — the key is
//!    `(SystemConfig::fingerprint(), mechanism, workload-or-mix)`, so two
//!    experiments asking for the same simulation share one run;
//! 2. serves repeated keys from the in-process [`SimCache`] (and, opted
//!    in via `--result-cache DIR`, from a hand-rolled-JSON on-disk cache
//!    that persists across invocations);
//! 3. fans the remaining unique jobs out through **one** `parallel_map`
//!    call, **cost-ordered** (estimated cycles, eight-core mixes first)
//!    so a long mix never lands on the queue tail and strands a worker.
//!
//! Correctness rests on two facts: a simulation is a pure function of
//! `(config, mechanism, workload)` (traces are seeded from the config),
//! and the fingerprint covers *every* config field by exhaustive
//! destructuring — see the contract on [`SystemConfig::fingerprint`].
//!
//! On top of whole-result memoization sits **warmup forking** (DESIGN.md
//! §12): sweep legs that disagree only in measure-phase knobs share a
//! warmup identity ([`SystemConfig::warmup_fingerprint`]), so the graph
//! simulates their common warmup once, snapshots it
//! ([`SimSnapshot`]), and forks every leg from the snapshot. Forked legs
//! are bit-identical to cold runs by the checkpoint round-trip contract
//! (tests/checkpoint.rs), so this is purely a wall-clock optimization.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::SystemConfig;
use crate::error::{Context, Result};
use crate::latency::MechanismKind;
use crate::sim::{SimResult, SimSnapshot, System};
use crate::trace::PROFILES;

use super::runner::parallel_map;

/// What one job simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadId {
    /// One workload from [`PROFILES`] on a single-core config.
    Single(usize),
    /// One of the paper's multiprogrammed mixes (`multicore_mix`).
    Mix(usize),
}

impl WorkloadId {
    /// Short slug for on-disk cache file names (`s3`, `m12`), interned.
    fn slug(&self) -> &'static str {
        static SLUGS: InternTable = OnceLock::new();
        intern(&SLUGS, *self, || match self {
            WorkloadId::Single(w) => format!("s{w}"),
            WorkloadId::Mix(m) => format!("m{m}"),
        })
    }
}

/// Per-[`WorkloadId`] string interner. The slug and workload label are
/// rebuilt on every cache probe, disk-path computation, and validation
/// of every leg, but the set of distinct values is tiny (one per
/// workload or mix index), so the first request builds the string once
/// and leaks it — the same `Box::leak` discipline the `--set` override
/// registry uses — and every later request is a map hit handing out the
/// `&'static str`, no allocation.
type InternTable = OnceLock<Mutex<HashMap<WorkloadId, &'static str>>>;

fn intern(table: &InternTable, w: WorkloadId, build: impl FnOnce() -> String) -> &'static str {
    let mut map = table.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    *map.entry(w).or_insert_with(|| &*Box::leak(build().into_boxed_str()))
}

/// The memoization key: everything a simulation's result depends on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobKey {
    pub cfg_fingerprint: u64,
    pub mechanism: MechanismKind,
    pub workload: WorkloadId,
}

/// The warmup-sharing key: legs with equal [`WarmupKey`]s reach
/// bit-identical state at the end of warmup and can fork from one
/// snapshot. Strictly coarser than [`JobKey`]: it hashes only the
/// warmup-relevant config slice ([`SystemConfig::warmup_fingerprint`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WarmupKey {
    pub warmup_fingerprint: u64,
    pub mechanism: MechanismKind,
    pub workload: WorkloadId,
}

/// One simulation an experiment wants run.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub cfg: SystemConfig,
    pub mechanism: MechanismKind,
    pub workload: WorkloadId,
}

impl JobSpec {
    /// A single-core job running `PROFILES[workload]`.
    pub fn single(cfg: SystemConfig, mechanism: MechanismKind, workload: usize) -> Self {
        assert_eq!(cfg.cpu.cores, 1, "Single jobs take a single-core config");
        assert!(workload < PROFILES.len(), "workload index out of range");
        Self { cfg, mechanism, workload: WorkloadId::Single(workload) }
    }

    /// A multiprogrammed job running mix `mix` on `cfg.cpu.cores` cores.
    pub fn mix(cfg: SystemConfig, mechanism: MechanismKind, mix: usize) -> Self {
        Self { cfg, mechanism, workload: WorkloadId::Mix(mix) }
    }

    pub fn key(&self) -> JobKey {
        JobKey {
            cfg_fingerprint: self.cfg.fingerprint(),
            mechanism: self.mechanism,
            workload: self.workload,
        }
    }

    /// The warmup-sharing identity of this leg.
    pub fn warmup_key(&self) -> WarmupKey {
        WarmupKey {
            warmup_fingerprint: self.cfg.warmup_fingerprint(self.mechanism),
            mechanism: self.mechanism,
            workload: self.workload,
        }
    }

    /// Estimated cost in core-instructions, the dispatch sort key. Mixes
    /// dominate by construction (8 cores and, under fixed-time
    /// measurement, a deep cycle window), so sorting by this descending
    /// schedules eight-core mixes first.
    pub fn cost(&self) -> u64 {
        let per_core = match self.cfg.measure_cycles {
            // Fixed-time runs do work proportional to the window, not the
            // instruction target (~5 CPU cycles per bus-visible event is
            // a crude but rank-stable conversion).
            Some(cycles) => self.cfg.insts_per_core.max(cycles / 5),
            None => self.cfg.insts_per_core,
        };
        self.cfg.cpu.cores as u64 * per_core
    }

    /// Build the (cold, unwarmed) system this spec describes.
    fn build_system(&self) -> System {
        match self.workload {
            WorkloadId::Single(w) => System::new(&self.cfg, self.mechanism, &[&PROFILES[w]]),
            WorkloadId::Mix(m) => System::new_mix(&self.cfg, self.mechanism, m),
        }
    }

    /// Run the simulation this spec describes, warmup included.
    fn run(&self) -> SimResult {
        self.build_system().run()
    }

    /// Run this leg forked from a warmed-up snapshot: restore, then
    /// measure. Returns `(result, true)` on a successful fork; a snapshot
    /// that fails to restore (corrupt or mismatched on-disk entry)
    /// degrades to a cold [`JobSpec::run`] and returns `(result, false)`
    /// — never a wrong result.
    fn run_forked(&self, snap: &SimSnapshot) -> (SimResult, bool) {
        let mut sys = self.build_system();
        if snap.restore_into(&mut sys).is_some() {
            (sys.run_measure(), true)
        } else {
            (self.run(), false)
        }
    }
}

/// Cache/dedup telemetry, accumulated across every graph run through one
/// [`SimCache`] and surfaced in suite output.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Jobs submitted to graphs.
    pub submitted: u64,
    /// Submissions collapsed onto an identical job in the same graph.
    pub deduped: u64,
    /// Unique jobs served from the in-process cache (a previous graph).
    pub memory_hits: u64,
    /// Unique jobs loaded from the on-disk cache (`--result-cache`).
    pub disk_hits: u64,
    /// Unique jobs actually simulated.
    pub simulated: u64,
    /// Warmup phases actually simulated for fork groups: snapshot builds
    /// plus cold fallbacks after a failed restore.
    pub warmup_sims: u64,
    /// Legs forked from a warmed-up snapshot instead of simulating their
    /// own warmup.
    pub warmup_forks: u64,
    /// CPU cycles of warmup simulated for fork groups (see
    /// [`CacheStats::warmup_sims`]).
    pub warmup_cycles_simulated: u64,
    /// CPU cycles of warmup forked legs skipped by restoring a snapshot —
    /// what the naive path would have re-simulated.
    pub warmup_cycles_forked: u64,
    /// Job attempts retried after a panic (each job runs under
    /// `catch_unwind` with bounded retry + backoff).
    pub retries: u64,
    /// Jobs that still failed after every retry; their legs are reported
    /// through [`JobResults::failures`] instead of aborting the sweep.
    pub failed: u64,
    /// Corrupt on-disk cache entries renamed aside (`.bad`) so they are
    /// preserved for inspection instead of re-read as misses forever.
    pub quarantined: u64,
}

impl CacheStats {
    /// Simulations avoided relative to the naive path that runs every
    /// submission: in-graph dedup plus memory and disk cache hits.
    pub fn eliminated(&self) -> u64 {
        self.deduped + self.memory_hits + self.disk_hits
    }

    /// One-line summary for suite output (format is stable — CI greps it;
    /// the warmup clause is appended after the original text so older
    /// greps keep matching).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "job graph: submitted {}, deduped {}, cache hits {} (memory {}, disk {}), simulated {} — {} redundant legs eliminated; warmup: {} forked, {} simulated ({} cycles reused, {} simulated)",
            self.submitted,
            self.deduped,
            self.memory_hits + self.disk_hits,
            self.memory_hits,
            self.disk_hits,
            self.simulated,
            self.eliminated(),
            self.warmup_forks,
            self.warmup_sims,
            self.warmup_cycles_forked,
            self.warmup_cycles_simulated,
        );
        if self.quarantined > 0 {
            s.push_str(&format!("; {} quarantined", self.quarantined));
        }
        if self.retries > 0 || self.failed > 0 {
            s.push_str(&format!("; faults: {} retried, {} failed", self.retries, self.failed));
        }
        s
    }
}

/// In-process result cache keyed by [`JobKey`], optionally backed by an
/// on-disk directory (`--result-cache DIR`) of hand-rolled JSON files —
/// one per key, named `{fingerprint:016x}.{mech}.{workload}.json`.
pub struct SimCache {
    map: HashMap<JobKey, Arc<SimResult>>,
    /// Warmed-up snapshots shared across graphs (and, disk-backed,
    /// across invocations) — `{warmup_fp:016x}.{mech}.{workload}.ckpt.json`.
    snaps: HashMap<WarmupKey, Arc<SimSnapshot>>,
    disk: Option<PathBuf>,
    pub stats: CacheStats,
}

impl SimCache {
    /// Purely in-process cache (the default).
    pub fn in_memory() -> Self {
        Self {
            map: HashMap::new(),
            snaps: HashMap::new(),
            disk: None,
            stats: CacheStats::default(),
        }
    }

    /// Cache backed by `dir`: misses are simulated then persisted, and a
    /// later invocation pointed at the same directory reloads them.
    pub fn with_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result cache dir {dir:?}"))?;
        Ok(Self {
            map: HashMap::new(),
            snaps: HashMap::new(),
            disk: Some(dir),
            stats: CacheStats::default(),
        })
    }

    fn disk_path(&self, key: &JobKey) -> Option<PathBuf> {
        self.disk.as_ref().map(|d| {
            d.join(format!(
                "{:016x}.{}.{}.json",
                key.cfg_fingerprint,
                mech_slug(key.mechanism),
                key.workload.slug()
            ))
        })
    }

    /// Rename a corrupt cache file aside as `{name}.bad` (best-effort) so
    /// it is preserved for inspection and, crucially, never re-read: a
    /// corrupt entry left in place would decode-fail on every invocation
    /// and the re-simulated insert could race its own overwrite.
    fn quarantine(&mut self, path: &std::path::Path) {
        self.stats.quarantined += 1;
        let mut bad = path.as_os_str().to_os_string();
        bad.push(".bad");
        if std::fs::rename(path, &bad).is_err() {
            // Read-only dir: removing also fails, and the entry simply
            // stays a (counted) miss.
            let _ = std::fs::remove_file(path);
        }
        eprintln!("warning: quarantined corrupt result-cache entry {}", path.display());
    }

    /// Look `key` up: memory first, then disk. Counts the hit.
    fn get(&mut self, key: &JobKey) -> Option<Arc<SimResult>> {
        if let Some(r) = self.map.get(key) {
            self.stats.memory_hits += 1;
            return Some(r.clone());
        }
        let path = self.disk_path(key)?;
        let mut text = std::fs::read_to_string(&path).ok()?;
        crate::faulthooks::maybe_corrupt_cache_entry(&mut text);
        let result = match diskjson::decode_result(&text) {
            Some(r) => r,
            None => {
                self.quarantine(&path);
                return None;
            }
        };
        // A decoded file must actually describe this key's simulation:
        // the fingerprint in the file name hashes only the config, so a
        // renamed/forged file (or a PROFILES reorder in a build that
        // forgot to bump `diskjson::VERSION`) would otherwise serve the
        // wrong workload's result. Mismatches are misses: the job
        // re-simulates and the insert overwrites the bad file.
        if result.workload != expected_workload(key.workload)
            || result.mechanism != key.mechanism.label()
        {
            return None;
        }
        let arc = Arc::new(result);
        self.map.insert(*key, arc.clone());
        self.stats.disk_hits += 1;
        Some(arc)
    }

    /// Record a freshly simulated result (and persist it if disk-backed).
    fn insert(&mut self, key: JobKey, result: Arc<SimResult>) {
        if let Some(path) = self.disk_path(&key) {
            // Atomic publish — write a process-unique temp file, then
            // rename (atomic within a directory), so an invocation
            // sharing this cache dir never reads a half-written entry.
            // Persistence stays best-effort: a read-only dir degrades to
            // the in-memory cache rather than failing the suite.
            let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
            if std::fs::write(&tmp, diskjson::encode_result(&result)).is_ok()
                && std::fs::rename(&tmp, &path).is_err()
            {
                let _ = std::fs::remove_file(&tmp);
            }
        }
        self.map.insert(key, result);
    }

    fn snapshot_path(&self, key: &WarmupKey) -> Option<PathBuf> {
        self.disk.as_ref().map(|d| {
            d.join(format!(
                "{:016x}.{}.{}.ckpt.json",
                key.warmup_fingerprint,
                mech_slug(key.mechanism),
                key.workload.slug()
            ))
        })
    }

    /// Look a warmed-up snapshot up: memory first, then disk. Disk loads
    /// are validated against the key's full identity triple — the
    /// fingerprint in the file name hashes only the config, so a renamed
    /// file must not seed another key's legs (restore would reject it
    /// anyway, but catching it here avoids burning a fork slot).
    fn get_snapshot(&mut self, key: &WarmupKey) -> Option<Arc<SimSnapshot>> {
        if let Some(s) = self.snaps.get(key) {
            return Some(s.clone());
        }
        let path = self.snapshot_path(key)?;
        let mut text = std::fs::read_to_string(&path).ok()?;
        crate::faulthooks::maybe_corrupt_checkpoint(&mut text);
        let snap = match SimSnapshot::decode(&text) {
            Some(s) => s,
            None => {
                self.quarantine(&path);
                return None;
            }
        };
        if snap.warmup_fingerprint != key.warmup_fingerprint
            || snap.mechanism != key.mechanism
            || snap.workload != expected_workload(key.workload)
        {
            return None;
        }
        let arc = Arc::new(snap);
        self.snaps.insert(*key, arc.clone());
        Some(arc)
    }

    /// Record a freshly captured snapshot (and persist it if disk-backed;
    /// same atomic-publish, best-effort discipline as [`SimCache::insert`]).
    fn insert_snapshot(&mut self, key: WarmupKey, snap: Arc<SimSnapshot>) {
        if let Some(path) = self.snapshot_path(&key) {
            let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
            if std::fs::write(&tmp, snap.encode()).is_ok() && std::fs::rename(&tmp, &path).is_err()
            {
                let _ = std::fs::remove_file(&tmp);
            }
        }
        self.snaps.insert(key, snap);
    }

    /// Unique results currently held in memory (tests/telemetry).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// The `SimResult::workload` label a key's simulation produces (what
/// `System::new`/`new_mix` stamp); disk loads are validated against it.
/// Interned like [`WorkloadId::slug`].
fn expected_workload(w: WorkloadId) -> &'static str {
    static LABELS: InternTable = OnceLock::new();
    intern(&LABELS, w, || match w {
        WorkloadId::Single(i) => PROFILES[i].name.to_string(),
        WorkloadId::Mix(m) => format!("mix{m:02}"),
    })
}

fn mech_slug(m: MechanismKind) -> &'static str {
    // From the single mechanism name table (latency::MECHANISM_TABLE).
    m.info().slug
}

/// Handle returned by [`JobGraph::submit`]; redeem it against the
/// [`JobResults`] of the graph run that issued it.
#[derive(Debug, Clone, Copy)]
pub struct JobTicket(usize);

/// Attempts beyond the first a panicking job gets before it is reported
/// as failed, and the linear backoff between them.
const JOB_RETRIES: u32 = 2;
const BACKOFF_MS: u64 = 25;

/// Per-job panic isolation: run `f` under `catch_unwind`, retrying up to
/// [`JOB_RETRIES`] times with linear backoff. Returns the value plus the
/// number of retries consumed, or the final panic message — a panicking
/// job must never take down the worker scope (and with it every other
/// leg of the sweep).
fn run_isolated<T>(f: impl Fn() -> T) -> (std::result::Result<T, String>, u64) {
    let mut last = String::new();
    for attempt in 0..=JOB_RETRIES {
        if attempt > 0 {
            std::thread::sleep(std::time::Duration::from_millis(BACKOFF_MS * attempt as u64));
        }
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(&f)) {
            Ok(v) => return (Ok(v), attempt as u64),
            Err(p) => last = panic_message(p.as_ref()),
        }
    }
    (Err(last), JOB_RETRIES as u64)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A batch of submitted jobs, deduped by [`JobKey`] at submission time.
#[derive(Default)]
pub struct JobGraph {
    /// Unique specs in first-submission order.
    specs: Vec<JobSpec>,
    index: HashMap<JobKey, usize>,
    /// Per-submission index into `specs`.
    tickets: Vec<usize>,
}

impl JobGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit a job; identical keys collapse onto one slot.
    pub fn submit(&mut self, spec: JobSpec) -> JobTicket {
        let key = spec.key();
        let slot = match self.index.get(&key) {
            Some(&s) => s,
            None => {
                let s = self.specs.len();
                self.specs.push(spec);
                self.index.insert(key, s);
                s
            }
        };
        self.tickets.push(slot);
        JobTicket(self.tickets.len() - 1)
    }

    /// Unique jobs currently in the graph.
    pub fn unique_len(&self) -> usize {
        self.specs.len()
    }

    /// Total submissions (including duplicates).
    pub fn submitted_len(&self) -> usize {
        self.tickets.len()
    }

    /// Run the graph memoized: cached keys are served from `cache`, the
    /// rest fan out through cost-ordered `parallel_map` calls, and fresh
    /// results are inserted back into `cache`.
    ///
    /// Two phases. **Warmup** (only when forking applies): legs that miss
    /// the result cache are grouped by [`WarmupKey`]; a group whose
    /// snapshot is already cached forks unconditionally, and a group of
    /// at least `checkpoint.min_fork_group` legs simulates its shared
    /// warmup once and snapshots it. **Measure**: every missing leg runs
    /// — forked legs restore and measure, the rest run cold — in one
    /// cost-ordered dispatch.
    pub fn run(self, cache: &mut SimCache) -> JobResults {
        cache.stats.submitted += self.tickets.len() as u64;
        cache.stats.deduped += (self.tickets.len() - self.specs.len()) as u64;

        let mut slots: Vec<Option<Arc<SimResult>>> = vec![None; self.specs.len()];
        let mut to_run: Vec<usize> = Vec::new();
        for (i, spec) in self.specs.iter().enumerate() {
            match cache.get(&spec.key()) {
                Some(r) => slots[i] = Some(r),
                None => to_run.push(i),
            }
        }

        // Group the misses by warmup identity, in first-submission order
        // so snapshot construction is deterministic.
        let mut gindex: HashMap<WarmupKey, usize> = HashMap::new();
        let mut groups: Vec<(WarmupKey, Vec<usize>)> = Vec::new();
        for &i in &to_run {
            let spec = &self.specs[i];
            if !spec.cfg.checkpoint.warmup_fork || spec.cfg.warmup_cpu_cycles == 0 {
                continue;
            }
            let key = spec.warmup_key();
            let gi = *gindex.entry(key).or_insert_with(|| {
                groups.push((key, Vec::new()));
                groups.len() - 1
            });
            groups[gi].1.push(i);
        }

        // Resolve snapshots: a cached one (earlier graph, or disk) is
        // free, so even a lone leg forks from it; building one only pays
        // off when `min_fork_group` legs will share it. Legs of one group
        // can disagree on `min_fork_group` (it is measure-side, outside
        // the warmup fingerprint); the first-submitted leg's value wins.
        let mut snap_for: HashMap<usize, Arc<SimSnapshot>> = HashMap::new();
        let mut to_build: Vec<usize> = Vec::new();
        for (gi, (key, legs)) in groups.iter().enumerate() {
            if let Some(s) = cache.get_snapshot(key) {
                for &i in legs {
                    snap_for.insert(i, s.clone());
                }
            } else if legs.len() >= self.specs[legs[0]].cfg.checkpoint.min_fork_group {
                to_build.push(gi);
            }
        }

        // Phase 1: simulate each group's shared warmup once, in parallel.
        // A build that panics through its retries degrades its legs to
        // cold runs (they simulate their own warmup) — never an abort.
        if !to_build.is_empty() {
            let specs = &self.specs;
            let groups_ref = &groups;
            let build = &to_build;
            let built = parallel_map(build.len(), |j| {
                let (_, legs) = &groups_ref[build[j]];
                run_isolated(|| {
                    crate::faulthooks::maybe_inject_job_panic();
                    let mut sys = specs[legs[0]].build_system();
                    sys.run_warmup();
                    SimSnapshot::capture(&sys)
                })
            });
            for (j, (snap, retries)) in built.into_iter().enumerate() {
                let (key, legs) = &groups[to_build[j]];
                cache.stats.retries += retries;
                match snap {
                    Ok(snap) => {
                        cache.stats.warmup_sims += 1;
                        cache.stats.warmup_cycles_simulated +=
                            self.specs[legs[0]].cfg.warmup_cpu_cycles;
                        let arc = Arc::new(snap);
                        cache.insert_snapshot(*key, arc.clone());
                        for &i in legs {
                            snap_for.insert(i, arc.clone());
                        }
                    }
                    Err(e) => eprintln!(
                        "warning: warmup build panicked after retries ({e}); {} legs run cold",
                        legs.len()
                    ),
                }
            }
        }

        // Phase 2, cost-ordered dispatch: most expensive first, submission
        // order as the deterministic tie-break. The atomic-index runner
        // consumes jobs in this order, so the long eight-core mixes start
        // while every worker still has a deep queue behind it, instead of
        // one worker dragging a tail-end mix alone.
        to_run.sort_by_key(|&i| (std::cmp::Reverse(self.specs[i].cost()), i));

        cache.stats.simulated += to_run.len() as u64;
        let specs = &self.specs;
        let order = &to_run;
        let snaps = &snap_for;
        let results = parallel_map(order.len(), |j| {
            let i = order[j];
            run_isolated(|| {
                crate::faulthooks::maybe_inject_job_panic();
                match snaps.get(&i) {
                    Some(s) => specs[i].run_forked(s),
                    None => (specs[i].run(), false),
                }
            })
        });
        let mut failures = Vec::new();
        for (j, (res, retries)) in results.into_iter().enumerate() {
            let i = to_run[j];
            cache.stats.retries += retries;
            let (r, forked) = match res {
                Ok(v) => v,
                Err(error) => {
                    // The leg exhausted its retries: report it and leave
                    // its slot empty so the rest of the sweep completes.
                    cache.stats.failed += 1;
                    failures.push(JobFailure {
                        workload: expected_workload(self.specs[i].workload),
                        mechanism: self.specs[i].mechanism.label(),
                        error,
                    });
                    continue;
                }
            };
            let warmup = self.specs[i].cfg.warmup_cpu_cycles;
            if forked {
                cache.stats.warmup_forks += 1;
                cache.stats.warmup_cycles_forked += warmup;
            } else if snap_for.contains_key(&i) {
                // Failed restore fell back to a cold run.
                cache.stats.warmup_sims += 1;
                cache.stats.warmup_cycles_simulated += warmup;
            }
            let arc = Arc::new(r);
            cache.insert(self.specs[i].key(), arc.clone());
            slots[i] = Some(arc);
        }

        JobResults { tickets: self.tickets, unique: slots, failures }
    }

    /// Run every submission independently — no dedup, no cache reads or
    /// writes, no cost ordering. This is the `--no-memo` escape hatch and
    /// the bench baseline that reproduces the pre-job-graph behavior; it
    /// still feeds the submission/simulation counters.
    pub fn run_all(self, cache: &mut SimCache) -> JobResults {
        cache.stats.submitted += self.tickets.len() as u64;
        cache.stats.simulated += self.tickets.len() as u64;
        let specs = &self.specs;
        let tickets = &self.tickets;
        let results = parallel_map(tickets.len(), |j| {
            run_isolated(|| {
                crate::faulthooks::maybe_inject_job_panic();
                specs[tickets[j]].run()
            })
        });
        let mut unique = Vec::with_capacity(results.len());
        let mut failures = Vec::new();
        for (j, (res, retries)) in results.into_iter().enumerate() {
            cache.stats.retries += retries;
            match res {
                Ok(r) => unique.push(Some(Arc::new(r))),
                Err(error) => {
                    cache.stats.failed += 1;
                    let spec = &self.specs[self.tickets[j]];
                    failures.push(JobFailure {
                        workload: expected_workload(spec.workload),
                        mechanism: spec.mechanism.label(),
                        error,
                    });
                    unique.push(None);
                }
            }
        }
        JobResults { tickets: (0..self.tickets.len()).collect(), unique, failures }
    }
}

/// One leg that exhausted its retries; surfaced in sweep summaries and
/// failure reports instead of aborting the suite.
#[derive(Debug, Clone)]
pub struct JobFailure {
    pub workload: &'static str,
    pub mechanism: &'static str,
    pub error: String,
}

/// Results of one graph run: redeem [`JobTicket`]s for shared
/// [`SimResult`]s. Legs that panicked through every retry leave an
/// empty slot and an entry in [`JobResults::failures`].
pub struct JobResults {
    tickets: Vec<usize>,
    unique: Vec<Option<Arc<SimResult>>>,
    failures: Vec<JobFailure>,
}

impl JobResults {
    /// Redeem a ticket. Panics if that leg failed after every retry —
    /// callers that tolerate holes use [`JobResults::try_get`].
    pub fn get(&self, t: JobTicket) -> &SimResult {
        self.try_get(t).expect("job leg failed after retries (see JobResults::failures)")
    }

    /// Redeem a ticket; `None` if the leg failed after every retry.
    pub fn try_get(&self, t: JobTicket) -> Option<&SimResult> {
        self.unique[self.tickets[t.0]].as_deref()
    }

    /// Legs that exhausted their retries in this graph run.
    pub fn failures(&self) -> &[JobFailure] {
        &self.failures
    }
}

/// Execution context threaded through every experiment: the shared
/// result cache plus the memoization switch (`--no-memo`).
pub struct JobEngine {
    pub cache: SimCache,
    /// When false, every graph runs through [`JobGraph::run_all`].
    pub memo: bool,
}

impl JobEngine {
    /// Memoizing engine with an in-process cache (the default).
    pub fn new() -> Self {
        Self { cache: SimCache::in_memory(), memo: true }
    }

    /// Non-memoizing engine: every submission simulates (`--no-memo`).
    pub fn no_memo() -> Self {
        Self { cache: SimCache::in_memory(), memo: false }
    }

    /// Memoizing engine persisted under `dir` (`--result-cache DIR`).
    pub fn with_disk(dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self { cache: SimCache::with_disk(dir)?, memo: true })
    }

    pub fn run(&mut self, graph: JobGraph) -> JobResults {
        if self.memo {
            graph.run(&mut self.cache)
        } else {
            graph.run_all(&mut self.cache)
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats
    }
}

impl Default for JobEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// Hand-rolled JSON codec for persisted [`SimResult`]s, on the shared
/// zero-dep parser (`coordinator::json`). The format is versioned and
/// fully under our control:
///
/// * every `f64` is stored as its IEEE-754 bit pattern (a JSON integer),
///   so round-trips are bit-exact — the memoization acceptance criterion
///   is bit-identity, and decimal printing cannot guarantee it
///   (`json::Val` keeps numeric tokens raw, so full-range `u64` bit
///   patterns never round through `f64`);
/// * `McStats` is a fixed-order 18-integer array per channel;
/// * `EnergyBreakdown` is a fixed-order 5-integer (bits) array.
///
/// Any parse failure — wrong version, unknown mechanism label, malformed
/// text — decodes to `None` and is treated as a cache miss, so a stale
/// or corrupt cache directory degrades to re-simulation, never to a
/// wrong result.
mod diskjson {
    use crate::controller::McStats;
    use crate::coordinator::json::{parse_root, Val};
    use crate::energy::EnergyBreakdown;
    use crate::latency::MechanismKind;
    use crate::sim::latency_hist::LatencySummary;
    use crate::sim::sample::SampleSummary;
    use crate::sim::SimResult;

    /// Cache-entry version: covers the JSON layout **and** simulator
    /// semantics. Bump it whenever the encoding changes *or* a code
    /// change can alter any simulation's results (timing model, trace
    /// generation, scheduler/mechanism behavior, PROFILES order) — the
    /// config fingerprint in the file name cannot see code changes, so
    /// this constant is what keeps an on-disk cache from serving results
    /// an older build computed.
    ///
    /// v2: `CombinedMech::on_activate` now grants the element-wise
    /// minimum effective timing when both ChargeCache and NUAT reduce,
    /// so CC+NUAT results from v1 builds may legitimately differ under
    /// asymmetric reduction configs.
    ///
    /// v3: results carry the interval-sampling summary
    /// (`SimResult::sampled`) as the fixed-order 7-integer `sampled`
    /// array (empty = not sampled); v2 entries lack the field.
    ///
    /// v4: `McStats` grew the four fault-injection counters
    /// (timing_violations, mitigation_evictions, guard_suppressed,
    /// rows_blacklisted), so the per-channel array is 18 integers.
    ///
    /// v5: results carry the per-request latency summary
    /// (`SimResult::latency`) as the fixed-order 7-integer `latency`
    /// array (empty = no reads in the window), and open-loop traffic
    /// (`traffic.*`) changed what a fixed-time run can simulate.
    pub const VERSION: u64 = 5;

    // ---- encoding ----

    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    fn bits_array(vals: &[f64]) -> String {
        let items: Vec<String> = vals.iter().map(|v| v.to_bits().to_string()).collect();
        format!("[{}]", items.join(","))
    }

    fn mc_array(m: &McStats) -> String {
        // Fixed field order; bump VERSION if it ever changes.
        format!(
            "[{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}]",
            m.acts,
            m.acts_reduced,
            m.reads,
            m.writes,
            m.precharges,
            m.refreshes,
            m.row_hits,
            m.row_misses,
            m.row_conflicts,
            m.read_latency_sum,
            m.read_latency_cnt,
            m.bank_open_cycles,
            m.wq_forwards,
            m.rejects,
            m.timing_violations,
            m.mitigation_evictions,
            m.guard_suppressed,
            m.rows_blacklisted
        )
    }

    /// `SimResult::sampled` as a fixed-order 7-integer array (empty when
    /// the run was not sampled): intervals, detailed_insts,
    /// skipped_insts, then the four summary floats as bit patterns.
    fn sampled_array(s: &Option<SampleSummary>) -> String {
        match s {
            None => "[]".to_string(),
            Some(s) => format!(
                "[{},{},{},{},{},{},{}]",
                s.intervals,
                s.detailed_insts,
                s.skipped_insts,
                s.ipc_mean.to_bits(),
                s.ipc_ci95.to_bits(),
                s.latency_mean.to_bits(),
                s.latency_ci95.to_bits()
            ),
        }
    }

    /// `SimResult::latency` as a fixed-order 7-integer array (empty when
    /// no read completed in the window): p50, p95, p99, p999, the mean's
    /// bit pattern, max, samples.
    fn latency_array(l: &Option<LatencySummary>) -> String {
        match l {
            None => "[]".to_string(),
            Some(l) => format!(
                "[{},{},{},{},{},{},{}]",
                l.p50,
                l.p95,
                l.p99,
                l.p999,
                l.mean.to_bits(),
                l.max,
                l.samples
            ),
        }
    }

    pub fn encode_result(r: &SimResult) -> String {
        let mcs: Vec<String> = r.mc.iter().map(mc_array).collect();
        let e = &r.energy;
        let energy =
            bits_array(&[e.act_pre_nj, e.read_nj, e.write_nj, e.refresh_nj, e.background_nj]);
        format!(
            "{{\n  \"version\": {VERSION},\n  \"workload\": \"{}\",\n  \"mechanism\": \"{}\",\n  \"core_ipc_bits\": {},\n  \"cpu_cycles\": {},\n  \"mc\": [{}],\n  \"rltl_bits\": {},\n  \"energy_bits\": {},\n  \"total_insts\": {},\n  \"llc_hits\": {},\n  \"llc_misses\": {},\n  \"sampled\": {},\n  \"latency\": {}\n}}\n",
            escape(&r.workload),
            escape(r.mechanism),
            bits_array(&r.core_ipc),
            r.cpu_cycles,
            mcs.join(","),
            bits_array(&r.rltl),
            energy,
            r.total_insts,
            r.llc_hits,
            r.llc_misses,
            sampled_array(&r.sampled),
            latency_array(&r.latency)
        )
    }

    // ---- decoding (shared parser; bit-pattern array helpers) ----

    /// Array of `u64` bit patterns decoded back to `f64`s.
    fn f64_bits_vec(v: &Val) -> Option<Vec<f64>> {
        v.arr()?.iter().map(|x| x.u64().map(f64::from_bits)).collect()
    }

    fn u64_vec(v: &Val) -> Option<Vec<u64>> {
        v.arr()?.iter().map(Val::u64).collect()
    }

    fn decode_mc(v: &Val) -> Option<McStats> {
        let f = u64_vec(v)?;
        if f.len() != 18 {
            return None;
        }
        Some(McStats {
            acts: f[0],
            acts_reduced: f[1],
            reads: f[2],
            writes: f[3],
            precharges: f[4],
            refreshes: f[5],
            row_hits: f[6],
            row_misses: f[7],
            row_conflicts: f[8],
            read_latency_sum: f[9],
            read_latency_cnt: f[10],
            bank_open_cycles: f[11],
            wq_forwards: f[12],
            rejects: f[13],
            timing_violations: f[14],
            mitigation_evictions: f[15],
            guard_suppressed: f[16],
            rows_blacklisted: f[17],
        })
    }

    fn decode_sampled(v: &Val) -> Option<Option<SampleSummary>> {
        let f = u64_vec(v)?;
        match f.len() {
            0 => Some(None),
            7 => Some(Some(SampleSummary {
                intervals: f[0],
                detailed_insts: f[1],
                skipped_insts: f[2],
                ipc_mean: f64::from_bits(f[3]),
                ipc_ci95: f64::from_bits(f[4]),
                latency_mean: f64::from_bits(f[5]),
                latency_ci95: f64::from_bits(f[6]),
            })),
            _ => None,
        }
    }

    fn decode_latency(v: &Val) -> Option<Option<LatencySummary>> {
        let f = u64_vec(v)?;
        match f.len() {
            0 => Some(None),
            7 => Some(Some(LatencySummary {
                p50: f[0],
                p95: f[1],
                p99: f[2],
                p999: f[3],
                mean: f64::from_bits(f[4]),
                max: f[5],
                samples: f[6],
            })),
            _ => None,
        }
    }

    pub fn decode_result(text: &str) -> Option<SimResult> {
        let root = parse_root(text)?;
        if root.field("version")?.u64()? != VERSION {
            return None;
        }
        // The mechanism label must map back onto the interned &'static str.
        let label = root.field("mechanism")?.str()?;
        let mechanism = MechanismKind::all().into_iter().find(|m| m.label() == label)?.label();
        let mc = root.field("mc")?.arr()?.iter().map(decode_mc).collect::<Option<Vec<_>>>()?;
        let e = f64_bits_vec(root.field("energy_bits")?)?;
        if e.len() != 5 {
            return None;
        }
        Some(SimResult {
            workload: root.field("workload")?.str()?.to_string(),
            mechanism,
            core_ipc: f64_bits_vec(root.field("core_ipc_bits")?)?,
            cpu_cycles: root.field("cpu_cycles")?.u64()?,
            mc,
            rltl: f64_bits_vec(root.field("rltl_bits")?)?,
            energy: EnergyBreakdown {
                act_pre_nj: e[0],
                read_nj: e[1],
                write_nj: e[2],
                refresh_nj: e[3],
                background_nj: e[4],
            },
            total_insts: root.field("total_insts")?.u64()?,
            llc_hits: root.field("llc_hits")?.u64()?,
            llc_misses: root.field("llc_misses")?.u64()?,
            sampled: decode_sampled(root.field("sampled")?)?,
            latency: decode_latency(root.field("latency")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::ExperimentScale;

    fn tiny_scale() -> ExperimentScale {
        ExperimentScale {
            insts_per_core: 2_000,
            warmup_cycles: 1_000,
            mixes: 1,
            ..ExperimentScale::default()
        }
    }

    fn tiny_single(mech: MechanismKind, w: usize) -> JobSpec {
        JobSpec::single(tiny_scale().single_cfg(), mech, w)
    }

    #[test]
    fn duplicate_submissions_share_one_simulation() {
        let mut g = JobGraph::new();
        let a = g.submit(tiny_single(MechanismKind::Baseline, 0));
        let b = g.submit(tiny_single(MechanismKind::Baseline, 0));
        let c = g.submit(tiny_single(MechanismKind::ChargeCache, 0));
        assert_eq!(g.unique_len(), 2);
        assert_eq!(g.submitted_len(), 3);

        let mut cache = SimCache::in_memory();
        let res = g.run(&mut cache);
        assert_eq!(cache.stats.submitted, 3);
        assert_eq!(cache.stats.deduped, 1);
        assert_eq!(cache.stats.simulated, 2);
        // Duplicates share the same Arc, and the distinct mechanism does not.
        assert!(std::ptr::eq(res.get(a), res.get(b)));
        assert!(!std::ptr::eq(res.get(a), res.get(c)));
    }

    #[test]
    fn second_graph_hits_in_process_cache() {
        let mut cache = SimCache::in_memory();
        let mut g1 = JobGraph::new();
        let t1 = g1.submit(tiny_single(MechanismKind::Baseline, 1));
        let r1 = g1.run(&mut cache);

        let mut g2 = JobGraph::new();
        let t2 = g2.submit(tiny_single(MechanismKind::Baseline, 1));
        let r2 = g2.run(&mut cache);

        assert_eq!(cache.stats.simulated, 1);
        assert_eq!(cache.stats.memory_hits, 1);
        assert_eq!(r1.get(t1), r2.get(t2));
    }

    #[test]
    fn run_all_bypasses_dedup_and_cache() {
        let mut cache = SimCache::in_memory();
        let mut g = JobGraph::new();
        let a = g.submit(tiny_single(MechanismKind::Baseline, 2));
        let b = g.submit(tiny_single(MechanismKind::Baseline, 2));
        let res = g.run_all(&mut cache);
        assert_eq!(cache.stats.simulated, 2);
        assert_eq!(cache.stats.deduped, 0);
        assert!(cache.is_empty(), "run_all must not populate the cache");
        // Independent simulations of the same spec are still bit-identical
        // (simulations are pure functions of the spec).
        assert_eq!(res.get(a), res.get(b));
        assert!(!std::ptr::eq(res.get(a), res.get(b)));
    }

    #[test]
    fn cost_orders_mixes_before_singles() {
        let scale = tiny_scale();
        let single = tiny_single(MechanismKind::Baseline, 0);
        let mix = JobSpec::mix(scale.eight_cfg(), MechanismKind::Baseline, 0);
        assert!(mix.cost() > single.cost(), "eight-core mixes must sort first");
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let mut g = JobGraph::new();
        let t = g.submit(tiny_single(MechanismKind::ChargeCache, 3));
        let mut cache = SimCache::in_memory();
        let res = g.run(&mut cache);
        let original = res.get(t);

        let text = super::diskjson::encode_result(original);
        let decoded = super::diskjson::decode_result(&text).expect("decodes");
        assert_eq!(&decoded, original);
        // Bit-exactness beyond PartialEq: every float's bit pattern.
        for (a, b) in original.core_ipc.iter().zip(&decoded.core_ipc) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in original.rltl.iter().zip(&decoded.rltl) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(original.energy.total_nj().to_bits(), decoded.energy.total_nj().to_bits());
    }

    #[test]
    fn corrupt_or_versioned_json_is_a_miss() {
        assert!(super::diskjson::decode_result("").is_none());
        assert!(super::diskjson::decode_result("{").is_none());
        assert!(super::diskjson::decode_result("{\"version\": 999}").is_none());
        assert!(super::diskjson::decode_result("[1,2,3]").is_none());
    }

    #[test]
    fn disk_cache_round_trips_across_engines() {
        let dir = std::env::temp_dir().join(format!("cc_simcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut first = SimCache::with_disk(&dir).unwrap();
        let mut g1 = JobGraph::new();
        let t1 = g1.submit(tiny_single(MechanismKind::Nuat, 4));
        let r1 = g1.run(&mut first);
        assert_eq!(first.stats.simulated, 1);

        // A fresh cache over the same directory serves the job from disk.
        let mut second = SimCache::with_disk(&dir).unwrap();
        let mut g2 = JobGraph::new();
        let t2 = g2.submit(tiny_single(MechanismKind::Nuat, 4));
        let r2 = g2.run(&mut second);
        assert_eq!(second.stats.simulated, 0);
        assert_eq!(second.stats.disk_hits, 1);
        assert_eq!(r1.get(t1), r2.get(t2), "disk round-trip must be bit-identical");

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forged_disk_entry_is_rejected_and_resimulated() {
        // The fingerprint in the file name only hashes the config, so a
        // file copied onto another key's path (or a stale cache from a
        // build with different PROFILES) must be rejected by the
        // workload check, not served as that key's result.
        let dir = std::env::temp_dir().join(format!("cc_forged_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let spec_a = tiny_single(MechanismKind::Baseline, 5);
        let spec_b = tiny_single(MechanismKind::Baseline, 6);
        let mut cache = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        g.submit(spec_a.clone());
        g.run(&mut cache);
        // Forge: present workload 5's result under workload 6's key.
        let pa = cache.disk_path(&spec_a.key()).unwrap();
        let pb = cache.disk_path(&spec_b.key()).unwrap();
        std::fs::copy(&pa, &pb).unwrap();

        let mut fresh = SimCache::with_disk(&dir).unwrap();
        let mut g2 = JobGraph::new();
        let t = g2.submit(spec_b);
        let res = g2.run(&mut fresh);
        assert_eq!(fresh.stats.disk_hits, 0, "forged entry must not hit");
        assert_eq!(fresh.stats.simulated, 1, "forged entry must re-simulate");
        assert_eq!(res.get(t).workload, PROFILES[6].name);

        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Legs differing only in a measure-phase knob, sharing one warmup.
    fn sweep_legs(n: u64) -> Vec<SystemConfig> {
        (0..n)
            .map(|k| {
                let mut c = tiny_scale().single_cfg();
                c.measure_cycles = Some(3_000 + 500 * k);
                c
            })
            .collect()
    }

    #[test]
    fn forked_legs_are_bit_identical_to_cold_runs() {
        let legs = sweep_legs(5);

        // Cold reference: forking disabled (a distinct fingerprint, so
        // nothing is shared with the forked pass below).
        let mut cold_cache = SimCache::in_memory();
        let mut g = JobGraph::new();
        let cold_tickets: Vec<_> = legs
            .iter()
            .map(|c| {
                let mut c = c.clone();
                c.checkpoint.warmup_fork = false;
                g.submit(JobSpec::single(c, MechanismKind::ChargeCache, 0))
            })
            .collect();
        let cold = g.run(&mut cold_cache);
        assert_eq!(cold_cache.stats.warmup_sims, 0);
        assert_eq!(cold_cache.stats.warmup_forks, 0);

        let mut cache = SimCache::in_memory();
        let mut g = JobGraph::new();
        let tickets: Vec<_> = legs
            .iter()
            .map(|c| g.submit(JobSpec::single(c.clone(), MechanismKind::ChargeCache, 0)))
            .collect();
        let res = g.run(&mut cache);
        assert_eq!(cache.stats.warmup_sims, 1, "one shared warmup simulated");
        assert_eq!(cache.stats.warmup_forks, 5, "every leg forked");
        assert_eq!(cache.stats.warmup_cycles_simulated, 1_000);
        assert_eq!(cache.stats.warmup_cycles_forked, 5_000);
        for (a, b) in cold_tickets.iter().zip(&tickets) {
            assert_eq!(cold.get(*a), res.get(*b), "fork must be bit-identical to cold");
        }
    }

    #[test]
    fn lone_legs_build_no_snapshot_but_reuse_cached_ones() {
        let legs = sweep_legs(3);
        let mut cache = SimCache::in_memory();

        // One leg alone: below min_fork_group, so no snapshot is built.
        let mut g = JobGraph::new();
        g.submit(JobSpec::single(legs[0].clone(), MechanismKind::Baseline, 0));
        g.run(&mut cache);
        assert_eq!(cache.stats.warmup_sims, 0);
        assert_eq!(cache.stats.warmup_forks, 0);

        // Two more legs form a group: the snapshot is built once...
        let mut g = JobGraph::new();
        g.submit(JobSpec::single(legs[1].clone(), MechanismKind::Baseline, 0));
        g.submit(JobSpec::single(legs[2].clone(), MechanismKind::Baseline, 0));
        g.run(&mut cache);
        assert_eq!(cache.stats.warmup_sims, 1);
        assert_eq!(cache.stats.warmup_forks, 2);

        // ...and a later lone leg forks from it for free.
        let mut lone = tiny_scale().single_cfg();
        lone.measure_cycles = Some(9_999);
        let mut g = JobGraph::new();
        g.submit(JobSpec::single(lone, MechanismKind::Baseline, 0));
        g.run(&mut cache);
        assert_eq!(cache.stats.warmup_sims, 1, "cached snapshot reused, not rebuilt");
        assert_eq!(cache.stats.warmup_forks, 3);
    }

    #[test]
    fn snapshots_persist_through_the_disk_cache() {
        let dir = std::env::temp_dir().join(format!("cc_ckptcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let legs = sweep_legs(2);

        let mut first = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        for c in &legs {
            g.submit(JobSpec::single(c.clone(), MechanismKind::Nuat, 1));
        }
        g.run(&mut first);
        assert_eq!(first.stats.warmup_sims, 1);

        // A fresh cache over the same directory forks a new leg straight
        // from the persisted snapshot — zero warmup simulated.
        let mut second = SimCache::with_disk(&dir).unwrap();
        let mut third = tiny_scale().single_cfg();
        third.measure_cycles = Some(7_777);
        let mut g = JobGraph::new();
        g.submit(JobSpec::single(third, MechanismKind::Nuat, 1));
        g.run(&mut second);
        assert_eq!(second.stats.warmup_sims, 0);
        assert_eq!(second.stats.warmup_forks, 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_falls_back_to_cold_run() {
        let dir = std::env::temp_dir().join(format!("cc_badckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let legs = sweep_legs(2);

        let mut first = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        for c in &legs {
            g.submit(JobSpec::single(c.clone(), MechanismKind::ChargeCache, 2));
        }
        let _ = g.run(&mut first);

        // Truncate the persisted snapshot: decode fails, so a fresh cache
        // re-simulates the warmup rather than serving garbage.
        let key = JobSpec::single(legs[0].clone(), MechanismKind::ChargeCache, 2).warmup_key();
        let path = first.snapshot_path(&key).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();

        let mut second = SimCache::with_disk(&dir).unwrap();
        let mut again = tiny_scale().single_cfg();
        again.measure_cycles = Some(8_888);
        let mut g = JobGraph::new();
        let t = g.submit(JobSpec::single(again.clone(), MechanismKind::ChargeCache, 2));
        let res = g.run(&mut second);
        assert_eq!(second.stats.warmup_forks, 0, "corrupt snapshot must not fork");
        // The result is still correct: identical to a cold simulation.
        let mut cold_cache = SimCache::in_memory();
        let mut cold = again;
        cold.checkpoint.warmup_fork = false;
        let mut g = JobGraph::new();
        let tc = g.submit(JobSpec::single(cold, MechanismKind::ChargeCache, 2));
        let cold_res = g.run(&mut cold_cache);
        assert_eq!(res.get(t), cold_res.get(tc));

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_isolated_retries_then_succeeds_or_reports() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = AtomicU32::new(0);
        let (res, retries) = run_isolated(|| {
            if n.fetch_add(1, Ordering::SeqCst) < 2 {
                panic!("flaky");
            }
            42
        });
        assert_eq!(res.unwrap(), 42);
        assert_eq!(retries, 2);

        let (res, retries) = run_isolated(|| -> u32 { panic!("always broken") });
        assert_eq!(res.unwrap_err(), "always broken");
        assert_eq!(retries, JOB_RETRIES as u64);
    }

    #[test]
    fn corrupt_result_entry_is_quarantined_not_a_permanent_miss() {
        let dir = std::env::temp_dir().join(format!("cc_quarantine_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let spec = tiny_single(MechanismKind::Baseline, 3);
        let mut cache = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        g.submit(spec.clone());
        g.run(&mut cache);
        let path = cache.disk_path(&spec.key()).unwrap();
        std::fs::write(&path, "{\"version\": 4, \"wor").unwrap();

        let mut fresh = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        let t = g.submit(spec.clone());
        let res = g.run(&mut fresh);
        assert_eq!(fresh.stats.disk_hits, 0);
        assert_eq!(fresh.stats.quarantined, 1, "corrupt entry must be quarantined");
        assert_eq!(fresh.stats.simulated, 1, "and the job re-simulated");
        assert_eq!(res.get(t).workload, PROFILES[3].name);
        // The corrupt bytes were preserved aside and a fresh entry
        // published in place, so the next engine hits clean.
        let mut bad = path.as_os_str().to_os_string();
        bad.push(".bad");
        assert!(std::path::PathBuf::from(bad).exists());
        let mut third = SimCache::with_disk(&dir).unwrap();
        let mut g = JobGraph::new();
        g.submit(spec);
        g.run(&mut third);
        assert_eq!(third.stats.disk_hits, 1);
        assert_eq!(third.stats.quarantined, 0);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_appends_fault_clauses_only_when_present() {
        let mut s = CacheStats::default();
        assert!(!s.summary().contains("faults:"));
        assert!(!s.summary().contains("quarantined"));
        s.retries = 3;
        s.failed = 1;
        s.quarantined = 2;
        let line = s.summary();
        assert!(line.starts_with("job graph: "), "clauses stay on the stable line");
        assert!(line.contains("; 2 quarantined"));
        assert!(line.ends_with("; faults: 3 retried, 1 failed"));
    }

    #[test]
    fn distinct_configs_do_not_alias() {
        let mut g = JobGraph::new();
        let mut hot = tiny_scale().single_cfg();
        hot.temperature_c = 45.0;
        g.submit(tiny_single(MechanismKind::Baseline, 0));
        g.submit(JobSpec::single(hot, MechanismKind::Baseline, 0));
        assert_eq!(g.unique_len(), 2, "config differences must split jobs");
    }
}
