//! The paper's experiments, each mapped to a function that produces the
//! rows of the corresponding figure/table (DESIGN.md §5 index).
//!
//! Every experiment executes through the fingerprint-keyed job graph
//! ([`super::jobs`]): it submits [`JobSpec`]s, and the graph dedupes,
//! serves repeats from the [`JobEngine`]'s cache, and fans the unique
//! legs out through one cost-ordered `parallel_map` call. The `*_with`
//! variants share a caller-provided engine (the `figures` command runs
//! fig1 + both suites + the sweeps over one engine, so overlapping legs
//! simulate exactly once); the plain-named wrappers keep the historical
//! signatures with a private per-call engine.

use std::collections::HashMap;

use crate::analysis::rltl::RLTL_INTERVALS_MS;
use crate::config::SystemConfig;
use crate::controller::SchedulerKind;
use crate::latency::MechanismKind;
use crate::sim::engine::LoopMode;
use crate::sim::stats::weighted_speedup;
use crate::sim::SimResult;
use crate::trace::{profile::multicore_mix, PROFILES};

use super::jobs::{JobEngine, JobGraph, JobSpec};

/// Simulation horizon knobs (the paper runs 1 B instructions; we scale
/// down — RLTL/RMPKC are stationary properties of the generators).
#[derive(Debug, Clone, Copy)]
pub struct ExperimentScale {
    /// Instructions per core in the measured region.
    pub insts_per_core: u64,
    /// Warmup CPU cycles.
    pub warmup_cycles: u64,
    /// Number of eight-core mixes (paper: 20).
    pub mixes: usize,
    /// Loop kernel for every simulation in the suite: the event-driven
    /// engine by default; `--strict-tick` selects the per-cycle oracle.
    pub loop_mode: LoopMode,
    /// Memory-scheduler policy for every controller in the suite
    /// (`--scheduler`).
    pub scheduler: SchedulerKind,
    /// Registry overrides (`--set path=value`) applied to every leg's
    /// config, after presets and the scale fields above (last wins).
    /// Interned (`&'static`) so the scale stays `Copy`: the CLI parses
    /// argv once per process and leaks one small allocation — see
    /// [`ExperimentScale::with_overrides`].
    pub overrides: &'static [(String, String)],
}

impl Default for ExperimentScale {
    fn default() -> Self {
        Self {
            insts_per_core: 500_000,
            warmup_cycles: 250_000,
            mixes: 20,
            loop_mode: LoopMode::EventDriven,
            scheduler: SchedulerKind::FrFcfs,
            overrides: &[],
        }
    }
}

impl ExperimentScale {
    pub fn quick() -> Self {
        Self { insts_per_core: 60_000, warmup_cycles: 30_000, mixes: 4, ..Self::default() }
    }

    /// Validate `sets` against the parameter registry and intern them
    /// into this scale. Every leg config this scale builds applies them
    /// last, so `--set` reaches suite legs, sweeps, and scenarios alike.
    /// Leaks one small allocation per call; callers are CLI scale
    /// construction (a handful of calls per invocation — `figures`
    /// rebuilds its scale per sub-figure) and tests, never per-job
    /// paths, so the total leak stays a few hundred bytes per process.
    pub fn with_overrides(
        mut self,
        sets: Vec<(String, String)>,
    ) -> crate::error::Result<Self> {
        if sets.is_empty() {
            return Ok(self);
        }
        for (path, _) in &sets {
            // The simulator reads the mechanism from JobSpec.mechanism,
            // not the config; overriding the (fingerprint-hashed) config
            // field here would only fork every leg's fingerprint away
            // from cache-mates while simulating nothing different.
            crate::ensure!(
                path != "mechanism",
                "--set mechanism= has no effect on suite legs; pick mechanisms with \
                 --mechanism (run) or a scenario \"mechanisms\" list"
            );
        }
        // Dry-run once: value parsing is config-independent, so a set
        // that applies cleanly here applies to every leg.
        let mut probe = SystemConfig::default();
        crate::config::schema::registry().apply(&mut probe, &sets)?;
        self.overrides = Box::leak(sets.into_boxed_slice());
        Ok(self)
    }

    fn apply_overrides(&self, cfg: &mut SystemConfig) {
        if self.overrides.is_empty() {
            return;
        }
        crate::config::schema::registry()
            .apply(cfg, self.overrides)
            .expect("overrides were validated by with_overrides");
    }

    /// Config for an `n`-core run at this scale: preset, horizon knobs,
    /// the fixed-time window for multiprogrammed runs, then `--set`
    /// overrides (which therefore win over everything scale-derived).
    pub fn multi_cfg(&self, cores: usize) -> SystemConfig {
        let mut cfg = SystemConfig::multi_core(cores);
        cfg.insts_per_core = self.insts_per_core;
        cfg.warmup_cpu_cycles = self.warmup_cycles;
        cfg.loop_mode = self.loop_mode;
        cfg.mc.scheduler = self.scheduler;
        if cores > 1 {
            // Multiprogrammed runs measure over a fixed time window (see
            // SystemConfig::measure_cycles): ~10 cycles per target
            // instruction gives every core a deep window at typical
            // shared-system IPCs.
            cfg.measure_cycles = Some(self.insts_per_core * 10);
        }
        self.apply_overrides(&mut cfg);
        cfg
    }

    pub fn single_cfg(&self) -> SystemConfig {
        self.multi_cfg(1)
    }

    pub fn eight_cfg(&self) -> SystemConfig {
        self.multi_cfg(8)
    }
}

/// One row of Fig. 4: per-mechanism speedup over baseline.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub workload: String,
    pub rmpkc: f64,
    /// (mechanism label, speedup, reduced-activation fraction).
    pub speedups: Vec<(&'static str, f64, f64)>,
}

/// Results of the full evaluation suite (single + eight core, all
/// mechanisms). Fig. 4 and Fig. 5 are both views over this.
pub struct SuiteResults {
    /// (workload, mechanism) -> result, single-core.
    pub single: HashMap<(String, &'static str), SimResult>,
    /// (mix, mechanism) -> result, eight-core.
    pub eight: HashMap<(usize, &'static str), SimResult>,
    /// Per-profile alone IPC (single-core baseline), for weighted speedup.
    pub alone_ipc: HashMap<String, f64>,
    pub scale: ExperimentScale,
}

const MECHS: [MechanismKind; 5] = [
    MechanismKind::Baseline,
    MechanismKind::ChargeCache,
    MechanismKind::Nuat,
    MechanismKind::ChargeCacheNuat,
    MechanismKind::LlDram,
];

/// Submit every single-core (workload x mechanism) leg into `graph`;
/// returns the tickets alongside their identifying pair.
fn submit_singles(
    scale: ExperimentScale,
    graph: &mut JobGraph,
) -> Vec<((usize, MechanismKind), super::jobs::JobTicket)> {
    (0..PROFILES.len())
        .flat_map(|w| MECHS.iter().map(move |&m| (w, m)))
        .map(|(w, m)| ((w, m), graph.submit(JobSpec::single(scale.single_cfg(), m, w))))
        .collect()
}

/// Submit every eight-core (mix x mechanism) leg into `graph`.
fn submit_eights(
    scale: ExperimentScale,
    graph: &mut JobGraph,
) -> Vec<((usize, MechanismKind), super::jobs::JobTicket)> {
    (0..scale.mixes)
        .flat_map(|mix| MECHS.iter().map(move |&m| (mix, m)))
        .map(|(mix, m)| ((mix, m), graph.submit(JobSpec::mix(scale.eight_cfg(), m, mix))))
        .collect()
}

/// Run every single-core (workload x mechanism) combination through the
/// shared engine's job graph.
pub fn run_single_suite_with(
    scale: ExperimentScale,
    eng: &mut JobEngine,
) -> HashMap<(String, &'static str), SimResult> {
    let mut graph = JobGraph::new();
    let tickets = submit_singles(scale, &mut graph);
    let res = eng.run(graph);
    tickets
        .into_iter()
        .map(|((w, m), t)| ((PROFILES[w].name.to_string(), m.label()), res.get(t).clone()))
        .collect()
}

/// Run every single-core (workload x mechanism) combination in parallel.
pub fn run_single_suite(scale: ExperimentScale) -> HashMap<(String, &'static str), SimResult> {
    run_single_suite_with(scale, &mut JobEngine::new())
}

/// Run every eight-core (mix x mechanism) combination through the shared
/// engine's job graph.
pub fn run_eight_suite_with(
    scale: ExperimentScale,
    eng: &mut JobEngine,
) -> HashMap<(usize, &'static str), SimResult> {
    let mut graph = JobGraph::new();
    let tickets = submit_eights(scale, &mut graph);
    let res = eng.run(graph);
    tickets.into_iter().map(|((mix, m), t)| ((mix, m.label()), res.get(t).clone())).collect()
}

/// Run every eight-core (mix x mechanism) combination in parallel.
pub fn run_eight_suite(scale: ExperimentScale) -> HashMap<(usize, &'static str), SimResult> {
    run_eight_suite_with(scale, &mut JobEngine::new())
}

/// Full suite (single + eight core + alone-IPC table), sharing `eng`'s
/// cache. Single- and eight-core legs go into **one** graph, so the
/// whole suite is a single cost-ordered `parallel_map` fan-out with the
/// eight-core mixes dispatched first.
pub fn run_suite_with(scale: ExperimentScale, eight: bool, eng: &mut JobEngine) -> SuiteResults {
    let mut graph = JobGraph::new();
    let single_tickets = submit_singles(scale, &mut graph);
    let eight_tickets = if eight { submit_eights(scale, &mut graph) } else { Vec::new() };
    let res = eng.run(graph);
    let single: HashMap<(String, &'static str), SimResult> = single_tickets
        .into_iter()
        .map(|((w, m), t)| ((PROFILES[w].name.to_string(), m.label()), res.get(t).clone()))
        .collect();
    let alone_ipc = single
        .iter()
        .filter(|((_, m), _)| *m == MechanismKind::Baseline.label())
        .map(|((w, _), r)| (w.clone(), r.ipc()))
        .collect();
    let eight_map = eight_tickets
        .into_iter()
        .map(|((mix, m), t)| ((mix, m.label()), res.get(t).clone()))
        .collect();
    SuiteResults { single, eight: eight_map, alone_ipc, scale }
}

/// Full suite (single + eight core + alone-IPC table).
pub fn run_suite(scale: ExperimentScale, eight: bool) -> SuiteResults {
    run_suite_with(scale, eight, &mut JobEngine::new())
}

impl SuiteResults {
    /// Fig. 4a rows, sorted ascending by baseline RMPKC (paper's x-axis).
    pub fn fig4a(&self) -> Vec<Fig4Row> {
        let mut rows: Vec<Fig4Row> = PROFILES
            .iter()
            .map(|p| {
                let base = &self.single[&(p.name.to_string(), "Baseline")];
                let speedups = MECHS[1..]
                    .iter()
                    .map(|m| {
                        let r = &self.single[&(p.name.to_string(), m.label())];
                        (m.label(), r.ipc() / base.ipc(), r.reduced_act_fraction())
                    })
                    .collect();
                Fig4Row {
                    workload: p.name.to_string(),
                    rmpkc: base.rmpkc(),
                    speedups,
                }
            })
            .collect();
        rows.sort_by(|a, b| a.rmpkc.partial_cmp(&b.rmpkc).unwrap());
        rows
    }

    /// Fig. 4b rows per mix: weighted-speedup ratio vs baseline.
    pub fn fig4b(&self) -> Vec<Fig4Row> {
        let mut rows = Vec::new();
        for mix in 0..self.scale.mixes {
            let profiles = multicore_mix(mix, 8);
            let alone: Vec<f64> = profiles
                .iter()
                .map(|p| self.alone_ipc[&p.name.to_string()])
                .collect();
            let base = &self.eight[&(mix, "Baseline")];
            let ws_base = weighted_speedup(&base.core_ipc, &alone);
            let speedups = MECHS[1..]
                .iter()
                .map(|m| {
                    let r = &self.eight[&(mix, m.label())];
                    (
                        m.label(),
                        weighted_speedup(&r.core_ipc, &alone) / ws_base,
                        r.reduced_act_fraction(),
                    )
                })
                .collect();
            rows.push(Fig4Row {
                workload: format!("mix{mix:02}"),
                rmpkc: base.rmpkc(),
                speedups,
            });
        }
        rows.sort_by(|a, b| a.rmpkc.partial_cmp(&b.rmpkc).unwrap());
        rows
    }

    /// Fig. 5: DRAM energy reduction vs baseline: (workload, mech, frac).
    pub fn fig5(&self, eight: bool) -> Vec<(String, Vec<(&'static str, f64)>)> {
        let mut out = Vec::new();
        if eight {
            for mix in 0..self.scale.mixes {
                let base = self.eight[&(mix, "Baseline")].energy_per_inst();
                let rows = MECHS[1..]
                    .iter()
                    .map(|m| {
                        let e = self.eight[&(mix, m.label())].energy_per_inst();
                        (m.label(), 1.0 - e / base)
                    })
                    .collect();
                out.push((format!("mix{mix:02}"), rows));
            }
        } else {
            for p in PROFILES.iter() {
                let base = self.single[&(p.name.to_string(), "Baseline")].energy_per_inst();
                let rows = MECHS[1..]
                    .iter()
                    .map(|m| {
                        let e = self.single[&(p.name.to_string(), m.label())].energy_per_inst();
                        (m.label(), 1.0 - e / base)
                    })
                    .collect();
                out.push((p.name.to_string(), rows));
            }
        }
        out
    }
}

/// Fig. 1 through a shared engine: average t-RLTL over the tracked
/// intervals. The baseline legs here are structurally identical to the
/// suite's Baseline legs, so under one engine (`figures`) they simulate
/// zero extra jobs.
pub fn fig1_with(scale: ExperimentScale, eng: &mut JobEngine) -> Vec<(f64, f64, f64)> {
    let mut graph = JobGraph::new();
    // Single-core: baseline runs of all 22 workloads.
    let singles: Vec<_> = (0..PROFILES.len())
        .map(|w| graph.submit(JobSpec::single(scale.single_cfg(), MechanismKind::Baseline, w)))
        .collect();
    let eights: Vec<_> = (0..scale.mixes)
        .map(|m| graph.submit(JobSpec::mix(scale.eight_cfg(), MechanismKind::Baseline, m)))
        .collect();
    let res = eng.run(graph);
    let single: Vec<&SimResult> = singles.iter().map(|&t| res.get(t)).collect();
    let eight: Vec<&SimResult> = eights.iter().map(|&t| res.get(t)).collect();
    let avg = |rs: &[&SimResult], i: usize| -> f64 {
        // Activation-weighted mean across workloads (matches the paper's
        // aggregate counting).
        let acts: u64 = rs.iter().map(|r| r.acts()).sum();
        if acts == 0 {
            return 0.0;
        }
        rs.iter().map(|r| r.rltl[i] * r.acts() as f64).sum::<f64>() / acts as f64
    };
    RLTL_INTERVALS_MS
        .iter()
        .enumerate()
        .map(|(i, &ms)| (ms, avg(&single, i), avg(&eight, i)))
        .collect()
}

/// Fig. 1: average t-RLTL over the tracked intervals.
/// Returns (interval_ms, avg_single, avg_eight).
pub fn fig1(scale: ExperimentScale) -> Vec<(f64, f64, f64)> {
    fig1_with(scale, &mut JobEngine::new())
}

/// Sensitivity: ChargeCache capacity sweep (entries per core).
///
/// The three `sweep_*` functions below are the **legacy reference
/// implementations** of the sweeps: the CLI now runs them as declarative
/// scenario specs (`examples/scenarios/sweep_*.json` through
/// [`super::scenario`]), and `tests/scenario.rs` pins the scenario path
/// bit-identical to these. They stay as the differential oracle (and as
/// the bench entry points in `benches/sweeps.rs`); new sweeps should be
/// scenario specs, not new functions here.
pub fn sweep_capacity(scale: ExperimentScale, entries: &[usize]) -> Vec<(usize, f64)> {
    sweep_capacity_with(scale, entries, &mut JobEngine::new())
}

pub fn sweep_capacity_with(
    scale: ExperimentScale,
    entries: &[usize],
    eng: &mut JobEngine,
) -> Vec<(usize, f64)> {
    sweep_eight(scale, entries, |cfg, &e| cfg.chargecache.entries_per_core = e, eng)
}

/// Sensitivity: caching duration sweep. The legal tRCD/tRAS reduction at
/// each duration comes from the circuit layer (timing table) — longer
/// durations keep rows cached longer but must assume more leakage.
pub fn sweep_duration(scale: ExperimentScale, durations_ms: &[f64]) -> Vec<(f64, f64)> {
    sweep_duration_with(scale, durations_ms, &mut JobEngine::new())
}

pub fn sweep_duration_with(
    scale: ExperimentScale,
    durations_ms: &[f64],
    eng: &mut JobEngine,
) -> Vec<(f64, f64)> {
    let (table, _) = crate::runtime::charge_model::timing_table_or_analytic(85.0, 1.25);
    sweep_eight(
        scale,
        durations_ms,
        |cfg, &d| {
            let (rcd, ras) = table.reduction_cycles(d * 1e-3);
            cfg.chargecache.duration_ms = d;
            cfg.chargecache.trcd_reduction = rcd.min(cfg.timing.trcd - 2);
            cfg.chargecache.tras_reduction = ras.min(cfg.timing.tras - 2);
        },
        eng,
    )
}

/// Sensitivity: temperature sweep at fixed 1 ms duration (paper Sec. 8.3:
/// ChargeCache works even at worst-case temperature). The timing table
/// is derived once per temperature *before* submission (under `pjrt` it
/// executes the AOT artifact — startup-class work that must not repeat
/// per job); jobs only copy the precomputed reduction cycles.
pub fn sweep_temperature(scale: ExperimentScale, temps_c: &[f64]) -> Vec<(f64, f64)> {
    sweep_temperature_with(scale, temps_c, &mut JobEngine::new())
}

pub fn sweep_temperature_with(
    scale: ExperimentScale,
    temps_c: &[f64],
    eng: &mut JobEngine,
) -> Vec<(f64, f64)> {
    let points: Vec<(f64, u64, u64)> = temps_c
        .iter()
        .map(|&t| {
            let (table, _) = crate::runtime::charge_model::timing_table_or_analytic(t, 1.25);
            let (rcd, ras) = table.reduction_cycles(1e-3);
            (t, rcd, ras)
        })
        .collect();
    sweep_eight(
        scale,
        &points,
        |cfg, &(temp, rcd, ras)| {
            cfg.temperature_c = temp;
            cfg.chargecache.trcd_reduction = rcd.min(cfg.timing.trcd - 2);
            cfg.chargecache.tras_reduction = ras.min(cfg.timing.tras - 2);
        },
        eng,
    )
    .into_iter()
    .map(|((t, _, _), speedup)| (t, speedup))
    .collect()
}

/// Shared sweep machinery: average eight-core CC speedup per point,
/// through the job graph.
///
/// The pre-graph code hand-deduped the Baseline legs (one per mix,
/// shared across sweep points, since no sweep here perturbs state a
/// Baseline reads); the graph now subsumes that: Baselines are submitted
/// once per mix, and any sweep point whose applied config collapses onto
/// another leg's fingerprint (e.g. the capacity sweep's 128-entry point,
/// which *is* the default config the suite already ran) dedupes
/// automatically — including against legs a previous experiment on the
/// same engine simulated. All unique legs still fan out through a single
/// cost-ordered `parallel_map` call.
fn sweep_eight<P: Copy>(
    scale: ExperimentScale,
    points: &[P],
    apply: impl Fn(&mut SystemConfig, &P),
    eng: &mut JobEngine,
) -> Vec<(P, f64)> {
    let mixes = scale.mixes;
    let mut graph = JobGraph::new();
    let base: Vec<_> = (0..mixes)
        .map(|m| graph.submit(JobSpec::mix(scale.eight_cfg(), MechanismKind::Baseline, m)))
        .collect();
    let cc: Vec<Vec<_>> = points
        .iter()
        .map(|p| {
            (0..mixes)
                .map(|m| {
                    let mut cfg = scale.eight_cfg();
                    apply(&mut cfg, p);
                    graph.submit(JobSpec::mix(cfg, MechanismKind::ChargeCache, m))
                })
                .collect()
        })
        .collect();
    let res = eng.run(graph);
    points
        .iter()
        .enumerate()
        .map(|(p, &point)| {
            let mut sum = 0.0;
            for mix in 0..mixes {
                // Sum of per-core IPCs over same alone-set cancels into
                // throughput ratio; adequate for sweep *trends*.
                let tb: f64 = res.get(base[mix]).core_ipc.iter().sum();
                let tc: f64 = res.get(cc[p][mix]).core_ipc.iter().sum();
                sum += tc / tb;
            }
            (point, sum / mixes as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scale_configs() {
        let s = ExperimentScale::quick();
        assert_eq!(s.single_cfg().cpu.cores, 1);
        assert_eq!(s.eight_cfg().cpu.cores, 8);
        assert_eq!(s.eight_cfg().dram.channels, 2);
    }

    #[test]
    fn sweep_shares_one_baseline_per_mix() {
        // Structural check of the deduped sweep: one baseline per mix,
        // shared across points, still yields one (point, speedup) row per
        // sweep point with a sane ratio.
        let scale = ExperimentScale {
            insts_per_core: 4_000,
            warmup_cycles: 2_000,
            mixes: 2,
            ..ExperimentScale::default()
        };
        let rows = sweep_capacity(scale, &[64, 128]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 64);
        assert_eq!(rows[1].0, 128);
        for (entries, speedup) in &rows {
            assert!(
                *speedup > 0.5 && *speedup < 2.0,
                "implausible speedup {speedup} at {entries} entries"
            );
        }
    }

    #[test]
    fn temperature_sweep_is_one_flat_job_set() {
        let scale = ExperimentScale {
            insts_per_core: 3_000,
            warmup_cycles: 1_500,
            mixes: 1,
            ..ExperimentScale::default()
        };
        let rows = sweep_temperature(scale, &[45.0, 85.0]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 45.0);
        assert_eq!(rows[1].0, 85.0);
    }

    #[test]
    fn mini_suite_has_sane_shape() {
        // Tiny horizon: structural test, not a results test.
        let scale = ExperimentScale {
            insts_per_core: 5_000,
            warmup_cycles: 2_000,
            mixes: 1,
            ..ExperimentScale::default()
        };
        let suite = run_suite(scale, false);
        assert_eq!(suite.single.len(), PROFILES.len() * 5);
        let rows = suite.fig4a();
        assert_eq!(rows.len(), PROFILES.len());
        // Sorted by RMPKC.
        for w in rows.windows(2) {
            assert!(w[0].rmpkc <= w[1].rmpkc);
        }
        // All four non-baseline mechanisms present per row.
        assert_eq!(rows[0].speedups.len(), 4);
    }
}
