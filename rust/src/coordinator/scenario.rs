//! Declarative scenario API (DESIGN.md §10).
//!
//! A **scenario spec** is a small hand-rolled-JSON document (same
//! zero-dep parsing infra as the result cache — [`super::json`])
//! describing an experiment grid declaratively:
//!
//! * a **base** machine preset (`single` / `eight` / a core count),
//! * config **overrides** applied through the typed parameter registry
//!   ([`crate::config::schema`]),
//! * the **mechanisms** to measure against Baseline,
//! * the **workloads** (single-core profiles or multiprogrammed mixes),
//! * zero or more **sweep axes** — each a registry path plus an explicit
//!   value list or a linear/log range, optionally with a named
//!   derivation rule for the computations the legacy sweeps performed
//!   imperatively (circuit-layer tRCD/tRAS reductions).
//!
//! Expansion produces the cartesian product of the axes and submits one
//! [`JobSpec`] per (point × mechanism × workload) through a shared
//! [`JobEngine`], so legs shared across scenarios (every axis's
//! Baseline, overlapping sweep points, suite legs from earlier commands
//! in the same invocation) deduplicate automatically and
//! `--result-cache` persists them. `tests/scenario.rs` pins the three
//! legacy sweeps (`capacity`, `duration`, `temperature`) bit-identical
//! to their checked-in spec files in `examples/scenarios/`.

use std::collections::HashMap;

use crate::config::schema;
use crate::config::SystemConfig;
use crate::error::{Context, Result, SimError};
use crate::latency::{MechanismKind, TimingTable};
use crate::sim::latency_hist::LatencySummary;
use crate::runtime::charge_model::timing_table_or_analytic;
use crate::trace::PROFILES;
use crate::{bail, ensure};

use super::experiments::ExperimentScale;
use super::jobs::{JobEngine, JobGraph, JobSpec, JobTicket, WorkloadId};
use super::json::{parse_root_at, Val};

/// Base machine preset a scenario starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BasePreset {
    /// Table 1 single-core system (1 channel, open-row).
    Single,
    /// Table 1 eight-core system (2 channels, closed-row, fixed-time).
    Eight,
    /// `multi_core(n)` preset.
    Cores(usize),
}

impl BasePreset {
    pub fn cores(&self) -> usize {
        match self {
            BasePreset::Single => 1,
            BasePreset::Eight => 8,
            BasePreset::Cores(n) => *n,
        }
    }
}

/// Which simulations a scenario measures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkloadSel {
    /// Single-core workloads by [`PROFILES`] index.
    Singles(Vec<usize>),
    /// Multiprogrammed mixes `0..n` (`None` = the scale's mix count).
    Mixes(Option<usize>),
}

/// Where the Baseline (speedup-denominator) legs run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineMode {
    /// One Baseline per workload at the **base** config, shared across
    /// all sweep points — the legacy sweep semantics. Correct whenever
    /// no axis perturbs state a Baseline simulation reads (the
    /// ChargeCache parameter sweeps); wrong for e.g. a scheduler axis.
    Shared,
    /// Baseline re-runs at every sweep point. Always correct; costs one
    /// extra leg per (point × workload), which the job graph dedupes
    /// whenever a point's config collapses onto the base.
    PerPoint,
}

/// Named derivation applied after an axis value is set — the imperative
/// computations of the legacy sweeps, made declarative by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeriveRule {
    /// ChargeCache tRCD/tRAS reductions from the circuit timing table at
    /// the config's temperature, for the config's caching duration
    /// (clamped to `timing - 2`). The legacy duration sweep.
    CcTimingFromDuration,
    /// Identical derivation, named for a temperature axis (the legacy
    /// temperature sweep at the paper's default 1 ms duration).
    CcTimingFromTemperature,
    /// Marks the **offered-load axis** of a tail-latency study (an axis
    /// over `traffic.rate_rps` in open-loop mode). Derives nothing — the
    /// registry applies the rate directly — but rows along this axis
    /// carry latency percentiles and the run reports each mechanism's
    /// saturation knee ([`knee_load`]).
    LatencyVsLoad,
}

impl DeriveRule {
    pub fn parse(s: &str) -> Option<DeriveRule> {
        match s {
            "cc-timing-from-duration" => Some(DeriveRule::CcTimingFromDuration),
            "cc-timing-from-temperature" => Some(DeriveRule::CcTimingFromTemperature),
            "latency-vs-load" => Some(DeriveRule::LatencyVsLoad),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeriveRule::CcTimingFromDuration => "cc-timing-from-duration",
            DeriveRule::CcTimingFromTemperature => "cc-timing-from-temperature",
            DeriveRule::LatencyVsLoad => "latency-vs-load",
        }
    }
}

/// One sweep axis: a registry path plus the values it takes (raw spec
/// tokens, applied through the registry so enum axes work too).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AxisSpec {
    pub param: String,
    pub values: Vec<String>,
    pub derive: Option<DeriveRule>,
}

/// A parsed scenario spec (see the module docs for the grammar).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub base: BasePreset,
    /// Registry overrides applied to the base config, in spec order.
    pub set: Vec<(String, String)>,
    /// Mechanisms measured against Baseline (Baseline itself is the
    /// implicit denominator and may not be listed).
    pub mechanisms: Vec<MechanismKind>,
    pub workloads: WorkloadSel,
    pub baseline: BaselineMode,
    pub axes: Vec<AxisSpec>,
    /// Optional horizon pins (CLI flags override, scale fills the rest).
    pub insts_per_core: Option<u64>,
    pub warmup_cycles: Option<u64>,
}

const SPEC_KEYS: &[&str] = &[
    "name",
    "description",
    "base",
    "set",
    "mechanisms",
    "workloads",
    "mixes",
    "baseline",
    "axes",
    "insts_per_core",
    "warmup_cycles",
];

/// Strict object-key validation: every key must be known, and no key may
/// repeat (`Val::field` returns the first occurrence, so a duplicate
/// would silently shadow the rest of the document).
fn check_keys(obj: &[(String, Val)], allowed: &[&str], what: &str) -> Result<()> {
    let mut seen = std::collections::HashSet::new();
    for (k, _) in obj {
        ensure!(
            allowed.contains(&k.as_str()),
            "{what}: unknown key {k:?} (expected one of: {})",
            allowed.join(", ")
        );
        ensure!(seen.insert(k.as_str()), "{what}: duplicate key {k:?}");
    }
    Ok(())
}

/// A JSON scalar as the string token the registry consumes: numbers keep
/// their raw source text, strings their contents.
fn value_token(v: &Val) -> Option<String> {
    match v {
        Val::Num(s) => Some(s.clone()),
        Val::Str(s) => Some(s.clone()),
        _ => None,
    }
}

impl ScenarioSpec {
    /// Parse a spec document. Syntax and vocabulary (keys, mechanism and
    /// workload names, derive rules) are checked here; registry paths
    /// and value types are checked in [`ScenarioSpec::expand`].
    pub fn parse(text: &str) -> Result<Self> {
        Self::parse_named(text, "scenario spec")
    }

    /// [`ScenarioSpec::parse`] for a spec read from `file`: malformed
    /// JSON — truncated download, stray comma — reports the file and the
    /// byte offset the parser stopped at ([`SimError::ParseAt`]).
    pub fn parse_named(text: &str, file: &str) -> Result<Self> {
        let root = parse_root_at(text).map_err(|offset| SimError::ParseAt {
            file: file.to_string(),
            offset,
            msg: "malformed JSON".to_string(),
        })?;
        let obj = root.entries().context("scenario spec: top level must be a JSON object")?;
        check_keys(obj, SPEC_KEYS, "scenario spec")?;

        let name = root
            .field("name")
            .and_then(Val::str)
            .context("scenario spec: missing \"name\"")?
            .to_string();
        let description =
            root.field("description").and_then(Val::str).unwrap_or_default().to_string();

        let base = match root.field("base") {
            None => BasePreset::Eight,
            Some(v) => match (v.str(), v.u64()) {
                (Some("single"), _) => BasePreset::Single,
                (Some("eight"), _) => BasePreset::Eight,
                (_, Some(n)) if n >= 1 => BasePreset::Cores(n as usize),
                _ => bail!(
                    "scenario {name}: \"base\" must be \"single\", \"eight\", or a core count"
                ),
            },
        };

        let mut set = Vec::new();
        if let Some(v) = root.field("set") {
            let entries = v.entries().with_context(|| {
                format!("scenario {name}: \"set\" must be an object of path: value")
            })?;
            for (path, val) in entries {
                let token = value_token(val).with_context(|| {
                    format!("scenario {name}: set.{path} must be a number or string")
                })?;
                set.push((path.clone(), token));
            }
        }

        let mechanisms = match root.field("mechanisms") {
            None => vec![MechanismKind::ChargeCache],
            Some(v) => {
                let items = v.arr().with_context(|| {
                    format!("scenario {name}: \"mechanisms\" must be an array of names")
                })?;
                let mut mechs = Vec::new();
                for item in items {
                    let s = item.str().with_context(|| {
                        format!("scenario {name}: mechanism entries must be strings")
                    })?;
                    let m = MechanismKind::parse(s).with_context(|| {
                        format!(
                            "scenario {name}: unknown mechanism {s:?} (one of: {})",
                            MechanismKind::valid_names()
                        )
                    })?;
                    ensure!(
                        m != MechanismKind::Baseline,
                        "scenario {name}: Baseline is the implicit speedup denominator and \
                         may not be listed in \"mechanisms\""
                    );
                    mechs.push(m);
                }
                ensure!(!mechs.is_empty(), "scenario {name}: \"mechanisms\" is empty");
                mechs
            }
        };

        ensure!(
            root.field("workloads").is_none() || root.field("mixes").is_none(),
            "scenario {name}: \"workloads\" (single-core) and \"mixes\" (multi-core) are \
             mutually exclusive"
        );
        let workloads = if let Some(v) = root.field("workloads") {
            if v.str() == Some("all") {
                WorkloadSel::Singles((0..PROFILES.len()).collect())
            } else {
                let items = v.arr().with_context(|| {
                    format!("scenario {name}: \"workloads\" must be \"all\" or an array of names")
                })?;
                let mut idx = Vec::new();
                for item in items {
                    let s = item.str().with_context(|| {
                        format!("scenario {name}: workload entries must be strings")
                    })?;
                    let w = PROFILES.iter().position(|p| p.name == s).with_context(|| {
                        format!(
                            "scenario {name}: unknown workload {s:?} (valid: {})",
                            PROFILES.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
                        )
                    })?;
                    idx.push(w);
                }
                ensure!(!idx.is_empty(), "scenario {name}: \"workloads\" is empty");
                WorkloadSel::Singles(idx)
            }
        } else if let Some(v) = root.field("mixes") {
            let n = v.u64().with_context(|| {
                format!("scenario {name}: \"mixes\" must be a positive integer")
            })? as usize;
            ensure!(n >= 1, "scenario {name}: \"mixes\" must be >= 1");
            WorkloadSel::Mixes(Some(n))
        } else if base.cores() == 1 {
            WorkloadSel::Singles((0..PROFILES.len()).collect())
        } else {
            WorkloadSel::Mixes(None)
        };

        let baseline = match root.field("baseline") {
            None => BaselineMode::PerPoint,
            Some(v) => match v.str() {
                Some("per-point") => BaselineMode::PerPoint,
                Some("shared") => BaselineMode::Shared,
                _ => bail!(
                    "scenario {name}: \"baseline\" must be \"shared\" or \"per-point\""
                ),
            },
        };

        let mut axes = Vec::new();
        if let Some(v) = root.field("axes") {
            let items = v
                .arr()
                .with_context(|| format!("scenario {name}: \"axes\" must be an array"))?;
            for item in items {
                axes.push(parse_axis(&name, item)?);
            }
        }

        let insts_per_core = match root.field("insts_per_core") {
            None => None,
            Some(v) => Some(v.u64().with_context(|| {
                format!("scenario {name}: \"insts_per_core\" must be an integer")
            })?),
        };
        let warmup_cycles = match root.field("warmup_cycles") {
            None => None,
            Some(v) => Some(v.u64().with_context(|| {
                format!("scenario {name}: \"warmup_cycles\" must be an integer")
            })?),
        };

        Ok(ScenarioSpec {
            name,
            description,
            base,
            set,
            mechanisms,
            workloads,
            baseline,
            axes,
            insts_per_core,
            warmup_cycles,
        })
    }

    /// Expand into a runnable plan: build the base config from `scale`
    /// (spec horizon pins win over the scale's, CLI `--set` overrides
    /// win over the spec's `set`), validate every registry path, and
    /// materialize the cartesian product of the axes.
    pub fn expand(&self, scale: &ExperimentScale) -> Result<ScenarioPlan> {
        let reg = schema::registry();
        let mut scale = *scale;
        if let Some(n) = self.insts_per_core {
            scale.insts_per_core = n;
        }
        if let Some(w) = self.warmup_cycles {
            scale.warmup_cycles = w;
        }

        // The mechanism is selected by the "mechanisms" list and carried
        // on JobSpec/JobKey; the config's own (fingerprint-hashed) field
        // is never read by the simulator, so a "mechanism" set/axis
        // would relabel rows without changing what simulates.
        for (path, _) in &self.set {
            ensure!(
                path != "mechanism",
                "scenario {}: set \"mechanism\" via the \"mechanisms\" list, not a config path",
                self.name
            );
        }
        let mut load_axis = None;
        for axis in &self.axes {
            ensure!(
                axis.param != "mechanism",
                "scenario {}: sweep mechanisms via the \"mechanisms\" list, not an axis",
                self.name
            );
            if axis.derive == Some(DeriveRule::LatencyVsLoad) {
                ensure!(
                    load_axis.is_none(),
                    "scenario {}: at most one axis may derive latency-vs-load",
                    self.name
                );
                // Knee detection interpolates in log-load, so every value
                // must be a positive number.
                for v in &axis.values {
                    ensure!(
                        v.parse::<f64>().is_ok_and(|f| f > 0.0 && f.is_finite()),
                        "scenario {}: latency-vs-load axis {} needs positive numeric \
                         values, got {v:?}",
                        self.name,
                        axis.param
                    );
                }
                load_axis = Some(axis.param.clone());
            }
        }

        let cores = self.base.cores();
        ensure!(cores >= 1, "scenario {}: base core count must be >= 1", self.name);
        let mut base_cfg = scale.multi_cfg(cores);
        reg.apply(&mut base_cfg, &self.set)
            .with_context(|| format!("scenario {}: applying \"set\"", self.name))?;
        // CLI `--set` wins over the spec's `set`: re-apply the scale's
        // interned overrides on top (idempotent — multi_cfg already
        // applied them once, before the spec's).
        reg.apply(&mut base_cfg, scale.overrides)?;

        // Checked against the post-override config, not the preset, so a
        // `--set cpu.cores=...` cannot smuggle a mismatch past expand.
        let units: Vec<WorkloadId> = match &self.workloads {
            WorkloadSel::Singles(idx) => {
                ensure!(
                    base_cfg.cpu.cores == 1,
                    "scenario {}: single-core \"workloads\" need a 1-core config",
                    self.name
                );
                idx.iter().map(|&w| WorkloadId::Single(w)).collect()
            }
            WorkloadSel::Mixes(n) => {
                ensure!(
                    base_cfg.cpu.cores > 1,
                    "scenario {}: \"mixes\" need a multi-core config",
                    self.name
                );
                (0..n.unwrap_or(scale.mixes)).map(WorkloadId::Mix).collect()
            }
        };
        ensure!(!units.is_empty(), "scenario {}: no workloads selected", self.name);

        // Cartesian product, first axis slowest (row-major); zero axes
        // mean one point at the base config (a pure mechanism compare).
        // Timing tables are memoized per (temperature, tCK) across the
        // whole expansion: table derivation is startup-class work under
        // `pjrt`, and the legacy sweeps derived once per temperature.
        let mut tables: HashMap<(u64, u64), TimingTable> = HashMap::new();
        let mut points = vec![ScenarioPoint { coords: Vec::new(), cfg: base_cfg.clone() }];
        for axis in &self.axes {
            ensure!(
                !axis.values.is_empty(),
                "scenario {}: axis {} has no values",
                self.name,
                axis.param
            );
            let mut next = Vec::with_capacity(points.len() * axis.values.len());
            for point in &points {
                for value in &axis.values {
                    let mut cfg = point.cfg.clone();
                    reg.set(&mut cfg, &axis.param, value).with_context(|| {
                        format!("scenario {}: axis {}", self.name, axis.param)
                    })?;
                    match axis.derive {
                        Some(
                            DeriveRule::CcTimingFromDuration
                            | DeriveRule::CcTimingFromTemperature,
                        ) => apply_derive(&mut cfg, &mut tables),
                        // latency-vs-load only tags the axis; the registry
                        // already applied the rate.
                        Some(DeriveRule::LatencyVsLoad) | None => {}
                    }
                    let mut coords = point.coords.clone();
                    coords.push((axis.param.clone(), value.clone()));
                    next.push(ScenarioPoint { coords, cfg });
                }
            }
            points = next;
        }
        // Axes can move cpu.cores too (a legitimate scaling study for
        // mixes), but single-workload jobs are pinned to one core — catch
        // that at expand, not as an assert inside a parallel_map worker.
        if matches!(self.workloads, WorkloadSel::Singles(_)) {
            for point in &points {
                ensure!(
                    point.cfg.cpu.cores == 1,
                    "scenario {}: point {:?} sets cpu.cores != 1 under single-core workloads",
                    self.name,
                    point.coords
                );
            }
        }

        Ok(ScenarioPlan {
            name: self.name.clone(),
            description: self.description.clone(),
            mechanisms: self.mechanisms.clone(),
            baseline: self.baseline,
            axes: self.axes.iter().map(|a| a.param.clone()).collect(),
            load_axis,
            base_cfg,
            points,
            units,
        })
    }
}

fn parse_axis(name: &str, item: &Val) -> Result<AxisSpec> {
    const AXIS_KEYS: &[&str] = &["param", "values", "range", "derive"];
    let entries =
        item.entries().with_context(|| format!("scenario {name}: axes entries must be objects"))?;
    check_keys(entries, AXIS_KEYS, &format!("scenario {name}: axis"))?;
    let param = item
        .field("param")
        .and_then(Val::str)
        .with_context(|| format!("scenario {name}: every axis needs a \"param\" path"))?
        .to_string();
    ensure!(
        item.field("values").is_none() || item.field("range").is_none(),
        "scenario {name}: axis {param}: \"values\" and \"range\" are mutually exclusive"
    );
    let values = if let Some(v) = item.field("values") {
        let items = v.arr().with_context(|| {
            format!("scenario {name}: axis {param}: \"values\" must be an array")
        })?;
        let mut tokens = Vec::new();
        for val in items {
            tokens.push(value_token(val).with_context(|| {
                format!("scenario {name}: axis {param}: values must be numbers or strings")
            })?);
        }
        tokens
    } else if let Some(r) = item.field("range") {
        parse_range(name, &param, r)?
    } else {
        bail!("scenario {name}: axis {param} needs \"values\" or \"range\"")
    };
    let derive = match item.field("derive").and_then(Val::str) {
        None => None,
        Some(s) => Some(DeriveRule::parse(s).with_context(|| {
            format!(
                "scenario {name}: axis {param}: unknown derive rule {s:?} \
                 (cc-timing-from-duration | cc-timing-from-temperature | latency-vs-load)"
            )
        })?),
    };
    Ok(AxisSpec { param, values, derive })
}

fn parse_range(name: &str, param: &str, r: &Val) -> Result<Vec<String>> {
    const RANGE_KEYS: &[&str] = &["from", "to", "steps", "spacing"];
    let entries = r
        .entries()
        .with_context(|| format!("scenario {name}: axis {param}: \"range\" must be an object"))?;
    check_keys(entries, RANGE_KEYS, &format!("scenario {name}: axis {param} range"))?;
    let get = |key: &str| -> Result<f64> {
        r.field(key).and_then(Val::f64).with_context(|| {
            format!("scenario {name}: axis {param}: range needs numeric \"{key}\"")
        })
    };
    let from = get("from")?;
    let to = get("to")?;
    let steps = r
        .field("steps")
        .and_then(Val::u64)
        .with_context(|| format!("scenario {name}: axis {param}: range needs integer \"steps\""))?
        as usize;
    ensure!(steps >= 1, "scenario {name}: axis {param}: \"steps\" must be >= 1");
    let log = match r.field("spacing").and_then(Val::str) {
        None | Some("linear") => false,
        Some("log") => true,
        Some(other) => {
            bail!("scenario {name}: axis {param}: spacing must be linear|log, got {other:?}")
        }
    };
    range_values(from, to, steps, log)
        .with_context(|| format!("scenario {name}: axis {param}"))
}

/// Expand a linear or logarithmic range into axis value tokens (spec
/// `range` objects and the CLI's `sweep --from/--to/--steps`).
pub fn range_values(from: f64, to: f64, steps: usize, log: bool) -> Result<Vec<String>> {
    ensure!(steps >= 1, "range needs at least one step");
    // A one-step range would silently ignore `to` — surface the mistake.
    ensure!(
        steps >= 2 || from == to,
        "a 1-step range never reaches \"to\" ({to}); use steps >= 2 or an explicit value list"
    );
    if log {
        ensure!(from > 0.0 && to > 0.0, "log spacing needs positive bounds");
    }
    if steps == 1 {
        return Ok(vec![format!("{from}")]);
    }
    Ok((0..steps)
        .map(|i| {
            // Linear spacing multiplies before dividing so integral grids
            // (9..12 in 4 steps) land exactly on integers; `Display`
            // prints the shortest round-trip form, so those values
            // format as integers and parse exactly.
            let v = if log {
                from * (to / from).powf(i as f64 / (steps - 1) as f64)
            } else {
                from + (to - from) * i as f64 / (steps - 1) as f64
            };
            format!("{v}")
        })
        .collect())
}

/// The legacy sweeps' circuit-layer computation: derive the legal
/// tRCD/tRAS reductions from the charge→timing table, for the config's
/// caching duration at the config's temperature. (The two
/// [`DeriveRule`] names exist so specs read as "what this axis
/// perturbs"; both re-derive from the same physical inputs, so e.g. a
/// temperature axis over a `set`-lengthened duration stays consistent.)
/// Derivation runs at **expansion** time (never per job), and `tables`
/// memoizes one derivation per (temperature, tCK).
fn apply_derive(cfg: &mut SystemConfig, tables: &mut HashMap<(u64, u64), TimingTable>) {
    let (temp, tck) = (cfg.temperature_c, cfg.timing.tck_ns);
    let table = tables
        .entry((temp.to_bits(), tck.to_bits()))
        .or_insert_with(|| timing_table_or_analytic(temp, tck).0);
    let age_s = cfg.chargecache.duration_ms * 1e-3;
    let (rcd, ras) = table.reduction_cycles(age_s);
    // Saturating: the registry makes timing.trcd/tras user-settable, so
    // trcd < 2 must clamp the reduction to 0, not wrap (the legacy
    // sweeps' plain subtraction only ever saw the 11/28 presets).
    cfg.chargecache.trcd_reduction = rcd.min(cfg.timing.trcd.saturating_sub(2));
    cfg.chargecache.tras_reduction = ras.min(cfg.timing.tras.saturating_sub(2));
}

/// One fully-expanded sweep point.
#[derive(Debug, Clone)]
pub struct ScenarioPoint {
    /// `(registry path, value token)` per axis, in spec axis order.
    pub coords: Vec<(String, String)>,
    cfg: SystemConfig,
}

impl ScenarioPoint {
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }
}

/// A validated, fully-expanded scenario ready to run.
pub struct ScenarioPlan {
    pub name: String,
    pub description: String,
    pub mechanisms: Vec<MechanismKind>,
    pub baseline: BaselineMode,
    /// Axis registry paths, spec order (table headers).
    pub axes: Vec<String>,
    /// Registry path of the offered-load axis, when one axis carries the
    /// `latency-vs-load` derive rule (tail-latency studies).
    pub load_axis: Option<String>,
    pub base_cfg: SystemConfig,
    pub points: Vec<ScenarioPoint>,
    pub units: Vec<WorkloadId>,
}

/// One measured row: sweep-point coordinates × mechanism → speedup.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioRow {
    pub coords: Vec<(String, String)>,
    pub mechanism: MechanismKind,
    /// Throughput speedup vs Baseline averaged over the workload units
    /// (the sum-of-core-IPC ratio — the legacy sweeps' metric). Open-loop
    /// legs retire no instructions, so there this is the Baseline/mech
    /// **p99 read-latency ratio** instead (still "higher is better").
    pub speedup: f64,
    /// Read-latency summary of the mechanism legs, unit-averaged
    /// ([`fold_latency`]); `None` when no unit recorded a read.
    pub latency: Option<LatencySummary>,
    /// Same for the Baseline (denominator) legs of this point.
    pub base_latency: Option<LatencySummary>,
}

/// Results of one scenario run.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioRun {
    pub rows: Vec<ScenarioRow>,
    pub points: usize,
    pub legs_submitted: usize,
    /// Legs that panicked through every retry ([`JobResults::failures`]):
    /// their units are dropped from the affected rows (a row with no
    /// surviving units is omitted) and the sweep still completes.
    pub failed_legs: usize,
}

/// Unit-average a set of per-leg latency summaries into one row value.
/// Percentiles are arithmetic means (rounded to nearest) — the same
/// equal-weight-per-unit convention as the speedup column — while `mean`
/// is sample-weighted, `max` is the true max, and `samples` the total.
fn fold_latency(units: &[LatencySummary]) -> Option<LatencySummary> {
    if units.is_empty() {
        return None;
    }
    let n = units.len() as u64;
    let avg = |f: fn(&LatencySummary) -> u64| -> u64 {
        (units.iter().map(f).sum::<u64>() + n / 2) / n
    };
    let samples: u64 = units.iter().map(|u| u.samples).sum();
    let mean = if samples == 0 {
        0.0
    } else {
        units.iter().map(|u| u.mean * u.samples as f64).sum::<f64>() / samples as f64
    };
    Some(LatencySummary {
        p50: avg(|u| u.p50),
        p95: avg(|u| u.p95),
        p99: avg(|u| u.p99),
        p999: avg(|u| u.p999),
        mean,
        max: units.iter().map(|u| u.max).max().unwrap_or(0),
        samples,
    })
}

/// Locate the saturation knee of a latency-vs-load curve: the offered
/// load where p99 first crosses **2× the lowest-load p99**, linearly
/// interpolated in log-load (open-loop sweeps are log-spaced, so the
/// interpolation matches the axis geometry). `points` is
/// `(offered load, p99)` sorted ascending by load; returns `None` when
/// the curve never crosses (the system never saturates in the swept
/// range) or fewer than two points exist.
pub fn knee_load(points: &[(f64, u64)]) -> Option<f64> {
    let &(_, base) = points.first()?;
    if base == 0 {
        return None;
    }
    let thresh = base as f64 * 2.0;
    for w in points.windows(2) {
        let ((x0, y0), (x1, y1)) =
            ((w[0].0, w[0].1 as f64), (w[1].0, w[1].1 as f64));
        if y0 < thresh && y1 >= thresh {
            let t = if y1 > y0 { (thresh - y0) / (y1 - y0) } else { 1.0 };
            return Some((x0.ln() + t * (x1.ln() - x0.ln())).exp());
        }
    }
    None
}

impl ScenarioRun {
    /// Per-curve knee loads over the `load_param` axis: the Baseline
    /// denominator's curve first (from the first listed mechanism's
    /// `base_latency` — identical across mechanisms at a given point),
    /// then one entry per mechanism, each labelled for display. `None`
    /// knee = that curve never saturated in the swept range.
    pub fn knees(&self, load_param: &str) -> Vec<(String, Option<f64>)> {
        let load_of = |row: &ScenarioRow| -> Option<f64> {
            row.coords
                .iter()
                .find(|(p, _)| p == load_param)
                .and_then(|(_, v)| v.parse().ok())
        };
        let curve = |pick: &dyn Fn(&ScenarioRow) -> Option<(f64, u64)>| -> Option<f64> {
            let mut pts: Vec<(f64, u64)> = self.rows.iter().filter_map(|r| pick(r)).collect();
            pts.sort_by(|a, b| a.0.total_cmp(&b.0));
            knee_load(&pts)
        };
        let mut out = Vec::new();
        if let Some(first) = self.rows.first().map(|r| r.mechanism) {
            out.push((
                "Baseline".to_string(),
                curve(&|r| {
                    (r.mechanism == first).then_some(())?;
                    Some((load_of(r)?, r.base_latency?.p99))
                }),
            ));
        }
        let mut seen: Vec<MechanismKind> = Vec::new();
        for row in &self.rows {
            if seen.contains(&row.mechanism) {
                continue;
            }
            seen.push(row.mechanism);
            let mech = row.mechanism;
            out.push((
                mech.label().to_string(),
                curve(&|r| {
                    (r.mechanism == mech).then_some(())?;
                    Some((load_of(r)?, r.latency?.p99))
                }),
            ));
        }
        out
    }
}

impl ScenarioPlan {
    fn job(cfg: &SystemConfig, mech: MechanismKind, unit: WorkloadId) -> JobSpec {
        match unit {
            WorkloadId::Single(w) => JobSpec::single(cfg.clone(), mech, w),
            WorkloadId::Mix(m) => JobSpec::mix(cfg.clone(), mech, m),
        }
    }

    /// Total legs one run submits (before dedup) — `--validate` output.
    pub fn leg_count(&self) -> usize {
        let mech_legs = self.points.len() * self.mechanisms.len() * self.units.len();
        match self.baseline {
            BaselineMode::Shared => self.units.len() + mech_legs,
            BaselineMode::PerPoint => self.points.len() * self.units.len() + mech_legs,
        }
    }

    /// Submit every leg through `eng`'s job graph and fold the results
    /// into per-(point × mechanism) speedup rows. Workload units are
    /// folded in submission order (mix 0, 1, ... — bit-compatible with
    /// the legacy sweeps' arithmetic).
    pub fn run_with(&self, eng: &mut JobEngine) -> ScenarioRun {
        let mut graph = JobGraph::new();
        // Baseline (denominator) legs: one per unit, either shared at
        // the base config or per sweep point.
        let shared_base: Vec<JobTicket> = match self.baseline {
            BaselineMode::Shared => self
                .units
                .iter()
                .map(|&u| graph.submit(Self::job(&self.base_cfg, MechanismKind::Baseline, u)))
                .collect(),
            BaselineMode::PerPoint => Vec::new(),
        };
        let point_base: Vec<Vec<JobTicket>> = match self.baseline {
            BaselineMode::Shared => Vec::new(),
            BaselineMode::PerPoint => self
                .points
                .iter()
                .map(|p| {
                    self.units
                        .iter()
                        .map(|&u| graph.submit(Self::job(&p.cfg, MechanismKind::Baseline, u)))
                        .collect()
                })
                .collect(),
        };
        let mech_tickets: Vec<Vec<Vec<JobTicket>>> = self
            .points
            .iter()
            .map(|p| {
                self.mechanisms
                    .iter()
                    .map(|&m| {
                        self.units
                            .iter()
                            .map(|&u| graph.submit(Self::job(&p.cfg, m, u)))
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let legs_submitted = graph.submitted_len();
        let res = eng.run(graph);
        let failed_legs = res.failures().len();
        for f in res.failures() {
            eprintln!(
                "warning: leg failed after retries: {} / {} — {}",
                f.workload, f.mechanism, f.error
            );
        }

        let mut rows = Vec::with_capacity(self.points.len() * self.mechanisms.len());
        for (pi, point) in self.points.iter().enumerate() {
            for (mi, &mech) in self.mechanisms.iter().enumerate() {
                let mut sum = 0.0;
                let mut units = 0usize;
                let mut mech_lat = Vec::new();
                let mut base_lat = Vec::new();
                for ui in 0..self.units.len() {
                    let bt = match self.baseline {
                        BaselineMode::Shared => shared_base[ui],
                        BaselineMode::PerPoint => point_base[pi][ui],
                    };
                    // A failed leg (baseline or mechanism side) drops this
                    // unit from the row instead of aborting the sweep.
                    let (Some(base), Some(with_mech)) =
                        (res.try_get(bt), res.try_get(mech_tickets[pi][mi][ui]))
                    else {
                        continue;
                    };
                    if let Some(l) = with_mech.latency {
                        mech_lat.push(l);
                    }
                    if let Some(l) = base.latency {
                        base_lat.push(l);
                    }
                    let tb: f64 = base.core_ipc.iter().sum();
                    let tc: f64 = with_mech.core_ipc.iter().sum();
                    if tb > 0.0 && tc > 0.0 {
                        sum += tc / tb;
                        units += 1;
                    } else if let (Some(bl), Some(ml)) = (base.latency, with_mech.latency) {
                        // Open-loop legs quiesce the cores (zero IPC on
                        // both sides); rank by tail latency instead.
                        if ml.p99 > 0 {
                            sum += bl.p99 as f64 / ml.p99 as f64;
                            units += 1;
                        }
                    }
                }
                if units == 0 {
                    continue;
                }
                rows.push(ScenarioRow {
                    coords: point.coords.clone(),
                    mechanism: mech,
                    speedup: sum / units as f64,
                    latency: fold_latency(&mech_lat),
                    base_latency: fold_latency(&base_lat),
                });
            }
        }
        ScenarioRun { rows, points: self.points.len(), legs_submitted, failed_legs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExperimentScale {
        ExperimentScale {
            insts_per_core: 2_000,
            warmup_cycles: 1_000,
            mixes: 1,
            ..ExperimentScale::default()
        }
    }

    #[test]
    fn minimal_spec_defaults() {
        let spec = ScenarioSpec::parse(r#"{ "name": "m" }"#).unwrap();
        assert_eq!(spec.base, BasePreset::Eight);
        assert_eq!(spec.mechanisms, vec![MechanismKind::ChargeCache]);
        assert_eq!(spec.workloads, WorkloadSel::Mixes(None));
        assert_eq!(spec.baseline, BaselineMode::PerPoint);
        assert!(spec.axes.is_empty());
        // Zero axes expand to one point at the base config.
        let plan = spec.expand(&tiny()).unwrap();
        assert_eq!(plan.points.len(), 1);
        assert_eq!(plan.units.len(), 1);
        assert_eq!(plan.leg_count(), 2); // baseline + cc on one mix
    }

    #[test]
    fn unknown_keys_and_vocab_are_rejected() {
        assert!(ScenarioSpec::parse(r#"{ "name": "x", "bogus": 1 }"#).is_err());
        let err = ScenarioSpec::parse(r#"{ "name": "x", "name": "y" }"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err:?}");
        let err = ScenarioSpec::parse(r#"{ "name": "x", "mechanisms": ["warp"] }"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cc+nuat"), "valid names missing from {err:?}");
        let err = ScenarioSpec::parse(r#"{ "name": "x", "mechanisms": ["baseline"] }"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("denominator"), "{err:?}");
        let err = ScenarioSpec::parse(
            r#"{ "name": "x", "base": "single", "workloads": ["mfc"] }"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mcf"), "workload list missing from {err:?}");
        assert!(ScenarioSpec::parse(
            r#"{ "name": "x", "workloads": ["mcf"], "mixes": 2 }"#
        )
        .is_err());
    }

    #[test]
    fn axis_expansion_is_cartesian_row_major() {
        let spec = ScenarioSpec::parse(
            r#"{
              "name": "grid",
              "base": "eight",
              "mixes": 1,
              "axes": [
                { "param": "chargecache.entries_per_core", "values": [64, 128] },
                { "param": "chargecache.ways", "values": [2, 4, 8] }
              ]
            }"#,
        )
        .unwrap();
        let plan = spec.expand(&tiny()).unwrap();
        assert_eq!(plan.points.len(), 6);
        // First axis slowest: entries=64 rows come first.
        assert_eq!(plan.points[0].coords, vec![
            ("chargecache.entries_per_core".to_string(), "64".to_string()),
            ("chargecache.ways".to_string(), "2".to_string()),
        ]);
        assert_eq!(plan.points[5].coords[0].1, "128");
        assert_eq!(plan.points[5].coords[1].1, "8");
        assert_eq!(plan.points[3].cfg().chargecache.entries_per_core, 128);
        assert_eq!(plan.points[3].cfg().chargecache.ways, 2);
    }

    #[test]
    fn range_axes_expand_linear_and_log() {
        let spec = ScenarioSpec::parse(
            r#"{
              "name": "r",
              "axes": [
                { "param": "timing.trcd", "range": { "from": 9, "to": 12, "steps": 4 } }
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(spec.axes[0].values, vec!["9", "10", "11", "12"]);

        let spec = ScenarioSpec::parse(
            r#"{
              "name": "r2",
              "axes": [
                { "param": "chargecache.duration_ms",
                  "range": { "from": 0.125, "to": 8, "steps": 7, "spacing": "log" } }
              ]
            }"#,
        )
        .unwrap();
        let vals: Vec<f64> =
            spec.axes[0].values.iter().map(|v| v.parse().unwrap()).collect();
        assert_eq!(vals.len(), 7);
        assert!(vals.windows(2).all(|w| w[0] < w[1]), "log range must ascend: {vals:?}");
        assert!((vals[0] - 0.125).abs() < 1e-12);
        assert!((vals[6] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn spec_set_applies_and_cli_overrides_win() {
        let spec = ScenarioSpec::parse(
            r#"{ "name": "s", "set": { "timing.trcd": 13, "mc.scheduler": "bliss" } }"#,
        )
        .unwrap();
        let plan = spec.expand(&tiny()).unwrap();
        assert_eq!(plan.base_cfg.timing.trcd, 13);
        assert_eq!(plan.base_cfg.mc.scheduler.label(), "BLISS");

        // CLI --set (interned scale overrides) beats the spec's set.
        let scale = tiny()
            .with_overrides(vec![("timing.trcd".to_string(), "12".to_string())])
            .unwrap();
        let plan = spec.expand(&scale).unwrap();
        assert_eq!(plan.base_cfg.timing.trcd, 12);
        assert_eq!(plan.base_cfg.mc.scheduler.label(), "BLISS");
    }

    #[test]
    fn bad_registry_paths_fail_at_expand() {
        let spec = ScenarioSpec::parse(
            r#"{ "name": "b", "axes": [ { "param": "chargecache.entires", "values": [1] } ] }"#,
        )
        .unwrap();
        let err = spec.expand(&tiny()).unwrap_err().to_string();
        assert!(err.contains("unknown parameter"), "{err:?}");

        let spec = ScenarioSpec::parse(
            r#"{ "name": "b2", "set": { "timing.trcd": 4.5 } }"#,
        )
        .unwrap();
        assert!(spec.expand(&tiny()).is_err(), "fractional u64 must fail");
    }

    #[test]
    fn derive_rules_round_trip_names() {
        for rule in [
            DeriveRule::CcTimingFromDuration,
            DeriveRule::CcTimingFromTemperature,
            DeriveRule::LatencyVsLoad,
        ] {
            assert_eq!(DeriveRule::parse(rule.name()), Some(rule));
        }
        assert_eq!(DeriveRule::parse("nope"), None);
    }

    #[test]
    fn load_axis_is_tagged_and_validated() {
        let spec = ScenarioSpec::parse(
            r#"{
              "name": "tail",
              "set": { "traffic.mode": "poisson" },
              "axes": [
                { "param": "traffic.rate_rps", "derive": "latency-vs-load",
                  "range": { "from": 1e7, "to": 1e9, "steps": 3, "spacing": "log" } }
              ]
            }"#,
        )
        .unwrap();
        let plan = spec.expand(&tiny()).unwrap();
        assert_eq!(plan.load_axis.as_deref(), Some("traffic.rate_rps"));
        assert_eq!(plan.points.len(), 3);
        // The derive rule must not perturb the config beyond the axis
        // value the registry already applied.
        assert!(plan.points[0].cfg().traffic.rate_rps > 0.0);

        // Two load axes are ambiguous for knee detection.
        let spec = ScenarioSpec::parse(
            r#"{
              "name": "tail2",
              "axes": [
                { "param": "traffic.rate_rps", "derive": "latency-vs-load", "values": [1e7] },
                { "param": "traffic.seed", "derive": "latency-vs-load", "values": [1] }
              ]
            }"#,
        )
        .unwrap();
        assert!(spec.expand(&tiny()).is_err());

        // Non-positive load values can't be placed on a log axis.
        let spec = ScenarioSpec::parse(
            r#"{
              "name": "tail3",
              "axes": [
                { "param": "traffic.rate_rps", "derive": "latency-vs-load", "values": [0] }
              ]
            }"#,
        )
        .unwrap();
        assert!(spec.expand(&tiny()).is_err());
    }

    #[test]
    fn knee_detection_interpolates_in_log_load() {
        // Flat at 100 until 1e8, then doubles by 4e8: the 2x threshold
        // (200) is crossed exactly at the 4e8 sample.
        let curve = [(1e7, 100), (1e8, 100), (4e8, 200), (1e9, 900)];
        let knee = knee_load(&curve).expect("curve crosses 2x");
        assert!((knee - 4e8).abs() / 4e8 < 1e-9, "knee {knee}");

        // Mid-segment crossing interpolates geometrically: threshold 200
        // halfway (linearly in p99) between 100 @1e8 and 300 @1e9 lands
        // at sqrt(1e8 * 1e9).
        let curve = [(1e8, 100), (1e9, 300)];
        let knee = knee_load(&curve).expect("crosses mid-segment");
        let expect = (1e8f64 * 1e9).sqrt();
        assert!((knee - expect).abs() / expect < 1e-9, "knee {knee} vs {expect}");

        // Never saturates / degenerate inputs.
        assert_eq!(knee_load(&[(1e7, 100), (1e9, 199)]), None);
        assert_eq!(knee_load(&[(1e7, 100)]), None);
        assert_eq!(knee_load(&[]), None);
        assert_eq!(knee_load(&[(1e7, 0), (1e9, 50)]), None);
    }

    #[test]
    fn fold_latency_averages_units() {
        let s = |p99: u64, mean: f64, samples: u64| LatencySummary {
            p50: p99 / 2,
            p95: p99,
            p99,
            p999: p99 * 2,
            mean,
            max: p99 * 3,
            samples,
        };
        assert_eq!(fold_latency(&[]), None);
        let f = fold_latency(&[s(100, 40.0, 10), s(200, 80.0, 30)]).unwrap();
        assert_eq!(f.p99, 150);
        assert_eq!(f.max, 600);
        assert_eq!(f.samples, 40);
        // Sample-weighted mean: (40*10 + 80*30) / 40 = 70.
        assert!((f.mean - 70.0).abs() < 1e-12);
    }
}
