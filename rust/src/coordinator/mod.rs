//! Experiment coordinator: runs the paper's evaluation matrix (workload x
//! mechanism x configuration) in parallel worker threads and renders each
//! figure/table of the paper.

pub mod cli;
pub mod experiments;
pub mod figures;
pub mod jobs;
pub(crate) mod json;
pub mod runner;
pub mod scenario;

pub use experiments::{ExperimentScale, Fig4Row, SuiteResults};
pub use jobs::{CacheStats, JobEngine, JobGraph, JobKey, JobSpec, SimCache, WorkloadId};
pub use runner::parallel_map;
pub use scenario::{ScenarioPlan, ScenarioRun, ScenarioSpec};
