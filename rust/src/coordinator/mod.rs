//! Experiment coordinator: runs the paper's evaluation matrix (workload x
//! mechanism x configuration) in parallel worker threads and renders each
//! figure/table of the paper.

pub mod cli;
pub mod experiments;
pub mod figures;
pub mod runner;

pub use experiments::{ExperimentScale, Fig4Row, SuiteResults};
pub use runner::parallel_map;
