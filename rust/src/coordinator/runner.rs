//! Minimal parallel runner (std::thread::scope work queue; the build is
//! offline so no rayon/tokio — simulations are embarrassingly parallel and
//! coarse-grained, so a simple atomic work index is optimal anyway).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0..n)` across `threads` workers, preserving index order in the
/// returned Vec. `f` must be pure w.r.t. the index.
pub fn parallel_map_threads<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *out[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// [`parallel_map_threads`] with the machine's available parallelism.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    parallel_map_threads(n, threads, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v = parallel_map_threads(100, 8, |i| i * 3);
        assert_eq!(v, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn works_single_threaded_and_empty() {
        assert_eq!(parallel_map_threads(3, 1, |i| i), vec![0, 1, 2]);
        let empty: Vec<usize> = parallel_map_threads(0, 4, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn heavy_fanout() {
        let v = parallel_map(64, |i| {
            // Small CPU-bound task.
            (0..1000u64).fold(i as u64, |a, b| a.wrapping_add(b * b))
        });
        assert_eq!(v.len(), 64);
        let expect = (0..1000u64).fold(7u64, |a, b| a.wrapping_add(b * b));
        assert_eq!(v[7], expect);
    }
}
