//! Minimal parallel runner (std::thread::scope work queue; the build is
//! offline so no rayon/tokio — simulations are embarrassingly parallel and
//! coarse-grained, so a simple atomic work index is optimal anyway).
//!
//! The worker count used by [`parallel_map`] resolves in priority order:
//! an explicit [`set_threads`] pin (CLI `--threads N`), the
//! `PALLAS_THREADS` environment variable, then the machine's available
//! parallelism. Pinning exists so benchmark suites can be reproduced on
//! shared machines — results are index-pure either way.
//!
//! The two threading knobs compose: `--threads` controls how many *jobs*
//! run concurrently; `--sim-threads` ([`set_sim_threads`] /
//! `PALLAS_SIM_THREADS`) controls how many channel shards each job's
//! simulation uses ([`crate::sim::shard`]). Total worker threads is
//! their product, so [`default_threads`] divides available parallelism
//! by the shard count instead of silently oversubscribing.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Once, OnceLock};

/// Worker-count pin for [`parallel_map`]; 0 means "not pinned".
static THREAD_PIN: AtomicUsize = AtomicUsize::new(0);

/// Shard-count pin for the channel-sharded simulation loop; 0 = unset.
static SIM_THREAD_PIN: AtomicUsize = AtomicUsize::new(0);

/// Pin the worker count for every subsequent [`parallel_map`] call
/// (CLI `--threads N`). Passing 0 clears the pin, restoring the
/// `PALLAS_THREADS` / available-parallelism fallback chain.
pub fn set_threads(n: usize) {
    THREAD_PIN.store(n, Ordering::Relaxed);
}

/// Pin the per-simulation shard count (CLI `--sim-threads N`). Passing 0
/// clears the pin, restoring the `PALLAS_SIM_THREADS` / single-threaded
/// fallback chain. Consulted by [`crate::sim::System`] when a config
/// leaves `sim.threads` at its 0 (auto) default.
pub fn set_sim_threads(n: usize) {
    SIM_THREAD_PIN.store(n, Ordering::Relaxed);
}

/// Resolve the per-simulation shard count: pin, then
/// `PALLAS_SIM_THREADS`, then 1 (the exact single-threaded event path —
/// sharding is opt-in, unlike job parallelism).
pub fn sim_threads() -> usize {
    let pinned = SIM_THREAD_PIN.load(Ordering::Relaxed);
    if pinned > 0 {
        return pinned;
    }
    if let Ok(v) = std::env::var("PALLAS_SIM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    1
}

/// Resolve the job worker count: pin, then `PALLAS_THREADS`, then the
/// machine — divided by the shard count so jobs × shards stays within
/// available parallelism. An explicit pin or env setting is honored as
/// given (the user asked for it), but still warned about when the
/// product oversubscribes.
fn default_threads() -> usize {
    let shards = sim_threads().max(1);
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let explicit = {
        let pinned = THREAD_PIN.load(Ordering::Relaxed);
        if pinned > 0 {
            Some(pinned)
        } else {
            std::env::var("PALLAS_THREADS")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
        }
    };
    match explicit {
        Some(n) => {
            if n.saturating_mul(shards) > avail {
                // Once per process: `parallel_map` runs per experiment
                // phase, and a suite would otherwise repeat this dozens
                // of times for one decision the user already made.
                static OVERSUBSCRIBED: Once = Once::new();
                OVERSUBSCRIBED.call_once(|| {
                    eprintln!(
                        "warning: --threads {n} x --sim-threads {shards} = {} worker threads \
                         exceeds available parallelism ({avail}); expect contention",
                        n * shards
                    );
                });
            }
            n
        }
        // Auto: cap jobs so jobs x shards <= available parallelism.
        None => (avail / shards).max(1),
    }
}

/// Run `f(0..n)` across `threads` workers, preserving index order in the
/// returned Vec. `f` must be pure w.r.t. the index.
///
/// Results land in per-slot [`OnceLock`]s: each index is claimed by
/// exactly one worker (the atomic fetch-add hands out every index once),
/// so the write is an uncontended lock-free store — the previous
/// `Mutex<Option<T>>` slots paid a lock/unlock round-trip per job for
/// mutual exclusion that the index claim already guarantees. The `Sync`
/// bound on `T` comes with sharing the `OnceLock` slots across workers
/// (`Mutex` needed only `Send`); every job payload here is plain data.
pub fn parallel_map_threads<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let out: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                if out[i].set(v).is_err() {
                    unreachable!("index {i} claimed twice");
                }
            });
        }
    });
    out.into_iter()
        .map(|c| c.into_inner().expect("worker filled every slot"))
        .collect()
}

/// [`parallel_map_threads`] with the configured worker count (pin >
/// `PALLAS_THREADS` > available parallelism).
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_threads(n, default_threads(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v = parallel_map_threads(100, 8, |i| i * 3);
        assert_eq!(v, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn works_single_threaded_and_empty() {
        assert_eq!(parallel_map_threads(3, 1, |i| i), vec![0, 1, 2]);
        let empty: Vec<usize> = parallel_map_threads(0, 4, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn heavy_fanout() {
        let v = parallel_map(64, |i| {
            // Small CPU-bound task.
            (0..1000u64).fold(i as u64, |a, b| a.wrapping_add(b * b))
        });
        assert_eq!(v.len(), 64);
        let expect = (0..1000u64).fold(7u64, |a, b| a.wrapping_add(b * b));
        assert_eq!(v[7], expect);
    }

    #[test]
    fn thread_pin_round_trips_and_preserves_results() {
        // Results are index-pure, so a pinned run must equal an unpinned
        // one (the pin only controls parallelism, pinned by the
        // engine_equiv determinism test across 1/2/8 workers too).
        let unpinned = parallel_map(16, |i| i * i);
        set_threads(2);
        let pinned = parallel_map(16, |i| i * i);
        set_threads(0);
        assert_eq!(unpinned, pinned);
    }
}
