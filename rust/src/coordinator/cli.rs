//! CLI v2 (offline build — no clap): typed subcommands over a
//! declarative command table.
//!
//! The binary declares a [`CommandSpec`] per subcommand — name, aliases,
//! summary, optional positional arguments, per-command [`FlagSpec`]s —
//! plus a shared list of common flags accepted everywhere. Parsing is
//! table-driven: unknown commands and flags are errors that list the
//! valid choices, a flag's arity comes from its spec (so a boolean flag
//! followed by a positional argument parses unambiguously), `--flag=v`
//! and `--flag v` are equivalent, and repeatable flags (`--set`)
//! accumulate. Help is rendered from the same table, so it cannot drift
//! from what parses.

use crate::config::schema;
use crate::error::{Context, Result};
use crate::{bail, ensure};

/// One named option a command accepts.
pub struct FlagSpec {
    pub name: &'static str,
    /// `None` = boolean flag; `Some(meta)` = takes one value, shown as
    /// `--name META` in help.
    pub value: Option<&'static str>,
    pub doc: &'static str,
    /// May be given more than once (occurrences accumulate).
    pub repeat: bool,
}

impl FlagSpec {
    pub const fn flag(name: &'static str, doc: &'static str) -> Self {
        Self { name, value: None, doc, repeat: false }
    }

    pub const fn value(name: &'static str, meta: &'static str, doc: &'static str) -> Self {
        Self { name, value: Some(meta), doc, repeat: false }
    }

    pub const fn repeated(name: &'static str, meta: &'static str, doc: &'static str) -> Self {
        Self { name, value: Some(meta), doc, repeat: true }
    }
}

/// One subcommand in the binary's table.
pub struct CommandSpec {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    /// Positional-argument name, if the command takes any (one or more).
    pub positional: Option<&'static str>,
    /// Command-specific flags (common flags are accepted everywhere).
    pub flags: &'static [FlagSpec],
    /// `Some(replacement)`: parsing succeeds, the dispatcher warns and
    /// forwards (thin deprecation alias).
    pub deprecated: Option<&'static str>,
}

/// Parsed command line: the resolved command plus its typed options.
pub struct Args {
    /// Canonical command name (aliases resolved).
    pub command: String,
    pub spec: &'static CommandSpec,
    /// Valued options in occurrence order (`get` returns the last).
    opts: Vec<(String, String)>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

fn resolve(
    commands: &'static [CommandSpec],
    raw: &str,
) -> Result<&'static CommandSpec> {
    // Leading-flag forms people type out of habit.
    let raw = match raw {
        "--help" | "-h" => "help",
        "--list-params" => "params",
        other => other,
    };
    if let Some(c) =
        commands.iter().find(|c| c.name == raw || c.aliases.contains(&raw))
    {
        return Ok(c);
    }
    let names: Vec<&str> =
        commands.iter().filter(|c| c.deprecated.is_none()).map(|c| c.name).collect();
    bail!("unknown command {raw:?} (commands: {})", names.join(" "))
}

fn find_flag<'a>(
    spec: &'a CommandSpec,
    common: &'a [FlagSpec],
    name: &str,
) -> Result<&'a FlagSpec> {
    if let Some(f) = spec.flags.iter().chain(common.iter()).find(|f| f.name == name) {
        return Ok(f);
    }
    let valid: Vec<String> = spec
        .flags
        .iter()
        .chain(common.iter())
        .map(|f| format!("--{}", f.name))
        .collect();
    bail!(
        "unknown option --{name} for `{}` (valid: {})",
        spec.name,
        valid.join(" ")
    )
}

impl Args {
    /// Parse from an iterator of argument strings (no program name)
    /// against a command table.
    pub fn parse_with<I: IntoIterator<Item = String>>(
        args: I,
        commands: &'static [CommandSpec],
        common: &'static [FlagSpec],
    ) -> Result<Self> {
        let mut it = args.into_iter();
        let raw_cmd = it.next().unwrap_or_else(|| "help".to_string());
        let spec = resolve(commands, &raw_cmd)?;
        let mut opts: Vec<(String, String)> = Vec::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        #[allow(clippy::while_let_on_iterator)] // the body advances `it` too
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let fs = find_flag(spec, common, &name)?;
                match fs.value {
                    Some(meta) => {
                        ensure!(
                            fs.repeat || !opts.iter().any(|(k, _)| *k == name),
                            "--{name} may be given only once"
                        );
                        // A valued flag consumes the next token
                        // unconditionally (values may look like anything,
                        // including a leading dash).
                        let v = match inline {
                            Some(v) => v,
                            None => it.next().with_context(|| {
                                format!("--{name} expects a value ({meta})")
                            })?,
                        };
                        opts.push((name, v));
                    }
                    None => {
                        ensure!(
                            inline.is_none(),
                            "--{name} is a flag and takes no value"
                        );
                        ensure!(
                            fs.repeat || !flags.iter().any(|f| *f == name),
                            "--{name} may be given only once"
                        );
                        flags.push(name);
                    }
                }
            } else {
                ensure!(
                    spec.positional.is_some(),
                    "unexpected argument {tok:?} for `{}`",
                    spec.name
                );
                positionals.push(tok);
            }
        }
        Ok(Self { command: spec.name.to_string(), spec, opts, flags, positionals })
    }

    pub fn from_env(
        commands: &'static [CommandSpec],
        common: &'static [FlagSpec],
    ) -> Result<Self> {
        Self::parse_with(std::env::args().skip(1), commands, common)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last occurrence of a valued option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Every occurrence of a repeatable option, in order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.opts.iter().filter(|(k, _)| k == name).map(|(_, v)| v.as_str()).collect()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}"))
            }
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{name} expects a number, got {v:?}"))
            }
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--set PATH=VALUE` occurrences as parsed assignments.
    pub fn set_overrides(&self) -> Result<Vec<(String, String)>> {
        self.get_all("set").into_iter().map(schema::parse_assignment).collect()
    }

    /// Parse a scheduler policy name (`--scheduler`) via the policy
    /// module's single name table.
    pub fn scheduler(
        &self,
        default: crate::controller::SchedulerKind,
    ) -> Result<crate::controller::SchedulerKind> {
        use crate::controller::SchedulerKind;
        match self.get("scheduler") {
            None => Ok(default),
            Some(s) => SchedulerKind::parse(s).with_context(|| {
                format!("unknown scheduler {s:?} ({})", SchedulerKind::valid_names())
            }),
        }
    }

    /// Parse a mechanism name via the mechanism name table.
    pub fn mechanism(
        &self,
        default: crate::latency::MechanismKind,
    ) -> Result<crate::latency::MechanismKind> {
        use crate::latency::MechanismKind;
        match self.get("mechanism") {
            None => Ok(default),
            Some(s) => MechanismKind::parse(s).with_context(|| {
                format!("unknown mechanism {s:?} ({})", MechanismKind::valid_names())
            }),
        }
    }
}

/// Global help: usage, the command table, and the common flags.
pub fn render_help(
    title: &str,
    commands: &'static [CommandSpec],
    common: &'static [FlagSpec],
) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push_str("\n\ncommands:\n");
    let listed = || commands.iter().filter(|c| c.deprecated.is_none());
    let width = listed().map(|c| c.name.len()).max().unwrap_or(0);
    for c in listed() {
        out.push_str(&format!("  {:<width$}  {}\n", c.name, c.summary));
    }
    let deprecated: Vec<String> = commands
        .iter()
        .filter_map(|c| c.deprecated.map(|r| format!("{} -> `{}`", c.name, r)))
        .collect();
    if !deprecated.is_empty() {
        out.push_str(&format!("\ndeprecated aliases: {}\n", deprecated.join(", ")));
    }
    out.push_str("\ncommon options (every command):\n");
    out.push_str(&render_flag_list(common));
    out.push_str("\nrun `chargecache help COMMAND` for per-command options,\n");
    out.push_str("and `chargecache params` for every `--set` parameter.\n");
    out
}

/// Per-command help: usage line, its flags, then the common flags.
pub fn render_command_help(cmd: &CommandSpec, common: &'static [FlagSpec]) -> String {
    let mut out = String::new();
    out.push_str(&format!("chargecache {}", cmd.name));
    if let Some(p) = cmd.positional {
        out.push_str(&format!(" {p}..."));
    }
    out.push_str(&format!(" [options]\n  {}\n", cmd.summary));
    if !cmd.aliases.is_empty() {
        out.push_str(&format!("  aliases: {}\n", cmd.aliases.join(", ")));
    }
    if let Some(replacement) = cmd.deprecated {
        out.push_str(&format!("  DEPRECATED: use `chargecache {replacement}`\n"));
    }
    if !cmd.flags.is_empty() {
        out.push_str("\noptions:\n");
        out.push_str(&render_flag_list(cmd.flags));
    }
    out.push_str("\ncommon options:\n");
    out.push_str(&render_flag_list(common));
    out
}

fn render_flag_list(flags: &[FlagSpec]) -> String {
    let label = |f: &FlagSpec| match f.value {
        Some(meta) => format!("--{} {}", f.name, meta),
        None => format!("--{}", f.name),
    };
    let width = flags.iter().map(|f| label(f).len()).max().unwrap_or(0);
    flags
        .iter()
        .map(|f| {
            let repeat = if f.repeat { " (repeatable)" } else { "" };
            format!("  {:<width$}  {}{repeat}\n", label(f), f.doc)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::SchedulerKind;
    use crate::latency::MechanismKind;

    static TEST_COMMON: &[FlagSpec] = &[
        FlagSpec::repeated("set", "PATH=VALUE", "override a config field"),
        FlagSpec::value("threads", "N", "worker count"),
        FlagSpec::value("insts", "N", "instructions per core"),
        FlagSpec::value("result-cache", "DIR", "persist results"),
        FlagSpec::flag("no-memo", "disable memoization"),
        FlagSpec::flag("quick", "small horizon"),
        FlagSpec::value("scheduler", "NAME", "scheduler policy"),
        FlagSpec::value("duration", "MS", "caching duration"),
        FlagSpec::value("workload", "NAME", "workload name"),
        FlagSpec::value("mechanism", "NAME", "mechanism name"),
    ];

    static TEST_COMMANDS: &[CommandSpec] = &[
        CommandSpec {
            name: "fig4",
            aliases: &[],
            summary: "speedup figure",
            positional: None,
            flags: &[FlagSpec::value("cores", "N", "core count")],
            deprecated: None,
        },
        CommandSpec {
            name: "scenario",
            aliases: &["scn"],
            summary: "run a spec file",
            positional: Some("FILE"),
            flags: &[FlagSpec::flag("validate", "parse and expand only")],
            deprecated: None,
        },
        CommandSpec {
            name: "figures",
            aliases: &[],
            summary: "all figures",
            positional: None,
            flags: &[],
            deprecated: None,
        },
        CommandSpec {
            name: "simulate",
            aliases: &[],
            summary: "one simulation",
            positional: None,
            flags: &[],
            deprecated: Some("run"),
        },
        CommandSpec {
            name: "help",
            aliases: &[],
            summary: "help",
            positional: Some("COMMAND"),
            flags: &[],
            deprecated: None,
        },
    ];

    fn args(s: &str) -> Args {
        Args::parse_with(s.split_whitespace().map(String::from), TEST_COMMANDS, TEST_COMMON)
            .unwrap()
    }

    fn args_err(s: &str) -> String {
        Args::parse_with(s.split_whitespace().map(String::from), TEST_COMMANDS, TEST_COMMON)
            .unwrap_err()
            .to_string()
    }

    #[test]
    fn parses_command_opts_flags() {
        let a = args("fig4 --cores 8 --insts 100000 --quick");
        assert_eq!(a.command, "fig4");
        assert_eq!(a.get_u64("cores", 1).unwrap(), 8);
        assert_eq!(a.get_u64("insts", 0).unwrap(), 100000);
        assert!(a.flag("quick"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn flag_arity_comes_from_the_table() {
        // A boolean flag followed by a positional must not eat it.
        let a = args("scenario --validate specs/cap.json");
        assert!(a.flag("validate"));
        assert_eq!(a.positionals, vec!["specs/cap.json"]);
        // Multiple positionals accumulate.
        let a = args("scenario a.json b.json");
        assert_eq!(a.positionals.len(), 2);
        // Commands without positionals reject stray arguments.
        assert!(args_err("fig4 stray").contains("unexpected argument"));
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = args("fig4 --set timing.trcd=12 --set mc.scheduler=bliss --cores=8");
        assert_eq!(a.get("cores"), Some("8"));
        let sets = a.set_overrides().unwrap();
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0], ("timing.trcd".to_string(), "12".to_string()));
        assert_eq!(sets[1].1, "bliss");
        // Only flags declared repeatable may repeat.
        assert!(args_err("fig4 --cores 4 --cores 8").contains("only once"));
    }

    #[test]
    fn unknown_commands_and_flags_list_choices() {
        let e = args_err("bogus");
        assert!(e.contains("unknown command"), "{e:?}");
        assert!(e.contains("fig4"), "{e:?}");
        assert!(!e.contains("simulate"), "deprecated aliases must not be advertised: {e:?}");
        let e = args_err("fig4 --corse 8");
        assert!(e.contains("--cores"), "valid flags missing: {e:?}");
        // Missing value for a valued flag.
        assert!(args_err("fig4 --cores").contains("expects a value"));
        // Value handed to a boolean flag.
        assert!(args_err("scenario --validate=yes x.json").contains("takes no value"));
    }

    #[test]
    fn aliases_and_deprecated_commands_resolve() {
        assert_eq!(args("scn x.json").command, "scenario");
        let a = args("simulate");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.spec.deprecated, Some("run"));
        // Bare invocation falls back to help; -h style too.
        let a = Args::parse_with(std::iter::empty(), TEST_COMMANDS, TEST_COMMON).unwrap();
        assert_eq!(a.command, "help");
        assert_eq!(args("--help").command, "help");
    }

    #[test]
    fn memoization_flags() {
        let a = args("figures --result-cache /tmp/cc-results --no-memo");
        assert_eq!(a.get("result-cache"), Some("/tmp/cc-results"));
        assert!(a.flag("no-memo"));
        let plain = args("figures");
        assert!(plain.get("result-cache").is_none());
        assert!(!plain.flag("no-memo"));
    }

    #[test]
    fn defaults_apply() {
        let a = args("simulate");
        assert_eq!(a.get_u64("cores", 1).unwrap(), 1);
        assert_eq!(a.get_str("workload", "mcf"), "mcf");
        assert_eq!(a.get_f64("duration", 1.0).unwrap(), 1.0);
    }

    #[test]
    fn mechanism_aliases() {
        assert_eq!(
            args("fig4 --mechanism cc").mechanism(MechanismKind::Baseline).unwrap(),
            MechanismKind::ChargeCache
        );
        assert_eq!(
            args("fig4 --mechanism ll-dram").mechanism(MechanismKind::Baseline).unwrap(),
            MechanismKind::LlDram
        );
        let e = args("fig4 --mechanism bogus")
            .mechanism(MechanismKind::Baseline)
            .unwrap_err()
            .to_string();
        assert!(e.contains("cc+nuat"), "valid names missing from {e:?}");
    }

    #[test]
    fn scheduler_aliases() {
        assert_eq!(
            args("fig4 --scheduler fcfs").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::Fcfs
        );
        assert_eq!(
            args("fig4 --scheduler BLISS").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::Bliss
        );
        assert_eq!(
            args("fig4").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::FrFcfs
        );
        let e = args("fig4 --scheduler lifo")
            .scheduler(SchedulerKind::FrFcfs)
            .unwrap_err()
            .to_string();
        assert!(e.contains("fr-fcfs | fcfs | bliss"), "{e:?}");
    }

    #[test]
    fn bad_numeric_options_error() {
        assert!(args("fig4 --insts abc").get_u64("insts", 0).is_err());
        assert!(args("fig4 --threads many").get_usize("threads", 0).is_err());
    }

    #[test]
    fn help_renders_from_the_table() {
        let help = render_help("title", TEST_COMMANDS, TEST_COMMON);
        assert!(help.contains("fig4"));
        assert!(help.contains("speedup figure"));
        assert!(help.contains("--set PATH=VALUE"));
        assert!(help.contains("deprecated aliases: simulate -> `run`"));
        let cmd = render_command_help(&TEST_COMMANDS[1], TEST_COMMON);
        assert!(cmd.contains("chargecache scenario FILE..."));
        assert!(cmd.contains("--validate"));
        assert!(cmd.contains("aliases: scn"));
    }
}
