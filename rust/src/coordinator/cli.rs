//! Minimal CLI argument parser (offline build — no clap): a subcommand
//! followed by `--key value` / `--flag` options.

use std::collections::HashMap;

use crate::bail;
use crate::error::{Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --option, got {a:?}"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key, it.next().unwrap());
                }
                _ => flags.push(key),
            }
        }
        Ok(Self { command, opts, flags })
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse a scheduler policy name (`--scheduler`).
    pub fn scheduler(
        &self,
        default: crate::controller::SchedulerKind,
    ) -> Result<crate::controller::SchedulerKind> {
        use crate::controller::SchedulerKind as S;
        match self.get("scheduler") {
            None => Ok(default),
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "fr-fcfs" | "frfcfs" => Ok(S::FrFcfs),
                "fcfs" => Ok(S::Fcfs),
                "bliss" => Ok(S::Bliss),
                other => bail!("unknown scheduler {other:?} (fr-fcfs | fcfs | bliss)"),
            },
        }
    }

    /// Parse a mechanism name.
    pub fn mechanism(&self, default: crate::latency::MechanismKind) -> Result<crate::latency::MechanismKind> {
        use crate::latency::MechanismKind as M;
        match self.get("mechanism") {
            None => Ok(default),
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "baseline" | "base" => Ok(M::Baseline),
                "chargecache" | "cc" => Ok(M::ChargeCache),
                "nuat" => Ok(M::Nuat),
                "cc+nuat" | "chargecachenuat" | "combined" => Ok(M::ChargeCacheNuat),
                "lldram" | "ll-dram" | "ll" => Ok(M::LlDram),
                other => bail!("unknown mechanism {other:?}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::MechanismKind;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_opts_flags() {
        let a = args("fig4 --cores 8 --insts 100000 --quick");
        assert_eq!(a.command, "fig4");
        assert_eq!(a.get_u64("cores", 1).unwrap(), 8);
        assert_eq!(a.get_u64("insts", 0).unwrap(), 100000);
        assert!(a.flag("quick"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn parses_threads_pin() {
        assert_eq!(args("fig4 --threads 3").get_usize("threads", 0).unwrap(), 3);
        assert_eq!(args("fig4").get_usize("threads", 0).unwrap(), 0);
        assert!(args("fig4 --threads many").get_usize("threads", 0).is_err());
    }

    #[test]
    fn memoization_flags() {
        // `figures --result-cache DIR` / `--no-memo` (job-graph knobs).
        let a = args("figures --result-cache /tmp/cc-results --no-memo");
        assert_eq!(a.get("result-cache"), Some("/tmp/cc-results"));
        assert!(a.flag("no-memo"));
        let plain = args("figures");
        assert!(plain.get("result-cache").is_none());
        assert!(!plain.flag("no-memo"));
    }

    #[test]
    fn defaults_apply() {
        let a = args("simulate");
        assert_eq!(a.get_u64("cores", 1).unwrap(), 1);
        assert_eq!(a.get_str("workload", "mcf"), "mcf");
        assert_eq!(a.get_f64("duration", 1.0).unwrap(), 1.0);
    }

    #[test]
    fn mechanism_aliases() {
        assert_eq!(
            args("x --mechanism cc").mechanism(MechanismKind::Baseline).unwrap(),
            MechanismKind::ChargeCache
        );
        assert_eq!(
            args("x --mechanism ll-dram").mechanism(MechanismKind::Baseline).unwrap(),
            MechanismKind::LlDram
        );
        assert!(args("x --mechanism bogus").mechanism(MechanismKind::Baseline).is_err());
    }

    #[test]
    fn scheduler_aliases() {
        use crate::controller::SchedulerKind;
        assert_eq!(
            args("x --scheduler fcfs").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::Fcfs
        );
        assert_eq!(
            args("x --scheduler BLISS").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::Bliss
        );
        assert_eq!(
            args("x").scheduler(SchedulerKind::FrFcfs).unwrap(),
            SchedulerKind::FrFcfs
        );
        assert!(args("x --scheduler lifo").scheduler(SchedulerKind::FrFcfs).is_err());
    }

    #[test]
    fn bad_option_errors() {
        assert!(Args::parse(vec!["cmd".into(), "oops".into()]).is_err());
        assert!(args("x --insts abc").get_u64("insts", 0).is_err());
    }
}
