//! Row-Level Temporal Locality (RLTL) measurement — the paper's Sec. 3
//! observation and Fig. 1.
//!
//! *t-RLTL* = fraction of row activations that occur within time `t` after
//! the **previous precharge of the same row**. The tracker records the last
//! precharge cycle per (rank, bank, row) and buckets each activation's
//! re-open interval.

use std::collections::HashMap;

use crate::latency::RowKey;

/// Fig. 1 time intervals in milliseconds.
pub const RLTL_INTERVALS_MS: [f64; 9] = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0];

#[derive(Debug, Clone)]
pub struct RltlTracker {
    /// Last precharge cycle per row.
    last_pre: HashMap<RowKey, u64>,
    /// Interval bounds in bus cycles (ascending), matching RLTL_INTERVALS_MS.
    bounds: Vec<u64>,
    /// Activations whose re-open interval fell within each bound.
    pub counts: Vec<u64>,
    /// Total activations observed (incl. first-touch activations).
    pub activations: u64,
}

impl RltlTracker {
    pub fn new(tck_ns: f64) -> Self {
        let bounds = RLTL_INTERVALS_MS
            .iter()
            .map(|ms| (ms * 1e6 / tck_ns) as u64)
            .collect::<Vec<_>>();
        let n = bounds.len();
        Self { last_pre: HashMap::new(), bounds, counts: vec![0; n], activations: 0 }
    }

    /// Record an activation of `key` at bus cycle `now`.
    pub fn on_activate(&mut self, now: u64, key: RowKey) {
        self.activations += 1;
        if let Some(&pre) = self.last_pre.get(&key) {
            let delta = now.saturating_sub(pre);
            for (i, &b) in self.bounds.iter().enumerate() {
                if delta <= b {
                    self.counts[i] += 1;
                }
            }
        }
    }

    /// Record a precharge of `key` at bus cycle `now`.
    pub fn on_precharge(&mut self, now: u64, key: RowKey) {
        self.last_pre.insert(key, now);
    }

    /// t-RLTL fractions aligned with [`RLTL_INTERVALS_MS`].
    pub fn fractions(&self) -> Vec<f64> {
        self.counts
            .iter()
            .map(|&c| if self.activations == 0 { 0.0 } else { c as f64 / self.activations as f64 })
            .collect()
    }

    /// t-RLTL for one interval (ms must be one of RLTL_INTERVALS_MS).
    pub fn fraction_at_ms(&self, ms: f64) -> f64 {
        let idx = RLTL_INTERVALS_MS
            .iter()
            .position(|&m| (m - ms).abs() < 1e-12)
            .expect("interval not tracked");
        self.fractions()[idx]
    }

    /// Merge another tracker's counts (for multi-channel aggregation).
    pub fn merge(&mut self, other: &RltlTracker) {
        assert_eq!(self.bounds, other.bounds);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.activations += other.activations;
    }

    pub fn reset_counts(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.activations = 0;
    }

    /// Checkpoint: map entries sorted by packed key for a canonical
    /// stream (iteration order itself never affects simulation).
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::RLTL);
        let mut pres: Vec<(u64, u64)> = self.last_pre.iter().map(|(k, &v)| (k.0, v)).collect();
        pres.sort_unstable();
        enc.usize(pres.len());
        for (k, v) in pres {
            enc.u64(k);
            enc.u64(v);
        }
        enc.usize(self.counts.len());
        for &c in &self.counts {
            enc.u64(c);
        }
        enc.u64(self.activations);
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::RLTL)?;
        let n = dec.usize()?;
        self.last_pre.clear();
        for _ in 0..n {
            let k = dec.u64()?;
            let v = dec.u64()?;
            self.last_pre.insert(RowKey(k), v);
        }
        if dec.usize()? != self.counts.len() {
            return None; // bucket count is tck-derived shape
        }
        for c in self.counts.iter_mut() {
            *c = dec.u64()?;
        }
        self.activations = dec.u64()?;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(row: u32) -> RowKey {
        RowKey::new(0, 0, row)
    }

    fn tracker() -> RltlTracker {
        RltlTracker::new(1.25) // 800 MHz: 1 ms = 800_000 cycles
    }

    #[test]
    fn first_activation_counts_in_denominator_only() {
        let mut t = tracker();
        t.on_activate(0, key(1));
        assert_eq!(t.activations, 1);
        assert!(t.fractions().iter().all(|&f| f == 0.0));
    }

    #[test]
    fn reopen_within_interval_counts() {
        let mut t = tracker();
        t.on_activate(0, key(1));
        t.on_precharge(100, key(1));
        t.on_activate(200, key(1)); // 100 cycles later: within every bucket
        assert_eq!(t.activations, 2);
        let f = t.fractions();
        assert!(f.iter().all(|&x| (x - 0.5).abs() < 1e-12));
    }

    #[test]
    fn bucket_boundaries_are_respected() {
        let mut t = tracker();
        let ms = 800_000u64; // 1 ms in cycles
        t.on_precharge(0, key(2));
        t.on_activate(ms * 3, key(2)); // 3 ms: misses 0.125-2 ms, hits 4-32
        let f = t.fractions();
        assert_eq!(f[..5], [0.0; 5]); // 0.125, 0.25, 0.5, 1, 2 ms
        assert!(f[5] > 0.0); // 4 ms
        assert!(f[8] > 0.0); // 32 ms
    }

    #[test]
    fn cumulative_over_intervals() {
        // Larger t always captures at least as many activations.
        let mut t = tracker();
        for i in 0..10u64 {
            let k = key(i as u32);
            t.on_precharge(i * 200_000, k);
            t.on_activate(i * 200_000 + i * 150_000, k);
        }
        let f = t.fractions();
        for w in f.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = tracker();
        let mut b = tracker();
        a.on_precharge(0, key(1));
        a.on_activate(10, key(1));
        b.on_precharge(0, key(2));
        b.on_activate(10, key(2));
        a.merge(&b);
        assert_eq!(a.activations, 2);
        assert!((a.fractions()[0] - 1.0).abs() < 1e-12);
    }
}
