//! Access-stream analyses: row-level temporal locality (Fig. 1) and
//! row-reuse distance (Sec. 8.3.2 distinguishes the two).

pub mod reuse;
pub mod rltl;

pub use reuse::ReuseTracker;
pub use rltl::RltlTracker;
