//! Row-reuse distance (Kandemir et al., SIGMETRICS'15), referenced by the
//! paper to explain where ChargeCache trails LL-DRAM (mcf/omnetpp-class
//! workloads have high reuse distance, so HCRAC entries are evicted or
//! expire before the row returns).
//!
//! Reuse distance of an activation = number of *other-row* activations in
//! the same bank since the previous activation of this row.

use std::collections::HashMap;

use crate::latency::RowKey;

#[derive(Debug, Clone, Default)]
pub struct ReuseTracker {
    /// Per-bank activation counter.
    bank_acts: HashMap<u64, u64>,
    /// Bank counter value at each row's previous activation.
    last_act: HashMap<RowKey, u64>,
    /// Histogram buckets: <16, <64, <256, <1024, >=1024.
    pub hist: [u64; 5],
    pub samples: u64,
}

impl ReuseTracker {
    pub fn new() -> Self {
        Self::default()
    }

    fn bank_of(key: RowKey) -> u64 {
        key.0 >> 32 // (rank, bank) bits
    }

    pub fn on_activate(&mut self, key: RowKey) {
        let bank = Self::bank_of(key);
        let counter = self.bank_acts.entry(bank).or_insert(0);
        *counter += 1;
        let now = *counter;
        if let Some(prev) = self.last_act.insert(key, now) {
            let dist = now - prev - 1;
            let bucket = match dist {
                0..=15 => 0,
                16..=63 => 1,
                64..=255 => 2,
                256..=1023 => 3,
                _ => 4,
            };
            self.hist[bucket] += 1;
            self.samples += 1;
        }
    }

    /// Checkpoint: both maps sorted by key for a canonical stream.
    pub fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        use crate::sim::checkpoint::tags;
        enc.tag(tags::REUSE);
        let mut acts: Vec<(u64, u64)> = self.bank_acts.iter().map(|(&k, &v)| (k, v)).collect();
        acts.sort_unstable();
        enc.usize(acts.len());
        for (k, v) in acts {
            enc.u64(k);
            enc.u64(v);
        }
        let mut last: Vec<(u64, u64)> = self.last_act.iter().map(|(k, &v)| (k.0, v)).collect();
        last.sort_unstable();
        enc.usize(last.len());
        for (k, v) in last {
            enc.u64(k);
            enc.u64(v);
        }
        for &h in &self.hist {
            enc.u64(h);
        }
        enc.u64(self.samples);
    }

    pub fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        use crate::sim::checkpoint::tags;
        dec.tag(tags::REUSE)?;
        let n = dec.usize()?;
        self.bank_acts.clear();
        for _ in 0..n {
            let k = dec.u64()?;
            let v = dec.u64()?;
            self.bank_acts.insert(k, v);
        }
        let m = dec.usize()?;
        self.last_act.clear();
        for _ in 0..m {
            let k = dec.u64()?;
            let v = dec.u64()?;
            self.last_act.insert(RowKey(k), v);
        }
        for h in self.hist.iter_mut() {
            *h = dec.u64()?;
        }
        self.samples = dec.u64()?;
        Some(())
    }

    /// Mean reuse-distance bucket midpoint (coarse scalar for reporting).
    pub fn mean_bucket(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        let mids = [8.0, 40.0, 160.0, 640.0, 2048.0];
        self.hist
            .iter()
            .zip(mids)
            .map(|(&c, m)| c as f64 * m)
            .sum::<f64>()
            / self.samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(bank: u32, row: u32) -> RowKey {
        RowKey::new(0, bank, row)
    }

    #[test]
    fn immediate_reuse_is_distance_zero() {
        let mut t = ReuseTracker::new();
        t.on_activate(key(0, 1));
        t.on_activate(key(0, 1));
        assert_eq!(t.samples, 1);
        assert_eq!(t.hist[0], 1);
    }

    #[test]
    fn interleaved_rows_increase_distance() {
        let mut t = ReuseTracker::new();
        t.on_activate(key(0, 1));
        for r in 2..20 {
            t.on_activate(key(0, r));
        }
        t.on_activate(key(0, 1)); // 18 other activations in between
        assert_eq!(t.hist[1], 1);
    }

    #[test]
    fn distances_are_per_bank() {
        let mut t = ReuseTracker::new();
        t.on_activate(key(0, 1));
        for r in 0..100 {
            t.on_activate(key(1, r)); // other bank: must not count
        }
        t.on_activate(key(0, 1));
        assert_eq!(t.hist[0], 1);
    }
}
