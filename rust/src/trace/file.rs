//! Trace file I/O — Ramulator-compatible CPU trace format.
//!
//! Each line: `<bubbles> <hex line addr> [W]`, e.g. `7 0x1a2b3c` or
//! `3 0x44 W`. `gen-traces` writes these; `simulate --trace-file` replays
//! them (looping at EOF, like Ramulator's trace wrap-around).

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result, SimError};

use super::{TraceEntry, TraceSource};

/// Parse one trace line (empty/comment lines -> None).
pub fn parse_line(line: &str) -> Result<Option<TraceEntry>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let bubbles: u32 = parts
        .next()
        .context("missing bubble count")?
        .parse()
        .context("bad bubble count")?;
    let addr_s = parts.next().context("missing address")?;
    let line_addr = if let Some(hex) = addr_s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).context("bad hex address")?
    } else {
        addr_s.parse().context("bad address")?
    };
    let is_write = match parts.next() {
        None => false,
        Some("W") | Some("w") => true,
        Some("R") | Some("r") => false,
        Some(x) => bail!("bad access type {x:?}"),
    };
    Ok(Some(TraceEntry { bubbles, line_addr, is_write }))
}

/// Write `n` records from `src` to `path`.
pub fn write_trace<P: AsRef<Path>>(path: P, src: &mut dyn TraceSource, n: u64) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# chargecache trace: <bubbles> <line addr hex> [W]")?;
    for _ in 0..n {
        let e = src.next_entry();
        if e.is_write {
            writeln!(w, "{} {:#x} W", e.bubbles, e.line_addr)?;
        } else {
            writeln!(w, "{} {:#x}", e.bubbles, e.line_addr)?;
        }
    }
    Ok(())
}

/// In-memory replaying trace (loops at the end).
pub struct FileTrace {
    entries: Vec<TraceEntry>,
    pos: usize,
}

impl FileTrace {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let mut text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        crate::faulthooks::maybe_truncate_trace(&mut text);
        Self::from_text(&text, &path.display().to_string())
    }

    /// Parse trace text, attributing any malformed line — including one
    /// cut short by a truncated read — to `file` at its byte offset
    /// ([`SimError::ParseAt`]); never a panic. Offsets assume `\n` line
    /// endings (what [`write_trace`] emits).
    pub fn from_text(text: &str, file: &str) -> Result<Self> {
        let mut entries = Vec::new();
        let mut offset = 0u64;
        for line in text.lines() {
            match parse_line(line) {
                Ok(Some(e)) => entries.push(e),
                Ok(None) => {}
                Err(e) => {
                    return Err(SimError::ParseAt {
                        file: file.to_string(),
                        offset,
                        msg: e.to_string(),
                    })
                }
            }
            offset += line.len() as u64 + 1;
        }
        if entries.is_empty() {
            bail!("empty trace file {file}");
        }
        Ok(Self { entries, pos: 0 })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl TraceSource for FileTrace {
    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        // Entries are loaded from the file path (immutable shape); only
        // the replay cursor is runtime state. The length guards against
        // restoring onto a different trace file.
        enc.usize(self.entries.len());
        enc.usize(self.pos);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        if dec.usize()? != self.entries.len() {
            return None;
        }
        let pos = dec.usize()?;
        if pos >= self.entries.len() {
            return None;
        }
        self.pos = pos;
        Some(())
    }

    fn next_entry(&mut self) -> TraceEntry {
        let e = self.entries[self.pos];
        self.pos = (self.pos + 1) % self.entries.len();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_read_and_write_lines() {
        assert_eq!(
            parse_line("7 0x1a2b").unwrap(),
            Some(TraceEntry { bubbles: 7, line_addr: 0x1a2b, is_write: false })
        );
        assert_eq!(
            parse_line("3 68 W").unwrap(),
            Some(TraceEntry { bubbles: 3, line_addr: 68, is_write: true })
        );
        assert_eq!(parse_line("# comment").unwrap(), None);
        assert_eq!(parse_line("").unwrap(), None);
        assert!(parse_line("x y").is_err());
        assert!(parse_line("1 0x10 Q").is_err());
    }

    #[test]
    fn truncated_input_reports_file_and_byte_offset() {
        // A read cut off mid-token: the error names the file and the
        // byte offset of the offending line, and nothing panics.
        let text = "# header\n7 0x1a2b\n3 0x";
        let err = FileTrace::from_text(text, "t.trace").unwrap_err();
        match err {
            SimError::ParseAt { ref file, offset, ref msg } => {
                assert_eq!(file, "t.trace");
                assert_eq!(offset, 18, "offset of the truncated line");
                assert!(msg.contains("bad hex address"), "{msg:?}");
            }
            other => panic!("expected ParseAt, got {other:?}"),
        }
        // A clean prefix still loads.
        assert_eq!(FileTrace::from_text("# header\n7 0x1a2b\n", "t.trace").unwrap().len(), 1);
    }

    #[test]
    fn round_trip_through_file() {
        use crate::trace::{Profile, SynthTrace};
        let dir = std::env::temp_dir().join("cc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let p = Profile::by_name("gcc").unwrap();
        let mut src = SynthTrace::new(p, 11, 0);
        write_trace(&path, &mut src, 500).unwrap();

        let mut reference = SynthTrace::new(p, 11, 0);
        let mut replay = FileTrace::load(&path).unwrap();
        assert_eq!(replay.len(), 500);
        for _ in 0..500 {
            assert_eq!(replay.next_entry(), reference.next_entry());
        }
        // Loops at the end.
        let mut reference2 = SynthTrace::new(p, 11, 0);
        assert_eq!(replay.next_entry(), reference2.next_entry());
    }
}
