//! Synthetic trace generator: turns a [`Profile`] into an infinite,
//! deterministic instruction stream.

use super::profile::{Pattern, Profile};
use super::rng::XorShift64;
use super::{TraceEntry, TraceSource};

/// Pointer-chase burst length (dependent accesses before re-randomizing).
const CHASE_BURST: u32 = 4;

/// Deterministic synthetic trace for one core.
pub struct SynthTrace {
    profile: Profile,
    rng: XorShift64,
    /// Base line address of this core's region (separate memory regions
    /// per core, as the paper notes for multiprogrammed workloads).
    base: u64,
    /// Per-stream cursors (streaming/strided patterns).
    cursors: Vec<u64>,
    next_stream: usize,
    /// Pointer-chase state.
    chase_pos: u64,
    chase_left: u32,
    /// Strided-pattern burst position (accesses left on current stream).
    stride_burst: u32,
    /// Spatial follow-through for Random/PointerChase: objects span
    /// several cache lines, so each random jump is followed by a few
    /// sequential neighbour lines (real-workload row-buffer locality).
    seq_pos: u64,
    seq_left: u32,
    /// Zipf-style temporal reuse: most irregular accesses fall in a hot
    /// subset (cache-resident in real workloads); the rest sweep the full
    /// working set. Keeps the DRAM-visible stream irregular while giving
    /// the LLC a realistic hit rate.
    hot_lines: u64,
}

/// Fraction of irregular accesses that target the hot subset.
const HOT_FRAC: f64 = 0.75;
/// Hot-subset cap (256 KiB in lines — LLC-resident even with 8 cores
/// sharing the 4 MiB LLC).
const HOT_CAP_LINES: u64 = 4 * 1024;

impl SynthTrace {
    /// `region` selects the core's address region (separate per core).
    pub fn new(profile: &Profile, seed: u64, region: u64) -> Self {
        let mut rng = XorShift64::new(seed ^ 0xDEAD_BEEF);
        let streams = match profile.pattern {
            Pattern::Strided { streams, .. } => streams.max(1) as usize,
            Pattern::Stream => 1,
            _ => 1,
        };
        let ws = profile.ws_lines.max(1);
        let cursors = (0..streams).map(|_| rng.below(ws)).collect();
        let chase_pos = rng.below(ws);
        Self {
            profile: *profile,
            rng,
            base: region << 36, // regions 64 GiB apart (line granularity)
            cursors,
            next_stream: 0,
            chase_pos,
            chase_left: 0,
            stride_burst: 0,
            seq_pos: 0,
            seq_left: 0,
            hot_lines: (profile.ws_lines / 4).clamp(1, HOT_CAP_LINES),
        }
    }

    /// Zipf-ish irregular target: hot subset with HOT_FRAC, else full WS.
    #[inline]
    fn irregular_target(&mut self, ws: u64) -> u64 {
        if self.rng.f64() < HOT_FRAC {
            self.rng.below(self.hot_lines.min(ws))
        } else {
            self.rng.below(ws)
        }
    }

    /// Random jump with spatial follow-through (see `seq_left`).
    #[inline]
    fn jump_with_locality(&mut self, target: u64, ws: u64) -> u64 {
        if self.seq_left > 0 {
            self.seq_left -= 1;
            self.seq_pos = (self.seq_pos + 1) % ws;
            return self.seq_pos;
        }
        // 1-4 sequential neighbours follow each jump.
        self.seq_left = self.rng.below(4) as u32 + 1;
        self.seq_pos = target;
        target
    }

    #[inline]
    fn ws(&self) -> u64 {
        self.profile.ws_lines.max(1)
    }

    /// Scatter logical row-groups across the physical row space (page
    /// allocation): a real OS maps a working set's pages all over DRAM,
    /// not into rows 0..N. Keeps within-row spatial locality (low 10 bits
    /// = col+bank untouched) while permuting the 16 row bits with an odd
    /// multiplier (a bijection mod 2^16), salted per region.
    #[inline]
    fn scatter(&self, logical_line: u64) -> u64 {
        const ROW_SHIFT: u64 = 10; // cols(7) + banks(3) in the default org
        let within = logical_line & ((1 << ROW_SHIFT) - 1);
        let group = logical_line >> ROW_SHIFT;
        let salt = self.base >> 36;
        let permuted = (group.wrapping_mul(40503).wrapping_add(salt * 0x9E37)) & 0xFFFF
            | (group >> 16 << 16); // keep giant-WS bits beyond the row field
        (permuted << ROW_SHIFT) | within
    }

    fn next_line(&mut self) -> u64 {
        let ws = self.ws();
        let off = match self.profile.pattern {
            Pattern::Stream => {
                let c = &mut self.cursors[0];
                *c = (*c + 1) % ws;
                *c
            }
            Pattern::Strided { stride, .. } => {
                // Stencil-style: a few consecutive touches per stream
                // before rotating, so same-row accesses arrive together
                // (matters for FR-FCFS row-hit batching). Burst length is
                // jittered — fixed lengths resonate with DRAM timing and
                // produce pathological synthetic schedules.
                let idx = self.next_stream;
                self.stride_burst = self.stride_burst.saturating_sub(1);
                if self.stride_burst == 0 {
                    self.stride_burst = 2 + self.rng.below(5) as u32;
                    self.next_stream = (self.next_stream + 1) % self.cursors.len();
                }
                let c = &mut self.cursors[idx];
                *c = (*c + stride) % ws;
                *c
            }
            Pattern::Random => {
                let target = self.irregular_target(ws);
                self.jump_with_locality(target, ws)
            }
            Pattern::PointerChase => {
                if self.seq_left > 0 {
                    self.jump_with_locality(0, ws)
                } else {
                    if self.chase_left == 0 {
                        self.chase_pos = self.irregular_target(ws);
                        self.chase_left = CHASE_BURST;
                    }
                    self.chase_left -= 1;
                    // Dependent hop: pseudo-random walk from position.
                    self.chase_pos = (self
                        .chase_pos
                        .wrapping_mul(0x5851_F42D_4C95_7F2D)
                        .wrapping_add(0x14057B7EF767814F))
                        % ws;
                    let t = self.chase_pos;
                    self.jump_with_locality(t, ws)
                }
            }
            Pattern::Mixed { stream_frac } => {
                if self.rng.f64() < stream_frac {
                    let c = &mut self.cursors[0];
                    *c = (*c + 1) % ws;
                    *c
                } else {
                    self.irregular_target(ws)
                }
            }
        };
        self.base + self.scatter(off)
    }
}

impl TraceSource for SynthTrace {
    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        enc.u64(self.rng.state());
        enc.usize(self.cursors.len());
        for &c in &self.cursors {
            enc.u64(c);
        }
        enc.usize(self.next_stream);
        enc.u64(self.chase_pos);
        enc.u32(self.chase_left);
        enc.u32(self.stride_burst);
        enc.u64(self.seq_pos);
        enc.u32(self.seq_left);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        self.rng = XorShift64::from_state(dec.u64()?);
        let n = dec.usize()?;
        if n != self.cursors.len() {
            return None; // stream count is profile-derived shape
        }
        for c in self.cursors.iter_mut() {
            *c = dec.u64()?;
        }
        self.next_stream = dec.usize()?;
        self.chase_pos = dec.u64()?;
        self.chase_left = dec.u32()?;
        self.stride_burst = dec.u32()?;
        self.seq_pos = dec.u64()?;
        self.seq_left = dec.u32()?;
        Some(())
    }

    fn next_entry(&mut self) -> TraceEntry {
        // Geometric-ish jitter around inst_per_mem (±50%) keeps cores from
        // lock-stepping in multiprogrammed mixes.
        let base = self.profile.inst_per_mem.max(1);
        let jitter = (self.rng.below(base as u64) as u32).min(base);
        let bubbles = (base - 1).saturating_sub(jitter / 2) + jitter;
        let is_write = self.rng.f64() < self.profile.write_frac;
        TraceEntry { bubbles, line_addr: self.next_line(), is_write }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::profile::PROFILES;

    fn profile(name: &str) -> &'static Profile {
        Profile::by_name(name).unwrap()
    }

    #[test]
    fn deterministic_per_seed() {
        let p = profile("mcf");
        let mut a = SynthTrace::new(p, 1, 0);
        let mut b = SynthTrace::new(p, 1, 0);
        for _ in 0..1000 {
            assert_eq!(a.next_entry(), b.next_entry());
        }
    }

    #[test]
    fn checkpoint_resumes_the_stream_exactly() {
        use crate::sim::checkpoint::{Dec, Enc};
        for p in PROFILES.iter() {
            let mut t = SynthTrace::new(p, 13, 1);
            for _ in 0..500 {
                t.next_entry();
            }
            let mut enc = Enc::new();
            t.export_state(&mut enc);
            let words = enc.into_words();
            // Restore into a *fresh* instance (differently advanced).
            let mut r = SynthTrace::new(p, 13, 1);
            for _ in 0..7 {
                r.next_entry();
            }
            let mut dec = Dec::new(&words);
            r.import_state(&mut dec).unwrap();
            assert!(dec.finished(), "{}: import must consume everything", p.name);
            for _ in 0..500 {
                assert_eq!(r.next_entry(), t.next_entry(), "{}", p.name);
            }
        }
    }

    #[test]
    fn stays_within_region() {
        for p in PROFILES.iter() {
            let mut t = SynthTrace::new(p, 3, 2);
            for _ in 0..2000 {
                let e = t.next_entry();
                assert_eq!(e.line_addr >> 36, 2, "{}", p.name);
            }
        }
    }

    #[test]
    fn distinct_lines_bounded_by_working_set() {
        use std::collections::HashSet;
        let p = profile("gromacs"); // 1 MiB-class working set
        let mut t = SynthTrace::new(p, 3, 0);
        let distinct: HashSet<u64> = (0..100_000).map(|_| t.next_entry().line_addr).collect();
        assert!(distinct.len() as u64 <= p.ws_lines);
    }

    #[test]
    fn stream_pattern_is_sequential_within_a_row() {
        // The scatter permutes 1024-line row-groups but keeps lines inside
        // a group contiguous: consecutive stream accesses off a group
        // boundary differ by exactly 1.
        let p = profile("libquantum");
        let mut t = SynthTrace::new(p, 5, 0);
        let mut consecutive = 0;
        let mut prev = t.next_entry().line_addr;
        for _ in 0..200 {
            let cur = t.next_entry().line_addr;
            if cur == prev + 1 {
                consecutive += 1;
            }
            prev = cur;
        }
        assert!(consecutive > 190, "stream locality destroyed: {consecutive}/200");
    }

    #[test]
    fn scatter_spreads_rows_across_the_row_space() {
        // Page-allocation realism: a small sequential working set must not
        // sit in the lowest rows; its row-groups spread over the 64K range.
        let p = profile("libquantum");
        let mut t = SynthTrace::new(p, 5, 0);
        let mut high_rows = 0;
        for _ in 0..10_000 {
            let e = t.next_entry();
            let row = (e.line_addr >> 10) & 0xFFFF;
            if row > 32_768 {
                high_rows += 1;
            }
        }
        assert!(high_rows > 2_000, "rows not scattered: {high_rows}/10000 high");
    }

    #[test]
    fn write_fraction_approximates_profile() {
        let p = profile("lbm"); // 0.45 writes
        let mut t = SynthTrace::new(p, 7, 0);
        let writes = (0..20_000)
            .filter(|_| t.next_entry().is_write)
            .count();
        let frac = writes as f64 / 20_000.0;
        assert!((frac - 0.45).abs() < 0.03, "write frac {frac}");
    }

    #[test]
    fn random_pattern_mixes_hot_reuse_with_cold_sweep() {
        use std::collections::HashMap;
        let p = profile("tpcc64"); // big-WS random
        let mut t = SynthTrace::new(p, 9, 0);
        let mut counts: HashMap<u64, u64> = HashMap::new();
        for _ in 0..50_000 {
            *counts.entry(t.next_entry().line_addr).or_insert(0) += 1;
        }
        let reused = counts.values().filter(|&&c| c > 1).count();
        let singles = counts.values().filter(|&&c| c == 1).count();
        // Zipf-ish: a reused hot set AND a broad cold tail must both exist.
        assert!(reused > 1_000, "hot-set reuse missing: {reused}");
        assert!(singles > 5_000, "cold sweep missing: {singles}");
    }

    #[test]
    fn different_regions_never_collide() {
        let p = profile("gcc");
        let mut a = SynthTrace::new(p, 1, 0);
        let mut b = SynthTrace::new(p, 1, 1);
        for _ in 0..1000 {
            assert_ne!(a.next_entry().line_addr, b.next_entry().line_addr);
        }
    }
}
