//! The 22 workload profiles (SPEC CPU2006 + TPC + STREAM stand-ins).
//!
//! Parameters are chosen to reproduce each benchmark's published memory
//! character: memory intensity (instructions per memory access), working
//! set (drives LLC miss rate), access pattern (drives row locality and
//! reuse distance), and write fraction. The paper sorts Fig. 4a by RMPKC;
//! the list below spans ~0 (povray) to very high (STREAM/lbm-class).

/// Memory access pattern of a workload region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// Sequential unit-stride streaming over the working set.
    Stream,
    /// `streams` concurrent sequential streams with `stride` lines.
    Strided { stride: u64, streams: u32 },
    /// Uniform random lines over the working set.
    Random,
    /// Random with short dependent bursts (pointer chasing: high reuse
    /// distance, poor row locality — the mcf/omnetpp class).
    PointerChase,
    /// `stream_frac` of accesses stream; the rest are random.
    Mixed { stream_frac: f64 },
}

/// A synthetic workload profile.
#[derive(Debug, Clone, Copy)]
pub struct Profile {
    pub name: &'static str,
    /// Average instructions between memory accesses (incl. the access).
    pub inst_per_mem: u32,
    /// Working set in cache lines (64 B each).
    pub ws_lines: u64,
    pub pattern: Pattern,
    /// Fraction of memory accesses that are writes.
    pub write_frac: f64,
}

impl Profile {
    pub fn by_name(name: &str) -> Option<&'static Profile> {
        PROFILES.iter().find(|p| p.name == name)
    }
    /// Working set in bytes.
    pub fn ws_bytes(&self) -> u64 {
        self.ws_lines * 64
    }
}

const MB: u64 = 1024 * 1024 / 64; // lines per MiB

/// The 22 workloads of the paper's evaluation (Sec. 6.1), as synthetic
/// stand-ins. Ordered roughly by expected RMPKC (ascending), mirroring
/// the paper's Fig. 4a x-axis. `inst_per_mem` is tuned so DRAM-reaching
/// traffic lands in the realistic MPKI range (tens per kilo-instruction
/// for the memory-bound class) rather than saturating the channel.
pub static PROFILES: [Profile; 22] = [
    // LLC-resident: negligible memory traffic.
    Profile { name: "povray", inst_per_mem: 6, ws_lines: MB / 8, pattern: Pattern::Mixed { stream_frac: 0.8 }, write_frac: 0.20 },
    Profile { name: "calculix", inst_per_mem: 6, ws_lines: MB / 4, pattern: Pattern::Stream, write_frac: 0.15 },
    Profile { name: "namd", inst_per_mem: 5, ws_lines: MB / 2, pattern: Pattern::Strided { stride: 2, streams: 4 }, write_frac: 0.20 },
    Profile { name: "gromacs", inst_per_mem: 5, ws_lines: MB, pattern: Pattern::Mixed { stream_frac: 0.6 }, write_frac: 0.25 },
    Profile { name: "h264ref", inst_per_mem: 8, ws_lines: 2 * MB, pattern: Pattern::Stream, write_frac: 0.30 },
    Profile { name: "hmmer", inst_per_mem: 20, ws_lines: 2 * MB, pattern: Pattern::Random, write_frac: 0.25 },
    Profile { name: "gobmk", inst_per_mem: 48, ws_lines: 5 * MB, pattern: Pattern::Random, write_frac: 0.20 },
    Profile { name: "dealII", inst_per_mem: 44, ws_lines: 8 * MB, pattern: Pattern::Mixed { stream_frac: 0.5 }, write_frac: 0.25 },
    Profile { name: "gcc", inst_per_mem: 40, ws_lines: 16 * MB, pattern: Pattern::Mixed { stream_frac: 0.4 }, write_frac: 0.30 },
    Profile { name: "astar", inst_per_mem: 44, ws_lines: 24 * MB, pattern: Pattern::PointerChase, write_frac: 0.15 },
    Profile { name: "tpcc64", inst_per_mem: 40, ws_lines: 96 * MB, pattern: Pattern::Random, write_frac: 0.35 },
    Profile { name: "cactusADM", inst_per_mem: 36, ws_lines: 48 * MB, pattern: Pattern::Strided { stride: 2, streams: 6 }, write_frac: 0.30 },
    Profile { name: "zeusmp", inst_per_mem: 32, ws_lines: 64 * MB, pattern: Pattern::Strided { stride: 2, streams: 8 }, write_frac: 0.30 },
    Profile { name: "sphinx3", inst_per_mem: 28, ws_lines: 32 * MB, pattern: Pattern::Stream, write_frac: 0.10 },
    Profile { name: "GemsFDTD", inst_per_mem: 28, ws_lines: 128 * MB, pattern: Pattern::Strided { stride: 8, streams: 6 }, write_frac: 0.30 },
    Profile { name: "leslie3d", inst_per_mem: 24, ws_lines: 96 * MB, pattern: Pattern::Strided { stride: 1, streams: 8 }, write_frac: 0.30 },
    Profile { name: "soplex", inst_per_mem: 24, ws_lines: 128 * MB, pattern: Pattern::Mixed { stream_frac: 0.5 }, write_frac: 0.20 },
    Profile { name: "omnetpp", inst_per_mem: 28, ws_lines: 96 * MB, pattern: Pattern::PointerChase, write_frac: 0.25 },
    Profile { name: "milc", inst_per_mem: 24, ws_lines: 192 * MB, pattern: Pattern::Random, write_frac: 0.25 },
    Profile { name: "libquantum", inst_per_mem: 20, ws_lines: 32 * MB, pattern: Pattern::Stream, write_frac: 0.25 },
    Profile { name: "mcf", inst_per_mem: 24, ws_lines: 512 * MB, pattern: Pattern::PointerChase, write_frac: 0.20 },
    Profile { name: "lbm", inst_per_mem: 20, ws_lines: 256 * MB, pattern: Pattern::Stream, write_frac: 0.45 },
];

/// The paper's 20 eight-core multiprogrammed mixes: 8 randomly-chosen
/// applications per mix (Sec. 6.1), deterministic in the mix index.
pub fn multicore_mix(mix: usize, cores: usize) -> Vec<&'static Profile> {
    use super::rng::XorShift64;
    let mut rng = XorShift64::new(0xC0FFEE ^ (mix as u64).wrapping_mul(0x9E3779B97F4A7C15));
    (0..cores)
        .map(|_| &PROFILES[rng.below(PROFILES.len() as u64) as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_two_unique_names() {
        use std::collections::HashSet;
        let names: HashSet<_> = PROFILES.iter().map(|p| p.name).collect();
        assert_eq!(names.len(), 22);
    }

    #[test]
    fn lookup_by_name() {
        assert!(Profile::by_name("mcf").is_some());
        assert!(Profile::by_name("nonexistent").is_none());
        assert_eq!(Profile::by_name("mcf").unwrap().ws_lines, 512 * MB);
    }

    #[test]
    fn working_sets_span_llc_boundary() {
        // Some profiles fit the 4 MB LLC (low RMPKC), some far exceed it.
        let fits = PROFILES.iter().filter(|p| p.ws_bytes() <= 2 << 20).count();
        let exceeds = PROFILES.iter().filter(|p| p.ws_bytes() > 64 << 20).count();
        assert!(fits >= 4, "need LLC-resident profiles");
        assert!(exceeds >= 6, "need memory-bound profiles");
    }

    #[test]
    fn mixes_are_deterministic_and_distinct() {
        let a = multicore_mix(0, 8);
        let b = multicore_mix(0, 8);
        assert_eq!(
            a.iter().map(|p| p.name).collect::<Vec<_>>(),
            b.iter().map(|p| p.name).collect::<Vec<_>>()
        );
        let c = multicore_mix(1, 8);
        assert_ne!(
            a.iter().map(|p| p.name).collect::<Vec<_>>(),
            c.iter().map(|p| p.name).collect::<Vec<_>>()
        );
    }
}
