//! Deterministic xorshift64* RNG (the repo builds offline; no rand crate).

#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Raw internal state (checkpointing).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild from a [`XorShift64::state`] value **without** the seed
    /// scrambling `new` applies — the restored stream continues exactly
    /// where the captured one left off.
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift64::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn from_state_resumes_the_stream_exactly() {
        let mut a = XorShift64::new(42);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = XorShift64::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = XorShift64::new(5);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            lo |= v < 0.4;
            hi |= v > 0.6;
        }
        assert!(lo && hi);
    }
}
