//! Workload traces: synthetic generators standing in for the paper's
//! Pin-collected SPEC CPU2006 / TPC / STREAM SimPoint traces.
//!
//! The substitution (DESIGN.md §3): Fig. 4's behaviour is governed by each
//! workload's memory intensity (RMPKC) and row-locality character, both of
//! which the generators control directly via working-set size, access
//! pattern, and memory-instruction density. Profiles are named after the
//! benchmarks in the paper's figures and ordered to reproduce the paper's
//! RMPKC spread.

pub mod file;
pub mod profile;
pub mod rng;
pub mod synth;

pub use profile::{Pattern, Profile, PROFILES};
pub use rng::XorShift64;
pub use synth::SynthTrace;

/// One trace record: `bubbles` non-memory instructions followed by a
/// memory access to cache line `line_addr`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEntry {
    pub bubbles: u32,
    pub line_addr: u64,
    pub is_write: bool,
}

/// Infinite instruction-stream source.
pub trait TraceSource: Send {
    fn next_entry(&mut self) -> TraceEntry;

    /// Serialize replay-cursor state for checkpointing. Stateless (or
    /// test-only) sources keep the default, which writes nothing; the
    /// core wraps these words in a length-prefixed block, so exports and
    /// imports stay paired even across differing implementations.
    fn export_state(&self, _enc: &mut crate::sim::checkpoint::Enc) {}

    /// Restore what [`TraceSource::export_state`] wrote. The default
    /// consumes nothing; `None` signals a corrupt stream.
    fn import_state(&mut self, _dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        Some(())
    }
}
