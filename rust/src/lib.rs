//! # ChargeCache — full-system reproduction
//!
//! Reproduction of *"Exploiting Row-Level Temporal Locality in DRAM to
//! Reduce the Memory Access Latency"* (Hassan et al., summary of the
//! HPCA 2016 ChargeCache paper).
//!
//! The crate is the **architecture layer (L3)** of a three-layer
//! hardware-codesign stack:
//!
//! * **L1 (Pallas)** — `python/compile/kernels/bitline.py`: batched
//!   transient simulation of the DRAM cell/bitline/sense-amp circuit
//!   (the paper's SPICE replacement).
//! * **L2 (JAX)** — `python/compile/model.py`: leakage + latency-table
//!   charge model, AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — a cycle-accurate DDR3 simulator
//!   (Ramulator-equivalent), trace-driven CPU cores + LLC, a memory
//!   controller implementing **ChargeCache** (HCRAC) plus the NUAT and
//!   LL-DRAM comparison mechanisms, DRAM energy / area models, and the
//!   experiment coordinator that regenerates every figure in the paper.
//!
//! Python never runs on the simulation path: the [`runtime`] module loads
//! the AOT artifacts via PJRT (the `xla` crate) at startup to build the
//! charge→timing tables; everything after that is pure Rust.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod cpu;
pub mod dram;
pub mod energy;
pub mod latency;
pub mod runtime;
pub mod sim;
pub mod trace;

pub use config::SystemConfig;
pub use latency::MechanismKind;
pub use sim::system::System;
