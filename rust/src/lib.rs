//! # ChargeCache — full-system reproduction
//!
//! Reproduction of *"Exploiting Row-Level Temporal Locality in DRAM to
//! Reduce the Memory Access Latency"* (Hassan et al., summary of the
//! HPCA 2016 ChargeCache paper).
//!
//! The crate is the **architecture layer (L3)** of a three-layer
//! hardware-codesign stack:
//!
//! * **L1 (Pallas)** — `python/compile/kernels/bitline.py`: batched
//!   transient simulation of the DRAM cell/bitline/sense-amp circuit
//!   (the paper's SPICE replacement).
//! * **L2 (JAX)** — `python/compile/model.py`: leakage + latency-table
//!   charge model, AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — a cycle-accurate DDR3 simulator
//!   (Ramulator-equivalent), trace-driven CPU cores + LLC, a memory
//!   controller implementing **ChargeCache** (HCRAC) plus the NUAT and
//!   LL-DRAM comparison mechanisms, DRAM energy / area models, and the
//!   experiment coordinator that regenerates every figure in the paper.
//!
//! The simulation loop is driven by the event kernel in [`sim::engine`]:
//! components surface *wake times* (earliest cycle they could act) and
//! the clock fast-forwards to the global minimum instead of ticking
//! every cycle. The original per-cycle loop survives as
//! [`sim::LoopMode::StrictTick`] and differential tests assert the two
//! produce bit-identical results.
//!
//! Python never runs on the simulation path: with the off-by-default
//! `pjrt` feature, the [`runtime`] module loads the AOT artifacts via
//! PJRT (the `xla` crate) at startup to build the charge→timing tables.
//! The default build uses the pure-Rust analytic circuit model instead
//! and has zero external dependencies.
//!
//! See `DESIGN.md` (repo root) for the architecture and per-experiment
//! index.

pub mod analysis;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod cpu;
pub mod dram;
pub mod energy;
pub mod error;
pub mod faulthooks;
pub mod latency;
pub mod runtime;
pub mod sim;
pub mod trace;

pub use config::SystemConfig;
pub use latency::MechanismKind;
pub use sim::engine::LoopMode;
pub use sim::system::System;
