//! Charge → timing table: the codesign bridge between the circuit layer
//! (L1/L2, JAX+Pallas artifacts) and the architecture layer.
//!
//! The table maps *row age* (time since the row's charge was last
//! replenished by an activation) to the legal tRCD/tRAS **reduction** in
//! bus cycles. The memory controller configures ChargeCache with the
//! reduction at its caching duration: entries younger than the duration
//! are guaranteed at least that much charge, so the reduction is safe for
//! every HCRAC hit (paper Sec. 5 / 6.2).
//!
//! Two constructors:
//! * [`TimingTable::from_runtime`] — execute the AOT-lowered
//!   `latency_table` HLO artifact via PJRT (the production path; see
//!   [`crate::runtime`]).
//! * [`TimingTable::analytic`] — a pure-Rust port of the same circuit
//!   model (`python/compile/kernels/circuit.py`), used as a fallback when
//!   artifacts are absent and as a cross-language consistency oracle in
//!   tests.

/// Circuit constants mirroring `python/compile/kernels/circuit.py`.
/// The calibration is re-derived here with the same closed forms so the
/// two languages cannot drift silently (tests compare against the HLO).
pub mod circuit {
    pub const VDD: f64 = 1.5;
    pub const VBL_PRE: f64 = VDD / 2.0;
    pub const C_CELL_F: f64 = 24e-15;
    pub const C_BL_F: f64 = 85e-15;
    pub const CS_RATIO: f64 = C_CELL_F / (C_CELL_F + C_BL_F);
    pub const V_READY: f64 = 0.75 * VDD;
    pub const V_RESTORE: f64 = 0.95 * VDD;
    pub const T_CS_NS: f64 = 2.0;
    pub const TAU_R0_NS: f64 = 2.2;
    pub const T_READY_FULL_NS: f64 = 10.0;
    pub const T_READY_WORST_NS: f64 = 14.5;
    pub const T_RESTORE_DELTA_NS: f64 = 9.6;
    pub const T_REFRESH_MS: f64 = 64.0;
    pub const T_CAL_CELSIUS: f64 = 85.0;
    pub const DT_NS: f64 = 0.01;
    pub const N_STEPS: usize = 4000;

    fn x0_of_vcell(v_cell: f64) -> f64 {
        (v_cell - VBL_PRE) * CS_RATIO
    }

    fn ln_g(x0: f64) -> f64 {
        let xm = VDD / 2.0;
        let xr = V_READY - VBL_PRE;
        ((xr * xr * (xm * xm - x0 * x0)) / (x0 * x0 * (xm * xm - xr * xr))).ln()
    }

    /// (sense-amp gain A [1/ns], retention tau [ms] @ 85C) — closed form.
    pub fn calibrate() -> (f64, f64) {
        let x0_full = x0_of_vcell(VDD);
        let a = ln_g(x0_full) / (2.0 * (T_READY_FULL_NS - T_CS_NS));
        let ln_g_worst = 2.0 * a * (T_READY_WORST_NS - T_CS_NS);
        let xm = VDD / 2.0;
        let xr = V_READY - VBL_PRE;
        let g = ln_g_worst.exp();
        let k = g * (xm * xm - xr * xr) / (xr * xr);
        let x0_w = (xm * xm / (k + 1.0)).sqrt();
        let v_worst = VBL_PRE + x0_w / CS_RATIO;
        let frac = (v_worst - VBL_PRE) / (VDD - VBL_PRE);
        let tau_ms = -T_REFRESH_MS / frac.ln();
        (a, tau_ms)
    }

    /// Restore time constant with depletion-dependent overdrive.
    pub fn tau_r_ns(v_cell0: f64, beta: f64) -> f64 {
        TAU_R0_NS * (1.0 + beta * (VDD - v_cell0) / VDD)
    }

    /// Euler-integrate one lane; returns (t_ready_ns, t_restore_ns).
    /// Same discretization as the Pallas kernel.
    pub fn sense_latency(v_cell0: f64, a: f64, beta: f64) -> (f64, f64) {
        let mut v_bl = VBL_PRE + (v_cell0 - VBL_PRE) * CS_RATIO;
        let mut v_c = v_bl;
        let tr = tau_r_ns(v_cell0, beta);
        let xm = VDD / 2.0;
        let dead = T_CS_NS / DT_NS;
        let (mut below_ready, mut below_restore) = (0u64, 0u64);
        for i in 0..N_STEPS {
            let on = if (i as f64) >= dead { 1.0 } else { 0.0 };
            let x = v_bl - VBL_PRE;
            let v_bl_next = v_bl + a * x * (1.0 - (x / xm) * (x / xm)) * on * DT_NS;
            v_c += (v_bl - v_c) / tr * on * DT_NS;
            v_bl = v_bl_next;
            if v_bl < V_READY {
                below_ready += 1;
            }
            if v_c < V_RESTORE {
                below_restore += 1;
            }
        }
        (below_ready as f64 * DT_NS, below_restore as f64 * DT_NS)
    }

    /// Bitline-voltage trajectory for one lane (same discretization as
    /// [`sense_latency`]), sampled every `stride` Euler steps — the
    /// pure-Rust stand-in for the `bitline_sweep` HLO artifact (Fig. 3)
    /// when the `pjrt` feature is off.
    pub fn bitline_trajectory(v_cell0: f64, a: f64, stride: usize) -> Vec<f64> {
        let mut v_bl = VBL_PRE + (v_cell0 - VBL_PRE) * CS_RATIO;
        let xm = VDD / 2.0;
        let dead = T_CS_NS / DT_NS;
        let stride = stride.max(1);
        let mut out = Vec::with_capacity(N_STEPS / stride + 1);
        for i in 0..N_STEPS {
            if i % stride == 0 {
                out.push(v_bl);
            }
            let on = if (i as f64) >= dead { 1.0 } else { 0.0 };
            let x = v_bl - VBL_PRE;
            v_bl += a * x * (1.0 - (x / xm) * (x / xm)) * on * DT_NS;
        }
        out
    }

    /// Calibrate the restore overdrive coefficient beta (bisection on the
    /// worst-vs-full restore delta == paper's 9.6 ns tRAS reduction).
    pub fn calibrate_restore(a: f64, tau_ms: f64) -> f64 {
        let v_worst = v_cell_after(T_REFRESH_MS * 1e-3, T_CAL_CELSIUS, tau_ms);
        let delta = |beta: f64| -> f64 {
            sense_latency(v_worst, a, beta).1 - sense_latency(VDD, a, beta).1
        };
        let (mut lo, mut hi) = (0.0f64, 20.0f64);
        for _ in 0..40 {
            let mid = 0.5 * (lo + hi);
            if delta(mid) < T_RESTORE_DELTA_NS {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Cell voltage after leaking for `t_ret_s` seconds at `temp_c`.
    pub fn v_cell_after(t_ret_s: f64, temp_c: f64, tau_ms_85: f64) -> f64 {
        let tau_s = tau_ms_85 * 1e-3 * 2.0f64.powf((T_CAL_CELSIUS - temp_c) / 10.0);
        VBL_PRE + (VDD - VBL_PRE) * (-t_ret_s / tau_s).exp()
    }
}

/// Age → legal (tRCD, tRAS) reduction table (ns domain, cycle-quantized on
/// query).
#[derive(Debug, Clone)]
pub struct TimingTable {
    /// Row ages in seconds (ascending).
    ages_s: Vec<f64>,
    /// Reductions in ns at each age: (tRCD reduction, tRAS reduction).
    reductions_ns: Vec<(f64, f64)>,
    /// Bus clock period used for cycle quantization.
    tck_ns: f64,
}

impl TimingTable {
    /// Standard age grid: log-spaced from 10 us to the 64 ms refresh window.
    pub fn default_age_grid(n: usize) -> Vec<f64> {
        let (lo, hi) = (1e-5f64, 0.064f64);
        (0..n)
            .map(|i| lo * (hi / lo).powf(i as f64 / (n - 1) as f64))
            .collect()
    }

    /// Build from a pre-computed table (the runtime path feeds HLO output
    /// here; see [`crate::runtime::charge_model`]).
    pub fn from_rows(ages_s: Vec<f64>, reductions_ns: Vec<(f64, f64)>, tck_ns: f64) -> Self {
        debug_assert_eq!(ages_s.len(), reductions_ns.len());
        debug_assert!(ages_s.windows(2).all(|w| w[0] <= w[1]));
        Self { ages_s, reductions_ns, tck_ns }
    }

    /// Pure-Rust analytic construction at `temp_c` (fallback + oracle).
    pub fn analytic(n: usize, temp_c: f64, tck_ns: f64) -> Self {
        let (a, tau_ms) = circuit::calibrate();
        let beta = circuit::calibrate_restore(a, tau_ms);
        let v_worst = circuit::v_cell_after(
            circuit::T_REFRESH_MS * 1e-3,
            circuit::T_CAL_CELSIUS,
            tau_ms,
        );
        let (worst_ready, worst_restore) = circuit::sense_latency(v_worst, a, beta);
        let ages = Self::default_age_grid(n);
        let reductions = ages
            .iter()
            .map(|&age| {
                let v = circuit::v_cell_after(age, temp_c, tau_ms);
                let (t_ready, t_restore) = circuit::sense_latency(v, a, beta);
                (
                    (worst_ready - t_ready).max(0.0),
                    (worst_restore - t_restore).max(0.0),
                )
            })
            .collect();
        Self::from_rows(ages, reductions, tck_ns)
    }

    /// Legal reduction in **bus cycles** for a row of age `age_s`
    /// (conservative: uses the next grid point at or above the age).
    pub fn reduction_cycles(&self, age_s: f64) -> (u64, u64) {
        let idx = match self
            .ages_s
            .binary_search_by(|probe| probe.partial_cmp(&age_s).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.ages_s.len() - 1),
        };
        let (rcd_ns, ras_ns) = self.reductions_ns[idx];
        (
            (rcd_ns / self.tck_ns).round() as u64,
            (ras_ns / self.tck_ns).round() as u64,
        )
    }

    /// Reduction in ns at the given age (same conservative lookup).
    pub fn reduction_ns(&self, age_s: f64) -> (f64, f64) {
        let idx = match self
            .ages_s
            .binary_search_by(|probe| probe.partial_cmp(&age_s).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.ages_s.len() - 1),
        };
        self.reductions_ns[idx]
    }

    pub fn len(&self) -> usize {
        self.ages_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ages_s.is_empty()
    }

    pub fn ages(&self) -> &[f64] {
        &self.ages_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_paper_endpoints() {
        let (a, tau_ms) = circuit::calibrate();
        let beta = circuit::calibrate_restore(a, tau_ms);
        let (t_full, r_full) = circuit::sense_latency(circuit::VDD, a, beta);
        let v_worst =
            circuit::v_cell_after(0.064, circuit::T_CAL_CELSIUS, tau_ms);
        let (t_worst, r_worst) = circuit::sense_latency(v_worst, a, beta);
        assert!((t_full - 10.0).abs() < 0.05, "t_full={t_full}");
        assert!((t_worst - 14.5).abs() < 0.05, "t_worst={t_worst}");
        assert!(((t_worst - t_full) - 4.5).abs() < 0.1);
        assert!(((r_worst - r_full) - 9.6).abs() < 0.1);
    }

    #[test]
    fn one_ms_grants_paper_cycle_reductions() {
        // The Table 1 operating point: 1 ms duration -> -4 tRCD, -8 tRAS.
        let t = TimingTable::analytic(64, 85.0, 1.25);
        let (rcd, ras) = t.reduction_cycles(1e-3);
        assert_eq!(rcd, 4);
        assert_eq!(ras, 8);
    }

    #[test]
    fn reductions_monotone_nonincreasing_with_age() {
        let t = TimingTable::analytic(64, 85.0, 1.25);
        let mut prev = (f64::INFINITY, f64::INFINITY);
        for &age in t.ages() {
            let r = t.reduction_ns(age);
            assert!(r.0 <= prev.0 + 1e-9 && r.1 <= prev.1 + 1e-9);
            prev = r;
        }
    }

    #[test]
    fn refresh_window_age_grants_nothing() {
        let t = TimingTable::analytic(64, 85.0, 1.25);
        let (rcd, ras) = t.reduction_cycles(0.064);
        assert_eq!(rcd, 0);
        assert!(ras <= 1);
    }

    #[test]
    fn colder_grants_at_least_as_much() {
        let hot = TimingTable::analytic(32, 85.0, 1.25);
        let cold = TimingTable::analytic(32, 45.0, 1.25);
        for &age in hot.ages() {
            assert!(cold.reduction_ns(age).0 >= hot.reduction_ns(age).0 - 1e-9);
        }
    }

    #[test]
    fn bitline_trajectory_crossing_matches_sense_latency() {
        let (a, tau_ms) = circuit::calibrate();
        let beta = circuit::calibrate_restore(a, tau_ms);
        let traj = circuit::bitline_trajectory(circuit::VDD, a, 1);
        let cross = traj.iter().position(|&v| v >= circuit::V_READY).unwrap();
        let t_cross = cross as f64 * circuit::DT_NS;
        let (t_ready, _) = circuit::sense_latency(circuit::VDD, a, beta);
        assert!(
            (t_cross - t_ready).abs() <= 2.0 * circuit::DT_NS,
            "trajectory crossing {t_cross} ns vs sense_latency {t_ready} ns"
        );
    }

    #[test]
    fn conservative_lookup_rounds_age_up() {
        let t = TimingTable::from_rows(
            vec![1e-4, 1e-3, 1e-2],
            vec![(5.0, 10.0), (4.5, 9.6), (2.0, 4.0)],
            1.25,
        );
        // An age between grid points must use the older (weaker) row.
        assert_eq!(t.reduction_ns(5e-4), (4.5, 9.6));
        assert_eq!(t.reduction_ns(1e-3), (4.5, 9.6));
        assert_eq!(t.reduction_ns(2e-3), (2.0, 4.0));
        // Beyond the grid: clamp to the last (weakest) row.
        assert_eq!(t.reduction_ns(1.0), (2.0, 4.0));
    }
}
