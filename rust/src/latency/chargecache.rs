//! ChargeCache — the paper's mechanism (Sec. 5).
//!
//! A small set-associative table in the memory controller, the
//! *Highly-Charged Row Address Cache* (HCRAC), replicated per core (this
//! instance covers one channel). Three operations:
//!
//! 1. On **PRE**, insert the closed row's address — its cells were just
//!    replenished by the activation, so it is highly charged *now*.
//! 2. On **ACT**, look the row up; a hit younger than the caching duration
//!    grants reduced tRCD/tRAS.
//! 3. Entries older than the caching duration are invalidated so a
//!    low-charge row is never accessed with lowered timing (correctness
//!    criterion; here enforced exactly at lookup, plus a periodic sweep
//!    that models the paper's hardware invalidation and keeps occupancy
//!    statistics honest).

use crate::config::{HcracPolicy, HcracSharing, SystemConfig};
use crate::trace::XorShift64;

use super::{Mechanism, RowKey, TimingGrant};

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    valid: bool,
    key: u64,
    inserted_at: u64,
    /// LRU stamp (monotone counter; lower = older).
    lru: u64,
}

/// One per-core HCRAC replica: `sets x ways` with LRU replacement.
#[derive(Debug, Clone)]
struct CoreTable {
    entries: Vec<Entry>,
    sets: usize,
    ways: usize,
    stamp: u64,
}

impl CoreTable {
    fn new(entries: usize, ways: usize) -> Self {
        let sets = (entries / ways).max(1);
        Self { entries: vec![Entry::default(); sets * ways], sets, ways, stamp: 0 }
    }

    #[inline]
    fn set_index(&self, key: RowKey) -> usize {
        // Multiplicative hash over the packed (rank, bank, row) key: rows
        // are low bits, so this spreads sequential rows across sets.
        let h = key.0.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) % self.sets
    }

    /// Look up `key`; on hit younger than `max_age` return true and touch
    /// LRU. Stale hits are invalidated eagerly.
    fn lookup(&mut self, key: RowKey, now: u64, max_age: u64) -> bool {
        let base = self.set_index(key) * self.ways;
        self.stamp += 1;
        for e in &mut self.entries[base..base + self.ways] {
            if e.valid && e.key == key.0 {
                if now.saturating_sub(e.inserted_at) <= max_age {
                    e.lru = self.stamp;
                    return true;
                }
                // Expired: invalidate (periodic invalidation, done exactly).
                e.valid = false;
                return false;
            }
        }
        false
    }

    /// Insert `key` at `now`, evicting the LRU way of its set.
    /// `promote=false` (BIP cold insertion) leaves the entry in LRU
    /// position so a thrashing stream cannot flush the whole set.
    fn insert(&mut self, key: RowKey, now: u64, promote: bool) {
        let base = self.set_index(key) * self.ways;
        self.stamp += 1;
        let set = &mut self.entries[base..base + self.ways];
        // Re-insertion of an existing key refreshes its timestamp.
        if let Some(e) = set.iter_mut().find(|e| e.valid && e.key == key.0) {
            e.inserted_at = now;
            e.lru = self.stamp;
            return;
        }
        // Invalid ways win outright (false < true); among valid ways the
        // lowest LRU stamp loses. Keying both on a bare `e.lru` would map
        // an invalid way and a BIP-cold-inserted way (lru = 0) to the
        // same key, letting `min_by_key`'s first-wins tie-break evict a
        // live entry while an empty way sits in the set.
        let victim = set.iter_mut().min_by_key(|e| (e.valid, e.lru)).expect("ways >= 1");
        let lru = if promote { self.stamp } else { 0 };
        *victim = Entry { valid: true, key: key.0, inserted_at: now, lru };
    }

    /// Drop `key`'s entry if present (violation mitigation). Returns
    /// true if a valid entry was evicted.
    fn invalidate(&mut self, key: RowKey) -> bool {
        let base = self.set_index(key) * self.ways;
        for e in &mut self.entries[base..base + self.ways] {
            if e.valid && e.key == key.0 {
                e.valid = false;
                return true;
            }
        }
        false
    }

    /// Periodic sweep: drop entries older than `max_age`.
    fn invalidate_older_than(&mut self, now: u64, max_age: u64) {
        for e in &mut self.entries {
            if e.valid && now.saturating_sub(e.inserted_at) > max_age {
                e.valid = false;
            }
        }
    }

    fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }
}

/// ChargeCache mechanism state for one memory channel.
pub struct ChargeCache {
    tables: Vec<CoreTable>,
    /// Caching duration in bus cycles.
    duration_cycles: u64,
    trcd_std: u64,
    tras_std: u64,
    trcd_red: u64,
    tras_red: u64,
    /// Sweep cadence for the periodic hardware invalidation model.
    sweep_interval: u64,
    next_sweep: u64,
    /// Insertion policy (LRU / bimodal).
    policy: HcracPolicy,
    /// BIP: RNG for the epsilon (1/32) promoted insertions.
    bip_rng: XorShift64,
    /// Statistics: activations that hit / total activations seen.
    pub hits: u64,
    pub lookups: u64,
    pub inserts: u64,
}

impl ChargeCache {
    pub fn new(cfg: &SystemConfig) -> Self {
        let duration_cycles = cfg.timing.ms_to_cycles(cfg.chargecache.duration_ms);
        // Shared design (paper footnote 3): one table with the same total
        // capacity instead of per-core replicas.
        let tables = match cfg.chargecache.sharing {
            HcracSharing::PerCore => (0..cfg.cpu.cores)
                .map(|_| CoreTable::new(cfg.chargecache.entries_per_core, cfg.chargecache.ways))
                .collect(),
            HcracSharing::Shared => vec![CoreTable::new(
                cfg.chargecache.entries_per_core * cfg.cpu.cores,
                cfg.chargecache.ways,
            )],
        };
        Self {
            tables,
            duration_cycles,
            trcd_std: cfg.timing.trcd,
            tras_std: cfg.timing.tras,
            trcd_red: cfg.timing.trcd - cfg.chargecache.trcd_reduction,
            tras_red: cfg.timing.tras - cfg.chargecache.tras_reduction,
            // Paper: entries checked periodically; an eighth of the duration
            // bounds staleness error while staying cheap in hardware.
            sweep_interval: (duration_cycles / 8).max(1),
            next_sweep: duration_cycles / 8,
            policy: cfg.chargecache.policy,
            bip_rng: XorShift64::new(cfg.seed ^ 0xB1B0),
            hits: 0,
            lookups: 0,
            inserts: 0,
        }
    }

    /// Total valid entries across core replicas (for tests/telemetry).
    pub fn occupancy(&self) -> usize {
        self.tables.iter().map(|t| t.occupancy()).sum()
    }

    /// Table replica for a request owner. LLC writebacks carry no owning
    /// core (u32::MAX); they are attributed to the last replica, which
    /// keeps their row tracking without polluting a specific core's table
    /// unfairly.
    #[inline]
    fn table_idx(&self, core: u32) -> usize {
        (core as usize).min(self.tables.len() - 1)
    }

    fn maybe_sweep(&mut self, now: u64) {
        if now >= self.next_sweep {
            for t in &mut self.tables {
                t.invalidate_older_than(now, self.duration_cycles);
            }
            self.next_sweep = now + self.sweep_interval;
        }
    }
}

impl Mechanism for ChargeCache {
    fn on_activate(&mut self, now: u64, core: u32, key: RowKey) -> TimingGrant {
        self.maybe_sweep(now);
        self.lookups += 1;
        let idx = self.table_idx(core);
        let hit = self.tables[idx].lookup(key, now, self.duration_cycles);
        if hit {
            self.hits += 1;
            TimingGrant { trcd: self.trcd_red, tras: self.tras_red, reduced: true }
        } else {
            TimingGrant { trcd: self.trcd_std, tras: self.tras_std, reduced: false }
        }
    }

    fn on_precharge(&mut self, now: u64, core: u32, key: RowKey) {
        self.maybe_sweep(now);
        self.inserts += 1;
        let promote = match self.policy {
            HcracPolicy::Lru => true,
            // BIP: promote with epsilon = 1/32 (Qureshi et al.).
            HcracPolicy::Bip => self.bip_rng.below(32) == 0,
        };
        let idx = self.table_idx(core);
        self.tables[idx].insert(key, now, promote);
    }

    fn on_refresh(&mut self, _now: u64, _rank: u32, _refresh_count: u64) {
        // Refresh replenishes rows but ChargeCache does not track it
        // (that is NUAT's domain); nothing to do.
    }

    fn on_violation(&mut self, _now: u64, core: u32, key: RowKey) -> bool {
        // Evict from the replica that produced the violating grant. With
        // per-core replicas another core may still hold the row; the
        // sink-level blacklist catches repeat offenders globally.
        let idx = self.table_idx(core);
        self.tables[idx].invalidate(key)
    }

    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        enc.usize(self.tables.len());
        for t in &self.tables {
            enc.usize(t.entries.len());
            for e in &t.entries {
                enc.bool(e.valid);
                enc.u64(e.key);
                enc.u64(e.inserted_at);
                enc.u64(e.lru);
            }
            enc.u64(t.stamp);
        }
        enc.u64(self.next_sweep);
        enc.u64(self.bip_rng.state());
        enc.u64(self.hits);
        enc.u64(self.lookups);
        enc.u64(self.inserts);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        if dec.usize()? != self.tables.len() {
            return None; // replica count is config-derived shape
        }
        for t in self.tables.iter_mut() {
            if dec.usize()? != t.entries.len() {
                return None;
            }
            for e in t.entries.iter_mut() {
                e.valid = dec.bool()?;
                e.key = dec.u64()?;
                e.inserted_at = dec.u64()?;
                e.lru = dec.u64()?;
            }
            t.stamp = dec.u64()?;
        }
        self.next_sweep = dec.u64()?;
        self.bip_rng = XorShift64::from_state(dec.u64()?);
        self.hits = dec.u64()?;
        self.lookups = dec.u64()?;
        self.inserts = dec.u64()?;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cc() -> ChargeCache {
        ChargeCache::new(&SystemConfig::default())
    }

    fn key(row: u32) -> RowKey {
        RowKey::new(0, 0, row)
    }

    #[test]
    fn miss_then_insert_then_hit() {
        let mut c = cc();
        assert!(!c.on_activate(0, 0, key(5)).reduced);
        c.on_precharge(100, 0, key(5));
        let g = c.on_activate(200, 0, key(5));
        assert!(g.reduced);
        assert_eq!(g.trcd, 7);
        assert_eq!(g.tras, 20);
        assert_eq!(c.hits, 1);
        assert_eq!(c.lookups, 2);
    }

    #[test]
    fn entry_expires_after_duration() {
        let mut c = cc();
        let dur = c.duration_cycles;
        c.on_precharge(0, 0, key(9));
        assert!(c.on_activate(dur, 0, key(9)).reduced); // exactly at limit: ok
        c.on_precharge(0, 0, key(10));
        assert!(!c.on_activate(dur + 1, 0, key(10)).reduced); // past limit
    }

    #[test]
    fn per_core_isolation() {
        let mut cfg = SystemConfig::default();
        cfg.cpu.cores = 2;
        let mut c = ChargeCache::new(&cfg);
        c.on_precharge(0, 0, key(7));
        assert!(!c.on_activate(10, 1, key(7)).reduced, "core 1 must miss");
        assert!(c.on_activate(10, 0, key(7)).reduced, "core 0 must hit");
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        // 1 set x 2 ways: third distinct key in the same set evicts LRU.
        let mut cfg = SystemConfig::default();
        cfg.chargecache.entries_per_core = 2;
        cfg.chargecache.ways = 2;
        let mut c = ChargeCache::new(&cfg);
        c.on_precharge(0, 0, key(1));
        c.on_precharge(1, 0, key(2));
        // Touch key(1) so key(2) becomes LRU.
        assert!(c.on_activate(2, 0, key(1)).reduced);
        c.on_precharge(3, 0, key(3)); // evicts key(2)
        assert!(!c.on_activate(4, 0, key(2)).reduced);
        assert!(c.on_activate(4, 0, key(3)).reduced);
    }

    #[test]
    fn cold_insert_never_evicts_over_an_empty_way() {
        // Regression: a BIP cold insertion leaves an entry at lru = 0,
        // the same victim key the old code gave invalid ways — so the
        // next insert could evict the live cold entry while an empty way
        // existed. Drive CoreTable directly (1 set x 2 ways).
        let mut t = CoreTable::new(2, 2);
        let k = |row: u32| RowKey::new(0, 0, row);
        t.insert(k(1), 0, false); // cold insert: lands with lru = 0
        assert_eq!(t.occupancy(), 1);
        t.insert(k(2), 1, true); // must fill the empty way, not evict k1
        assert_eq!(t.occupancy(), 2, "second insert must use the empty way");
        assert!(t.lookup(k(1), 2, 1000), "cold entry survived");
        assert!(t.lookup(k(2), 2, 1000));
        // With the set now full, a further insert evicts the true LRU
        // (the cold entry, which was never touched before the lookups
        // above promoted it — so after touching k1 then k2, k1 is LRU).
        t.insert(k(3), 3, true);
        assert_eq!(t.occupancy(), 2);
        assert!(!t.lookup(k(1), 4, 1000), "LRU entry evicted");
        assert!(t.lookup(k(2), 4, 1000));
        assert!(t.lookup(k(3), 4, 1000));
    }

    #[test]
    fn violation_evicts_the_entry() {
        let mut c = cc();
        c.on_precharge(0, 0, key(6));
        assert!(c.on_activate(10, 0, key(6)).reduced);
        assert!(c.on_violation(10, 0, key(6)), "entry was cached, must evict");
        assert!(!c.on_activate(11, 0, key(6)).reduced, "evicted row must miss");
        assert!(!c.on_violation(12, 0, key(6)), "nothing left to evict");
        // The next precharge re-inserts it as usual.
        c.on_precharge(20, 0, key(6));
        assert!(c.on_activate(30, 0, key(6)).reduced);
    }

    #[test]
    fn reinsert_refreshes_age() {
        let mut c = cc();
        let dur = c.duration_cycles;
        c.on_precharge(0, 0, key(4));
        c.on_precharge(dur, 0, key(4)); // re-close refreshes charge
        assert!(c.on_activate(dur + dur / 2, 0, key(4)).reduced);
    }

    #[test]
    fn periodic_sweep_prunes_occupancy() {
        let mut c = cc();
        let dur = c.duration_cycles;
        for r in 0..64 {
            c.on_precharge(0, 0, key(r));
        }
        assert!(c.occupancy() > 0);
        // Drive time past duration via an activate (triggers sweep).
        c.on_activate(2 * dur, 0, key(10_000));
        assert_eq!(c.occupancy(), 0);
    }

    #[test]
    fn storage_entries_match_config() {
        let cfg = SystemConfig::default();
        let c = ChargeCache::new(&cfg);
        let total: usize = c.tables.iter().map(|t| t.entries.len()).sum();
        assert_eq!(total, cfg.chargecache.entries_per_core * cfg.cpu.cores);
    }

    #[test]
    fn shared_table_serves_cross_core_hits() {
        // Footnote 3 design: core 1 benefits from core 0's precharge.
        let mut cfg = SystemConfig::default();
        cfg.cpu.cores = 4;
        cfg.chargecache.sharing = crate::config::HcracSharing::Shared;
        let mut c = ChargeCache::new(&cfg);
        assert_eq!(c.tables.len(), 1);
        assert_eq!(c.tables[0].entries.len(), 128 * 4);
        c.on_precharge(0, 0, key(7));
        assert!(c.on_activate(10, 1, key(7)).reduced, "cross-core hit");
        assert!(c.on_activate(10, 3, key(7)).reduced);
    }

    #[test]
    fn bip_resists_thrashing_streams() {
        // A scan of many one-shot rows must not flush a reused row out of
        // a BIP table, while it does flush it from LRU.
        let run = |policy: crate::config::HcracPolicy| -> bool {
            let mut cfg = SystemConfig::default();
            cfg.chargecache.entries_per_core = 4; // 2 sets x 2 ways
            cfg.chargecache.policy = policy;
            let mut c = ChargeCache::new(&cfg);
            c.on_precharge(0, 0, key(1));
            c.on_activate(1, 0, key(1)); // promote the reused row
            c.on_precharge(2, 0, key(1));
            // Thrash with 64 distinct rows.
            for r in 100..164 {
                c.on_precharge(3, 0, key(r));
            }
            c.on_activate(10, 0, key(1)).reduced
        };
        assert!(!run(crate::config::HcracPolicy::Lru), "LRU should thrash");
        assert!(run(crate::config::HcracPolicy::Bip), "BIP should retain");
    }

    #[test]
    fn bip_still_caches_reused_rows() {
        let mut cfg = SystemConfig::default();
        cfg.chargecache.policy = crate::config::HcracPolicy::Bip;
        let mut c = ChargeCache::new(&cfg);
        c.on_precharge(0, 0, key(5));
        assert!(c.on_activate(10, 0, key(5)).reduced);
    }
}
