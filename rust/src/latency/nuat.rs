//! NUAT (Shin et al., HPCA 2014) — the paper's main comparison point.
//!
//! NUAT grants reduced timing to rows that were **recently refreshed**:
//! right after a refresh, a row's cells are replenished, exactly like after
//! an activation — but NUAT only exploits the *refresh* replenishment, not
//! the access replenishment (that is ChargeCache's insight). Because the
//! refresh pointer sweeps all rows once per retention window, only a
//! `window / retention` fraction of rows is ever eligible — hence NUAT's
//! much smaller benefit (paper Sec. 6.3 / Sec. 7).
//!
//! Model: all-bank REF commands rotate through `rows / refs_per_window`
//! row groups; a row's last-refresh time is reconstructed from the rank's
//! REF counter.

use crate::config::SystemConfig;

use super::{Mechanism, RowKey, TimingGrant};

pub struct Nuat {
    /// Eligibility window in bus cycles after a row's refresh.
    window_cycles: u64,
    /// tREFI in bus cycles (REF k is assumed issued at ~k * tREFI).
    trefi: u64,
    /// Number of REF commands that cover all rows once (retention window).
    refs_per_window: u64,
    /// Rows advanced per REF (rows / refs_per_window).
    rows_per_ref: u64,
    /// Per-rank REF counters (mirrors the device's refresh engine).
    ref_count: Vec<u64>,
    trcd_std: u64,
    tras_std: u64,
    trcd_red: u64,
    tras_red: u64,
    pub hits: u64,
    pub lookups: u64,
}

impl Nuat {
    pub fn new(cfg: &SystemConfig) -> Self {
        // Retention window: 64 ms (8192 REFs at 7.8 us tREFI for 64K rows).
        let retention_cycles = cfg.timing.ms_to_cycles(64.0);
        let refs_est = (retention_cycles / cfg.timing.trefi).max(1);
        // Round rows-per-REF up so a full sweep fits the retention window.
        let rows_per_ref = ((cfg.dram.rows as u64) + refs_est - 1) / refs_est;
        let rows_per_ref = rows_per_ref.max(1).next_power_of_two();
        let refs_per_window = ((cfg.dram.rows as u64) / rows_per_ref).max(1);
        Self {
            window_cycles: cfg.timing.ms_to_cycles(cfg.nuat.window_ms),
            trefi: cfg.timing.trefi,
            refs_per_window,
            rows_per_ref,
            ref_count: vec![0; cfg.dram.ranks],
            trcd_std: cfg.timing.trcd,
            tras_std: cfg.timing.tras,
            trcd_red: cfg.timing.trcd - cfg.nuat.trcd_reduction,
            tras_red: cfg.timing.tras - cfg.nuat.tras_reduction,
            hits: 0,
            lookups: 0,
        }
    }

    /// Approximate cycle at which `row` was last refreshed, given the
    /// rank's REF counter (None if it has not been refreshed yet).
    fn last_refresh_cycle(&self, rank: u32, row: u32) -> Option<u64> {
        let count = self.ref_count[rank as usize];
        if count == 0 {
            return None;
        }
        let slot = (row as u64 / self.rows_per_ref) % self.refs_per_window;
        let last_idx = count - 1;
        // Largest k <= last_idx with k % refs_per_window == slot.
        let rem = last_idx % self.refs_per_window;
        let k = if rem >= slot {
            last_idx - (rem - slot)
        } else {
            let back = rem + self.refs_per_window - slot;
            if last_idx < back {
                return None;
            }
            last_idx - back
        };
        Some(k * self.trefi)
    }
}

impl Mechanism for Nuat {
    fn on_activate(&mut self, now: u64, _core: u32, key: RowKey) -> TimingGrant {
        self.lookups += 1;
        let hit = self
            .last_refresh_cycle(key.rank(), key.row())
            .is_some_and(|at| now.saturating_sub(at) <= self.window_cycles);
        if hit {
            self.hits += 1;
            TimingGrant { trcd: self.trcd_red, tras: self.tras_red, reduced: true }
        } else {
            TimingGrant { trcd: self.trcd_std, tras: self.tras_std, reduced: false }
        }
    }

    fn on_precharge(&mut self, _now: u64, _core: u32, _key: RowKey) {
        // NUAT ignores access-driven replenishment (the paper's point).
    }

    fn on_refresh(&mut self, _now: u64, rank: u32, refresh_count: u64) {
        self.ref_count[rank as usize] = refresh_count;
    }

    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        enc.usize(self.ref_count.len());
        for &c in &self.ref_count {
            enc.u64(c);
        }
        enc.u64(self.hits);
        enc.u64(self.lookups);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        if dec.usize()? != self.ref_count.len() {
            return None; // rank count is config-derived shape
        }
        for c in self.ref_count.iter_mut() {
            *c = dec.u64()?;
        }
        self.hits = dec.u64()?;
        self.lookups = dec.u64()?;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nuat() -> Nuat {
        Nuat::new(&SystemConfig::default())
    }

    #[test]
    fn geometry_covers_all_rows_per_window() {
        let n = nuat();
        // 64 ms / 7.8 us = 8192 REFs; 64K rows / 8192 = 8 rows per REF.
        assert_eq!(n.refs_per_window, 8192);
        assert_eq!(n.rows_per_ref, 8);
    }

    #[test]
    fn unrefreshed_rows_get_standard_timing() {
        let mut n = nuat();
        let g = n.on_activate(100, 0, RowKey::new(0, 0, 5));
        assert!(!g.reduced);
    }

    #[test]
    fn recently_refreshed_row_hits() {
        let mut n = nuat();
        // REF #0 covers rows 0..8 and is assumed issued at cycle 0.
        n.on_refresh(0, 0, 1);
        let g = n.on_activate(10, 0, RowKey::new(0, 0, 3));
        assert!(g.reduced);
        assert_eq!(g.trcd, 7);
        // Row 8 belongs to the next REF slot -> no grant yet.
        assert!(!n.on_activate(10, 0, RowKey::new(0, 0, 8)).reduced);
    }

    #[test]
    fn refresh_benefit_expires_after_window() {
        let mut n = nuat();
        n.on_refresh(0, 0, 1);
        let w = n.window_cycles;
        assert!(n.on_activate(w, 0, RowKey::new(0, 0, 1)).reduced);
        assert!(!n.on_activate(w + 1, 0, RowKey::new(0, 0, 1)).reduced);
    }

    #[test]
    fn access_does_not_extend_eligibility() {
        // Precharging (i.e. a full access) must not create NUAT eligibility.
        let mut n = nuat();
        n.on_precharge(0, 0, RowKey::new(0, 0, 42));
        assert!(!n.on_activate(1, 0, RowKey::new(0, 0, 42)).reduced);
    }

    #[test]
    fn eligible_fraction_is_small() {
        // With a 1 ms window and 64 ms retention, ~1/64 of rows eligible:
        // after many refreshes, random-row activations rarely hit.
        let mut n = nuat();
        // Simulate 8192 refreshes spaced tREFI apart (one full sweep).
        let trefi = n.trefi;
        for k in 1..=8192u64 {
            n.on_refresh(k * trefi, 0, k);
        }
        let now = 8192 * trefi;
        let mut hits = 0;
        let rows = 4096u32;
        for r in 0..rows {
            let row = r * 16 % 65536; // spread over the bank
            if n.on_activate(now, 0, RowKey::new(0, 0, row)).reduced {
                hits += 1;
            }
        }
        let frac = hits as f64 / rows as f64;
        assert!(frac < 0.05, "eligible fraction {frac} should be ~1/64");
        assert!(frac > 0.001, "some rows must be eligible, got {frac}");
    }
}
