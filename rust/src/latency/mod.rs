//! DRAM latency-reduction mechanisms — the paper's contribution and its
//! comparison points.
//!
//! * [`chargecache`] — **ChargeCache** (HCRAC): track recently-precharged
//!   rows; grant reduced tRCD/tRAS to re-activations within the caching
//!   duration (the paper's mechanism, Sec. 5).
//! * [`nuat`] — NUAT (Shin et al., HPCA'14): reduced timing only for rows
//!   *recently refreshed* (the paper's main comparison point).
//! * LL-DRAM — idealized: every activation gets reduced timing.
//!
//! All mechanisms sit behind the [`Mechanism`] trait, hooked by the memory
//! controller on every ACT/PRE/REF.

pub mod chargecache;
pub mod nuat;
pub mod timing_table;


use crate::config::SystemConfig;

pub use chargecache::ChargeCache;
pub use nuat::Nuat;
pub use timing_table::TimingTable;

/// Row identity (channel, rank, bank, row packed into 64 bits).
///
/// Mechanism and RLTL instances are per-channel, so keys were historically
/// only rank/bank/row-qualified. The controller now stamps its channel id
/// into every key it builds ([`RowKey::new_in_channel`]), so keys from
/// different channels can never silently collide if they ever meet in a
/// shared structure (merged RLTL histograms, a future cross-channel
/// HCRAC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RowKey(pub u64);

impl RowKey {
    /// Channel-0 key (single-channel paths and tests).
    pub fn new(rank: u32, bank: u32, row: u32) -> Self {
        Self::new_in_channel(0, rank, bank, row)
    }
    /// Fully-qualified key: `channel:8 | rank:8 | bank:16 | row:32`.
    pub fn new_in_channel(channel: u32, rank: u32, bank: u32, row: u32) -> Self {
        debug_assert!(channel < 256 && rank < 256, "key fields overflow packing");
        Self(
            ((channel as u64) << 56)
                | ((rank as u64) << 48)
                | ((bank as u64) << 32)
                | row as u64,
        )
    }
    pub fn row(&self) -> u32 {
        (self.0 & 0xffff_ffff) as u32
    }
    pub fn bank(&self) -> u32 {
        ((self.0 >> 32) & 0xffff) as u32
    }
    pub fn rank(&self) -> u32 {
        ((self.0 >> 48) & 0xff) as u32
    }
    pub fn channel(&self) -> u32 {
        (self.0 >> 56) as u32
    }
}

/// Timing granted for one activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimingGrant {
    /// Effective tRCD in bus cycles.
    pub trcd: u64,
    /// Effective tRAS in bus cycles.
    pub tras: u64,
    /// Whether the mechanism granted reduced timing.
    pub reduced: bool,
}

/// Which mechanism a simulation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MechanismKind {
    /// Standard DDR3 timing for every access.
    Baseline,
    /// The paper's mechanism.
    ChargeCache,
    /// Recently-refreshed-rows-only comparison point.
    Nuat,
    /// ChargeCache and NUAT combined (hit if either grants).
    ChargeCacheNuat,
    /// Idealized low-latency DRAM: all rows, all the time.
    LlDram,
}

/// One row of the mechanism name table: every string a mechanism is
/// known by, in one place. CLI parsing (`--mechanism`), scenario specs,
/// the config registry, figure labels, and result-cache file slugs all
/// derive from this table — there is deliberately no second list of
/// mechanism names anywhere in the crate.
#[derive(Debug, Clone, Copy)]
pub struct MechanismInfo {
    pub kind: MechanismKind,
    /// Canonical lowercase name (CLI `--mechanism`, scenario specs,
    /// `--set mechanism=`).
    pub name: &'static str,
    /// Display label (figure/table output, `SimResult::mechanism`).
    pub label: &'static str,
    /// Filename-safe slug (on-disk result-cache entries).
    pub slug: &'static str,
    /// Additional accepted spellings (parsing only, never printed).
    pub aliases: &'static [&'static str],
}

/// The single source of truth for mechanism names (see [`MechanismInfo`]).
pub const MECHANISM_TABLE: [MechanismInfo; 5] = [
    MechanismInfo {
        kind: MechanismKind::Baseline,
        name: "baseline",
        label: "Baseline",
        slug: "baseline",
        aliases: &["base"],
    },
    MechanismInfo {
        kind: MechanismKind::ChargeCache,
        name: "cc",
        label: "ChargeCache",
        slug: "cc",
        aliases: &["chargecache"],
    },
    MechanismInfo {
        kind: MechanismKind::Nuat,
        name: "nuat",
        label: "NUAT",
        slug: "nuat",
        aliases: &[],
    },
    MechanismInfo {
        kind: MechanismKind::ChargeCacheNuat,
        name: "cc+nuat",
        label: "CC+NUAT",
        slug: "ccnuat",
        aliases: &["chargecachenuat", "combined", "ccnuat"],
    },
    MechanismInfo {
        kind: MechanismKind::LlDram,
        name: "ll-dram",
        label: "LL-DRAM",
        slug: "lldram",
        aliases: &["lldram", "ll"],
    },
];

/// Canonical mechanism names in table order (CLI help, registry choices).
pub const MECHANISM_NAMES: [&str; 5] = [
    MECHANISM_TABLE[0].name,
    MECHANISM_TABLE[1].name,
    MECHANISM_TABLE[2].name,
    MECHANISM_TABLE[3].name,
    MECHANISM_TABLE[4].name,
];

impl MechanismKind {
    pub fn all() -> [MechanismKind; 5] {
        [
            MechanismKind::Baseline,
            MechanismKind::ChargeCache,
            MechanismKind::Nuat,
            MechanismKind::ChargeCacheNuat,
            MechanismKind::LlDram,
        ]
    }

    /// This mechanism's row in the name table.
    pub fn info(&self) -> MechanismInfo {
        *MECHANISM_TABLE.iter().find(|i| i.kind == *self).expect("every kind has a table row")
    }

    pub fn label(&self) -> &'static str {
        self.info().label
    }

    /// Canonical lowercase name (the parse/print round-trip identity).
    pub fn name(&self) -> &'static str {
        self.info().name
    }

    /// Parse any accepted spelling — canonical name, display label, or
    /// alias — case-insensitively.
    pub fn parse(s: &str) -> Option<MechanismKind> {
        let lower = s.to_ascii_lowercase();
        MECHANISM_TABLE
            .iter()
            .find(|i| {
                i.name == lower
                    || i.label.eq_ignore_ascii_case(&lower)
                    || i.aliases.contains(&lower.as_str())
            })
            .map(|i| i.kind)
    }

    /// `name | name | ...` list for unknown-mechanism error messages.
    pub fn valid_names() -> String {
        MECHANISM_NAMES.join(" | ")
    }
}

/// Per-channel mechanism hook. `now` is in DRAM bus cycles.
pub trait Mechanism: Send {
    /// Called when the controller issues an ACT for `core`'s request.
    fn on_activate(&mut self, now: u64, core: u32, key: RowKey) -> TimingGrant;
    /// Called when a row is closed (explicit PRE or auto-precharge).
    fn on_precharge(&mut self, now: u64, core: u32, key: RowKey);
    /// Called after each all-bank REF completes on `rank`.
    fn on_refresh(&mut self, now: u64, rank: u32, refresh_count: u64);

    /// A reduced-timing grant for `key` turned out to violate the row's
    /// true safe window ([`crate::controller::fault`]): the mechanism
    /// must stop granting reduced timing for it until the next
    /// precharge. Returns true if a cached entry was actually evicted.
    /// Mechanisms without a table (baseline, NUAT, LL-DRAM) keep the
    /// default no-op.
    fn on_violation(&mut self, _now: u64, _core: u32, _key: RowKey) -> bool {
        false
    }

    /// Checkpoint hook: stateless mechanisms (baseline, LL-DRAM) keep
    /// the defaults, which write/consume nothing.
    fn export_state(&self, _enc: &mut crate::sim::checkpoint::Enc) {}

    /// Restore what [`Mechanism::export_state`] wrote.
    fn import_state(&mut self, _dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        Some(())
    }
}

/// Baseline: standard timing always.
pub struct BaselineMech {
    trcd: u64,
    tras: u64,
}

impl BaselineMech {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self { trcd: cfg.timing.trcd, tras: cfg.timing.tras }
    }
}

impl Mechanism for BaselineMech {
    fn on_activate(&mut self, _now: u64, _core: u32, _key: RowKey) -> TimingGrant {
        TimingGrant { trcd: self.trcd, tras: self.tras, reduced: false }
    }
    fn on_precharge(&mut self, _now: u64, _core: u32, _key: RowKey) {}
    fn on_refresh(&mut self, _now: u64, _rank: u32, _refresh_count: u64) {}
}

/// LL-DRAM: idealized — reduced timing for every activation (paper Sec. 6.3
/// comparison upper bound).
pub struct LlDramMech {
    trcd: u64,
    tras: u64,
}

impl LlDramMech {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            trcd: cfg.timing.trcd - cfg.chargecache.trcd_reduction,
            tras: cfg.timing.tras - cfg.chargecache.tras_reduction,
        }
    }
}

impl Mechanism for LlDramMech {
    fn on_activate(&mut self, _now: u64, _core: u32, _key: RowKey) -> TimingGrant {
        TimingGrant { trcd: self.trcd, tras: self.tras, reduced: true }
    }
    fn on_precharge(&mut self, _now: u64, _core: u32, _key: RowKey) {}
    fn on_refresh(&mut self, _now: u64, _rank: u32, _refresh_count: u64) {}
}

/// Combination mechanism: grant the reduction if either component grants
/// (paper's "ChargeCache + NUAT" configuration).
pub struct CombinedMech {
    pub cc: ChargeCache,
    pub nuat: Nuat,
}

impl Mechanism for CombinedMech {
    fn on_activate(&mut self, now: u64, core: u32, key: RowKey) -> TimingGrant {
        let g_cc = self.cc.on_activate(now, core, key);
        let g_nu = self.nuat.on_activate(now, core, key);
        // Both components track the same physical fact (the row's cells
        // are highly charged), so when both grant, the activation is
        // entitled to the better of the two reductions. Taking the
        // element-wise minimum matters when the configs are asymmetric
        // (e.g. a NUAT sensitivity point with a deeper tRCD reduction
        // than ChargeCache's); with the default symmetric 4/8-cycle
        // reductions the minimum equals either grant.
        match (g_cc.reduced, g_nu.reduced) {
            (true, true) => TimingGrant {
                trcd: g_cc.trcd.min(g_nu.trcd),
                tras: g_cc.tras.min(g_nu.tras),
                reduced: true,
            },
            (true, false) => g_cc,
            (false, true) => g_nu,
            (false, false) => g_cc,
        }
    }
    fn on_precharge(&mut self, now: u64, core: u32, key: RowKey) {
        self.cc.on_precharge(now, core, key);
        self.nuat.on_precharge(now, core, key);
    }
    fn on_refresh(&mut self, now: u64, rank: u32, refresh_count: u64) {
        self.cc.on_refresh(now, rank, refresh_count);
        self.nuat.on_refresh(now, rank, refresh_count);
    }

    fn on_violation(&mut self, now: u64, core: u32, key: RowKey) -> bool {
        // No short-circuit: both components must drop the row.
        let a = self.cc.on_violation(now, core, key);
        let b = self.nuat.on_violation(now, core, key);
        a | b
    }

    fn export_state(&self, enc: &mut crate::sim::checkpoint::Enc) {
        self.cc.export_state(enc);
        self.nuat.export_state(enc);
    }

    fn import_state(&mut self, dec: &mut crate::sim::checkpoint::Dec) -> Option<()> {
        self.cc.import_state(dec)?;
        self.nuat.import_state(dec)
    }
}

/// Build the mechanism instance for one channel.
pub fn build_mechanism(kind: MechanismKind, cfg: &SystemConfig) -> Box<dyn Mechanism> {
    match kind {
        MechanismKind::Baseline => Box::new(BaselineMech::new(cfg)),
        MechanismKind::ChargeCache => Box::new(ChargeCache::new(cfg)),
        MechanismKind::Nuat => Box::new(Nuat::new(cfg)),
        MechanismKind::ChargeCacheNuat => Box::new(CombinedMech {
            cc: ChargeCache::new(cfg),
            nuat: Nuat::new(cfg),
        }),
        MechanismKind::LlDram => Box::new(LlDramMech::new(cfg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rowkey_packs_fields() {
        let k = RowKey::new(1, 7, 65535);
        assert_eq!(k.channel(), 0);
        assert_eq!(k.rank(), 1);
        assert_eq!(k.bank(), 7);
        assert_eq!(k.row(), 65535);
    }

    #[test]
    fn rowkey_channels_never_collide() {
        let a = RowKey::new_in_channel(0, 0, 3, 42);
        let b = RowKey::new_in_channel(1, 0, 3, 42);
        assert_ne!(a, b);
        assert_eq!(b.channel(), 1);
        assert_eq!(b.rank(), 0);
        assert_eq!(b.bank(), 3);
        assert_eq!(b.row(), 42);
        // Channel 0 keys keep the legacy packing.
        assert_eq!(a, RowKey::new(0, 3, 42));
    }

    #[test]
    fn baseline_never_reduces() {
        let cfg = SystemConfig::default();
        let mut m = BaselineMech::new(&cfg);
        let g = m.on_activate(0, 0, RowKey::new(0, 0, 0));
        assert!(!g.reduced);
        assert_eq!(g.trcd, 11);
        assert_eq!(g.tras, 28);
    }

    #[test]
    fn lldram_always_reduces() {
        let cfg = SystemConfig::default();
        let mut m = LlDramMech::new(&cfg);
        let g = m.on_activate(0, 0, RowKey::new(0, 0, 0));
        assert!(g.reduced);
        assert_eq!(g.trcd, 7);
        assert_eq!(g.tras, 20);
    }

    #[test]
    fn name_table_round_trips_every_kind() {
        for kind in MechanismKind::all() {
            assert_eq!(MechanismKind::parse(kind.name()), Some(kind));
            assert_eq!(MechanismKind::parse(kind.label()), Some(kind));
            for &alias in kind.info().aliases {
                assert_eq!(MechanismKind::parse(alias), Some(kind), "alias {alias}");
            }
        }
        assert_eq!(MechanismKind::parse("CC"), Some(MechanismKind::ChargeCache));
        assert_eq!(MechanismKind::parse("bogus"), None);
        assert!(MechanismKind::valid_names().contains("cc+nuat"));
    }

    #[test]
    fn combined_grant_takes_the_minimum_effective_timing() {
        // Regression: with asymmetric reductions (NUAT deeper than CC),
        // a row both mechanisms cover must get the better grant, not
        // unconditionally ChargeCache's.
        let mut cfg = SystemConfig::default();
        cfg.nuat.trcd_reduction = 6; // 11 - 6 = 5 < CC's 11 - 4 = 7
        cfg.nuat.tras_reduction = 10; // 28 - 10 = 18 < CC's 28 - 8 = 20
        let mut m = CombinedMech { cc: ChargeCache::new(&cfg), nuat: Nuat::new(&cfg) };
        // REF #0 covers rows 0..8 (assumed issued at cycle 0) — NUAT
        // eligibility; the precharge makes the same row a CC hit.
        m.on_refresh(0, 0, 1);
        let key = RowKey::new(0, 0, 3);
        m.on_precharge(0, 0, key);
        let g = m.on_activate(10, 0, key);
        assert!(g.reduced);
        assert_eq!(g.trcd, 5, "must take NUAT's deeper tRCD reduction");
        assert_eq!(g.tras, 18, "must take NUAT's deeper tRAS reduction");

        // CC-only hit (row outside the refreshed group) still grants CC's
        // reduction, and a NUAT-only hit grants NUAT's.
        let cc_only = RowKey::new(0, 0, 5000);
        m.on_precharge(20, 0, cc_only);
        let g_cc = m.on_activate(30, 0, cc_only);
        assert!(g_cc.reduced);
        assert_eq!((g_cc.trcd, g_cc.tras), (7, 20));
        let nuat_only = RowKey::new(0, 0, 4); // refreshed, never precharged
        let g_nu = m.on_activate(30, 0, nuat_only);
        assert!(g_nu.reduced);
        assert_eq!((g_nu.trcd, g_nu.tras), (5, 18));
    }
}
